"""Predicate normalization & implication (DESIGN.md §10): table-driven
interval cases, conjunct containment, normalized digests, residuals —
and the explicit NON-implications the semantic matcher must refuse."""
import numpy as np
import pytest

from repro.core import plan as P
from repro.dataflow.expr import (Col, Const, implies, pred_columns,
                                 pred_normal_key, residual_pred, to_cnf)
from repro.dataflow.table import Table

x, y = Col("x"), Col("y")

IMPLICATIONS = [
    # interval reasoning on one column
    (x > 20, x > 10),
    (x > 10, x > 10),
    (x >= 10, x >= 10),
    (x > 10, x >= 10),
    (x >= 11, x > 10),
    (x == 5, x >= 5),
    (x == 5, x <= 5),
    (x == 5, x > 4),
    (x == 5, x != 4),
    (x < 3, x < 10),
    (x < 10, x <= 10),
    (x <= 9, x < 10),
    (x > 10, x != 10),
    (x >= 11, x != 10),
    (x < 10, x != 10),
    # constant-on-the-left form is normalized into the same atom
    (Const(20) < x, x > 10),
    (x > 20, Const(10) < x),
    # conjunct subsets / commuted conjuncts
    ((x > 20) & (y < 3), x > 10),
    ((x > 20) & (y < 3), y < 3),
    ((x > 5) & (y < 3), (y < 3) & (x > 5)),
    ((x > 20) & (y < 3), (x > 10) & (y < 5)),
    # disjunction weakening
    (x > 20, (x > 10) | (y < 3)),
    ((x > 20) | (y < 1), (x > 10) | (y < 3)),
]

NON_IMPLICATIONS = [
    (x > 10, x > 20),                 # weaker never implies stronger
    (x >= 10, x > 10),                # boundary point
    (x != 10, x > 10),
    (x >= 5, x == 5),
    (x < 10, x < 3),
    (x > 10, y > 10),                 # disjoint columns
    ((x > 10) | (y < 3), x > 10),     # disjunction is weaker than atom
    (x > 10, (x > 10) & (y < 3)),     # missing conjunct
    ((x > 10) & (y < 5), (x > 20) & (y < 3)),
]


@pytest.mark.parametrize("p,q", IMPLICATIONS)
def test_implies(p, q):
    assert implies(p, q)


@pytest.mark.parametrize("p,q", NON_IMPLICATIONS)
def test_not_implies(p, q):
    assert not implies(p, q)


def test_implication_agrees_with_evaluation():
    """Every table-driven pair checked against brute-force evaluation
    over a value grid: implies=True rows must satisfy q wherever p."""
    vals = np.arange(-2, 25, dtype=np.int32)
    grid = Table.from_numpy({
        "x": np.repeat(vals, len(vals)),
        "y": np.tile(vals, len(vals)),
    })
    for p, q in IMPLICATIONS:
        pv = np.asarray(p.eval(grid)).astype(bool)
        qv = np.asarray(q.eval(grid)).astype(bool)
        assert not (pv & ~qv).any(), (p.key(), q.key())
    for p, q in NON_IMPLICATIONS:
        pv = np.asarray(p.eval(grid)).astype(bool)
        qv = np.asarray(q.eval(grid)).astype(bool)
        assert (pv & ~qv).any(), \
            f"counter-example missing on grid: {p.key()} vs {q.key()}"


# ---------------------------------------------------------------------------
# Normalized digests


def test_commuted_conjuncts_hash_equal():
    a = (x > 5) & (y < 3)
    b = (y < 3) & (x > 5)
    assert pred_normal_key(a) == pred_normal_key(b)
    fa = P.PhysicalPlan([P.store(P.filter_(P.load("t"), a), "o")])
    fb = P.PhysicalPlan([P.store(P.filter_(P.load("t"), b), "o")])
    assert P.plan_signature(fa) == P.plan_signature(fb)


def test_reassociated_conjuncts_hash_equal():
    a = ((x > 5) & (y < 3)) & (x != 0)
    b = (x > 5) & ((y < 3) & (x != 0))
    assert pred_normal_key(a) == pred_normal_key(b)


def test_flipped_comparison_hashes_equal():
    assert pred_normal_key(Const(5) < x) == pred_normal_key(x > 5)


def test_distinct_predicates_hash_differently():
    assert pred_normal_key(x > 5) != pred_normal_key(x > 6)
    assert pred_normal_key(x > 5) != pred_normal_key(x >= 5)
    assert pred_normal_key(x > 5) != pred_normal_key(y > 5)
    assert pred_normal_key((x > 5) & (y < 3)) != \
        pred_normal_key((x > 5) | (y < 3))


# ---------------------------------------------------------------------------
# Residuals (the compensation predicate)


def _sat(pred, t):
    return np.asarray(pred.eval(t)).astype(bool)


def test_residual_reconstructs_strong_predicate():
    rng = np.random.default_rng(0)
    t = Table.from_numpy({
        "x": rng.integers(0, 40, 256).astype(np.int32),
        "y": rng.integers(0, 10, 256).astype(np.int32),
    })
    cases = [
        ((x > 20) & (y < 3), x > 10),
        (x > 20, x > 10),
        ((x > 20) & (y < 3), (x > 10) & (y < 3)),
        (x == 5, x >= 5),
    ]
    for p, q in cases:
        r = residual_pred(p, q)
        assert r is not None
        assert np.array_equal(_sat(q, t) & _sat(r, t), _sat(p, t))


def test_residual_none_for_equivalent_predicates():
    assert residual_pred(x > 10, Const(10) < x) is None
    assert residual_pred((x > 5) & (y < 3), (y < 3) & (x > 5)) is None


def test_pred_columns_and_cnf_shape():
    p = (x > 20) & ((y < 3) | (x != 0))
    assert pred_columns(p) == {"x", "y"}
    clauses = to_cnf(p)
    assert len(clauses) == 2
    assert {len(c) for c in clauses} == {1, 2}


# ---------------------------------------------------------------------------
# Robustness: float32 rounding and CNF size bounds


def test_float32_collapsed_constants_refuse_implication():
    """Predicates evaluate against float32 columns: two reals that round
    to the same float32 make 'strictly stronger' unsound, so the checker
    must refuse (regression for the rounding soundness hole)."""
    f32_tenth = float(np.float32(0.1))          # 0.10000000149011612
    assert f32_tenth > 0.1                      # distinct as Python reals
    assert not implies(x >= f32_tenth, x > 0.1)
    assert not implies(x == 16777216.0, x != 16777217.0)  # f32-equal
    # float32-exact constants still imply
    assert implies(x > 20.5, x > 10.25)
    assert implies(x >= 11.0, x > 10.5)


def test_oversized_predicate_falls_back_without_blowup():
    """OR-over-AND distribution is exponential; past MAX_CNF_CLAUSES the
    digest falls back to the raw key and implication refuses — in linear
    time, not 2^n (regression for the fingerprinting blowup)."""
    import time

    from repro.dataflow.expr import MAX_CNF_CLAUSES, PredicateTooComplex

    big = None
    for i in range(20):
        term = (Col(f"a{i}") > 1) & (Col(f"b{i}") > 2)   # 2^20 clauses
        big = term if big is None else (big | term)
    t0 = time.time()
    key = pred_normal_key(big)
    assert not implies(big, big & (x > 0))
    assert residual_pred(big, big) is big      # sound full re-filter
    plan = P.PhysicalPlan([P.store(P.filter_(P.load("t"), big), "o")])
    plan.fingerprints()
    assert time.time() - t0 < 1.0, "must not distribute exponentially"
    assert key[0] == "rawpred"
    with pytest.raises(PredicateTooComplex):
        to_cnf(big)
    # small predicates keep the normal form
    small = (x > 5) & (y < 3)
    assert pred_normal_key(small)[0] == "cnf"
    assert len(to_cnf(small)) <= MAX_CNF_CLAUSES
