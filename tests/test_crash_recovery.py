"""Crash safety: WAL journal replay, SIGKILL-mid-publish recovery,
snapshot/journal damage tolerance (DESIGN.md §13).

The subprocess harness kills a real process (SIGKILL — no atexit, no
flush) while the write-behind flusher is mid-publish, then reopens the
store + journal in this process and asserts the recovery invariants:
zero orphaned ``.tmp-*`` dirs, zero repository entries pointing at
missing/unverifiable artifacts, and reuse still working for everything
published before the kill.
"""
import json
import os
import signal
import subprocess
import sys
import time

import pytest

from _service_util import fresh_driver, results_identical, run_mix
from repro.core.repository import Repository
from repro.core.restore import ReStore
from repro.core.serialize import load_repository, save_repository
from repro.service.journal import RepositoryJournal, replay_journal
from repro.store.artifacts import ArtifactStore, Catalog
from repro.workloads import pigmix

N_ROWS = 512
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = r"""
import sys, time
from repro.core.repository import Repository
from repro.core.restore import ReStore
from repro.service.journal import RepositoryJournal
from repro.store.artifacts import ArtifactStore, Catalog
from repro.workloads import pigmix

root, marker = sys.argv[1], sys.argv[2]


class StallAtPublish:
    # fault-injector shim: signal the parent, then hang the flusher
    # mid-publish (tmp dir fully written, rename not yet issued) so a
    # SIGKILL lands at the worst moment
    def __init__(self):
        self.armed = False

    def on(self, point, name, path=None):
        if self.armed and point == "publish":
            with open(marker + ".tmp", "w") as f:
                f.write(name)
            import os
            os.replace(marker + ".tmp", marker)
            time.sleep(600)


inj = StallAtPublish()
store = ArtifactStore(root=root, fault_injector=inj)
cat = Catalog(store)
pigmix.register_all(cat, n_rows=%(n_rows)d)
journal = RepositoryJournal(root)
repo = Repository()
repo.bind_journal(journal)
journal.repo = repo
drv = ReStore(cat, store, repo)

drv.run_plan(pigmix.L3("sum"))
store.flush()                       # first workflow fully durable
print("FLUSHED", flush=True)
inj.armed = True
drv.run_plan(pigmix.L2())           # second workflow: publish stalls
store.flush()                       # never returns; parent SIGKILLs
""" % {"n_rows": N_ROWS}


def _spawn_and_kill(tmp_path):
    root = str(tmp_path / "store")
    marker = str(tmp_path / "mid_publish")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    proc = subprocess.Popen([sys.executable, "-c", _CHILD, root, marker],
                            env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE)
    deadline = time.time() + 300
    while not os.path.exists(marker):
        if proc.poll() is not None:
            out, err = proc.communicate()
            raise AssertionError(
                f"child died before the kill point:\n{err.decode()}")
        assert time.time() < deadline, "child never reached mid-publish"
        time.sleep(0.01)
    os.kill(proc.pid, signal.SIGKILL)
    proc.wait(timeout=60)
    return root


def test_sigkill_mid_publish_recovers_clean(tmp_path):
    root = _spawn_and_kill(tmp_path)
    # the kill left an orphaned tmp dir behind (the stalled publish)
    assert any(d.startswith(".tmp-") for d in os.listdir(root)), \
        "harness must actually catch a mid-publish state"

    store = ArtifactStore(root=root)
    repo, journal = RepositoryJournal.recover(store)
    # invariant 1: no orphaned tmp dirs survive recovery
    assert not any(d.startswith(".tmp-") for d in os.listdir(root))
    # invariant 2: every surviving entry points at verified bytes
    for e in repo.entries:
        assert store.exists(e.artifact) and store.verify(e.artifact)
    assert journal.recovered_entries == len(repo.entries)
    assert journal.recovered_entries >= 1, \
        "the flushed first workflow must survive the crash"

    # reuse still works for everything published before the kill
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=N_ROWS)
    drv = ReStore(cat, store, repo)
    _, rep = drv.run_plan(pigmix.L3("sum"))
    assert rep.n_executed == 0, "whole-workflow reuse after recovery"

    # and interrupted work recomputes correctly from cold
    baseline = run_mix(fresh_driver(n_rows=N_ROWS))
    got = run_mix(drv)
    assert results_identical(baseline, got)


# ------------------------------------- SIGKILL mid-demotion (DESIGN.md §15)

_DEMOTE_CHILD = r"""
import sys, time
import numpy as np
from repro.dataflow.table import Table
from repro.store.artifacts import ArtifactStore
from repro.store.tiers import RemoteObjectStore

root, remote_root, marker = sys.argv[1], sys.argv[2], sys.argv[3]


class StallAfterRemotePublish:
    # blob published to the remote tier, local delete not yet issued —
    # a SIGKILL here leaves BOTH durable copies
    def on(self, point, name, path=None):
        if point == "remote_published":
            import os
            with open(marker + ".tmp", "w") as f:
                f.write(name)
            os.replace(marker + ".tmp", marker)
            time.sleep(600)


store = ArtifactStore(root=root,
                      remote=RemoteObjectStore(remote_root),
                      write_behind=False,
                      fault_injector=StallAfterRemotePublish())
rng = np.random.default_rng(0)
t = Table.from_numpy({"k": rng.integers(0, 99, 512).astype(np.int64),
                      "v": rng.random(512).astype(np.float32)})
store.put("victim", t)
print("PUT", flush=True)
store.demote_to_remote("victim")   # stalls mid-demotion; parent SIGKILLs
"""


def _crc_table(t):
    import zlib

    import numpy as np
    d = t.to_numpy()
    acc = 0
    for c in sorted(d):
        acc = zlib.crc32(np.ascontiguousarray(d[c]).tobytes(),
                         zlib.crc32(c.encode(), acc))
    return acc


def test_sigkill_mid_demotion_lower_tier_wins(tmp_path):
    """ISSUE 8 satellite: a kill between the remote publish and the
    local delete leaves both copies on disk — reopen must resolve
    ownership to the LOWER tier (verified remote wins) and serve the
    exact bytes."""
    root = str(tmp_path / "store")
    remote_root = str(tmp_path / "remote")
    marker = str(tmp_path / "mid_demote")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    proc = subprocess.Popen(
        [sys.executable, "-c", _DEMOTE_CHILD, root, remote_root, marker],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE)
    deadline = time.time() + 300
    while not os.path.exists(marker):
        if proc.poll() is not None:
            _, err = proc.communicate()
            raise AssertionError(
                f"child died before the kill point:\n{err.decode()}")
        assert time.time() < deadline, "child never reached mid-demotion"
        time.sleep(0.01)
    os.kill(proc.pid, signal.SIGKILL)
    proc.wait(timeout=60)

    from repro.store.artifacts import _encode_name
    from repro.store.tiers import RemoteObjectStore
    # the kill really landed mid-transition: both durable copies exist
    assert os.path.exists(os.path.join(root, _encode_name("victim"),
                                       "manifest.json"))
    remote = RemoteObjectStore(remote_root)
    assert remote.exists(_encode_name("victim"))

    store = ArtifactStore(root=root, remote=remote, write_behind=False)
    assert store.stats["remote_reconciled"] == 1
    assert store.authoritative_tier("victim") == "remote"
    assert not os.path.exists(os.path.join(root, _encode_name("victim"),
                                           "manifest.json"))
    import numpy as np
    rng = np.random.default_rng(0)
    from repro.dataflow.table import Table
    expect = Table.from_numpy(
        {"k": rng.integers(0, 99, 512).astype(np.int64),
         "v": rng.random(512).astype(np.float32)})
    assert _crc_table(store.get("victim")) == _crc_table(expect)
    store.close()


# ------------------------------------------------- journal unit behavior


def _disk_driver(tmp_path, journal=True):
    root = str(tmp_path / "store")
    store = ArtifactStore(root=root)
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=N_ROWS)
    repo = Repository()
    j = None
    if journal:
        j = RepositoryJournal(root)
        repo.bind_journal(j)
        j.repo = repo
    return ReStore(cat, store, repo), j, root


def test_recover_drops_entries_for_missing_artifacts(tmp_path):
    drv, _, root = _disk_driver(tmp_path)
    drv.run_plan(pigmix.L3("sum"))
    drv.store.flush()
    n = len(drv.repo)
    victim = drv.repo.entries[0].artifact
    import shutil
    from repro.store.artifacts import _encode_name
    shutil.rmtree(os.path.join(root, _encode_name(victim)))

    store2 = ArtifactStore(root=root)
    repo2, journal2 = RepositoryJournal.recover(store2)
    assert journal2.reconciled_drops == 1
    assert len(repo2) == n - 1
    assert all(e.artifact != victim for e in repo2.entries)


def test_corrupt_snapshot_falls_back_to_journal_replay(tmp_path):
    drv, j, root = _disk_driver(tmp_path)
    drv.run_plan(pigmix.L3("sum"))
    drv.store.flush()
    n = len(drv.repo)
    j.close()
    with open(j.snapshot_path, "w") as f:
        f.write("{ definitely not json")
    store2 = ArtifactStore(root=root)
    repo2, _ = RepositoryJournal.recover(store2)
    assert len(repo2) == n, "journal alone must rebuild the state"


def test_rotate_compacts_journal_and_roundtrips(tmp_path):
    drv, j, root = _disk_driver(tmp_path)
    drv.run_plan(pigmix.L3("sum"))
    drv.store.flush()
    n = len(drv.repo)
    assert j.appended > 0
    j.rotate(drv.repo)
    assert j.rotations == 1
    assert os.path.getsize(j.journal_path) == 0, "rotate truncates"
    snap = json.load(open(j.snapshot_path))
    assert len(snap["entries"]) == n
    j.close()
    store2 = ArtifactStore(root=root)
    repo2, _ = RepositoryJournal.recover(store2)
    assert len(repo2) == n


def test_auto_rotation_at_threshold(tmp_path):
    drv, j, root = _disk_driver(tmp_path)
    j.rotate_every = 5
    drv.run_plan(pigmix.L3("sum"))
    drv.run_plan(pigmix.L3("mean"))
    assert j.rotations >= 1
    store2 = ArtifactStore(root=root)
    repo2, _ = RepositoryJournal.recover(store2)
    assert len(repo2) == len(drv.repo)


def test_torn_journal_tail_is_tolerated(tmp_path):
    drv, j, root = _disk_driver(tmp_path)
    drv.run_plan(pigmix.L3("sum"))
    drv.store.flush()
    n = len(drv.repo)
    j.close()
    with open(j.journal_path, "a") as f:
        f.write('{"t": "add", "e": {"trunc')    # crash mid-append
    store2 = ArtifactStore(root=root)
    repo2, _ = RepositoryJournal.recover(store2)
    assert len(repo2) == n


def test_use_records_replay_post_update_totals(tmp_path):
    drv, j, root = _disk_driver(tmp_path)
    drv.run_plan(pigmix.L3("sum"))
    drv.run_plan(pigmix.L3("sum"))      # second run: reuse -> use records
    drv.store.flush()
    by_sig = {e.signature: e for e in drv.repo.entries}
    store2 = ArtifactStore(root=root)
    repo2, _ = RepositoryJournal.recover(store2)
    for e in repo2.entries:
        live = by_sig[e.signature]
        assert e.use_count == live.use_count
        assert e.saved_s_total == pytest.approx(live.saved_s_total)


def test_pins_are_not_restored(tmp_path):
    drv, j, root = _disk_driver(tmp_path)
    drv.run_plan(pigmix.L3("sum"))
    drv.store.flush()
    drv.repo.pin([drv.repo.entries[0].artifact])
    store2 = ArtifactStore(root=root)
    repo2, _ = RepositoryJournal.recover(store2)
    assert not repo2.pinned, "pins are run-scoped, never recovered"


def test_load_repository_corrupt_state_falls_back_to_journal(tmp_path):
    drv, j, root = _disk_driver(tmp_path)
    drv.run_plan(pigmix.L3("sum"))
    drv.store.flush()
    n = len(drv.repo)
    state = str(tmp_path / "state.json")
    save_repository(drv.repo, state)
    with open(state, "w") as f:
        f.write('{"entries": [truncated')
    with pytest.raises((ValueError, OSError)):
        load_repository(state)          # pre-§13 contract: raise
    repo2 = load_repository(state, journal_path=root)
    assert len(repo2) == n


def test_replay_journal_accepts_store_root_or_journal_dir(tmp_path):
    drv, j, root = _disk_driver(tmp_path)
    drv.run_plan(pigmix.L2())
    drv.store.flush()
    n = len(drv.repo)
    assert len(replay_journal(root)) == n
    assert len(replay_journal(os.path.join(root, "_journal"))) == n


def test_journal_dir_never_scanned_as_artifact(tmp_path):
    drv, j, root = _disk_driver(tmp_path)
    drv.run_plan(pigmix.L2())
    drv.store.flush()
    store2 = ArtifactStore(root=root)
    assert "_journal" not in store2.names()
    assert all("_journal" not in n for n in store2.names())
