"""Sharded artifact storage (DESIGN.md §11): per-partition shard files,
the manifest partition property, bit-identical round-trips vs the
monolithic layout, and re-partition-on-read when the shard count of a
stored artifact does not match the consumer's mesh."""
import tempfile

import numpy as np
import pytest

from repro.core.plan import Partitioning
from repro.dataflow.table import Table, partition_hash
from repro.store.artifacts import ArtifactStore


def make_table(n=200, nkeys=13, seed=0):
    rng = np.random.default_rng(seed)
    return Table.from_numpy({
        "k": rng.integers(0, nkeys, n).astype(np.int32),
        "k2": rng.integers(0, 5, n).astype(np.int32),
        "v": rng.integers(0, 100, n).astype(np.float32),
    })


def canon(tb: Table):
    d = tb.to_numpy()
    order = np.lexsort(tuple(d[c] for c in sorted(d, reverse=True)))
    return {c: d[c][order] for c in sorted(d)}


def assert_rows_equal(a: Table, b: Table):
    ca, cb = canon(a), canon(b)
    assert sorted(ca) == sorted(cb)
    for c in ca:
        assert ca[c].dtype == cb[c].dtype, c
        assert np.array_equal(ca[c], cb[c]), c


def assert_block_layout(t: Table, part: dict):
    """Every valid row of block i must hash to partition i."""
    cap = t.capacity
    n_parts = part["n_parts"]
    assert cap % n_parts == 0
    blk = cap // n_parts
    pid = np.asarray(partition_hash(t, part["keys"])) \
        % np.uint32(n_parts)
    mask = np.asarray(t.valid)
    assert np.array_equal(pid[mask],
                          (np.arange(cap) // blk)[mask])


def block_partitioned(store: ArtifactStore, name: str, keys, n_parts: int):
    """Store ``name``'s table re-laid-out in partition blocks, then put
    it back with the partition property (the layout a mesh producer
    creates naturally)."""
    t, part = store.get_partitioned(name, keys, n_parts)
    return t, part


# ---------------------------------------------------------------------------


def test_sharded_roundtrip_bit_identical_to_monolithic():
    t = make_table()
    root = tempfile.mkdtemp(prefix="part_store_")
    s = ArtifactStore(root=root)
    s.put("mono", t)
    tp, _ = block_partitioned(s, "mono", ["k"], 4)
    s.put("part", tp, partitioning={"keys": ["k"], "n_parts": 4})
    s.flush()
    s.close()

    s2 = ArtifactStore(root=root)      # fresh open: reads from disk
    part = s2.partitioning("part")
    assert part is not None
    assert part["keys"] == ["k"] and part["n_parts"] == 4
    assert part["shard_capacity"] * 4 == s2.get("part").capacity
    assert sum(part["shard_rows"]) == 200
    assert s2.partitioning("mono") is None
    assert_rows_equal(s2.get("mono"), s2.get("part"))
    assert_block_layout(s2.get("part"), part)
    s2.close()


def test_mismatched_p_repartitions_on_read():
    t = make_table(seed=3)
    s = ArtifactStore(root=tempfile.mkdtemp(prefix="part_store_"))
    s.put("a", t)
    tp, _ = block_partitioned(s, "a", ["k"], 4)
    s.put("art", tp, partitioning={"keys": ["k"], "n_parts": 4})
    s.flush()

    got, part = s.get_partitioned("art", ["k"], 8)   # P mismatch: 4 -> 8
    assert part["n_parts"] == 8
    assert_rows_equal(t, got)
    assert_block_layout(got, part)
    # second read serves the cached re-partitioned view
    got2, part2 = s.get_partitioned("art", ["k"], 8)
    assert got2 is got and part2 == part
    s.close()


def test_compatible_partitioning_loads_shuffle_free():
    t = make_table(seed=4)
    s = ArtifactStore(root=tempfile.mkdtemp(prefix="part_store_"))
    s.put("a", t)
    tp, _ = block_partitioned(s, "a", ["k"], 8)
    s.put("art", tp, partitioning={"keys": ["k"], "n_parts": 8})
    # subset keys cover a wider grouping: no re-partition needed
    got, part = s.get_partitioned("art", ["k", "k2"], 8)
    assert part["keys"] == ["k"]                  # stored property served
    assert got.capacity == s.get("art").capacity
    s.close()


def test_put_rejects_layout_violating_partition_claim():
    t = make_table(seed=5)
    s = ArtifactStore(root=tempfile.mkdtemp(prefix="part_store_"))
    with pytest.raises(ValueError):
        s.put("bad", t, partitioning={"keys": ["k"], "n_parts": 4})
    assert not s.exists("bad")
    s.close()


def test_delete_drops_shards_and_derived_views():
    t = make_table(seed=6)
    s = ArtifactStore(root=tempfile.mkdtemp(prefix="part_store_"))
    s.put("a", t)
    tp, _ = block_partitioned(s, "a", ["k"], 4)
    s.put("art", tp, partitioning={"keys": ["k"], "n_parts": 4})
    s.flush()
    s.get_partitioned("art", ["k"], 8)            # derived view cached
    s.delete("art")
    assert not s.exists("art")
    with pytest.raises(KeyError):
        s.get("art")
    # the derived re-partitioned view must not survive the delete
    assert not any(k.startswith("art#") for k in s._repart_meta)
    assert "art#repart8:k" not in s.cache
    s.close()


def test_reput_invalidates_derived_repartition_views():
    """A re-put of an artifact must drop cached ``#repart`` views —
    serving the OLD data's re-partitioned view to a mismatched-P
    consumer would silently aggregate stale rows."""
    s = ArtifactStore(root=tempfile.mkdtemp(prefix="part_store_"))
    t1 = make_table(seed=8)
    s.put("a", t1)
    v1, _ = s.get_partitioned("a", ["k"], 8)
    t2 = make_table(seed=9)              # different content, same name
    s.put("a", t2)
    v2, part = s.get_partitioned("a", ["k"], 8)
    assert v2 is not v1
    assert_rows_equal(t2, v2)
    assert_block_layout(v2, part)
    s.close()


def test_memory_backend_partitioned_roundtrip():
    t = make_table(seed=7)
    s = ArtifactStore()                           # no root: mem backend
    s.put("a", t)
    tp, _ = block_partitioned(s, "a", ["k"], 4)
    s.put("art", tp, partitioning={"keys": ["k"], "n_parts": 4})
    assert s.partitioning("art")["n_parts"] == 4
    assert_rows_equal(t, s.get("art"))
    s.close()


def test_derived_views_are_bounded_per_artifact():
    """ISSUE 8: probes cycling through distinct mesh sizes used to
    accumulate one full-size derived view (plus metadata) per size,
    unboundedly.  At most ``max_derived_views`` live views may exist
    per base artifact, oldest evicted first."""
    t = make_table(seed=11)
    s = ArtifactStore(root=tempfile.mkdtemp(prefix="part_store_"))
    s.put("a", t)
    for p in (2, 4, 8, 16, 32, 64, 128, 256, 512):
        s.get_partitioned("a", ["k"], p)
    live = [k for k in s._repart_meta if k.startswith("a#repart")]
    assert len(live) <= s.max_derived_views, \
        f"unbounded derived-view accumulation: {len(live)} views"
    # the survivors are the most recent P values
    assert {int(k.split("#repart")[1].split(":")[0]) for k in live} \
        == {64, 128, 256, 512}
    assert s.cache.total_bytes == s.cache.recount()
    s.close()


def test_repartition_roundtrip_after_append_serves_merged_rows():
    """ISSUE 8: P=4 -> P=8 -> P=4 after an in-place append.  Every view
    served after the append must contain the merged rows — a
    pre-append snapshot view is a silent wrong answer — and returning
    to an already-seen P must rebuild, not resurrect."""
    t = make_table(n=160, seed=12)
    s = ArtifactStore(root=tempfile.mkdtemp(prefix="part_store_"))
    s.put("a", t)
    tp, _ = block_partitioned(s, "a", ["k"], 4)
    s.put("art", tp, partitioning={"keys": ["k"], "n_parts": 4})
    v4a, _ = s.get_partitioned("art", ["k"], 4)   # stored property path
    v8a, part8a = s.get_partitioned("art", ["k"], 8)
    delta = make_table(n=40, seed=13)
    s.append("art", delta)

    from repro.dataflow.table import concat_tables
    merged = concat_tables([t, delta])
    v8b, part8b = s.get_partitioned("art", ["k"], 8)
    assert v8b is not v8a, "stale pre-append view served"
    assert_rows_equal(merged, v8b)
    assert_block_layout(v8b, part8b)
    v4b, part4b = s.get_partitioned("art", ["k"], 4)
    assert_rows_equal(merged, v4b)
    v8c, _ = s.get_partitioned("art", ["k"], 8)   # back again: still merged
    assert_rows_equal(merged, v8c)
    assert s.cache.total_bytes == s.cache.recount()
    s.close()


def test_derived_view_metadata_pruned_on_cache_eviction():
    """A derived view squeezed out of the device cache by byte pressure
    must not leave metadata behind (the stale-hit guard would otherwise
    keep a dangling entry forever, and the hit path could pair fresh
    metadata with missing data)."""
    t = make_table(n=400, seed=14)
    s = ArtifactStore(root=tempfile.mkdtemp(prefix="part_store_"),
                      cache_bytes=3 * t.nbytes())
    s.put("a", t)
    s.get_partitioned("a", ["k"], 8)
    ck = [k for k in s._repart_meta if k.startswith("a#repart")][0]
    # pressure: unrelated puts evict the view from the device cache
    for i in range(4):
        s.put(f"f{i}", make_table(n=400, seed=20 + i))
    assert ck not in s.cache
    assert ck not in s._repart_meta, \
        "evicted view's metadata leaked"
    # and the next request rebuilds correctly
    v, part = s.get_partitioned("a", ["k"], 8)
    assert_rows_equal(t, v)
    assert_block_layout(v, part)
    s.close()


def test_partitioning_dataclass_covers_and_aligns():
    p = Partitioning(("a",), 8)
    assert p.covers(("a", "b"), 8)
    assert not p.covers(("b",), 8)
    assert not p.covers(("a", "b"), 4)
    assert p.aligns(("a",), 8)
    assert not p.aligns(("a", "b"), 8)
    q = Partitioning(("a", "b"), 8)
    assert not q.covers(("a",), 8)                # superset does not cover
    assert Partitioning.from_dict(p.to_dict()) == p
