"""Differential fuzzing of the reuse pipeline (DESIGN.md §10).

Random logical plans (filters with random comparison predicates,
projections, group-bys, joins over small generated tables) are executed
three ways — plain (no stores, no rewriting), through ReStore cold, and
through ReStore warm after seeding *related* plans (weakened predicates,
widened projections, so the semantic subsumption path fires) — and every
way must produce bit-identical sorted outputs.

Bit-identity is achievable because the generated data is integer-valued
(sums stay far below 2**24, so float32 aggregation is exact regardless
of padding or artifact compaction).  A fixed-seed subset always runs;
the hypothesis sweep runs wherever hypothesis is installed (the CI fuzz
job).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import plan as P
from repro.core.plan import rebind_load_versions
from repro.core.restore import ReStore
from repro.dataflow.expr import BinOp, Col, Const, Expr
from repro.dataflow.table import Table
from repro.store.artifacts import ArtifactStore, Catalog

N_FACT = 96
N_DIM = 8


def _fact(seed: int) -> Table:
    rng = np.random.default_rng(seed)
    return Table.from_numpy({
        "k": rng.integers(0, N_DIM, N_FACT).astype(np.int32),
        "v": rng.integers(0, 100, N_FACT).astype(np.int32),
        # integer-valued float column: float32 sums stay exact
        "w": rng.integers(0, 50, N_FACT).astype(np.float32),
    })


def _dim() -> Table:
    ks = np.arange(N_DIM, dtype=np.int32)
    return Table.from_numpy({"dk": ks, "extra": (ks * 7 % 5).astype(np.int32)})


# ---------------------------------------------------------------------------
# Random plan generation (np.random driven so it runs with or without
# hypothesis; hypothesis supplies only the seed/depth)


_CMPS = ("lt", "le", "gt", "ge", "eq", "ne")


def _random_const(rng):
    """Mostly ints; sometimes rounding-hostile floats (decimal fractions
    are inexact in float32 — probing the implication checker's
    conservative float32 handling end to end)."""
    v = int(rng.integers(0, 100))
    r = rng.random()
    if r < 0.15:
        return v + 0.1
    if r < 0.25:
        return v + 1e-9
    return v


def _random_atom(rng, cols) -> Expr:
    c = Col(cols[int(rng.integers(0, len(cols)))])
    cmp_op = _CMPS[int(rng.integers(0, len(_CMPS)))]
    return BinOp(cmp_op, c, Const(_random_const(rng)))


def _random_pred(rng, cols) -> Expr:
    atoms = []
    for _ in range(int(rng.integers(1, 3))):
        a = _random_atom(rng, cols)
        if rng.random() < 0.3:       # disjunctive clause
            a = a | _random_atom(rng, cols)
        atoms.append(a)
    pred = atoms[0]
    for a in atoms[1:]:
        pred = pred & a
    return pred


def random_workflow(rng, depth: int) -> P.PhysicalPlan:
    op = P.load("fact")
    cols = ["k", "v", "w"]
    joined = False
    for _ in range(depth):
        choice = int(rng.integers(0, 6))
        if choice == 5:
            choice = 0               # filters twice as likely: they are
        if choice == 0:              # the semantic path's bread & butter
            op = P.filter_(op, _random_pred(rng, cols))
        elif choice == 1:
            n_keep = int(rng.integers(1, len(cols) + 1))
            keep = sorted(rng.choice(cols, size=n_keep, replace=False))
            op = P.project(op, keep)
            cols = keep
        elif choice == 2 and "k" in cols and len(cols) > 1:
            agg_col = next(c for c in cols if c != "k")
            op = P.groupby(op, ["k"], {"s": ("sum", agg_col),
                                       "n": ("count", agg_col),
                                       "mx": ("max", agg_col)})
            cols = ["k", "mx", "n", "s"]
        elif choice == 3 and "k" in cols and not joined:
            op = P.join(op, P.load("dim"), ["k"], ["dk"])
            cols = sorted(set(cols) | {"dk", "extra"})
            joined = True
        else:
            op = P.distinct(op)
    return P.PhysicalPlan([P.store(op, "out")])


# ---------------------------------------------------------------------------
# Related-plan synthesis: weaker filters, wider projections


def _weaken_pred(e: Expr, rng) -> Expr:
    if isinstance(e, BinOp) and e.op == "and":
        r = rng.random()
        if r < 0.3:
            return _weaken_pred(e.lhs, rng)     # drop a conjunct
        return BinOp("and", _weaken_pred(e.lhs, rng),
                     _weaken_pred(e.rhs, rng))
    if isinstance(e, BinOp) and e.op in ("lt", "le", "gt", "ge", "eq") \
            and isinstance(e.rhs, Const):
        delta = int(rng.integers(1, 20))
        v = e.rhs.value
        if e.op in ("gt", "ge"):
            return BinOp(e.op, e.lhs, Const(v - delta))
        if e.op in ("lt", "le"):
            return BinOp(e.op, e.lhs, Const(v + delta))
        return BinOp("ge", e.lhs, Const(v))     # x==c weakened to x>=c
    return e


def weaken_plan(plan: P.PhysicalPlan, rng) -> P.PhysicalPlan:
    """A *covering* variant: every FILTER keeps a weaker predicate, every
    PROJECT may be dropped (the widest possible column set)."""
    memo = {}

    def rebuild(op):
        if id(op) in memo:
            return memo[id(op)]
        ins = [rebuild(i) for i in op.inputs]
        if op.kind == "FILTER":
            new = P.filter_(ins[0], _weaken_pred(op.params["pred"], rng))
        elif op.kind == "PROJECT" and rng.random() < 0.5:
            new = ins[0]
        else:
            new = P.Operator(op.kind, dict(op.params), ins)
        memo[id(op)] = new
        return new

    sinks = []
    for s in plan.sinks:
        new_in = rebuild(s.inputs[0])
        if new_in.kind == "LOAD":
            # weakening collapsed the whole chain: storing a raw source
            # load is not a meaningful seed job, keep the original form
            new_in = s.inputs[0]
        sinks.append(P.store(new_in, s.params["name"]))
    return P.PhysicalPlan(sinks)


# ---------------------------------------------------------------------------
# Differential harness


def _fresh(seed: int, **kw) -> ReStore:
    store = ArtifactStore()
    cat = Catalog(store)
    cat.register("fact", _fact(seed))
    cat.register("dim", _dim())
    return ReStore(cat, store, **kw)


def _canon(table: Table):
    d = table.to_numpy()                 # valid rows only
    order = np.lexsort(tuple(d[c] for c in sorted(d, reverse=True)))
    return {c: d[c][order] for c in sorted(d)}


def _assert_identical(ref, got, label: str):
    a, b = _canon(ref), _canon(got)
    assert sorted(a) == sorted(b), f"{label}: column sets differ"
    for c in a:
        assert a[c].dtype == b[c].dtype, f"{label}:{c}: dtype differs"
        assert np.array_equal(a[c], b[c]), \
            f"{label}:{c}: rows differ\n{a[c]}\nvs\n{b[c]}"


def check_differential(seed: int, depth: int) -> dict:
    """One fuzz case.  Returns hit counters (for the smoke assertions)."""
    rng = np.random.default_rng(seed)
    plan = random_workflow(rng, depth)

    ref_rs = _fresh(seed, heuristic="off", rewrite_enabled=False,
                    semantic=False)
    ref, _ = ref_rs.run_plan(plan)

    # arm 2: ReStore cold, then the identical plan again (store fast path)
    cold_rs = _fresh(seed, heuristic="aggressive")
    got, _ = cold_rs.run_plan(plan)
    _assert_identical(ref["out"], got["out"], "cold")
    again, rep = cold_rs.run_plan(plan)
    _assert_identical(ref["out"], again["out"], "warm-exact")
    assert rep.n_executed == 0, "identical recurring job must fully reuse"

    # arm 3: warm after seeding *related* (covering) plans
    warm_rs = _fresh(seed, heuristic="aggressive")
    for _ in range(2):
        warm_rs.run_plan(weaken_plan(plan, rng))
    sem_before = warm_rs.repo.semantic_hits
    got3, rep3 = warm_rs.run_plan(plan)
    _assert_identical(ref["out"], got3["out"], "warm-semantic")
    return {"semantic_hits": warm_rs.repo.semantic_hits - sem_before,
            "reused": rep3.n_reused}


# always-on subset: exercises the harness in tier-1 without hypothesis
@pytest.mark.parametrize("seed,depth", [(0, 2), (1, 2), (2, 2), (4, 3),
                                        (6, 3), (5, 4)])
def test_differential_fixed_seeds(seed, depth):
    check_differential(seed, depth)


def test_semantic_path_exercised():
    """The designated seeds must drive the semantic (compensation) path —
    otherwise the differential arms silently degrade to exact-only
    coverage."""
    hits = 0
    for seed, depth in [(0, 2), (2, 2), (3, 2)]:
        hits += check_differential(seed, depth)["semantic_hits"]
    assert hits > 0, "no semantic hit across the designated seeds"


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 10**6), depth=st.integers(1, 4))
    def test_differential_fuzz(seed, depth):
        check_differential(seed, depth)


# ---------------------------------------------------------------------------
# Append-churn differential (DESIGN.md §12): after a random append to
# the fact table and maintain(refresh), the warm repository must answer
# the new-version plan BIT-identically to a cold plain run over the
# appended data — entries with no derivable delta plan silently fall
# back to R4 deletion, which must be just as invisible in the output.


def _fact_delta(seed: int, n: int) -> Table:
    rng = np.random.default_rng(seed * 31 + 5)
    return Table.from_numpy({
        "k": rng.integers(0, N_DIM, n).astype(np.int32),
        "v": rng.integers(0, 100, n).astype(np.int32),
        "w": rng.integers(0, 50, n).astype(np.float32),
    })


def check_append_differential(seed: int, depth: int) -> dict:
    """One append-churn fuzz case.  Returns maintain counters."""
    rng = np.random.default_rng(seed)
    plan = random_workflow(rng, depth)
    delta = _fact_delta(seed, int(rng.integers(1, 40)))

    warm_rs = _fresh(seed, heuristic="aggressive")
    warm_rs.run_plan(plan)
    warm_rs.catalog.append("fact", delta)
    rep = warm_rs.maintain(mode="refresh")
    plan_new = rebind_load_versions(
        plan, {"fact": warm_rs.catalog.version("fact")})
    got, _ = warm_rs.run_plan(plan_new)

    ref_rs = _fresh(seed, heuristic="off", rewrite_enabled=False,
                    semantic=False)
    ref_rs.catalog.append("fact", delta)
    ref, _ = ref_rs.run_plan(plan_new)
    _assert_identical(ref["out"], got["out"], "append-refresh")
    return rep


@pytest.mark.parametrize("seed,depth", [(0, 2), (1, 2), (2, 2), (4, 3),
                                        (6, 3), (5, 4)])
def test_append_differential_fixed_seeds(seed, depth):
    check_append_differential(seed, depth)


def test_refresh_path_exercised():
    """The designated seeds must actually drive delta refreshes —
    otherwise the append arm silently degrades to pure R4 coverage."""
    refreshed = 0
    for seed, depth in [(0, 2), (1, 2), (2, 2)]:
        refreshed += check_append_differential(seed, depth)["refreshed"]
    assert refreshed > 0, "no refresh across the designated seeds"


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 10**6), depth=st.integers(1, 4))
    def test_append_differential_fuzz(seed, depth):
        check_append_differential(seed, depth)


# ---------------------------------------------------------------------------
# Mesh-executed differential arm (DESIGN.md §11): random plans run on an
# 8-device mesh — cold, warm (whole-job fast path) and warm after
# covering seeds — must stay BIT-identical to the single-device plain
# run.  Spawns a subprocess (XLA_FLAGS must be set before jax imports;
# the main pytest process keeps seeing 1 device).

_MESH_FUZZ = """
import numpy as np, jax
import test_fuzz_reuse as F

mesh = jax.make_mesh((8,), ("data",))
for seed, depth in [(0, 2), (2, 2), (5, 3)]:
    rng = np.random.default_rng(seed)
    plan = F.random_workflow(rng, depth)

    ref_rs = F._fresh(seed, heuristic="off", rewrite_enabled=False,
                      semantic=False)
    ref, _ = ref_rs.run_plan(plan)

    # skew_factor = n_shards makes the exchange lossless (bucket ==
    # local capacity), so tiny skewed tables cannot drop rows
    rs = F._fresh(seed, heuristic="aggressive", mesh=mesh,
                  skew_factor=8.0)
    got, _ = rs.run_plan(plan)
    F._assert_identical(ref["out"], got["out"], f"mesh-cold[{seed}]")
    again, rep = rs.run_plan(plan)
    F._assert_identical(ref["out"], again["out"], f"mesh-warm[{seed}]")
    assert rep.n_executed == 0, "identical recurring job must fully reuse"

    warm_rs = F._fresh(seed, heuristic="aggressive", mesh=mesh,
                       skew_factor=8.0)
    for _ in range(2):
        warm_rs.run_plan(F.weaken_plan(plan, rng))
    got3, _ = warm_rs.run_plan(plan)
    F._assert_identical(ref["out"], got3["out"], f"mesh-warm-sem[{seed}]")
    print("seed", seed, "OK")

# skew-overflow arm: skew_factor=1.0 leaves no headroom for key skew, so
# the bounded exchange buckets overflow; the engine must COUNT the
# overflow (JobStats audit trail) and recover losslessly via the
# skew=n_shards retry -- still bit-identical to single-device plain.
# partition_aware=False keeps every exchange live (no co-partitioned
# skips), so the overflow path is actually on the line.
ovf_hits = 0
for seed, depth in [(0, 2), (2, 2), (5, 3)]:
    rng = np.random.default_rng(seed)
    plan = F.random_workflow(rng, depth)
    ref_rs = F._fresh(seed, heuristic="off", rewrite_enabled=False,
                      semantic=False)
    ref, _ = ref_rs.run_plan(plan)
    ovf_rs = F._fresh(seed, heuristic="aggressive", mesh=mesh,
                      skew_factor=1.0, partition_aware=False)
    got, rep = ovf_rs.run_plan(plan)
    F._assert_identical(ref["out"], got["out"], f"mesh-overflow[{seed}]")
    for j in rep.jobs:
        if j.stats is not None:
            assert j.stats.shuffle_overflow == 0 \
                or j.stats.shuffle_retries > 0, \
                "overflow without the lossless retry"
            ovf_hits += int(j.stats.shuffle_overflow > 0)
assert ovf_hits > 0, "skew-overflow path never exercised"
print("OK")
"""


def test_mesh_differential_fixed_seeds():
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), os.path.join(repo, "tests")])
    out = subprocess.run([sys.executable, "-c", _MESH_FUZZ], env=env,
                         capture_output=True, text=True, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    assert out.stdout.strip().endswith("OK")
