"""Regression tests for the join probe-window gather.

``op_join`` selects, per left row, the probe-window slot of its j-th
verified match.  The old ``jnp.take(..., axis=1)`` gather (a) built a
(Cl, Cl) intermediate — ~800x slower on XLA CPU at 64k rows — and (b)
indexed every row by *row 0's* argmax, joining the wrong right row
whenever a row's first match sits past window slot 0 (hash ties, or
duplicate right keys under expansion > 1).  These tests pin the exact
per-row semantics against a numpy nested-loop reference."""
import numpy as np

from repro.dataflow.physical import op_join
from repro.dataflow.table import Table


def _np_join(left, right, lk, rk, expansion):
    """Reference inner join with per-left-row match cap (numpy loops)."""
    lc, rc = left.to_numpy(), right.to_numpy()
    rows = []
    for i in range(len(lc[lk])):
        n = 0
        for j in range(len(rc[rk])):
            if lc[lk][i] == rc[rk][j]:
                rows.append((lc[lk][i], lc["lv"][i], rc["rv"][j]))
                n += 1
                if n == expansion:
                    break
    return sorted(rows)


def _got(table: Table):
    d = table.to_numpy()
    return sorted(zip(d["k"], d["lv"], d["rv"]))


def test_duplicate_right_keys_with_expansion():
    # right has two rows per key: under expansion=2 the second match
    # lives at window slot 1, where the old gather used row 0's offset
    left = Table.from_numpy({
        "k": np.array([7, 5, 3, 5], np.int32),
        "lv": np.array([10, 20, 30, 40], np.int32)})
    # filler keys keep the right capacity above the probe window, so
    # the tail-clip overflow heuristic stays out of the way
    filler = np.arange(1000, 1012, dtype=np.int32)
    right = Table.from_numpy({
        "k": np.concatenate([np.array([5, 5, 3], np.int32), filler]),
        "rv": np.concatenate([np.array([100, 200, 300], np.int32),
                              np.zeros(12, np.int32)])})
    out, overflow = op_join(left, right, ["k"], ["k"], expansion=2)
    assert int(overflow) == 0
    assert _got(out) == _np_join(left, right, "k", "k", 2)


def test_unmatched_first_row_does_not_poison_gather():
    # row 0 is unmatched (argmax of all-False = 0); every other row's
    # match offset must still be its own
    left = Table.from_numpy({
        "k": np.array([99, 1, 2, 3], np.int32),
        "lv": np.arange(4, dtype=np.int32)})
    right = Table.from_numpy({
        "k": np.array([3, 2, 1], np.int32),
        "rv": np.array([30, 20, 10], np.int32)})
    out, _ = op_join(left, right, ["k"], ["k"], expansion=1)
    assert _got(out) == _np_join(left, right, "k", "k", 1)


def test_join_probe_is_linear_not_quadratic():
    # smoke guard for the (Cl, Cl) gather regression: 32k x 64 joins in
    # well under a second when the gather is per-row
    import time

    import jax
    rng = np.random.default_rng(0)
    n = 1 << 15
    left = Table.from_numpy({
        "k": rng.integers(0, 64, n).astype(np.int32),
        "lv": rng.integers(0, 100, n).astype(np.int32)})
    right = Table.from_numpy({
        "k": np.arange(64, dtype=np.int32),
        "rv": np.arange(64, dtype=np.int32)})
    f = jax.jit(lambda a, b: op_join(a, b, ["k"], ["k"], 1)[0])
    jax.block_until_ready(f(left, right))        # compile off the clock
    t0 = time.perf_counter()
    jax.block_until_ready(f(left, right))
    assert time.perf_counter() - t0 < 1.0
