"""Multi-query batch optimizer (DESIGN.md §16): shared sub-plans execute
exactly once per batch, batched results are bit-identical to sequential
per-query execution, planning probes never masquerade as reuse hits, and
known-uses hints override the seen-once admission gate."""
import numpy as np
import pytest

from repro.core.cost_model import CostModel
from repro.core.mqo import count_dup_executions, optimize_batch, run_batch
from repro.core.restore import ReStore
from repro.core.rewriter import rewrite_plan
from repro.dataflow.builder import Dataflow, col
from repro.dataflow.compiler import compile_workflow
from repro.service.service import ReStoreService
from repro.store.artifacts import ArtifactStore, Catalog
from repro.workloads import pigmix
from repro.workloads.stream import StreamConfig, run_stream

N_ROWS = 1024


def _driver(heuristic="cost", n_rows=N_ROWS):
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=n_rows)
    return ReStore(cat, store, heuristic=heuristic)


def _canon(table):
    d = table.to_numpy()

    def key(a):
        return (np.ascontiguousarray(a).view(f"S{a.shape[1]}").ravel()
                if a.ndim == 2 else a)

    order = np.lexsort(tuple(key(d[c]) for c in sorted(d, reverse=True)))
    return {c: d[c][order] for c in sorted(d)}


def _assert_identical(a, b):
    assert set(a) == set(b)
    for k in a:
        ca, cb = _canon(a[k]), _canon(b[k])
        assert set(ca) == set(cb)
        for c in ca:
            assert np.array_equal(ca[c], cb[c]), (k, c)


def _scan_variant(thresh, name):
    return (Dataflow.load("page_views")
            .filter(col("timespent") > thresh)
            .group_by("user", n=("count", "timespent"))
            .store(name).build())


BATCH = [pigmix.L3("sum"), pigmix.L3F(), pigmix.L2(),
         _scan_variant(10, "v10"), _scan_variant(60, "v60")]


# ------------------------------------------------------------- planning


def test_optimize_batch_finds_exact_maximal_shared():
    bp = optimize_batch([pigmix.L3("sum"), pigmix.L3F(), pigmix.L2()])
    by_kind = {s.kind: s for s in bp.shared}
    # L3/L3F share the whole join; L2 shares only the pv projection
    assert by_kind["JOIN"].n_consumers == 2
    assert by_kind["PROJECT"].n_consumers == 3
    assert len(bp.shared) == 2
    assert bp.shared_plan is not None
    assert set(bp.known_uses) >= bp.boundary_artifacts


def test_optimize_batch_semantic_covering():
    bp = optimize_batch([_scan_variant(10, "a"), _scan_variant(50, "b"),
                         _scan_variant(80, "c")])
    sem = [s for s in bp.shared if s.semantic]
    assert len(sem) == 1
    # the weakest predicate (>10) covers all three variants
    assert sem[0].kind == "FILTER"
    assert sem[0].n_consumers == 3


def test_optimize_batch_no_overlap_shares_nothing():
    bp = optimize_batch([pigmix.L6(), pigmix.L8()])
    assert bp.shared == []
    assert bp.shared_plan is None
    assert bp.known_uses == {}


def test_optimize_batch_accepts_builders():
    flow = (Dataflow.load("page_views").project("user", "timespent")
            .store("x"))
    bp = optimize_batch([flow, flow.build()])
    assert len(bp.shared) == 1
    assert bp.shared[0].n_consumers == 2


def test_optimize_batch_drops_already_stored_from_prefix():
    rs = _driver()
    rs.run(pigmix.L3("sum"))   # materializes the join boundary
    bp = optimize_batch([pigmix.L3("sum"), pigmix.L3F()], repo=rs.repo)
    join = [s for s in bp.shared if s.kind == "JOIN"]
    assert join and join[0].already_stored
    live = ([] if bp.shared_plan is None else
            [s.params["name"] for s in bp.shared_plan.sinks])
    assert join[0].plan.sinks[0].params["name"] not in live


def test_planning_probe_does_not_credit_record_use():
    rs = _driver(heuristic="aggressive")
    rs.run(pigmix.L3("sum"))
    entries = rs.repo.ordered()
    assert entries
    before = {e.artifact: e.use_count for e in entries}
    wf = compile_workflow(pigmix.L3("sum"))
    for job in wf.jobs:
        rewrite_plan(job.plan, rs.repo, record=False)
    after = {e.artifact: e.use_count for e in rs.repo.ordered()}
    assert after == before, "planning probes must not credit record_use"
    # the default (execution-time) path still credits
    for job in wf.jobs:
        rewrite_plan(job.plan, rs.repo)
    assert any(after[a] < e.use_count for a, e in
               {e.artifact: e for e in rs.repo.ordered()}.items())


def test_known_uses_hint_admits_never_seen_subjob():
    cm = CostModel()
    fp = "deadbeef" * 8
    assert not cm.should_materialize(fp)
    cm.set_known_uses({fp: 3.0})
    assert cm.should_materialize(fp)
    assert cm.should_materialize("other" * 8, artifact=fp)
    cm.clear_known_uses([fp])
    assert not cm.should_materialize(fp)
    # max-merge: a second batch never lowers an existing hint
    cm.set_known_uses({"k": 5.0})
    cm.set_known_uses({"k": 2.0})
    assert cm.known_uses_for("k") == 5.0
    cm.clear_known_uses()
    assert cm.known_uses == {}


# ------------------------------------------------------------ execution


def test_batch_bit_identical_to_sequential_with_zero_dups():
    br = run_batch(_driver(), BATCH)
    assert br.dup_executions == 0
    assert len(br.batch.shared) >= 1
    seq = _driver()
    for q, bres in zip(BATCH, br.results):
        sres, _ = seq.run(q)
        _assert_identical(bres, sres)


def test_shared_subplan_executed_exactly_once():
    rs = _driver()
    br = run_batch(rs, BATCH)
    assert br.shared_report is not None
    # shared prefix ran; each query's overlapping job reused it
    assert br.shared_report.n_executed >= 1
    assert count_dup_executions(br.batch, br.reports) == 0
    # every shared artifact exists and is a repository entry
    for s in br.batch.shared:
        assert rs.store.exists(s.artifact)
        assert any(e.artifact == s.artifact for e in rs.repo.ordered())


def test_count_dup_executions_flags_unshielded_recompute():
    # a driver that never reuses recomputes every shared sub-plan —
    # the audit must see that, not just the happy path
    bp = optimize_batch([pigmix.L3("sum"), pigmix.L3F()])
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=256)
    rs = ReStore(cat, store, heuristic="off", rewrite_enabled=False)
    reports = [rs.run(p)[1] for p in bp.plans]
    assert count_dup_executions(bp, reports) >= 1


def test_batch_releases_hints_and_pins():
    rs = _driver()
    run_batch(rs, BATCH)
    assert rs.repo.cost_model.known_uses == {}
    assert not rs.repo.pinned


def test_semantic_variants_compensate_from_covering_chain():
    rs = _driver()
    variants = [_scan_variant(10, "a"), _scan_variant(50, "b"),
                _scan_variant(80, "c")]
    br = run_batch(rs, variants)
    assert br.dup_executions == 0
    n_sem = sum(j.n_semantic for rep in br.reports for j in rep.jobs)
    assert n_sem >= 2, "stricter variants must splice the covering chain"
    seq = _driver()
    for q, bres in zip(variants, br.results):
        sres, _ = seq.run(q)
        _assert_identical(bres, sres)


def test_run_batch_via_driver_convenience():
    br = _driver().run_batch([pigmix.L3("sum"), pigmix.L3F()])
    assert br.dup_executions == 0
    assert {"L3_sum_out"} <= set(br.results[0])


# -------------------------------------------------------------- service


def test_submit_batch_fans_out_tickets():
    rs_store = ArtifactStore()
    cat = Catalog(rs_store)
    pigmix.register_all(cat, n_rows=N_ROWS)
    svc = ReStoreService(cat, rs_store, n_workers=2, heuristic="cost")
    try:
        tickets = svc.submit_batch(
            BATCH, tenants=["a", "b", "c", "a", "b"])
        results = [t.result(120) for t in tickets]
        st = svc.stats()
        assert st["batches"] == 1
        assert st["batch_shared_subplans"] >= 1
        assert st["dup_executions"] == 0
    finally:
        svc.stop()
    seq = _driver()
    for q, (bres, _rep) in zip(BATCH, results):
        sres, _ = seq.run(q)
        _assert_identical(bres, sres)


def test_submit_batch_accepts_builders_and_tenant_mismatch_raises():
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=256)
    svc = ReStoreService(cat, store, n_workers=1, heuristic="cost")
    try:
        with pytest.raises(ValueError, match="1:1"):
            svc.submit_batch([pigmix.L2()], tenants=["a", "b"])
        flow = (Dataflow.load("page_views").project("user")
                .distinct().store("u"))
        (res, _), = [t.result(60) for t in
                     svc.submit_batch([flow])]
        assert "u" in res
    finally:
        svc.stop()


# --------------------------------------------------------------- stream


def test_stream_mqo_mode_batches_without_dups():
    cfg = StreamConfig(n_events=8, n_rows=512, batch_size=4)
    r = run_stream("mqo", cfg)
    assert r.batches == 2
    assert r.mqo_dup_executions == 0
    assert len(r.events) == 8
    # a window's events see at least as much reuse as sequential cost
    r_cost = run_stream("cost", StreamConfig(n_events=8, n_rows=512))
    assert r.n_reused_total >= r_cost.n_reused_total
