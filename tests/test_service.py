"""Concurrent ReStore service: worker pool, singleflight, fairness,
backpressure, retries, deadlines, shutdown (DESIGN.md §13)."""
import threading
import time

import pytest

from _service_util import identical, results_identical, run_mix
from repro.core.repository import Repository
from repro.service.journal import RepositoryJournal
from repro.service.service import (ReStoreService, ServiceClosed,
                                   ServiceOverloaded, ServiceTimeout)
from repro.store.artifacts import (ArtifactStore, Catalog,
                                   TransientStoreError)
from repro.workloads import pigmix

N_ROWS = 512


def _service(tmp_path=None, **kw):
    store = ArtifactStore(root=None if tmp_path is None
                          else str(tmp_path / "store"))
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=N_ROWS)
    kw.setdefault("n_workers", 2)
    return ReStoreService(cat, store, Repository(), **kw)


def _gate(svc):
    """Make every worker block inside run_plan until released —
    deterministic queue-buildup for the scheduling tests."""
    ev = threading.Event()
    for drv in svc._drivers:
        orig = drv.run_plan

        def wrapped(plan, _orig=orig):
            ev.wait(30)
            return _orig(plan)

        drv.run_plan = wrapped
    return ev


def _distinct_plans():
    return [pigmix.L2(), pigmix.L3("sum"), pigmix.L3("mean"),
            pigmix.L4(), pigmix.L5()]


def _wait(pred, timeout=10.0):
    deadline = time.time() + timeout
    while not pred():
        assert time.time() < deadline, "condition never became true"
        time.sleep(0.002)


# -------------------------------------------------------------- correctness


def test_concurrent_results_match_serial_baseline():
    from _service_util import fresh_driver
    baseline = run_mix(fresh_driver(n_rows=N_ROWS))
    svc = _service(n_workers=4)
    try:
        tickets = [(label, svc.submit(qfn(), tenant=f"t{i % 2}"))
                   for i, (label, qfn) in enumerate(
                       [("L3_sum", lambda: pigmix.L3("sum")),
                        ("L2", pigmix.L2),
                        ("L3_mean", lambda: pigmix.L3("mean"))])]
        got = {}
        for label, t in tickets:
            results, report = t.result(timeout=120)
            for sink, table in results.items():
                got[f"{label}:{sink}"] = table
        assert results_identical(baseline, got)
        st = svc.stats()
        assert st["dup_executions"] == 0
        assert st["completed"] == 3 and st["failed"] == 0
    finally:
        svc.stop()


def test_shared_repository_gives_cross_tenant_reuse():
    svc = _service(n_workers=2)
    try:
        svc.run(pigmix.L3("sum"), tenant="alice", timeout=120)
        _, rep = svc.run(pigmix.L3("mean"), tenant="bob", timeout=120)
        assert not rep.jobs[0].executed, \
            "bob must reuse alice's join sub-job"
    finally:
        svc.stop()


# ------------------------------------------------------------- singleflight


def test_singleflight_computes_once_and_shares_results():
    svc = _service(n_workers=1)
    gate = _gate(svc)
    try:
        tickets = [svc.submit(pigmix.L3("sum"), tenant=f"t{i}")
                   for i in range(5)]
        gate.set()
        outs = [t.result(timeout=120) for t in tickets]
        st = svc.stats()
        assert st["singleflight_hits"] == 4
        assert st["dup_executions"] == 0
        assert st["completed"] == 5
        r0 = outs[0][0]
        for results, _ in outs[1:]:
            assert sorted(results) == sorted(r0)
            for k in r0:
                assert identical(r0[k], results[k])
    finally:
        svc.stop()


def test_singleflight_disabled_executes_each_submit():
    svc = _service(n_workers=1, singleflight=False)
    gate = _gate(svc)
    try:
        tickets = [svc.submit(pigmix.L2(), tenant="t") for _ in range(3)]
        gate.set()
        for t in tickets:
            t.result(timeout=120)
        assert svc.stats()["singleflight_hits"] == 0
        assert svc.stats()["completed"] == 3
    finally:
        svc.stop()


# ------------------------------------------------------------- backpressure


def test_backpressure_rejects_nonblocking_when_full():
    svc = _service(n_workers=1, max_queue=2)
    gate = _gate(svc)
    try:
        plans = _distinct_plans()
        svc.submit(plans[0], tenant="t")
        _wait(lambda: svc.stats()["executing"] == 1)
        svc.submit(plans[1], tenant="t")
        svc.submit(plans[2], tenant="t")
        with pytest.raises(ServiceOverloaded):
            svc.submit(plans[3], tenant="t", block=False)
        with pytest.raises(ServiceOverloaded):
            svc.submit(plans[4], tenant="t", timeout=0.05)
        assert svc.stats()["rejected"] == 2
        gate.set()
    finally:
        svc.stop()


def test_blocking_submit_proceeds_when_space_frees():
    svc = _service(n_workers=1, max_queue=1)
    gate = _gate(svc)
    try:
        plans = _distinct_plans()
        svc.submit(plans[0], tenant="t")
        _wait(lambda: svc.stats()["executing"] == 1)
        svc.submit(plans[1], tenant="t")      # queue now full
        release = threading.Timer(0.05, gate.set)
        release.start()
        t = svc.submit(plans[2], tenant="t", timeout=30)  # blocks, then ok
        t.result(timeout=120)
        release.join()
    finally:
        svc.stop()


# ----------------------------------------------------------------- fairness


def test_round_robin_prevents_tenant_starvation():
    svc = _service(n_workers=1)
    gate = _gate(svc)
    order = []
    for drv in svc._drivers:
        orig = drv.run_plan

        def wrapped(plan, _orig=orig):
            order.append(plan.sinks[0].params["name"])
            return _orig(plan)

        drv.run_plan = wrapped
    try:
        plans = _distinct_plans()
        first = svc.submit(plans[0], tenant="chatty")
        _wait(lambda: svc.stats()["executing"] == 1)
        for p in plans[1:]:
            svc.submit(p, tenant="chatty")
        quiet = svc.submit(pigmix.L6(), tenant="quiet")
        gate.set()
        quiet.result(timeout=120)
        first.result(timeout=120)
        svc.stop()                       # drain the rest
        chatty_last = max(i for i, s in enumerate(order)
                          if s != "L6_out")
        assert order.index("L6_out") < chatty_last, \
            f"quiet tenant starved: {order}"
    finally:
        svc.stop()


# --------------------------------------------------------- retry / deadline


def test_transient_errors_requeue_with_backoff():
    svc = _service(n_workers=1, max_attempts=3, retry_base_s=0.001)
    calls = {"n": 0}
    drv = svc._drivers[0]
    orig = drv.run_plan

    def flaky(plan):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise TransientStoreError("art/x", "injected transient")
        return orig(plan)

    drv.run_plan = flaky
    try:
        results, _ = svc.run(pigmix.L2(), timeout=120)
        assert "L2_out" in results
        st = svc.stats()
        assert st["retries"] == 2 and calls["n"] == 3
        assert st["completed"] == 1 and st["failed"] == 0
    finally:
        svc.stop()


def test_transient_errors_exhaust_to_failure():
    svc = _service(n_workers=1, max_attempts=2, retry_base_s=0.001)

    def always_fail(plan):
        raise TransientStoreError("art/x", "injected transient")

    svc._drivers[0].run_plan = always_fail
    try:
        with pytest.raises(TransientStoreError):
            svc.run(pigmix.L2(), timeout=120)
        st = svc.stats()
        assert st["failed"] == 1 and st["retries"] == 1
    finally:
        svc.stop()


def test_deadline_exceeded_fails_at_pickup():
    svc = _service(n_workers=1)
    gate = _gate(svc)
    try:
        blocker = svc.submit(pigmix.L2(), tenant="t")
        _wait(lambda: svc.stats()["executing"] == 1)
        doomed = svc.submit(pigmix.L4(), tenant="t", deadline_s=0.01)
        time.sleep(0.05)
        gate.set()
        with pytest.raises(ServiceTimeout):
            doomed.result(timeout=120)
        blocker.result(timeout=120)
        assert svc.stats()["timeouts"] == 1
    finally:
        svc.stop()


# ----------------------------------------------------------------- shutdown


def test_stop_drain_finishes_queued_work():
    svc = _service(n_workers=2)
    tickets = [svc.submit(p, tenant="t") for p in _distinct_plans()]
    svc.stop(drain=True)
    for t in tickets:
        t.result(timeout=1)              # already resolved
    assert svc.stats()["completed"] == len(tickets)
    with pytest.raises(ServiceClosed):
        svc.submit(pigmix.L2())


def test_stop_nondrain_fails_queued_tickets():
    svc = _service(n_workers=1)
    gate = _gate(svc)
    running = svc.submit(pigmix.L2(), tenant="t")
    _wait(lambda: svc.stats()["executing"] == 1)
    queued = svc.submit(pigmix.L4(), tenant="t")
    stopper = threading.Thread(target=svc.stop,
                               kwargs={"drain": False})
    stopper.start()
    time.sleep(0.05)
    gate.set()
    stopper.join(timeout=60)
    assert not stopper.is_alive()
    running.result(timeout=1)
    with pytest.raises(ServiceClosed):
        queued.result(timeout=1)


# ------------------------------------------------- journal + maintenance


def test_service_with_journal_recovers_for_reuse(tmp_path):
    root = str(tmp_path / "store")
    store = ArtifactStore(root=root)
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=N_ROWS)
    svc = ReStoreService(cat, store, Repository(), n_workers=2,
                         journal=RepositoryJournal(root))
    svc.run(pigmix.L3("sum"), tenant="a", timeout=120)
    svc.run(pigmix.L2(), tenant="b", timeout=120)
    n_entries = len(svc.repo)
    svc.stop()
    assert n_entries > 0

    # new process: reopen everything from disk
    store2 = ArtifactStore(root=root)
    cat2 = Catalog(store2)
    pigmix.register_all(cat2, n_rows=N_ROWS)
    repo2, journal2 = RepositoryJournal.recover(store2)
    assert journal2.recovered_entries == n_entries
    assert journal2.reconciled_drops == 0
    svc2 = ReStoreService(cat2, store2, repo2, n_workers=2,
                          journal=journal2)
    try:
        _, rep = svc2.run(pigmix.L3("sum"), tenant="a", timeout=120)
        assert rep.n_executed == 0, "full reuse after recovery"
    finally:
        svc2.stop()


def test_maintain_now_runs_and_returns_counters(tmp_path):
    svc = _service(tmp_path, n_workers=1)
    try:
        svc.run(pigmix.L3("sum"), timeout=120)
        out = svc.maintain_now()
        assert isinstance(out, dict)
    finally:
        svc.stop()


def test_stats_shape():
    svc = _service(n_workers=1)
    try:
        svc.run(pigmix.L2(), tenant="t0", timeout=120)
        st = svc.stats()
        for k in ("submitted", "completed", "failed", "rejected",
                  "retries", "timeouts", "singleflight_hits",
                  "dup_executions", "degraded", "flush_failures",
                  "queued", "executing", "per_tenant", "store",
                  "quarantined"):
            assert k in st
        assert st["per_tenant"]["t0"]["completed"] == 1
    finally:
        svc.stop()


# --------------------------------------------- speculative prefetch (§15)


def test_prefetch_loop_warms_repeated_reads(tmp_path):
    svc = _service(tmp_path, n_workers=1, prefetch_interval_s=0.02,
                   prefetch_k=4)
    try:
        for _ in range(3):
            svc.run(pigmix.L3("sum"), timeout=120)
        _wait(lambda: svc.stats()["prefetch"]["observed"] > 0)
        st = svc.stats()["prefetch"]
        for k in ("hits", "observed", "prefetched", "hit_rate",
                  "predictions", "refreshed_ahead"):
            assert k in st
        assert st["predictions"], "repeated reads must rank something"
        warmed = svc.prefetch_now()
        assert isinstance(warmed, list)
    finally:
        svc.stop()


def test_prefetch_disabled_by_default():
    svc = _service(n_workers=1)
    try:
        assert svc.prefetcher is None
        assert svc.prefetch_now() == []
        assert "prefetch" not in svc.stats()
    finally:
        svc.stop()


def test_stream_reports_prefetch_counters():
    from repro.workloads.stream import StreamConfig, run_stream
    cfg = StreamConfig(n_events=10, n_tenants=2, n_rows=512,
                       append_every=4, seed=5, prefetch=True,
                       prefetch_k=4)
    res = run_stream("keep", cfg)
    assert res.prefetch_hits > 0, "zipfian replay predictions must land"
    assert res.refreshed_ahead > 0, \
        "append churn must refresh hot artifacts ahead of arrival"
    # prefetch must never change results: same stream without it
    base = run_stream("keep", StreamConfig(n_events=10, n_tenants=2,
                                           n_rows=512, append_every=4,
                                           seed=5))
    assert len(base.events) == len(res.events)
    assert base.n_reused_total == res.n_reused_total
