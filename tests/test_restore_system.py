"""End-to-end ReStore behaviour: the paper's reuse scenarios + heuristic
semantics, verified against direct execution."""
import numpy as np
import pytest

from repro.core import plan as P
from repro.core.enumerator import AGGRESSIVE, CONSERVATIVE, HEURISTICS
from repro.core.restore import ReStore
from repro.dataflow.expr import Col
from repro.dataflow.physical import execute_plan
from repro.store.artifacts import ArtifactStore, Catalog
from repro.workloads import pigmix


def fresh(n_rows=2048, heuristic="aggressive"):
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=n_rows)
    return ReStore(cat, store, heuristic=heuristic)


def _rows(table):
    return {k: np.sort(v.astype(np.float64), axis=0)
            for k, v in table.to_numpy().items()
            if v.dtype.kind in "if"}


def test_whole_job_reuse_gives_same_results():
    rs = fresh()
    res_a, rep_a = rs.run_plan(pigmix.L3("sum"))
    assert rep_a.n_executed == 2
    # variant shares job 1
    res_b, rep_b = rs.run_plan(pigmix.L3("mean"))
    assert not rep_b.jobs[0].executed, "join job reused"
    assert rep_b.jobs[1].executed

    # correctness: compare with a cold engine
    cold = fresh()
    res_ref, _ = cold.run_plan(pigmix.L3("mean"))
    for k in res_ref:
        a, b = _rows(res_ref[k]), _rows(res_b[k])
        for c in a:
            assert np.allclose(a[c], b[c], atol=1e-3)


def test_subjob_reuse_gives_same_results():
    rs = fresh()
    rs.run_plan(pigmix.L3("sum"))     # stores Load+Project sub-jobs
    pv = P.project(P.load("page_views"), ["user", "estimated_revenue"])
    f = P.filter_(pv, Col("estimated_revenue") > 50.0)
    q = P.PhysicalPlan([P.store(f, "q_out")])
    res, rep = rs.run_plan(q)
    assert rep.jobs[0].reused_artifacts, "sub-job reuse must fire"

    cold = fresh()
    res_ref, _ = cold.run_plan(q)
    a, b = _rows(res_ref["q_out"]), _rows(res["q_out"])
    for c in a:
        assert np.allclose(a[c], b[c], atol=1e-3)


def test_heuristics_store_sets_are_nested():
    """H_C subset-of H_A subset-of NH, reflected in stored artifacts."""
    stored = {}
    for h in ("conservative", "aggressive", "none"):
        rs = fresh(heuristic=h)
        _, rep = rs.run_plan(pigmix.L3("sum"))
        stored[h] = {a for j in rep.jobs for a in j.stored_candidates}
    assert stored["conservative"] <= stored["aggressive"] <= stored["none"]
    assert CONSERVATIVE < AGGRESSIVE
    assert set(HEURISTICS) == {"conservative", "aggressive", "none", "off",
                               "cost"}


def test_off_heuristic_stores_only_job_outputs():
    rs = fresh(heuristic="off")
    _, rep = rs.run_plan(pigmix.L3("sum"))
    for j in rep.jobs:
        # only whole-job outputs, no Split/Store injections
        assert all(a.startswith("art/") for a in j.stored_candidates)
    # job outputs are 2 (join artifact, group artifact)
    n = sum(len(j.stored_candidates) for j in rep.jobs)
    assert n == 2


def test_rewritten_workflow_correct_for_every_pigmix_query():
    rs = fresh()
    for name, qfn in pigmix.QUERIES.items():
        rs.run_plan(qfn())            # populate
    # fresh driver over the SAME repo: everything reusable
    rs2 = ReStore(rs.catalog, rs.store, rs.repo, heuristic="off")
    for name, qfn in pigmix.QUERIES.items():
        res, rep = rs2.run_plan(qfn())
        assert rep.n_executed == 0, f"{name}: full reuse expected"


def test_catalog_version_bump_prevents_stale_reuse():
    rs = fresh()
    rs.run_plan(pigmix.L3("sum"))
    assert len(rs.repo) > 0
    # modify the source dataset -> R4
    rs.catalog.register("page_views", pigmix.gen_page_views(1024, seed=99))
    assert rs.repo.evict_stale(rs.catalog) == len(rs.repo.entries) == 0 \
        or len(rs.repo) >= 0
    # build the plan against the new version: no stale matches possible
    pv = P.project(P.load("page_views",
                          version=rs.catalog.version("page_views")),
                   ["user", "estimated_revenue"])
    q = P.PhysicalPlan([P.store(pv, "v_out")])
    _, rep = rs.run_plan(q)
    assert not rep.jobs[0].reused_artifacts
