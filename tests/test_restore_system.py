"""End-to-end ReStore behaviour: the paper's reuse scenarios + heuristic
semantics, verified against direct execution."""
import numpy as np
import pytest

from repro.core import plan as P
from repro.core.enumerator import AGGRESSIVE, CONSERVATIVE, HEURISTICS
from repro.core.restore import ReStore
from repro.dataflow.expr import Col
from repro.dataflow.physical import execute_plan
from repro.store.artifacts import ArtifactStore, Catalog
from repro.workloads import pigmix


def fresh(n_rows=2048, heuristic="aggressive"):
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=n_rows)
    return ReStore(cat, store, heuristic=heuristic)


def _rows(table):
    return {k: np.sort(v.astype(np.float64), axis=0)
            for k, v in table.to_numpy().items()
            if v.dtype.kind in "if"}


def test_whole_job_reuse_gives_same_results():
    rs = fresh()
    res_a, rep_a = rs.run_plan(pigmix.L3("sum"))
    assert rep_a.n_executed == 2
    # variant shares job 1
    res_b, rep_b = rs.run_plan(pigmix.L3("mean"))
    assert not rep_b.jobs[0].executed, "join job reused"
    assert rep_b.jobs[1].executed

    # correctness: compare with a cold engine
    cold = fresh()
    res_ref, _ = cold.run_plan(pigmix.L3("mean"))
    for k in res_ref:
        a, b = _rows(res_ref[k]), _rows(res_b[k])
        for c in a:
            assert np.allclose(a[c], b[c], atol=1e-3)


def test_subjob_reuse_gives_same_results():
    # min_splice_benefit_s=0 disarms the L7 exact-splice guard: at this
    # toy size a streaming Project region never clears the overhead bar
    # (see test_l7_streaming_splice_declined) and this test is about the
    # sub-job reuse MECHANISM, not its economics
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=2048)
    rs = ReStore(cat, store, heuristic="aggressive",
                 min_splice_benefit_s=0.0)
    rs.run_plan(pigmix.L3("sum"))     # stores Load+Project sub-jobs
    pv = P.project(P.load("page_views"), ["user", "estimated_revenue"])
    f = P.filter_(pv, Col("estimated_revenue") > 50.0)
    q = P.PhysicalPlan([P.store(f, "q_out")])
    res, rep = rs.run_plan(q)
    assert rep.jobs[0].reused_artifacts, "sub-job reuse must fire"

    cold = fresh()
    res_ref, _ = cold.run_plan(q)
    a, b = _rows(res_ref["q_out"]), _rows(res["q_out"])
    for c in a:
        assert np.allclose(a[c], b[c], atol=1e-3)


def test_heuristics_store_sets_are_nested():
    """H_C subset-of H_A subset-of NH, reflected in stored artifacts."""
    stored = {}
    for h in ("conservative", "aggressive", "none"):
        rs = fresh(heuristic=h)
        _, rep = rs.run_plan(pigmix.L3("sum"))
        stored[h] = {a for j in rep.jobs for a in j.stored_candidates}
    assert stored["conservative"] <= stored["aggressive"] <= stored["none"]
    assert CONSERVATIVE < AGGRESSIVE
    assert set(HEURISTICS) == {"conservative", "aggressive", "none", "off",
                               "cost"}


def test_off_heuristic_stores_only_job_outputs():
    rs = fresh(heuristic="off")
    _, rep = rs.run_plan(pigmix.L3("sum"))
    for j in rep.jobs:
        # only whole-job outputs, no Split/Store injections
        assert all(a.startswith("art/") for a in j.stored_candidates)
    # job outputs are 2 (join artifact, group artifact)
    n = sum(len(j.stored_candidates) for j in rep.jobs)
    assert n == 2


def test_rewritten_workflow_correct_for_every_pigmix_query():
    rs = fresh()
    for name, qfn in pigmix.QUERIES.items():
        rs.run_plan(qfn())            # populate
    # fresh driver over the SAME repo: everything reusable
    rs2 = ReStore(rs.catalog, rs.store, rs.repo, heuristic="off")
    for name, qfn in pigmix.QUERIES.items():
        res, rep = rs2.run_plan(qfn())
        assert rep.n_executed == 0, f"{name}: full reuse expected"


def test_catalog_version_bump_prevents_stale_reuse():
    rs = fresh()
    rs.run_plan(pigmix.L3("sum"))
    assert len(rs.repo) > 0
    # modify the source dataset -> R4
    rs.catalog.register("page_views", pigmix.gen_page_views(1024, seed=99))
    assert rs.repo.evict_stale(rs.catalog) == len(rs.repo.entries) == 0 \
        or len(rs.repo) >= 0
    # build the plan against the new version: no stale matches possible
    pv = P.project(P.load("page_views",
                          version=rs.catalog.version("page_views")),
                   ["user", "estimated_revenue"])
    q = P.PhysicalPlan([P.store(pv, "v_out")])
    _, rep = rs.run_plan(q)
    assert not rep.jobs[0].reused_artifacts


# ---------------------------------------------------------------------------
# The L7 exact-splice guard (DESIGN.md §14): reusing a stored streaming
# region (LOAD+FOREACH/PROJECT/FILTER chains) whose output is about as
# big as its input LOSES time — the load of the artifact costs more than
# recomputing the cheap streaming ops, the regression that put PigMix L7
# at 0.6x reuse speedup.  The armed CostModel.should_splice declines
# those splices; blocking regions and evidence-free entries still
# splice unconditionally.


def _evict_finals(rs, plan):
    from repro.dataflow.compiler import compile_workflow
    finals = set(compile_workflow(plan).final_outputs.values())
    for name in finals:
        rs.store.delete(name)
    rs.repo._replace([e for e in rs.repo.entries
                      if e.artifact not in finals], [], None)


def test_l7_streaming_splice_declined():
    """The L7 repro, end to end: with the guard armed (the engine-owned
    default), the FOREACH splice is declined and the job re-executes
    from the source; disarmed, the same repo splices it.  Results are
    identical either way — the guard is pure economics."""
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=4096)
    rs = ReStore(cat, store, heuristic="aggressive")
    res_cold, _ = rs.run_plan(pigmix.L7())

    _evict_finals(rs, pigmix.L7())
    armed = ReStore(cat, store, rs.repo, heuristic="off")
    assert armed.repo.cost_model.min_splice_benefit_s > 0
    res_a, rep_a = armed.run_plan(pigmix.L7())
    assert all(not j.reused_artifacts for j in rep_a.jobs), \
        "streaming splice must be declined by the armed guard"
    assert any(j.executed for j in rep_a.jobs)

    _evict_finals(rs, pigmix.L7())
    rs.repo.cost_model.min_splice_benefit_s = 0.0
    disarmed = ReStore(cat, store, rs.repo, heuristic="off")
    res_d, rep_d = disarmed.run_plan(pigmix.L7())
    assert any(j.reused_artifacts for j in rep_d.jobs), \
        "disarmed guard must splice the stored FOREACH region"

    for res in (res_a, res_d):
        a, b = _rows(res_cold["L7_out"]), _rows(res["L7_out"])
        for c in a:
            assert np.allclose(a[c], b[c], atol=1e-3)


def test_should_splice_economics():
    """Unit-level pin of the admission rule itself."""
    from repro.core import plan as P2
    from repro.core.cost_model import CostModel
    from repro.core.repository import make_entry

    streaming = P2.PhysicalPlan(
        [P2.store(P2.project(P2.load("t"), ["a"]), "s_out")])
    blocking = P2.PhysicalPlan(
        [P2.store(P2.groupby(P2.project(P2.load("t"), ["a"]), ["a"],
                             {"n": ("count", "a")}), "b_out")])

    cm = CostModel(min_splice_benefit_s=1e-3)
    mb = int(2e6)        # ~1ms of load bandwidth per default CostModel
    # streaming region that barely shrinks its input: benefit below the
    # bar -> declined (the L7 shape)
    assert not cm.should_splice(
        make_entry(streaming, "a1", bytes_in=mb, bytes_out=mb - 100))
    # the same region with a strong reduction clears the bar
    assert cm.should_splice(
        make_entry(streaming, "a2", bytes_in=100 * mb, bytes_out=mb))
    # blocking regions always splice: recomputing a groupby/join is the
    # expensive path the paper's always-reuse rule addresses
    assert cm.should_splice(
        make_entry(blocking, "a3", bytes_in=mb, bytes_out=mb))
    # no bytes evidence -> no grounds to decline
    assert cm.should_splice(make_entry(streaming, "a4"))
    # inert at the bare-CostModel default threshold of 0
    assert CostModel().should_splice(
        make_entry(streaming, "a5", bytes_in=mb, bytes_out=mb))
