"""Device-resident artifact cache + write-behind persistence.

Covers the storage-hierarchy contracts from DESIGN.md §3: LRU eviction at
the byte bound, ``flush()`` as the durability barrier, crash safety (an
artifact is fully published or absent, never torn), alias resolution
through the cache, the injective name encoding, and manifest/data
capacity agreement.  Plus the ISSUE 8 accounting sweep: byte-exact
ledger under append/merge mutation storms, atomic read-merge-write
under concurrent appends, and swap_if never resurrecting an evicted
entry.
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.dataflow.table import Table
from repro.store.artifacts import (ArtifactStore, DeviceCache, _decode_name,
                                   _encode_name)


def _table(n=64, nvalid=None, seed=0):
    rng = np.random.default_rng(seed)
    return Table.from_numpy(
        {"a": rng.integers(0, 100, n).astype(np.int32),
         "b": rng.random(n).astype(np.float32)},
        nvalid=n if nvalid is None else nvalid)


def _tbytes(t):
    return t.nbytes()


# --------------------------------------------------------------- device LRU


def test_lru_evicts_at_byte_bound():
    t = _table(64)
    nb = _tbytes(t)
    cache = DeviceCache(max_bytes=3 * nb)
    for i in range(3):
        cache.put(f"t{i}", t, nb)
    assert len(cache) == 3 and cache.total_bytes == 3 * nb
    cache.get("t0")                      # refresh t0: t1 is now LRU
    cache.put("t3", t, nb)
    assert "t1" not in cache, "LRU entry must be evicted at the bound"
    assert "t0" in cache and "t2" in cache and "t3" in cache
    assert cache.total_bytes <= cache.max_bytes


def test_oversized_artifact_bypasses_cache():
    t = _table(64)
    cache = DeviceCache(max_bytes=10)
    cache.put("big", t, _tbytes(t))
    assert "big" not in cache and cache.total_bytes == 0


def test_get_of_just_produced_artifact_hits_device_cache(tmp_path):
    store = ArtifactStore(root=str(tmp_path / "a"))
    t = _table(64)
    store.put("x", t)
    h0 = store.cache.hits
    got = store.get("x")                 # no flush yet: must not need disk
    assert store.cache.hits == h0 + 1
    assert got.capacity == 64
    np.testing.assert_array_equal(np.asarray(got.col("a")),
                                  np.asarray(t.col("a")))
    store.close()


def test_eviction_falls_back_to_pending_then_disk(tmp_path):
    # cache far too small for even one artifact: every get must be served
    # by the pending write queue or by disk — never KeyError
    store = ArtifactStore(root=str(tmp_path / "a"), cache_bytes=1)
    t = _table(64)
    store.put("x", t)
    got = store.get("x")
    np.testing.assert_array_equal(np.asarray(got.col("a")),
                                  np.asarray(t.col("a")))
    store.flush()
    got2 = store.get("x")
    np.testing.assert_array_equal(np.asarray(got2.col("a")),
                                  np.asarray(t.col("a")))
    store.close()


def test_alias_resolves_through_cache(tmp_path):
    store = ArtifactStore(root=str(tmp_path / "a"))
    t = _table(32)
    store.put("target", t)
    store.alias("other", "target")
    assert store.exists("other")
    assert store.get("other") is store.get("target"), \
        "alias must hit the same cached device table"
    store.close()


# ------------------------------------------------------------ write-behind


def test_flush_is_a_durability_barrier(tmp_path):
    root = str(tmp_path / "a")
    store = ArtifactStore(root=root)
    t = _table(64, nvalid=20)
    store.put("x", t)
    store.flush()
    # fresh store object == simulated restart: only disk state survives
    store2 = ArtifactStore(root=root)
    assert store2.exists("x")
    got = store2.get("x")
    assert int(np.asarray(got.num_valid())) == 20
    store.close()
    store2.close()


def test_kill_before_flush_leaves_no_torn_artifact(tmp_path, monkeypatch):
    # simulated kill: the flusher thread never runs, pending writes die
    # with the process
    monkeypatch.setattr(
        "repro.store.artifacts._WriteBehind._ensure_thread",
        lambda self: None)
    root = str(tmp_path / "a")
    store = ArtifactStore(root=root)
    store.put("x", _table(64))
    assert store.exists("x")             # visible pre-crash via the cache
    # "restart": a new store sees either a complete artifact or nothing
    store2 = ArtifactStore(root=root)
    assert not store2.exists("x")
    assert store2.names() == []
    # no half-published directories: anything on disk is either a
    # complete artifact (manifest + data) or an unpublished .tmp- dir
    for d in os.listdir(root):
        full = os.path.join(root, d)
        if not d.startswith(".tmp-"):
            assert os.path.exists(os.path.join(full, "manifest.json"))
            assert os.path.exists(os.path.join(full, "data.npz"))
    store2.close()


def test_failed_write_publishes_nothing_and_raises_on_flush(
        tmp_path, monkeypatch):
    root = str(tmp_path / "a")
    store = ArtifactStore(root=root)

    def boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr("repro.store.artifacts.np.savez", boom)
    store.put("x", _table(64))
    with pytest.raises(OSError):
        store.flush()
    monkeypatch.undo()
    assert not os.path.exists(os.path.join(store._path("x"),
                                           "manifest.json"))
    assert [d for d in os.listdir(root) if not d.startswith(".tmp-")] == []
    # a lost write must stop advertising the artifact: otherwise later
    # runs would "reuse" data that will never be on disk
    assert not store.exists("x")
    with pytest.raises(KeyError):
        store.get("x")
    store.close()


def test_repeated_puts_coalesce_to_latest(tmp_path):
    root = str(tmp_path / "a")
    store = ArtifactStore(root=root)
    for seed in range(6):
        store.put("x", _table(64, seed=seed))
    store.flush()
    store2 = ArtifactStore(root=root)
    np.testing.assert_array_equal(
        np.asarray(store2.get("x").col("a")),
        np.asarray(_table(64, seed=5).col("a")))
    store.close()
    store2.close()


def test_delete_cancels_pending_write(tmp_path):
    root = str(tmp_path / "a")
    store = ArtifactStore(root=root)
    store.put("x", _table(64))
    store.delete("x")
    store.flush()
    assert not store.exists("x")
    store2 = ArtifactStore(root=root)
    assert not store2.exists("x")
    store.close()
    store2.close()


def test_synchronous_mode_still_supported(tmp_path):
    store = ArtifactStore(root=str(tmp_path / "a"), write_behind=False)
    store.put("x", _table(64))
    assert os.path.exists(os.path.join(store._path("x"), "data.npz"))
    store.flush()                        # no-op, must not hang
    store.close()


# ------------------------------------- accounting under mutation (ISSUE 8)


def test_append_byte_accounting_stays_exact_under_pressure(tmp_path):
    """Repeated in-place appends against a tight budget: the ledger
    must equal an independent recount after every merge, every recorded
    entry size must equal its live table's bytes (eviction ordering is
    priced on post-merge sizes), and the bound must hold."""
    t0 = _table(64)
    store = ArtifactStore(root=str(tmp_path / "a"),
                          cache_bytes=6 * t0.nbytes())
    store.put("x", t0)
    for i in range(6):
        store.append("x", _table(64, seed=i + 1))
        store.put(f"filler{i}", _table(64, seed=100 + i))  # pressure
        c = store.cache
        assert c.total_bytes == c.recount(), \
            f"ledger drifted after append {i}"
        with c._lock:
            entries = list(c._entries.items())
        for k, (tab, nb) in entries:
            assert nb == tab.nbytes(), \
                f"{k}: recorded {nb} != live table {tab.nbytes()}"
        assert c.total_bytes <= c.max_bytes
    # the appended artifact's cached copy is the merged value
    got = store.get("x")
    assert int(np.asarray(got.num_valid())) == 7 * 64
    store.close()


def test_concurrent_appends_merge_both_deltas(tmp_path):
    """Two racing appends of the same artifact: the read-merge-write
    must be atomic.  Pre-fix, thread A read the pre-B value, merged its
    own delta and put — silently erasing B's committed delta."""
    store = ArtifactStore(root=str(tmp_path / "a"))
    store.put("x", Table.from_numpy({"a": np.array([0], np.int64)}))
    a_entered = threading.Event()
    b_done = threading.Event()
    real_get = store.get

    def slow_get(name, *args, **kw):
        t = real_get(name, *args, **kw)
        if (threading.current_thread().name == "appender-a"
                and not a_entered.is_set()):
            a_entered.set()
            b_done.wait(timeout=0.5)   # pre-fix: B commits in this gap
        return t

    store.get = slow_get

    def run_a():
        store.append("x", Table.from_numpy({"a": np.array([1], np.int64)}))

    def run_b():
        a_entered.wait(timeout=2.0)
        store.append("x", Table.from_numpy({"a": np.array([2], np.int64)}))
        b_done.set()

    ta = threading.Thread(target=run_a, name="appender-a")
    tb = threading.Thread(target=run_b, name="appender-b")
    ta.start()
    tb.start()
    ta.join(timeout=10)
    tb.join(timeout=10)
    assert not ta.is_alive() and not tb.is_alive()
    store.get = real_get
    rows = sorted(store.get("x").to_numpy()["a"].tolist())
    assert rows == [0, 1, 2], f"a concurrent append was lost: {rows}"
    store.close()


def test_swap_if_does_not_resurrect_evicted_entry():
    """The flusher publishes a compacted table via swap_if after the
    LRU already evicted the entry: re-inserting would evict
    recently-used entries for one nobody asked for, and double-count
    its bytes against the budget."""
    t = _table(64)
    nb = t.nbytes()
    cache = DeviceCache(max_bytes=2 * nb)
    cache.put("a", t, nb)
    cache.put("b", _table(64, seed=1), nb)
    cache.put("c", _table(64, seed=2), nb)     # evicts "a"
    assert "a" not in cache
    cache.swap_if("a", t, _table(64, seed=3), nb)
    assert "a" not in cache, "evicted entry must not be resurrected"
    assert "b" in cache and "c" in cache
    assert cache.total_bytes == cache.recount() == 2 * nb


def test_oversized_put_reports_eviction_and_keeps_ledger_clean():
    t = _table(64)
    cache = DeviceCache(max_bytes=10)
    seen = []
    cache.on_evict = lambda name, tab, nb: seen.append((name, nb))
    cache.put("big", t, t.nbytes())
    assert "big" not in cache and cache.total_bytes == 0
    assert seen == [("big", t.nbytes())], \
        "oversized artifacts must still offer themselves for demotion"
    assert cache.recount() == 0


# ------------------------------------------------- naming & manifest fixes


def test_name_encoding_is_injective():
    names = ["art/q__v2", "a__b", "a/b", "a_u/b", "plain", "_u", "__",
             "art/x_y/z__w"]
    encoded = [_encode_name(n) for n in names]
    assert len(set(encoded)) == len(names)
    for n, e in zip(names, encoded):
        assert _decode_name(e) == n
        assert "/" not in e


def test_double_underscore_name_survives_reopen(tmp_path):
    root = str(tmp_path / "a")
    store = ArtifactStore(root=root)
    t = _table(32)
    store.put("art/q__v2", t)
    store.flush()
    store2 = ArtifactStore(root=root)
    assert store2.names() == ["art/q__v2"]
    got = store2.get("art/q__v2")
    np.testing.assert_array_equal(np.asarray(got.col("a")),
                                  np.asarray(t.col("a")))
    store.close()
    store2.close()


def test_manifest_capacity_matches_stored_data(tmp_path):
    root = str(tmp_path / "a")
    store = ArtifactStore(root=root)
    # 256-capacity table with 10 valid rows in a compacted prefix: stored
    # capacity shrinks to 16, and the manifest must say so
    store.put("x", _table(256, nvalid=10))
    store.flush()
    with open(os.path.join(store._path("x"), "manifest.json")) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(store._path("x"), "data.npz"))
    assert manifest["capacity"] == len(z["__valid__"]) == 16
    assert manifest["rows"] == 10
    store2 = ArtifactStore(root=root)
    assert store2.get("x").capacity == manifest["capacity"]
    store.close()
    store2.close()
