"""Process-wide plan-fingerprint jit cache + kernel padding paths.

Benchmarks build a fresh ``Engine`` per arm; identical plans must
trace/compile exactly once per process.  The padded Pallas path must give
identical results to the dense fallback at capacities that are not tile
multiples.
"""
import jax
import numpy as np

from repro.core import plan as P
from repro.dataflow import physical as PH
from repro.dataflow.compiler import compile_workflow
from repro.dataflow.executor import GLOBAL_JIT_CACHE, Engine
from repro.dataflow.physical import execute_plan
from repro.dataflow.table import Table, encode_strings
from repro.store.artifacts import ArtifactStore, Catalog


def _catalog(n=512, seed=0):
    rng = np.random.default_rng(seed)
    t = Table.from_numpy({
        "k": rng.integers(0, 16, n).astype(np.int32),
        "v": rng.random(n).astype(np.float32)})
    store = ArtifactStore()
    cat = Catalog(store)
    cat.register("t", t)
    return cat, store


def _plan():
    g = P.groupby(P.load("t"), ["k"], {"s": ("sum", "v")})
    return P.PhysicalPlan([P.store(g, "out")])


def test_identical_plans_compile_once_across_engines():
    GLOBAL_JIT_CACHE.clear()
    cat1, store1 = _catalog()
    cat2, store2 = _catalog()
    wf1 = compile_workflow(_plan())
    wf2 = compile_workflow(_plan())

    eng1 = Engine(cat1, store1)
    res1, stats1 = eng1.run_workflow(wf1)
    misses_after_first = GLOBAL_JIT_CACHE.misses
    assert misses_after_first >= 1

    eng2 = Engine(cat2, store2)     # fresh engine, identical plan
    res2, stats2 = eng2.run_workflow(wf2)
    assert GLOBAL_JIT_CACHE.misses == misses_after_first, \
        "identical plan in a second Engine must not re-trace"
    assert GLOBAL_JIT_CACHE.hits >= 1

    # per-op stats must be keyed by the CURRENT plan's uids even when
    # the jitted fn (and its stats) came from the first plan's closure
    wf2_uids = {op.uid for j in wf2.jobs for op in j.plan.topo()}
    for st in stats2:
        assert st.op_rows, "op_rows lost through the shared jit cache"
        assert set(st.op_rows) <= wf2_uids
    for st1, st2 in zip(stats1, stats2):
        assert sorted(st1.op_rows.values()) == sorted(st2.op_rows.values())

    # results agree
    a = np.sort(np.asarray(res1["out"].to_numpy()["s"]))
    b = np.sort(np.asarray(res2["out"].to_numpy()["s"]))
    np.testing.assert_allclose(a, b, atol=1e-4)


def test_different_plans_get_distinct_cache_entries():
    GLOBAL_JIT_CACHE.clear()
    cat, store = _catalog()
    eng = Engine(cat, store)
    eng.run_workflow(compile_workflow(_plan()))
    m1 = GLOBAL_JIT_CACHE.misses
    other = P.PhysicalPlan([P.store(
        P.groupby(P.load("t"), ["k"], {"m": ("mean", "v")}), "out2")])
    eng.run_workflow(compile_workflow(other))
    assert GLOBAL_JIT_CACHE.misses > m1


def _odd_capacity_tables():
    # 300 and 320 are > 256 and not multiples of 256: before the padding
    # change these capacities silently bailed to the dense fallback
    rng = np.random.default_rng(7)
    n_l, n_r = 320, 300
    left = Table.from_numpy({
        "key": encode_strings([f"k{i % 40}" for i in range(n_l)]),
        "val": rng.random(n_l).astype(np.float32)})
    right = Table.from_numpy({
        "key": encode_strings([f"k{i}" for i in range(n_r)]),
        "payload": rng.integers(0, 100, n_r).astype(np.int32)})
    return left, right


def _sorted_cols(res):
    return {c: np.sort(np.asarray(v).astype(np.float64), axis=0)
            for c, v in res.to_numpy().items()}


def test_pallas_padded_matches_fallback_at_odd_capacity():
    left, right = _odd_capacity_tables()
    gplan = P.PhysicalPlan([P.store(P.groupby(
        P.load("t"), ["key"], {"s": ("sum", "val"),
                               "c": ("count", "val")}), "out")])
    jplan = P.PhysicalPlan([P.store(P.join(
        P.load("t"), P.load("r"), ["key"], ["key"]), "out")])
    datasets = {"t": left, "r": right}
    ref_g, _ = execute_plan(gplan, datasets)
    ref_j, _ = execute_plan(jplan, datasets)
    PH.set_use_pallas(True)
    try:
        got_g, _ = execute_plan(gplan, datasets)
        got_j, _ = execute_plan(jplan, datasets)
    finally:
        PH.set_use_pallas(False)
    for ref, got in ((ref_g, got_g), (ref_j, got_j)):
        r, g = _sorted_cols(ref["out"]), _sorted_cols(got["out"])
        assert sorted(r) == sorted(g)
        for c in r:
            np.testing.assert_allclose(r[c], g[c], atol=1e-3)


def test_compact_is_stable_and_sort_free_correct():
    """Table.compact() (cumsum+searchsorted, no sort) must move valid
    rows to a prefix preserving their order."""
    rng = np.random.default_rng(11)
    n = 513                              # deliberately not a power of two
    v = np.zeros(n, bool)
    v[rng.choice(n, 200, replace=False)] = True
    t = Table.from_numpy({"a": np.arange(n, dtype=np.int32)})
    t = Table(t.columns, jax.numpy.asarray(v))
    c = t.compact()
    got_valid = np.asarray(c.valid)
    assert got_valid[:200].all() and not got_valid[200:].any()
    np.testing.assert_array_equal(np.asarray(c.col("a"))[:200],
                                  np.flatnonzero(v).astype(np.int32))


def test_hash_cache_shares_across_operators():
    """A fan-out hitting GROUPBY + JOIN on the same key column must hash
    each (columns, seed) pair once per plan execution: the GROUPBY's h1
    (seed 0) is the JOIN's probe hash."""
    calls = {"n": 0}
    orig = PH.hash_columns

    def counting(table, names, seed=0):
        calls["n"] += 1
        return orig(table, names, seed=seed)

    rng = np.random.default_rng(3)
    t = Table.from_numpy({"k": rng.integers(0, 8, 256).astype(np.int32),
                          "v": rng.random(256).astype(np.float32)})
    r = Table.from_numpy({"k": np.arange(8, dtype=np.int32),
                          "w": rng.random(8).astype(np.float32)})
    src = P.load("t")
    g = P.groupby(src, ["k"], {"s": ("sum", "v")})
    j = P.join(src, P.load("r"), ["k"], ["k"])
    plan = P.PhysicalPlan([P.store(g, "g"), P.store(j, "j")])

    PH.hash_columns = counting
    try:
        execute_plan(plan, {"t": t, "r": r})
        with_cache = calls["n"]
        calls["n"] = 0
        # same ops called directly, no shared cache
        PH.op_groupby(t, ["k"], {"s": ("sum", "v")})
        PH.op_join(t, r, ["k"], ["k"])
        without_cache = calls["n"]
    finally:
        PH.hash_columns = orig
    # groupby: (k,0) (k,101); join: (k,0) shared + right (k,0)
    assert with_cache == without_cache - 1, \
        "plan execution must share key hashes across operators"
