"""Serving + prefix-reuse repository: reuse never changes outputs, the
sub-prefix (sub-job) aliases fire, and the eviction rules hold."""
import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.models.api import build
from repro.serve.engine import ServeEngine
from repro.serve.prefix_repo import PrefixRepository, prefix_fingerprints


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-1.7b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_reuse_matches_plain(setup):
    cfg, model, params = setup
    repo = PrefixRepository()
    reuse = ServeEngine(model, params, max_len=64, prefix_repo=repo)
    plain = ServeEngine(model, params, max_len=64)
    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab_size, 24)
    for i in range(3):
        p = np.concatenate([shared, rng.integers(1, cfg.vocab_size, 8)])
        a, sa = reuse.serve(p, 6)
        b, _ = plain.serve(p, 6)
        assert (a == b).all(), i
        if i > 0:
            assert sa.reused_tokens >= 24, "shared prefix must be reused"


def test_recurrent_arch_exact_prefix_only(setup):
    cfg = get_config("xlstm-350m", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    repo = PrefixRepository()
    eng = ServeEngine(model, params, max_len=48, prefix_repo=repo)
    plain = ServeEngine(model, params, max_len=48)
    rng = np.random.default_rng(1)
    p = rng.integers(1, cfg.vocab_size, 16)
    a1, s1 = eng.serve(p, 4)
    a2, s2 = eng.serve(p, 4)       # exact hit
    b, _ = plain.serve(p, 4)
    assert (a1 == b).all() and (a2 == b).all()
    assert s2.reused_tokens == 16 and s2.prefilled_tokens == 0


def test_fingerprint_chain_properties():
    t1 = np.array([1, 2, 3])
    t2 = np.array([1, 2, 4])
    f1 = prefix_fingerprints(t1, "v0")
    f2 = prefix_fingerprints(t2, "v0")
    assert f1[:2] == f2[:2] and f1[2] != f2[2]
    assert prefix_fingerprints(t1, "v1") != f1   # model version matters


def test_eviction_rules():
    repo = PrefixRepository(capacity_bytes=1 << 20)
    import jax.numpy as jnp
    big = {"k": jnp.zeros((1 << 17,), jnp.float32)}   # 512 KiB
    t = np.arange(10)
    repo.store(t, big)
    repo.store(np.arange(12), big)
    assert repo.total_bytes <= repo.capacity_bytes
    # R3: LRU window eviction
    for e in repo.entries.values():
        e.last_used = 1.0
    assert repo.evict_unused(window_s=1) >= 1
    # R4: version invalidation clears everything
    repo.store(t, big)
    n = repo.invalidate_version("v2")
    assert n >= 1 and len(repo) == 0


def test_continuous_batching_matches_sequential(setup):
    """BatchEngine (slot-managed batched decode, mid-flight admission)
    produces exactly the sequential ServeEngine outputs."""
    import numpy as np
    from repro.serve.batch_engine import BatchEngine
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, n) for n in (9, 14, 7, 11)]
    ref_engine = ServeEngine(model, params, max_len=48)
    refs = [ref_engine.serve(p, 5)[0] for p in prompts]
    be = BatchEngine(model, params, n_slots=2, max_len=48)
    reqs = [be.submit(p, 5, rid=i) for i, p in enumerate(prompts)]
    be.run()
    for r, ref in zip(reqs, refs):
        assert r.done and (np.array(r.out) == ref).all()
