"""End-to-end behaviour of the full system: the paper's headline claims,
checked as assertions rather than plots."""
import numpy as np

from repro.core.restore import ReStore
from repro.store.artifacts import ArtifactStore, Catalog
from repro.workloads import pigmix


def test_paper_headline_reuse_speedup():
    """Reusing stored results must cut executed work dramatically
    (paper Fig 9/10: order-of-magnitude speedups).  Asserted on work
    executed (jobs/operators) — wall-time ratios live in benchmarks/."""
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=4096)
    rs = ReStore(cat, store, heuristic="aggressive")

    ops_cold = ops_warm = 0
    for name, qfn in pigmix.QUERIES.items():
        _, rep = rs.run_plan(qfn())
        ops_cold += sum(j.n_ops_before for j in rep.jobs)

    rs2 = ReStore(cat, store, rs.repo, heuristic="off")
    for name, qfn in pigmix.QUERIES.items():
        _, rep = rs2.run_plan(qfn())
        ops_warm += sum(j.n_ops_after for j in rep.jobs if j.executed)
    assert ops_warm == 0, "second pass must execute nothing"


def test_sharing_between_different_queries():
    """L3 reuses L2-style sub-jobs; variants share jobs — the cross-query
    sharing the paper motivates with the Facebook 7-day policy."""
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=4096)
    # L2 shares L3's streaming page_views projection — below the L7
    # exact-splice guard's bar at this toy size, and this test pins the
    # cross-query sharing mechanism, so the guard is disarmed
    rs = ReStore(cat, store, heuristic="aggressive",
                 min_splice_benefit_s=0.0)

    rs.run_plan(pigmix.L3("sum"))
    repo_size_after_l3 = len(rs.repo)
    _, rep = rs.run_plan(pigmix.L3("max"))
    assert not rep.jobs[0].executed, "join job shared between variants"
    # repository statistics recorded reuse
    used = [e for e in rs.repo.entries if e.use_count > 0]
    assert repo_size_after_l3 > 0
    _, rep2 = rs.run_plan(pigmix.L2())
    # L2 (join with power_users) shares the page_views projection sub-job
    assert any(j.reused_artifacts for j in rep2.jobs), \
        "cross-query sub-job sharing must fire"
