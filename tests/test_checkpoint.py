"""Checkpointing: atomic roundtrip, torn-write immunity, and exact
resume-after-failure through the train driver."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3)
    return {"w": jax.random.normal(ks[0], (8, 16)),
            "nested": {"b": jax.random.normal(ks[1], (16,)),
                       "s": jnp.int32(7)},
            "t": (jax.random.normal(ks[2], (4,)),
                  jnp.ones((2, 2), jnp.bfloat16))}


def test_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    save_checkpoint(d, 42, tree, extra={"note": "x"})
    assert latest_step(d) == 42
    restored, manifest = restore_checkpoint(d, 42, jax.eval_shape(
        lambda: tree))
    assert manifest["step"] == 42 and manifest["extra"]["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        af = np.asarray(jnp.asarray(a, jnp.float32))
        bf = np.asarray(jnp.asarray(b, jnp.float32))
        assert np.allclose(af, bf)


def test_torn_checkpoint_ignored(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 10, _tree())
    # simulate a torn write: step dir with broken manifest
    torn = os.path.join(d, "step_00000020")
    os.makedirs(torn)
    with open(os.path.join(torn, "manifest.json"), "w") as f:
        f.write("{ this is not json")
    assert latest_step(d) == 10, "torn checkpoints must be skipped"


def test_multiple_steps_latest_wins(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in (5, 10, 15):
        save_checkpoint(d, s, _tree(seed=s))
    assert latest_step(d) == 15


def test_train_resume_exact(tmp_path):
    """Uninterrupted run == (run to step 6, kill, resume) — same losses."""
    from repro.launch.train import train
    d1 = str(tmp_path / "a")
    losses_full = train(steps=10, ckpt_every=3, ckpt_dir=d1, quiet=True,
                        seq_len=16, batch_size=2)

    d2 = str(tmp_path / "b")
    train(steps=6, ckpt_every=3, ckpt_dir=d2, quiet=True,
          seq_len=16, batch_size=2)
    # resume: checkpoints exist at step 3 and 6; resumes from 6
    losses_resumed = train(steps=10, ckpt_every=3, ckpt_dir=d2,
                           quiet=True, seq_len=16, batch_size=2)
    assert np.allclose(losses_full[6:], losses_resumed, atol=1e-5), \
        (losses_full[6:], losses_resumed)
