"""Repository economics: eviction rules R3/R4 (window semantics, store
deletion), byte-budget admission/eviction ordering under both policies,
the cost model's materialization decisions, and the executor's per-op
cost attribution (DESIGN.md §9)."""
import time

import numpy as np
import pytest

from repro.core import plan as P
from repro.core.cost_model import CostModel
from repro.core.repository import Repository, make_entry
from repro.core.restore import ReStore
from repro.dataflow.executor import attribute_op_costs
from repro.dataflow.expr import Col
from repro.dataflow.table import Table
from repro.store.artifacts import ArtifactStore, Catalog
from repro.workloads import pigmix


def _table(n=4):
    return Table.from_numpy({"a": np.arange(n, dtype=np.int32)})


def _entry(store, name, *, bytes_out=1000, producer_cost_s=1.0,
           bytes_in=10_000):
    """Distinct-signature entry whose artifact really exists in store."""
    pl = P.PhysicalPlan([P.store(P.project(P.load("d"), [name]), name)])
    store.put(name, _table())
    return make_entry(pl, name, bytes_in=bytes_in, bytes_out=bytes_out,
                      producer_cost_s=producer_cost_s)


def _fresh_cm(**kw):
    kw.setdefault("fixed_io_s", 0.0)
    kw.setdefault("reuse_halflife_s", 1e9)   # no decay inside a test
    return CostModel(**kw)


# ---------------------------------------------------------------- R3 / R4

def test_evict_unused_window_semantics_and_store_deletion():
    store = ArtifactStore()
    repo = Repository()
    old = _entry(store, "art/old")
    new = _entry(store, "art/new")
    repo.add(old)
    repo.add(new)
    old.last_used = time.time() - 100.0
    new.last_used = time.time()
    assert repo.evict_unused(10.0, store=store) == 1
    assert [e.artifact for e in repo.entries] == ["art/new"]
    assert not store.exists("art/old")
    assert store.exists("art/new")


def test_evict_unused_defaults_to_bound_store():
    store = ArtifactStore()
    repo = Repository()
    repo.bind_store(store)
    e = _entry(store, "art/x")
    repo.add(e)
    e.last_used = time.time() - 100.0
    assert repo.evict_unused(1.0) == 1
    assert not store.exists("art/x")


def test_evict_stale_against_version_bumped_catalog_deletes_artifacts():
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=1024)
    rs = ReStore(cat, store, heuristic="aggressive")
    rs.run_plan(pigmix.L3("sum"))
    assert len(rs.repo) > 0
    arts = [e.artifact for e in rs.repo.entries]
    # re-ingest the source: every derived entry is stale (rule R4)
    cat.register("page_views", pigmix.gen_page_views(1024, seed=7))
    dropped = rs.repo.evict_stale(cat)          # bound store by default
    assert dropped == len(arts)
    assert len(rs.repo) == 0
    for a in arts:
        assert not store.exists(a)


# ------------------------------------------------------------ byte budget

def test_under_budget_admission_is_unconditional():
    store = ArtifactStore()
    repo = Repository(budget_bytes=10_000, policy="cost",
                      cost_model=_fresh_cm())
    repo.bind_store(store)
    assert repo.add(_entry(store, "art/a", bytes_out=4000,
                           producer_cost_s=1e-9))
    assert repo.add(_entry(store, "art/b", bytes_out=4000,
                           producer_cost_s=1e-9))
    assert repo.total_stored_bytes() == 8000
    assert repo.evictions == 0


def test_lru_policy_evicts_least_recently_used():
    store = ArtifactStore()
    repo = Repository(budget_bytes=2000, policy="lru")
    repo.bind_store(store)
    e1 = _entry(store, "art/e1")
    e2 = _entry(store, "art/e2")
    repo.add(e1)
    repo.add(e2)
    repo.record_use(e1)                 # e2 becomes the LRU victim
    assert repo.add(_entry(store, "art/e3"))
    names = {e.artifact for e in repo.entries}
    assert names == {"art/e1", "art/e3"}
    assert not store.exists("art/e2")
    assert repo.evictions == 1


def test_cost_policy_evicts_lowest_benefit_per_byte():
    store = ArtifactStore()
    repo = Repository(budget_bytes=2000, policy="cost",
                      cost_model=_fresh_cm())
    repo.bind_store(store)
    cheap = _entry(store, "art/cheap", producer_cost_s=1e-4)
    rich = _entry(store, "art/rich", producer_cost_s=5.0)
    repo.add(cheap)
    repo.add(rich)
    mid = _entry(store, "art/mid", producer_cost_s=1.0)
    assert repo.add(mid)
    names = {e.artifact for e in repo.entries}
    assert names == {"art/rich", "art/mid"}
    assert not store.exists("art/cheap")


def test_cost_policy_rejects_newcomer_worth_less_than_incumbents():
    store = ArtifactStore()
    repo = Repository(budget_bytes=2000, policy="cost",
                      cost_model=_fresh_cm())
    repo.bind_store(store)
    repo.add(_entry(store, "art/a", producer_cost_s=5.0))
    repo.add(_entry(store, "art/b", producer_cost_s=5.0))
    loser = _entry(store, "art/loser", producer_cost_s=1e-4)
    assert not repo.add(loser)
    assert repo.rejections == 1
    assert {e.artifact for e in repo.entries} == {"art/a", "art/b"}
    # the caller (ReStore) is responsible for deleting rejected artifacts


def test_oversized_entry_rejected_outright():
    store = ArtifactStore()
    repo = Repository(budget_bytes=500, policy="cost",
                      cost_model=_fresh_cm())
    repo.bind_store(store)
    assert not repo.add(_entry(store, "art/huge", bytes_out=1000))


def test_pinned_entries_never_budget_evicted_and_rebalance_settles():
    store = ArtifactStore()
    repo = Repository(budget_bytes=1000, policy="cost",
                      cost_model=_fresh_cm())
    repo.bind_store(store)
    pinned = _entry(store, "art/pin", producer_cost_s=1e-6)
    repo.pin({"art/pin"})
    assert repo.add(pinned)             # pinned: admitted unconditionally
    rich = _entry(store, "art/rich", producer_cost_s=5.0)
    assert not repo.add(rich)           # only evictable entry is pinned
    repo.unpin({"art/pin"})
    repo.add(rich)                      # now the pin is gone: evicts art/pin
    assert {e.artifact for e in repo.entries} == {"art/rich"}
    # rebalance on an over-budget repo trims the weakest entries
    repo.budget_bytes = 0
    assert repo.rebalance() == 1
    assert len(repo) == 0
    assert not store.exists("art/rich")


def test_delete_drops_alias_so_restore_is_not_redirected():
    store = ArtifactStore()
    store.put("art/target", _table(4))
    store.alias("art/out", "art/target")
    assert store.exists("art/out")
    store.delete("art/out")         # deletes through...: alias dropped
    # re-storing the name must land under the name itself, not the
    # stale alias target
    store.put("art/out", _table(8))
    assert int(store.get("art/out").num_valid()) == 8
    assert int(store.get("art/target").num_valid()) == 4


# -------------------------------------------------------------- cost model

def test_should_materialize_requires_history_and_positive_savings():
    cm = _fresh_cm()
    assert not cm.should_materialize("never-seen")
    cm.observe_op("hot", rows_out=100, bytes_out=1000, producer_cost_s=0.5)
    assert cm.should_materialize("hot")
    # producing is cheaper than reloading -> keep recomputing
    slow = _fresh_cm(load_bandwidth_bytes_s=1.0)
    slow.observe_op("big", rows_out=100, bytes_out=100_000,
                    producer_cost_s=0.5)
    assert not slow.should_materialize("big")


def test_observe_stored_bytes_pins_exact_size():
    cm = _fresh_cm()
    cm.observe_op("x", rows_out=10, bytes_out=999, producer_cost_s=0.1)
    cm.observe_stored_bytes("x", 123)
    cm.observe_op("x", rows_out=10, bytes_out=5555, producer_cost_s=0.1)
    assert cm.stats_for("x").bytes_out == 123   # estimate never overwrites


def test_calibrate_io_from_store_samples(tmp_path):
    # sentinel defaults: calibration must overwrite BOTH bandwidths
    cm = CostModel(load_bandwidth_bytes_s=123.0,
                   store_bandwidth_bytes_s=456.0)
    store = ArtifactStore(root=str(tmp_path))
    t = Table.from_numpy(
        {"a": np.zeros(1 << 15, dtype=np.int64)})   # > calibration floor
    store.put("big", t)
    store.flush()
    store.cache.drop("big")                         # force a real disk read
    store.get("big")
    cm.calibrate_io(store)
    assert cm.load_bw != 123.0 and cm.load_bw > 0
    assert cm.store_bw != 456.0 and cm.store_bw > 0
    io = store.io_stats()
    assert io["load_bytes"] > 1 << 16 and io["store_bytes"] > 1 << 16
    store.close()


def test_calibrate_io_prefers_disk_over_cache_hits(tmp_path):
    """A storm of near-free cache hits must not inflate load bandwidth
    past what the disk tier measured."""
    store = ArtifactStore(root=str(tmp_path))
    t = Table.from_numpy({"a": np.zeros(1 << 15, dtype=np.int64)})
    store.put("big", t)
    store.flush()
    store.cache.drop("big")
    store.get("big")                                # one disk read
    for _ in range(50):
        store.get("big")                            # cache hits
    io = store.io_stats()
    assert io["memload_bytes"] > io["load_bytes"]   # hits sampled apart
    cm = CostModel()
    cm.calibrate_io(store)
    disk_bw = io["load_bytes"] / io["load_s"]
    assert cm.load_bw == pytest.approx(disk_bw)
    store.close()


def test_calibrate_io_disk_backed_never_blends_memory_speed(tmp_path):
    """ISSUE 8: a disk-backed store whose probe mix is many cache hits
    plus a few tiny disk reads (below the sample-mass floor) must keep
    its prior load bandwidth, NOT fall back to memory-speed samples —
    pre-fix calibrate_io blended the tiers and priced cold reads at
    ~zero, so refresh_decision always chose 'load'."""
    store = ArtifactStore(root=str(tmp_path))
    small = Table.from_numpy({"a": np.zeros(64, dtype=np.int64)})
    store.put("tiny", small)
    store.flush()
    store.cache.drop("tiny")
    store.get("tiny")                   # disk read below MIN_SAMPLE_BYTES
    big = Table.from_numpy({"a": np.zeros(1 << 16, dtype=np.int64)})
    store.put("hot", big)
    for _ in range(20):
        store.get("hot")                # cache hits: huge memload mass
    io = store.io_stats()
    assert io["has_disk"]
    assert io["memload_bytes"] > CostModel.MIN_SAMPLE_BYTES
    assert io["load_bytes"] <= CostModel.MIN_SAMPLE_BYTES
    cm = CostModel(load_bandwidth_bytes_s=123.0)
    cm.calibrate_io(store)
    assert cm.load_bw == 123.0, \
        "disk-backed store calibrated cold loads from cache-hit samples"
    # the device tier still calibrates from those same samples
    assert cm.tier_bw["device"] == pytest.approx(
        io["memload_bytes"] / io["memload_s"])
    store.close()


def test_calibrate_io_separates_tier_bandwidths():
    """Mixed traffic across host and remote tiers must produce distinct
    per-tier bandwidths — no blending into one 'load' number."""
    samples = {
        "has_disk": True,
        "load_bytes": 1 << 20, "load_s": 1.0,        # disk:   ~1 MB/s
        "memload_bytes": 1 << 24, "memload_s": 0.1,  # device: fast
        "hostload_bytes": 1 << 22, "hostload_s": 1.0,
        "remoteload_bytes": 1 << 20, "remoteload_s": 4.0,
        "store_bytes": 1 << 20, "store_s": 2.0,
    }

    class FakeStore:
        io_stats = samples
    cm = CostModel()
    cm.calibrate_io(FakeStore())
    assert cm.load_bw == pytest.approx((1 << 20) / 1.0)
    assert cm.tier_bw["device"] == pytest.approx((1 << 24) / 0.1)
    assert cm.tier_bw["host"] == pytest.approx((1 << 22) / 1.0)
    assert cm.tier_bw["remote"] == pytest.approx((1 << 20) / 4.0)
    assert cm.store_bw == pytest.approx((1 << 20) / 2.0)
    # pricing reflects the separation: remote adds latency on top of bw
    assert cm.tier_load_cost_s(1 << 20, "remote") \
        > cm.tier_load_cost_s(1 << 20, "disk") \
        > cm.tier_load_cost_s(1 << 20, "device")


def test_calibrate_io_legacy_stats_keep_memload_fallback():
    """A stats dict that predates the tier tags (no ``has_disk`` key)
    is a memory-backed store by construction: memload samples may
    stand in for the load bandwidth there."""
    class Legacy:
        io_stats = {"memload_bytes": 1 << 20, "memload_s": 0.5}
    cm = CostModel(load_bandwidth_bytes_s=123.0)
    cm.calibrate_io(Legacy())
    assert cm.load_bw == pytest.approx((1 << 20) / 0.5)


# ------------------------------------------------- executor cost attribution

def test_attribute_op_costs_sums_to_wall_on_single_sink():
    pv = P.project(P.load("d"), ["a"])
    f = P.filter_(pv, Col("a") > 0)
    plan = P.PhysicalPlan([P.store(f, "out")])
    ops = plan.topo()
    op_rows = {op.uid: 100 for op in ops}
    cost = attribute_op_costs(plan, op_rows, wall_s=2.0)
    sink = plan.sinks[0]
    assert cost[sink.uid] == pytest.approx(2.0)
    # cumulative cost grows monotonically downstream
    assert cost[pv.uid] < cost[f.uid] < cost[sink.uid]


# -------------------------------------------- structural fingerprints / R4

def test_structural_fingerprints_mask_versions_and_rebind():
    def q(version):
        pv = P.project(P.load("page_views", version=version), ["user"])
        return P.PhysicalPlan([P.store(pv, "out")])

    v0, v1 = q(0), q(1)
    assert (v0.fingerprints()[id(v0.sinks[0])]
            != v1.fingerprints()[id(v1.sinks[0])])
    assert (v0.structural_fingerprints()[id(v0.sinks[0])]
            == v1.structural_fingerprints()[id(v1.sinks[0])])
    rebound = P.rebind_load_versions(v0, {"page_views": 1})
    assert (rebound.fingerprints()[id(rebound.sinks[0])]
            == v1.fingerprints()[id(v1.sinks[0])])


# --------------------------------------------------- cost heuristic (e2e)

def test_cost_mode_first_sighting_stores_only_job_outputs():
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=1024)
    repo = Repository(cost_model=_fresh_cm())
    rs = ReStore(cat, store, repo, heuristic="cost")
    _, rep = rs.run_plan(pigmix.L3("sum"))
    stored = [a for j in rep.jobs for a in j.stored_candidates]
    assert len(stored) == 2             # the 2 whole-job outputs, nothing else


def test_cost_mode_materializes_recurring_subjob_then_reuses_it():
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=1024)
    repo = Repository(cost_model=_fresh_cm())
    rs = ReStore(cat, store, repo, heuristic="cost")
    rs.run_plan(pigmix.L3("sum"))       # 1st sighting of the projection

    pv = P.project(P.load("page_views"), ["user", "estimated_revenue"])
    q1 = P.PhysicalPlan([P.store(
        P.filter_(pv, Col("estimated_revenue") > 50.0), "q1_out")])
    sfp = q1.structural_fingerprints()[id(pv)]
    st = repo.cost_model.stats_for(sfp)
    assert st is not None and st.times_seen >= 1   # stats wiring works
    st.producer_cost_s = 10.0           # make the benefit decisive

    _, rep1 = rs.run_plan(q1)
    stored = [a for j in rep1.jobs for a in j.stored_candidates]
    assert len(stored) >= 2             # job output + materialized projection

    pv2 = P.project(P.load("page_views"), ["user", "estimated_revenue"])
    q2 = P.PhysicalPlan([P.store(
        P.filter_(pv2, Col("estimated_revenue") > 80.0), "q2_out")])
    _, rep2 = rs.run_plan(q2)
    assert any(j.reused_artifacts for j in rep2.jobs)


def test_budgeted_restore_respects_budget_and_reclaims_rejects():
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=1024)
    # size the candidate volume, then replay with a 30% budget
    probe = ReStore(cat, ArtifactStore(), Repository(),
                    heuristic="aggressive")
    probe.run_plan(pigmix.L3("sum"))
    total = probe.repo.total_stored_bytes()
    assert total > 0

    budget = int(total * 0.3)
    repo = Repository(budget_bytes=budget, policy="cost",
                      cost_model=_fresh_cm())
    rs = ReStore(cat, store, repo, heuristic="aggressive")
    _, rep = rs.run_plan(pigmix.L3("sum"))
    assert repo.total_stored_bytes() <= budget
    assert not repo.pinned              # run-scoped pins are released
    # a repeat run (served via aliases/store fast path) releases pins too
    rs.run_plan(pigmix.L3("sum"))
    assert not repo.pinned
    # every surviving byte is accounted for: an artifact in the store is
    # either a repository entry or a workflow job output; rejected
    # injected candidates were deleted again
    entry_arts = {e.artifact for e in repo.entries}
    job_outputs = {a for j in rep.jobs for a in j.reused_artifacts} | \
                  {a for j in rep.jobs for a in j.stored_candidates}
    from repro.dataflow.compiler import compile_workflow
    wf_outputs = {o for j in compile_workflow(pigmix.L3("sum")).jobs
                  for o in j.outputs}
    for n in store.names():
        if not n.startswith("art/"):
            continue
        assert n in entry_arts or n in wf_outputs, n


# ----------------------------------------------------------- stream driver

def test_stream_driver_smoke_all_modes():
    from repro.workloads.stream import StreamConfig, run_stream
    cfg = StreamConfig(n_events=6, n_tenants=2, n_rows=512,
                       churn_every=3, seed=1)
    keep = run_stream("keep", cfg)
    assert len(keep.events) == 6 and keep.total_wall_s > 0
    assert keep.peak_store_bytes > 0
    off = run_stream("off", cfg)
    assert off.n_reused_total == 0
    budget = max(int(keep.peak_store_bytes * 0.25), 1)
    for mode in ("lru", "cost"):
        r = run_stream(mode, cfg, budget_bytes=budget)
        assert len(r.events) == 6
        assert r.repo_bytes <= budget
    # identical schedule across modes (same seed)
    assert [e.template for e in keep.events] == \
           [e.template for e in off.events]


# ------------------------------------------- cross-kind budget (§17)

def _cross_kind_repo(budget):
    """One repository + budget serving BOTH artifact kinds: analytics
    entries bound to an ArtifactStore, prefix entries to a KVTierStore,
    recency on the deterministic logical clock."""
    from repro.serve.kv_repo import KVRepository, LogicalClock
    from repro.serve.kv_store import KVTierStore
    store = ArtifactStore()
    repo = Repository(budget_bytes=budget, cost_model=_fresh_cm(),
                      clock=LogicalClock())
    repo.bind_store(store)
    kv = KVRepository(repository=repo, store=KVTierStore())
    return repo, store, kv


def test_hot_kv_prefix_evicts_cold_analytics_artifact():
    import jax.numpy as jnp
    repo, store, kv = _cross_kind_repo(budget=3000)
    cold = _entry(store, "art/cold", bytes_out=2000,
                  producer_cost_s=0.001)
    assert repo.add(cold)
    # a hot prompt prefix (32 tokens, 4 observed reuses) is worth more
    # per byte than the barely-used analytics artifact: admitting it
    # under the SHARED budget evicts the plan entry from ITS store
    e = kv.store_prefix(np.arange(32),
                        {"k": jnp.zeros((2000,), jnp.uint8)},
                        history_uses=4.0)
    assert e is not None
    assert not store.exists("art/cold")
    assert kv.store.exists(e.artifact)
    assert [x.kind for x in repo.entries] == ["prefix"]


def test_hot_analytics_artifact_evicts_cold_kv_prefix():
    import jax.numpy as jnp
    repo, store, kv = _cross_kind_repo(budget=3000)
    e = kv.store_prefix(np.arange(4),
                        {"k": jnp.zeros((2000,), jnp.uint8)})
    assert e is not None
    hot = _entry(store, "art/hot", bytes_out=2000, producer_cost_s=5.0)
    assert repo.add(hot)
    # the eviction routed the delete to the PREFIX kind's store
    assert not kv.store.exists(e.artifact)
    assert store.exists("art/hot")
    assert [x.kind for x in repo.entries] == ["plan"]


def test_stats_report_both_kinds_under_one_budget():
    import jax.numpy as jnp
    repo, store, kv = _cross_kind_repo(budget=10_000)
    repo.add(_entry(store, "art/a", bytes_out=1000))
    e = kv.store_prefix(np.arange(8),
                        {"k": jnp.zeros((1000,), jnp.uint8)})
    kv.record_use(kv.probe(np.arange(8)))
    hit = kv.probe(np.arange(8 + 4))     # covering prefix of a longer
    kv.record_use(hit)                   # prompt: semantic hit
    s = repo.stats()
    assert s["plan"]["entries"] == 1 and s["plan"]["bytes"] == 1000
    assert s["prefix"]["entries"] == 1 and s["prefix"]["bytes"] == 1000
    assert s["prefix"]["exact_hits"] == 1
    assert s["prefix"]["semantic_hits"] == 1
    assert repo.total_stored_bytes() == 2000
    assert e.bytes_out == 1000
