"""Autotuner unit tests (DESIGN.md §14): persistence round-trip,
deterministic selection under a stubbed measurement, graceful fallback
on missing/corrupt tables, and the inert-by-default runtime hook."""
import json

import pytest

from repro.kernels import autotune
from repro.kernels.autotune import TuningTable, rows_bucket


@pytest.fixture
def tmp_table(tmp_path, monkeypatch):
    """Point the module at a throwaway table file + clear its cache."""
    path = str(tmp_path / "tuning.json")
    monkeypatch.setenv(autotune.DEFAULT_TABLE_ENV, path)
    autotune._cache.clear()
    yield path
    autotune._cache.clear()


def test_rows_bucket_size_classes():
    assert rows_bucket(0) == 0
    assert rows_bucket(1) == 1
    assert rows_bucket(65535) == rows_bucket(65536) == 65536
    assert rows_bucket(65537) == 131072


def test_persist_round_trip(tmp_table):
    t = TuningTable()
    t.put("exchange", 0, "row", "skew", 1.25)
    t.put("partition_scatter", 60000, "uint32", "tile_n", 2048)
    t.save(tmp_table)
    back = TuningTable.load(tmp_table)
    assert back.entries == t.entries
    # same size class, different row count: one entry covers both
    assert back.get("partition_scatter", 65536, "uint32", "tile_n") == 2048
    assert back.get("partition_scatter", 70000, "uint32", "tile_n") is None


def test_load_missing_or_corrupt_is_empty(tmp_path):
    assert TuningTable.load(str(tmp_path / "nope.json")).entries == {}
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert TuningTable.load(str(bad)).entries == {}
    # valid JSON, wrong shape: non-dict root and non-dict values dropped
    lst = tmp_path / "list.json"
    lst.write_text("[1, 2]")
    assert TuningTable.load(str(lst)).entries == {}
    mixed = tmp_path / "mixed.json"
    mixed.write_text(json.dumps({"a|0|row": {"skew": 2.0}, "b|0|row": 7}))
    assert TuningTable.load(str(mixed)).entries == {"a|0|row": {"skew": 2.0}}


def test_tune_deterministic_and_tie_break():
    calls = []

    def measure(c):
        calls.append(c)
        return {256: 3.0, 512: 1.0, 1024: 1.0}[c]

    t = TuningTable()
    best = autotune.tune("op", 100, "uint32", "tile_n", [256, 512, 1024],
                         measure, table=t, reps=3)
    assert best == 512, "ties break toward the earlier candidate"
    assert t.get("op", 100, "uint32", "tile_n") == 512
    assert len(calls) == 9, "reps measurements per candidate"


def test_tune_price_prunes_before_measuring():
    measured = []

    def measure(c):
        measured.append(c)
        return float(c)

    best = autotune.tune("op", 0, "d", "p", [4, 3, 2, 1], measure,
                         price=lambda c: float(c), top_k=2, reps=1)
    assert best == 1
    assert sorted(measured) == [1, 2], "only the top_k cheapest are timed"


def test_tune_empty_candidates_raises():
    with pytest.raises(ValueError):
        autotune.tune("op", 0, "d", "p", [], lambda c: 0.0)


def test_choose_inert_unless_enabled(tmp_table, monkeypatch):
    t = TuningTable()
    t.put("exchange", 0, "row", "skew", 1.25)
    t.save(tmp_table)
    monkeypatch.delenv(autotune.ENABLE_ENV, raising=False)
    assert autotune.choose("exchange", 0, "row", "skew", 4.0) == 4.0
    monkeypatch.setenv(autotune.ENABLE_ENV, "0")
    assert autotune.choose("exchange", 0, "row", "skew", 4.0) == 4.0
    monkeypatch.setenv(autotune.ENABLE_ENV, "1")
    assert autotune.choose("exchange", 0, "row", "skew", 4.0) == 1.25


def test_choose_missing_entry_falls_back(tmp_table, monkeypatch):
    monkeypatch.setenv(autotune.ENABLE_ENV, "1")
    # no table file at all: defaults survive
    assert autotune.choose("exchange", 0, "row", "skew", 4.0) == 4.0
    assert autotune.choose("join_probe", 4096, "uint32", "slack", 4) == 4


def test_choose_coerces_to_default_type(tmp_table, monkeypatch):
    monkeypatch.setenv(autotune.ENABLE_ENV, "1")
    t = TuningTable()
    t.put("partition_scatter", 100, "uint32", "tile_n", 512.9)
    t.put("exchange", 0, "row", "skew", "junk")
    t.save(tmp_table)
    autotune.get_table(refresh=True)
    v = autotune.choose("partition_scatter", 100, "uint32", "tile_n", 256)
    assert v == 512 and isinstance(v, int)
    # uncoercible value: the default survives a hand-edited table
    assert autotune.choose("exchange", 0, "row", "skew", 4.0) == 4.0


def test_scatter_tile_price_monotone_dispatch_tradeoff():
    """The roofline price must penalise tiny tiles (dispatch-bound) and
    keep the working-set term finite — a sanity pin for the consumer in
    roofline/analysis.py, not a performance claim."""
    price = autotune.scatter_tile_price(1 << 16, 8)
    costs = {t: price(t) for t in (64, 256, 1024, 4096)}
    assert all(c > 0 for c in costs.values())
    assert costs[64] > costs[4096], "dispatch overhead dominates tiny tiles"
