"""Physical operators vs pure-numpy oracles, including hypothesis
property tests over random tables."""
import collections

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import plan as P
from repro.dataflow.expr import Col
from repro.dataflow.physical import execute_plan
from repro.dataflow.table import Table, decode_strings, encode_strings


def make_table(n, n_keys, seed=0, capacity=None):
    rng = np.random.default_rng(seed)
    keys = [f"k{i}" for i in range(n_keys)]
    return Table.from_numpy({
        "key": encode_strings([keys[i] for i in
                               rng.integers(0, n_keys, n)]),
        "ikey": rng.integers(0, n_keys, n).astype(np.int32),
        "val": rng.uniform(-5, 5, n).astype(np.float32),
        "cnt": rng.integers(0, 10, n).astype(np.int32),
    }, capacity=capacity or n)


def test_filter_matches_numpy():
    t = make_table(500, 7)
    plan = P.PhysicalPlan([P.store(
        P.filter_(P.load("t"), Col("val") > 0.0), "out")])
    out, _ = execute_plan(plan, {"t": t})
    got = out["out"].to_numpy()
    ref = t.to_numpy()
    assert len(got["val"]) == int((ref["val"] > 0).sum())
    assert (got["val"] > 0).all()


def test_groupby_sum_matches_numpy():
    t = make_table(512, 9)
    plan = P.PhysicalPlan([P.store(P.groupby(
        P.load("t"), ["key"], {"s": ("sum", "val"),
                               "c": ("count", "val"),
                               "mx": ("max", "val"),
                               "mn": ("min", "val")}), "out")])
    out, _ = execute_plan(plan, {"t": t})
    got = out["out"].to_numpy()
    ref = t.to_numpy()
    oracle = collections.defaultdict(list)
    for k, v in zip(decode_strings(ref["key"]), ref["val"]):
        oracle[k].append(v)
    gk = decode_strings(got["key"])
    assert sorted(gk) == sorted(oracle)
    for k, s, c, mx, mn in zip(gk, got["s"], got["c"], got["mx"],
                               got["mn"]):
        assert abs(s - sum(oracle[k])) < 1e-2
        assert c == len(oracle[k])
        assert abs(mx - max(oracle[k])) < 1e-5
        assert abs(mn - min(oracle[k])) < 1e-5


def test_join_matches_numpy():
    left = make_table(300, 11, seed=1)
    rng = np.random.default_rng(2)
    rkeys = [f"k{i}" for i in range(8)]        # subset of left keys
    right = Table.from_numpy({
        "key": encode_strings(rkeys),
        "payload": rng.integers(0, 100, len(rkeys)).astype(np.int32)})
    plan = P.PhysicalPlan([P.store(P.join(
        P.load("l"), P.load("r"), ["key"], ["key"]), "out")])
    out, _ = execute_plan(plan, {"l": left, "r": right})
    got = out["out"].to_numpy()
    lref = left.to_numpy()
    lk = decode_strings(lref["key"])
    expected = sum(1 for k in lk if k in rkeys)
    assert len(got["val"]) == expected
    payload_of = dict(zip(rkeys, right.to_numpy()["payload"]))
    for k, p in zip(decode_strings(got["key"]), got["payload"]):
        assert payload_of[k] == p


def test_distinct_union():
    t = make_table(200, 5)
    pr = P.project(P.load("t"), ["key"])
    plan = P.PhysicalPlan([P.store(P.distinct(
        P.union(pr, P.project(P.load("t2"), ["key"]))), "out")])
    out, _ = execute_plan(plan, {"t": t, "t2": make_table(100, 8, seed=9)})
    got = decode_strings(out["out"].to_numpy()["key"])
    ref = set(decode_strings(t.to_numpy()["key"])) | \
        set(decode_strings(make_table(100, 8, seed=9).to_numpy()["key"]))
    assert sorted(got) == sorted(ref)


def test_cogroup_counts():
    a = make_table(256, 6, seed=3)
    b = make_table(128, 6, seed=4)
    plan = P.PhysicalPlan([P.store(P.cogroup(
        P.load("a"), P.load("b"), ["key"], ["key"],
        {"na": ("count", "val")}, {"nb": ("count", "val")}), "out")])
    out, _ = execute_plan(plan, {"a": a, "b": b})
    got = out["out"].to_numpy()
    ca = collections.Counter(decode_strings(a.to_numpy()["key"]))
    cb = collections.Counter(decode_strings(b.to_numpy()["key"]))
    for k, na, nb in zip(decode_strings(got["key"]), got["l_na"],
                         got["r_nb"]):
        assert ca.get(k, 0) == na and cb.get(k, 0) == nb


# ---------------------------------------------------------------------------
# hypothesis properties


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 200), n_keys=st.integers(1, 20),
       seed=st.integers(0, 1000))
def test_property_groupby_total_is_preserved(n, n_keys, seed):
    """Sum over groups == sum over rows (mass conservation)."""
    t = make_table(n, n_keys, seed=seed)
    plan = P.PhysicalPlan([P.store(P.groupby(
        P.load("t"), ["ikey"], {"s": ("sum", "val")}), "out")])
    out, _ = execute_plan(plan, {"t": t})
    got = out["out"].to_numpy()
    ref = t.to_numpy()
    assert abs(got["s"].sum() - ref["val"].sum()) < 1e-2
    assert len(got["s"]) == len(np.unique(ref["ikey"]))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 200), frac=st.floats(0.0, 1.0),
       seed=st.integers(0, 1000))
def test_property_filter_partition(n, frac, seed):
    """|filter(p)| + |filter(!p)| == |t| and both subsets verify p."""
    t = make_table(n, 5, seed=seed)
    thresh = float(np.quantile(t.to_numpy()["val"], frac))
    pos = P.PhysicalPlan([P.store(
        P.filter_(P.load("t"), Col("val") > thresh), "out")])
    neg = P.PhysicalPlan([P.store(
        P.filter_(P.load("t"), Col("val") <= thresh), "out")])
    got_p, _ = execute_plan(pos, {"t": t})
    got_n, _ = execute_plan(neg, {"t": t})
    np_, nn = len(got_p["out"].to_numpy()["val"]), \
        len(got_n["out"].to_numpy()["val"])
    assert np_ + nn == len(t.to_numpy()["val"])


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 150), seed=st.integers(0, 1000))
def test_property_distinct_idempotent(n, seed):
    t = make_table(n, 6, seed=seed)
    d1 = P.PhysicalPlan([P.store(P.distinct(
        P.project(P.load("t"), ["key"])), "out")])
    out1, _ = execute_plan(d1, {"t": t})
    d2 = P.PhysicalPlan([P.store(P.distinct(P.load("u")), "out")])
    out2, _ = execute_plan(d2, {"u": out1["out"]})
    a = sorted(decode_strings(out1["out"].to_numpy()["key"]))
    b = sorted(decode_strings(out2["out"].to_numpy()["key"]))
    assert a == b


def test_engine_with_pallas_kernels_matches_pure_jax():
    """GROUPBY + JOIN produce identical results with the Pallas kernel
    hot paths enabled (interpret mode on CPU)."""
    from repro.dataflow import physical as PH
    t = make_table(256, 9, seed=11)
    rng = np.random.default_rng(12)
    right = Table.from_numpy({
        "key": encode_strings([f"k{i}" for i in range(6)]),
        "payload": rng.integers(0, 100, 6).astype(np.int32)})
    gplan = P.PhysicalPlan([P.store(P.groupby(
        P.load("t"), ["key"], {"s": ("sum", "val"),
                               "m": ("mean", "val")}), "out")])
    jplan = P.PhysicalPlan([P.store(P.join(
        P.load("t"), P.load("r"), ["key"], ["key"]), "out")])
    ref_g, _ = execute_plan(gplan, {"t": t})
    ref_j, _ = execute_plan(jplan, {"t": t, "r": right})
    PH.set_use_pallas(True)
    try:
        got_g, _ = execute_plan(gplan, {"t": t})
        got_j, _ = execute_plan(jplan, {"t": t, "r": right})
    finally:
        PH.set_use_pallas(False)
    for ref, got in ((ref_g, got_g), (ref_j, got_j)):
        r, g = ref["out"].to_numpy(), got["out"].to_numpy()
        assert sorted(r) == sorted(g)
        for c in r:
            rv = np.sort(r[c].astype(np.float64), axis=0)
            gv = np.sort(g[c].astype(np.float64), axis=0)
            assert np.allclose(rv, gv, atol=1e-3), c
