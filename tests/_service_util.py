"""Shared helpers for the service / fault / crash-recovery suites.

Canonical comparison: reused artifacts are compacted to power-of-two
capacities while cold results keep the original capacity, so raw array
equality over padded tables is meaningless.  ``identical`` compares the
*valid* rows after a lexicographic sort — bit-identity of the answer,
not of the padding.
"""
import numpy as np

from repro.core.repository import Repository
from repro.core.restore import ReStore
from repro.store.artifacts import ArtifactStore, Catalog
from repro.workloads import pigmix


def sortable(a):
    """1-D lexsort key: fixed-width byte-string columns (2-D uint8)
    collapse to bytes scalars."""
    if a.ndim == 2:
        return np.ascontiguousarray(a).view(f"S{a.shape[1]}").ravel()
    return a


def canon(table):
    d = table.to_numpy()
    order = np.lexsort(tuple(sortable(d[c])
                             for c in sorted(d, reverse=True)))
    return {c: d[c][order] for c in sorted(d)}


def identical(a, b):
    ca, cb = canon(a), canon(b)
    if sorted(ca) != sorted(cb):
        return False
    return all(np.array_equal(ca[c], cb[c]) for c in ca)


def results_identical(ra, rb):
    if sorted(ra) != sorted(rb):
        return False
    return all(identical(ra[k], rb[k]) for k in ra)


def fresh_driver(root=None, n_rows=512, seed=0, injector=None,
                 repository=None, **kw):
    """ReStore driver over a fresh store (+ optional disk root and
    fault injector), with pigmix registered at ``n_rows``."""
    store = ArtifactStore(root=None if root is None else str(root),
                          fault_injector=injector,
                          **{k: v for k, v in kw.items()
                             if k in ("cache_bytes", "write_behind",
                                      "tmp_gc_age_s")})
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=n_rows, seed=seed)
    repo = repository if repository is not None else Repository()
    drv_kw = {k: v for k, v in kw.items()
              if k not in ("cache_bytes", "write_behind", "tmp_gc_age_s")}
    return ReStore(cat, store, repo, **drv_kw)


def query_mix():
    """The suites' standard workload: reuse-heavy (L3 variants share the
    join sub-job) plus an independent join."""
    return [("L3_sum", lambda: pigmix.L3("sum")),
            ("L2", pigmix.L2),
            ("L3_mean", lambda: pigmix.L3("mean"))]


def run_mix(driver):
    """Run the standard mix, returning {label/sink: Table}."""
    out = {}
    for label, qfn in query_mix():
        results, _ = driver.run_plan(qfn())
        for sink, table in results.items():
            out[f"{label}:{sink}"] = table
    return out
