"""Unified serving session (DESIGN.md §17): prefix-KV reuse through the
ReStore repository — bit-identical decodes across reuse and tiers, the
submission semantics (singleflight, tenants, deadlines, backpressure),
deterministic accounting, and the deprecated aliases."""
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.api import build
from repro.serve.kv_repo import KVRepository, LogicalClock
from repro.serve.kv_store import KVTierStore
from repro.serve.session import (ServeSession, SessionSaturated,
                                 ServeStats)
from repro.service.faults import FaultInjector, FaultSchedule


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-1.7b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _kv(tmp_path=None, budget=1 << 34, injector=None):
    store = KVTierStore(
        remote_root=str(tmp_path / "kv-remote") if tmp_path else None,
        injector=injector)
    return KVRepository(budget_bytes=budget, store=store)


# ---------------------------------------------------------------------------
# Bit-identity: cold vs prefix-warm vs tier-round-tripped


def test_warm_and_tier_roundtrip_bit_identical(setup, tmp_path):
    cfg, model, params = setup
    cold = ServeSession(model, params, max_len=64)
    kv = _kv(tmp_path)
    warm = ServeSession(model, params, max_len=64, kv=kv)
    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab_size, 24)
    p1 = np.concatenate([shared, rng.integers(1, cfg.vocab_size, 8)])
    p2 = np.concatenate([shared, rng.integers(1, cfg.vocab_size, 8)])

    ref1, _ = cold.serve(p1, 6)
    ref2, _ = cold.serve(p2, 6)
    a1, s1 = warm.serve(p1, 6)           # cold store
    a2, s2 = warm.serve(p2, 6)           # alias hit on the shared 24
    assert (a1 == ref1).all() and (a2 == ref2).all()
    assert s1.reused_tokens == 0 and s2.reused_tokens >= 24

    # demote every snapshot device -> remote blob, then serve again:
    # the splice promotes back through the tiers, decode unchanged
    names = {e.artifact for e in kv.repository.entries}
    for n in names:
        assert kv.store.demote_to_remote(n)
        assert kv.store.residency(n) == "remote"
    a2r, s2r = warm.serve(p2, 6)
    assert (a2r == ref2).all()
    assert s2r.reused_tokens >= 24
    assert kv.store.stats["remote_hits"] >= 1


def test_exact_hit_uses_stored_logits(setup):
    cfg, model, params = setup
    kv = _kv()
    sess = ServeSession(model, params, max_len=48, kv=kv)
    cold = ServeSession(model, params, max_len=48)
    rng = np.random.default_rng(3)
    p = rng.integers(1, cfg.vocab_size, 16)
    ref, _ = cold.serve(p, 4)
    a1, _ = sess.serve(p, 4)
    a2, s2 = sess.serve(p, 4)            # exact full-prompt hit
    assert (a1 == ref).all() and (a2 == ref).all()
    assert s2.reused_tokens == 16 and s2.prefilled_tokens == 0
    assert kv.stats()["exact_hits"] >= 1


def test_recurrent_arch_exact_length_only():
    """SSM/recurrent caches cannot be truncated: no every_k aliases are
    registered, and the exact hit replays stored logits rather than
    re-advancing the state."""
    cfg = get_config("xlstm-350m", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kv = _kv()
    sess = ServeSession(model, params, max_len=48, kv=kv)
    rng = np.random.default_rng(1)
    p = rng.integers(1, cfg.vocab_size, 16)
    sess.serve(p, 4)
    # one entry: the full 16-token state, no intermediate aliases
    assert len(kv) == 1
    (e,) = kv.entries.values()
    assert e.plan.n_ops() == 16
    _, s2 = sess.serve(p, 4)
    assert s2.reused_tokens == 16 and s2.prefilled_tokens == 0


# ---------------------------------------------------------------------------
# R4 + fault injection


def test_version_invalidation_r4(setup):
    cfg, model, params = setup
    kv = _kv()
    sess = ServeSession(model, params, max_len=48, kv=kv)
    rng = np.random.default_rng(2)
    p = rng.integers(1, cfg.vocab_size, 16)
    sess.serve(p, 2)
    assert len(kv) >= 1
    n = kv.invalidate_version("v2")
    assert n >= 1 and len(kv) == 0
    assert len(kv.store) == 0            # artifacts deleted, not leaked
    assert kv.probe(p) is None           # new version: nothing matches


def test_corrupt_remote_blob_quarantined_then_cold_prefill(
        setup, tmp_path):
    """A bit-flipped remote KV blob fails the RSB1 checksum on splice:
    the snapshot is quarantined, its entries un-advertised, and the
    request falls back to a cold prefill — same output, no crash."""
    cfg, model, params = setup
    inj = FaultInjector(FaultSchedule(0, rates={"flip": 1.0},
                                      max_faults=1))
    kv = _kv(tmp_path, injector=inj)
    sess = ServeSession(model, params, max_len=48, kv=kv)
    cold = ServeSession(model, params, max_len=48)
    rng = np.random.default_rng(4)
    p = rng.integers(1, cfg.vocab_size, 16)
    ref, _ = cold.serve(p, 4)
    sess.serve(p, 4)
    for e in list(kv.entries.values()):
        kv.store.demote_to_remote(e.artifact)   # flip fires on publish
    assert inj.total_injected() == 1
    a, s = sess.serve(p, 4)
    assert (a == ref).all()
    assert s.reused_tokens == 0          # quarantined -> cold prefill
    assert kv.store.stats["quarantined"] >= 1


# ---------------------------------------------------------------------------
# Submission semantics


def test_singleflight_identical_inflight_prompts(setup):
    cfg, model, params = setup
    sess = ServeSession(model, params, n_slots=2, max_len=48)
    rng = np.random.default_rng(5)
    p = rng.integers(1, cfg.vocab_size, 9)
    t1 = sess.submit(p, 4)
    t2 = sess.submit(p, 4)               # identical in-flight: follower
    t3 = sess.submit(p, 5)               # different max_new: own decode
    sess.run()
    assert sess.stats["singleflight_hits"] == 1
    assert sess.stats["dup_executions"] == 0
    assert t1.done() and t2.done() and t3.done()
    assert (t1.result() == t2.result()).all()
    assert len(t3.result()) == 5


def test_tenant_round_robin_admission(setup):
    cfg, model, params = setup
    sess = ServeSession(model, params, n_slots=1, max_len=48)
    rng = np.random.default_rng(6)
    pa1 = rng.integers(1, cfg.vocab_size, 7)
    pa2 = rng.integers(1, cfg.vocab_size, 7)
    pb1 = rng.integers(1, cfg.vocab_size, 7)
    ta1 = sess.submit(pa1, 1, tenant="a")
    ta2 = sess.submit(pa2, 1, tenant="a")
    tb1 = sess.submit(pb1, 1, tenant="b")
    sess.step()                          # admits + finishes a1
    assert ta1.done() and not ta2.done() and not tb1.done()
    sess.step()                          # round-robin: b1 before a2
    assert tb1.done() and not ta2.done()
    sess.step()
    assert ta2.done()


def test_deadline_expiry_and_backpressure(setup):
    cfg, model, params = setup
    sess = ServeSession(model, params, n_slots=1, max_len=48,
                        max_queue=2)
    rng = np.random.default_rng(7)
    long = sess.submit(rng.integers(1, cfg.vocab_size, 8), 6)
    late = sess.submit(rng.integers(1, cfg.vocab_size, 8), 2,
                       deadline_steps=1)
    with pytest.raises(SessionSaturated):
        sess.submit(rng.integers(1, cfg.vocab_size, 8), 2)
    sess.run()
    assert long.done() and len(long.result()) == 6
    assert late.done()
    with pytest.raises(RuntimeError, match="deadline"):
        late.result()
    assert sess.stats["expired"] == 1


# ---------------------------------------------------------------------------
# Deterministic accounting + alias budget charging (regression tests for
# the pre-§17 PrefixRepository bugs)


def _fake_cache(kib):
    return {"k": jnp.zeros((kib << 8,), jnp.float32)}   # kib KiB


def test_eviction_order_is_wall_clock_free():
    """Recency flows through the injectable logical clock: two
    repositories replaying the same operations pick the same R3
    victims, however much wall time the replay took (the old
    PrefixRepository stamped time.time() inside match)."""
    survivors = []
    for _ in range(2):
        kv = KVRepository(budget_bytes=1 << 22)
        a = np.arange(10)
        b = np.arange(12)
        kv.store_prefix(a, _fake_cache(4))      # created_at = 1
        hit = kv.probe(a)
        kv.record_use(hit)                      # a.last_used = 2
        kv.store_prefix(b, _fake_cache(4))      # created_at = 3
        kv.evict_unused(window_s=1)             # now = 4: drops a only
        survivors.append(sorted(kv.entries.keys()))
    assert survivors[0] == survivors[1]
    assert len(survivors[0]) == 1


def test_alias_entries_never_budget_charged_and_die_with_parent():
    """every_k alias entries share the parent's arrays: they charge
    zero bytes to the budget, and evicting the parent snapshot drops
    them too (the old class left aliases advertising deleted arrays)."""
    kv = KVRepository(budget_bytes=5 << 20)
    a = np.arange(24)
    parent = kv.store_prefix(a, _fake_cache(4096), every_k=8)  # 4 MiB
    assert parent is not None and len(kv) == 3     # parent + 8, 16
    # shared arrays charged exactly once
    assert kv.repository.total_stored_bytes() == parent.bytes_out
    assert kv.total_bytes == parent.bytes_out

    # admitting a second 4 MiB snapshot under a 5 MiB budget must evict
    # the parent — and every alias with it, atomically
    b = np.arange(100, 124)
    kept = kv.store_prefix(b, _fake_cache(4096))
    assert kept is not None
    assert all(e.artifact == kept.artifact
               for e in kv.entries.values())
    assert not kv.store.exists(parent.artifact)
    assert kv.probe(a) is None                     # no dangling aliases


def test_append_extension_rides_refresh_path():
    """Multi-turn growth: extending a stored prefix re-keys the entry
    in place (§12 reindex) instead of storing a second snapshot."""
    kv = KVRepository(budget_bytes=1 << 22)
    a = np.arange(8)
    e = kv.store_prefix(a, _fake_cache(4))
    old_art = e.artifact
    grown = np.concatenate([a, np.arange(50, 54)])
    hit = kv.probe(grown)
    assert hit is not None and hit.length == 8 and not hit.exact
    e2 = kv.extend(hit, grown, _fake_cache(6))
    assert e2 is e                       # same entry object, re-keyed
    assert len(kv) == 1
    assert kv.repository.refreshes == 1
    assert not kv.store.exists(old_art)  # superseded snapshot freed
    hit2 = kv.probe(grown)
    assert hit2 is not None and hit2.exact
    with pytest.raises(ValueError):
        kv.extend(hit2, np.arange(100, 104), _fake_cache(4))


def test_pinned_snapshot_never_evicted():
    kv = KVRepository(budget_bytes=5 << 20)
    a = kv.store_prefix(np.arange(10), _fake_cache(4096))
    kv.pin(a)
    b = kv.store_prefix(np.arange(20, 30), _fake_cache(4096))
    assert b is None                     # rejected: incumbent is pinned
    assert kv.probe(np.arange(10)) is not None
    kv.unpin(a)
    assert kv.store_prefix(np.arange(20, 30),
                           _fake_cache(4096)) is not None


# ---------------------------------------------------------------------------
# Serialization + aliases


def test_prefix_entry_serialize_roundtrip():
    from repro.core.serialize import entry_from_json, entry_to_json
    kv = KVRepository(budget_bytes=1 << 22)
    e = kv.store_prefix(np.arange(12), _fake_cache(4))
    kv.record_use(kv.probe(np.arange(12)))
    d = entry_to_json(e)
    assert d["kind"] == "prefix"
    back = entry_from_json(d)
    assert back is not None and back.kind == "prefix"
    assert back.signature == e.signature
    assert list(back.plan.tokens) == list(range(12))
    assert back.use_count == e.use_count
    # integrity: a corrupted token chain no longer matches its signature
    bad = dict(d)
    bad["plan"] = {"prefix": {"tokens": [9] * 12, "model_version": "v0"}}
    assert entry_from_json(bad) is None


def test_deprecated_aliases_delegate(setup):
    """Old entry points warn once and produce the new path's results."""
    cfg, model, params = setup
    rng = np.random.default_rng(8)
    p = rng.integers(1, cfg.vocab_size, 9)
    new = ServeSession(model, params, max_len=48)
    ref, _ = new.serve(p, 4)

    from repro.serve.engine import ServeEngine
    from repro.serve.batch_engine import BatchEngine
    from repro.serve.prefix_repo import PrefixRepository
    with pytest.warns(DeprecationWarning):
        eng = ServeEngine(model, params, max_len=48)
    a, st = eng.serve(p, 4)
    assert (a == ref).all() and isinstance(st, ServeStats)

    with pytest.warns(DeprecationWarning):
        be = BatchEngine(model, params, n_slots=2, max_len=48)
    r = be.submit(p, 4, rid=7)
    be.run()
    assert r.done and r.rid == 7 and (np.array(r.out) == ref).all()

    with pytest.warns(DeprecationWarning):
        repo = PrefixRepository(capacity_bytes=1 << 22)
    # old verbs are the new verbs: match == probe+splice+record_use
    repo.store(np.arange(10), _fake_cache(4))
    hit = repo.match(np.arange(10))
    assert hit is not None and hit.length == 10
    assert repo.kv.stats()["exact_hits"] == 1
    assert repo.total_bytes == repo.kv.total_bytes
