"""Incremental artifact maintenance (DESIGN.md §12).

Per-op-class merge correctness: for every refreshable root class the
refreshed artifact must be BIT-identical to a cold recompute over the
appended inputs (integer-valued data keeps float32 aggregation exact;
re-aggregation merges at most two partials per key, so it is exact for
any float data).  Plus: non-appendable staleness falls back to R4
deletion, partitioned artifacts refresh shard-locally, the cost model
arbitrates refresh/lazy/delete, lazy refreshes fire on the next probe,
and an in-place refresh invalidates every derived view of the old value
(the stale-view regression).
"""
import numpy as np
import pytest

from repro.core import plan as P
from repro.core.cost_model import CostModel
from repro.core.delta import _reagg_merge, derive_refresh
from repro.core.plan import Partitioning, rebind_load_versions
from repro.core.repository import make_entry
from repro.core.restore import ReStore
from repro.dataflow.expr import Col, Const
from repro.dataflow.physical import op_groupby
from repro.dataflow.table import Table, partition_hash
from repro.store.artifacts import ArtifactStore, Catalog

N_DIM = 8


def fact(seed: int, n: int = 96) -> Table:
    rng = np.random.default_rng(seed)
    return Table.from_numpy({
        "k": rng.integers(0, N_DIM, n).astype(np.int32),
        "v": rng.integers(0, 100, n).astype(np.int32),
        # integer-valued float column: float32 sums stay exact
        "w": rng.integers(0, 50, n).astype(np.float32),
    })


def dim(lo: int = 0, hi: int = N_DIM) -> Table:
    ks = np.arange(lo, hi, dtype=np.int32)
    return Table.from_numpy({"dk": ks, "x": (ks * 3).astype(np.int32)})


def canon(t: Table):
    d = t.to_numpy()
    order = np.lexsort(tuple(d[c] for c in sorted(d, reverse=True)))
    return {c: d[c][order] for c in sorted(d)}


def assert_identical(a: Table, b: Table, label: str = ""):
    ca, cb = canon(a), canon(b)
    assert sorted(ca) == sorted(cb), f"{label}: column sets differ"
    for c in ca:
        assert ca[c].dtype == cb[c].dtype, f"{label}:{c}"
        assert np.array_equal(ca[c], cb[c]), f"{label}:{c}"


def _restore(delta_fact=None, delta_dim=None, **kw) -> ReStore:
    store = ArtifactStore()
    cat = Catalog(store)
    cat.register("fact", fact(0))
    cat.register("dim", dim())
    # the refresh contract under test is "the refreshed artifact answers
    # the new-version query exactly" — including for streaming-only
    # (union/foreach) chains the L7 exact-splice guard would decline at
    # this toy size, so the guard is disarmed here
    kw.setdefault("min_splice_benefit_s", 0.0)
    rs = ReStore(cat, store, **kw)
    if delta_fact is not None:
        cat.append("fact", delta_fact)
    if delta_dim is not None:
        cat.append("dim", delta_dim)
    return rs


def _check_refresh(build, delta_fact=None, delta_dim=None,
                   expect_refresh=True):
    """Cold run -> append -> maintain(refresh) -> the new-version query
    must be answered without executing, bit-identical to a plain cold
    run over the appended data.  Returns the maintain report."""
    rs = _restore(heuristic="aggressive")
    rs.run_plan(build())
    if delta_fact is not None:
        rs.catalog.append("fact", delta_fact)
    if delta_dim is not None:
        rs.catalog.append("dim", delta_dim)
    rep = rs.maintain(mode="refresh")
    versions = {ds: rs.catalog.version(ds) for ds in ("fact", "dim")}
    plan2 = rebind_load_versions(build(), versions)
    got, run_rep = rs.run_plan(plan2)

    ref_rs = _restore(delta_fact, delta_dim, heuristic="off",
                      rewrite_enabled=False, semantic=False)
    ref, _ = ref_rs.run_plan(plan2)
    assert_identical(ref["out"], got["out"])
    if expect_refresh:
        assert rep["refreshed"] >= 1
        assert run_rep.n_executed == 0, \
            "refreshed repo must answer the new-version query exactly"
    return rep


# ---------------------------------------------------------------------------
# Per-op-class merge correctness (bit-identity vs cold recompute)


def test_refresh_recordwise_chain():
    def build():
        f = P.filter_(P.load("fact"), Col("v") > 20)
        pr = P.project(f, ["k", "v"])
        fe = P.foreach(pr, {"k": Col("k"), "v2": Col("v") * Const(2)})
        return P.PhysicalPlan([P.store(fe, "out")])
    _check_refresh(build, delta_fact=fact(7, 32))


def test_refresh_union():
    def build():
        a = P.project(P.load("fact"), ["k"])
        b = P.foreach(P.project(P.load("dim"), ["dk"]), {"k": Col("dk")})
        return P.PhysicalPlan([P.store(P.union(a, b), "out")])
    _check_refresh(build, delta_fact=fact(8, 24))


def test_refresh_union_both_inputs_changed():
    def build():
        a = P.project(P.load("fact"), ["k"])
        b = P.foreach(P.project(P.load("dim"), ["dk"]), {"k": Col("dk")})
        return P.PhysicalPlan([P.store(P.union(a, b), "out")])
    _check_refresh(build, delta_fact=fact(9, 16),
                   delta_dim=dim(N_DIM, N_DIM + 4))


def test_refresh_groupby_all_decomposable_aggs():
    def build():
        f = P.filter_(P.load("fact"), Col("v") > 10)
        g = P.groupby(f, ["k"], {"s": ("sum", "w"), "n": ("count", "v"),
                                 "mn": ("min", "v"), "mx": ("max", "v")})
        return P.PhysicalPlan([P.store(g, "out")])
    _check_refresh(build, delta_fact=fact(11, 48))


def test_refresh_distinct():
    def build():
        d = P.distinct(P.project(P.load("fact"), ["k", "v"]))
        return P.PhysicalPlan([P.store(d, "out")])
    _check_refresh(build, delta_fact=fact(12, 40))


def test_refresh_join_left_side_changed():
    def build():
        j = P.join(P.project(P.load("fact"), ["k", "v"]),
                   P.load("dim"), ["k"], ["dk"])
        return P.PhysicalPlan([P.store(j, "out")])
    _check_refresh(build, delta_fact=fact(13, 32))


def test_refresh_join_both_sides_changed():
    # appended dim keys are globally unique, so the bounded probe
    # window never saturates and the three-way delta join is exact
    def build():
        j = P.join(P.project(P.load("fact"), ["k", "v"]),
                   P.load("dim"), ["k"], ["dk"])
        return P.PhysicalPlan([P.store(j, "out")])
    rng = np.random.default_rng(14)
    extra = Table.from_numpy({
        "k": rng.integers(0, N_DIM + 4, 24).astype(np.int32),
        "v": rng.integers(0, 100, 24).astype(np.int32),
        "w": rng.integers(0, 50, 24).astype(np.float32)})
    _check_refresh(build, delta_fact=extra,
                   delta_dim=dim(N_DIM, N_DIM + 4))


# ---------------------------------------------------------------------------
# Fallback to R4 (delete) when no delta plan is derivable


def test_rewrite_churn_falls_back_to_delete():
    def build():
        g = P.groupby(P.load("fact"), ["k"], {"s": ("sum", "v")})
        return P.PhysicalPlan([P.store(g, "out")])
    rs = _restore(heuristic="off")
    rs.run_plan(build())
    rs.catalog.register("fact", fact(55))          # arbitrary rewrite
    rep = rs.maintain(mode="refresh")
    assert rep == {"refreshed": 0, "lazy": 0, "deleted": 1}
    assert len(rs.repo) == 0


def test_nondecomposable_aggregate_falls_back_to_delete():
    def build():
        g = P.groupby(P.load("fact"), ["k"], {"m": ("mean", "v")})
        return P.PhysicalPlan([P.store(g, "out")])
    rs = _restore(heuristic="off")
    rs.run_plan(build())
    rs.catalog.append("fact", fact(3, 8))
    rep = rs.maintain(mode="refresh")
    assert rep["deleted"] == 1 and rep["refreshed"] == 0


def test_ops_above_blocking_root_fall_back_to_delete():
    def build():
        g = P.groupby(P.load("fact"), ["k"], {"s": ("sum", "v")})
        f = P.foreach(g, {"k": Col("k"), "s2": Col("s") * Const(2)})
        return P.PhysicalPlan([P.store(f, "out")])
    rs = _restore(heuristic="off")
    rs.run_plan(build())
    rs.catalog.append("fact", fact(3, 8))
    rep = rs.maintain(mode="refresh")
    # whole-job entry (FOREACH over GROUPBY) is not derivable
    assert rep["deleted"] >= 1
    entry_plans = [e.plan.sinks[0].inputs[0].kind for e in rs.repo.entries]
    assert "FOREACH" not in entry_plans


def test_boundary_artifact_inputs_fall_back_to_delete():
    # a two-job workflow: the downstream job's entry loads an art/ name
    def build():
        g = P.groupby(P.load("fact"), ["k"], {"s": ("sum", "v")})
        j = P.join(g, P.load("dim"), ["k"], ["dk"])
        return P.PhysicalPlan([P.store(j, "out")])
    rs = _restore(heuristic="off")
    rs.run_plan(build())
    art_loaders = [e for e in rs.repo.entries
                   if any(ld.params["dataset"].startswith("art/")
                          for ld in e.plan.loads())]
    assert art_loaders, "expected a downstream entry loading a boundary"
    rs.catalog.append("fact", fact(3, 8))
    rep = rs.maintain(mode="refresh")
    assert rep["deleted"] >= len(art_loaders)
    # the first-job groupby entry refreshed, though
    assert rep["refreshed"] >= 1


# ---------------------------------------------------------------------------
# Catalog append lineage


def test_catalog_lineage():
    store = ArtifactStore()
    cat = Catalog(store)
    cat.register("fact", fact(0, 10))
    assert cat.version("fact") == 0 and cat.rows_at("fact", 0) == 10
    cat.append("fact", fact(1, 4))
    assert cat.version("fact") == 1
    assert cat.rows_at("fact", 1) == 14
    assert cat.is_append_since("fact", 0)
    d = cat.delta_table("fact", 0)
    assert int(np.asarray(d.valid).sum()) == 4
    snap = cat.snapshot_table("fact", 0)
    assert_identical(snap, fact(0, 10))
    assert abs(cat.delta_fraction("fact", 0) - 0.4) < 1e-9
    # prefix stability: first 10 valid rows of v1 == v0 rows, in order
    cur = cat.get("fact").to_numpy()
    old = fact(0, 10).to_numpy()
    for c in old:
        assert np.array_equal(cur[c][:10], old[c])
    cat.register("fact", fact(2, 6))               # rewrite resets lineage
    assert cat.version("fact") == 2
    assert not cat.is_append_since("fact", 1)
    assert cat.delta_table("fact", 1) is None


# ---------------------------------------------------------------------------
# Partitioned artifacts: shard-local refresh


def _partitioned(store: ArtifactStore, name: str, t: Table, keys,
                 n_parts: int):
    store.put(name + "#tmp", t)
    tp, _ = store.get_partitioned(name + "#tmp", keys, n_parts)
    store.put(name, tp, partitioning={"keys": list(keys),
                                      "n_parts": n_parts})
    store.delete(name + "#tmp")


def _assert_block_layout(t: Table, keys, n_parts: int):
    blk = t.capacity // n_parts
    pid = np.asarray(partition_hash(t, keys)) % np.uint32(n_parts)
    mask = np.asarray(t.valid)
    assert np.array_equal(pid[mask], (np.arange(t.capacity) // blk)[mask])


def test_partitioned_append_is_shard_local_and_layout_valid():
    store = ArtifactStore()
    t, d = fact(0, 64), fact(5, 16)
    _partitioned(store, "art", t, ["k"], 4)
    store.append("art", d)
    part = store.partitioning("art")
    assert part is not None and part["n_parts"] == 4
    got = store.get("art")
    assert int(np.asarray(got.valid).sum()) == 64 + 16
    _assert_block_layout(got, ["k"], 4)
    # value identity: monolithic concat of the same rows
    s2 = ArtifactStore()
    s2.put("ref", t)
    s2.append("ref", d)
    assert_identical(got, s2.get("ref"))


def test_partitioned_reagg_merge_matches_global_merge():
    old = op_groupby(fact(0, 64), ["k"], {"s": ("sum", "w"),
                                          "n": ("count", "v")})
    partial = op_groupby(fact(5, 32), ["k"], {"s": ("sum", "w"),
                                              "n": ("count", "v")})
    merge = _reagg_merge(("k",), {"s": ("sum", "s"), "n": ("sum", "n")})
    store = ArtifactStore()
    _partitioned(store, "agg", old, ["k"], 4)
    store.merge_shards("agg", partial, merge_fn=merge)
    got = store.get("agg")
    assert store.partitioning("agg")["n_parts"] == 4
    _assert_block_layout(got, ["k"], 4)
    assert_identical(got, merge(old, partial))


def test_partitioned_refresh_e2e_preserves_property():
    def build():
        g = P.groupby(P.load("fact"), ["k"], {"s": ("sum", "w"),
                                              "n": ("count", "v")})
        return P.PhysicalPlan([P.store(g, "out")])
    rs = _restore(heuristic="off")
    rs.run_plan(build())
    (entry,) = rs.repo.entries
    # re-lay the stored artifact out partitioned on the group keys (the
    # layout a mesh producer creates naturally, DESIGN.md §11)
    tp, _ = rs.store.get_partitioned(entry.artifact, ["k"], 4)
    rs.store.put(entry.artifact, tp,
                 partitioning={"keys": ["k"], "n_parts": 4})
    entry.partitioning = rs.store.partitioning(entry.artifact)
    delta = fact(21, 48)
    rs.catalog.append("fact", delta)
    rep = rs.maintain(mode="refresh")
    assert rep["refreshed"] == 1
    part = rs.store.partitioning(entry.artifact)
    assert part is not None and part["n_parts"] == 4, \
        "shard-local refresh must preserve the partition property"
    assert entry.partitioning == part
    plan2 = rebind_load_versions(build(), {"fact": 1})
    got, run_rep = rs.run_plan(plan2)
    assert run_rep.n_executed == 0
    ref_rs = _restore(delta, heuristic="off", rewrite_enabled=False,
                      semantic=False)
    ref, _ = ref_rs.run_plan(plan2)
    assert_identical(ref["out"], got["out"])


# ---------------------------------------------------------------------------
# Stale-view regression: an in-place refresh must invalidate derived
# get_partitioned views and the device-cache entry of the old value


def test_refresh_invalidates_derived_views_and_device_cache():
    import tempfile
    store = ArtifactStore(root=tempfile.mkdtemp(prefix="delta_reg_"))
    t, d = fact(0, 64), fact(5, 16)
    _partitioned(store, "art", t, ["k"], 4)
    # derived re-partitioned view at a different P + a cached get()
    v8, _ = store.get_partitioned("art", ["k"], 8)
    assert int(np.asarray(v8.valid).sum()) == 64
    assert store.get("art") is not None
    store.append("art", d)
    got = store.get("art")                         # device cache path
    assert int(np.asarray(got.valid).sum()) == 80, \
        "device cache served a stale pre-refresh table"
    v8b, _ = store.get_partitioned("art", ["k"], 8)
    assert int(np.asarray(v8b.valid).sum()) == 80, \
        "derived re-partitioned view survived the refresh"
    _assert_block_layout(v8b, ["k"], 8)
    store.flush()
    store.close()


def test_monolithic_refresh_replaces_device_cache_and_disk():
    import tempfile
    store = ArtifactStore(root=tempfile.mkdtemp(prefix="delta_reg2_"))
    store.put("a", fact(0, 32))
    assert store.cache.get("a") is not None
    store.append("a", fact(1, 8))
    assert int(np.asarray(store.get("a").valid).sum()) == 40
    store.flush()
    # reopened store reads the refreshed bytes
    s2 = ArtifactStore(root=store.root)
    assert int(np.asarray(s2.get("a").valid).sum()) == 40
    store.close()
    s2.close()


# ---------------------------------------------------------------------------
# Cost-model arbitration + lazy refresh


def _entry_for_decision(use_count=0, producer_cost_s=10.0,
                        bytes_out=1 << 10):
    plan = P.PhysicalPlan([P.store(
        P.groupby(P.load("fact"), ["k"], {"s": ("sum", "v")}), "art/d")])
    e = make_entry(plan, "art/d", bytes_out=bytes_out,
                   exec_time_s=producer_cost_s,
                   producer_cost_s=producer_cost_s)
    e.use_count = use_count
    if use_count:
        import time
        e.last_used = time.time()
    return e


def test_refresh_decision_hot_entry_refreshes():
    cm = CostModel()
    e = _entry_for_decision(use_count=3)
    assert cm.refresh_decision(e, delta_fraction=0.05) == "refresh"


def test_refresh_decision_large_delta_deletes():
    cm = CostModel()
    e = _entry_for_decision(use_count=3)
    # refresh cost >= recompute cost: no point maintaining
    assert cm.refresh_decision(e, delta_fraction=1.5) == "delete"


def test_refresh_decision_worthless_entry_deletes():
    cm = CostModel(fixed_io_s=0.5)     # io dwarfs the 0.1s producer
    e = _entry_for_decision(use_count=0, producer_cost_s=0.1)
    assert cm.refresh_decision(e, delta_fraction=0.05) == "delete"


def test_refresh_decision_cold_entry_defers():
    cm = CostModel()
    e = _entry_for_decision(use_count=0)     # expected uses ~ prior 0.5
    assert cm.refresh_decision(e, delta_fraction=0.05) == "lazy"


def test_lazy_refresh_fires_on_probe():
    def build():
        g = P.groupby(P.load("fact"), ["k"], {"s": ("sum", "v")})
        return P.PhysicalPlan([P.store(g, "out")])
    rs = _restore(heuristic="off")
    rs.run_plan(build())
    delta = fact(3, 16)
    rs.catalog.append("fact", delta)
    rep = rs.maintain(mode="lazy")
    assert rep["lazy"] == 1 and len(rs.repo.pending_refresh) == 1
    plan2 = rebind_load_versions(build(), {"fact": 1})
    got, run_rep = rs.run_plan(plan2)
    assert rs.repo.refreshes == 1 and not rs.repo.pending_refresh
    assert run_rep.n_executed == 0
    ref_rs = _restore(delta, heuristic="off", rewrite_enabled=False,
                      semantic=False)
    ref, _ = ref_rs.run_plan(plan2)
    assert_identical(ref["out"], got["out"])


def test_lazy_refresh_rederives_after_second_append():
    def build():
        g = P.groupby(P.load("fact"), ["k"], {"s": ("sum", "v")})
        return P.PhysicalPlan([P.store(g, "out")])
    rs = _restore(heuristic="off")
    rs.run_plan(build())
    rs.catalog.append("fact", fact(3, 16))
    rs.maintain(mode="lazy")
    rs.catalog.append("fact", fact(4, 8))          # moved again
    plan2 = rebind_load_versions(build(), {"fact": 2})
    got, run_rep = rs.run_plan(plan2)
    assert rs.repo.refreshes == 1 and run_rep.n_executed == 0
    ref_rs = _restore(heuristic="off", rewrite_enabled=False,
                      semantic=False)
    ref_rs.catalog.append("fact", fact(3, 16))
    ref_rs.catalog.append("fact", fact(4, 8))
    ref, _ = ref_rs.run_plan(plan2)
    assert_identical(ref["out"], got["out"])


def test_maintain_auto_uses_cost_model():
    def build():
        g = P.groupby(P.load("fact"), ["k"], {"s": ("sum", "v")})
        return P.PhysicalPlan([P.store(g, "out")])
    rs = _restore(heuristic="off")
    rs.run_plan(build())
    rs.run_plan(build())                  # whole-job fast path: a reuse
    (entry,) = rs.repo.entries
    assert entry.use_count >= 1
    entry.producer_cost_s = 10.0          # make reuse clearly valuable
    rs.catalog.append("fact", fact(3, 8))
    rep = rs.maintain(mode="auto")
    assert rep["refreshed"] == 1          # hot + cheap delta => eager


def test_stream_append_churn_smoke():
    from repro.workloads.stream import StreamConfig, run_stream
    cfg = StreamConfig(n_events=8, n_tenants=2, n_rows=1 << 8,
                       append_every=3, append_frac=0.25,
                       maintain="refresh", seed=0)
    res = run_stream("keep", cfg)
    assert len(res.events) == 8
    assert res.refreshes >= 1, "append churn must drive refreshes"


def test_refresh_skipped_when_new_version_already_recomputed():
    """If a probe recomputed (and registered) the new-version value
    before maintain() ran, refreshing the stale entry would index two
    entries under one signature — the stale entry must R4-drop."""
    def build():
        g = P.groupby(P.load("fact"), ["k"], {"s": ("sum", "v")})
        return P.PhysicalPlan([P.store(g, "out")])
    rs = _restore(heuristic="off")
    rs.run_plan(build())
    rs.catalog.append("fact", fact(3, 16))
    # the new-version plan runs BEFORE maintenance: recompute + register
    plan2 = rebind_load_versions(build(), {"fact": 1})
    rs.run_plan(plan2)
    assert len(rs.repo) == 2            # stale v0 entry + fresh v1 entry
    rep = rs.maintain(mode="refresh")
    assert rep == {"refreshed": 0, "lazy": 0, "deleted": 1}
    assert len(rs.repo) == 1
    (entry,) = rs.repo.entries
    assert entry.source_versions["fact"] == 1
    assert rs.repo.by_sig[entry.signature] is entry

    # same guard on the lazy path: park a refresh, then register a
    # fresh entry at the refreshed signature before the probe fires
    rs2 = _restore(heuristic="off")
    rs2.run_plan(build())
    rs2.catalog.append("fact", fact(3, 16))
    assert rs2.maintain(mode="lazy")["lazy"] == 1
    (spec,) = rs2.repo.pending_refresh.values()
    dup = make_entry(rebind_load_versions(build(), {"fact": 1}),
                     "art/dup", bytes_out=64)
    assert dup.signature == spec.refreshed_signature
    assert rs2.repo.add(dup)
    n = rs2.repo.refresh_pending(plan2, rs2.engine, rs2.catalog,
                                 rs2.store)
    assert n == 0 and not rs2.repo.pending_refresh
    assert [e.signature for e in rs2.repo.entries] == [dup.signature]


def test_derive_refresh_none_when_not_stale():
    def build():
        g = P.groupby(P.load("fact"), ["k"], {"s": ("sum", "v")})
        return P.PhysicalPlan([P.store(g, "out")])
    rs = _restore(heuristic="off")
    rs.run_plan(build())
    (entry,) = rs.repo.entries
    assert derive_refresh(entry, rs.catalog) is None


def test_partitioning_dataclass_roundtrip_unrelated_guard():
    # merge_shards rejects non-partitioned artifacts loudly
    store = ArtifactStore()
    store.put("mono", fact(0, 16))
    with pytest.raises(ValueError):
        store.merge_shards("mono", fact(1, 4))
    assert Partitioning.from_dict(None) is None
