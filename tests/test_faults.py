"""Seeded fault-injection sweep + targeted degradation paths
(DESIGN.md §13).

The sweep is the headline: for every seeded schedule the driver must
return bit-identical answers (canonical compare — padding/capacity may
differ between reused and cold results) with no permanent query
failure, while the injector tears writes, flips bytes, garbles
manifests, throws transient IO errors and adds latency.  Reuse is an
optimization, never a correctness dependency.

``RESTORE_FAULT_SCHEDULES`` scales the sweep (default 40 here; the CI
``faults`` job shards seed offsets so the matrix covers >= 200).
"""
import os
import tempfile

import pytest

from _service_util import fresh_driver, results_identical, run_mix
from repro.core.repository import Repository
from repro.core.restore import ReStore
from repro.service.faults import FaultInjector, FaultSchedule
from repro.store.artifacts import ArtifactStore, Catalog
from repro.workloads import pigmix

N_ROWS = 512
SWEEP_RATES = {"transient": 0.15, "latency": 0.05,
               "truncate": 0.10, "flip": 0.10, "manifest": 0.05}


def _n_schedules(default=40):
    return int(os.environ.get("RESTORE_FAULT_SCHEDULES", default))


def _seed_base():
    return int(os.environ.get("RESTORE_FAULT_SEED_BASE", 0))


# ------------------------------------------------------------------ sweep


def test_fault_sweep_bit_identical_and_no_permanent_failure():
    baseline = run_mix(fresh_driver(n_rows=N_ROWS))
    n = _n_schedules()
    base = _seed_base()
    total_injected = 0
    total_quarantined = 0
    bad = []
    for seed in range(base, base + n):
        inj = FaultInjector(FaultSchedule(seed, rates=SWEEP_RATES,
                                          max_faults=6),
                            latency_s=0.001)
        with tempfile.TemporaryDirectory() as root:
            drv = fresh_driver(root=root, n_rows=N_ROWS, injector=inj)
            try:
                got = run_mix(drv)          # must never raise
                drv.store.flush()
            except BaseException as e:      # noqa: BLE001 - report seed
                bad.append((seed, repr(e)))
                continue
            if not results_identical(baseline, got):
                bad.append((seed, "result mismatch"))
            total_quarantined += drv.store.stats["quarantined"]
        total_injected += inj.total_injected()
    assert not bad, f"failing seeds: {bad[:5]} ({len(bad)}/{n})"
    # a sweep that never fired a fault proves nothing
    assert total_injected > 0, "no faults injected across the sweep"


def test_schedule_is_deterministic():
    a = FaultSchedule(7, rates=SWEEP_RATES, max_faults=100)
    b = FaultSchedule(7, rates=SWEEP_RATES, max_faults=100)
    draws_a = [a.draw("read") for _ in range(200)]
    draws_b = [b.draw("read") for _ in range(200)]
    assert draws_a == draws_b
    assert any(k is not None for k in draws_a)


def test_injector_respects_fault_budget():
    inj = FaultInjector(FaultSchedule(3, rates={"transient": 1.0},
                                      max_faults=2))
    fired = 0
    for _ in range(10):
        try:
            inj.on("read", "x")
        except OSError:
            fired += 1
    assert fired == 2 and inj.total_injected() == 2


# ------------------------------------------------- targeted degradation


def _corrupt_every_artifact(root):
    """Flip one byte in every published .npz under ``root``."""
    n = 0
    for d in os.listdir(root):
        path = os.path.join(root, d)
        if not os.path.isdir(path) or d.startswith((".", "_")):
            continue
        for fn in os.listdir(path):
            if fn.endswith(".npz"):
                fp = os.path.join(path, fn)
                with open(fp, "r+b") as f:
                    b = f.read(1)
                    f.seek(0)
                    f.write(bytes([b[0] ^ 0xFF]))
                n += 1
                break
    return n


def test_corrupted_artifacts_quarantined_with_cold_fallback(tmp_path):
    baseline = run_mix(fresh_driver(n_rows=N_ROWS))
    root = str(tmp_path / "store")
    drv = fresh_driver(root=root, n_rows=N_ROWS)
    run_mix(drv)
    drv.store.flush()
    assert _corrupt_every_artifact(root) > 0
    # reopen: fresh store instance (cold caches) over the damaged root,
    # same repository -> every reuse attempt hits a checksum failure
    store2 = ArtifactStore(root=root)
    cat2 = Catalog(store2)
    pigmix.register_all(cat2, n_rows=N_ROWS, seed=0)
    drv2 = ReStore(cat2, store2, drv.repo)
    got = run_mix(drv2)
    assert results_identical(baseline, got), \
        "cold fallback must reproduce the fault-free answer"
    assert store2.stats["quarantined"] >= 1
    # quarantined artifacts are gone from disk and from the repository
    for e in drv2.repo.entries:
        assert store2.exists(e.artifact)


def test_degraded_runs_surface_in_report(tmp_path):
    root = str(tmp_path / "store")
    drv = fresh_driver(root=root, n_rows=N_ROWS)
    results, _ = drv.run_plan(pigmix.L3("sum"))
    drv.store.flush()
    assert _corrupt_every_artifact(root) > 0
    store2 = ArtifactStore(root=root)
    cat2 = Catalog(store2)
    pigmix.register_all(cat2, n_rows=N_ROWS, seed=0)
    drv2 = ReStore(cat2, store2, drv.repo)
    _, rep = drv2.run_plan(pigmix.L3("sum"))
    assert rep.degraded >= 1


def test_manifest_corruption_reaped_on_open(tmp_path):
    root = str(tmp_path / "store")
    drv = fresh_driver(root=root, n_rows=N_ROWS)
    drv.run_plan(pigmix.L2())
    drv.store.flush()
    dirs = [d for d in os.listdir(root)
            if os.path.isdir(os.path.join(root, d))
            and not d.startswith((".", "_"))]
    victim = os.path.join(root, sorted(dirs)[0], "manifest.json")
    with open(victim, "w") as f:
        f.write("{ not json")
    store2 = ArtifactStore(root=root)
    assert store2.stats["corrupt_on_open"] == 1
    assert not os.path.exists(os.path.dirname(victim)), \
        "corrupt artifact dir must be removed at open"


def test_transient_read_errors_are_retried(tmp_path):
    root = str(tmp_path / "store")
    drv = fresh_driver(root=root, n_rows=N_ROWS)
    results, _ = drv.run_plan(pigmix.L2())
    drv.store.flush()
    names = [e.artifact for e in drv.repo.entries]
    assert names
    inj = FaultInjector(FaultSchedule(0, rates={"transient": 1.0},
                                      max_faults=3))
    store2 = ArtifactStore(root=root, fault_injector=inj)
    t = store2.get(names[0])            # 3 injected failures, then clean
    assert t is not None
    assert store2.stats["read_retries"] == 3


def test_transient_reads_exhaust_to_transient_error(tmp_path):
    from repro.store.artifacts import TransientStoreError
    root = str(tmp_path / "store")
    drv = fresh_driver(root=root, n_rows=N_ROWS)
    drv.run_plan(pigmix.L2())
    drv.store.flush()
    name = drv.repo.entries[0].artifact
    inj = FaultInjector(FaultSchedule(0, rates={"transient": 1.0},
                                      max_faults=10**6))
    store2 = ArtifactStore(root=root, fault_injector=inj)
    with pytest.raises(TransientStoreError):
        store2.get(name)


def test_simulated_crash_in_flusher_reports_at_flush(tmp_path):
    """A SimulatedCrash killing a write-behind flush is a permanent
    failure: flush() raises, the artifact is de-advertised, and its
    orphaned tmp dir is reaped on the next open."""
    from repro.store.artifacts import ArtifactFlushError
    root = str(tmp_path / "store")
    inj = FaultInjector(FaultSchedule(0, rates={}, max_faults=1))
    store = ArtifactStore(root=root, fault_injector=inj)
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=N_ROWS)
    drv = ReStore(cat, store, Repository())
    inj.arm("publish")
    _, rep = drv.run_plan(pigmix.L2())
    flush_failed = bool(rep.flush_failures)
    if not flush_failed:                # crash hit a later artifact
        with pytest.raises(ArtifactFlushError):
            store.flush()
    assert any(d.startswith(".tmp-") for d in os.listdir(root)), \
        "a crash mid-publish leaves its tmp dir, like a real kill"
    store2 = ArtifactStore(root=root, tmp_gc_age_s=0)
    assert not any(d.startswith(".tmp-") for d in os.listdir(root))
    assert store2.stats["tmp_gc"] >= 1
