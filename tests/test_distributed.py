"""Distribution tests.  These spawn SUBPROCESSES that set
XLA_FLAGS=--xla_force_host_platform_device_count before importing jax —
the main pytest process must keep seeing 1 device."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_main_process_sees_one_device():
    import jax
    assert len(jax.devices()) == 1


def test_sharded_train_step_matches_single_device():
    """Same params+batch: loss on a (2 data x 2 model) mesh == 1 device."""
    run_sub("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.api import build
from repro.launch.sharding import param_specs, batch_specs, to_named

cfg = get_config("qwen3-1.7b", smoke=True)
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = model.demo_batch(jax.random.PRNGKey(1), seq=16, gbs=4)

loss_1dev = model.loss_fn(params, batch)[0]

mesh = jax.make_mesh((2, 2), ("data", "model"))
p_sh = to_named(param_specs(cfg, params, mesh), mesh)
b_sh = to_named(batch_specs(cfg, batch, mesh), mesh)
params_s = jax.device_put(params, p_sh)
batch_s = jax.device_put(batch, b_sh)
with mesh:
    loss_mesh = jax.jit(lambda p, b: model.loss_fn(p, b)[0],
                        in_shardings=(p_sh, b_sh))(params_s, batch_s)
err = abs(float(loss_1dev) - float(loss_mesh))
assert err < 1e-4, (float(loss_1dev), float(loss_mesh))
print("OK", err)
""")


def test_dryrun_cell_compiles_on_8_devices():
    """A reduced-mesh dry-run of a full-size arch config."""
    run_sub("""
import jax
from repro.configs import get_config
from repro.models.api import build
from repro.launch.sharding import param_specs, batch_specs, to_named

cfg = get_config("yi-6b")
model = build(cfg)
mesh = jax.make_mesh((2, 4), ("data", "model"))
ps = model.init_shapes(jax.random.PRNGKey(0))
p_sh = to_named(param_specs(cfg, ps, mesh), mesh)
import jax.numpy as jnp
batch = {"tokens": jax.ShapeDtypeStruct((8, 512), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 512), jnp.int32),
         "positions": jax.ShapeDtypeStruct((512,), jnp.int32)}
b_sh = to_named(batch_specs(cfg, batch, mesh), mesh)
with mesh:
    lowered = jax.jit(lambda p, b: model.loss_fn(p, b)[0],
                      in_shardings=(p_sh, b_sh)).lower(ps, batch)
    compiled = lowered.compile()
print("compiled OK,", compiled.memory_analysis().temp_size_in_bytes)
""")


def test_elastic_checkpoint_restore_across_meshes():
    """Save on a (4,) DP mesh, restore on (2, 2) — shapes re-shard."""
    run_sub("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train.checkpoint import save_checkpoint, restore_checkpoint, \
    latest_step

d = tempfile.mkdtemp()
mesh_a = jax.make_mesh((4,), ("data",))
tree = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                            NamedSharding(mesh_a, P("data", None)))}
save_checkpoint(d, 3, tree)

mesh_b = jax.make_mesh((2, 2), ("data", "model"))
target = jax.eval_shape(lambda: tree)
sh = {"w": NamedSharding(mesh_b, P("data", "model"))}
restored, m = restore_checkpoint(d, 3, target, sh)
assert m["step"] == 3
assert np.allclose(np.asarray(restored["w"]),
                   np.arange(64.0).reshape(8, 8))
assert restored["w"].sharding == sh["w"]
print("elastic restore OK")
""")


def test_make_production_mesh_multi_pod():
    run_sub("""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert dict(m1.shape) == {"data": 16, "model": 16}
m2 = make_production_mesh(multi_pod=True)
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
print("meshes OK")
""", devices=512)


def test_shard_map_moe_matches_gspmd():
    """Expert-parallel shard_map MoE == the GSPMD dispatch (outputs exact;
    aux is per-DP-group, Switch-style, so compared loosely)."""
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import dist
from repro.models.layers import (_moe_forward_gspmd,
                                 _moe_forward_shard_map, init_moe)

cfg = get_config("qwen3-moe-235b-a22b", smoke=True)
p = init_moe(cfg, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model),
                      jnp.float32)
ref, aux_ref = _moe_forward_gspmd(cfg, p, x)
mesh = jax.make_mesh((2, 2), ("data", "model"))
dist.set_mesh(mesh)
with mesh:
    out, aux = jax.jit(
        lambda p, x: _moe_forward_shard_map(cfg, p, x, mesh))(p, x)
assert float(jnp.abs(ref - out).max()) < 1e-4
assert abs(float(aux_ref) - float(aux)) / float(aux_ref) < 0.05
print("OK")
""")


def test_sequence_sharded_decode_matches_reference():
    """Flash-decoding with a sequence-sharded cache == single-device
    decode across a prefill+decode rollout."""
    run_sub("""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models.api import build
from repro.models import dist

cfg = get_config("llama4-maverick-400b-a17b", smoke=True)
m = build(cfg)
key = jax.random.PRNGKey(0)
params = m.init(key)
T, K, B = 12, 4, 4
full = m.demo_batch(key, seq=T + K, gbs=B)

def sl(b, s0, s1):
    out = {}
    for k2, v in b.items():
        if k2 == "labels":
            continue
        if k2 == "positions":
            out[k2] = v[s0:s1]
        elif v.ndim >= 2:
            out[k2] = v[:, s0:s1]
        else:
            out[k2] = v[s0:s1]
    return out

dist.set_mesh(None); dist.set_optimized(False)
cache = m.init_cache(B, 16)
lg, cache = m.prefill(params, sl(full, 0, T), cache)
ref = [lg]
for t in range(K):
    lg, cache = m.decode_step(params, sl(full, T + t, T + t + 1), cache,
                              jnp.int32(T + t))
    ref.append(lg)

mesh = jax.make_mesh((2, 4), ("data", "model"))
dist.set_mesh(mesh); dist.set_optimized(True)
cache = m.init_cache(B, 16)
with mesh:
    lg, cache = m.prefill(params, sl(full, 0, T), cache)
    got = [lg]
    for t in range(K):
        lg, cache = jax.jit(m.decode_step)(
            params, sl(full, T + t, T + t + 1), cache, jnp.int32(T + t))
        got.append(lg)
errs = [float(jnp.abs(a - b).max()) for a, b in zip(ref, got)]
assert max(errs) < 2e-3, errs
print("OK")
""")


def test_distributed_groupby_matches_single_device():
    """The shard_map shuffle (hash partition + all_to_all + local
    aggregate) equals the single-device GROUPBY."""
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.dataflow.table import Table, encode_strings, decode_strings
from repro.dataflow.physical import op_groupby
from repro.dataflow.shuffle import distributed_groupby

rng = np.random.default_rng(0)
n = 1024
t = Table.from_numpy({
    "key": encode_strings([f"k{i}" for i in rng.integers(0, 37, n)]),
    "val": rng.uniform(0, 10, n).astype(np.float32),
})
keys, aggs = ["key"], {"s": ("sum", "val"), "c": ("count", "val")}
ref = op_groupby(t, keys, aggs)
mesh = jax.make_mesh((8,), ("data",))
with mesh:
    got, ovf = jax.jit(
        lambda tt: distributed_groupby(tt, keys, aggs, mesh))(t)
assert int(ovf) == 0
r, g = ref.to_numpy(), got.to_numpy()
rk = decode_strings(r["key"]); gk = decode_strings(g["key"])
assert sorted(rk) == sorted(gk)
rmap = dict(zip(rk, zip(r["s"], r["c"])))
for k, s, c in zip(gk, g["s"], g["c"]):
    assert abs(rmap[k][0] - s) < 1e-2 and rmap[k][1] == c, k
print("OK")
""")


def test_compressed_gradient_allreduce():
    """int8 gradient psum with error feedback: per-step error bounded by
    the quantization grid, and the ACCUMULATED update over many steps
    converges to the true mean (error feedback kills the bias)."""
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.train.compression import make_compressed_sync

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
sync = make_compressed_sync(mesh, ("data",))

shape = (8, 64)     # leading dim = per-shard slices
errors = {"w": jnp.zeros((64,), jnp.float32)}
acc_c = np.zeros(64)
acc_t = np.zeros(64)
with mesh:
    for step in range(50):
        g = rng.normal(size=shape).astype(np.float32) * (1 + step % 3)
        true_mean = g.mean(0)
        mean_c, errors = jax.jit(sync)({"w": jnp.asarray(g)}, errors)
        step_err = np.abs(np.asarray(mean_c["w"]) - true_mean).max()
        assert step_err < np.abs(g).max() / 127 * 2 + 1e-6, step_err
        acc_c += np.asarray(mean_c["w"])
        acc_t += true_mean
rel = np.abs(acc_c - acc_t).max() / np.abs(acc_t).max()
assert rel < 0.02, rel    # error feedback: accumulated bias vanishes
print("OK", rel)
""")
