"""Mesh-sharded relational execution (DESIGN.md §11).  Like
tests/test_distributed.py, these spawn SUBPROCESSES that set
XLA_FLAGS=--xla_force_host_platform_device_count before importing jax —
the main pytest process must keep seeing 1 device."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PRELUDE = """
import numpy as np, jax, jax.numpy as jnp
from repro.dataflow.table import Table, encode_strings, decode_strings

def canon(tb):
    d = tb.to_numpy()
    order = np.lexsort(tuple(d[c] for c in sorted(d, reverse=True)))
    return {c: d[c][order] for c in sorted(d)}

def assert_rows_equal(a, b, label=""):
    ca, cb = canon(a), canon(b)
    assert sorted(ca) == sorted(cb), (label, sorted(ca), sorted(cb))
    for c in ca:
        assert np.array_equal(ca[c], cb[c]), (label, c, ca[c], cb[c])
"""


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", _PRELUDE + code], env=env,
                         capture_output=True, text=True, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_join_matches_single_device():
    run_sub("""
from repro.dataflow.physical import op_join
from repro.dataflow.shuffle import distributed_join

rng = np.random.default_rng(0)
left = Table.from_numpy({"k": rng.integers(0, 16, 256).astype(np.int32),
                         "a": rng.integers(0, 9, 256).astype(np.int32)})
right = Table.from_numpy({"rk": np.arange(16, dtype=np.int32),
                          "b": (np.arange(16) * 3 % 7).astype(np.int32)})
ref, _ = op_join(left, right, ["k"], ["rk"])
mesh = jax.make_mesh((8,), ("data",))
with mesh:
    got, sh_ovf, ovf = jax.jit(lambda l, r: distributed_join(
        l, r, ["k"], ["rk"], mesh, skew_factor=8.0))(left, right)
assert int(sh_ovf) == 0 and int(ovf) == 0, (int(sh_ovf), int(ovf))
assert_rows_equal(ref, got, "join")

# rename-chain edge: the right side carries BOTH "v" and "v_r", and the
# left carries "v" — op_join renames sequentially (v -> v_r -> v_r_r),
# and the shard_map out_specs must agree
left2 = Table.from_numpy({"k": np.arange(16, dtype=np.int32),
                          "v": np.arange(16, dtype=np.int32)})
right2 = Table.from_numpy({"k2": np.arange(16, dtype=np.int32),
                           "v": (np.arange(16) * 2).astype(np.int32),
                           "v_r": (np.arange(16) * 3).astype(np.int32)})
ref2, _ = op_join(left2, right2, ["k"], ["k2"])
with mesh:
    got2, so2, o2 = jax.jit(lambda l, r: distributed_join(
        l, r, ["k"], ["k2"], mesh, skew_factor=8.0))(left2, right2)
assert int(so2) == 0 and int(o2) == 0
assert_rows_equal(ref2, got2, "join-rename-chain")
print("OK")
""")


def test_distributed_distinct_and_cogroup_match_single_device():
    run_sub("""
from repro.dataflow.physical import op_cogroup, op_distinct
from repro.dataflow.shuffle import distributed_cogroup, distributed_distinct

rng = np.random.default_rng(1)
dt = Table.from_numpy({"x": rng.integers(0, 12, 512).astype(np.int32),
                       "y": rng.integers(0, 3, 512).astype(np.int32)})
mesh = jax.make_mesh((8,), ("data",))
with mesh:
    got, ovf = jax.jit(lambda t: distributed_distinct(
        t, mesh, skew_factor=8.0))(dt)
assert int(ovf) == 0
assert_rows_equal(op_distinct(dt), got, "distinct")

a = Table.from_numpy({"u": rng.integers(0, 10, 256).astype(np.int32),
                      "v": rng.integers(0, 50, 256).astype(np.float32)})
b = Table.from_numpy({"w": rng.integers(0, 10, 128).astype(np.int32),
                      "z": rng.integers(0, 50, 128).astype(np.float32)})
al = {"sv": ("sum", "v"), "cv": ("count", "v")}
ar = {"sz": ("sum", "z")}
ref = op_cogroup(a, b, ["u"], ["w"], al, ar)
with mesh:
    got, ovf = jax.jit(lambda x, y: distributed_cogroup(
        x, y, ["u"], ["w"], al, ar, mesh, skew_factor=8.0))(a, b)
assert int(ovf) == 0
assert_rows_equal(ref, got, "cogroup")
print("OK")
""")


def test_mesh_restore_warm_run_skips_shuffle_and_matches_plain():
    """End to end: a mesh ReStore run reuses the join artifact of a
    prior query AND skips the group-by exchange, because the artifact is
    co-partitioned on the grouping key; results stay bit-identical to
    the single-device plain run (integer-valued data).  The
    partition-blind ablation reuses without skipping."""
    run_sub("""
from repro.core import plan as P
from repro.core.restore import ReStore
from repro.store.artifacts import ArtifactStore, Catalog

def fact(n=512):
    rng = np.random.default_rng(0)
    return Table.from_numpy({
        "k": rng.integers(0, 24, n).astype(np.int32),
        "v": rng.integers(0, 100, n).astype(np.int32),
        "w": rng.integers(0, 50, n).astype(np.float32)})

def dim():
    ks = np.arange(24, dtype=np.int32)
    return Table.from_numpy({"dk": ks, "e": (ks * 7 % 5).astype(np.int32)})

def q(aggs):
    j = P.join(P.load("fact"), P.load("dim"), ["k"], ["dk"])
    g = P.groupby(j, ["k"], aggs)
    return P.PhysicalPlan([P.store(g, "out")])

def fresh(**kw):
    s = ArtifactStore(); c = Catalog(s)
    c.register("fact", fact()); c.register("dim", dim())
    return ReStore(c, s, **kw)

A1 = {"s": ("sum", "w")}
A2 = {"s": ("sum", "w"), "n": ("count", "w"), "m": ("max", "v")}
rs0 = fresh(heuristic="off", rewrite_enabled=False, semantic=False)
ref1, _ = rs0.run_plan(q(A1))
ref2, _ = rs0.run_plan(q(A2))

mesh = jax.make_mesh((8,), ("data",))
rs = fresh(heuristic="aggressive", mesh=mesh, skew_factor=8.0)
got1, rep1 = rs.run_plan(q(A1))
assert_rows_equal(ref1["out"], got1["out"], "cold")
assert all(j.stats.shuffle_overflow == 0 and j.stats.join_overflow == 0
           for j in rep1.jobs if j.stats)
got2, rep2 = rs.run_plan(q(A2))
assert_rows_equal(ref2["out"], got2["out"], "warm")
assert rep2.n_reused > 0
assert any(j.stats.shuffles_skipped > 0 for j in rep2.jobs if j.stats), \\
    "co-partitioned reuse must skip the group-by exchange"
e = next(e for e in rs.repo.entries if e.partitioning)
assert e.partitioning["keys"] == ["k"]

blind = fresh(heuristic="aggressive", mesh=mesh, skew_factor=8.0,
              partition_aware=False)
blind.run_plan(q(A1))
got3, rep3 = blind.run_plan(q(A2))
assert_rows_equal(ref2["out"], got3["out"], "blind")
assert rep3.n_reused > 0
assert all(j.stats.shuffles_skipped == 0 for j in rep3.jobs if j.stats)
print("OK")
""")


def test_mesh_restore_disk_store_repartition_on_read():
    """A repository artifact stored with P=4 shards answers a P=8 mesh:
    the engine re-partitions on read and the consumer still skips its
    exchange.  Also covers the disk-backed sharded write path under
    mesh execution."""
    run_sub("""
import tempfile
from repro.core import plan as P
from repro.core.restore import ReStore
from repro.store.artifacts import ArtifactStore, Catalog

def fact(n=512):
    rng = np.random.default_rng(0)
    return Table.from_numpy({
        "k": rng.integers(0, 24, n).astype(np.int32),
        "w": rng.integers(0, 50, n).astype(np.float32)})

def dim():
    ks = np.arange(24, dtype=np.int32)
    return Table.from_numpy({"dk": ks, "e": (ks * 7 % 5).astype(np.int32)})

def q(aggs):
    j = P.join(P.load("fact"), P.load("dim"), ["k"], ["dk"])
    g = P.groupby(j, ["k"], aggs)
    return P.PhysicalPlan([P.store(g, "out")])

root = tempfile.mkdtemp(prefix="mesh_repart_")
store = ArtifactStore(root=root)
cat = Catalog(store)
store.put("fact", fact())
store.put("dim", dim())

A1 = {"s": ("sum", "w")}
A2 = {"s": ("sum", "w"), "n": ("count", "w")}
# the reference runs against its OWN store: sharing one would leave
# A2's final artifact behind and turn the probe run into the whole-job
# fast path (nothing executed, nothing to skip)
ref_store = ArtifactStore()
ref_store.put("fact", fact()); ref_store.put("dim", dim())
ref_rs = ReStore(Catalog(ref_store), ref_store, heuristic="off",
                 rewrite_enabled=False, semantic=False)
ref, _ = ref_rs.run_plan(q(A2))

# seed on a 4-shard mesh: the stored join artifact is P=4-partitioned
mesh4 = jax.make_mesh((4,), ("data",))
rs4 = ReStore(cat, store, heuristic="aggressive", mesh=mesh4,
              skew_factor=4.0)
rs4.run_plan(q(A1))
store.flush()
parts = [store.partitioning(n) for n in store.names()
         if store.partitioning(n)]
assert any(p["n_parts"] == 4 and p["keys"] == ["k"] for p in parts), parts

# consume on an 8-shard mesh: P mismatch -> re-partition on read,
# the group-by exchange is STILL skipped
mesh8 = jax.make_mesh((8,), ("data",))
rs8 = ReStore(cat, store, repository=rs4.repo, heuristic="aggressive",
              mesh=mesh8, skew_factor=8.0)
got, rep = rs8.run_plan(q(A2))
assert_rows_equal(ref["out"], got["out"], "repart")
assert rep.n_reused > 0
assert any(j.stats.shuffles_skipped > 0 for j in rep.jobs if j.stats), \\
    "re-partitioned-on-read artifact must still skip the exchange"
print("OK")
""")
