"""Workflow compiler: job splitting mirrors Pig (one blocking op per
reduce stage), content-addressed artifact naming is deterministic, and —
the load-bearing property — executing the compiled workflow equals
executing the original plan directly."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import plan as P
from repro.dataflow.expr import Col
from repro.dataflow.compiler import compile_workflow
from repro.dataflow.executor import Engine
from repro.dataflow.physical import execute_plan
from repro.dataflow.table import Table, encode_strings
from repro.store.artifacts import ArtifactStore, Catalog
from repro.workloads import pigmix
from tests.test_matcher import random_plan, _table


def test_q2_splits_into_two_jobs():
    pv = P.project(P.load("page_views"), ["user", "estimated_revenue"])
    u = P.project(P.load("users"), ["name"])
    j = P.join(pv, u, ["user"], ["name"])
    g = P.groupby(j, ["user"], {"s": ("sum", "estimated_revenue")})
    wf = compile_workflow(P.PhysicalPlan([P.store(g, "out")]))
    assert wf.n_jobs() == 2
    assert wf.jobs[0].blocking == "JOIN"
    assert wf.jobs[1].blocking == "GROUPBY"
    # job 2 reads job 1's artifact
    assert wf.jobs[0].outputs[0] in wf.jobs[1].inputs


def test_map_only_job():
    f = P.filter_(P.project(P.load("t"), ["key", "val"]),
                  Col("val") > 1.0)
    wf = compile_workflow(P.PhysicalPlan([P.store(f, "out")]))
    assert wf.n_jobs() == 1 and wf.jobs[0].blocking is None


def test_l11_multi_job_dag():
    wf = compile_workflow(pigmix.L11())
    assert wf.n_jobs() >= 2          # distinct(pv) + final distinct
    # topological: every input artifact is produced by an earlier job
    seen = set()
    for job in wf.jobs:
        for i in job.inputs:
            assert (not i.startswith("art/")) or i in seen, i
        seen.update(job.outputs)


def test_artifact_names_deterministic():
    wfs = [compile_workflow(pigmix.L3("sum")) for _ in range(2)]
    assert [j.outputs for j in wfs[0].jobs] == \
        [j.outputs for j in wfs[1].jobs]
    # L3 variants share the join job's artifact (cross-query reuse)
    wf_mean = compile_workflow(pigmix.L3("mean"))
    assert wf_mean.jobs[0].outputs == wfs[0].jobs[0].outputs
    assert wf_mean.jobs[1].outputs != wfs[0].jobs[1].outputs


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), depth=st.integers(1, 5))
def test_property_workflow_equals_direct_execution(seed, depth):
    rng = np.random.default_rng(seed)
    plan = random_plan(rng, depth)
    t = _table(seed=seed % 13)

    ref, _ = execute_plan(plan, {"t": t})

    store = ArtifactStore()
    cat = Catalog(store)
    cat.register("t", t)
    wf = compile_workflow(plan)
    results, _ = Engine(cat, store).run_workflow(wf)
    r, g = ref["out"].to_numpy(), results["out"].to_numpy()
    assert sorted(r) == sorted(g)
    for c in r:
        rv = np.sort(r[c].astype(np.float64), axis=0)
        gv = np.sort(g[c].astype(np.float64), axis=0)
        assert np.allclose(rv, gv, atol=1e-3), c


def test_all_pigmix_queries_compile_and_run():
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=512)
    eng = Engine(cat, store)
    for name, qfn in pigmix.QUERIES.items():
        wf = compile_workflow(qfn())
        results, stats = eng.run_workflow(wf)
        for tname, tab in results.items():
            assert int(tab.num_valid()) >= 0
            for c in tab.to_numpy().values():
                assert not np.isnan(c.astype(np.float64)).any(), \
                    (name, tname)
