"""LM data pipeline on the dataflow engine + ReStore reuse across runs."""
import numpy as np
import pytest

from repro.core.restore import ReStore
from repro.store.artifacts import ArtifactStore, Catalog
from repro.train.data import (batches_from_table, pipeline_plan,
                              run_pipeline, synthetic_corpus)


def _restore():
    store = ArtifactStore()
    cat = Catalog(store)
    cat.register("corpus", synthetic_corpus(128, 64, 1024))
    # the shared pipeline prefix is a streaming (filter) region; at this
    # toy corpus size the L7 exact-splice guard would decline it, and
    # these tests pin the prefix-sharing mechanism itself
    return ReStore(cat, store, heuristic="aggressive",
                   min_splice_benefit_s=0.0)


def test_pipeline_filters_and_dedups():
    rs = _restore()
    table, rep = run_pipeline(rs, rs.catalog.get("corpus"),
                              min_quality=0.3)
    n = int(table.num_valid())
    corpus = rs.catalog.get("corpus").to_numpy()
    keep = corpus["quality"] > 0.3
    uniq = len(np.unique(corpus["tokens"][keep], axis=0))
    assert n == uniq, "dedup + filter must match numpy oracle"


def test_rerun_fully_reused():
    rs = _restore()
    run_pipeline(rs, rs.catalog.get("corpus"))
    _, rep2 = run_pipeline(rs, rs.catalog.get("corpus"))
    assert rep2.n_executed == 0


def test_prefix_shared_between_variants():
    rs = _restore()
    rs.run_plan(pipeline_plan(0.3, out_name="a"))
    _, rep = rs.run_plan(pipeline_plan(0.3, min_length=32, out_name="b"))
    assert sum(len(j.reused_artifacts) for j in rep.jobs) > 0


def test_batcher_deterministic_skip_ahead():
    rs = _restore()
    table, _ = run_pipeline(rs, rs.catalog.get("corpus"))
    b1 = batches_from_table(table, 4, 32, seed=1)
    b2 = batches_from_table(table, 4, 32, seed=1)
    for _ in range(3):
        next(b2)
    a = [next(b1) for _ in range(5)]
    b = [next(b2) for _ in range(2)]
    assert (a[3][0] == b[0][0]).all() and (a[4][1] == b[1][1]).all()
