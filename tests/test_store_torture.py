"""Concurrent artifact-store torture + write-behind failure reporting
(DESIGN.md §13 satellites).

The torture test hammers ONE shared ArtifactStore (device cache and
write-behind enabled) from many threads with put/get/delete/alias/
flush/gc and asserts the two invariants torn state would break: every
successful ``get`` returns an internally-consistent table (version tag
and checksum column agree), and the store reopened from disk afterwards
verifies clean.
"""
import os
import random
import threading
import time

import numpy as np
import pytest

from repro.dataflow.table import Table
from repro.store.artifacts import (ArtifactFlushError, ArtifactMissingError,
                                   ArtifactStore, CorruptArtifactError)

N_THREADS = 6
OPS_PER_THREAD = 60
NAMES = [f"art/t{i}" for i in range(8)]


def _tagged_table(tag: int, n=256):
    # "check" is derived from "v": a torn read (rows from two versions)
    # breaks the equality below
    v = np.full(n, tag, dtype=np.int32)
    return Table.from_numpy({"v": v, "check": v * 2 + 1})


def _consistent(t):
    d = t.to_numpy()
    v = d["v"]
    return (v == v[0]).all() and (d["check"] == v * 2 + 1).all()


def test_concurrent_store_torture(tmp_path):
    store = ArtifactStore(root=str(tmp_path / "store"))
    errors = []
    inconsistent = []

    def worker(wid):
        rng = random.Random(1000 + wid)
        try:
            for op in range(OPS_PER_THREAD):
                name = rng.choice(NAMES)
                r = rng.random()
                if r < 0.40:
                    store.put(name, _tagged_table(wid * 1000 + op))
                elif r < 0.80:
                    try:
                        t = store.get(name)
                    except (ArtifactMissingError, KeyError):
                        continue
                    if not _consistent(t):
                        inconsistent.append(name)
                elif r < 0.90:
                    store.delete(name)
                elif r < 0.95:
                    store.alias(f"alias/{wid}", name)
                else:
                    try:
                        store.flush()
                    except ArtifactFlushError as e:
                        errors.append(repr(e))
        except BaseException as e:      # noqa: BLE001 - surface in main
            errors.append(f"worker {wid}: {e!r}")

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive(), "torture worker deadlocked"
    assert not errors, errors[:3]
    assert not inconsistent, f"torn reads observed: {inconsistent[:3]}"
    store.flush()

    # survivors are readable and internally consistent
    for name in list(store.names()):
        t = store.get(name)
        assert _consistent(t)
        assert store.verify(name)

    # disk state reopens clean: no tmp dirs, no corrupt manifests,
    # every artifact verifies against its checksums
    store2 = ArtifactStore(root=store.root, tmp_gc_age_s=0)
    assert store2.stats["corrupt_on_open"] == 0
    assert not any(d.startswith(".tmp-")
                   for d in os.listdir(store.root))
    for name in store2.names():
        assert store2.verify(name), f"{name} fails checksum after reopen"
        assert _consistent(store2.get(name))


# ----------------------------------------------- write-behind failures


def test_flush_failure_is_recorded_and_raised(tmp_path, monkeypatch):
    """Satellite (a): a failed background write must never vanish —
    it is recorded per artifact, the artifact is de-advertised, and
    ``flush()`` (the durability barrier) raises."""
    store = ArtifactStore(root=str(tmp_path / "store"))
    import repro.store.artifacts as A
    real_savez = np.savez
    monkeypatch.setattr(A.np, "savez",
                        lambda *a, **k: (_ for _ in ()).throw(
                            OSError("disk on fire")))
    store.put("art/doomed", _tagged_table(1))
    with pytest.raises(ArtifactFlushError) as ei:
        store.flush()
    assert "art/doomed" in ei.value.failures
    assert isinstance(ei.value, OSError), "pre-§13 catch still works"
    assert not store.exists("art/doomed"), \
        "a failed write must de-advertise the artifact"
    assert store.stats["write_retries"] > 0, "OSError path is retried"

    # the failure does not wedge the store: subsequent writes succeed
    monkeypatch.setattr(A.np, "savez", real_savez)
    store.put("art/fine", _tagged_table(2))
    store.flush()                        # failures were drained: no raise
    assert store.exists("art/fine")
    store.close()


def test_flush_failure_counts_per_artifact(tmp_path, monkeypatch):
    store = ArtifactStore(root=str(tmp_path / "store"))
    import repro.store.artifacts as A
    monkeypatch.setattr(A.np, "savez",
                        lambda *a, **k: (_ for _ in ()).throw(
                            OSError("nope")))
    store.put("art/a", _tagged_table(1))
    store.put("art/b", _tagged_table(2))
    with pytest.raises(ArtifactFlushError) as ei:
        store.flush()
    assert set(ei.value.failures) == {"art/a", "art/b"}


# ------------------------------------------------------- tmp-dir GC


def test_tmp_gc_age_guard(tmp_path):
    root = str(tmp_path / "store")
    os.makedirs(root)
    fresh = os.path.join(root, ".tmp-fresh")
    stale = os.path.join(root, ".tmp-stale")
    os.makedirs(fresh)
    os.makedirs(stale)
    old = time.time() - 48 * 3600
    os.utime(stale, (old, old))

    # default age guard: a fresh tmp dir may belong to a LIVE writer in
    # another process — only the stale one is reaped
    store = ArtifactStore(root=root)
    assert not os.path.exists(stale)
    assert os.path.exists(fresh)
    assert store.stats["tmp_gc"] == 1

    # age 0 (we KNOW no writer survived, e.g. post-crash recovery)
    store2 = ArtifactStore(root=root, tmp_gc_age_s=0)
    assert not os.path.exists(fresh)
    assert store2.stats["tmp_gc"] == 1


def test_corrupt_artifact_error_from_verify_path(tmp_path):
    store = ArtifactStore(root=str(tmp_path / "store"))
    store.put("art/x", _tagged_table(3))
    store.flush()
    from repro.store.artifacts import _encode_name
    d = os.path.join(store.root, _encode_name("art/x"))
    npz = [f for f in os.listdir(d) if f.endswith(".npz")][0]
    p = os.path.join(d, npz)
    with open(p, "r+b") as f:
        b = f.read(1)
        f.seek(0)
        f.write(bytes([b[0] ^ 0xFF]))
    assert not store.verify("art/x")
    store2 = ArtifactStore(root=store.root)
    with pytest.raises(CorruptArtifactError):
        store2.get("art/x")
