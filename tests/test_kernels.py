"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret=True)
vs the pure-jnp ref.py oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import mha
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.segment_reduce.ops import segment_sum
from repro.kernels.segment_reduce.ref import segment_sum_ref
from repro.kernels.filter_project.ops import compact
from repro.kernels.filter_project.ref import filter_compact_ref
from repro.kernels.radix_partition.ops import partition
from repro.kernels.radix_partition.ref import radix_partition_ref
from repro.kernels.hash_join.ops import probe
from repro.kernels.hash_join.ref import join_probe_ref


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d", [
    (1, 2, 2, 64, 64, 32),
    (2, 4, 2, 128, 256, 64),
    (1, 8, 1, 64, 128, 128),      # MQA
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, sq, skv, d, causal, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), dtype)
    o1 = mha(q, k, v, causal=causal, impl="pallas", block_q=64,
             block_k=64)
    o2 = mha(q, k, v, causal=causal, impl="ref")
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    assert jnp.abs(o1.astype(jnp.float32)
                   - o2.astype(jnp.float32)).max() < tol


def test_flash_attention_decode_with_kv_len():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 4, 1, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 4, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 4, 256, 64)), jnp.float32)
    for kv_len in (1, 100, 256):
        o1 = mha(q, k, v, kv_len=kv_len, causal=True, impl="pallas",
                 block_q=1, block_k=128, q_offset=kv_len - 1)
        o2 = mha(q, k, v, kv_len=kv_len, causal=True, impl="ref",
                 q_offset=kv_len - 1)
        assert jnp.abs(o1 - o2).max() < 2e-5, kv_len


@pytest.mark.parametrize("n,d,s,tile", [
    (256, 4, 16, 64), (1024, 8, 100, 128), (512, 1, 512, 256),
])
def test_segment_reduce_sweep(n, d, s, tile):
    """Kernel contract (matches the engine's GROUPBY): seg ids are sorted
    AND dense (consecutive — produced by a cumsum over boundaries)."""
    rng = np.random.default_rng(2)
    raw = np.sort(rng.integers(0, s, n))
    _, seg = np.unique(raw, return_inverse=True)    # densify
    seg = seg.astype(np.int32)
    seg[-n // 8:] = s                     # sentinel (invalid) tail
    vals = rng.normal(size=(n, d)).astype(np.float32)
    a = segment_sum(jnp.asarray(vals), jnp.asarray(seg), num_segments=s,
                    impl="pallas", tile_n=tile)
    b = segment_sum_ref(jnp.asarray(vals), jnp.asarray(seg),
                        num_segments=s)
    assert jnp.allclose(a, b, atol=1e-4)


@pytest.mark.parametrize("n,d,keep", [(256, 4, 0.3), (1024, 2, 0.9),
                                      (512, 8, 0.0)])
def test_filter_project_sweep(n, d, keep):
    rng = np.random.default_rng(3)
    vals = rng.normal(size=(n, d)).astype(np.float32)
    mask = rng.random(n) < keep
    o1, t1 = compact(jnp.asarray(vals), jnp.asarray(mask),
                     impl="pallas", tile_n=128)
    o2, t2 = filter_compact_ref(jnp.asarray(vals), jnp.asarray(mask))
    assert int(t1) == int(t2) == int(mask.sum())
    assert jnp.allclose(o1, o2)


@pytest.mark.parametrize("n,parts", [(256, 4), (1024, 16), (512, 64)])
def test_radix_partition_sweep(n, parts):
    rng = np.random.default_rng(4)
    h = rng.integers(0, 2**32, n, dtype=np.uint32)
    valid = rng.random(n) < 0.8
    p1, h1 = partition(jnp.asarray(h), jnp.asarray(valid), n_parts=parts,
                       impl="pallas", tile_n=128)
    p2, h2 = radix_partition_ref(jnp.asarray(h), jnp.asarray(valid),
                                 n_parts=parts, tile_n=128)
    assert (np.asarray(p1) == np.asarray(p2)).all()
    assert (np.asarray(h1) == np.asarray(h2)).all()
    assert int(h1.sum()) == int(valid.sum())


@pytest.mark.parametrize("n,r", [(256, 1), (512, 100), (1024, 4096)])
def test_hash_join_probe_sweep(n, r):
    rng = np.random.default_rng(5)
    rh = np.sort(rng.integers(0, 2**32, r, dtype=np.uint32))
    lh = rng.integers(0, 2**32, n, dtype=np.uint32)
    lh[: n // 4] = rh[rng.integers(0, r, n // 4)]   # guaranteed hits
    lh[0], lh[1] = 0, np.uint32(2**32 - 1)          # extremes
    q1 = probe(jnp.asarray(lh), jnp.asarray(rh), impl="pallas",
               tile_n=128)
    q2 = join_probe_ref(jnp.asarray(lh), jnp.asarray(rh))
    assert (np.asarray(q1) == np.asarray(q2)).all()
