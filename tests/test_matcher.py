"""ReStore core: containment matching, Algorithm-1 agreement, rewriting
correctness, repository ordering + eviction rules, with hypothesis
property tests over randomly generated plans."""
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # only the property tests need hypothesis
    HAVE_HYPOTHESIS = False

    def _noop_deco(*a, **k):
        return lambda f: pytest.mark.skip("hypothesis not installed")(f)

    given = settings = _noop_deco

    class st:            # placeholder so strategy expressions still parse
        @staticmethod
        def integers(*a, **k):
            return None

from repro.core import plan as P
from repro.core.matcher import (FingerprintIndex, SemanticIndex,
                                match_bottom_up, pairwise_plan_traversal)
from repro.core.repository import Repository, make_entry
from repro.core.restore import ReStore
from repro.core.rewriter import rewrite_plan
from repro.dataflow.expr import Col
from repro.dataflow.physical import execute_plan
from repro.dataflow.table import Table, encode_strings
from repro.store.artifacts import ArtifactStore, Catalog


def _table(n=128, seed=0):
    rng = np.random.default_rng(seed)
    return Table.from_numpy({
        "key": encode_strings([f"k{i}" for i in
                               rng.integers(0, 10, n)]),
        "val": rng.uniform(0, 10, n).astype(np.float32),
        "num": rng.integers(0, 100, n).astype(np.int32),
    })


# ---------------------------------------------------------------------------
# random plan generator (chains + joins) for property tests


def random_plan(rng: np.random.Generator, depth: int = 4):
    op = P.load("t")
    for _ in range(depth):
        kind = rng.integers(0, 4)
        if kind == 0:
            op = P.filter_(op, Col("val") > float(rng.uniform(0, 10)))
        elif kind == 1:
            op = P.foreach(op, {"key": Col("key"),
                                "val": Col("val") * float(rng.uniform(1, 3)),
                                "num": Col("num")})
        elif kind == 2:
            op = P.groupby(op, ["key"], {"val": ("sum", "val"),
                                         "num": ("max", "num"),
                                         })
            op = P.foreach(op, {"key": Col("key"), "val": Col("val"),
                                "num": Col("num")})
        else:
            op = P.distinct(op)
    return P.PhysicalPlan([P.store(op, "out")])


@settings(max_examples=25, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), depth=st.integers(1, 5),
       cut=st.integers(0, 5))
def test_property_subplan_always_contained(seed, depth, cut):
    """Any prefix sub-plan of a plan is found by both matchers, and both
    return the same anchor."""
    rng = np.random.default_rng(seed)
    plan = random_plan(rng, depth)
    ops = [o for o in plan.topo() if o.kind not in ("LOAD", "STORE")]
    target = ops[min(cut, len(ops) - 1)]
    sub = plan.subplan_upto(target, "sub")
    m1 = match_bottom_up(plan, sub)
    m2 = pairwise_plan_traversal(plan, sub)
    assert m1 is not None, "bottom-up must find its own sub-plan"
    assert m2 is not None, "Algorithm 1 must find its own sub-plan"
    fps = plan.fingerprints()
    assert fps[id(m1)] == fps[id(target)]
    assert fps[id(m2)] == fps[id(target)]


@settings(max_examples=15, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), depth=st.integers(1, 4))
def test_property_rewrite_preserves_results(seed, depth):
    """Executing the rewritten plan (with the matched region answered
    from a stored artifact) gives the same rows as the original."""
    rng = np.random.default_rng(seed)
    plan = random_plan(rng, depth)
    t = _table(seed=seed % 17)
    ops = [o for o in plan.topo() if o.kind not in ("LOAD", "STORE")]
    target = ops[rng.integers(0, len(ops))]
    sub = plan.subplan_upto(target, "sub")

    # execute the sub-plan, store its artifact, register in repository
    sub_out, _ = execute_plan(sub, {"t": t})
    repo = Repository()
    repo.add(make_entry(sub, "art/test", bytes_in=100, bytes_out=10))

    rw = rewrite_plan(plan, repo)
    assert rw.used, "the stored sub-plan must be reused"
    ref, _ = execute_plan(plan, {"t": t})
    got, _ = execute_plan(rw.plan, {"t": t, "art/test": sub_out["sub"]})
    r, g = ref["out"].to_numpy(), got["out"].to_numpy()
    assert sorted(r) == sorted(g)
    for c in r:
        rv, gv = np.sort(r[c], axis=0), np.sort(g[c], axis=0)
        assert np.allclose(rv.astype(np.float64), gv.astype(np.float64),
                           atol=1e-3), c


@settings(max_examples=25, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), depth1=st.integers(1, 4),
       depth2=st.integers(1, 4))
def test_property_matchers_agree_on_random_pairs(seed, depth1, depth2):
    """On arbitrary (input, repo) plan pairs — not just prefix sub-plans —
    the production matcher and Algorithm 1 agree: both miss, or both
    return anchors with equal fingerprints.  And the semantic index never
    fires when the exact index would (exact hits take priority)."""
    rng = np.random.default_rng(seed)
    plan = random_plan(rng, depth1)
    repo_plan = random_plan(rng, depth2)
    m1 = match_bottom_up(plan, repo_plan)
    m2 = pairwise_plan_traversal(plan, repo_plan)
    assert (m1 is None) == (m2 is None)
    if m1 is not None:
        fps = plan.fingerprints()
        assert fps[id(m1)] == fps[id(m2)]
        assert SemanticIndex(plan).probe(repo_plan) is None, \
            "semantic probe must stand down when the exact index hits"


@settings(max_examples=25, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), depth=st.integers(1, 5),
       cut=st.integers(0, 5))
def test_property_semantic_never_fires_on_exact_subplans(seed, depth, cut):
    rng = np.random.default_rng(seed)
    plan = random_plan(rng, depth)
    ops = [o for o in plan.topo() if o.kind not in ("LOAD", "STORE")]
    sub = plan.subplan_upto(ops[min(cut, len(ops) - 1)], "sub")
    assert FingerprintIndex(plan).probe(sub) is not None
    assert SemanticIndex(plan).probe(sub) is None


def test_semantic_index_weaker_filter_and_wider_project():
    base = P.project(P.load("t"), ["key", "val", "num"])
    q = P.PhysicalPlan([P.store(
        P.project(P.filter_(base, Col("val") > 20.0), ["key", "val"]),
        "out")])
    stored = P.PhysicalPlan([P.store(
        P.filter_(P.project(P.load("t"), ["key", "val", "num"]),
                  Col("val") > 10.0), "s")])
    assert FingerprintIndex(q).probe(stored) is None
    m = SemanticIndex(q).probe(stored)
    assert m is not None
    assert m.residual is not None, "residual predicate must be re-applied"
    assert m.narrow_cols == ("key", "val")
    # reverse direction must refuse: stored is STRONGER than the query
    stronger = P.PhysicalPlan([P.store(
        P.filter_(P.project(P.load("t"), ["key", "val", "num"]),
                  Col("val") > 30.0), "s")])
    assert SemanticIndex(q).probe(stronger) is None
    # narrower stored projection must refuse: 'num' is gone
    narrower = P.PhysicalPlan([P.store(
        P.project(P.load("t"), ["key"]), "s")])
    q2 = P.PhysicalPlan([P.store(P.project(P.load("t"), ["key", "val"]),
                                 "o")])
    assert SemanticIndex(q2).probe(narrower) is None


def test_fingerprint_index_prefers_topologically_latest_anchor():
    """Diamond plan with a duplicated subtree: the index must keep ALL
    ops per fingerprint and anchor at the topologically-latest one, so
    sub-job credit attribution can't land on the wrong node."""
    dup_a = P.filter_(P.load("t"), Col("val") > 1.0)
    dup_b = P.filter_(P.load("t"), Col("val") > 1.0)   # identical twin
    left = P.distinct(dup_a)
    right = P.project(dup_b, ["key", "val"])
    plan = P.PhysicalPlan([P.store(P.union(left, right), "out")])

    sub = P.PhysicalPlan([P.store(
        P.filter_(P.load("t"), Col("val") > 1.0), "s")])
    idx = FingerprintIndex(plan)
    fps = plan.fingerprints()
    fp = fps[id(dup_a)]
    assert fp == fps[id(dup_b)]
    assert len(idx.by_fp[fp]) == 2, "both duplicate ops must be indexed"
    anchor = idx.probe(sub)
    topo_pos = {id(o): i for i, o in enumerate(plan.topo())}
    assert topo_pos[id(anchor)] == max(topo_pos[id(dup_a)],
                                       topo_pos[id(dup_b)])
    assert match_bottom_up(plan, sub) is anchor
    # both duplicated sites get rewritten (fresh scan per round)
    repo = Repository()
    repo.add(make_entry(sub, "art/dup", bytes_in=100, bytes_out=10))
    rw = rewrite_plan(plan, repo)
    kinds = [o.kind for o in rw.plan.topo()]
    assert kinds.count("FILTER") == 0, "every duplicate site rewritten"


def test_no_false_containment():
    base = P.filter_(P.load("t"), Col("val") > 1.0)
    plan = P.PhysicalPlan([P.store(base, "out")])
    other = P.PhysicalPlan([P.store(
        P.filter_(P.load("t"), Col("val") > 2.0), "s")])
    assert match_bottom_up(plan, other) is None
    assert pairwise_plan_traversal(plan, other) is None
    # different source dataset
    other2 = P.PhysicalPlan([P.store(
        P.filter_(P.load("t2"), Col("val") > 1.0), "s")])
    assert match_bottom_up(plan, other2) is None
    assert pairwise_plan_traversal(plan, other2) is None
    # different dataset VERSION (eviction rule R4, structural form)
    other3 = P.PhysicalPlan([P.store(
        P.filter_(P.load("t", version=1), Col("val") > 1.0), "s")])
    assert match_bottom_up(plan, other3) is None


def test_repository_ordering_subsumption_first():
    """A plan that subsumes another must be scanned first."""
    small = P.PhysicalPlan([P.store(
        P.project(P.load("t"), ["key", "val"]), "a")])
    f = P.filter_(P.project(P.load("t"), ["key", "val"]),
                  Col("val") > 1.0)
    big = P.PhysicalPlan([P.store(f, "b")])
    repo = Repository()
    repo.add(make_entry(small, "art/s", bytes_in=100, bytes_out=90))
    repo.add(make_entry(big, "art/b", bytes_in=100, bytes_out=10))
    ordered = repo.ordered()
    assert ordered[0].artifact == "art/b", "subsumer (larger plan) first"
    assert repo.subsumes(ordered[0], ordered[1])


def test_eviction_rules():
    repo = Repository(keep_only_reducing=True)
    growing = make_entry(P.PhysicalPlan([P.store(
        P.distinct(P.load("t")), "x")]), "art/x",
        bytes_in=10, bytes_out=100)
    assert not repo.add(growing), "R1: growing outputs rejected"

    repo2 = Repository(keep_only_time_saving=True,
                       load_bandwidth_bytes_s=1e9)
    cheap = make_entry(P.PhysicalPlan([P.store(
        P.distinct(P.load("t")), "y")]), "art/y",
        bytes_in=100, bytes_out=50, exec_time_s=1e-12)
    assert not repo2.add(cheap), "R2: faster-to-recompute rejected"

    repo3 = Repository()
    e = make_entry(P.PhysicalPlan([P.store(
        P.distinct(P.load("t")), "z")]), "art/z",
        bytes_in=100, bytes_out=50)
    repo3.add(e)
    e.last_used = time.time() - 1000
    assert repo3.evict_unused(window_s=10) == 1, "R3: LRU window"
    assert len(repo3) == 0

    repo4 = Repository()
    e2 = make_entry(P.PhysicalPlan([P.store(
        P.distinct(P.load("t")), "w")]), "art/w",
        bytes_in=100, bytes_out=50, source_versions={"t": 0})
    repo4.add(e2)
    store = ArtifactStore()
    cat = Catalog(store)
    cat.register("t", _table())       # version 0
    assert repo4.evict_stale(cat) == 0
    cat.register("t", _table(seed=5))  # bump to version 1
    assert repo4.evict_stale(cat) == 1, "R4: modified inputs evicted"


def test_repository_persistence_roundtrip(tmp_path):
    """Repository entries (plans + stats) survive a driver restart and
    still match/rewrite — the cross-run durability the paper's 7-day
    retention story requires."""
    from repro.core.serialize import load_repository, save_repository
    from repro.workloads import pigmix
    from repro.core.restore import ReStore

    store = ArtifactStore(root=str(tmp_path / "artifacts"))
    cat = Catalog(store)
    store.put("page_views", pigmix.gen_page_views(1024))
    store.put("users", pigmix.gen_users())
    store.put("power_users", pigmix.gen_power_users())
    rs = ReStore(cat, store, heuristic="aggressive")
    rs.run_plan(pigmix.L3("sum"))
    n = len(rs.repo)
    assert n > 0
    save_repository(rs.repo, str(tmp_path / "repo.json"))

    # "restart": new process state, same storage
    store2 = ArtifactStore(root=str(tmp_path / "artifacts"))
    cat2 = Catalog(store2)
    repo2 = load_repository(str(tmp_path / "repo.json"))
    assert len(repo2) == n
    rs2 = ReStore(cat2, store2, repo2, heuristic="off")
    _, rep = rs2.run_plan(pigmix.L3("mean"))
    assert not rep.jobs[0].executed, \
        "restored repository must still answer the shared join job"
    # stats round-tripped
    assert all(e.signature and e.bytes_out >= 0 for e in repo2.entries)


def test_corrupted_entry_rejected(tmp_path):
    from repro.core.serialize import (plan_to_json, repository_from_json,
                                      repository_to_json)
    from repro.core.repository import Repository
    small = P.PhysicalPlan([P.store(
        P.project(P.load("t"), ["key", "val"]), "a")])
    repo = Repository()
    repo.add(make_entry(small, "art/s", bytes_in=10, bytes_out=5))
    text = repository_to_json(repo)
    corrupted = text.replace('"key"', '"kez"', 1)   # tamper with the plan
    repo2 = repository_from_json(corrupted)
    assert len(repo2) == 0, "signature mismatch must reject the entry"
