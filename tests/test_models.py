"""Per-architecture smoke tests (reduced configs): one forward/train step
on CPU asserting output shapes + no NaNs, plus prefill+decode consistency
with the full forward."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.api import build
from repro.models.lm import block_period, slot_kinds
from repro.train.optimizer import AdamW

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(KEY)
    batch = model.demo_batch(KEY, seq=32, gbs=2)

    total, (loss, aux) = model.loss_fn(params, batch)
    assert jnp.isfinite(total), arch
    assert loss.shape == ()

    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    new_params, opt_state, gnorm = opt.update(grads, opt_state, params)
    assert jnp.isfinite(gnorm)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(new_params)):
        assert a.shape == b.shape
        assert jnp.isfinite(b.astype(jnp.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(KEY)
    T, K, B = 12, 3, 2
    full = model.demo_batch(KEY, seq=T + K, gbs=B)

    if cfg.family == "encdec":
        from repro.models.encdec import encdec_forward
        logits_full, _ = encdec_forward(
            cfg, params, full["enc_embeds"], full["tokens"],
            full["enc_positions"], full["positions"])
    else:
        from repro.models.lm import lm_forward
        logits_full, _ = lm_forward(
            cfg, params, full.get("embeds", full.get("tokens")),
            full["positions"])

    def sl(b, s0, s1):
        out = {}
        for k2, v in b.items():
            if k2 == "labels":
                continue
            if k2 in ("enc_embeds", "enc_positions"):
                out[k2] = v
            elif k2 == "positions":
                out[k2] = v[..., s0:s1] if cfg.m_rope else v[s0:s1]
            elif v.ndim >= 2:
                out[k2] = v[:, s0:s1]
            else:
                out[k2] = v[s0:s1]
        return out

    cache = model.init_cache(B, T + K, enc_len=T + K)
    logits_p, cache = model.prefill(params, sl(full, 0, T), cache)
    errs = [float(jnp.abs(logits_p[:, -1] - logits_full[:, T - 1]).max())]
    for t in range(K):
        logits_d, cache = model.decode_step(
            params, sl(full, T + t, T + t + 1), cache, jnp.int32(T + t))
        errs.append(float(jnp.abs(logits_d[:, 0]
                                  - logits_full[:, T + t]).max()))
    assert max(errs) < 2e-3, (arch, errs)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_structure(arch):
    """Full configs: layer layout divides evenly, param count matches the
    published scale, and input_specs build for every applicable shape."""
    cfg = get_config(arch)
    model = build(cfg)
    if cfg.family != "encdec":
        assert cfg.n_layers % block_period(cfg) == 0
        kinds = slot_kinds(cfg)
        assert len(kinds) == block_period(cfg)
    n = cfg.total_params()
    expected = {"qwen3-1.7b": 1.7e9, "codeqwen1.5-7b": 7e9,
                "minicpm3-4b": 4e9, "yi-6b": 6e9,
                "qwen3-moe-235b-a22b": 235e9,
                "llama4-maverick-400b-a17b": 400e9,
                "seamless-m4t-medium": 1.2e9,   # 2x12L d1024 + 256k vocab
                "xlstm-350m": 0.35e9, "qwen2-vl-72b": 72e9,
                "jamba-1.5-large-398b": 398e9}[arch]
    assert 0.5 * expected < n < 2.0 * expected, (arch, n, expected)
    from repro.models.api import SHAPES, shape_applicable
    for shape in SHAPES:
        ok, why = shape_applicable(cfg, shape)
        if not ok:
            continue
        specs = model.input_specs(shape)
        assert specs, (arch, shape)


def test_long_500k_only_for_subquadratic():
    from repro.models.api import shape_applicable
    runs = [a for a in ARCH_IDS
            if shape_applicable(get_config(a), "long_500k")[0]]
    assert sorted(runs) == ["jamba-1.5-large-398b", "xlstm-350m"]


def test_moe_aux_loss_nonzero_and_balanced_router_low():
    cfg = get_config("qwen3-moe-235b-a22b", smoke=True)
    model = build(cfg)
    params = model.init(KEY)
    batch = model.demo_batch(KEY, seq=32, gbs=2)
    _, (_, aux) = model.loss_fn(params, batch)
    assert float(aux) > 0.0


def test_chunked_attention_matches_naive():
    """The optimized long-sequence attention path is exact."""
    import numpy as np
    from repro.models.layers import _sdpa, _sdpa_chunked
    rng = np.random.default_rng(0)
    for (b, h, sq, skv, causal, off, kvl) in [
            (2, 4, 2048, 2048, True, 0, None),
            (1, 2, 2048, 4096, True, 2048, None),
            (2, 2, 2048, 2048, False, 0, 1500)]:
        q = jnp.asarray(rng.normal(size=(b, h, sq, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, h, skv, 32)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, h, skv, 32)), jnp.float32)
        a = _sdpa(q, k, v, causal=causal, q_offset=off, kv_len=kvl)
        for unroll in (False, True):
            c = _sdpa_chunked(q, k, v, causal=causal, q_offset=off,
                              kv_len=kvl, chunk=1024, unroll=unroll)
            assert float(jnp.abs(a - c).max()) < 2e-3
