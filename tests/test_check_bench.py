"""Negative tests for the BENCH_core.json CI gate (tools/check_bench.py):
the acceptance floors must actually fail when violated — a gate that
passes everything is indistinguishable from no gate."""
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "tools"))

import check_bench  # noqa: E402


def _doc(**overrides):
    base = {
        "runs": [{
            "label": "full", "n_rows": 1 << 15, "trials": 3,
            "queries": {
                "L3": {"t_plain_s": 0.4, "t_store_s": 0.41,
                       "t_reuse_s": 0.02, "store_overhead": 1.02,
                       "reuse_speedup": 20.0},
                "L7": {"t_plain_s": 0.3, "t_store_s": 0.31,
                       "t_reuse_s": 0.29, "store_overhead": 1.03,
                       "reuse_speedup": 1.03},
            },
            "avg_store_overhead": 1.02, "avg_reuse_speedup": 10.5,
        }],
        "dist_runs": [{
            "label": "full", "n_rows": 1 << 16, "n_shards": 8,
            "arms": {}, "speedup_copart_vs_blind": 2.5,
            "mesh_vs_single": 1.2, "shuffles_skipped": 3,
        }],
        "delta_runs": [{
            "label": "full", "n_rows": 1 << 16, "trials": 1,
            "sweep": [
                {"template": "groupby", "frac": 0.10, "t_refresh_s": 0.1,
                 "t_recompute_s": 0.9, "speedup": 9.0, "identical": True},
                {"template": "join", "frac": 0.10, "t_refresh_s": 0.2,
                 "t_recompute_s": 0.8, "speedup": 4.0, "identical": True},
                {"template": "join", "frac": 0.50, "t_refresh_s": 0.6,
                 "t_recompute_s": 0.8, "speedup": 1.3, "identical": True},
            ],
        }],
        "service_runs": [{
            "label": "full", "n_rows": 1 << 15, "n_events": 48,
            "worker_sweep": [
                {"workers": 1, "goodput_per_s": 5.0, "p95_ms": 900.0},
                {"workers": 4, "goodput_per_s": 9.0, "p95_ms": 450.0},
            ],
            "goodput_scaling_4w_vs_1w": 1.8,
            "singleflight_hits": 21, "dup_executions": 0,
        }],
        "tier_runs": [{
            "label": "full", "n_rows": 1 << 16, "n_artifacts": 24,
            "probes": 120, "t_off_s": 1.9, "t_on_s": 1.3,
            "speedup_prefetch": 1.46, "prefetch_hit_rate": 0.94,
            "cold_start_s": 0.25, "identical": True,
        }],
        "prefix_runs": [{
            "label": "full", "n_requests": 48, "n_prompts": 8,
            "prefix_len": 320, "suffix_len": 16, "n_decode": 2,
            "t_noreuse_s": 8.0, "t_reuse_s": 3.0, "wall_speedup": 2.7,
            "reused_token_frac": 0.9, "p50_reuse_ms": 40.0,
            "p95_reuse_ms": 120.0, "identical": True,
        }],
        "mqo_runs": [{
            "label": "full", "n_rows": 1 << 15, "n_queries": 7,
            "n_tenants": 3, "trials": 3, "t_noreuse_s": 2.4,
            "t_sequential_s": 1.9, "t_batched_s": 1.0,
            "speedup_batched_vs_sequential": 1.9,
            "speedup_batched_vs_noreuse": 2.4,
            "shared_subplans": 3, "semantic_subplans": 1,
            "dup_executions": 0, "identical": True,
        }],
    }
    base.update(overrides)
    return base


def _run(tmp_path, doc) -> int:
    p = tmp_path / "bench.json"
    p.write_text(json.dumps(doc))
    return check_bench.check(str(p))


def test_good_doc_passes(tmp_path):
    assert _run(tmp_path, _doc()) == 0


def test_delta_floor_violation_fails(tmp_path):
    doc = _doc()
    doc["delta_runs"][0]["sweep"][0]["speedup"] = 2.0   # < 3.0 at 10%
    assert _run(tmp_path, doc) == 1


def test_delta_floor_exempts_small_and_large_fracs(tmp_path):
    doc = _doc()
    # CI smoke size: below FLOOR_MIN_ROWS, no speedup floor
    doc["delta_runs"][0]["n_rows"] = 1 << 13
    doc["delta_runs"][0]["sweep"][0]["speedup"] = 0.5
    assert _run(tmp_path, doc) == 0
    # full size but a >10% fraction: not in the floor regime
    doc = _doc()
    doc["delta_runs"][0]["sweep"][2]["speedup"] = 0.5
    assert _run(tmp_path, doc) == 0


def test_delta_bit_identity_gates_at_any_size(tmp_path):
    doc = _doc()
    doc["delta_runs"][0]["n_rows"] = 1 << 13            # even CI smoke
    doc["delta_runs"][0]["sweep"][1]["identical"] = False
    assert _run(tmp_path, doc) == 1


def test_delta_missing_field_fails(tmp_path):
    doc = _doc()
    del doc["delta_runs"][0]["sweep"]
    assert _run(tmp_path, doc) == 1


def test_copart_floor_violation_fails(tmp_path):
    doc = _doc()
    doc["dist_runs"][0]["speedup_copart_vs_blind"] = 1.2
    assert _run(tmp_path, doc) == 1


def test_same_label_regression_fails(tmp_path):
    doc = _doc()
    second = json.loads(json.dumps(doc["delta_runs"][0]))
    for pt in second["sweep"]:
        pt["speedup"] = pt["speedup"] * 0.5             # >20% drop
    second["sweep"][0]["speedup"] = 3.5                 # still above floor
    second["sweep"][1]["speedup"] = 3.1
    doc["delta_runs"].append(second)
    assert _run(tmp_path, doc) == 1


# ------------------------------------------------ service_runs (ISSUE 6)


def test_service_scaling_floor_violation_fails(tmp_path):
    doc = _doc()
    doc["service_runs"][0]["goodput_scaling_4w_vs_1w"] = 1.2
    assert _run(tmp_path, doc) == 1


def test_service_scaling_floor_exempts_small_sizes(tmp_path):
    doc = _doc()
    doc["service_runs"][0]["n_rows"] = 1 << 12          # CI smoke size
    doc["service_runs"][0]["goodput_scaling_4w_vs_1w"] = 1.0
    assert _run(tmp_path, doc) == 0


def test_service_dup_executions_gate_at_any_size(tmp_path):
    doc = _doc()
    doc["service_runs"][0]["n_rows"] = 1 << 12          # even CI smoke
    doc["service_runs"][0]["dup_executions"] = 1
    assert _run(tmp_path, doc) == 1


def test_service_requires_singleflight_coverage(tmp_path):
    doc = _doc()
    doc["service_runs"][0]["singleflight_hits"] = 0
    assert _run(tmp_path, doc) == 1


def test_service_missing_field_fails(tmp_path):
    doc = _doc()
    del doc["service_runs"][0]["worker_sweep"]
    assert _run(tmp_path, doc) == 1


def test_service_same_label_regression_fails(tmp_path):
    doc = _doc()
    doc["service_runs"][0]["goodput_scaling_4w_vs_1w"] = 2.5
    second = json.loads(json.dumps(doc["service_runs"][0]))
    second["goodput_scaling_4w_vs_1w"] = 1.8            # above floor,
    doc["service_runs"].append(second)                  # but a >20% drop
    assert _run(tmp_path, doc) == 1


def test_mesh_vs_single_floor_violation_fails(tmp_path):
    doc = _doc()
    doc["dist_runs"][0]["mesh_vs_single"] = 0.48   # the pre-PR7 regime
    assert _run(tmp_path, doc) == 1


def test_mesh_vs_single_floor_exempts_small_sizes(tmp_path):
    doc = _doc()
    doc["dist_runs"][0]["n_rows"] = 1 << 12        # CI smoke size
    doc["dist_runs"][0]["mesh_vs_single"] = 0.48
    assert _run(tmp_path, doc) == 0


def test_mesh_vs_single_missing_field_fails(tmp_path):
    doc = _doc()
    del doc["dist_runs"][0]["mesh_vs_single"]
    assert _run(tmp_path, doc) == 1


def test_query_reuse_floor_violation_fails(tmp_path):
    doc = _doc()
    doc["runs"][0]["queries"]["L7"]["reuse_speedup"] = 0.60  # the L7 bug
    assert _run(tmp_path, doc) == 1


def test_query_reuse_floor_tolerates_noise_at_unity(tmp_path):
    doc = _doc()
    # a declined splice re-executes: speedup 1.0 by construction, and
    # timing noise may put the measured ratio a hair under
    doc["runs"][0]["queries"]["L7"]["reuse_speedup"] = 0.97
    assert _run(tmp_path, doc) == 0


def test_query_reuse_floor_exempts_small_sizes(tmp_path):
    doc = _doc()
    doc["runs"][0]["n_rows"] = 1 << 12
    doc["runs"][0]["queries"]["L7"]["reuse_speedup"] = 0.60
    assert _run(tmp_path, doc) == 0


# --------------------------------------------------- tier_runs (ISSUE 8)


def test_tier_prefetch_floor_violation_fails(tmp_path):
    doc = _doc()
    doc["tier_runs"][0]["speedup_prefetch"] = 1.1       # < 1.3 at full
    assert _run(tmp_path, doc) == 1


def test_tier_prefetch_floor_exempts_small_sizes(tmp_path):
    doc = _doc()
    doc["tier_runs"][0]["n_rows"] = 1 << 12             # CI smoke size
    doc["tier_runs"][0]["speedup_prefetch"] = 1.1
    assert _run(tmp_path, doc) == 0


def test_tier_bit_identity_gates_at_any_size(tmp_path):
    doc = _doc()
    doc["tier_runs"][0]["n_rows"] = 1 << 12             # even CI smoke
    doc["tier_runs"][0]["identical"] = False
    assert _run(tmp_path, doc) == 1


def test_tier_cold_start_must_complete(tmp_path):
    doc = _doc()
    doc["tier_runs"][0]["cold_start_s"] = None
    assert _run(tmp_path, doc) == 1


def test_tier_missing_field_fails(tmp_path):
    doc = _doc()
    del doc["tier_runs"][0]["prefetch_hit_rate"]
    assert _run(tmp_path, doc) == 1


def test_tier_same_label_regression_fails(tmp_path):
    doc = _doc()
    second = json.loads(json.dumps(doc["tier_runs"][0]))
    doc["tier_runs"][0]["speedup_prefetch"] = 2.5
    second["speedup_prefetch"] = 1.5                    # above floor,
    doc["tier_runs"].append(second)                     # but a >20% drop
    assert _run(tmp_path, doc) == 1


# ------------------------------------------------ prefix_runs (ISSUE 10)


def test_prefix_speedup_floor_violation_fails(tmp_path):
    doc = _doc()
    doc["prefix_runs"][0]["wall_speedup"] = 1.6         # < 2.0 at full
    assert _run(tmp_path, doc) == 1


def test_prefix_floor_exempts_small_sizes(tmp_path):
    doc = _doc()
    doc["prefix_runs"][0]["n_requests"] = 6             # CI smoke size
    doc["prefix_runs"][0]["wall_speedup"] = 1.1
    assert _run(tmp_path, doc) == 0
    doc = _doc()
    doc["prefix_runs"][0]["prefix_len"] = 96            # short prefixes
    doc["prefix_runs"][0]["wall_speedup"] = 1.1
    assert _run(tmp_path, doc) == 0


def test_prefix_bit_identity_gates_at_any_size(tmp_path):
    doc = _doc()
    doc["prefix_runs"][0]["n_requests"] = 6             # even CI smoke
    doc["prefix_runs"][0]["identical"] = False
    assert _run(tmp_path, doc) == 1


def test_prefix_reused_fraction_floor_fails(tmp_path):
    doc = _doc()
    doc["prefix_runs"][0]["reused_token_frac"] = 0.3    # < 0.5 at full
    assert _run(tmp_path, doc) == 1


def test_prefix_missing_field_fails(tmp_path):
    doc = _doc()
    del doc["prefix_runs"][0]["p95_reuse_ms"]
    assert _run(tmp_path, doc) == 1


def test_prefix_same_label_regression_fails(tmp_path):
    doc = _doc()
    second = json.loads(json.dumps(doc["prefix_runs"][0]))
    doc["prefix_runs"][0]["wall_speedup"] = 4.0
    second["wall_speedup"] = 2.5                        # above floor,
    doc["prefix_runs"].append(second)                   # but a >20% drop
    assert _run(tmp_path, doc) == 1


# ---------------------------------------------------- mqo_runs (ISSUE 9)


def test_mqo_speedup_floor_violation_fails(tmp_path):
    doc = _doc()
    doc["mqo_runs"][0]["speedup_batched_vs_sequential"] = 1.2  # < 1.5
    assert _run(tmp_path, doc) == 1


def test_mqo_speedup_floor_exempts_small_sizes(tmp_path):
    doc = _doc()
    doc["mqo_runs"][0]["n_rows"] = 1 << 12              # CI smoke size
    doc["mqo_runs"][0]["speedup_batched_vs_sequential"] = 1.2
    assert _run(tmp_path, doc) == 0


def test_mqo_bit_identity_gates_at_any_size(tmp_path):
    doc = _doc()
    doc["mqo_runs"][0]["n_rows"] = 1 << 12              # even CI smoke
    doc["mqo_runs"][0]["identical"] = False
    assert _run(tmp_path, doc) == 1


def test_mqo_dup_executions_gate_at_any_size(tmp_path):
    doc = _doc()
    doc["mqo_runs"][0]["n_rows"] = 1 << 12              # even CI smoke
    doc["mqo_runs"][0]["dup_executions"] = 2
    assert _run(tmp_path, doc) == 1


def test_mqo_requires_shared_subplans(tmp_path):
    doc = _doc()
    doc["mqo_runs"][0]["shared_subplans"] = 0
    assert _run(tmp_path, doc) == 1


def test_mqo_missing_field_fails(tmp_path):
    doc = _doc()
    del doc["mqo_runs"][0]["t_batched_s"]
    assert _run(tmp_path, doc) == 1


def test_mqo_same_label_regression_fails(tmp_path):
    doc = _doc()
    second = json.loads(json.dumps(doc["mqo_runs"][0]))
    doc["mqo_runs"][0]["speedup_batched_vs_sequential"] = 2.5
    second["speedup_batched_vs_sequential"] = 1.8       # above floor,
    doc["mqo_runs"].append(second)                      # but a >20% drop
    assert _run(tmp_path, doc) == 1
