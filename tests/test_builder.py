"""Pig-style builder DSL (DESIGN.md §16): the front-end must be a pure
notation change — plans built through ``dataflow.builder`` must be
fingerprint-identical to hand-built ``core.plan`` wiring (fingerprints
are the reuse currency: repository keys, singleflight keys, MQO sharing
keys), and execute to bit-identical results."""
import numpy as np
import pytest

from repro.core import plan as P
from repro.core.restore import ReStore
from repro.dataflow.builder import Dataflow, as_plan, col
from repro.dataflow.expr import Col
from repro.dataflow.physical import execute_plan
from repro.store.artifacts import ArtifactStore, Catalog
from repro.workloads import pigmix

N_ROWS = 512


def _fps(plan):
    return set(plan.fingerprints().values())


# --------------------------------------------------- PigMix equivalence


@pytest.mark.parametrize("name", sorted(pigmix.QUERIES))
def test_pigmix_dsl_matches_legacy(name):
    assert _fps(pigmix.QUERIES[name]()) == _fps(pigmix.LEGACY[name]())


def test_pigmix_parametrized_variants_match_legacy():
    for agg in ("sum", "mean", "count"):
        assert _fps(pigmix.L3(agg)) == _fps(pigmix._legacy_L3(agg))
    for second in ("power_users", "users"):
        assert _fps(pigmix.L11(second)) == _fps(pigmix._legacy_L11(second))
    for n in (2, 3, 5):
        assert _fps(pigmix.QP(n)) == _fps(pigmix._legacy_QP(n))
    for field in sorted(pigmix.FILTER_FIELDS):
        assert _fps(pigmix.QF(field)) == _fps(pigmix._legacy_QF(field))


def test_pigmix_dsl_signature_matches_legacy():
    # fingerprint identity must extend to the signature the repository
    # and the service singleflight key by
    for name in sorted(pigmix.QUERIES):
        assert (P.plan_signature(pigmix.QUERIES[name]())
                == P.plan_signature(pigmix.LEGACY[name]()))


# --------------------------------------- random-program property sweep

NUMERIC = ["action", "timespent", "timestamp"]


def _random_pair(rng):
    """One random builder program and its hand-built twin."""
    flow = Dataflow.load("page_views")
    op = P.load("page_views")
    cur = ["user", "action", "timespent", "timestamp"]
    for _ in range(int(rng.integers(1, 4))):
        kind = rng.choice(["filter", "project", "distinct", "foreach"])
        numeric = [c for c in cur if c in NUMERIC]
        if kind == "filter" and numeric:
            c = str(rng.choice(numeric))
            thr = int(rng.integers(0, 50))
            flow = flow.filter(col(c) > thr)
            op = P.filter_(op, Col(c) > thr)
        elif kind == "project" and len(cur) > 1:
            k = int(rng.integers(1, len(cur)))
            sel = sorted(rng.choice(cur, size=k, replace=False).tolist())
            flow = flow.project(*sel)
            op = P.project(op, sel)
            cur = sel
        elif kind == "foreach" and numeric:
            c = str(rng.choice(numeric))
            gens = {"k": Col(c) * 2, "v": Col(c)}
            flow = flow.foreach(k=col(c) * 2, v=col(c))
            op = P.foreach(op, gens)
            cur = ["k", "v"]
        else:
            flow = flow.distinct()
            op = P.distinct(op)
    numeric = [c for c in cur if c in NUMERIC or c in ("k", "v")]
    if rng.random() < 0.5 and numeric:
        key = cur[0]
        val = numeric[-1]
        flow = flow.group_by(key, n=("count", val))
        op = P.groupby(op, [key], {"n": ("count", val)})
    return (flow.store("out").build(),
            P.PhysicalPlan([P.store(op, "out")]))


def test_random_programs_fingerprint_identical():
    # seeded always-on sweep (no hypothesis in the container)
    for seed in range(60):
        rng = np.random.default_rng(seed)
        built, hand = _random_pair(rng)
        assert _fps(built) == _fps(hand), f"seed {seed}"
        assert P.plan_signature(built) == P.plan_signature(hand)


def test_random_programs_execute_bit_identical():
    datasets = {"page_views": pigmix.gen_page_views(N_ROWS)}
    for seed in range(8):
        rng = np.random.default_rng(seed)
        built, hand = _random_pair(rng)
        out_b, _ = execute_plan(built, datasets)
        out_h, _ = execute_plan(hand, datasets)
        assert set(out_b) == set(out_h)
        for k in out_b:
            a, b = out_b[k].to_numpy(), out_h[k].to_numpy()
            assert set(a) == set(b)
            for c in a:
                assert np.array_equal(a[c], b[c]), (seed, k, c)


# ------------------------------------------------- DSL surface details


def test_dag_fanout_shares_the_operator():
    scan = Dataflow.load("page_views").project("user", "timespent")
    plan = (scan.group_by("user", t=("sum", "timespent")).store("a")
            .build(scan.distinct().store("b")))
    assert len(plan.sinks) == 2
    # one physical PROJECT feeds both sinks
    assert sum(1 for o in plan.topo() if o.kind == "PROJECT") == 1


def test_build_without_store_raises():
    with pytest.raises(ValueError, match="store"):
        Dataflow.load("page_views").distinct().build()


def test_group_by_rejects_bad_agg():
    with pytest.raises(ValueError, match="agg fn"):
        Dataflow.load("x").group_by("u", n=("median", "v"))
    with pytest.raises(TypeError, match="tuple"):
        Dataflow.load("x").group_by("u", n="count")


def test_join_key_validation():
    a, b = Dataflow.load("x"), Dataflow.load("y")
    with pytest.raises(TypeError, match="key columns"):
        a.join(b)
    with pytest.raises(TypeError, match="not both"):
        a.join(b, on="k", left_on="k", right_on="k")
    j = a.join(b, on="k")
    assert j.op.params["left_keys"] == ("k",)
    assert j.op.params["right_keys"] == ("k",)


def test_filter_rejects_non_expr():
    with pytest.raises(TypeError, match="Expr"):
        Dataflow.load("x").filter(True)


def test_as_plan_coercion():
    plan = pigmix.L2()
    assert as_plan(plan) is plan
    flow = Dataflow.load("page_views").project("user").store("o")
    assert _fps(as_plan(flow)) == _fps(flow.build())
    with pytest.raises(TypeError):
        as_plan("not a plan")


# -------------------------------------------- unified submission surface


def _driver(n_rows=N_ROWS, **kw):
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=n_rows)
    return ReStore(cat, store, **kw)


def test_restore_run_accepts_builder_and_plan():
    rs = _driver()
    flow = (Dataflow.load("page_views").project("user", "timespent")
            .group_by("user", t=("sum", "timespent")).store("o"))
    out_flow, _ = rs.run(flow)
    cold = _driver()
    out_plan, _ = cold.run(flow.build())
    a, b = out_flow["o"].to_numpy(), out_plan["o"].to_numpy()
    for c in a:
        assert np.array_equal(a[c], b[c])


def test_run_plan_alias_still_works():
    rs = _driver()
    res, rep = rs.run_plan(pigmix.L2())
    assert "L2_out" in res and rep.n_executed >= 1
