"""Tiered artifact store (DESIGN.md §15): the device → host → disk →
remote hierarchy, the single-authoritative-tier invariant, bit-exact
promotion/demotion round-trips (including the cold-tier columnar
codec), crash windows inside a demotion, the remote object store's
batched operations, and the speculative prefetcher's signal mining.
"""
import os
import zlib

import numpy as np
import pytest

from repro.dataflow.table import Table
from repro.service.faults import FaultInjector, FaultSchedule
from repro.store.artifacts import (ArtifactStore, CorruptArtifactError,
                                   SimulatedCrash)
from repro.store.prefetch import SpeculativePrefetcher
from repro.store.tiers import (HostCache, RemoteObjectStore,
                               decode_artifact_blob, encode_artifact_blob,
                               verify_blob)
from repro.train.compression import decode_array, encode_array

DTYPES = (np.int32, np.int64, np.uint8, np.float32, np.float64)


def _table(n=64, seed=0):
    rng = np.random.default_rng(seed)
    cols = {f"c_{dt.__name__}": rng.integers(0, 100, n).astype(dt)
            for dt in DTYPES}
    return Table.from_numpy(cols)


def _crc(t: Table) -> int:
    d = t.to_numpy()
    acc = 0
    for c in sorted(d):
        acc = zlib.crc32(np.ascontiguousarray(d[c]).tobytes(),
                         zlib.crc32(c.encode(), acc))
    return acc


def _tiered_store(tmp_path, latency_s=0.0, **kw):
    remote = RemoteObjectStore(str(tmp_path / "remote"),
                               latency_s=latency_s)
    return ArtifactStore(root=str(tmp_path / "store"), remote=remote,
                         write_behind=False, **kw), remote


# ----------------------------------------------------- lossless codec


@pytest.mark.parametrize("dt", DTYPES)
def test_codec_roundtrip_bit_exact(dt):
    rng = np.random.default_rng(3)
    a = rng.integers(0, 255, 1000).astype(dt)
    b = decode_array(encode_array(a))
    assert b.dtype == a.dtype and np.array_equal(a, b)


def test_codec_roundtrip_empty_and_noncontiguous():
    assert decode_array(encode_array(np.empty(0, np.float32))).size == 0
    a = np.arange(100, dtype=np.int64)[::2]          # non-contiguous view
    assert np.array_equal(decode_array(encode_array(a)), a)


def test_blob_roundtrip_and_corruption_detected():
    manifest = {"name": "x", "nbytes": 123}
    files = {"data.npz": {"a": np.arange(256, dtype=np.int64),
                          "__valid__": np.ones(256, dtype=bool)}}
    blob = encode_artifact_blob(manifest, files)
    m2, f2 = decode_artifact_blob(blob)
    assert m2 == manifest
    assert np.array_equal(f2["data.npz"]["a"], files["data.npz"]["a"])
    assert verify_blob(blob)
    # flip one payload byte -> checksum mismatch
    body = bytearray(blob)
    body[-10] ^= 0xFF
    with pytest.raises(ValueError):
        decode_artifact_blob(bytes(body))
    # truncate -> structural damage
    with pytest.raises(ValueError):
        decode_artifact_blob(blob[:len(blob) - 7])
    assert not verify_blob(blob[:8])


# ------------------------------------------------------- host tier LRU


def test_host_cache_lru_eviction_and_accounting():
    h = HostCache(max_bytes=3000)
    pay = lambda i: {"a": np.full(100, i, dtype=np.int64)}  # 800 B each
    for i in range(4):
        h.put(f"p{i}", pay(i))
    assert "p0" not in h and "p1" in h            # oldest evicted first
    assert h.total_bytes == h.recount() <= 3000
    h.get("p1")                                    # touch: now most recent
    h.put("p4", pay(4))
    assert "p1" in h and "p2" not in h
    # overwrite replaces, never double-counts
    h.put("p4", pay(5))
    assert h.total_bytes == h.recount()
    # oversized payloads are not cacheable and never corrupt the ledger
    h.put("huge", {"a": np.zeros(1000, dtype=np.int64)})
    assert "huge" not in h
    assert h.total_bytes == h.recount()


# ------------------------------------------------ remote object store


def test_remote_batched_ops_charge_one_request(tmp_path):
    r = RemoteObjectStore(str(tmp_path))
    blobs = {f"k{i}": encode_artifact_blob(
        {"name": f"k{i}"}, {"d": {"a": np.arange(i + 1, dtype=np.int32)}})
        for i in range(5)}
    for k, b in blobs.items():
        r.put_object(k, b)
    base = r.stats["requests"]
    got = r.get_many(list(blobs) + ["missing"])
    assert r.stats["requests"] == base + 1        # ONE round-trip
    assert sorted(got) == sorted(blobs)
    assert all(got[k] == blobs[k] for k in blobs)
    heads = r.head_many(list(blobs))
    assert r.stats["requests"] == base + 2
    assert all(heads[k]["manifest"]["name"] == k for k in blobs)
    with pytest.raises(KeyError):
        r.get_object("missing")
    assert r.keys() == sorted(blobs)
    # orphaned tmp uploads (a killed demotion) are reaped, not listed
    open(os.path.join(str(tmp_path), ".tmp-orphan"), "wb").close()
    assert r.keys() == sorted(blobs)
    assert r.gc_tmp() == 1


# ------------------------------------------- residency / authoritative


def test_residency_ladder_and_single_authoritative_tier(tmp_path):
    s, remote = _tiered_store(tmp_path, host_bytes=1 << 20)
    t = _table(seed=1)
    ref = _crc(t)
    s.put("a", t)
    assert s.residency("a") == "device"
    assert s.authoritative_tier("a") == "disk"     # write-through
    s.demote_to_remote("a")
    assert s.authoritative_tier("a") == "remote"
    assert not os.path.exists(os.path.join(s._path("a"), "manifest.json"))
    assert s.residency("a") == "device"            # cache copy still valid
    s.cache.drop("a")
    s.host.drop("a")
    assert s.residency("a") == "remote"
    assert _crc(s.get("a")) == ref                 # cold remote read
    s.promote_from_remote("a")
    assert s.authoritative_tier("a") == "disk"
    assert not remote.exists(s._remote_key("a"))   # exactly one owner
    assert _crc(s.get("a")) == ref
    s.close()


def test_promote_demote_promote_bit_identical(tmp_path):
    """Two full round-trips through the compressed remote tier must be
    bit-exact for every column dtype."""
    s, _ = _tiered_store(tmp_path)
    t = _table(n=500, seed=2)
    ref = _crc(t)
    s.put("a", t)
    for _ in range(2):
        s.demote_to_remote("a")
        s.cache.drop("a")
        got = s.get("a")                           # serves from remote
        assert _crc(got) == ref
        s.promote_from_remote("a")
        s.cache.drop("a")
        assert _crc(s.get("a")) == ref             # serves from disk
    s.close()


def test_partitioned_artifact_survives_remote_roundtrip(tmp_path):
    s, _ = _tiered_store(tmp_path)
    t = _table(n=240, seed=3)
    s.put("base", t)
    tp, _part = s.get_partitioned("base", ["c_int32"], 4)
    s.put("a", tp, partitioning={"keys": ["c_int32"], "n_parts": 4})
    ref = _crc(s.get("a"))
    s.demote_to_remote("a")
    s.cache.drop("a")
    s.drop_caches()
    assert _crc(s.get("a")) == ref
    s.promote_from_remote("a")
    assert s.partitioning("a")["n_parts"] == 4     # property survives
    s.close()


def test_random_population_has_exactly_one_durable_owner(tmp_path):
    """Property sweep: random sizes and random demotion choices — after
    any sequence, every artifact has exactly one durable tier and reads
    bit-identically from it."""
    rng = np.random.default_rng(7)
    s, remote = _tiered_store(tmp_path, host_bytes=1 << 18,
                              cache_bytes=1 << 18)
    refs = {}
    for i in range(12):
        t = _table(n=int(rng.integers(16, 400)), seed=100 + i)
        s.put(f"art{i}", t)
        refs[f"art{i}"] = _crc(t)
    demoted = [n for n in refs if rng.random() < 0.5]
    for n in demoted:
        s.demote_to_remote(n)
    s.drop_caches()
    for n, ref in refs.items():
        tier = s.authoritative_tier(n)
        assert tier == ("remote" if n in demoted else "disk"), n
        on_disk = os.path.exists(os.path.join(s._path(n), "manifest.json"))
        on_remote = remote.exists(s._remote_key(n))
        assert on_disk != on_remote, f"{n}: not exactly one durable copy"
        assert _crc(s.get(n)) == ref, n
    s.close()


def test_device_eviction_demotes_to_host_and_serves_back(tmp_path):
    t = _table(n=256, seed=4)
    nb = t.nbytes()
    s = ArtifactStore(root=str(tmp_path / "store"), cache_bytes=2 * nb,
                      host_bytes=16 * nb, write_behind=False)
    names = [f"a{i}" for i in range(4)]
    refs = {}
    for i, n in enumerate(names):
        tt = _table(n=256, seed=10 + i)
        refs[n] = _crc(tt)
        s.put(n, tt)
    assert s.residency("a0") == "host"             # squeezed out of device
    before = dict(s.io_stats())
    assert _crc(s.get("a0")) == refs["a0"]
    after = s.io_stats()
    assert after["hostload_bytes"] > before["hostload_bytes"], \
        "host-served read must be sampled under its own tier tag"
    assert s.residency("a0") == "device"           # promoted back up
    s.close()


def test_corrupt_remote_blob_raises_corrupt_error(tmp_path):
    s, remote = _tiered_store(tmp_path)
    s.put("a", _table(seed=5))
    s.demote_to_remote("a")
    s.drop_caches()
    p = remote.path(s._remote_key("a"))
    with open(p, "r+b") as f:                      # flip a payload byte
        f.seek(-5, os.SEEK_END)
        b = f.read(1)
        f.seek(-5, os.SEEK_END)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(CorruptArtifactError):
        s.get("a")
    s.close()


def test_prewarm_batches_remote_and_fills_device(tmp_path):
    s, remote = _tiered_store(tmp_path)
    refs = {}
    for i in range(3):
        t = _table(seed=20 + i)
        refs[f"a{i}"] = _crc(t)
        s.put(f"a{i}", t)
        s.demote_to_remote(f"a{i}")
    s.drop_caches()
    base = remote.stats["requests"]
    warmed = s.prewarm(list(refs) + ["missing"])
    assert sorted(warmed) == sorted(refs)
    assert remote.stats["requests"] == base + 1    # ONE batched fetch
    for n in refs:
        assert s.residency(n) == "device"
        assert s.authoritative_tier(n) == "remote"  # warm, not migrate
        assert _crc(s.get(n)) == refs[n]
    s.close()


# --------------------------------------------- crash windows (ISSUE 8)


def _armed_injector(point):
    inj = FaultInjector(FaultSchedule(seed=0, rates={}, max_faults=0))
    inj.arm(point)
    return inj


def test_crash_before_remote_upload_leaves_disk_authoritative(tmp_path):
    remote = RemoteObjectStore(str(tmp_path / "remote"))
    inj = _armed_injector("remote_write")
    s = ArtifactStore(root=str(tmp_path / "store"), remote=remote,
                      write_behind=False, fault_injector=inj)
    t = _table(seed=6)
    ref = _crc(t)
    s.put("a", t)
    with pytest.raises(SimulatedCrash):
        s.demote_to_remote("a")
    # reopen: the upload never happened, disk still owns the bytes
    s2 = ArtifactStore(root=str(tmp_path / "store"), remote=remote,
                       write_behind=False)
    assert s2.authoritative_tier("a") == "disk"
    assert not remote.exists(s2._remote_key("a"))
    assert _crc(s2.get("a")) == ref
    s2.close()


def test_crash_after_remote_publish_reconciles_to_remote(tmp_path):
    """The satellite contract: a kill AFTER the remote publish but
    BEFORE the local delete leaves both copies; reopen must resolve to
    the LOWER tier (verified remote wins) with the bytes intact."""
    remote = RemoteObjectStore(str(tmp_path / "remote"))
    inj = _armed_injector("remote_published")
    s = ArtifactStore(root=str(tmp_path / "store"), remote=remote,
                      write_behind=False, fault_injector=inj)
    t = _table(seed=7)
    ref = _crc(t)
    s.put("a", t)
    with pytest.raises(SimulatedCrash):
        s.demote_to_remote("a")
    # mid-crash state: both durable copies exist
    assert os.path.exists(os.path.join(s._path("a"), "manifest.json"))
    assert remote.exists(s._remote_key("a"))

    s2 = ArtifactStore(root=str(tmp_path / "store"), remote=remote,
                       write_behind=False)
    assert s2.stats["remote_reconciled"] == 1
    assert s2.authoritative_tier("a") == "remote"
    assert not os.path.exists(os.path.join(s2._path("a"), "manifest.json"))
    assert _crc(s2.get("a")) == ref
    s2.close()


def test_torn_remote_blob_on_reopen_keeps_disk_copy(tmp_path):
    """The dual of verified-remote-wins: an UNVERIFIABLE remote blob is
    torn-upload garbage — reopen deletes it and the disk copy stays
    authoritative."""
    remote = RemoteObjectStore(str(tmp_path / "remote"))
    inj = _armed_injector("remote_published")
    s = ArtifactStore(root=str(tmp_path / "store"), remote=remote,
                      write_behind=False, fault_injector=inj)
    t = _table(seed=8)
    ref = _crc(t)
    s.put("a", t)
    with pytest.raises(SimulatedCrash):
        s.demote_to_remote("a")
    p = remote.path(s._remote_key("a"))
    with open(p, "r+b") as f:
        f.truncate(os.path.getsize(p) // 2)        # torn upload
    s2 = ArtifactStore(root=str(tmp_path / "store"), remote=remote,
                       write_behind=False)
    assert s2.authoritative_tier("a") == "disk"
    assert not remote.exists(s2._remote_key("a"))
    assert _crc(s2.get("a")) == ref
    s2.close()


def test_fault_points_cover_remote_reads(tmp_path):
    s, remote = _tiered_store(tmp_path)
    s.put("a", _table(seed=9))
    s.demote_to_remote("a")
    s.drop_caches()
    s.fault_injector = _armed_injector("remote_read")
    with pytest.raises(SimulatedCrash):
        s.get("a")
    s.fault_injector = None
    assert s.get("a") is not None                  # recoverable afterwards
    s.close()


# ------------------------------------------------ speculative prefetch


class _LogOnlyStore:
    """Minimal store stub: a read_log plus a prewarm that records."""

    def __init__(self):
        import collections
        self.read_log = collections.deque()
        self.prewarmed = []

    def prewarm(self, names):
        self.prewarmed.append(list(names))
        return list(names)


def test_prefetcher_ranks_by_decayed_popularity():
    st = _LogOnlyStore()
    pf = SpeculativePrefetcher(st, k=2, decay=0.5)
    for name in ["a", "a", "b", "a", "c", "a"]:
        st.read_log.append((name, "disk"))
    pf.poll()
    assert pf.predict()[0] == "a"
    # drift: a goes quiet, c dominates -> decay forgets a
    for _ in range(10):
        st.read_log.append(("c", "disk"))
    pf.poll()
    assert pf.predict()[0] == "c"
    assert pf.observed == 16


def test_prefetcher_accounts_hits_against_warmed_set():
    st = _LogOnlyStore()
    pf = SpeculativePrefetcher(st, k=1)
    st.read_log.append(("hot", "disk"))
    assert pf.prefetch() == ["hot"]
    assert pf.prefetched == 1
    st.read_log.append(("hot", "device"))          # prediction came true
    pf.poll()
    assert pf.hits == 1 and pf.hit_rate == 1.0
    # an unprobed warm entry counts against precision
    pf.prefetch()
    assert pf.hit_rate == pytest.approx(0.5)


def test_observe_append_refreshes_hot_set_ahead_of_arrival():
    st = _LogOnlyStore()
    calls = []

    def maintainer(names):
        calls.append(set(names))
        return {"refreshed": len(names)}

    pf = SpeculativePrefetcher(st, k=2, maintainer=maintainer)
    for name in ["x", "x", "y"]:
        st.read_log.append((name, "disk"))
    pf.observe_append("ds")
    assert calls == [{"x", "y"}]
    assert pf.refreshed_ahead == 2
    assert st.prewarmed[-1] == ["x", "y"]          # re-warmed after refresh
    # cadence EWMA needs two appends for a gap
    pf.observe_append("ds")
    assert pf.appends == 2 and pf.append_gap is not None
    st_stats = pf.stats()
    assert st_stats["appends"] == 2
    assert st_stats["predictions"][0] == "x"


def test_observe_append_tolerates_maintainer_failure():
    st = _LogOnlyStore()

    def broken(names):
        raise RuntimeError("refresh blew up")

    pf = SpeculativePrefetcher(st, k=1, maintainer=broken)
    st.read_log.append(("x", "disk"))
    assert pf.observe_append("ds") == {}           # swallowed, not fatal
    assert pf.refreshed_ahead == 0
    assert st.prewarmed                            # warming still happened
