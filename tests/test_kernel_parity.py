"""Kernel parity wall (ISSUE 7): every ``kernels/*/ops.py`` entry point
is property-tested against its ``ref.py`` oracle.

Routing/compaction kernels (partition, scatter_slots, probe, compact,
segment_sum over integer-valued data) must be BIT-identical between the
Pallas path (interpret mode on CPU) and the reference: the exchange and
the store's partition layout both assume the two agree on row placement.
flash_attention reorders float accumulation by construction, so it gets
a tight tolerance instead.

Shapes are property-driven: hypothesis when installed, and an always-on
seeded-PRNG sweep otherwise (the CI image does not ship hypothesis), so
the same generators run either way.  Cases cover non-tile-multiple and
sub-tile sizes, empty/all-invalid rows, hash-tie-heavy keys (constant
and few-distinct hashes force bucket overflow and probe ties), and
float32/int32 payloads.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.filter_project.ops import compact
from repro.kernels.flash_attention.ops import mha
from repro.kernels.hash_join.ops import probe
from repro.kernels.radix_partition.ops import partition, scatter_slots
from repro.kernels.segment_reduce.ops import segment_sum

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# non-tile-multiple, sub-tile, exact-tile and straddling sizes
SIZES = [1, 7, 127, 128, 129, 333, 1024]
TILES = [128, 256]


def _hashes(rng, n, ties: str):
    """uint32 hash lanes: uniform, few-distinct (tie-heavy), constant."""
    if ties == "uniform":
        h = rng.integers(0, 1 << 32, n, dtype=np.uint32)
    elif ties == "few":
        pool = rng.integers(0, 1 << 32, max(1, n // 8) or 1,
                            dtype=np.uint32)
        h = pool[rng.integers(0, len(pool), n)]
    else:                                   # "const": every key ties
        h = np.full(n, np.uint32(0xDEADBEEF))
    return jnp.asarray(h)


def _valid(rng, n, mode: str):
    if mode == "none":                      # all-invalid rows
        v = np.zeros(n, bool)
    elif mode == "all":
        v = np.ones(n, bool)
    else:
        v = rng.random(n) < 0.7
    return jnp.asarray(v)


# ------------------------------------------------------------ checkers


def check_partition(seed, n, tile, ties, vmode, n_parts=8):
    rng = np.random.default_rng(seed)
    h, v = _hashes(rng, n, ties), _valid(rng, n, vmode)
    pid_p, hist_p = partition(h, v, n_parts=n_parts, impl="pallas",
                              tile_n=tile)
    pid_r, hist_r = partition(h, v, n_parts=n_parts, impl="ref",
                              tile_n=tile)
    np.testing.assert_array_equal(np.asarray(pid_p), np.asarray(pid_r))
    # hist is per-TILE: the pallas path pads to a tile multiple while the
    # ref clamps the tile, so tile counts differ on ragged sizes — the
    # shared contract is the per-partition totals (and exact per-tile
    # equality whenever the shapes agree)
    hp, hr = np.asarray(hist_p), np.asarray(hist_r)
    np.testing.assert_array_equal(hp.sum(axis=0), hr.sum(axis=0))
    if hp.shape == hr.shape:
        np.testing.assert_array_equal(hp, hr)


def check_scatter(seed, n, tile, ties, vmode, n_parts=8):
    rng = np.random.default_rng(seed)
    h, v = _hashes(rng, n, ties), _valid(rng, n, vmode)
    # small bucket so tie-heavy hashes overflow; large enough that
    # uniform cases mostly fit
    bucket = max(2, (n // n_parts) + 2)
    s_p, ovf_p = scatter_slots(h, v, n_parts=n_parts, bucket=bucket,
                               impl="pallas", tile_n=tile)
    s_r, ovf_r = scatter_slots(h, v, n_parts=n_parts, bucket=bucket,
                               impl="ref", tile_n=tile)
    np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_r))
    assert int(ovf_p) == int(ovf_r)
    # contract: valid kept rows land in their partition's bucket range,
    # dropped rows on the overflow slot, invalid rows never kept
    s, vm = np.asarray(s_r), np.asarray(v)
    keep = s < n_parts * bucket
    assert not np.any(keep & ~vm)
    pid = np.asarray(partition(h, v, n_parts=n_parts, impl="ref")[0])
    assert np.array_equal(s[keep] // bucket, pid[keep])


def check_compact(seed, n, tile, vmode, dtype):
    rng = np.random.default_rng(seed)
    d = int(rng.integers(1, 5))
    vals = rng.integers(-100, 100, (n, d)).astype(dtype)
    m = _valid(rng, n, vmode)
    out_p, tot_p = compact(jnp.asarray(vals), m, impl="pallas",
                           tile_n=tile)
    out_r, tot_r = compact(jnp.asarray(vals), m, impl="ref", tile_n=tile)
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_r))
    assert int(tot_p) == int(tot_r) == int(np.asarray(m).sum())


def check_probe(seed, n, tile, ties):
    rng = np.random.default_rng(seed)
    lh = _hashes(rng, n, ties)
    rh = jnp.sort(_hashes(rng, max(1, n // 2), ties))
    q_p = probe(lh, rh, impl="pallas", tile_n=tile)
    q_r = probe(lh, rh, impl="ref", tile_n=tile)
    np.testing.assert_array_equal(np.asarray(q_p), np.asarray(q_r))


def check_segment_sum(seed, n, tile, dtype, num_segments=16):
    rng = np.random.default_rng(seed)
    d = int(rng.integers(1, 4))
    # integer-valued payloads: float addition is then exact in any
    # order, so parity can demand bit-identity
    vals = rng.integers(-50, 50, (n, d)).astype(dtype)
    # sorted AND dense ids (consecutive, cumsum over boundary bits) —
    # the kernel's contract, as the engine's GROUPBY produces them; the
    # start offset still covers negative and past-num_segments ids,
    # which both impls must drop identically
    start = int(rng.integers(-1, 2))
    sid = jnp.asarray((start + np.cumsum(rng.integers(0, 2, n)))
                      .astype(np.int32))
    o_p = segment_sum(jnp.asarray(vals), sid, num_segments=num_segments,
                      impl="pallas", tile_n=tile)
    o_r = segment_sum(jnp.asarray(vals), sid, num_segments=num_segments,
                      impl="ref", tile_n=tile)
    np.testing.assert_array_equal(np.asarray(o_p), np.asarray(o_r))


def check_mha(seed, sq, skv):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, 2, sq, 16), np.float32))
    k = jnp.asarray(rng.standard_normal((1, 2, skv, 16), np.float32))
    v = jnp.asarray(rng.standard_normal((1, 2, skv, 16), np.float32))
    o_p = mha(q, k, v, causal=True, impl="pallas", block_q=64,
              block_k=64, interpret=True)
    o_r = mha(q, k, v, causal=True, impl="ref")
    # float accumulation is reordered by the online softmax: tight
    # tolerance, not bit-identity
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r),
                               atol=2e-5, rtol=2e-5)


# ----------------------------------------------- always-on seeded sweep


@pytest.mark.parametrize("ties", ["uniform", "few", "const"])
@pytest.mark.parametrize("vmode", ["mixed", "all", "none"])
def test_partition_and_scatter_parity_sweep(ties, vmode):
    for i, n in enumerate(SIZES):
        tile = TILES[i % len(TILES)]
        check_partition(i, n, tile, ties, vmode)
        check_scatter(100 + i, n, tile, ties, vmode)


def test_scatter_non_pow2_parts_dispatches_to_ref():
    rng = np.random.default_rng(0)
    h, v = _hashes(rng, 200, "uniform"), _valid(rng, 200, "mixed")
    s_p, o_p = scatter_slots(h, v, n_parts=6, bucket=40, impl="pallas")
    s_r, o_r = scatter_slots(h, v, n_parts=6, bucket=40, impl="ref")
    np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_r))
    assert int(o_p) == int(o_r)


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_compact_parity_sweep(dtype):
    for i, n in enumerate(SIZES):
        for vmode in ("mixed", "all", "none"):
            check_compact(i, n, TILES[i % len(TILES)], vmode, dtype)


@pytest.mark.parametrize("ties", ["uniform", "few", "const"])
def test_probe_parity_sweep(ties):
    for i, n in enumerate(SIZES):
        check_probe(i, n, TILES[i % len(TILES)], ties)


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_segment_sum_parity_sweep(dtype):
    for i, n in enumerate(SIZES):
        check_segment_sum(i, n, TILES[i % len(TILES)], dtype)


def test_mha_parity_seeded():
    # ragged sizes below the block (the kernel clamps its block to the
    # sequence) plus exact block multiples; non-multiple sizes above the
    # block are rejected by the kernel's precondition, and causal
    # sq > skv (queries with zero visible keys) is outside the contract
    for seed, (sq, skv) in enumerate([(64, 64), (37, 53), (64, 128),
                                      (1, 64)]):
        check_mha(seed, sq, skv)


# ------------------------------------------------- hypothesis wrappers

if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 10**6), n=st.integers(1, 700),
           tile=st.sampled_from(TILES),
           ties=st.sampled_from(["uniform", "few", "const"]),
           vmode=st.sampled_from(["mixed", "all", "none"]))
    def test_partition_scatter_parity_fuzz(seed, n, tile, ties, vmode):
        check_partition(seed, n, tile, ties, vmode)
        check_scatter(seed, n, tile, ties, vmode)

    @settings(max_examples=25, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 10**6), n=st.integers(1, 700),
           tile=st.sampled_from(TILES),
           dtype=st.sampled_from([np.float32, np.int32]),
           vmode=st.sampled_from(["mixed", "all", "none"]))
    def test_compact_parity_fuzz(seed, n, tile, dtype, vmode):
        check_compact(seed, n, tile, vmode, dtype)

    @settings(max_examples=25, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 10**6), n=st.integers(1, 700),
           tile=st.sampled_from(TILES),
           ties=st.sampled_from(["uniform", "few", "const"]))
    def test_probe_parity_fuzz(seed, n, tile, ties):
        check_probe(seed, n, tile, ties)

    @settings(max_examples=25, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 10**6), n=st.integers(1, 700),
           tile=st.sampled_from(TILES),
           dtype=st.sampled_from([np.float32, np.int32]))
    def test_segment_sum_parity_fuzz(seed, n, tile, dtype):
        check_segment_sum(seed, n, tile, dtype)
