"""Figs 13 + 14 + Table 1: No-Heuristic vs Conservative vs Aggressive.

Per query and heuristic: execution time with Store injection (Fig 14),
execution time when reusing the stored sub-jobs (Fig 13), and stored
bytes (Table 1).  Paper's findings to validate: H_A reuse ~= NH reuse;
H_C stores least and benefits least; NH stores far more bytes for no
extra benefit.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import emit, measure_query         # noqa: E402
from repro.workloads import pigmix                        # noqa: E402

QUERIES = ["L2", "L3", "L3F", "L4", "L5", "L6", "L7", "L8", "L11"]
HEURISTICS = ["none", "conservative", "aggressive"]   # none == paper's NH


def run(n_rows: int = 1 << 14):
    table1 = {}
    for q in QUERIES:
        row = {}
        for h in HEURISTICS:
            m = measure_query(pigmix.QUERIES[q], n_rows, h)
            tag = {"none": "NH", "conservative": "HC",
                   "aggressive": "HA"}[h]
            emit(f"fig14/store_time/{q}/{tag}", m["t_store"],
                 f"overhead={m['t_store'] / max(m['t_plain'], 1e-9):.2f}")
            emit(f"fig13/reuse_time/{q}/{tag}", m["t_reuse"],
                 f"speedup={m['t_plain'] / max(m['t_reuse'], 1e-9):.2f}")
            row[tag] = m["stored_bytes"]
        table1[q] = row
        emit(f"table1/stored_bytes/{q}", 0.0,
             f"HC={row['HC']};HA={row['HA']};NH={row['NH']}")
    # the paper's claims as checkable aggregates
    ha_le_nh = all(r["HA"] <= r["NH"] for r in table1.values())
    hc_le_ha = all(r["HC"] <= r["HA"] for r in table1.values())
    emit("table1/claims", 0.0,
         f"HA_bytes<=NH_bytes={ha_le_nh};HC_bytes<=HA_bytes={hc_le_ha}")


if __name__ == "__main__":
    run()
