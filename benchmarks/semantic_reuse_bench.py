"""Semantic-reuse benchmark: speedup from subsumption matching with
compensation rewrites (DESIGN.md §10), appended to ``BENCH_core.json``.

The producer query is join + group-by + FILTER(total > θ_base) over
PigMix data; only *whole-job* outputs are stored (heuristic "off" — the
paper's free materialization).  The probe query re-runs with a strictly
STRONGER threshold θ(r), chosen so that a fraction ``r`` of the stored
rows survive (the predicate-overlap ratio).  Three arms per ratio:

  t_plain     fresh driver, no stores, no rewriting        (no-reuse)
  t_exact     warm driver, exact matching only — the FILTER fingerprint
              differs, so only the shared join job is answered
  t_semantic  warm driver with the subsumption fallback — the final job
              is answered from the covering artifact plus a residual
              FILTER, skipping the group-by entirely

The tracked claim (ISSUE 3 acceptance): t_plain / t_semantic ≥ 2 at
overlap ≥ 0.5.
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks.common import emit, run_time              # noqa: E402
from repro.core import plan as P                          # noqa: E402
from repro.core.restore import ReStore                    # noqa: E402
from repro.dataflow.expr import Col                       # noqa: E402
from repro.store.artifacts import ArtifactStore, Catalog  # noqa: E402
from repro.workloads import pigmix                        # noqa: E402

OUT = os.path.join(_ROOT, "BENCH_core.json")

# every probe is strictly stronger than the stored predicate (overlap
# 1.0 would be the identical query — the whole-job fast path's business)
OVERLAPS = (0.90, 0.75, 0.50, 0.25)
BASE_KEEP = 0.8        # the stored artifact keeps 80% of the groups


def _query(theta: float) -> P.PhysicalPlan:
    pv = P.project(P.load("page_views"), ["user", "estimated_revenue"])
    u = P.project(P.load("users"), ["name"])
    j = P.join(pv, u, ["user"], ["name"])
    g = P.groupby(j, ["user"], {"total": ("sum", "estimated_revenue")})
    f = P.filter_(g, Col("total") > theta)
    return P.PhysicalPlan([P.store(f, "sem_out")])


def _totals(n_rows: int) -> "list[float]":
    """Per-user revenue totals, host-side (for threshold quantiles)."""
    import numpy as np
    d = pigmix.gen_page_views(n_rows).to_numpy()
    users = d["user"]
    flat = users.reshape(users.shape[0], -1)
    _, inv = np.unique(flat, axis=0, return_inverse=True)
    sums = np.zeros(inv.max() + 1, dtype=np.float64)
    np.add.at(sums, inv, d["estimated_revenue"].astype(np.float64))
    return sorted(sums)


def _theta_for_keep(totals, keep_frac: float) -> float:
    """Threshold keeping ~``keep_frac`` of the groups under total > θ."""
    idx = int(round((1.0 - keep_frac) * (len(totals) - 1)))
    return float(totals[max(0, min(idx, len(totals) - 1))])


def _fresh(n_rows: int, **kw) -> ReStore:
    store = ArtifactStore(root=tempfile.mkdtemp(prefix="restore_sem_"))
    cat = Catalog(store)
    store.put("page_views", pigmix.gen_page_views(n_rows))
    store.put("users", pigmix.gen_users())
    store.put("power_users", pigmix.gen_power_users())
    return ReStore(cat, store, measure_exec=True, **kw)


def _close(rs: ReStore) -> None:
    rs.store.close()
    shutil.rmtree(rs.store.root, ignore_errors=True)


def run(label: str | None = None, n_rows: int = 1 << 15,
        out_path: str = OUT, trials: int = 3):
    # CI sizes the sweep down via env (the docs job exercises the bench
    # on every push; the committed BENCH_core.json entry uses defaults)
    n_rows = int(os.environ.get("SEMANTIC_BENCH_NROWS", n_rows))
    trials = int(os.environ.get("SEMANTIC_BENCH_TRIALS", trials))
    totals = _totals(n_rows)
    theta_base = _theta_for_keep(totals, BASE_KEEP)

    rec = {"label": label or "run", "n_rows": n_rows, "trials": trials,
           "sweep": []}
    for overlap in OVERLAPS:
        theta_q = _theta_for_keep(totals, BASE_KEEP * overlap)
        t_plain, t_exact, t_semantic, hits = [], [], [], 0
        for _ in range(trials):
            rs0 = _fresh(n_rows, heuristic="off", rewrite_enabled=False,
                         semantic=False)
            t_plain.append(run_time(rs0, _query(theta_q)))
            _close(rs0)

            for use_sem, bucket in ((False, t_exact), (True, t_semantic)):
                rs = _fresh(n_rows, heuristic="off", semantic=use_sem)
                rs.run_plan(_query(theta_base))       # seed: whole-job only
                _, rep = rs.run_plan(_query(theta_q))
                bucket.append(rep.total_wall_s)
                if use_sem:
                    hits += rep.n_semantic
                _close(rs)

        med = lambda xs: sorted(xs)[len(xs) // 2]     # noqa: E731
        row = {"overlap": overlap,
               "theta": round(theta_q, 2),
               "t_plain_s": round(med(t_plain), 6),
               "t_exact_s": round(med(t_exact), 6),
               "t_semantic_s": round(med(t_semantic), 6),
               "semantic_hits": hits,
               "speedup_vs_plain": round(
                   med(t_plain) / max(med(t_semantic), 1e-9), 4),
               "speedup_vs_exact": round(
                   med(t_exact) / max(med(t_semantic), 1e-9), 4)}
        rec["sweep"].append(row)
        emit(f"semantic/overlap_{int(overlap * 100)}", row["t_semantic_s"],
             f"speedup={row['speedup_vs_plain']:.2f};"
             f"vs_exact={row['speedup_vs_exact']:.2f};hits={hits}")
        assert hits > 0, f"semantic path did not fire at overlap={overlap}"

    doc = {"runs": []}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    runs = doc.setdefault("semantic_runs", [])
    # keep the last 2 prior same-label entries (real predecessors for
    # the nightly consecutive same-label regression gate)
    same = [r for r in runs if r["label"] == rec["label"]][-2:]
    doc["semantic_runs"] = [r for r in runs
                            if r["label"] != rec["label"]] + same + [rec]
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    at50 = next(r for r in rec["sweep"] if r["overlap"] == 0.50)
    emit("semantic/summary", 0.0,
         f"speedup_at_50={at50['speedup_vs_plain']:.2f};out={out_path}")


if __name__ == "__main__":
    run(label=sys.argv[1] if len(sys.argv) > 1 else None)
