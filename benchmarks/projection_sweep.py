"""Fig 16: QP template — overhead & speedup vs number of projected
fields.  Paper: more data reduction (fewer fields) => lower overhead,
higher speedup; monotone trend.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import emit, measure_query         # noqa: E402
from repro.workloads import pigmix                        # noqa: E402


def run(n_rows: int = 1 << 14):
    results = []
    for nf in range(1, 6):
        m = measure_query(lambda nf=nf: pigmix.QP(nf), n_rows,
                          "aggressive", datasets="synth")
        ov = m["t_store"] / max(m["t_plain"], 1e-9)
        sp = m["t_plain"] / max(m["t_reuse"], 1e-9)
        results.append((nf, ov, sp))
        emit(f"fig16/projection/{nf}_fields", m["t_reuse"],
             f"overhead={ov:.2f};speedup={sp:.2f}")
    # monotonicity claim (allowing measurement noise via trend check)
    sp_first, sp_last = results[0][2], results[-1][2]
    emit("fig16/claims", 0.0,
         f"speedup_1field={sp_first:.2f};speedup_5fields={sp_last:.2f};"
         f"fewer_fields_faster={sp_first >= sp_last}")


if __name__ == "__main__":
    run()
