"""Shared harness for the paper-figure benchmarks.

Protocol per measurement (mirrors paper §7):
  t_plain     — fresh store, no sub-job stores, no rewriting
  t_store     — fresh store, Store operators injected per heuristic
                (overhead = t_store / t_plain, Fig 11/14)
  t_reuse     — warm store/repository from a prior run, final outputs
                evicted so the terminal job re-executes; jobs rewritten
                against the repository (speedup = t_plain / t_reuse,
                Figs 9/10/12/13)

Execution times use Engine(measure_exec=True): each jitted job is warmed
once off the clock, so times compare execution, not tracing+compile
(Hadoop job-launch overhead is constant across the paper's arms; JIT
compile is not, so it must be excluded).
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.core.repository import Repository              # noqa: E402
from repro.core.restore import ReStore                    # noqa: E402
from repro.store.artifacts import ArtifactStore, Catalog  # noqa: E402
from repro.workloads import pigmix                        # noqa: E402


import tempfile


def fresh_restore(n_rows: int, heuristic: str, rewrite: bool,
                  datasets: str = "pigmix", seed: int = 0) -> ReStore:
    """Disk-backed store; SOURCE datasets also live in the store (the
    HDFS analogue) so every job pays a real T_load."""
    store = ArtifactStore(root=tempfile.mkdtemp(prefix="restore_bench_"))
    cat = Catalog(store)
    if datasets == "pigmix":
        store.put("page_views", pigmix.gen_page_views(n_rows, seed))
        store.put("users", pigmix.gen_users())
        store.put("power_users", pigmix.gen_power_users())
    elif datasets == "synth":
        store.put("synth", pigmix.gen_synth(n_rows, seed=seed))
    rs = ReStore(cat, store, Repository(), heuristic=heuristic,
                 rewrite_enabled=rewrite, measure_exec=True)
    return rs


def run_time(rs: ReStore, plan) -> float:
    _, report = rs.run_plan(plan)
    return report.total_wall_s


def evict_final_outputs(rs: ReStore, plan) -> None:
    """Drop the terminal artifacts (and their repo entries) so the final
    job re-executes — the paper reuses *intermediate* outputs."""
    from repro.dataflow.compiler import compile_workflow
    wf = compile_workflow(plan)
    finals = set(wf.final_outputs.values())
    for name in finals:
        rs.store.delete(name)
    rs.repo._replace([e for e in rs.repo.entries
                      if e.artifact not in finals], [], None)


def measure_query(plan_fn, n_rows: int, heuristic: str = "aggressive",
                  datasets: str = "pigmix"):
    """Returns dict(t_plain, t_store, t_reuse, stored_bytes)."""
    import shutil

    rs0 = fresh_restore(n_rows, "off", False, datasets)
    t_plain = run_time(rs0, plan_fn())
    src_bytes = sum(rs0.store.nbytes(n) for n in rs0.store.names()
                    if not n.startswith("art/"))
    rs0.store.close()         # stop the flusher, release the device cache
    shutil.rmtree(rs0.store.root, ignore_errors=True)

    rs1 = fresh_restore(n_rows, heuristic, False, datasets)
    t_store = run_time(rs1, plan_fn())
    # Table 1 counts the output of Store operators ADDED by the heuristic
    # — whole-job outputs are stored under every policy and are excluded
    from repro.dataflow.compiler import compile_workflow
    whole_job = {o for j in compile_workflow(plan_fn()).jobs
                 for o in j.outputs}
    stored = sum(rs1.store.nbytes(n) for n in rs1.store.names()
                 if n.startswith("art/") and n not in whole_job)

    evict_final_outputs(rs1, plan_fn())
    rs2 = ReStore(rs1.catalog, rs1.store, rs1.repo,
                  heuristic="off", rewrite_enabled=True, measure_exec=True)
    t_reuse = run_time(rs2, plan_fn())
    rs1.store.close()         # rs2 shares rs1's store object
    shutil.rmtree(rs1.store.root, ignore_errors=True)
    return {"t_plain": t_plain, "t_store": t_store, "t_reuse": t_reuse,
            "stored_bytes": stored, "source_bytes": src_bytes}


def emit(name: str, seconds: float, derived: str):
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.0f},{derived}", flush=True)
