"""Fig 9: reusing the output of WHOLE jobs (L3 + L11 variants).

The variant queries share their first job(s) with a previously executed
variant; ReStore answers those jobs from the store and only the terminal
job runs.  Reported: per-variant speedup + the average (paper: 9.8x on
Hadoop — disk-bound; CPU/XLA ratios differ but must be >> 1), and the
overhead (paper: 0% — no Store operators are injected for whole jobs).
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import emit, evict_final_outputs, fresh_restore, \
    run_time                                              # noqa: E402
from repro.core.restore import ReStore                    # noqa: E402
from repro.workloads import pigmix                        # noqa: E402


def run(n_rows: int = 1 << 14):
    speedups = []
    # L3 aggregate variants share the join job
    variants = [lambda: pigmix.L3("sum"), lambda: pigmix.L3("mean"),
                lambda: pigmix.L3("max"), lambda: pigmix.L3("min")]
    # L11 second-dataset variants share the distinct(page_views) job
    variants += [lambda: pigmix.L11("power_users"),
                 lambda: pigmix.L11("users")]

    # cold baselines, one per variant
    for i, v in enumerate(variants):
        rs = fresh_restore(n_rows, "off", False)
        t_plain = run_time(rs, v())
        rs.store.close()      # release the flusher thread + device cache

        # warm: execute the *sibling* variant first (shares job 1), evict
        # its final output, rerun the target variant with rewriting
        sib = variants[i - 1 if i % 2 else i + 1 - (i == len(variants) - 1)]
        rs2 = fresh_restore(n_rows, "off", False)
        run_time(rs2, sib())
        evict_final_outputs(rs2, v())
        rs3 = ReStore(rs2.catalog, rs2.store, rs2.repo, heuristic="off",
                      rewrite_enabled=True, measure_exec=True)
        t_reuse = run_time(rs3, v())
        rs2.store.close()     # rs3 shares rs2's store object
        sp = t_plain / max(t_reuse, 1e-9)
        speedups.append(sp)
        emit(f"fig9/whole_job/variant{i}", t_reuse, f"speedup={sp:.2f}")

    avg = sum(speedups) / len(speedups)
    emit("fig9/whole_job/average", 0.0,
         f"avg_speedup={avg:.2f};paper=9.8x_on_disk_bound_hadoop;"
         f"overhead=1.00")
    return avg


if __name__ == "__main__":
    run()
