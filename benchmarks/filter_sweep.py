"""Fig 17: QF template — overhead & speedup vs filter selectivity
(field6: 0.5% selected ... field12: 60% selected).  Paper: less selective
filters (more surviving data) => higher overhead, lower speedup.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import emit, measure_query         # noqa: E402
from repro.workloads import pigmix                        # noqa: E402


def run(n_rows: int = 1 << 14):
    results = []
    for field, frac in pigmix.FILTER_FIELDS.items():
        m = measure_query(lambda f=field: pigmix.QF(f), n_rows,
                          "aggressive", datasets="synth")
        ov = m["t_store"] / max(m["t_plain"], 1e-9)
        sp = m["t_plain"] / max(m["t_reuse"], 1e-9)
        results.append((frac, ov, sp))
        emit(f"fig17/filter/{field}_{int(frac * 1000)}permille",
             m["t_reuse"], f"overhead={ov:.2f};speedup={sp:.2f}")
    sp_first, sp_last = results[0][2], results[-1][2]
    emit("fig17/claims", 0.0,
         f"speedup_0.5pct={sp_first:.2f};speedup_60pct={sp_last:.2f};"
         f"more_selective_faster={sp_first >= sp_last}")


if __name__ == "__main__":
    run()
