"""Policy benchmark: cumulative stream runtime vs repository byte budget,
appended to ``BENCH_core.json`` (DESIGN.md §9).

One multi-tenant zipfian stream (identical event schedule for every arm,
dataset churn included) is replayed under three policies:

  off   — recompute everything (no reuse)                [budget-free]
  lru   — store everything, LRU eviction at the budget
  cost  — cost-model materialization + benefit-per-byte eviction

for a sweep of budgets expressed as fractions of the total candidate
byte volume (measured once with an unbudgeted store-everything run).
The paper's economics predict — and this snapshot tracks PR over PR —
that at tight budgets (~25%) the cost policy beats both baselines:
unlike LRU it keeps the artifacts whose recompute-savings per byte are
highest, and unlike `off` it reuses at all.
"""
from __future__ import annotations

import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks.common import emit                        # noqa: E402
from repro.workloads.stream import StreamConfig, run_stream  # noqa: E402

OUT = os.path.join(_ROOT, "BENCH_core.json")

BUDGET_FRACTIONS = (0.10, 0.25, 0.50, 1.00)


def run(label: str | None = None, out_path: str = OUT,
        cfg: StreamConfig | None = None):
    cfg = cfg or StreamConfig(n_events=48, n_tenants=3, n_rows=1 << 12,
                              zipf_s=1.1, churn_every=20, seed=0)

    # size the candidate volume with an unbudgeted store-everything run
    keep = run_stream("keep", cfg)
    total_bytes = keep.peak_store_bytes
    emit("policy/keep", keep.total_wall_s,
         f"candidate_bytes={total_bytes}")

    off = run_stream("off", cfg)
    emit("policy/off", off.total_wall_s, "no-reuse baseline")

    budgets = []
    for frac in BUDGET_FRACTIONS:
        budget = int(total_bytes * frac)
        lru = run_stream("lru", cfg, budget_bytes=budget)
        cost = run_stream("cost", cfg, budget_bytes=budget)
        budgets.append({
            "frac": frac,
            "budget_bytes": budget,
            "lru_s": round(lru.total_wall_s, 6),
            "cost_s": round(cost.total_wall_s, 6),
            "lru_reuses": lru.n_reused_total,
            "cost_reuses": cost.n_reused_total,
            "lru_evictions": lru.evictions,
            "cost_evictions": cost.evictions,
            "cost_rejections": cost.rejections,
            "lru_cum_s": [round(x, 6) for x in lru.cum_wall_s],
            "cost_cum_s": [round(x, 6) for x in cost.cum_wall_s],
        })
        emit(f"policy/budget_{int(frac * 100)}pct", cost.total_wall_s,
             f"cost={cost.total_wall_s:.3f}s;lru={lru.total_wall_s:.3f}s;"
             f"off={off.total_wall_s:.3f}s")

    rec = {
        "label": label or "run",
        "n_events": cfg.n_events,
        "n_tenants": cfg.n_tenants,
        "n_rows": cfg.n_rows,
        "churn_every": cfg.churn_every,
        "total_candidate_bytes": total_bytes,
        "off_s": round(off.total_wall_s, 6),
        "off_cum_s": [round(x, 6) for x in off.cum_wall_s],
        "keep_s": round(keep.total_wall_s, 6),
        "budgets": budgets,
    }

    doc = {"runs": []}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    runs = doc.setdefault("policy_runs", [])
    doc["policy_runs"] = [r for r in runs if r["label"] != rec["label"]]
    doc["policy_runs"].append(rec)
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    emit("policy/done", 0.0, f"out={out_path}")
    return rec


if __name__ == "__main__":
    run(label=sys.argv[1] if len(sys.argv) > 1 else None)
