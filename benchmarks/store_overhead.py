"""Fig 11: overhead of injecting Store operators (aggressive heuristic),
at two data scales.  Paper: 2.4x @15GB vs 1.6x @150GB — RELATIVE overhead
shrinks as the data (and so T_load/T_sort) grows.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import emit, measure_query         # noqa: E402
from repro.workloads import pigmix                        # noqa: E402

QUERIES = ["L2", "L3", "L4", "L5", "L6", "L7", "L8", "L11"]


def run(n_small: int = 1 << 13, n_large: int = 1 << 15):
    for scale, n_rows in (("small", n_small), ("large", n_large)):
        overheads = []
        for q in QUERIES:
            m = measure_query(pigmix.QUERIES[q], n_rows, "aggressive")
            ov = m["t_store"] / max(m["t_plain"], 1e-9)
            overheads.append(ov)
            emit(f"fig11/overhead/{scale}/{q}", m["t_store"],
                 f"overhead={ov:.2f}")
        avg = sum(overheads) / len(overheads)
        emit(f"fig11/overhead/{scale}/average", 0.0,
             f"avg_overhead={avg:.2f};paper=2.4x_small_1.6x_large")


if __name__ == "__main__":
    run()
