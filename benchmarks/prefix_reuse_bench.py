"""Beyond-paper: serving-side prefix reuse (ReStore's algorithms applied
to KV/recurrent state).  A fleet of prompts sharing a system prefix is
served with and without the prefix repository; outputs are verified
identical, wall-time speedup and reuse fraction reported.
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

import numpy as np                                        # noqa: E402
import jax                                                # noqa: E402

from benchmarks.common import emit                        # noqa: E402
from repro.configs import get_config                      # noqa: E402
from repro.models.api import build                        # noqa: E402
from repro.serve.engine import ServeEngine                # noqa: E402
from repro.serve.prefix_repo import PrefixRepository      # noqa: E402


def run(n_requests: int = 6, prefix_len: int = 96, suffix_len: int = 16,
        n_decode: int = 2):
    cfg = get_config("qwen3-1.7b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prefix = rng.integers(1, cfg.vocab_size, prefix_len)
    prompts = [np.concatenate([prefix,
                               rng.integers(1, cfg.vocab_size, suffix_len)])
               for _ in range(n_requests)]

    def run_fleet(repo):
        eng = ServeEngine(model, params, max_len=prefix_len + suffix_len
                          + n_decode + 2, prefix_repo=repo)
        outs, stats = [], []
        # warm BOTH prefill shapes (full prompt + suffix-only) off the
        # clock, using a disposable prefix that matches nothing later
        warm_prefix = rng.integers(1, cfg.vocab_size, prefix_len)
        for _ in range(2):
            eng.serve(np.concatenate(
                [warm_prefix,
                 rng.integers(1, cfg.vocab_size, suffix_len)]), n_decode)
        t0 = time.perf_counter()
        for p in prompts:
            o, s = eng.serve(p, n_decode)
            outs.append(o)
            stats.append(s)
        return outs, stats, time.perf_counter() - t0

    outs_plain, _, t_plain = run_fleet(None)
    repo = PrefixRepository()
    outs_reuse, stats, t_reuse = run_fleet(repo)
    for a, b in zip(outs_plain, outs_reuse):
        assert (a == b).all(), "prefix reuse must not change outputs"

    reused = sum(s.reused_tokens for s in stats)
    total = sum(s.reused_tokens + s.prefilled_tokens for s in stats)
    # wall speedup on CPU is decode-dispatch-bound (~1.0); the prefill
    # work avoided — the production win — is the reused-token fraction
    emit("beyond/prefix_reuse/fleet", t_reuse,
         f"wall_speedup={t_plain / max(t_reuse, 1e-9):.2f};"
         f"prefill_tokens_from_repo={reused / total:.0%};"
         f"outputs_identical=True")


if __name__ == "__main__":
    run()
