"""Serving prefix-KV reuse through the unified repository (ISSUE 10,
DESIGN.md §17).

A zipfian stream of requests over a small population of long prompt
prefixes (the shared-system-prompt regime) is served twice with the SAME
`ServeSession.serve` path: once cold (kv=None) and once with a
`KVRepository` attached.  Greedy decodes must be bit-identical; the
reuse arm reports wall speedup, reused-token fraction, and p50/p95
per-request latency.  The full-size entry is gated by
``tools/check_bench.py`` (``prefix_runs``: >= 2x wall speedup and
>= 0.5 reused-token fraction; bit-identity at any size).

Env knobs (CI runs a small labelled entry, nightly the full size):
  PREFIX_BENCH_REQUESTS  stream length            (default 48)
  PREFIX_BENCH_PROMPTS   distinct prefixes        (default 8)
  PREFIX_BENCH_PREFIX    prefix tokens            (default 1024)
  PREFIX_BENCH_SUFFIX    per-request suffix tokens (default 16)
  PREFIX_BENCH_DECODE    greedy decode tokens     (default 2)
  PREFIX_BENCH_ZIPF      zipf exponent            (default 1.1)
  PREFIX_BENCH_EVERY_K   alias stride             (default 64)
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, "src")

import numpy as np                                        # noqa: E402
import jax                                                # noqa: E402

from benchmarks.common import emit                        # noqa: E402
from repro.configs import get_config                      # noqa: E402
from repro.models.api import build                        # noqa: E402
from repro.serve.kv_repo import KVRepository              # noqa: E402
from repro.serve.session import ServeSession              # noqa: E402

OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_core.json")


def _zipf_ranks(n_requests: int, n_prompts: int, a: float, rng):
    """Zipf-distributed prefix choices clipped to the population."""
    w = 1.0 / np.arange(1, n_prompts + 1) ** a
    w /= w.sum()
    return rng.choice(n_prompts, size=n_requests, p=w)


def run(label: str | None = None, out_path: str = OUT):
    n_requests = int(os.environ.get("PREFIX_BENCH_REQUESTS", 48))
    n_prompts = int(os.environ.get("PREFIX_BENCH_PROMPTS", 8))
    prefix_len = int(os.environ.get("PREFIX_BENCH_PREFIX", 1024))
    suffix_len = int(os.environ.get("PREFIX_BENCH_SUFFIX", 16))
    n_decode = int(os.environ.get("PREFIX_BENCH_DECODE", 2))
    zipf_a = float(os.environ.get("PREFIX_BENCH_ZIPF", 1.1))
    # alias stride: prefix_len must be a multiple so the shared-prefix
    # boundary has an alias to hit
    every_k = int(os.environ.get("PREFIX_BENCH_EVERY_K", 64))

    cfg = get_config("qwen3-1.7b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    max_len = prefix_len + suffix_len + n_decode + 2

    prefixes = [rng.integers(1, cfg.vocab_size, prefix_len)
                for _ in range(n_prompts)]
    ranks = _zipf_ranks(n_requests, n_prompts, zipf_a, rng)
    prompts = [np.concatenate(
        [prefixes[r], rng.integers(1, cfg.vocab_size, suffix_len)])
        for r in ranks]

    def run_arm(kv):
        sess = ServeSession(model, params, max_len=max_len, kv=kv,
                            every_k=every_k)
        # warm BOTH prefill shapes (full prompt + residual suffix) off
        # the clock with a disposable prefix that matches nothing later
        warm_prefix = rng.integers(1, cfg.vocab_size, prefix_len)
        for _ in range(2):
            sess.serve(np.concatenate(
                [warm_prefix,
                 rng.integers(1, cfg.vocab_size, suffix_len)]), n_decode)
        outs, stats, laps = [], [], []
        t0 = time.perf_counter()
        for p in prompts:
            t1 = time.perf_counter()
            o, s = sess.serve(p, n_decode)
            laps.append(time.perf_counter() - t1)
            outs.append(o)
            stats.append(s)
        return outs, stats, laps, time.perf_counter() - t0

    outs_plain, _, _, t_plain = run_arm(None)
    kv = KVRepository(model_version=cfg.name)
    outs_reuse, stats, laps, t_reuse = run_arm(kv)
    identical = all((a == b).all()
                    for a, b in zip(outs_plain, outs_reuse))
    assert identical, "prefix reuse must not change greedy decodes"

    reused = sum(s.reused_tokens for s in stats)
    total = sum(s.reused_tokens + s.prefilled_tokens for s in stats)
    speedup = t_plain / max(t_reuse, 1e-9)
    frac = reused / max(total, 1)
    lap_ms = np.asarray(laps) * 1e3

    rec = {"label": label or "run",
           "n_requests": n_requests, "n_prompts": n_prompts,
           "prefix_len": prefix_len, "suffix_len": suffix_len,
           "n_decode": n_decode, "zipf_a": zipf_a,
           "t_noreuse_s": round(t_plain, 6),
           "t_reuse_s": round(t_reuse, 6),
           "wall_speedup": round(speedup, 4),
           "reused_token_frac": round(frac, 4),
           "p50_reuse_ms": round(float(np.percentile(lap_ms, 50)), 3),
           "p95_reuse_ms": round(float(np.percentile(lap_ms, 95)), 3),
           "kv_entries": len(kv), "kv_bytes": kv.total_bytes,
           "exact_hits": kv.stats()["exact_hits"],
           "semantic_hits": kv.stats()["semantic_hits"],
           "identical": identical}
    emit("serve/prefix_stream", t_reuse,
         f"noreuse={t_plain:.4f}s;speedup={speedup:.2f};"
         f"reused_frac={frac:.2f};p95={rec['p95_reuse_ms']:.1f}ms;"
         f"identical={identical}")

    doc = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    runs = doc.setdefault("prefix_runs", [])
    # keep the last 2 prior same-label entries (the nightly regression
    # gate compares consecutive same-label entries)
    same = [r for r in runs if r["label"] == rec["label"]][-2:]
    doc["prefix_runs"] = [r for r in runs
                          if r["label"] != rec["label"]] + same + [rec]
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    emit("serve/prefix_done", 0.0, f"out={out_path}")
    return rec


if __name__ == "__main__":
    run(label=sys.argv[1] if len(sys.argv) > 1 else None)
