"""Core perf snapshot: t_plain / t_store / t_reuse per query, appended to
``BENCH_core.json`` so the bench trajectory is tracked PR over PR.

Protocol is the same disk-backed three-arm measurement as the figure
benches (see common.measure_query): store overhead = t_store/t_plain
(paper Fig 11), reuse speedup = t_plain/t_reuse (paper Figs 9/10).
"""
from __future__ import annotations

import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks.common import emit, measure_query         # noqa: E402
from repro.workloads import pigmix                        # noqa: E402

# L2/L3: join/groupby-heavy (reuse-speedup signal); L4-L11 map-heavy
# (store-overhead signal: T_store is a visible fraction of cheap jobs)
QUERIES = ["L2", "L3", "L4", "L6", "L7", "L8", "L11"]
OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "BENCH_core.json")


def _median(xs):
    xs = sorted(xs)
    return xs[len(xs) // 2]


def run(label: str | None = None, n_rows: int = 1 << 15,
        out_path: str = OUT, trials: int = 3):
    """Each query is measured ``trials`` times and the per-metric median
    is recorded — single-arm stalls (CPU steal, disk hiccups) otherwise
    dominate the cheap map-only queries."""
    rec = {"label": label or "run", "n_rows": n_rows, "trials": trials,
           "queries": {}}
    raw = {q: [] for q in QUERIES}
    for trial in range(trials):
        for q in QUERIES:
            raw[q].append(measure_query(pigmix.QUERIES[q], n_rows,
                                        "aggressive"))
    for q in QUERIES:
        t_plain = _median([m["t_plain"] for m in raw[q]])
        t_store = _median([m["t_store"] for m in raw[q]])
        t_reuse = _median([m["t_reuse"] for m in raw[q]])
        rec["queries"][q] = {
            "t_plain_s": round(t_plain, 6),
            "t_store_s": round(t_store, 6),
            "t_reuse_s": round(t_reuse, 6),
            "store_overhead": round(t_store / max(t_plain, 1e-9), 4),
            "reuse_speedup": round(t_plain / max(t_reuse, 1e-9), 4),
        }
        emit(f"core/{q}", t_plain,
             f"overhead={rec['queries'][q]['store_overhead']:.2f};"
             f"speedup={rec['queries'][q]['reuse_speedup']:.2f}")
    ovs = [v["store_overhead"] for v in rec["queries"].values()]
    sps = [v["reuse_speedup"] for v in rec["queries"].values()]
    rec["avg_store_overhead"] = round(sum(ovs) / len(ovs), 4)
    rec["avg_reuse_speedup"] = round(sum(sps) / len(sps), 4)

    doc = {"runs": []}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    # keep the last 2 prior same-label entries so the nightly workflow
    # (prior snapshot restored from the actions cache) has a real
    # predecessor for check_bench's consecutive same-label gate
    same = [r for r in doc["runs"] if r["label"] == rec["label"]][-2:]
    doc["runs"] = [r for r in doc["runs"]
                   if r["label"] != rec["label"]] + same + [rec]
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    emit("core/average", 0.0,
         f"avg_overhead={rec['avg_store_overhead']:.2f};"
         f"avg_speedup={rec['avg_reuse_speedup']:.2f};out={out_path}")


if __name__ == "__main__":
    run(label=sys.argv[1] if len(sys.argv) > 1 else None)
