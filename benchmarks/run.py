"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  Fig 9      whole-job reuse speedup        (whole_job_reuse)
  Figs 10+12 sub-job reuse speedup, 2 scales (subjob_reuse)
  Fig 11     Store-injection overhead, 2 scales (store_overhead)
  Figs 13+14 + Table 1  NH / H_C / H_A      (heuristics)
  Fig 16     projection data-reduction sweep (projection_sweep)
  Fig 17     filter selectivity sweep        (filter_sweep)
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

from benchmarks import (filter_sweep, heuristics, prefix_reuse_bench,  # noqa
                        projection_sweep, store_overhead, subjob_reuse,
                        whole_job_reuse)

SUITES = {
    "fig9_whole_job": whole_job_reuse.run,
    "fig10_12_subjob": subjob_reuse.run,
    "fig11_overhead": store_overhead.run,
    "fig13_14_table1_heuristics": heuristics.run,
    "fig16_projection": projection_sweep.run,
    "fig17_filter": filter_sweep.run,
    "beyond_prefix_reuse": prefix_reuse_bench.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(SUITES) + [None])
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        fn()
        print(f"# suite {name} finished in {time.time() - t0:.1f}s",
              flush=True)


if __name__ == "__main__":
    main()
