"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  Fig 9      whole-job reuse speedup        (whole_job_reuse)
  Figs 10+12 sub-job reuse speedup, 2 scales (subjob_reuse)
  Fig 11     Store-injection overhead, 2 scales (store_overhead)
  Figs 13+14 + Table 1  NH / H_C / H_A      (heuristics)
  Fig 16     projection data-reduction sweep (projection_sweep)
  Fig 17     filter selectivity sweep        (filter_sweep)
  beyond     budgeted-repository policy sweep (policy_bench)
"""
from __future__ import annotations

import argparse
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks import (core_bench, delta_bench, distributed_bench,  # noqa
                        filter_sweep, heuristics, mqo_bench,
                        policy_bench, prefix_reuse_bench,
                        projection_sweep, semantic_reuse_bench,
                        service_bench, store_overhead, subjob_reuse,
                        tier_bench, whole_job_reuse)

SUITES = {
    "core": core_bench.run,
    "policy": policy_bench.run,
    "semantic": semantic_reuse_bench.run,
    "dist": distributed_bench.run,
    "delta": delta_bench.run,
    "service": service_bench.run,
    "tier": tier_bench.run,
    "mqo": mqo_bench.run,
    "prefix": prefix_reuse_bench.run,
    "fig9_whole_job": whole_job_reuse.run,
    "fig10_12_subjob": subjob_reuse.run,
    "fig11_overhead": store_overhead.run,
    "fig13_14_table1_heuristics": heuristics.run,
    "fig16_projection": projection_sweep.run,
    "fig17_filter": filter_sweep.run,
}

# suites that accept a --label (snapshots into BENCH_core.json)
LABELLED = {"core", "policy", "semantic", "dist", "delta", "service",
            "tier", "mqo", "prefix"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(SUITES) + [None])
    ap.add_argument("--label", default=None,
                    help="run label recorded in BENCH_core.json "
                         "(core/policy suites)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        if name in LABELLED:
            fn(label=args.label)
        else:
            fn()
        print(f"# suite {name} finished in {time.time() - t0:.1f}s",
              flush=True)


if __name__ == "__main__":
    main()
