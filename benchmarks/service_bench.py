"""Concurrent-service benchmark: goodput + latency percentiles vs
worker count under an open-loop zipfian arrival stream, appended to
``BENCH_core.json`` as ``service_runs`` (DESIGN.md §13).

Protocol:

  * every event is a UNIQUE plan body (a filter threshold drawn from
    the event index) so neither the repository nor singleflight can
    collapse the work — the sweep measures concurrent *execution*, not
    reuse.  All variants share one jitted shape family and are
    precompiled in a serial warmup (GLOBAL_JIT_CACHE is process-wide),
    so the measured phase contains zero compiles;
  * each job carries the constant launch + DFS round-trip overhead of
    the paper's MapReduce setting (``job_overhead_s`` — our in-process
    engine has none).  That overhead is wait, not compute, so the
    worker pool overlaps it; the goodput-scaling gate measures exactly
    that overlap (on this container's single core, XLA compute itself
    cannot parallelize — as in the paper, per-job overhead dominates);
  * arrivals are open-loop Poisson (``stream.open_loop_arrivals``) at a
    rate calibrated to ~2x one worker's measured capacity: one worker
    saturates, four keep up — the gate checks 4-worker goodput >= 1.5x
    1-worker goodput (CHECK_BENCH_MIN_SERVICE);
  * each arm ends with a stampede phase: identical plans submitted
    back-to-back must collapse via singleflight (hits == burst - 1) and
    the dup-execution counter must stay 0 across the whole sweep.

Env knobs: SERVICE_BENCH_NROWS (default 1<<15), SERVICE_BENCH_EVENTS
(default 48), SERVICE_BENCH_WORKERS (default "1,2,4"),
SERVICE_BENCH_OVERHEAD_MS (default 100), SERVICE_BENCH_TRIALS
(default 2 — each arm runs TRIALS times and keeps its best-goodput
trial: on a single shared core the OS scheduler's thread-placement
noise can swamp a single 10s arm, and best-of-N strips exactly that
noise without touching the workload).
"""
from __future__ import annotations

import json
import os
import sys
import threading
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

import numpy as np                                            # noqa: E402

from benchmarks.common import emit                            # noqa: E402
from repro.core import plan as P                              # noqa: E402
from repro.core.repository import Repository                  # noqa: E402
from repro.core.restore import ReStore                        # noqa: E402
from repro.dataflow.expr import Col                           # noqa: E402
from repro.service.service import ReStoreService              # noqa: E402
from repro.store.artifacts import ArtifactStore, Catalog      # noqa: E402
from repro.workloads import pigmix                            # noqa: E402
from repro.workloads.stream import open_loop_arrivals         # noqa: E402

OUT = os.path.join(_ROOT, "BENCH_core.json")

N_ROWS = int(os.environ.get("SERVICE_BENCH_NROWS", 1 << 15))
N_EVENTS = int(os.environ.get("SERVICE_BENCH_EVENTS", 48))
WORKERS = tuple(int(w) for w in
                os.environ.get("SERVICE_BENCH_WORKERS", "1,2,4").split(","))
OVERHEAD_S = float(os.environ.get("SERVICE_BENCH_OVERHEAD_MS", 100)) / 1e3
TRIALS = int(os.environ.get("SERVICE_BENCH_TRIALS", 2))
BURST = 8
N_TENANTS = 3


def _block(results) -> None:
    """Force async XLA dispatch to completion — latency must count the
    compute, not just the enqueue."""
    import jax
    for t in results.values():
        jax.block_until_ready(t.col(t.names[0]))


def _event_plan(i: int, tag: str) -> P.PhysicalPlan:
    """Join + filter + wide groupby; the threshold makes every event's
    body unique (no reuse, no singleflight collapse), the tag keeps
    sink names unique per arm (the whole-job fast path is name-based)."""
    pv = P.project(P.load("page_views"),
                   ["user", "query_term", "timespent",
                    "estimated_revenue"])
    u = P.project(P.load("users"), ["name"])
    j = P.join(pv, u, ["user"], ["name"])
    f = P.filter_(j, Col("timespent") > (i % 97))
    g = P.groupby(f, ["user", "query_term"],
                  {"rev": ("sum", "estimated_revenue"),
                   "n": ("count", "timespent")})
    return P.PhysicalPlan([P.store(g, f"svc_{tag}_{i}_out")])


def _fresh(tag_unused=None):
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=N_ROWS)
    return store, cat


def _warmup() -> float:
    """Serially compile + run every plan variant once; returns the mean
    post-compile execution time (the calibration for the offered rate)."""
    store, cat = _fresh()
    drv = ReStore(cat, store, Repository(), heuristic="off",
                  rewrite_enabled=False)
    for i in range(N_EVENTS):                    # compile pass
        drv.run_plan(_event_plan(i, "warm"))
    drv.run_plan(_event_plan(0, "warmburst"))    # the stampede plan body
    t0 = time.perf_counter()
    for i in range(N_EVENTS):                    # timed pass, all cached
        results, _ = drv.run_plan(_event_plan(i, "timed"))
        _block(results)
    return (time.perf_counter() - t0) / N_EVENTS


def _run_arm(n_workers: int, rate_per_s: float, tag: str) -> dict:
    store, cat = _fresh()
    svc = ReStoreService(cat, store, Repository(), n_workers=n_workers,
                         max_queue=4 * N_EVENTS,
                         job_overhead_s=OVERHEAD_S, heuristic="off",
                         rewrite_enabled=False)
    arrivals = open_loop_arrivals(N_EVENTS, rate_per_s, seed=7)
    lat = []
    lat_lock = threading.Lock()
    waiters = []

    def wait_for(ticket, submitted):
        results, _ = ticket.result(timeout=600)
        _block(results)
        done = time.perf_counter()
        with lat_lock:
            lat.append(done - submitted)

    rng = np.random.default_rng(11)
    tenants = rng.integers(N_TENANTS, size=N_EVENTS)
    t0 = time.perf_counter()
    for i in range(N_EVENTS):
        gap = t0 + arrivals[i] - time.perf_counter()
        if gap > 0:
            time.sleep(gap)
        tk = svc.submit(_event_plan(i, tag), tenant=f"t{tenants[i]}")
        w = threading.Thread(target=wait_for,
                             args=(tk, time.perf_counter()))
        w.start()
        waiters.append(w)
    for w in waiters:
        w.join(timeout=600)
    makespan = time.perf_counter() - t0

    # stampede phase: identical bodies back-to-back collapse into one
    # execution via singleflight
    burst = [svc.submit(_event_plan(0, "warmburst"), tenant=f"t{i % 2}")
             for i in range(BURST)]
    for tk in burst:
        tk.result(timeout=600)
    st = svc.stats()
    svc.stop()
    qs = np.quantile(np.array(lat), [0.50, 0.95, 0.99])
    return {
        "workers": n_workers,
        "goodput_per_s": round(N_EVENTS / makespan, 3),
        "p50_ms": round(float(qs[0]) * 1e3, 3),
        "p95_ms": round(float(qs[1]) * 1e3, 3),
        "p99_ms": round(float(qs[2]) * 1e3, 3),
        "completed": st["completed"],
        "failed": st["failed"],
        "singleflight_hits": st["singleflight_hits"],
        "dup_executions": st["dup_executions"],
    }


def run(label: str | None = None, out_path: str = OUT):
    mean_exec_s = _warmup()
    # ~2x one worker's capacity (overhead + compute): one worker
    # saturates, four keep up
    rate = 2.0 / max(mean_exec_s + OVERHEAD_S, 1e-4)
    emit("service/warmup", mean_exec_s,
         f"overhead={OVERHEAD_S * 1e3:.0f}ms;"
         f"offered_rate={rate:.1f}/s")

    sweep = []
    for w in WORKERS:
        arm = max((_run_arm(w, rate, tag=f"w{w}t{t}")
                   for t in range(TRIALS)),
                  key=lambda a: a["goodput_per_s"])
        sweep.append(arm)
        emit(f"service/goodput_{w}w", 1.0 / max(arm["goodput_per_s"],
                                                1e-9),
             f"goodput={arm['goodput_per_s']}/s;p95={arm['p95_ms']}ms")

    by_w = {a["workers"]: a for a in sweep}
    lo = by_w.get(1, sweep[0])
    hi = by_w.get(4, sweep[-1])
    scaling = hi["goodput_per_s"] / max(lo["goodput_per_s"], 1e-9)
    rec = {
        "label": label or "run",
        "n_rows": N_ROWS,
        "n_events": N_EVENTS,
        "n_tenants": N_TENANTS,
        "offered_rate_per_s": round(rate, 3),
        "mean_exec_ms": round(mean_exec_s * 1e3, 3),
        "job_overhead_ms": round(OVERHEAD_S * 1e3, 3),
        "worker_sweep": sweep,
        "goodput_scaling_4w_vs_1w": round(scaling, 4),
        "singleflight_hits": sum(a["singleflight_hits"] for a in sweep),
        "dup_executions": sum(a["dup_executions"] for a in sweep),
    }
    emit("service/scaling_4w_vs_1w", scaling,
         f"hits={rec['singleflight_hits']};dups={rec['dup_executions']}")

    doc = {"runs": []}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    runs = doc.setdefault("service_runs", [])
    doc["service_runs"] = [r for r in runs if r["label"] != rec["label"]]
    doc["service_runs"].append(rec)
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    emit("service/done", 0.0, f"out={out_path}")
    return rec


if __name__ == "__main__":
    run(label=sys.argv[1] if len(sys.argv) > 1 else None)
