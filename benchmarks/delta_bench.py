"""Delta-refresh benchmark: refresh vs delete-and-recompute across
append fractions, appended to ``BENCH_core.json`` (DESIGN.md §12).

Two templates over PigMix-shaped data (integer-valued aggregation
columns, so float32 merges are exact and bit-identity is checkable):

  groupby — per-user sum+count of timespent (decomposable aggregates,
            merged by shard-/key-local re-aggregation);
  join    — page_views projection ⋈ power_users (delta join, merged by
            append).

Protocol per (template, append fraction):

  1. cold run through ReStore (whole-job output stored + registered);
  2. ``Catalog.append`` of fraction × n_rows fresh page_views rows;
  3. refresh arm — ``ReStore.maintain(mode="refresh")``: the delta job
     plus the merge, timed;
  4. recompute arm — identical setup, stale entries R4-deleted
     (``evict_stale``), the query re-run cold at the new size, timed;
  5. bit-identity — both arms' final outputs must be identical
     (canonically sorted rows), and the refreshed repository must
     answer the new-version query with zero executed jobs.

Each protocol runs ``trials`` times (fresh stores; the process-wide jit
cache is warm after the first trial, so medians compare execution, not
tracing) and the per-arm median is recorded.  The committed full-size
entry is gated by ``tools/check_bench.py``: at ≤10% append fraction,
refresh must beat recompute by ≥3x for both templates.
"""
from __future__ import annotations

import gc
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

import numpy as np                                        # noqa: E402

from benchmarks.common import emit                        # noqa: E402
from repro.core import plan as P                          # noqa: E402
from repro.core.plan import rebind_load_versions          # noqa: E402
from repro.core.repository import Repository              # noqa: E402
from repro.core.restore import ReStore                    # noqa: E402
from repro.store.artifacts import ArtifactStore, Catalog  # noqa: E402
from repro.workloads import pigmix                        # noqa: E402

OUT = os.path.join(_ROOT, "BENCH_core.json")

FRACTIONS = (0.01, 0.05, 0.10, 0.25, 0.50)


def t_groupby() -> P.PhysicalPlan:
    # L6-shaped: wide (string) key pair, all four decomposable
    # aggregates — the expensive recurring aggregate the paper reuses
    pv = P.project(P.load("page_views"),
                   ["user", "query_term", "timespent"])
    g = P.groupby(pv, ["user", "query_term"],
                  {"total": ("sum", "timespent"),
                   "n": ("count", "timespent"),
                   "mn": ("min", "timespent"),
                   "mx": ("max", "timespent")})
    return P.PhysicalPlan([P.store(g, "delta_groupby_out")])


def t_join() -> P.PhysicalPlan:
    pv = P.project(P.load("page_views"), ["user", "timespent"])
    pu = P.project(P.load("power_users"), ["name"])
    j = P.join(pv, pu, ["user"], ["name"])
    return P.PhysicalPlan([P.store(j, "delta_join_out")])


TEMPLATES = {"groupby": t_groupby, "join": t_join}


def _sortable(a: np.ndarray) -> np.ndarray:
    """1-D lexsort key: fixed-width byte-string columns collapse to
    bytes scalars."""
    if a.ndim == 2:
        return np.ascontiguousarray(a).view(f"S{a.shape[1]}").ravel()
    return a


def _canon(table):
    d = table.to_numpy()
    order = np.lexsort(tuple(_sortable(d[c])
                             for c in sorted(d, reverse=True)))
    return {c: d[c][order] for c in sorted(d)}


def _identical(a, b) -> bool:
    ca, cb = _canon(a), _canon(b)
    if sorted(ca) != sorted(cb):
        return False
    return all(np.array_equal(ca[c], cb[c]) for c in ca)


def _setup(build, n_rows: int, seed: int) -> ReStore:
    store = ArtifactStore(cache_bytes=256 * 1024 * 1024)
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=n_rows, seed=seed)
    rs = ReStore(cat, store, Repository(), heuristic="off")
    rs.run_plan(build())                       # cold run: artifact stored
    return rs


def _one_point(build, n_rows: int, frac: int, seed: int):
    """(t_refresh, t_recompute, identical, refreshed_count) for one
    template at one append fraction."""
    n_delta = max(int(n_rows * frac), 1)
    delta = pigmix.gen_page_views(n_delta, seed=seed + 9000)

    # refresh arm
    rs = _setup(build, n_rows, seed)
    rs.catalog.append("page_views", delta)
    t0 = time.perf_counter()
    rep = rs.maintain(mode="refresh")
    t_refresh = time.perf_counter() - t0
    plan2 = rebind_load_versions(
        build(), {ds: rs.catalog.version(ds) for ds in
                  ("page_views", "users", "power_users")})
    out_r, run_rep = rs.run_plan(plan2)
    assert run_rep.n_executed == 0, \
        "refreshed repository must answer the new-version query exactly"

    # recompute arm (the pre-§12 behavior: R4 delete, run cold)
    rs2 = _setup(build, n_rows, seed)
    rs2.catalog.append("page_views", delta)
    rs2.repo.evict_stale(rs2.catalog)
    t0 = time.perf_counter()
    out_c, _ = rs2.run_plan(plan2)
    t_recompute = time.perf_counter() - t0

    key = list(out_r)[0]
    ident = _identical(out_r[key], out_c[key])
    n_ref = rep["refreshed"]
    # free both arms' stores (hundreds of MB of device tables) NOW:
    # deferred GC of prior points otherwise stalls later timed windows
    rs.store.close()
    rs2.store.close()
    del rs, rs2, out_r, out_c
    gc.collect()
    return t_refresh, t_recompute, ident, n_ref


def run(label: str | None = None, n_rows: int = 1 << 19,
        out_path: str = OUT, trials: int = 3):
    n_rows = int(os.environ.get("DELTA_BENCH_NROWS", n_rows))
    trials = int(os.environ.get("DELTA_BENCH_TRIALS", trials))
    sweep = []
    for tname, build in TEMPLATES.items():
        for frac in FRACTIONS:
            # warmup pass (discarded): every plain/delta/merge shape of
            # this point compiles here, so the timed trials below
            # compare execution, not tracing — the same convention as
            # every other benchmark in this repo
            _one_point(build, n_rows, frac, seed=0)
            rs_t, rc_t, idents, refreshed = [], [], [], 0
            for trial in range(trials):
                tr, tc, ident, n_ref = _one_point(build, n_rows, frac,
                                                  seed=trial)
                rs_t.append(tr)
                rc_t.append(tc)
                idents.append(ident)
                refreshed += n_ref
            t_refresh = sorted(rs_t)[len(rs_t) // 2]
            t_recompute = sorted(rc_t)[len(rc_t) // 2]
            assert refreshed >= trials, \
                f"{tname}@{frac}: refresh path not exercised"
            pt = {"template": tname, "frac": frac,
                  "t_refresh_s": round(t_refresh, 6),
                  "t_recompute_s": round(t_recompute, 6),
                  "speedup": round(t_recompute / max(t_refresh, 1e-9), 4),
                  "identical": all(idents)}
            sweep.append(pt)
            emit(f"delta/{tname}_{int(frac * 100)}pct", t_refresh,
                 f"recompute={t_recompute:.4f}s;"
                 f"speedup={pt['speedup']:.2f};identical={pt['identical']}")

    rec = {"label": label or "run", "n_rows": n_rows, "trials": trials,
           "sweep": sweep}
    doc = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    runs = doc.setdefault("delta_runs", [])
    # keep the last 2 prior same-label entries: check_bench's
    # regression gate compares CONSECUTIVE same-label entries, so the
    # nightly workflow (which restores the previous snapshot from the
    # actions cache) gets a real predecessor to gate against
    same = [r for r in runs if r["label"] == rec["label"]][-2:]
    doc["delta_runs"] = [r for r in runs
                         if r["label"] != rec["label"]] + same + [rec]
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    emit("delta/done", 0.0, f"out={out_path}")
    return rec


if __name__ == "__main__":
    run(label=sys.argv[1] if len(sys.argv) > 1 else None)
