"""Figs 10 + 12: reusing SUB-JOB outputs (aggressive heuristic), at two
data scales.  Paper: average speedup 3.0x @15GB, 24.4x @150GB — speedup
grows with scale because T_load dominates Eq. 2.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks.common import emit, measure_query         # noqa: E402
from repro.workloads import pigmix                        # noqa: E402

QUERIES = ["L2", "L3", "L4", "L5", "L6", "L7", "L8", "L11"]


def run(n_small: int = 1 << 13, n_large: int = 1 << 15):
    for scale, n_rows in (("small", n_small), ("large", n_large)):
        speedups = []
        for q in QUERIES:
            m = measure_query(pigmix.QUERIES[q], n_rows, "aggressive")
            sp = m["t_plain"] / max(m["t_reuse"], 1e-9)
            speedups.append(sp)
            emit(f"fig10_12/subjob/{scale}/{q}", m["t_reuse"],
                 f"speedup={sp:.2f}")
        avg = sum(speedups) / len(speedups)
        emit(f"fig10_12/subjob/{scale}/average", 0.0,
             f"avg_speedup={avg:.2f};paper=3.0x_small_24.4x_large")


if __name__ == "__main__":
    run()
