"""Tiered-store benchmark: speculative prefetch vs demand paging over a
device → host → disk → remote hierarchy, appended to ``BENCH_core.json``
(DESIGN.md §15).

Setup: N artifacts live remote-authoritative behind an emulated object
store with per-request latency and bandwidth injection.  A zipfian
probe stream reads them through a tiered ArtifactStore whose device and
host budgets each hold only a few artifacts, and every ``flush_every``
probes the working set is dropped (``drop_caches`` — other tenants
claiming the accelerator between this stream's bursts).  Both arms see
the IDENTICAL probe sequence, budgets, and pressure:

  off — demand paging: every cold probe pays the remote round-trip
        inside its own timed window;
  on  — a ``SpeculativePrefetcher`` mines the store's read log and,
        between probes (the background cadence a service runs it on,
        off the clock), re-warms the predicted top-k with ONE batched
        fetch.

The timed quantity is the sum of probe ``get()`` walls — the store-level
analogue of the stream drivers' timed windows (the engine warms loads
off the clock, so prefetch benefit is only observable here).  Gates
(tools/check_bench.py): prefetch speedup ≥ 1.3x at full size,
bit-identical probe results between arms at any size, and a cold start
from the remote tier alone (fresh disk root, batched rehydrate) must
complete.
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time
import zlib

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

import numpy as np                                        # noqa: E402

from benchmarks.common import emit                        # noqa: E402
from repro.core.cost_model import CostModel               # noqa: E402
from repro.dataflow.table import Table                    # noqa: E402
from repro.store.artifacts import ArtifactStore           # noqa: E402
from repro.store.prefetch import SpeculativePrefetcher    # noqa: E402
from repro.store.tiers import RemoteObjectStore           # noqa: E402

OUT = os.path.join(_ROOT, "BENCH_core.json")

REMOTE_LATENCY_S = 0.015
REMOTE_BW = 2e8


def _art(i: int) -> str:
    return f"tier_art_{i:03d}"


def _mk_table(i: int, n_rows: int) -> Table:
    rng = np.random.default_rng(1000 + i)
    return Table.from_numpy({
        "k": rng.integers(0, 1 << 40, n_rows).astype(np.int64),
        "v": rng.standard_normal(n_rows).astype(np.float32)})


def _crc(table: Table) -> int:
    d = table.to_numpy()
    h = 0
    for c in sorted(d):
        h = zlib.crc32(np.ascontiguousarray(d[c]).tobytes(), h)
    return h


def _probe_seq(n_arts: int, probes: int, zipf_s: float = 1.1,
               seed: int = 7):
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, n_arts + 1) ** zipf_s
    p /= p.sum()
    perm = np.random.default_rng(seed + 1).permutation(n_arts)
    return [int(perm[rng.choice(n_arts, p=p)]) for _ in range(probes)]


def _populate(disk_root: str, remote_root: str, n_arts: int, n_rows: int,
              art_bytes: int):
    """Fresh tiered store with every artifact remote-authoritative."""
    remote = RemoteObjectStore(remote_root, latency_s=0.0)  # free setup
    store = ArtifactStore(root=disk_root, cache_bytes=4 * art_bytes,
                          host_bytes=4 * art_bytes, remote=remote)
    for i in range(n_arts):
        store.put(_art(i), _mk_table(i, n_rows))
    store.flush()
    for i in range(n_arts):
        store.demote_to_remote(_art(i))
    store.drop_caches()
    store.close()
    return remote


def _run_arm(prefetch: bool, n_arts: int, n_rows: int, art_bytes: int,
             seq, flush_every: int, k: int):
    disk_root = tempfile.mkdtemp(prefix="tier_bench_")
    remote_root = tempfile.mkdtemp(prefix="tier_remote_")
    _populate(disk_root, remote_root, n_arts, n_rows, art_bytes)
    remote = RemoteObjectStore(remote_root, latency_s=REMOTE_LATENCY_S,
                               bandwidth_bytes_s=REMOTE_BW)
    store = ArtifactStore(root=disk_root, cache_bytes=4 * art_bytes,
                          host_bytes=4 * art_bytes, remote=remote)
    pf = SpeculativePrefetcher(store, k=k) if prefetch else None
    total = 0.0
    crcs = []
    for i, a in enumerate(seq):
        if i and i % flush_every == 0:
            store.drop_caches()         # tenant pressure: both arms
            if pf is not None:
                pf.prefetch()           # background re-warm, off clock
        t0 = time.perf_counter()
        t = store.get(_art(a))
        total += time.perf_counter() - t0
        crcs.append(_crc(t))
        if pf is not None:
            pf.prefetch()               # between-probe cadence, off clock
    stats = pf.stats() if pf is not None else {}
    cm = CostModel()
    cm.calibrate_io(store)
    bw = {"disk": cm.load_bw, **cm.tier_bw}
    store.close()
    return {"wall_s": total, "crcs": crcs, "prefetch": stats, "bw": bw,
            "disk_root": disk_root, "remote_root": remote_root}


def _cold_start(disk_root: str, remote_root: str, n_arts: int) -> float:
    """Fresh machine, remote tier only: reopen over an EMPTY disk root
    and rehydrate every artifact (batched head index + batched fetch)."""
    fresh = tempfile.mkdtemp(prefix="tier_cold_")
    remote = RemoteObjectStore(remote_root, latency_s=REMOTE_LATENCY_S,
                               bandwidth_bytes_s=REMOTE_BW)
    t0 = time.perf_counter()
    store = ArtifactStore(root=fresh, cache_bytes=1 << 30,
                          host_bytes=1 << 30, remote=remote)
    names = [_art(i) for i in range(n_arts)]
    assert all(store.exists(n) for n in names), \
        "cold start: remote index incomplete"
    warmed = store.prewarm(names)
    assert len(warmed) == n_arts, \
        f"cold start rehydrated {len(warmed)}/{n_arts}"
    cold_s = time.perf_counter() - t0
    store.close()
    shutil.rmtree(fresh, ignore_errors=True)
    return cold_s


def run(label: str | None = None, n_rows: int = 1 << 16,
        out_path: str = OUT):
    n_rows = int(os.environ.get("TIER_BENCH_NROWS", n_rows))
    n_arts = int(os.environ.get("TIER_BENCH_ARTS", 24))
    probes = int(os.environ.get("TIER_BENCH_PROBES", 120))
    flush_every = int(os.environ.get("TIER_BENCH_FLUSH_EVERY", 12))
    k = int(os.environ.get("TIER_BENCH_K", 6))
    art_bytes = _mk_table(0, n_rows).nbytes()
    seq = _probe_seq(n_arts, probes)

    off = _run_arm(False, n_arts, n_rows, art_bytes, seq, flush_every, k)
    on = _run_arm(True, n_arts, n_rows, art_bytes, seq, flush_every, k)
    identical = off["crcs"] == on["crcs"]
    speedup = off["wall_s"] / max(on["wall_s"], 1e-9)
    cold_s = _cold_start(on["disk_root"], on["remote_root"], n_arts)
    for r in (off, on):
        shutil.rmtree(r["disk_root"], ignore_errors=True)
        shutil.rmtree(r["remote_root"], ignore_errors=True)

    rec = {"label": label or "run", "n_rows": n_rows,
           "n_artifacts": n_arts, "probes": probes,
           "flush_every": flush_every, "prefetch_k": k,
           "remote_latency_s": REMOTE_LATENCY_S,
           "t_off_s": round(off["wall_s"], 6),
           "t_on_s": round(on["wall_s"], 6),
           "speedup_prefetch": round(speedup, 4),
           "prefetch_hit_rate": round(
               on["prefetch"].get("hit_rate", 0.0), 4),
           "prefetched": on["prefetch"].get("prefetched", 0),
           "cold_start_s": round(cold_s, 6),
           "identical": identical,
           "bw": {t: round(v, 1) for t, v in on["bw"].items()}}
    emit("tier/prefetch", on["wall_s"],
         f"off={off['wall_s']:.4f}s;speedup={speedup:.2f};"
         f"hit_rate={rec['prefetch_hit_rate']:.2f};"
         f"identical={identical}")
    emit("tier/cold_start", cold_s, f"n_artifacts={n_arts}")

    doc = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    runs = doc.setdefault("tier_runs", [])
    # keep the last 2 prior same-label entries (the nightly regression
    # gate compares consecutive same-label entries)
    same = [r for r in runs if r["label"] == rec["label"]][-2:]
    doc["tier_runs"] = [r for r in runs
                        if r["label"] != rec["label"]] + same + [rec]
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    emit("tier/done", 0.0, f"out={out_path}")
    return rec


if __name__ == "__main__":
    run(label=sys.argv[1] if len(sys.argv) > 1 else None)
