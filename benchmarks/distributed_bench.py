"""Distributed execution benchmark (DESIGN.md §11), appended to
``BENCH_core.json`` under ``dist_runs``.

Workload: the shuffle-heavy PigMix shape — join(page_views, users) then
group-by user — on an 8-way forced-host device mesh.  Arms:

  t_single        single device, no reuse (plain)
  t_mesh_plain    8-way mesh, no reuse: both exchanges run
  t_reuse_blind   8-way mesh, WARM, partition-blind: the join artifact
                  is reused but stored monolithic, so the group-by must
                  still exchange every row
  t_reuse_copart  8-way mesh, WARM, partition-aware: the reused join
                  artifact is co-partitioned on the grouping key — the
                  group-by runs shuffle-free per shard

The tracked claim (ISSUE 4 acceptance): t_reuse_blind / t_reuse_copart
>= 2 at the default (committed) size — partition-aware reuse skips the
exchange, not just the compute.

The sweep runs in a SUBPROCESS that sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before importing
jax, exactly like tests/test_distributed.py; the parent process (and
anything else in the same interpreter) keeps its 1-device view.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

OUT = os.path.join(_ROOT, "BENCH_core.json")
N_SHARDS = 8
HISTORY_PER_LABEL = 5        # the check_bench regression gate needs
                             # same-label history, so entries append


# ---------------------------------------------------------------------------
# Child: runs inside the 8-device subprocess


def _child(n_rows: int, trials: int, out_path: str) -> None:
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    import jax

    from repro.core import plan as P
    from repro.core.restore import ReStore
    from repro.store.artifacts import ArtifactStore, Catalog
    from repro.workloads import pigmix

    assert len(jax.devices()) >= N_SHARDS, jax.devices()
    mesh = jax.make_mesh((N_SHARDS,), ("data",))

    def probe(aggs):
        pv = P.project(P.load("page_views"), ["user", "estimated_revenue"])
        u = P.project(P.load("users"), ["name"])
        j = P.join(pv, u, ["user"], ["name"])
        g = P.groupby(j, ["user"], aggs)
        return P.PhysicalPlan([P.store(g, "dist_out")])

    A_SEED = {"total": ("sum", "estimated_revenue")}
    A_PROBE = {"total": ("sum", "estimated_revenue"),
               "n": ("count", "estimated_revenue"),
               "mx": ("max", "estimated_revenue")}

    def fresh(**kw):
        store = ArtifactStore(root=tempfile.mkdtemp(prefix="dist_bench_"))
        store.put("page_views", pigmix.gen_page_views(n_rows))
        store.put("users", pigmix.gen_users())
        return ReStore(Catalog(store), store, measure_exec=True,
                       repeats=3, **kw)

    def close(rs):
        import shutil
        rs.store.close()
        shutil.rmtree(rs.store.root, ignore_errors=True)

    def timed(rs, plan):
        _, rep = rs.run_plan(plan)
        return rep.total_wall_s, rep

    med = lambda xs: sorted(xs)[len(xs) // 2]     # noqa: E731
    t_single, t_mesh, t_blind, t_copart = [], [], [], []
    skipped = 0
    for _ in range(trials):
        rs = fresh(heuristic="off", rewrite_enabled=False, semantic=False)
        t_single.append(timed(rs, probe(A_PROBE))[0])
        close(rs)

        rs = fresh(heuristic="off", rewrite_enabled=False, semantic=False,
                   mesh=mesh)
        t_mesh.append(timed(rs, probe(A_PROBE))[0])
        close(rs)

        for aware, bucket in ((False, t_blind), (True, t_copart)):
            rs = fresh(heuristic="aggressive", mesh=mesh,
                       partition_aware=aware)
            rs.run_plan(probe(A_SEED))            # warm: join artifact
            t, rep = timed(rs, probe(A_PROBE))
            bucket.append(t)
            if aware:
                skipped += sum(j.stats.shuffles_skipped
                               for j in rep.jobs if j.stats)
            close(rs)

    rec = {
        "n_rows": n_rows, "n_shards": N_SHARDS, "trials": trials,
        "arms": {"t_single_s": round(med(t_single), 6),
                 "t_mesh_plain_s": round(med(t_mesh), 6),
                 "t_reuse_blind_s": round(med(t_blind), 6),
                 "t_reuse_copart_s": round(med(t_copart), 6)},
        "shuffles_skipped": skipped,
        "speedup_copart_vs_blind": round(
            med(t_blind) / max(med(t_copart), 1e-9), 4),
        "speedup_copart_vs_plain": round(
            med(t_mesh) / max(med(t_copart), 1e-9), 4),
        "mesh_vs_single": round(
            med(t_single) / max(med(t_mesh), 1e-9), 4),
    }
    assert skipped > 0, "partition-aware arm never skipped an exchange"
    with open(out_path, "w") as f:
        json.dump(rec, f)


# ---------------------------------------------------------------------------
# Parent


def run(label: str | None = None, n_rows: int = 1 << 16,
        out_path: str = OUT, trials: int = 3):
    from benchmarks.common import emit

    # CI sizes the sweep down via env (the docs job exercises the bench
    # on every push; the committed BENCH_core.json entry uses defaults)
    n_rows = int(os.environ.get("DIST_BENCH_NROWS", n_rows))
    trials = int(os.environ.get("DIST_BENCH_TRIALS", trials))

    child_out = tempfile.mktemp(suffix=".json")
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_SHARDS}"
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", child_out,
         "--n-rows", str(n_rows), "--trials", str(trials)],
        env=env, capture_output=True, text=True, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(f"distributed bench child failed:\n"
                           f"{proc.stderr[-3000:]}")
    with open(child_out) as f:
        rec = json.load(f)
    os.unlink(child_out)
    rec["label"] = label or "run"

    a = rec["arms"]
    emit("dist/single_device", a["t_single_s"], "plain")
    emit("dist/mesh8_plain", a["t_mesh_plain_s"],
         f"vs_single={rec['mesh_vs_single']:.2f}")
    emit("dist/mesh8_reuse_blind", a["t_reuse_blind_s"],
         "warm;monolithic artifact")
    emit("dist/mesh8_reuse_copart", a["t_reuse_copart_s"],
         f"warm;speedup_vs_blind={rec['speedup_copart_vs_blind']:.2f};"
         f"skipped={rec['shuffles_skipped']}")

    doc = {"runs": []}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    runs = doc.setdefault("dist_runs", [])
    runs.append(rec)
    # keep bounded same-label history (newest last) for the regression gate
    kept, per_label = [], {}
    for r in reversed(runs):
        per_label[r["label"]] = per_label.get(r["label"], 0) + 1
        if per_label[r["label"]] <= HISTORY_PER_LABEL:
            kept.append(r)
    doc["dist_runs"] = list(reversed(kept))
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    emit("dist/summary", 0.0,
         f"copart_vs_blind={rec['speedup_copart_vs_blind']:.2f};"
         f"out={out_path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", default=None)
    ap.add_argument("--n-rows", type=int, default=1 << 16)
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--label", default=None)
    args = ap.parse_args()
    if args.child:
        _child(args.n_rows, args.trials, args.child)
    else:
        run(label=args.label, n_rows=args.n_rows, trials=args.trials)


if __name__ == "__main__":
    main()
