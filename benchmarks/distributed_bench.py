"""Distributed execution benchmark (DESIGN.md §11), appended to
``BENCH_core.json`` under ``dist_runs``.

Workload: the shuffle-heavy PigMix shape — join(page_views, users) then
group-by user — on an 8-way forced-host device mesh.  The users table
scales with the data (n_rows / 8 distinct users) the way PigMix's does.
Inputs live in the store's distributed layout: the partition-aware
engine loads them co-partitioned on the demanded keys (one cached host
pass — M3R-style partition stability), so steady-state mesh runs spend
their time on sharded compute, not on re-exchanging static datasets.
Arms:

  t_single        single device, no reuse (plain)
  t_mesh_plain    8-way mesh, no result reuse: cold sharded execution
                  over co-partitioned input loads
  t_reuse_blind   8-way mesh, WARM, partition-blind: the join artifact
                  is reused but stored monolithic, so the group-by must
                  still exchange every row (and the input loads are
                  exchanged too — the blind engine ignores layout)
  t_reuse_copart  8-way mesh, WARM, partition-aware: the reused join
                  artifact is co-partitioned on the grouping key — the
                  group-by runs shuffle-free per shard

Tracked claims: t_reuse_blind / t_reuse_copart >= 2 at the default
(committed) size (ISSUE 4 — partition-aware reuse skips the exchange,
not just the compute), and t_single / t_mesh_plain >= 1 (ISSUE 7 — the
sharded path must not lose to recompute-on-one-device).

With ``RESTORE_AUTOTUNE=1`` the child runs a tuning pass first
(kernels/autotune.py): exchange skew measured on an exchange-running
configuration, join probe slack on the co-partitioned one, and the
Pallas scatter tile priced through roofline/analysis.py; the persisted
table then feeds every arm via ``autotune.choose``.

The sweep runs in a SUBPROCESS that sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before importing
jax, exactly like tests/test_distributed.py; the parent process (and
anything else in the same interpreter) keeps its 1-device view.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

OUT = os.path.join(_ROOT, "BENCH_core.json")
N_SHARDS = 8
HISTORY_PER_LABEL = 5        # the check_bench regression gate needs
                             # same-label history, so entries append


# ---------------------------------------------------------------------------
# Child: runs inside the 8-device subprocess


def _child(n_rows: int, trials: int, out_path: str) -> None:
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    import jax

    from repro.core import plan as P
    from repro.core.restore import ReStore
    from repro.store.artifacts import ArtifactStore, Catalog
    from repro.workloads import pigmix

    assert len(jax.devices()) >= N_SHARDS, jax.devices()
    mesh = jax.make_mesh((N_SHARDS,), ("data",))

    def probe(aggs):
        pv = P.project(P.load("page_views"), ["user", "estimated_revenue"])
        u = P.project(P.load("users"), ["name"])
        j = P.join(pv, u, ["user"], ["name"])
        g = P.groupby(j, ["user"], aggs)
        return P.PhysicalPlan([P.store(g, "dist_out")])

    A_SEED = {"total": ("sum", "estimated_revenue")}
    A_PROBE = {"total": ("sum", "estimated_revenue"),
               "n": ("count", "estimated_revenue"),
               "mx": ("max", "estimated_revenue")}

    n_users = max(200, n_rows // 8)

    def fresh(**kw):
        store = ArtifactStore(root=tempfile.mkdtemp(prefix="dist_bench_"))
        store.put("page_views",
                  pigmix.gen_page_views(n_rows, n_users=n_users))
        store.put("users", pigmix.gen_users(n_users=n_users))
        return ReStore(Catalog(store), store, measure_exec=True,
                       repeats=3, **kw)

    def close(rs):
        import shutil
        rs.store.close()
        shutil.rmtree(rs.store.root, ignore_errors=True)

    def timed(rs, plan):
        _, rep = rs.run_plan(plan)
        return rep.total_wall_s, rep

    med = lambda xs: sorted(xs)[len(xs) // 2]     # noqa: E731

    def _tune():
        """Tuning pass (only under RESTORE_AUTOTUNE=1): measure the
        probe workload per candidate, reject any candidate that
        overflowed a bucket or probe window (a dropped-row retry is
        never worth a faster wall), persist the winners."""
        from repro.kernels import autotune
        if not autotune.enabled():
            return
        table = autotune.get_table(refresh=True)

        def run_arm(**kw):
            rs = fresh(heuristic="off", rewrite_enabled=False,
                       semantic=False, mesh=mesh, **kw)
            t, rep = timed(rs, probe(A_PROBE))
            bad = any(j.stats.shuffle_overflow or j.stats.join_overflow
                      or j.stats.shuffle_retries
                      for j in rep.jobs if j.stats)
            close(rs)
            return 1e9 if bad else t

        # skew: the per-destination bucket headroom of the exchange —
        # tuned with partition-blind loads so the exchange actually
        # runs.  The candidate is pinned into the live table first:
        # the engine reads the knob through choose(), which shadows
        # any constructor argument once an entry exists.
        def skew_measure(s):
            table.put("exchange", 0, "row", "skew", float(s))
            table.save(autotune.table_path())
            autotune.get_table(refresh=True)
            return run_arm(partition_aware=False)

        best_skew = autotune.tune("exchange", 0, "row", "skew",
                                  [1.25, 2.0, 4.0], skew_measure,
                                  table=table, reps=1)
        skew_measure(best_skew)      # leave the winner in the table
        # probe slack: extra hash-tie window width of the join probe —
        # tuned on the co-partitioned path the arms below run
        def slack_measure(s):
            table.put("join_probe", 0, "uint32", "slack", int(s))
            for b in range(8, 21):
                table.put("join_probe", 1 << b, "uint32", "slack", int(s))
            table.save(autotune.table_path())
            autotune.get_table(refresh=True)
            return run_arm()

        best = autotune.tune("join_probe", 0, "uint32", "slack",
                             [1, 2, 4], slack_measure, table=table, reps=1)
        slack_measure(best)          # leave the winner in the table
        # Pallas scatter tile: priced analytically (roofline) — a CPU
        # host cannot time the real kernel, hardware runs would measure
        price = autotune.scatter_tile_price(n_rows, N_SHARDS)
        autotune.tune("partition_scatter", n_rows, "uint32", "tile_n",
                      [256, 512, 1024, 2048], price,
                      table=table, price=price, top_k=4, reps=1)
        table.save(autotune.table_path())
        autotune.get_table(refresh=True)

    _tune()
    t_single, t_mesh, t_blind, t_copart = [], [], [], []
    skipped = 0
    for _ in range(trials):
        rs = fresh(heuristic="off", rewrite_enabled=False, semantic=False)
        t_single.append(timed(rs, probe(A_PROBE))[0])
        close(rs)

        rs = fresh(heuristic="off", rewrite_enabled=False, semantic=False,
                   mesh=mesh)
        t_mesh.append(timed(rs, probe(A_PROBE))[0])
        close(rs)

        for aware, bucket in ((False, t_blind), (True, t_copart)):
            rs = fresh(heuristic="aggressive", mesh=mesh,
                       partition_aware=aware)
            rs.run_plan(probe(A_SEED))            # warm: join artifact
            t, rep = timed(rs, probe(A_PROBE))
            bucket.append(t)
            if aware:
                skipped += sum(j.stats.shuffles_skipped
                               for j in rep.jobs if j.stats)
            close(rs)

    rec = {
        "n_rows": n_rows, "n_shards": N_SHARDS, "trials": trials,
        "arms": {"t_single_s": round(med(t_single), 6),
                 "t_mesh_plain_s": round(med(t_mesh), 6),
                 "t_reuse_blind_s": round(med(t_blind), 6),
                 "t_reuse_copart_s": round(med(t_copart), 6)},
        "shuffles_skipped": skipped,
        "speedup_copart_vs_blind": round(
            med(t_blind) / max(med(t_copart), 1e-9), 4),
        "speedup_copart_vs_plain": round(
            med(t_mesh) / max(med(t_copart), 1e-9), 4),
        "mesh_vs_single": round(
            med(t_single) / max(med(t_mesh), 1e-9), 4),
    }
    assert skipped > 0, "partition-aware arm never skipped an exchange"
    with open(out_path, "w") as f:
        json.dump(rec, f)


# ---------------------------------------------------------------------------
# Parent


def run(label: str | None = None, n_rows: int = 1 << 16,
        out_path: str = OUT, trials: int = 3):
    from benchmarks.common import emit

    # CI sizes the sweep down via env (the docs job exercises the bench
    # on every push; the committed BENCH_core.json entry uses defaults)
    n_rows = int(os.environ.get("DIST_BENCH_NROWS", n_rows))
    trials = int(os.environ.get("DIST_BENCH_TRIALS", trials))

    child_out = tempfile.mktemp(suffix=".json")
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_SHARDS}"
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", child_out,
         "--n-rows", str(n_rows), "--trials", str(trials)],
        env=env, capture_output=True, text=True, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(f"distributed bench child failed:\n"
                           f"{proc.stderr[-3000:]}")
    with open(child_out) as f:
        rec = json.load(f)
    os.unlink(child_out)
    rec["label"] = label or "run"

    a = rec["arms"]
    emit("dist/single_device", a["t_single_s"], "plain")
    emit("dist/mesh8_plain", a["t_mesh_plain_s"],
         f"vs_single={rec['mesh_vs_single']:.2f}")
    emit("dist/mesh8_reuse_blind", a["t_reuse_blind_s"],
         "warm;monolithic artifact")
    emit("dist/mesh8_reuse_copart", a["t_reuse_copart_s"],
         f"warm;speedup_vs_blind={rec['speedup_copart_vs_blind']:.2f};"
         f"skipped={rec['shuffles_skipped']}")

    doc = {"runs": []}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    runs = doc.setdefault("dist_runs", [])
    runs.append(rec)
    # keep bounded same-label history (newest last) for the regression gate
    kept, per_label = [], {}
    for r in reversed(runs):
        per_label[r["label"]] = per_label.get(r["label"], 0) + 1
        if per_label[r["label"]] <= HISTORY_PER_LABEL:
            kept.append(r)
    doc["dist_runs"] = list(reversed(kept))
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    emit("dist/summary", 0.0,
         f"copart_vs_blind={rec['speedup_copart_vs_blind']:.2f};"
         f"out={out_path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", default=None)
    ap.add_argument("--n-rows", type=int, default=1 << 16)
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--label", default=None)
    args = ap.parse_args()
    if args.child:
        _child(args.n_rows, args.trials, args.child)
    else:
        run(label=args.label, n_rows=args.n_rows, trials=args.trials)


if __name__ == "__main__":
    main()
