"""Multi-query batch-optimizer benchmark, appended to ``BENCH_core.json``
as ``mqo_runs`` (DESIGN.md §16).

Protocol — one batch of 7 queries from 3 tenants, all built through the
Pig-style DSL, engineered so the overlap sits *mid-job* (an expensive
shared FOREACH + selective FILTERs under divergent GROUPBYs, plus a
filter-variant family that only subsumption can share).  Blocking-op
boundaries are content-addressed and reused by plain sequential ReStore
after one sighting, so this workload isolates what batching adds:

  * no-reuse    — heuristic off, rewriting off: every query pays the
                  full pipeline (the paper's baseline);
  * sequential  — one cost-mode driver, queries run in arrival order:
                  the seen-once admission gate means shared chains
                  execute twice before the repository steps in, and the
                  filter variants never cross-share (each is seen once);
  * batched     — same cost-mode configuration, but the batch goes
                  through ``run_batch``: common sub-plans execute once
                  in a deduplicated shared prefix, known-uses hints
                  admit them with certain (not estimated) consumer
                  counts, and the subsumed variants compensate from the
                  covering chain.

All arms use measure_exec=True (jobs warmed off the clock — compile time
is excluded, as everywhere in this harness) on a disk-backed store, and
run MQO_BENCH_TRIALS times taking the median; batched time counts
planning + shared prefix + every per-query run.  The record also audits
``identical`` (batched results bit-identical to sequential) and
``dup_executions`` (a shared sub-plan executing twice anywhere is a
correctness bug in the optimizer, not a perf detail).

Env knobs: MQO_BENCH_NROWS (default 1<<15), MQO_BENCH_TRIALS (default 3).
"""
from __future__ import annotations

import json
import os
import shutil
import statistics
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

import numpy as np                                        # noqa: E402

from benchmarks.common import emit, fresh_restore         # noqa: E402
from repro.core.mqo import run_batch                      # noqa: E402
from repro.dataflow.builder import Dataflow, col          # noqa: E402

OUT = os.path.join(_ROOT, "BENCH_core.json")

N_ROWS = int(os.environ.get("MQO_BENCH_NROWS", 1 << 15))
TRIALS = int(os.environ.get("MQO_BENCH_TRIALS", 3))
N_TENANTS = 3


def _heavy() -> Dataflow:
    """The expensive shared map phase: one wide FOREACH every tenant's
    query starts from (score in [0, 553))."""
    return Dataflow.load("page_views").foreach(
        user=col("user"),
        ts=col("timespent"),
        score=col("timespent") * 3 + col("timestamp") * 11,
        rev=col("estimated_revenue") * 2 + col("timespent"),
        load=col("timespent") * col("timespent") + col("action") * 13,
        wt=col("timestamp") * 7 + col("action") % 5,
    )


def make_batch():
    """7 queries, 3 tenants: an exact-shared selective chain under three
    divergent group-bys, a subsumption family of score thresholds, and
    one more exact pair on a different column."""
    hot = _heavy().filter(col("score") > 500)
    cool = _heavy().filter(col("load") > 9000)

    def var(t):
        return _heavy().filter(col("score") > t)

    queries = [
        hot.group_by("user", s=("sum", "score"),
                     n=("count", "ts")).store("mqo_q1").build(),
        hot.group_by("ts", r=("sum", "rev")).store("mqo_q2").build(),
        hot.group_by("wt", v=("mean", "load")).store("mqo_q3").build(),
        var(400).group_by("user", a=("mean", "rev")).store("mqo_q4")
           .build(),
        var(460).group_by("ts", b=("sum", "load")).store("mqo_q5")
           .build(),
        var(500).group_by("wt", c=("count", "ts")).store("mqo_q6")
           .build(),
        cool.group_by("user", w=("sum", "wt")).store("mqo_q7").build(),
    ]
    tenants = ["a", "b", "c", "a", "b", "c", "a"]
    return queries, tenants


def _canon(table):
    d = table.to_numpy()

    def key(a):
        return (np.ascontiguousarray(a).view(f"S{a.shape[1]}").ravel()
                if a.ndim == 2 else a)

    order = np.lexsort(tuple(key(d[c]) for c in sorted(d, reverse=True)))
    return {c: d[c][order] for c in sorted(d)}


def _identical(a, b) -> bool:
    if set(a) != set(b):
        return False
    for k in a:
        ca, cb = _canon(a[k]), _canon(b[k])
        if set(ca) != set(cb) or any(not np.array_equal(ca[c], cb[c])
                                     for c in ca):
            return False
    return True


def _teardown(rs) -> None:
    rs.store.close()
    shutil.rmtree(rs.store.root, ignore_errors=True)


def _trial(queries):
    """One cold trial of all three arms; returns
    (t_noreuse, t_sequential, t_batched, seq_results, batch_result)."""
    rs = fresh_restore(N_ROWS, "off", rewrite=False)
    t_noreuse = sum(rs.run(q)[1].total_wall_s for q in queries)
    _teardown(rs)

    rs = fresh_restore(N_ROWS, "cost", rewrite=True)
    seq = [rs.run(q) for q in queries]
    t_sequential = sum(rep.total_wall_s for _, rep in seq)
    _teardown(rs)

    rs = fresh_restore(N_ROWS, "cost", rewrite=True)
    br = run_batch(rs, queries)
    t_batched = (br.batch.planning_s + br.shared_wall_s
                 + sum(rep.total_wall_s for rep in br.reports))
    _teardown(rs)
    return (t_noreuse, t_sequential, t_batched,
            [out for out, _ in seq], br)


def run(label: str | None = None, out_path: str = OUT):
    queries, tenants = make_batch()
    rows = []
    for _ in range(TRIALS):
        rows.append(_trial(queries))
    t_noreuse = statistics.median(r[0] for r in rows)
    t_sequential = statistics.median(r[1] for r in rows)
    t_batched = statistics.median(r[2] for r in rows)
    seq_results, br = rows[-1][3], rows[-1][4]
    identical = all(_identical(b, s)
                    for b, s in zip(br.results, seq_results))
    dups = max(r[4].dup_executions for r in rows)

    sp_seq = t_sequential / max(t_batched, 1e-9)
    sp_plain = t_noreuse / max(t_batched, 1e-9)
    emit("mqo/no_reuse", t_noreuse, f"n_rows={N_ROWS}")
    emit("mqo/sequential", t_sequential,
         f"speedup_vs_plain={t_noreuse / max(t_sequential, 1e-9):.2f}x")
    emit("mqo/batched", t_batched,
         f"speedup_vs_sequential={sp_seq:.2f}x;"
         f"shared={len(br.batch.shared)};dups={dups};"
         f"identical={identical}")

    rec = {
        "label": label or "run",
        "n_rows": N_ROWS,
        "n_queries": len(queries),
        "n_tenants": len(set(tenants)),
        "trials": TRIALS,
        "t_noreuse_s": round(t_noreuse, 4),
        "t_sequential_s": round(t_sequential, 4),
        "t_batched_s": round(t_batched, 4),
        "speedup_batched_vs_sequential": round(sp_seq, 4),
        "speedup_batched_vs_noreuse": round(sp_plain, 4),
        "shared_subplans": len(br.batch.shared),
        "semantic_subplans": sum(1 for s in br.batch.shared if s.semantic),
        "dup_executions": dups,
        "identical": identical,
    }

    doc = {"runs": []}
    if os.path.exists(out_path):
        with open(out_path) as f:
            doc = json.load(f)
    runs = doc.setdefault("mqo_runs", [])
    doc["mqo_runs"] = [r for r in runs if r["label"] != rec["label"]]
    doc["mqo_runs"].append(rec)
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    emit("mqo/done", 0.0, f"out={out_path}")
    return rec


if __name__ == "__main__":
    run(label=sys.argv[1] if len(sys.argv) > 1 else None)
