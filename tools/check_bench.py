"""BENCH_core.json gate: schema validation + speedup-regression check.

Two jobs:

  1. **Schema** — every run list (``runs`` / ``policy_runs`` /
     ``semantic_runs`` / ``dist_runs``) must carry the fields its
     benchmark writes, so a refactor that silently changes the snapshot
     format fails CI instead of rotting the history.
  2. **Regression gate** — within each list, consecutive entries with
     the SAME label are compared on their headline reuse-speedup
     metric; a drop of more than ``MAX_REGRESSION`` (20%) fails.
     Labels isolate scales: the small CI run (label "ci") is never
     compared against a committed full-size entry.

Additionally the committed full-size entries must meet acceptance
floors (entries below ``FLOOR_MIN_ROWS`` rows — CI smoke sizes — are
exempt):

  * ``runs`` — every per-query reuse speedup at least
    ``MIN_QUERY_REUSE``x (with a small timing-noise tolerance: streaming
    queries whose splice the L7 guard declines legitimately sit AT 1.0);
    a committed query below 1x means reuse made it slower (ISSUE 7);
  * ``dist_runs`` — co-partitioned reuse at least ``MIN_COPART_SPEEDUP``x
    faster than partition-blind reuse (ISSUE 4), and the plain 8-way
    mesh at least ``MIN_MESH_VS_SINGLE``x the single-device cold run
    (ISSUE 7 — sharded execution must not lose to one device);
  * ``delta_runs`` — at append fractions ≤ ``DELTA_FLOOR_MAX_FRAC``,
    delta refresh at least ``MIN_DELTA_SPEEDUP``x faster than
    delete-and-recompute for the groupby and join templates (ISSUE 5);
    every sweep point of every entry (any size) must also record
    ``identical: true`` — a refresh that is fast but wrong gates red;
  * ``service_runs`` — 4-worker goodput at least ``MIN_SERVICE_SCALING``x
    the 1-worker goodput at full size (ISSUE 6); every entry of ANY
    size must record ``dup_executions == 0`` (the singleflight
    invariant) and at least one singleflight hit;
  * ``tier_runs`` — speculative prefetch at least
    ``MIN_PREFETCH_SPEEDUP``x faster than demand paging at full size
    (ISSUE 8); every entry of ANY size must record ``identical: true``
    (both arms returned bit-identical tables) and a finite, positive
    ``cold_start_s`` (the cold start from the remote tier completed);
  * ``prefix_runs`` — the serving prefix-reuse stream at full size
    (``PREFIX_FLOOR_MIN_REQUESTS`` requests and
    ``PREFIX_FLOOR_MIN_PREFIX`` prefix tokens) must run at least
    ``MIN_PREFIX_SPEEDUP``x faster with KV reuse than without, with a
    reused-token fraction of at least ``MIN_PREFIX_REUSED_FRAC``
    (ISSUE 10); every entry of ANY size must record
    ``identical: true`` — greedy decodes with reuse must be
    bit-identical to the no-reuse arm;
  * ``mqo_runs`` — batched execution at least ``MIN_MQO_SPEEDUP``x
    faster than sequential ReStore at full size (ISSUE 9); every entry
    of ANY size must record ``identical: true`` (batched results
    bit-identical to sequential), ``dup_executions == 0`` (a shared
    sub-plan executing twice is an optimizer bug, not noise) and at
    least one shared sub-plan (a batch that shares nothing measures
    nothing).

Usage: python tools/check_bench.py [path]   (exit 0 = all checks pass)
"""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_PATH = os.path.join(ROOT, "BENCH_core.json")

MAX_REGRESSION = float(os.environ.get("CHECK_BENCH_MAX_REGRESSION", 0.20))
MIN_COPART_SPEEDUP = float(os.environ.get("CHECK_BENCH_MIN_COPART", 2.0))
MIN_MESH_VS_SINGLE = float(os.environ.get("CHECK_BENCH_MIN_MESH", 1.0))
MIN_QUERY_REUSE = float(os.environ.get("CHECK_BENCH_MIN_QUERY_REUSE", 1.0))
# reuse-speedup floors compare medians of repeated wall times; queries
# pinned AT the floor (declined splices re-execute, speedup == 1.0 by
# construction) need headroom for timer noise
QUERY_NOISE_TOL = float(os.environ.get("CHECK_BENCH_QUERY_NOISE_TOL", 0.05))
MIN_DELTA_SPEEDUP = float(os.environ.get("CHECK_BENCH_MIN_DELTA", 3.0))
MIN_SERVICE_SCALING = float(os.environ.get("CHECK_BENCH_MIN_SERVICE", 1.5))
MIN_PREFETCH_SPEEDUP = float(os.environ.get("CHECK_BENCH_MIN_PREFETCH", 1.3))
MIN_MQO_SPEEDUP = float(os.environ.get("CHECK_BENCH_MIN_MQO", 1.5))
MIN_PREFIX_SPEEDUP = float(os.environ.get("CHECK_BENCH_MIN_PREFIX", 2.0))
MIN_PREFIX_REUSED_FRAC = float(
    os.environ.get("CHECK_BENCH_MIN_PREFIX_FRAC", 0.5))
PREFIX_FLOOR_MIN_REQUESTS = 32   # the prefix bench's full size...
PREFIX_FLOOR_MIN_PREFIX = 256    # ...in requests and prefix tokens
DELTA_FLOOR_MAX_FRAC = 0.10      # the ISSUE 5 "≤10% append" regime
DELTA_FLOOR_TEMPLATES = ("groupby", "join")
FLOOR_MIN_ROWS = 1 << 16         # full-size entries only
SERVICE_FLOOR_MIN_ROWS = 1 << 15  # the service bench's full size
MQO_FLOOR_MIN_ROWS = 1 << 15     # the MQO bench's full size

# run-list name -> (required fields, headline metric fn or None)


def _semantic_headline(rec):
    at50 = [r for r in rec["sweep"] if r.get("overlap") == 0.50]
    return at50[0]["speedup_vs_plain"] if at50 else None


def _delta_headline(rec):
    pts = [r["speedup"] for r in rec["sweep"]
           if r.get("frac", 1.0) <= DELTA_FLOOR_MAX_FRAC]
    return min(pts) if pts else None


SCHEMAS = {
    "runs": (("label", "n_rows", "queries", "avg_store_overhead",
              "avg_reuse_speedup"),
             lambda r: r["avg_reuse_speedup"]),
    "policy_runs": (("label", "n_events", "n_rows", "budgets"), None),
    "semantic_runs": (("label", "n_rows", "sweep"), _semantic_headline),
    "dist_runs": (("label", "n_rows", "n_shards", "arms",
                   "speedup_copart_vs_blind", "mesh_vs_single",
                   "shuffles_skipped"),
                  lambda r: r["speedup_copart_vs_blind"]),
    "delta_runs": (("label", "n_rows", "sweep"), _delta_headline),
    "service_runs": (("label", "n_rows", "n_events", "worker_sweep",
                      "goodput_scaling_4w_vs_1w", "singleflight_hits",
                      "dup_executions"),
                     lambda r: r["goodput_scaling_4w_vs_1w"]),
    "tier_runs": (("label", "n_rows", "n_artifacts", "probes",
                   "speedup_prefetch", "prefetch_hit_rate",
                   "cold_start_s", "identical"),
                  lambda r: r["speedup_prefetch"]),
    "prefix_runs": (("label", "n_requests", "n_prompts", "prefix_len",
                     "suffix_len", "n_decode", "wall_speedup",
                     "reused_token_frac", "p50_reuse_ms", "p95_reuse_ms",
                     "identical"),
                    lambda r: r["wall_speedup"]),
    "mqo_runs": (("label", "n_rows", "n_queries", "n_tenants",
                  "t_noreuse_s", "t_sequential_s", "t_batched_s",
                  "speedup_batched_vs_sequential",
                  "speedup_batched_vs_noreuse", "shared_subplans",
                  "dup_executions", "identical"),
                 lambda r: r["speedup_batched_vs_sequential"]),
}


def check(path: str) -> int:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict):
        print(f"error: {path} top level must be an object")
        return 1

    errors = []
    n_checked = 0
    for list_name, (fields, headline) in SCHEMAS.items():
        entries = doc.get(list_name, [])
        if not isinstance(entries, list):
            errors.append(f"{list_name}: must be a list")
            continue
        n_before = len(errors)
        for i, rec in enumerate(entries):
            missing = [f for f in fields if f not in rec]
            if missing:
                errors.append(f"{list_name}[{i}] "
                              f"(label={rec.get('label')!r}): "
                              f"missing fields {missing}")
        if len(errors) > n_before:
            continue        # THIS list is malformed; others still gate

        # regression gate: consecutive same-label entries
        if headline is not None:
            by_label = {}
            for rec in entries:
                by_label.setdefault(rec["label"], []).append(rec)
            for label, seq in by_label.items():
                for prev, cur in zip(seq, seq[1:]):
                    p, c = headline(prev), headline(cur)
                    if p is None or c is None or p <= 0:
                        continue
                    n_checked += 1
                    if c < (1.0 - MAX_REGRESSION) * p:
                        errors.append(
                            f"{list_name} label={label!r}: reuse speedup "
                            f"regressed {p:.2f} -> {c:.2f} "
                            f"(> {MAX_REGRESSION:.0%} drop)")

        # per-query reuse floor on full-size core-bench entries (ISSUE 7)
        if list_name == "runs":
            bar = MIN_QUERY_REUSE * (1.0 - QUERY_NOISE_TOL)
            for rec in entries:
                if rec["n_rows"] < FLOOR_MIN_ROWS // 2:
                    continue     # core bench's full size is 1<<15
                for q, m in sorted(rec["queries"].items()):
                    n_checked += 1
                    s = m.get("reuse_speedup", 0.0)
                    if s < bar:
                        errors.append(
                            f"runs label={rec['label']!r} query={q}: "
                            f"reuse speedup {s:.2f} below the "
                            f"{MIN_QUERY_REUSE:.1f}x floor (reuse made "
                            f"it slower; {rec['n_rows']} rows)")

        # acceptance floors for full-size distributed entries
        if list_name == "dist_runs":
            for rec in entries:
                if rec["n_rows"] >= FLOOR_MIN_ROWS:
                    n_checked += 2
                    s = rec["speedup_copart_vs_blind"]
                    if s < MIN_COPART_SPEEDUP:
                        errors.append(
                            f"dist_runs label={rec['label']!r}: "
                            f"co-partitioned reuse speedup {s:.2f} below "
                            f"the {MIN_COPART_SPEEDUP:.1f}x floor "
                            f"({rec['n_rows']} rows)")
                    ms = rec["mesh_vs_single"]
                    if ms < MIN_MESH_VS_SINGLE * (1.0 - QUERY_NOISE_TOL):
                        errors.append(
                            f"dist_runs label={rec['label']!r}: plain "
                            f"mesh vs single-device {ms:.2f} below the "
                            f"{MIN_MESH_VS_SINGLE:.1f}x floor "
                            f"({rec['n_rows']} rows)")

        # acceptance floors for delta-refresh entries (ISSUE 5)
        if list_name == "delta_runs":
            for rec in entries:
                for pt in rec["sweep"]:
                    n_checked += 1
                    if not pt.get("identical", False):
                        errors.append(
                            f"delta_runs label={rec['label']!r} "
                            f"{pt.get('template')}@{pt.get('frac')}: "
                            f"refresh result not bit-identical to "
                            f"recompute")
                    if (rec["n_rows"] >= FLOOR_MIN_ROWS
                            and pt.get("frac", 1.0) <= DELTA_FLOOR_MAX_FRAC
                            and pt.get("template")
                            in DELTA_FLOOR_TEMPLATES
                            and pt.get("speedup", 0.0)
                            < MIN_DELTA_SPEEDUP):
                        errors.append(
                            f"delta_runs label={rec['label']!r} "
                            f"{pt['template']}@{pt['frac']}: refresh "
                            f"speedup {pt['speedup']:.2f} below the "
                            f"{MIN_DELTA_SPEEDUP:.1f}x floor "
                            f"({rec['n_rows']} rows)")

        # acceptance floors for concurrent-service entries (ISSUE 6)
        if list_name == "service_runs":
            for rec in entries:
                n_checked += 1
                if rec["dup_executions"] != 0:
                    errors.append(
                        f"service_runs label={rec['label']!r}: "
                        f"{rec['dup_executions']} duplicate executions "
                        f"(singleflight invariant is == 0)")
                if rec["singleflight_hits"] < 1:
                    errors.append(
                        f"service_runs label={rec['label']!r}: no "
                        f"singleflight hits recorded (stampede phase "
                        f"did not run)")
                if rec["n_rows"] >= SERVICE_FLOOR_MIN_ROWS:
                    s = rec["goodput_scaling_4w_vs_1w"]
                    if s < MIN_SERVICE_SCALING:
                        errors.append(
                            f"service_runs label={rec['label']!r}: "
                            f"4w/1w goodput scaling {s:.2f} below the "
                            f"{MIN_SERVICE_SCALING:.1f}x floor "
                            f"({rec['n_rows']} rows)")

        # acceptance floors for tiered-store entries (ISSUE 8)
        if list_name == "tier_runs":
            for rec in entries:
                n_checked += 2
                if not rec.get("identical", False):
                    errors.append(
                        f"tier_runs label={rec['label']!r}: prefetch "
                        f"and demand-paging arms not bit-identical")
                cold = rec.get("cold_start_s")
                if not (isinstance(cold, (int, float)) and cold > 0):
                    errors.append(
                        f"tier_runs label={rec['label']!r}: cold start "
                        f"from the remote tier did not complete "
                        f"(cold_start_s={cold!r})")
                if rec["n_rows"] >= FLOOR_MIN_ROWS:
                    n_checked += 1
                    s = rec["speedup_prefetch"]
                    if s < MIN_PREFETCH_SPEEDUP:
                        errors.append(
                            f"tier_runs label={rec['label']!r}: prefetch "
                            f"speedup {s:.2f} below the "
                            f"{MIN_PREFETCH_SPEEDUP:.1f}x floor "
                            f"({rec['n_rows']} rows)")

        # acceptance floors for serving prefix-reuse entries (ISSUE 10)
        if list_name == "prefix_runs":
            for rec in entries:
                n_checked += 1
                if not rec.get("identical", False):
                    errors.append(
                        f"prefix_runs label={rec['label']!r}: greedy "
                        f"decodes with reuse not bit-identical to the "
                        f"no-reuse arm")
                if (rec["n_requests"] >= PREFIX_FLOOR_MIN_REQUESTS
                        and rec["prefix_len"] >= PREFIX_FLOOR_MIN_PREFIX):
                    n_checked += 2
                    s = rec["wall_speedup"]
                    if s < MIN_PREFIX_SPEEDUP:
                        errors.append(
                            f"prefix_runs label={rec['label']!r}: wall "
                            f"speedup {s:.2f} below the "
                            f"{MIN_PREFIX_SPEEDUP:.1f}x floor "
                            f"({rec['n_requests']} requests, prefix "
                            f"{rec['prefix_len']})")
                    fr = rec["reused_token_frac"]
                    if fr < MIN_PREFIX_REUSED_FRAC:
                        errors.append(
                            f"prefix_runs label={rec['label']!r}: "
                            f"reused-token fraction {fr:.2f} below the "
                            f"{MIN_PREFIX_REUSED_FRAC:.2f} floor")

        # acceptance floors for batch-optimizer entries (ISSUE 9)
        if list_name == "mqo_runs":
            for rec in entries:
                n_checked += 3
                if not rec.get("identical", False):
                    errors.append(
                        f"mqo_runs label={rec['label']!r}: batched "
                        f"results not bit-identical to sequential")
                if rec["dup_executions"] != 0:
                    errors.append(
                        f"mqo_runs label={rec['label']!r}: "
                        f"{rec['dup_executions']} duplicate shared-"
                        f"sub-plan executions (invariant is == 0)")
                if rec["shared_subplans"] < 1:
                    errors.append(
                        f"mqo_runs label={rec['label']!r}: no shared "
                        f"sub-plans found (the batch workload must "
                        f"overlap)")
                if rec["n_rows"] >= MQO_FLOOR_MIN_ROWS:
                    n_checked += 1
                    s = rec["speedup_batched_vs_sequential"]
                    if s < MIN_MQO_SPEEDUP:
                        errors.append(
                            f"mqo_runs label={rec['label']!r}: batched "
                            f"vs sequential speedup {s:.2f} below the "
                            f"{MIN_MQO_SPEEDUP:.1f}x floor "
                            f"({rec['n_rows']} rows)")

    if errors:
        for e in errors:
            print(f"check_bench: {e}")
        return 1
    n_entries = sum(len(doc.get(k, [])) for k in SCHEMAS)
    print(f"bench check OK: {n_entries} entries across "
          f"{sum(1 for k in SCHEMAS if doc.get(k))} run lists, "
          f"{n_checked} gate comparisons")
    return 0


if __name__ == "__main__":
    sys.exit(check(sys.argv[1] if len(sys.argv) > 1 else DEFAULT_PATH))
