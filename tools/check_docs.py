"""Docs reference checker: every ``DESIGN.md §N`` cited anywhere under
``src/**`` must resolve to an actual ``## §N`` section of DESIGN.md, so
docstring references can't silently rot as the design doc evolves.

Plain "paper §N" citations (the ReStore paper's own sections) and
"EXPERIMENTS.md §..." notes are out of scope — only references that name
DESIGN.md are checked.

Usage: python tools/check_docs.py   (exit 0 = all references resolve)
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DESIGN = os.path.join(ROOT, "DESIGN.md")
SRC = os.path.join(ROOT, "src")

REF_RE = re.compile(r"DESIGN\.md[^§\n]{0,40}§(\d+)")
SECTION_RE = re.compile(r"^##\s+§(\d+)\b", re.MULTILINE)


def design_sections() -> set[str]:
    with open(DESIGN) as f:
        return set(SECTION_RE.findall(f.read()))


def iter_source_files():
    for dirpath, dirnames, filenames in os.walk(SRC):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in filenames:
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def main() -> int:
    sections = design_sections()
    if not sections:
        print(f"error: no '## §N' sections found in {DESIGN}")
        return 1
    bad = []
    n_refs = 0
    for path in iter_source_files():
        with open(path) as f:
            text = f.read()
        for m in REF_RE.finditer(text):
            n_refs += 1
            if m.group(1) not in sections:
                line = text[:m.start()].count("\n") + 1
                bad.append((os.path.relpath(path, ROOT), line, m.group(1)))
    if bad:
        for path, line, sec in bad:
            print(f"{path}:{line}: reference to DESIGN.md §{sec}, "
                  f"but DESIGN.md has no '## §{sec}' section")
        print(f"\n{len(bad)} dangling reference(s); DESIGN.md defines "
              f"§{{{', '.join(sorted(sections, key=int))}}}")
        return 1
    print(f"docs check OK: {n_refs} DESIGN.md § references across src/ "
          f"all resolve ({len(sections)} sections defined)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
