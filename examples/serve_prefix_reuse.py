"""Serving-time prefix-KV reuse through the unified repository
(DESIGN.md §17).

Walkthrough of the one-economics-engine serving stack, with every claim
asserted:

  1. cold prefill → snapshot stored as a ``kind="prefix"`` repository
     entry; a later prompt sharing the system prefix takes a
     subsumption hit and prefills only its suffix — bit-identical to a
     session without reuse
  2. multi-turn append: extending a stored prefix re-keys the entry in
     place (the §12 delta-refresh path) instead of storing a second
     snapshot
  3. tiering: snapshots demoted to the remote RSB1 blob tier are
     promoted back on use and still decode bit-identically

Usage: PYTHONPATH=src python examples/serve_prefix_reuse.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np     # noqa: E402
import jax             # noqa: E402

from repro.configs import get_config                     # noqa: E402
from repro.models.api import build                       # noqa: E402
from repro.serve.kv_repo import KVRepository             # noqa: E402
from repro.serve.kv_store import KVTierStore             # noqa: E402
from repro.serve.session import ServeSession             # noqa: E402


def main():
    cfg = get_config("qwen3-1.7b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    remote = tempfile.mkdtemp(prefix="kv_remote_")
    kv = KVRepository(model_version="demo-v1",
                      store=KVTierStore(remote_root=remote))
    sess = ServeSession(model, params, max_len=96, kv=kv)
    plain = ServeSession(model, params, max_len=96)

    rng = np.random.default_rng(0)
    system_prefix = rng.integers(1, cfg.vocab_size, 48)

    # -- 1. store, then subsumption hit ------------------------------
    total_prefilled = total_reused = 0
    for i in range(4):
        user_part = rng.integers(1, cfg.vocab_size, 16)
        prompt = np.concatenate([system_prefix, user_part])
        out, stats = sess.serve(prompt, n_decode=8)
        ref, _ = plain.serve(prompt, n_decode=8)
        assert (out == ref).all(), "reuse must not change outputs"
        total_prefilled += stats.prefilled_tokens
        total_reused += stats.reused_tokens
        print(f"request {i}: reused {stats.reused_tokens:3d} tokens, "
              f"prefilled {stats.prefilled_tokens:3d}")
    assert total_reused > 0, "later requests must hit the shared prefix"
    assert kv.stats()["semantic_hits"] > 0     # prefix-subsumption hits

    # -- 2. append-style extension rides the refresh path ------------
    first = np.concatenate([system_prefix,
                            rng.integers(1, cfg.vocab_size, 8)])
    sess2 = ServeSession(model, params, max_len=96, kv=kv, every_k=0)
    sess2.serve(first, n_decode=0)
    n_before = len(kv)
    turn2 = np.concatenate([first, rng.integers(1, cfg.vocab_size, 8)])
    hit = kv.probe(turn2)
    assert hit is not None and hit.length == len(first)
    hit = kv.splice(hit)
    _logits, cache = sess2._prefill(turn2, hit.cache, hit.length)
    entry = kv.extend(hit, turn2, cache)
    assert len(kv) == n_before               # re-keyed, not duplicated
    assert kv.repository.refreshes >= 1
    follow = kv.probe(turn2)
    assert follow is not None and follow.exact \
        and follow.entry is entry
    print(f"append extension: entry re-keyed in place "
          f"({kv.repository.refreshes} refreshes, {len(kv)} entries)")

    # -- 3. tier round-trip stays bit-identical ----------------------
    probe_prompt = np.concatenate(
        [system_prefix, rng.integers(1, cfg.vocab_size, 16)])
    warm_out, _ = sess.serve(probe_prompt, n_decode=8)
    for e in list(kv.entries.values()):
        kv.store.demote_to_remote(e.artifact)
    cold_out, st = sess.serve(probe_prompt, n_decode=8)
    assert (warm_out == cold_out).all(), "tier round-trip changed decode"
    assert st.reused_tokens > 0
    assert kv.store.stats["remote_hits"] > 0
    print(f"tier round-trip: {kv.store.stats['remote_hits']} remote "
          f"promotions, decode bit-identical")

    frac = total_reused / (total_reused + total_prefilled)
    print(f"repository: {len(kv)} prefix entries, "
          f"{kv.total_bytes >> 10} KiB under the shared budget; "
          f"prompt tokens answered from the repository: {frac:.0%}")
    print("serve_prefix_reuse OK")


if __name__ == "__main__":
    main()
