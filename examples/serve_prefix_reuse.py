"""Serving with ReStore-style prefix reuse (beyond-paper extension).

A fleet of prompts sharing a long system prefix: the first request
prefills everything; later requests reuse the stored prefix state and
prefill only their suffix.  Outputs are verified identical to a no-reuse
engine.

Usage: PYTHONPATH=src python examples/serve_prefix_reuse.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np     # noqa: E402
import jax             # noqa: E402

from repro.configs import get_config                     # noqa: E402
from repro.models.api import build                       # noqa: E402
from repro.serve.engine import ServeEngine               # noqa: E402
from repro.serve.prefix_repo import PrefixRepository     # noqa: E402


def main():
    cfg = get_config("qwen3-1.7b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    repo = PrefixRepository(model_version="demo-v1")
    engine = ServeEngine(model, params, max_len=96, prefix_repo=repo)
    plain = ServeEngine(model, params, max_len=96)

    rng = np.random.default_rng(0)
    system_prefix = rng.integers(1, cfg.vocab_size, 48)

    total_prefilled = total_reused = 0
    for i in range(4):
        user_part = rng.integers(1, cfg.vocab_size, 16)
        prompt = np.concatenate([system_prefix, user_part])
        out, stats = engine.serve(prompt, n_decode=8)
        ref, _ = plain.serve(prompt, n_decode=8)
        assert (out == ref).all(), "reuse must not change outputs"
        total_prefilled += stats.prefilled_tokens
        total_reused += stats.reused_tokens
        print(f"request {i}: reused {stats.reused_tokens:3d} tokens, "
              f"prefilled {stats.prefilled_tokens:3d}, "
              f"wall {stats.wall_s:.2f}s")

    frac = total_reused / (total_reused + total_prefilled)
    print(f"prefix repo entries: {len(repo)}; "
          f"fraction of prompt tokens answered from the repository: "
          f"{frac:.0%}")
    print("serve_prefix_reuse OK")


if __name__ == "__main__":
    main()
