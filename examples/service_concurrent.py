"""Concurrent fault-tolerant ReStore service walkthrough (DESIGN.md
§13), with every claim asserted:

  1. two tenants submit workflows to a 4-worker service sharing ONE
     repository — bob's variant reuses the join sub-job alice's query
     materialized moments earlier;
  2. a stampede of identical submissions collapses via singleflight:
     one execution, every ticket gets the (identical) results, and the
     duplicate-execution counter stays 0;
  3. an artifact is corrupted on disk (one flipped byte); the checksum
     catches it on load, the artifact is quarantined, and the query
     transparently falls back to a cold recompute — same answer;
  4. the repository journal survives a "restart": a fresh store +
     recovered repository still answer alice's query with zero
     executed jobs.

Run: PYTHONPATH=src python examples/service_concurrent.py
"""
import os
import tempfile

import numpy as np

from repro.core.repository import Repository
from repro.service.journal import RepositoryJournal
from repro.service.service import ReStoreService
from repro.store.artifacts import ArtifactStore, Catalog, _encode_name
from repro.workloads import pigmix

N_ROWS = 2048


def canon(table):
    d = table.to_numpy()

    def key(a):
        return (np.ascontiguousarray(a).view(f"S{a.shape[1]}").ravel()
                if a.ndim == 2 else a)

    order = np.lexsort(tuple(key(d[c]) for c in sorted(d, reverse=True)))
    return {c: d[c][order] for c in sorted(d)}


def main():
    root = tempfile.mkdtemp(prefix="restore_service_")
    store = ArtifactStore(root=root)
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=N_ROWS)
    svc = ReStoreService(cat, store, Repository(), n_workers=4,
                         journal=RepositoryJournal(root))

    # -- 1. cross-tenant sub-job reuse through the shared repository
    results_a, rep_a = svc.run(pigmix.L3("sum"), tenant="alice")
    assert rep_a.n_executed == 2, "alice runs cold: join + groupby"
    _, rep_b = svc.run(pigmix.L3("mean"), tenant="bob")
    assert not rep_b.jobs[0].executed, \
        "bob's variant reuses alice's join sub-job"
    print(f"[1] alice executed {rep_a.n_executed} jobs cold; "
          f"bob reused her join and executed "
          f"{sum(1 for j in rep_b.jobs if j.executed)}")

    # -- 2. stampede control: 6 identical submissions, one execution
    tickets = [svc.submit(pigmix.L6(), tenant=t)
               for t in ("alice", "bob", "alice", "bob", "carol", "dan")]
    outs = [t.result(timeout=300) for t in tickets]
    st = svc.stats()
    assert st["singleflight_hits"] == 5, "five tickets drafted behind one"
    assert st["dup_executions"] == 0, "the key never executed twice"
    ref = canon(outs[0][0]["L6_out"])
    for results, _ in outs[1:]:
        got = canon(results["L6_out"])
        assert all(np.array_equal(ref[c], got[c]) for c in ref)
    print(f"[2] 6 identical submissions -> "
          f"{st['singleflight_hits']} singleflight hits, "
          f"{st['dup_executions']} duplicate executions")

    # -- 3. corruption -> quarantine -> transparent cold fallback
    store.flush()
    victim = svc.repo.entries[0].artifact
    d = os.path.join(root, _encode_name(victim))
    npz = [f for f in os.listdir(d) if f.endswith(".npz")][0]
    with open(os.path.join(d, npz), "r+b") as f:
        b = f.read(1)
        f.seek(0)
        f.write(bytes([b[0] ^ 0xFF]))        # one flipped bit(ish)
    baseline = canon(results_a["L3_sum_out"])
    svc.stop()

    store2 = ArtifactStore(root=root)        # cold caches: disk is read
    cat2 = Catalog(store2)
    pigmix.register_all(cat2, n_rows=N_ROWS)
    repo2, journal2 = RepositoryJournal.recover(store2)
    assert journal2.reconciled_drops >= 1, \
        "recovery reconciles the corrupt artifact away"
    assert all(store2.exists(e.artifact) and store2.verify(e.artifact)
               for e in repo2.entries)
    svc2 = ReStoreService(cat2, store2, repo2, n_workers=2,
                          journal=journal2)
    results_c, rep_c = svc2.run(pigmix.L3("sum"), tenant="alice")
    got = canon(results_c["L3_sum_out"])
    assert all(np.array_equal(baseline[c], got[c]) for c in baseline), \
        "cold fallback reproduces the original answer exactly"
    print(f"[3] corrupted {victim!r} was quarantined "
          f"(reconciled_drops={journal2.reconciled_drops}); "
          f"recompute matches the original bit-for-bit")

    # -- 4. journal recovery keeps reuse working across the "restart"
    assert rep_c.degraded == 0, "recovery already dropped the bad entry"
    _, rep_d = svc2.run(pigmix.L3("mean"), tenant="bob")
    assert not rep_d.jobs[0].executed, \
        "journal-recovered repository still serves the join sub-job"
    svc2.stop()
    print(f"[4] after restart + recovery: bob's query reused the join "
          f"again ({len(repo2)} entries survived)")
    print("service walkthrough OK")


if __name__ == "__main__":
    main()
