"""Incremental artifact maintenance walkthrough (DESIGN.md §12).

The append → refresh → exact-hit story, with every claim asserted:

  1. a per-user revenue aggregate runs cold and its artifact is stored;
  2. the page_views dataset GROWS by append (`Catalog.append`) — under
     rule R4 alone this would delete the artifact and recompute from
     zero;
  3. `ReStore.maintain()` derives a delta plan (aggregate the appended
     rows only), merges the partial into the stored artifact, and
     rebinds the entry to the new dataset version;
  4. the new-version query is answered WITHOUT executing anything, and
     the answer is bit-identical to a cold recompute over the appended
     data.

Run: PYTHONPATH=src python examples/delta_refresh.py
"""
import numpy as np

from repro.core import plan as P
from repro.core.plan import rebind_load_versions
from repro.core.restore import ReStore
from repro.dataflow.table import Table
from repro.store.artifacts import ArtifactStore, Catalog

N_USERS = 50


def page_views(seed: int, n: int) -> Table:
    rng = np.random.default_rng(seed)
    return Table.from_numpy({
        "user": rng.integers(0, N_USERS, n).astype(np.int32),
        # integer-valued revenue keeps float32 sums exact, so
        # bit-identity below is checkable
        "revenue": rng.integers(0, 100, n).astype(np.float32),
    })


def revenue_by_user() -> P.PhysicalPlan:
    g = P.groupby(P.load("page_views"), ["user"],
                  {"total": ("sum", "revenue"), "n": ("count", "revenue")})
    return P.PhysicalPlan([P.store(g, "rev_out")])


def canon(t: Table):
    d = t.to_numpy()
    order = np.lexsort(tuple(d[c] for c in sorted(d, reverse=True)))
    return {c: d[c][order] for c in sorted(d)}


def main():
    store = ArtifactStore()
    catalog = Catalog(store)
    catalog.register("page_views", page_views(0, 4096))
    rs = ReStore(catalog, store, heuristic="off")

    # 1. cold run: the aggregate is computed and registered
    _, cold = rs.run_plan(revenue_by_user())
    assert cold.n_executed == 1 and len(rs.repo) == 1
    (entry,) = rs.repo.entries
    print(f"cold run executed; artifact {entry.artifact} stored "
          f"(source version {entry.source_versions['page_views']})")

    # 2. the dataset grows by 10% — version bumps, entry goes stale
    catalog.append("page_views", page_views(7, 410))
    assert catalog.version("page_views") == 1
    assert catalog.is_append_since("page_views", 0)
    print(f"appended 410 rows (delta fraction "
          f"{catalog.delta_fraction('page_views', 0):.1%}); "
          f"entry is stale")

    # 3. refresh from the delta instead of R4 delete-and-recompute
    report = rs.maintain(mode="refresh")
    assert report == {"refreshed": 1, "lazy": 0, "deleted": 0}, report
    assert entry.source_versions["page_views"] == 1, \
        "refresh must rebind the entry to the new version"
    print("maintain(): delta aggregated + merged, entry rebound")

    # 4. the new-version query is an exact hit, bit-identical to cold
    plan_v1 = rebind_load_versions(revenue_by_user(), {"page_views": 1})
    got, warm = rs.run_plan(plan_v1)
    assert warm.n_executed == 0 and warm.n_reused == 1, \
        "refreshed entry must answer the new-version query exactly"

    ref_store = ArtifactStore()
    ref_cat = Catalog(ref_store)
    ref_cat.register("page_views", page_views(0, 4096))
    ref_cat.append("page_views", page_views(7, 410))
    ref_rs = ReStore(ref_cat, ref_store, heuristic="off",
                     rewrite_enabled=False, semantic=False)
    ref, _ = ref_rs.run_plan(plan_v1)
    a, b = canon(ref["rev_out"]), canon(got["rev_out"])
    for c in a:
        assert np.array_equal(a[c], b[c]), c
    print("new-version query: 0 jobs executed, result bit-identical "
          "to cold recompute — OK")


if __name__ == "__main__":
    main()
