"""Multi-query batch optimization walkthrough (DESIGN.md §16), with
every claim asserted:

  1. three tenants write their queries in the Pig-style dataflow DSL —
     the front-end is pure notation: a DSL plan is fingerprint-identical
     to hand-built ``core.plan`` wiring, so it shares everything the
     hand-built plan would;
  2. ``optimize_batch`` finds the overlap: the scan+project two of the
     tenants start from is shared exactly, and their filter variants of
     different strength share the weaker (covering) chain by
     subsumption — the third tenant overlaps with nobody and is simply
     passed through;
  3. ``submit_batch`` executes the shared prefix ONCE, fans out one
     ticket per query, and every shared sub-plan is admitted to the
     repository with *known* (not estimated) consumer counts — the
     duplicate-execution audit stays 0;
  4. the batched answers are bit-identical to running each query alone
     on a cold driver.

Run: PYTHONPATH=src python examples/mqo_batch.py
"""
import numpy as np

from repro.core import plan as P
from repro.core.mqo import optimize_batch
from repro.core.restore import ReStore
from repro.dataflow.builder import Dataflow, col
from repro.dataflow.expr import Col
from repro.service.service import ReStoreService
from repro.store.artifacts import ArtifactStore, Catalog
from repro.workloads import pigmix

N_ROWS = 2048


def canon(table):
    d = table.to_numpy()

    def key(a):
        return (np.ascontiguousarray(a).view(f"S{a.shape[1]}").ravel()
                if a.ndim == 2 else a)

    order = np.lexsort(tuple(key(d[c]) for c in sorted(d, reverse=True)))
    return {c: d[c][order] for c in sorted(d)}


def main():
    # ---- 1. three tenants' queries, written in the DSL ----------------
    scan = Dataflow.load("page_views").project("user", "timespent")
    alice = (Dataflow.load("page_views")
             .project("user", "estimated_revenue")
             .group_by("user", rev=("sum", "estimated_revenue"))
             .store("alice_revenue"))
    bob = (scan.filter(col("timespent") > 20)
           .group_by("user", n=("count", "timespent")).store("bob_hot"))
    carol = (scan.filter(col("timespent") > 60)
             .group_by("user", n=("count", "timespent"))
             .store("carol_hotter"))
    queries = [alice, bob, carol]

    # the DSL is pure notation: fingerprints match hand-built wiring
    hand = P.PhysicalPlan([P.store(
        P.groupby(P.project(P.load("page_views"),
                            ["user", "estimated_revenue"]),
                  ["user"], {"rev": ("sum", "estimated_revenue")}),
        "alice_revenue")])
    assert (set(alice.build().fingerprints().values())
            == set(hand.fingerprints().values()))
    print("1. DSL plan is fingerprint-identical to hand-built wiring")

    # ---- 2. the optimizer sees the overlap ---------------------------
    bp = optimize_batch(queries)
    kinds = sorted((s.kind, s.n_consumers, s.semantic) for s in bp.shared)
    # bob and carol share the scan+project exactly; carol's stricter
    # filter is answered from bob's covering chain by subsumption;
    # alice overlaps with nobody — and still gets the right answer
    assert ("PROJECT", 2, False) in kinds
    assert any(k == "FILTER" and sem for k, _, sem in kinds)
    print(f"2. shared sub-plans: {kinds}")
    assert bp.known_uses, "shared artifacts carry known-consumer hints"

    # ---- 3. one shared execution, N tickets --------------------------
    store = ArtifactStore()
    cat = Catalog(store)
    pigmix.register_all(cat, n_rows=N_ROWS)
    svc = ReStoreService(cat, store, n_workers=2, heuristic="cost")
    try:
        tickets = svc.submit_batch(queries, tenants=["alice", "bob",
                                                     "carol"])
        batched = [t.result(timeout=120)[0] for t in tickets]
        st = svc.stats()
    finally:
        svc.stop()
    assert st["batches"] == 1
    assert st["batch_shared_subplans"] == len(bp.shared)
    assert st["dup_executions"] == 0
    print(f"3. batch of {len(queries)} ran with "
          f"{st['batch_shared_subplans']} shared sub-plans and "
          f"0 duplicate executions")

    # ---- 4. bit-identical to cold solo runs --------------------------
    for q, got in zip(queries, batched):
        cold_store = ArtifactStore()
        cold_cat = Catalog(cold_store)
        pigmix.register_all(cold_cat, n_rows=N_ROWS)
        want, _ = ReStore(cold_cat, cold_store, heuristic="off").run(q)
        assert set(got) == set(want)
        for k in got:
            a, b = canon(got[k]), canon(want[k])
            assert all(np.array_equal(a[c], b[c]) for c in a)
    print("4. batched answers bit-identical to cold solo runs")

    # Col is re-exported for hand-built plans; the DSL's `col` is the
    # same Expr type, so predicates compare equal across front-ends
    assert (col("timespent") > 20).key() == (Col("timespent") > 20).key()
    print("ok: multi-query batch optimization walkthrough passed")


if __name__ == "__main__":
    main()
