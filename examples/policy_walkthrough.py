"""Repository economics walkthrough: two workflows sharing one
byte-budgeted repository (store -> evict -> re-derive), DESIGN.md §9.

  1. Workflow A (L3 sum) populates the shared repository; its join
     sub-job becomes a stored artifact.
  2. Workflow B (L3 mean) — a different tenant's variant — reuses A's
     join job straight from the repository.
  3. Eviction: rule R3 (time-window) wipes the unused entries AND
     deletes their artifacts from the store through the bound store.
  4. Re-derivation: workflow B runs again, recomputes from the sources,
     and repopulates the repository — same results as step 2.
  5. Byte-budget admission: a tiny repository keeps the artifact with
     the highest predicted benefit per byte and rejects/evicts the rest.

Every printed claim is asserted, so this file doubles as a smoke test
(CI runs it in the docs job).

Usage: PYTHONPATH=src python examples/policy_walkthrough.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import plan as P
from repro.core.cost_model import CostModel
from repro.core.repository import Repository, make_entry
from repro.core.restore import ReStore
from repro.store.artifacts import ArtifactStore, Catalog
from repro.workloads import pigmix


def sorted_rows(table):
    return {k: np.sort(v.astype(np.float64), axis=0)
            for k, v in table.to_numpy().items()
            if v.dtype.kind in "if"}


def main():
    store = ArtifactStore()
    catalog = Catalog(store)
    pigmix.register_all(catalog, n_rows=1 << 12)
    repo = Repository(budget_bytes=64 * 1024 * 1024, policy="cost")

    print("=== 1. Workflow A (tenant 1): L3 sum populates the repository ===")
    rs_a = ReStore(catalog, store, repo, heuristic="aggressive")
    _, rep_a = rs_a.run_plan(pigmix.L3("sum"))
    assert rep_a.n_executed == 2, "cold run must execute both jobs"
    assert len(repo) > 0, "repository must hold entries after workflow A"
    print(f"  executed {rep_a.n_executed} jobs, repository holds "
          f"{len(repo)} entries / {repo.total_stored_bytes()} bytes")

    print("=== 2. Workflow B (tenant 2): L3 mean reuses A's join job ===")
    rs_b = ReStore(catalog, store, repo, heuristic="aggressive")
    res_b, rep_b = rs_b.run_plan(pigmix.L3("mean"))
    assert not rep_b.jobs[0].executed, "join job must come from the repo"
    assert rep_b.jobs[1].executed, "only the mean aggregate recomputes"
    print(f"  join job reused ({rep_b.jobs[0].reused_artifacts}); "
          f"only the aggregate executed")

    print("=== 3. Eviction: rule R3 wipes the repo AND the store ===")
    artifacts = [e.artifact for e in repo.entries]
    time.sleep(0.02)
    dropped = repo.evict_unused(window_s=0.0)   # bound store deletes too
    assert dropped == len(artifacts) and len(repo) == 0
    for a in artifacts:
        assert not store.exists(a), f"{a} must be deleted from the store"
    print(f"  evicted {dropped} entries; artifacts deleted from the store")

    print("=== 4. Re-derivation: B recomputes from sources, same answer ===")
    res_b2, rep_b2 = rs_b.run_plan(pigmix.L3("mean"))
    assert rep_b2.n_executed == 2, "after eviction everything re-executes"
    a, b = sorted_rows(res_b["L3_mean_out"]), sorted_rows(res_b2["L3_mean_out"])
    for c in a:
        assert np.allclose(a[c], b[c], atol=1e-3), f"column {c} differs"
    assert len(repo) > 0, "re-derivation repopulates the repository"
    print(f"  re-executed {rep_b2.n_executed} jobs; results identical; "
          f"repository repopulated ({len(repo)} entries)")

    print("=== 5. Byte budget: benefit-per-byte admission ===")
    cm = CostModel(fixed_io_s=0.0, reuse_halflife_s=1e9)
    tiny = Repository(budget_bytes=2000, policy="cost", cost_model=cm)
    tiny.bind_store(store)

    def synthetic(name, producer_cost_s):
        pl = P.PhysicalPlan([P.store(P.project(P.load("d"), [name]), name)])
        store.put(name, pigmix.gen_users())
        return make_entry(pl, name, bytes_in=10_000, bytes_out=1000,
                          producer_cost_s=producer_cost_s)

    assert tiny.add(synthetic("art/cheap-to-recompute", 1e-4))
    assert tiny.add(synthetic("art/expensive-join", 5.0))
    # budget full (2 x 1000 bytes); a mid-value entry evicts the cheap one
    assert tiny.add(synthetic("art/mid-value", 1.0))
    kept = {e.artifact for e in tiny.entries}
    assert kept == {"art/expensive-join", "art/mid-value"}, kept
    assert not store.exists("art/cheap-to-recompute")
    # ... and a low-value newcomer is rejected outright
    assert not tiny.add(synthetic("art/near-worthless", 1e-5))
    assert tiny.rejections == 1
    print(f"  kept {sorted(kept)} under a 2000-byte budget; "
          f"evicted the cheap-to-recompute artifact, rejected the "
          f"worthless one")

    print("policy walkthrough OK")


if __name__ == "__main__":
    main()
