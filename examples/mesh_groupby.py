"""Mesh-sharded execution with partition-aware reuse (DESIGN.md §11).

  1. Run a join + group-by on an 8-way device mesh.  Every blocking
     operator executes as a shard_map map->shuffle->reduce stage; the
     join's output artifact is stored as 8 per-partition shards,
     hash-partitioned on the grouping key.
  2. Run a second query over the same join.  The join is answered from
     the repository, and because the reused artifact is co-partitioned
     on the consumer's keys, the group-by runs SHUFFLE-FREE — reuse
     skips the exchange, not just the compute.

This script re-executes itself with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the mesh
exists on a plain CPU machine (set before any jax import, as always).

Usage: PYTHONPATH=src python examples/mesh_groupby.py
"""
import os
import subprocess
import sys

sys.path.insert(0, "src")

N_DEVICES = 8


def main():
    import jax

    from repro.core import plan as P
    from repro.core.restore import ReStore
    from repro.store.artifacts import ArtifactStore, Catalog
    from repro.workloads import pigmix

    mesh = jax.make_mesh((N_DEVICES,), ("data",))
    store = ArtifactStore()
    catalog = Catalog(store)
    pigmix.register_all(catalog, n_rows=1 << 13)
    restore = ReStore(catalog, store, heuristic="aggressive", mesh=mesh)

    def query(aggs, out):
        pv = P.project(P.load("page_views"), ["user", "estimated_revenue"])
        u = P.project(P.load("users"), ["name"])
        j = P.join(pv, u, ["user"], ["name"])
        return P.PhysicalPlan([P.store(P.groupby(j, ["user"], aggs), out)])

    print(f"=== Q1 on a {N_DEVICES}-way mesh: join + group-by ===")
    _, rep1 = restore.run_plan(query(
        {"total": ("sum", "estimated_revenue")}, "q1_out"))
    for j in rep1.jobs:
        if j.stats:
            print(f"  job {j.job_id}: {j.stats.shuffles} exchanges, "
                  f"{j.stats.shuffles_skipped} skipped")
    parts = [(n, store.partitioning(n)) for n in store.names()
             if store.partitioning(n)]
    assert parts, "mesh run must record partition properties"
    n, p = parts[0]
    print(f"  artifact {n}: {p['n_parts']} shards on keys {p['keys']}")

    print("=== Q2: same join, different aggregates ===")
    _, rep2 = restore.run_plan(query(
        {"total": ("sum", "estimated_revenue"),
         "visits": ("count", "estimated_revenue")}, "q2_out"))
    skipped = sum(j.stats.shuffles_skipped for j in rep2.jobs if j.stats)
    print(f"  reused {rep2.n_reused} artifacts, "
          f"skipped {skipped} exchange(s)")
    assert rep2.n_reused > 0, "join must be answered from the repository"
    assert skipped > 0, \
        "co-partitioned reuse must skip the group-by exchange"
    print("mesh group-by example OK")


if __name__ == "__main__":
    if "--child" in sys.argv:
        main()
    else:
        env = dict(os.environ)
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={N_DEVICES}"
        env.setdefault("PYTHONPATH", "src")
        out = subprocess.run([sys.executable, os.path.abspath(__file__),
                              "--child"], env=env, cwd=os.getcwd())
        sys.exit(out.returncode)
