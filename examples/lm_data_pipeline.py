"""LM data pipeline with ReStore reuse across training runs.

Two pipeline configurations share their tokenize+filter prefix; the
second run reuses the first run's intermediate artifacts — exactly the
paper's sub-job reuse, applied to the framework's own data preparation.

Usage: PYTHONPATH=src python examples/lm_data_pipeline.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.restore import ReStore
from repro.store.artifacts import ArtifactStore, Catalog
from repro.train.data import pipeline_plan, synthetic_corpus


def main():
    store = ArtifactStore()
    catalog = Catalog(store)
    catalog.register("corpus", synthetic_corpus(512, 128, 8192))
    # min_splice_benefit_s=0: the walkthrough pins prefix-reuse
    # MECHANICS at toy scale, where the production default would
    # (correctly) decline the streaming tokenize+filter splice as not
    # worth its IO (DESIGN.md §14)
    restore = ReStore(catalog, store, heuristic="aggressive",
                      min_splice_benefit_s=0.0)

    print("=== run A: quality > 0.3 ===")
    _, repA = restore.run_plan(pipeline_plan(0.3, out_name="corpusA"))
    for j in repA.jobs:
        print(f"  job {j.job_id}: executed={j.executed} "
              f"stored={len(j.stored_candidates)}")

    print("=== run A again (identical pipeline) ===")
    _, repA2 = restore.run_plan(pipeline_plan(0.3, out_name="corpusA"))
    print(f"  jobs executed: {repA2.n_executed} (expect 0 — full reuse)")
    assert repA2.n_executed == 0

    print("=== run B: same filter, extra length cut ===")
    _, repB = restore.run_plan(pipeline_plan(0.3, min_length=64,
                                             out_name="corpusB"))
    reused = sum(len(j.reused_artifacts) for j in repB.jobs)
    print(f"  artifacts reused from run A: {reused}")
    assert reused > 0, "shared tokenize+filter prefix must be reused"
    print("lm_data_pipeline OK")


if __name__ == "__main__":
    main()
