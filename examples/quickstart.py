"""Quickstart: the paper's Q1/Q2 story end to end.

  1. Run Q1 (PigMix L2-style join).  ReStore stores the join output AND
     the sub-job outputs picked by the Aggressive Heuristic.
  2. Run Q2 (L3-style join+group).  Its first job is answered entirely
     from the repository (whole-job reuse, paper Fig 4); only the group
     job executes.
  3. Run Q3 (same Load+Project prefix, different filter).  The prefix is
     answered from a stored sub-job (paper Fig 6).

Usage: PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import plan as P
from repro.core.restore import ReStore
from repro.dataflow.expr import Col
from repro.store.artifacts import ArtifactStore, Catalog
from repro.workloads import pigmix


def main():
    store = ArtifactStore()
    catalog = Catalog(store)
    pigmix.register_all(catalog, n_rows=1 << 14)
    # min_splice_benefit_s=0: this walkthrough demonstrates the paper's
    # splice MECHANICS at toy scale, where the production default would
    # (correctly) decline the Q3 streaming splice as not worth its IO
    # (DESIGN.md §14)
    restore = ReStore(catalog, store, heuristic="aggressive",
                      min_splice_benefit_s=0.0)

    print("=== Q1: join page_views x users (paper Fig 2) ===")
    # exactly the paper's Q1: project both sources, join on user==name
    pv1 = P.project(P.load("page_views"), ["user", "estimated_revenue"])
    u1 = P.project(P.load("users"), ["name"])
    q1 = P.PhysicalPlan([P.store(P.join(pv1, u1, ["user"], ["name"]),
                                 "q1_out")])
    _, rep1 = restore.run_plan(q1)
    for j in rep1.jobs:
        print(f"  job {j.job_id}: executed={j.executed} "
              f"stored={len(j.stored_candidates)} sub-job artifacts")
    print(f"  repository now holds {len(restore.repo)} plans")

    print("=== Q2: join + group (paper Fig 3) ===")
    q2 = pigmix.L3("sum")
    res2, rep2 = restore.run_plan(q2)
    for j in rep2.jobs:
        print(f"  job {j.job_id}: executed={j.executed} "
              f"reused={j.reused_artifacts}")
    assert not rep2.jobs[0].executed, "join job must be reused from Q1"
    print(f"  -> job 1 answered from the repository (whole-job reuse); "
          f"result rows: {int(res2[list(res2)[0]].num_valid())}")

    print("=== Q3: same Load+Project prefix, new filter (paper Fig 6) ===")
    pv = P.project(P.load("page_views"), ["user", "estimated_revenue"])
    f = P.filter_(pv, Col("estimated_revenue") > 50.0)
    q3 = P.PhysicalPlan([P.store(f, "q3_out")])
    _, rep3 = restore.run_plan(q3)
    j3 = rep3.jobs[0]
    print(f"  job 0: reused sub-job artifacts {j3.reused_artifacts}")
    print(f"  plan shrank {j3.n_ops_before} -> {j3.n_ops_after} operators")
    assert j3.reused_artifacts, "sub-job reuse must fire"
    print("quickstart OK")


if __name__ == "__main__":
    main()
