"""Tiered artifact store walkthrough (DESIGN.md §15).

The device → host → disk → remote story, with every claim asserted:

  1. artifacts are stored on disk and demoted to an S3-style remote
     tier (column-compressed blob, atomic publish) — after which
     exactly ONE durable tier owns each artifact;
  2. a fresh store over the same remote cold-starts from remote-only
     state via one batched header fetch, and a cold `get` serves the
     exact bytes back through the latency-injected remote;
  3. a speculative prefetcher mines the store's read log, predicts the
     hot artifact, and warms it with a batched background fetch — so
     the next probe is a device hit instead of a remote round-trip;
  4. promotion rehydrates the artifact to disk bit-identically and
     retires the remote copy (still exactly one owner).

Run: PYTHONPATH=src python examples/tiered_prefetch.py
"""
import tempfile
import time
import zlib

import numpy as np

from repro.dataflow.table import Table
from repro.store.artifacts import ArtifactStore
from repro.store.prefetch import SpeculativePrefetcher
from repro.store.tiers import RemoteObjectStore


def make_table(i: int, n: int = 4096) -> Table:
    rng = np.random.default_rng(i)
    return Table.from_numpy({
        "k": rng.integers(0, 997, n).astype(np.int64),
        "v": rng.random(n).astype(np.float32),
    })


def crc(t: Table) -> int:
    d = t.to_numpy()
    acc = 0
    for c in sorted(d):
        acc = zlib.crc32(np.ascontiguousarray(d[c]).tobytes(),
                         zlib.crc32(c.encode(), acc))
    return acc


def main():
    disk = tempfile.mkdtemp(prefix="tier_disk_")
    remote_root = tempfile.mkdtemp(prefix="tier_remote_")
    names = [f"agg_{i}" for i in range(6)]

    # 1. populate disk, then demote everything to the remote tier
    store = ArtifactStore(root=disk,
                          remote=RemoteObjectStore(remote_root),
                          write_behind=False)
    refs = {}
    for i, name in enumerate(names):
        t = make_table(i)
        refs[name] = crc(t)
        store.put(name, t)
        assert store.authoritative_tier(name) == "disk"
        store.demote_to_remote(name)
        assert store.authoritative_tier(name) == "remote"
    store.close()
    print(f"demoted {len(names)} artifacts to the remote tier")

    # 2. cold start: a FRESH disk root over the same remote.  Reopen
    # indexes the population with one batched header fetch; a cold get
    # pays the injected latency but serves the exact bytes.
    remote = RemoteObjectStore(remote_root, latency_s=0.01)
    store = ArtifactStore(root=tempfile.mkdtemp(prefix="tier_disk2_"),
                          remote=remote, write_behind=False)
    assert all(store.exists(n) for n in names), "cold open must index"
    t0 = time.perf_counter()
    assert crc(store.get("agg_0")) == refs["agg_0"]
    cold_s = time.perf_counter() - t0
    assert cold_s >= 0.01, "cold read must pay the remote latency"
    print(f"cold remote read: {cold_s * 1e3:.1f} ms (bit-identical)")

    # 3. speculative prefetch: replay a skewed probe pattern, let the
    # prefetcher mine the read log, then warm its prediction.
    store.drop_caches()
    pf = SpeculativePrefetcher(store, k=1)
    for name in ["agg_3", "agg_3", "agg_1", "agg_3"]:
        store.get(name)
    pf.poll()
    assert pf.predict() == ["agg_3"], "zipfian skew must rank agg_3 first"
    store.drop_caches()                       # tenant pressure evicts all
    warmed = pf.prefetch()                    # background, untimed re-warm
    assert warmed == ["agg_3"]
    assert store.residency("agg_3") == "device"
    t0 = time.perf_counter()
    assert crc(store.get("agg_3")) == refs["agg_3"]
    warm_s = time.perf_counter() - t0
    assert warm_s < 0.01, "a warmed probe must not pay remote latency"
    pf.poll()                                 # settle accounting
    assert pf.hits >= 1 and pf.hit_rate > 0.0
    print(f"prefetched {warmed} -> warm probe {warm_s * 1e3:.2f} ms, "
          f"hit rate {pf.hit_rate:.2f}")

    # 4. promote back to disk: bit-identical, remote copy retired
    store.promote_from_remote("agg_3")
    assert store.authoritative_tier("agg_3") == "disk"
    assert not remote.exists(store._remote_key("agg_3"))
    store.cache.drop("agg_3")
    assert crc(store.get("agg_3")) == refs["agg_3"]
    print("promotion round-trip bit-identical; exactly one durable owner")
    store.close()
    print("OK")


if __name__ == "__main__":
    main()
