"""End-to-end training driver example.

Default: a quick 30-step run of the reduced config with checkpointing.
``--preset 100m --steps 300`` trains a genuine ~100M-parameter model for
a few hundred steps (slow on CPU; the same driver + dryrun shardings run
the full configs on a pod).

Usage:
  PYTHONPATH=src python examples/train_lm.py
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    losses = train(arch="qwen3-1.7b", steps=args.steps,
                   batch_size=4 if args.preset == "100m" else 8,
                   seq_len=128 if args.preset == "100m" else 64,
                   ckpt_dir=args.ckpt_dir,
                   scale=100.0 if args.preset == "100m" else 1.0)
    k = max(1, len(losses) // 5)
    print(f"first-{k} avg loss {sum(losses[:k]) / k:.4f} -> "
          f"last-{k} avg loss {sum(losses[-k:]) / k:.4f}")
    assert sum(losses[-k:]) <= sum(losses[:k]), "loss should decrease"
    print("train_lm OK")


if __name__ == "__main__":
    main()
