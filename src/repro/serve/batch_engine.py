"""Deprecated alias: `BatchEngine` → `ServeSession` (DESIGN.md §17).

Continuous batching lives in the unified `ServeSession`; this shim keeps
the old ``submit(prompt, max_new, rid)`` / ``step`` / ``run`` surface
for one release.  `Request` is the old name for `ServeRequest` (the
first three fields are positionally identical).
"""
from __future__ import annotations

import warnings
from typing import Optional

from ..models.api import Model
from .session import ServeRequest as Request
from .session import ServeSession

__all__ = ["BatchEngine", "Request"]


class BatchEngine:
    def __init__(self, model: Model, params, n_slots: int = 4,
                 max_len: int = 256, prefix_repo=None,
                 eos_token: int = -1):
        warnings.warn(
            "BatchEngine is deprecated; use repro.serve.ServeSession "
            "(one submission surface for sequential and batched serving)",
            DeprecationWarning, stacklevel=2)
        kv = None
        if prefix_repo is not None:
            kv = getattr(prefix_repo, "kv", prefix_repo)
        self._session = ServeSession(model, params, n_slots=n_slots,
                                     max_len=max_len, kv=kv,
                                     eos_token=eos_token, every_k=0)
        self.model = model
        self.params = params
        self.repo = prefix_repo

    # old surface: submit returns the request object itself
    def submit(self, prompt, max_new: int, rid: int) -> Request:
        t = self._session.submit(prompt, max_new)
        t.request.rid = rid
        return t.request

    def step(self) -> bool:
        return self._session.step()

    def run(self, max_steps: int = 10_000) -> None:
        self._session.run(max_steps)

    @property
    def queue(self):
        return [r for q in self._session._queues.values() for r in q]

    @property
    def slot_req(self):
        return self._session.slot_req
