"""Continuous-batching serving engine.

Production serving never decodes one request at a time: a fixed-size
batch of decode *slots* runs every step; finished sequences free their
slot and queued requests are admitted mid-flight (Orca-style continuous
batching).  The decode step is compiled ONCE for the slot batch; per-slot
indices live in the cache positions, so admission is a cache write, not a
recompile.

Prefill runs per-request (optionally through the PrefixRepository) into a
scratch cache, then the slot's cache rows are spliced in.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import Model
from .prefix_repo import PrefixRepository


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchEngine:
    def __init__(self, model: Model, params, n_slots: int = 4,
                 max_len: int = 256,
                 prefix_repo: Optional[PrefixRepository] = None,
                 eos_token: int = -1):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.repo = prefix_repo
        self.eos = eos_token
        cfg = model.cfg

        self.cache = model.init_cache(n_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)   # next write index
        self.next_tok = np.zeros(n_slots, np.int32)
        self.queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, b, c, i: model.decode_step(p, b, c, i))

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int, rid: int) -> Request:
        r = Request(rid, np.asarray(prompt, np.int32), max_new)
        self.queue.append(r)
        return r

    def _admit(self, slot: int, r: Request):
        """Prefill the request into a size-1 scratch cache, splice its
        rows into the slot, seed the first token."""
        cfg = self.model.cfg
        s = len(r.prompt)
        scratch = self.model.init_cache(1, self.max_len)
        start = 0
        if self.repo is not None:
            hit = self.repo.match(r.prompt)
            if hit is not None and hit.length < s:
                scratch, start = hit.cache, hit.length
        pos = jnp.arange(start, s, dtype=jnp.int32)
        if cfg.m_rope:
            pos = jnp.tile(pos[None, None], (3, 1, 1))
        batch = {"tokens": jnp.asarray(r.prompt[None, start:]),
                 "positions": pos}
        logits, scratch = self.model.prefill(self.params, batch, scratch,
                                             start=start)
        if self.repo is not None:
            self.repo.store(r.prompt, scratch, logits=logits)

        # splice scratch row 0 into slot `slot` of the live cache
        def splice(live, sc):
            if live.ndim >= 2 and live.shape[1] == self.n_slots \
                    and sc.shape[1] == 1:
                return live.at[:, slot].set(sc[:, 0])
            return live
        self.cache = jax.tree_util.tree_map(splice, self.cache, scratch)
        self.slot_req[slot] = r
        self.slot_pos[slot] = s
        self.next_tok[slot] = int(jnp.argmax(logits[0, -1]))

    # ------------------------------------------------------------------
    def step(self):
        """Admit queued requests to free slots, then one batched decode
        step for every live slot."""
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.queue:
                self._admit(slot, self.queue.pop(0))
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return False

        cfg = self.model.cfg
        # per-slot positions: a (B, 1) positions array (rope consumes the
        # batched form); idle slots decode harmlessly at position 0
        pos = jnp.asarray(self.slot_pos, jnp.int32)[:, None]
        if cfg.m_rope:
            pos = jnp.tile(pos[None], (3, 1, 1))
        batch = {"tokens": jnp.asarray(self.next_tok[:, None]),
                 "positions": pos}
        # batched decode needs per-slot cache indices: we pass the max and
        # rely on per-slot positions for rope; the cache write index must
        # be per-slot, so we use the vmapped path below instead when
        # positions diverge.
        logits, self.cache = self._decode(self.params, batch, self.cache,
                                          jnp.asarray(self.slot_pos))
        toks = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)

        for slot in live:
            r = self.slot_req[slot]
            r.out.append(int(self.next_tok[slot]))
            self.slot_pos[slot] += 1
            self.next_tok[slot] = int(toks[slot])
            if len(r.out) >= r.max_new or int(toks[slot]) == self.eos \
                    or self.slot_pos[slot] >= self.max_len - 1:
                r.done = True
                self.slot_req[slot] = None      # slot freed -> admission
        return True

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
