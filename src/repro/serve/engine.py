"""Deprecated alias: `ServeEngine` → `ServeSession` (DESIGN.md §17).

The sequential serving engine merged into the unified `ServeSession`
submission surface; this shim keeps the old constructor and ``serve``
signature for one release and delegates everything to a session.
"""
from __future__ import annotations

import warnings
from typing import Optional

from ..models.api import Model
from .session import ServeSession, ServeStats

__all__ = ["ServeEngine", "ServeStats"]


class ServeEngine:
    def __init__(self, model: Model, params, max_len: int = 512,
                 prefix_repo=None):
        warnings.warn(
            "ServeEngine is deprecated; use repro.serve.ServeSession "
            "(one submission surface for sequential and batched serving)",
            DeprecationWarning, stacklevel=2)
        kv = None
        if prefix_repo is not None:
            # accept both the old PrefixRepository shim and a bare
            # KVRepository
            kv = getattr(prefix_repo, "kv", prefix_repo)
        self._session = ServeSession(model, params, n_slots=1,
                                     max_len=max_len, kv=kv)
        self.model = model
        self.params = params
        self.max_len = max_len
        self.repo = prefix_repo

    def serve(self, prompt, n_decode: int) -> tuple:
        return self._session.serve(prompt, n_decode)
