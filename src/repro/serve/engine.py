"""Serving engine: batched prefill/decode with ReStore-style prefix reuse.

serve() greedily decodes n tokens from a prompt.  With a PrefixRepository
attached, the longest stored prefix's cache snapshot is reused and only
the prompt suffix is prefilled — the decode-path equivalent of rewriting
a MapReduce job to Load a stored sub-job output.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import Model
from .prefix_repo import PrefixRepository


@dataclasses.dataclass
class ServeStats:
    prefilled_tokens: int
    reused_tokens: int
    decoded_tokens: int
    wall_s: float


class ServeEngine:
    def __init__(self, model: Model, params, max_len: int = 512,
                 prefix_repo: Optional[PrefixRepository] = None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.repo = prefix_repo
        cfg = model.cfg
        self._decode = jax.jit(
            lambda p, b, c, i: model.decode_step(p, b, c, i))

    def _positions(self, start, length, batch=1):
        cfg = self.model.cfg
        pos = jnp.arange(start, start + length, dtype=jnp.int32)
        if cfg.m_rope:
            return jnp.tile(pos[None, None], (3, batch, 1))
        return pos

    def serve(self, prompt: np.ndarray, n_decode: int) -> tuple:
        """prompt: (S,) int32.  Returns (generated tokens, ServeStats)."""
        t0 = time.time()
        cfg = self.model.cfg
        prompt = np.asarray(prompt, np.int32)
        s = len(prompt)

        reused = 0
        cache = self.model.init_cache(1, self.max_len)
        start = 0
        hit = None
        if self.repo is not None:
            hit = self.repo.match(prompt)
            if hit is not None and hit.length <= s:
                cache = hit.cache
                start = hit.length
                reused = hit.length

        positional = (cfg.family in ("dense", "moe", "vlm", "encdec")
                      and cfg.ssm is None and cfg.xlstm is None)
        if start < s:
            batch = {"tokens": jnp.asarray(prompt[None, start:]),
                     "positions": self._positions(start, s - start)}
            logits, cache = self.model.prefill(self.params, batch, cache,
                                               start=start)
        elif hit is not None and hit.logits is not None:
            # exact hit: stored logits — a recurrent state must not be
            # advanced again by replaying the final token
            logits = hit.logits
        else:
            # positional cache: replaying the last token is idempotent
            batch = {"tokens": jnp.asarray(prompt[None, -1:]),
                     "positions": self._positions(s - 1, 1)}
            logits, cache = self._decode(self.params, batch, cache,
                                         jnp.int32(s - 1))

        if self.repo is not None and reused < s:
            # positional (attention) caches admit intermediate-prefix
            # aliases (the sub-job enumeration analogue); recurrent
            # states are exact-length only
            self.repo.store(prompt, cache,
                            every_k=8 if positional else 0,
                            logits=logits)

        out = []
        tok = int(jnp.argmax(logits[0, -1]))
        for i in range(n_decode):
            out.append(tok)
            batch = {"tokens": jnp.asarray([[tok]], jnp.int32),
                     "positions": self._positions(s + i, 1)}
            logits, cache = self._decode(self.params, batch, cache,
                                         jnp.int32(s + i))
            tok = int(jnp.argmax(logits[0, -1]))

        return np.array(out, np.int32), ServeStats(
            prefilled_tokens=s - reused, reused_tokens=reused,
            decoded_tokens=n_decode, wall_s=time.time() - t0)
