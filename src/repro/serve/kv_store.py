"""Tiered store for serving-time KV/recurrent state (DESIGN.md §17).

The §15 storage hierarchy, applied to prefix snapshots: the hot tier is
the device (the live jax pytrees a decode step consumes), the warm tier
is the §15 ``HostCache`` (numpy leaf payloads, bytes-bounded LRU), and
the cold tier is the §15 ``RemoteObjectStore`` holding one compressed
``RSB1`` blob per snapshot — the same codec, checksums and atomic
publish analytics artifacts use, so corruption detection and the fault
choke points (``remote_read`` / ``remote_write`` / ``remote_published``)
come for free.

The store exposes the same surfaces the §15 machinery expects from an
artifact store: ``read_log`` + ``prewarm`` feed `SpeculativePrefetcher`
(popular prompt states ride ONE batched remote fetch), ``io_stats``
feeds `CostModel.calibrate_io` with tier-tagged samples, and ``delete``
is what budget eviction routes here via ``Repository.bind_store(...,
kind="prefix")``.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..store.tiers import (HostCache, RemoteObjectStore,
                           decode_artifact_blob, encode_artifact_blob)


class KVTierStore:
    def __init__(self, host_bytes: int = 1 << 30,
                 remote_root: Optional[str] = None,
                 remote_latency_s: float = 0.0,
                 remote_bandwidth_bytes_s: Optional[float] = None,
                 injector=None):
        # name -> (cache pytree of jax arrays, logits or None)
        self._device: Dict[str, Tuple[object, object]] = {}
        # name -> {"treedef", "nbytes", "n_leaves"}: kept for every
        # stored name (tiny) so a remote blob can be unflattened back
        self._meta: Dict[str, dict] = {}
        self.host = HostCache(host_bytes)
        self.remote = (RemoteObjectStore(remote_root,
                                         latency_s=remote_latency_s,
                                         bandwidth_bytes_s=(
                                             remote_bandwidth_bytes_s))
                       if remote_root else None)
        self.injector = injector
        self.read_log: "collections.deque" = collections.deque(maxlen=4096)
        self._lock = threading.RLock()
        self.stats = {"puts": 0, "deletes": 0, "quarantined": 0,
                      "device_hits": 0, "host_hits": 0, "remote_hits": 0,
                      "misses": 0, "demotions": 0, "prewarmed": 0}
        self._io = {"memload_bytes": 0, "memload_s": 0.0,
                    "hostload_bytes": 0, "hostload_s": 0.0,
                    "remoteload_bytes": 0, "remoteload_s": 0.0,
                    "store_bytes": 0, "store_s": 0.0}

    # --------------------------------------------------------------- util
    def _fault(self, point: str, name: str, path: Optional[str] = None):
        if self.injector is not None:
            self.injector.on(point, name, path=path)

    @staticmethod
    def _nbytes(leaves, logits) -> int:
        # .nbytes comes from shape/dtype — no device transfer (puts are
        # on the serve hot path; np.asarray would force a sync)
        nb = sum(int(a.nbytes) for a in leaves)
        if logits is not None:
            nb += int(logits.nbytes)
        return nb

    def _key(self, name: str) -> str:
        return name.replace("/", "_")

    # ---------------------------------------------------------------- put
    def put(self, name: str, cache, logits=None) -> int:
        """Register a snapshot in the device tier; returns its byte
        size (what the repository entry charges to the budget)."""
        leaves, treedef = jax.tree_util.tree_flatten(cache)
        nb = self._nbytes(leaves, logits)
        with self._lock:
            self._device[name] = (cache, logits)
            self._meta[name] = {"treedef": treedef, "nbytes": nb,
                                "n_leaves": len(leaves)}
            self.stats["puts"] += 1
        return nb

    def exists(self, name: str) -> bool:
        with self._lock:
            return name in self._meta

    def nbytes(self, name: str) -> int:
        with self._lock:
            return self._meta[name]["nbytes"]

    def residency(self, name: str) -> Optional[str]:
        with self._lock:
            if name in self._device:
                return "device"
            if name in self.host:
                return "host"
            if name in self._meta and self.remote is not None \
                    and self.remote.exists(self._key(name)):
                return "remote"
            return None

    # ---------------------------------------------------------------- get
    def get(self, name: str):
        """Fetch ``(cache, logits)``, promoting cold copies to the
        device tier.  Raises KeyError on a miss; a corrupt remote blob
        is quarantined (deleted + un-advertisable) and reads as a miss
        — the caller falls back to a cold prefill."""
        t0 = time.perf_counter()
        with self._lock:
            ent = self._device.get(name)
            meta = self._meta.get(name)
        if ent is not None:
            self.stats["device_hits"] += 1
            self.read_log.append((name, "device"))
            self._io["memload_bytes"] += meta["nbytes"]
            self._io["memload_s"] += time.perf_counter() - t0
            return ent
        if meta is None:
            self.stats["misses"] += 1
            raise KeyError(name)
        payload = self.host.get(name)
        if payload is not None:
            out = self._rebuild(name, meta, payload)
            self.stats["host_hits"] += 1
            self.read_log.append((name, "host"))
            self._io["hostload_bytes"] += meta["nbytes"]
            self._io["hostload_s"] += time.perf_counter() - t0
            return out
        payload = self._fetch_remote(name)
        if payload is None:
            self.stats["misses"] += 1
            raise KeyError(name)
        out = self._rebuild(name, meta, payload)
        self.stats["remote_hits"] += 1
        self.read_log.append((name, "remote"))
        self._io["remoteload_bytes"] += meta["nbytes"]
        self._io["remoteload_s"] += time.perf_counter() - t0
        return out

    def _rebuild(self, name: str, meta: dict, payload: dict):
        """numpy leaf payload -> live jax pytree, promoted to device."""
        leaves = [jnp.asarray(payload[f"leaf{i:05d}"])
                  for i in range(meta["n_leaves"])]
        logits = payload.get("logits")
        if logits is not None:
            logits = jnp.asarray(logits)
        cache = jax.tree_util.tree_unflatten(meta["treedef"], leaves)
        with self._lock:
            self._device[name] = (cache, logits)
        return cache, logits

    def _payload(self, name: str) -> Optional[dict]:
        """Device snapshot as a flat numpy payload (host/blob form)."""
        with self._lock:
            ent = self._device.get(name)
        if ent is None:
            return None
        cache, logits = ent
        leaves = jax.tree_util.tree_leaves(cache)
        payload = {f"leaf{i:05d}": np.asarray(a)
                   for i, a in enumerate(leaves)}
        if logits is not None:
            payload["logits"] = np.asarray(logits)
        return payload

    def _fetch_remote(self, name: str) -> Optional[dict]:
        if self.remote is None:
            return None
        key = self._key(name)
        if not self.remote.exists(key):
            return None
        self._fault("remote_read", name)
        blob = self.remote.get_object(key)
        try:
            _manifest, files = decode_artifact_blob(blob, verify=True)
            return files["kv"]
        except (ValueError, KeyError):
            self.quarantine(name)
            return None

    # -------------------------------------------------------------- tiers
    def demote_to_host(self, name: str) -> bool:
        payload = self._payload(name)
        if payload is None:
            return False
        with self._lock:
            self.host.put(name, payload)
            self._device.pop(name, None)
            self.stats["demotions"] += 1
        return True

    def demote_to_remote(self, name: str) -> bool:
        """Push the snapshot down to the remote blob tier (RSB1 codec,
        per-column checksums, atomic publish) and drop the warm copies."""
        if self.remote is None:
            raise RuntimeError("KVTierStore has no remote tier")
        payload = self._payload(name)
        if payload is None:
            payload = self.host.get(name)
        if payload is None:
            return False
        with self._lock:
            meta = self._meta[name]
        t0 = time.perf_counter()
        blob = encode_artifact_blob(
            {"name": name, "n_leaves": meta["n_leaves"]},
            {"kv": payload})
        self._fault("remote_write", name)
        path = self.remote.put_object(self._key(name), blob)
        self._fault("remote_published", name, path=path)
        self._io["store_bytes"] += len(blob)
        self._io["store_s"] += time.perf_counter() - t0
        with self._lock:
            self._device.pop(name, None)
            self.host.drop(name)
            self.stats["demotions"] += 1
        return True

    def prewarm(self, names) -> list:
        """Batched cache fill from the remote tier: every cold name
        rides ONE ``get_many`` (one latency charge for the batch — the
        economics that make speculative prefetch beat demand paging)."""
        cold = [n for n in names
                if n in self._meta and n not in self._device
                and n not in self.host]
        if not cold or self.remote is None:
            return []
        blobs = self.remote.get_many([self._key(n) for n in cold])
        warmed = []
        for n in cold:
            blob = blobs.get(self._key(n))
            if blob is None:
                continue
            try:
                _m, files = decode_artifact_blob(blob, verify=True)
            except (ValueError, KeyError):
                self.quarantine(n)
                continue
            self.host.put(n, files["kv"])
            warmed.append(n)
        self.stats["prewarmed"] += len(warmed)
        return warmed

    # ------------------------------------------------------------- delete
    def delete(self, name: str) -> None:
        """Drop a snapshot from every tier (idempotent — budget eviction
        and quarantine may race on the same name)."""
        with self._lock:
            self._device.pop(name, None)
            self._meta.pop(name, None)
            self.host.drop(name)
            self.stats["deletes"] += 1
        if self.remote is not None:
            self.remote.delete(self._key(name))

    def quarantine(self, name: str) -> None:
        """A damaged blob was detected: delete the bytes everywhere so
        the next read is an honest cold miss (DESIGN.md §13)."""
        self.stats["quarantined"] += 1
        self.delete(name)

    # ------------------------------------------------------------ pricing
    def io_stats(self) -> dict:
        s = dict(self._io)
        s["has_disk"] = False
        return s

    def total_stored_bytes(self) -> int:
        with self._lock:
            return sum(m["nbytes"] for m in self._meta.values())

    def __contains__(self, name: str) -> bool:
        return self.exists(name)

    def __len__(self) -> int:
        with self._lock:
            return len(self._meta)
