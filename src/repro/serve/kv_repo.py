"""Serving-time prefix reuse on the ReStore repository (DESIGN.md §17).

`KVRepository` is the serve-path adapter over the SAME machinery that
manages analytics artifacts — not a parallel class:

  * entries are `RepositoryEntry(kind="prefix")` over a `PrefixPlan`,
    admitted and evicted by `CostModel.benefit_per_byte` under the
    repository's (possibly shared) ``budget_bytes``;
  * the verbs mirror the analytics rewriter: ``probe`` (longest stored
    prefix — the semantic-subsumption analog, side-effect free),
    ``splice`` (materialize the stored state from the tier store),
    ``record_use`` (credit the hit: "exact" for a full-prompt match,
    "semantic" for a covering prefix that needs residual-suffix
    compensation);
  * ``store_prefix`` registers snapshots (with ``every_k`` sub-prefix
    aliases, the sub-job-enumeration analog); ``extend`` grows a stored
    conversation in place via the §12 delta-refresh path
    (`Repository.reindex`) instead of re-storing from scratch;
  * R4 is literal: prefix entries carry the model-version epoch as a
    source version and ``invalidate_version`` runs ``evict_stale``
    against the model catalog.

By default the repository clock is a logical event counter, so recency
and eviction order are deterministic under test — the pre-§17
`PrefixRepository` stamped ``time.time()`` inside ``match`` and its
eviction order depended on the wall clock.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional

from ..core.cost_model import CostModel
from ..core.prefix_plan import (PrefixPlan, make_prefix_entry,
                                prefix_fingerprints)
from ..core.repository import Repository, RepositoryEntry
from .kv_store import KVTierStore


class LogicalClock:
    """Monotonic event counter: deterministic recency for tests and
    single-process serving (wall-clock ties broke LRU determinism)."""

    def __init__(self):
        self._c = itertools.count(1)

    def __call__(self) -> float:
        return float(next(self._c))


class _ModelCatalog:
    """Catalog shim for rule R4: the serve path's one "source dataset"
    is the model weights; its version is an epoch bumped on change."""

    MODEL = "__model__"

    def __init__(self):
        self.epoch = 0

    def version(self, dataset: str) -> int:
        return self.epoch


@dataclasses.dataclass
class PrefixHit:
    """A probe result: the matched entry plus the covered length.
    ``splice`` fills ``cache``/``logits`` from the tier store."""
    entry: RepositoryEntry
    length: int
    exact: bool
    cache: object = None
    logits: object = None


class KVRepository:
    def __init__(self, model_version: str = "v0",
                 budget_bytes: Optional[int] = 1 << 34,
                 repository: Optional[Repository] = None,
                 cost_model: Optional[CostModel] = None,
                 store: Optional[KVTierStore] = None,
                 clock=None):
        self.model_version = str(model_version)
        self.clock = clock if clock is not None else LogicalClock()
        if repository is not None:
            self.repository = repository
            self.cost_model = repository.cost_model
        else:
            self.cost_model = cost_model or CostModel()
            self.repository = Repository(budget_bytes=budget_bytes,
                                         cost_model=self.cost_model,
                                         clock=self.clock)
        # `is not None`, not truthiness: an empty KVTierStore has
        # len() == 0 and would be silently replaced
        self.store = store if store is not None else KVTierStore()
        self.repository.bind_store(self.store, kind="prefix")
        self.catalog = _ModelCatalog()
        # artifact -> token length of the FULL stored snapshot: stored
        # last-token logits are only valid for a hit of exactly that
        # length (an alias hit must re-derive its logits)
        self._full_len: Dict[str, int] = {}

    # ------------------------------------------------------------- verbs
    def probe(self, tokens) -> Optional[PrefixHit]:
        """Longest stored prefix of ``tokens`` — scan from the full
        length down, so the first match is the best match (the ordering
        rule).  Pure: no recency mutation (that is ``record_use``'s
        job, exactly as in the analytics path)."""
        fps = prefix_fingerprints(tokens, self.model_version)
        by_sig = self.repository.by_sig
        for i in range(len(fps) - 1, -1, -1):
            e = by_sig.get(fps[i])
            if e is not None and e.kind == "prefix":
                return PrefixHit(entry=e, length=i + 1,
                                 exact=(i + 1 == len(fps)))
        return None

    def splice(self, hit: PrefixHit) -> Optional[PrefixHit]:
        """Materialize the hit's stored state (promoting through the
        tiers).  A quarantined/vanished snapshot un-advertises its
        entries and returns None — the caller prefills cold."""
        try:
            cache, logits = self.store.get(hit.entry.artifact)
        except KeyError:
            self.repository.drop_artifact(hit.entry.artifact)
            self._full_len.pop(hit.entry.artifact, None)
            return None
        hit.cache = cache
        # stored last-token logits belong to the FULL stored prefix;
        # an alias (shorter) hit must not reuse them
        hit.logits = logits \
            if self._full_len.get(hit.entry.artifact) == hit.length \
            else None
        return hit

    def record_use(self, hit: PrefixHit, saved_s: Optional[float] = None
                   ) -> None:
        """Credit a reuse: an exact full-prompt hit is an "exact" hit;
        a covering prefix (residual suffix still prefilled — the
        compensation compute) is a "semantic" hit, same split the
        analytics rewriter reports (DESIGN.md §10)."""
        if saved_s is None:
            saved_s = max(
                self.cost_model.prefill_cost_s(hit.length)
                - self.cost_model.tier_load_cost_s(
                    hit.entry.bytes_out, "device"), 0.0)
        self.repository.record_use(
            hit.entry, saved_s=saved_s,
            kind="exact" if hit.exact else "semantic")

    # ------------------------------------------------------------- store
    def store_prefix(self, tokens, cache, *, logits=None,
                     every_k: int = 0, history_uses: float = 0.0
                     ) -> Optional[RepositoryEntry]:
        """Register a prefill snapshot.  With ``every_k > 0``, ALSO
        register alias entries for intermediate prefix lengths sharing
        the same snapshot (paper §4 sub-job enumeration) — positional
        caches only; a recurrent state is exact-length only, so SSM/
        hybrid callers must pass ``every_k=0``.  Aliases charge zero
        bytes (the arrays are shared, charged once on the parent) and
        are evicted with their parent."""
        plan = PrefixPlan(tokens, self.model_version)
        existing = self.repository.by_sig.get(plan.signature)
        if existing is not None:
            return existing
        name = "kv-" + plan.signature
        nbytes = self.store.put(name, cache, logits)
        entry = make_prefix_entry(
            plan, name, nbytes=nbytes,
            producer_cost_s=self.cost_model.prefill_cost_s(plan.n_ops()),
            created_at=self.clock(), history_uses=history_uses,
            source_versions={_ModelCatalog.MODEL: self.catalog.epoch})
        if not self.repository.add(entry):
            self.store.delete(name)     # rejected by the budget
            return None
        self._full_len[name] = plan.n_ops()
        if every_k:
            for ln in range(every_k, plan.n_ops(), every_k):
                sub = plan.prefix(ln)
                if sub.signature in self.repository.by_sig:
                    continue
                alias = make_prefix_entry(
                    sub, name, nbytes=0,
                    producer_cost_s=self.cost_model.prefill_cost_s(ln),
                    created_at=self.clock(),
                    source_versions={
                        _ModelCatalog.MODEL: self.catalog.epoch})
                self.repository.add(alias)
        return entry

    def extend(self, hit: PrefixHit, tokens, cache, *, logits=None
               ) -> Optional[RepositoryEntry]:
        """Append-style prefix extension: a multi-turn conversation
        grew a stored prefix, so the entry rides the §12 refresh path —
        mutated in place and re-keyed (`Repository.reindex`) — instead
        of storing a second snapshot of mostly-identical state.  The
        hit's aliases keep pointing at the old artifact only if any
        exist; otherwise the superseded snapshot's bytes are freed."""
        entry = hit.entry
        plan = PrefixPlan(tokens, self.model_version)
        if not entry.plan.is_prefix_of(plan):
            raise ValueError("extend: stored entry is not a prefix of "
                             "the new tokens")
        existing = self.repository.by_sig.get(plan.signature)
        if existing is not None:
            return existing
        old_sig, old_name = entry.signature, entry.artifact
        name = "kv-" + plan.signature
        nbytes = self.store.put(name, cache, logits)
        entry.plan = plan
        entry.signature = plan.signature
        entry.artifact = name
        entry.bytes_out = nbytes
        entry.rows_out = plan.n_ops()
        entry.producer_cost_s = self.cost_model.prefill_cost_s(
            plan.n_ops())
        self.repository.reindex(entry, old_sig)
        self._full_len[name] = plan.n_ops()
        if not any(e.artifact == old_name
                   for e in self.repository.entries):
            self.store.delete(old_name)
            self._full_len.pop(old_name, None)
        self.repository.rebalance()
        return entry

    # ----------------------------------------------------------- pinning
    def pin(self, entry: RepositoryEntry) -> None:
        """Pin a spliced snapshot for the duration of a decode — a
        pinned artifact is never a budget-eviction victim."""
        self.repository.pin([entry.artifact])

    def unpin(self, entry: RepositoryEntry) -> None:
        self.repository.unpin([entry.artifact])

    # ---------------------------------------------------------- eviction
    def evict_unused(self, window_s: float) -> int:
        """Rule R3 over prefix entries (window in clock units)."""
        return self.repository.evict_unused(window_s)

    def invalidate_version(self, new_version: str) -> int:
        """Rule R4: the decode path's input dataset (the model weights)
        changed — every stored state is unreachable garbage.  Bump the
        model catalog epoch and run the same ``evict_stale`` sweep
        analytics entries get, scoped to the prefix kind."""
        n_before = self._n_prefix_entries()
        self.model_version = str(new_version)
        self.catalog.epoch += 1
        self.repository.evict_stale(self.catalog, kinds=("prefix",))
        return n_before - self._n_prefix_entries()

    # ------------------------------------------------------------ helpers
    def calibrate(self) -> None:
        """Refresh the cost model's tier prices from the KV store's
        measured transfers (same loop the analytics driver runs)."""
        self.cost_model.calibrate_io(self.store)

    def _n_prefix_entries(self) -> int:
        return sum(1 for e in self.repository.entries
                   if e.kind == "prefix")

    @property
    def entries(self):
        """Prefix entries keyed by signature (fingerprint)."""
        return {e.signature: e for e in self.repository.entries
                if e.kind == "prefix"}

    @property
    def total_bytes(self) -> int:
        return sum(e.bytes_out for e in self.repository.entries
                   if e.kind == "prefix")

    def stats(self) -> dict:
        return self.repository.stats().get("prefix", {
            "entries": 0, "bytes": 0,
            "exact_hits": 0, "semantic_hits": 0})

    def __len__(self) -> int:
        return self._n_prefix_entries()
