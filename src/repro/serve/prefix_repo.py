"""Deprecated alias: `PrefixRepository` → `KVRepository` (DESIGN.md §17).

The serving prefix cache is no longer a standalone class — prefix
snapshots are `RepositoryEntry(kind="prefix")` rows in the SAME ReStore
repository that manages analytics artifacts, priced by the same
`CostModel` under the same byte budget, stored in the §15 tier
hierarchy.  This shim keeps the old ``match`` / ``store`` surface for
one release; ``match`` is now literally ``probe → splice → record_use``.

It also fixes two standing accounting bugs of the old class, carried by
the new machinery:

  * ``match`` stamped ``time.time()``, making eviction order depend on
    the wall clock — recency now flows through the repository's logical
    clock (deterministic under test);
  * ``every_k`` alias entries reported ``nbytes=0`` but LRU eviction
    could drop the parent while aliases kept advertising the deleted
    arrays — eviction now expands to every entry sharing the artifact.
"""
from __future__ import annotations

import warnings

from ..core.prefix_plan import prefix_fingerprints  # noqa: F401 (re-export)
from .kv_repo import KVRepository, PrefixHit

__all__ = ["PrefixRepository", "PrefixHit", "prefix_fingerprints"]


class PrefixRepository:
    def __init__(self, model_version: str = "v0",
                 capacity_bytes: int = 1 << 34):
        warnings.warn(
            "PrefixRepository is deprecated; use repro.serve.KVRepository "
            "(prefix snapshots live in the unified ReStore repository)",
            DeprecationWarning, stacklevel=2)
        self.kv = KVRepository(model_version=model_version,
                               budget_bytes=capacity_bytes)

    @property
    def model_version(self) -> str:
        return self.kv.model_version

    @property
    def capacity_bytes(self) -> int:
        return self.kv.repository.budget_bytes

    @property
    def total_bytes(self) -> int:
        return self.kv.total_bytes

    @property
    def entries(self):
        """Live prefix entries keyed by fingerprint (signature)."""
        return self.kv.entries

    # old verbs, expressed as the new ones
    def match(self, tokens):
        hit = self.kv.probe(tokens)
        if hit is None:
            return None
        hit = self.kv.splice(hit)
        if hit is None:
            return None
        self.kv.record_use(hit)
        return hit

    def store(self, tokens, cache, *, every_k: int = 0, logits=None):
        return self.kv.store_prefix(tokens, cache, logits=logits,
                                    every_k=every_k)

    def evict_unused(self, window_s: float) -> int:
        return self.kv.evict_unused(window_s)

    def invalidate_version(self, new_version: str) -> int:
        return self.kv.invalidate_version(new_version)

    def __len__(self) -> int:
        return len(self.kv)
