"""Prefix-reuse repository for serving — ReStore's algorithms applied to
the decode path (beyond-paper extension, DESIGN.md §4).

The correspondence:
  physical plan            <->  token prefix (chain of per-token "ops")
  plan containment (Alg 1) <->  longest stored prefix of the request
  job output artifact      <->  KV cache / recurrent state after prefix
  ordering rule (best 1st) <->  longest prefix first
  eviction R1              <->  keep only if recompute cost > store cost
  eviction R3              <->  LRU window
  eviction R4              <->  model/version change invalidates entries

Entries are content-addressed with the same Merkle idea as plans: the
fingerprint of a prefix is hash(fingerprint(prefix[:-1]), token[-1]).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np


def prefix_fingerprints(tokens: np.ndarray, model_version: str) -> List[str]:
    """Fingerprint of every prefix of a token sequence (Merkle chain)."""
    out = []
    h = hashlib.sha256(model_version.encode()).hexdigest()
    for t in tokens:
        h = hashlib.sha256(f"{h}:{int(t)}".encode()).hexdigest()
        out.append(h)
    return out


@dataclasses.dataclass
class PrefixEntry:
    fingerprint: str
    length: int
    cache: object                # model cache pytree snapshot
    nbytes: int
    created_at: float
    last_used: float = 0.0
    use_count: int = 0
    logits: object = None        # last-token logits (exact-hit fast path:
    #                              recurrent states must NOT be re-advanced)


class PrefixRepository:
    def __init__(self, model_version: str = "v0",
                 capacity_bytes: int = 1 << 34):
        self.model_version = model_version
        self.entries: Dict[str, PrefixEntry] = {}
        self.capacity_bytes = capacity_bytes
        self.total_bytes = 0

    # ------------------------------------------------------------- match
    def match(self, tokens: np.ndarray) -> Optional[PrefixEntry]:
        """Longest stored prefix of ``tokens`` (first match is best match:
        scan from the full length down — the ordering rule)."""
        fps = prefix_fingerprints(tokens, self.model_version)
        for i in range(len(fps) - 1, -1, -1):
            e = self.entries.get(fps[i])
            if e is not None:
                e.last_used = time.time()
                e.use_count += 1
                return e
        return None

    # ------------------------------------------------------------- store
    def store(self, tokens: np.ndarray, cache, *, every_k: int = 0,
              logits=None) -> Optional[PrefixEntry]:
        """Store the prefix state; with every_k > 0, ALSO register entries
        for intermediate prefix lengths sharing the same cache arrays —
        the sub-job-enumeration analogue (paper §4).  Only valid for
        positional caches (attention KV): a recurrent state is exact-length
        only, so SSM/hybrid archs must pass every_k=0."""
        fps = prefix_fingerprints(tokens, self.model_version)
        fp = fps[-1]
        if fp in self.entries:
            return self.entries[fp]
        nbytes = sum(x.size * x.dtype.itemsize
                     for x in jax.tree_util.tree_leaves(cache))
        # R1 analogue: don't store states that exceed the budget per entry
        if nbytes > self.capacity_bytes:
            return None
        while self.total_bytes + nbytes > self.capacity_bytes \
                and self.entries:
            self._evict_lru()
        e = PrefixEntry(fp, len(tokens), cache, nbytes, time.time(),
                        logits=logits)
        self.entries[fp] = e
        self.total_bytes += nbytes
        if every_k:
            for ln in range(every_k, len(tokens), every_k):
                sub_fp = fps[ln - 1]
                if sub_fp not in self.entries:
                    # shares arrays: zero marginal bytes (alias entry)
                    self.entries[sub_fp] = PrefixEntry(
                        sub_fp, ln, cache, 0, time.time())
        return e

    # ------------------------------------------------------------- evict
    def _evict_lru(self):
        victim = min(self.entries.values(),
                     key=lambda e: e.last_used or e.created_at)
        self.total_bytes -= victim.nbytes
        del self.entries[victim.fingerprint]

    def evict_unused(self, window_s: float) -> int:
        """Rule R3."""
        now = time.time()
        drop = [e for e in self.entries.values()
                if now - (e.last_used or e.created_at) > window_s]
        for e in drop:
            self.total_bytes -= e.nbytes
            del self.entries[e.fingerprint]
        return len(drop)

    def invalidate_version(self, new_version: str) -> int:
        """Rule R4: the 'input dataset' (model weights) changed."""
        n = len(self.entries)
        self.entries.clear()
        self.total_bytes = 0
        self.model_version = new_version
        return n

    def __len__(self):
        return len(self.entries)
