"""One submission surface for serving (DESIGN.md §17).

`ServeSession` merges the sequential `ServeEngine` and the
continuous-batching `BatchEngine` behind one API, shaped like the
analytics `ReStoreService`: requests are objects with tenant / deadline
semantics, ``submit`` returns a ticket, identical in-flight prompts are
singleflighted (followers share the leader's decode), queue admission is
round-robin across tenants, and a bounded queue applies backpressure.

Prefix reuse flows through the `KVRepository` verbs — ``probe`` (pure
longest-prefix lookup), ``splice`` (materialize the snapshot from the
tier store; a quarantined blob degrades to a cold prefill), and
``record_use`` (credit the hit) — with the spliced entry pinned for the
duration of the decode, exactly as the analytics driver pins workflow
artifacts while downstream jobs consume them.

Greedy decode outputs are bit-identical with or without reuse: the
reused state is the same numbers the prefill would have produced (the
fingerprint chain guarantees the tokens match), so reuse only removes
redundant compute — the ReStore contract.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import Model
from .kv_repo import KVRepository


class SessionSaturated(RuntimeError):
    """Backpressure: the session queue is full — retry later."""


@dataclasses.dataclass
class ServeStats:
    prefilled_tokens: int
    reused_tokens: int
    decoded_tokens: int
    wall_s: float


@dataclasses.dataclass
class ServeRequest:
    """One serving request.  ``rid``/``prompt``/``max_new`` keep the old
    `batch_engine.Request` positional layout; tenant/deadline/ticket
    semantics are the §17 unification with the service submission API."""
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    tenant: str = "default"
    # admission deadline in session steps (logical time, deterministic):
    # a request still queued after this many ``step()`` calls expires
    deadline_steps: Optional[int] = None
    error: Optional[str] = None
    stats: Optional[ServeStats] = None
    submitted_at: int = 0
    followers: List["ServeRequest"] = dataclasses.field(
        default_factory=list)


class ServeTicket:
    """Handle returned by ``submit``: resolved when the session's run
    loop finishes (or expires) the request."""

    def __init__(self, request: ServeRequest):
        self.request = request

    def done(self) -> bool:
        return self.request.done

    def result(self) -> np.ndarray:
        if not self.request.done:
            raise RuntimeError(
                "request not finished — drive ServeSession.run()/step()")
        if self.request.error is not None:
            raise RuntimeError(self.request.error)
        return np.asarray(self.request.out, np.int32)


class ServeSession:
    def __init__(self, model: Model, params, *, n_slots: int = 4,
                 max_len: int = 256, kv: Optional[KVRepository] = None,
                 eos_token: int = -1, every_k: int = 8,
                 max_queue: int = 256):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.kv = kv
        self.eos = eos_token
        self.every_k = every_k
        self.max_queue = max_queue

        self.cache = model.init_cache(n_slots, max_len)
        self.slot_req: List[Optional[ServeRequest]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)   # next write index
        self.next_tok = np.zeros(n_slots, np.int32)
        self._slot_pin: List[Optional[object]] = [None] * n_slots
        self._queues: Dict[str, collections.deque] = {}
        self._tenants: List[str] = []         # round-robin order
        self._rr = 0
        self._inflight: Dict[bytes, ServeRequest] = {}
        self._rids = itertools.count()
        self._tick = 0                        # logical step counter
        self.stats = {"submitted": 0, "served": 0, "expired": 0,
                      "singleflight_hits": 0, "dup_executions": 0,
                      "reused_tokens": 0, "prefilled_tokens": 0}
        self._decode = jax.jit(
            lambda p, b, c, i: model.decode_step(p, b, c, i))
        # jitted prefill with a dynamic start offset: one compile per
        # suffix LENGTH, shared across every splice depth — eager
        # dispatch would otherwise swamp the reuse win
        self._prefill_fn = jax.jit(
            lambda p, b, c, s: model.prefill(p, b, c, start=s))

    # ---------------------------------------------------------------- util
    @property
    def _positional(self) -> bool:
        cfg = self.model.cfg
        return (cfg.family in ("dense", "moe", "vlm", "encdec")
                and cfg.ssm is None and cfg.xlstm is None)

    def _positions(self, start, length, batch=1):
        pos = jnp.arange(start, start + length, dtype=jnp.int32)
        if self.model.cfg.m_rope:
            return jnp.tile(pos[None, None], (3, batch, 1))
        return pos

    def _probe_splice(self, prompt: np.ndarray, *, strict: bool):
        """probe → splice, pin on success.  ``strict`` drops exact
        full-prompt hits (the batch path seeds its first token from the
        prefill logits, so it always prefills at least one token)."""
        if self.kv is None:
            return None
        hit = self.kv.probe(prompt)
        if hit is None or hit.length > len(prompt) \
                or (strict and hit.length >= len(prompt)):
            return None
        hit = self.kv.splice(hit)
        if hit is None:
            return None                # quarantined → cold prefill
        self.kv.record_use(hit)
        self.kv.pin(hit.entry)
        return hit

    def _prefill(self, prompt, cache, start):
        """Prefill ``prompt[start:]``; feeds the cost model's online
        prefill-rate calibration (the serve-path analog of IO bandwidth
        calibration — what prices snapshots for admission)."""
        s = len(prompt)
        batch = {"tokens": jnp.asarray(prompt[None, start:]),
                 "positions": self._positions(start, s - start)}
        t0 = time.perf_counter()
        logits, cache = self._prefill_fn(self.params, batch, cache,
                                         jnp.int32(start))
        if self.kv is not None:
            jax.block_until_ready(logits)
            self.kv.cost_model.observe_prefill(
                s - start, time.perf_counter() - t0)
        return logits, cache

    # ---------------------------------------------------------- sequential
    def serve(self, prompt: np.ndarray, n_decode: int) -> tuple:
        """Synchronous single-request path: greedily decode ``n_decode``
        tokens.  Returns ``(generated tokens, ServeStats)``."""
        t0 = time.time()
        prompt = np.asarray(prompt, np.int32)
        s = len(prompt)

        reused = 0
        cache = self.model.init_cache(1, self.max_len)
        start = 0
        hit = self._probe_splice(prompt, strict=False)
        if hit is not None:
            cache = hit.cache
            start = reused = hit.length
        try:
            if start < s:
                logits, cache = self._prefill(prompt, cache, start)
            elif hit is not None and hit.logits is not None:
                # exact hit: stored logits — a recurrent state must not
                # be advanced again by replaying the final token
                logits = hit.logits
            else:
                # positional cache: replaying the last token is
                # idempotent
                batch = {"tokens": jnp.asarray(prompt[None, -1:]),
                         "positions": self._positions(s - 1, 1)}
                logits, cache = self._decode(self.params, batch, cache,
                                             jnp.int32(s - 1))

            if self.kv is not None and reused < s:
                # positional (attention) caches admit intermediate-
                # prefix aliases (the sub-job enumeration analogue);
                # recurrent states are exact-length only
                self.kv.store_prefix(
                    prompt, cache, logits=logits,
                    every_k=self.every_k if self._positional else 0)

            out = []
            tok = int(jnp.argmax(logits[0, -1]))
            for i in range(n_decode):
                out.append(tok)
                batch = {"tokens": jnp.asarray([[tok]], jnp.int32),
                         "positions": self._positions(s + i, 1)}
                logits, cache = self._decode(self.params, batch, cache,
                                             jnp.int32(s + i))
                tok = int(jnp.argmax(logits[0, -1]))
        finally:
            if hit is not None:
                self.kv.unpin(hit.entry)

        self.stats["served"] += 1
        self.stats["reused_tokens"] += reused
        self.stats["prefilled_tokens"] += s - reused
        return np.array(out, np.int32), ServeStats(
            prefilled_tokens=s - reused, reused_tokens=reused,
            decoded_tokens=n_decode, wall_s=time.time() - t0)

    # ---------------------------------------------------------- submission
    def submit(self, prompt: np.ndarray, max_new: int, *,
               tenant: str = "default",
               deadline_steps: Optional[int] = None) -> ServeTicket:
        """Enqueue a request; returns a ticket resolved by the run loop.
        An identical in-flight (prompt, max_new) rides the leader's
        decode (singleflight); a full queue raises `SessionSaturated`."""
        prompt = np.asarray(prompt, np.int32)
        key = prompt.tobytes() + b":" + str(int(max_new)).encode()
        leader = self._inflight.get(key)
        if leader is not None and not leader.done:
            r = ServeRequest(next(self._rids), prompt, max_new,
                             tenant=tenant, deadline_steps=deadline_steps,
                             submitted_at=self._tick)
            leader.followers.append(r)
            self.stats["singleflight_hits"] += 1
            return ServeTicket(r)
        if sum(len(q) for q in self._queues.values()) >= self.max_queue:
            raise SessionSaturated(
                f"serve queue full ({self.max_queue} requests)")
        r = ServeRequest(next(self._rids), prompt, max_new,
                         tenant=tenant, deadline_steps=deadline_steps,
                         submitted_at=self._tick)
        r._key = key
        self._inflight[key] = r
        if tenant not in self._queues:
            self._queues[tenant] = collections.deque()
            self._tenants.append(tenant)
        self._queues[tenant].append(r)
        self.stats["submitted"] += 1
        return ServeTicket(r)

    def _resolve(self, r: ServeRequest) -> None:
        r.done = True
        self._inflight.pop(getattr(r, "_key", None), None)
        for f in r.followers:
            f.out = list(r.out)
            f.error = r.error
            f.stats = r.stats
            f.done = True

    def _expire(self, r: ServeRequest) -> None:
        r.error = (f"deadline exceeded: queued {self._tick - r.submitted_at}"
                   f" steps, deadline {r.deadline_steps}")
        self.stats["expired"] += 1
        self._resolve(r)

    def _next_request(self) -> Optional[ServeRequest]:
        """Round-robin across tenants (per-tenant FIFO): one tenant's
        burst cannot starve the others' admissions."""
        for _ in range(len(self._tenants)):
            t = self._tenants[self._rr % len(self._tenants)]
            self._rr += 1
            q = self._queues[t]
            while q:
                r = q.popleft()
                if r.deadline_steps is not None \
                        and self._tick - r.submitted_at > r.deadline_steps:
                    self._expire(r)
                    continue
                return r
        return None

    # ------------------------------------------------------------ batching
    def _admit(self, slot: int, r: ServeRequest) -> None:
        """Prefill the request into a size-1 scratch cache (through the
        repository verbs), splice its rows into the slot, seed the first
        token, and pin the reused snapshot for the slot's lifetime."""
        s = len(r.prompt)
        scratch = self.model.init_cache(1, self.max_len)
        start = 0
        hit = self._probe_splice(r.prompt, strict=True)
        if hit is not None:
            scratch, start = hit.cache, hit.length
        logits, scratch = self._prefill(r.prompt, scratch, start)
        if self.kv is not None:
            self.kv.store_prefix(r.prompt, scratch, logits=logits)

        # splice scratch row 0 into slot `slot` of the live cache
        def splice(live, sc):
            if live.ndim >= 2 and live.shape[1] == self.n_slots \
                    and sc.shape[1] == 1:
                return live.at[:, slot].set(sc[:, 0])
            return live
        self.cache = jax.tree_util.tree_map(splice, self.cache, scratch)
        self.slot_req[slot] = r
        self._slot_pin[slot] = hit.entry if hit is not None else None
        self.slot_pos[slot] = s
        self.next_tok[slot] = int(jnp.argmax(logits[0, -1]))
        r.stats = ServeStats(prefilled_tokens=s - start,
                             reused_tokens=start,
                             decoded_tokens=0, wall_s=0.0)
        self.stats["reused_tokens"] += start
        self.stats["prefilled_tokens"] += s - start

    def _finish(self, slot: int) -> None:
        r = self.slot_req[slot]
        if r.stats is not None:
            r.stats.decoded_tokens = len(r.out)
        self.stats["served"] += 1
        self._resolve(r)
        if self._slot_pin[slot] is not None:
            self.kv.unpin(self._slot_pin[slot])
            self._slot_pin[slot] = None
        self.slot_req[slot] = None          # slot freed -> admission

    def step(self) -> bool:
        """Admit queued requests to free slots, then one batched decode
        step for every live slot.  Returns False when nothing is live."""
        self._tick += 1
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None:
                r = self._next_request()
                if r is None:
                    break
                self._admit(slot, r)
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return False

        # per-slot positions: a (B, 1) positions array (rope consumes
        # the batched form); idle slots decode harmlessly at position 0
        pos = jnp.asarray(self.slot_pos, jnp.int32)[:, None]
        if self.model.cfg.m_rope:
            pos = jnp.tile(pos[None], (3, 1, 1))
        batch = {"tokens": jnp.asarray(self.next_tok[:, None]),
                 "positions": pos}
        logits, self.cache = self._decode(self.params, batch, self.cache,
                                          jnp.asarray(self.slot_pos))
        toks = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)

        for slot in live:
            r = self.slot_req[slot]
            r.out.append(int(self.next_tok[slot]))
            self.slot_pos[slot] += 1
            self.next_tok[slot] = int(toks[slot])
            if len(r.out) >= r.max_new or int(toks[slot]) == self.eos \
                    or self.slot_pos[slot] >= self.max_len - 1:
                self._finish(slot)
        return True

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def run(self, max_steps: int = 10_000) -> None:
        """Drive the continuous-batching loop until every submitted
        request is finished (or ``max_steps`` elapses)."""
        for _ in range(max_steps):
            if not self.step() and not self.pending():
                break
