"""Uniform model API: build(config) -> Model with train/prefill/decode
step functions and ShapeDtypeStruct input specs for every assigned input
shape (the dry-run contract).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import encdec as ED
from . import lm as LM

# assigned input shapes: name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and not cfg.is_subquadratic():
        return False, ("pure full-attention architecture: long_500k needs "
                       "sub-quadratic attention (skip noted in DESIGN.md)")
    return True, ""


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict:
        if self.cfg.family == "encdec":
            return ED.init_encdec(self.cfg, key)
        return LM.init_lm(self.cfg, key)

    def init_shapes(self, key) -> Dict:
        return jax.eval_shape(lambda k: self.init(k), key)

    # ---------------------------------------------------------------- fwd/loss
    def loss_fn(self, params, batch):
        cfg = self.cfg
        if cfg.family == "encdec":
            logits, aux = ED.encdec_forward(
                cfg, params, batch["enc_embeds"], batch["tokens"],
                batch["enc_positions"], batch["positions"])
        else:
            logits, aux = LM.lm_forward(
                cfg, params, batch.get("embeds", batch.get("tokens")),
                batch["positions"])
        logp = jax.nn.log_softmax(logits, -1)
        ll = jnp.take_along_axis(logp, batch["labels"][..., None], -1)[..., 0]
        loss = -ll.mean()
        return loss + 0.01 * aux, (loss, aux)

    # ---------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_len: int, enc_len: int = 0):
        if self.cfg.family == "encdec":
            return ED.init_dec_cache(self.cfg, batch, max_len,
                                     enc_len or max_len)
        return LM.init_cache(self.cfg, batch, max_len)

    def prefill(self, params, batch, cache, start=None):
        cfg = self.cfg
        if cfg.family == "encdec":
            return ED.encdec_prefill(cfg, params, batch["enc_embeds"],
                                     batch["enc_positions"],
                                     batch["tokens"], batch["positions"],
                                     cache)
        return LM.lm_prefill(cfg, params,
                             batch.get("embeds", batch.get("tokens")),
                             batch["positions"], cache, start)

    def decode_step(self, params, batch, cache, index):
        cfg = self.cfg
        if cfg.family == "encdec":
            return ED.encdec_decode(cfg, params, batch["tokens"],
                                    batch["positions"], cache, index)
        return LM.lm_decode(cfg, params,
                            batch.get("embeds", batch.get("tokens")),
                            batch["positions"], cache, index)

    # ---------------------------------------------------------------- specs
    def input_specs(self, shape_name: str) -> Dict:
        """ShapeDtypeStruct stand-ins for every model input (no device
        allocation) — the dry-run contract."""
        cfg = self.cfg
        seq, gbs, kind = SHAPES[shape_name]
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)
        S = jax.ShapeDtypeStruct

        def positions(b, s):
            if cfg.m_rope:
                return S((3, b, s), i32)
            return S((s,), i32)

        if kind == "train":
            batch = {"positions": positions(gbs, seq),
                     "labels": S((gbs, seq), i32)}
            if cfg.family == "encdec":
                batch["enc_embeds"] = S((gbs, seq, cfg.d_model), dt)
                batch["enc_positions"] = S((seq,), i32)
                batch["tokens"] = S((gbs, seq), i32)
            elif cfg.frontend == "embeds":
                batch["embeds"] = S((gbs, seq, cfg.d_model), dt)
            else:
                batch["tokens"] = S((gbs, seq), i32)
            return batch

        if kind == "prefill":
            batch = {"positions": positions(gbs, seq)}
            if cfg.family == "encdec":
                batch["enc_embeds"] = S((gbs, seq, cfg.d_model), dt)
                batch["enc_positions"] = S((seq,), i32)
                batch["tokens"] = S((gbs, seq), i32)
            elif cfg.frontend == "embeds":
                batch["embeds"] = S((gbs, seq, cfg.d_model), dt)
            else:
                batch["tokens"] = S((gbs, seq), i32)
            cache = jax.eval_shape(
                lambda: self.init_cache(gbs, seq, enc_len=seq))
            return {"batch": batch, "cache": cache}

        # decode: one new token against a cache of length seq
        batch = {"positions": positions(gbs, 1)}
        if cfg.family == "encdec":
            batch["tokens"] = S((gbs, 1), i32)
        elif cfg.frontend == "embeds":
            batch["embeds"] = S((gbs, 1, cfg.d_model), dt)
        else:
            batch["tokens"] = S((gbs, 1), i32)
        cache = jax.eval_shape(lambda: self.init_cache(gbs, seq,
                                                       enc_len=min(seq, 32768)))
        return {"batch": batch, "cache": cache,
                "index": S((), i32)}

    # ---------------------------------------------------------------- demo data
    def demo_batch(self, key, seq: int, gbs: int, kind: str = "train"):
        """Small concrete batch for smoke tests."""
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        pos = (jnp.tile(jnp.arange(seq, dtype=jnp.int32)[None, None],
                        (3, gbs, 1))
               if cfg.m_rope else jnp.arange(seq, dtype=jnp.int32))
        batch = {"positions": pos,
                 "labels": jax.random.randint(ks[0], (gbs, seq), 0,
                                              cfg.vocab_size)}
        if cfg.family == "encdec":
            batch["enc_embeds"] = jax.random.normal(
                ks[1], (gbs, seq, cfg.d_model), jnp.dtype(cfg.dtype))
            batch["enc_positions"] = jnp.arange(seq, dtype=jnp.int32)
            batch["tokens"] = jax.random.randint(ks[2], (gbs, seq), 0,
                                                 cfg.vocab_size)
        elif cfg.frontend == "embeds":
            batch["embeds"] = jax.random.normal(
                ks[1], (gbs, seq, cfg.d_model), jnp.dtype(cfg.dtype))
        else:
            batch["tokens"] = jax.random.randint(ks[2], (gbs, seq), 0,
                                                 cfg.vocab_size)
        return batch


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
