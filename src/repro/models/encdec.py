"""Encoder–decoder backbone (SeamlessM4T text/speech transformer).

The speech frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, T, d).  The decoder is a standard causal
stack with cross-attention; decode caches both its self-attention KV and
the projected cross KV (computed once at prefill).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (Params, _dtype, _init, attn_forward, init_attn,
                     init_mlp, mlp_forward, rmsnorm)


def init_encdec(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    dt = _dtype(cfg)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.ones((cfg.d_model,), dt),
                "attn": init_attn(cfg, k1),
                "ln2": jnp.ones((cfg.d_model,), dt),
                "ffn": init_mlp(cfg, k1, cfg.d_ff)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": jnp.ones((cfg.d_model,), dt),
                "self_attn": init_attn(cfg, k1),
                "ln_x": jnp.ones((cfg.d_model,), dt),
                "cross_attn": init_attn(cfg, k2),
                "ln2": jnp.ones((cfg.d_model,), dt),
                "ffn": init_mlp(cfg, k3, cfg.d_ff)}

    ek = jax.random.split(ks[0], cfg.n_encoder_layers)
    dk = jax.random.split(ks[1], cfg.n_layers)
    enc = jax.tree_util.tree_map(lambda *x: jnp.stack(x),
                                 *[enc_layer(k) for k in ek])
    dec = jax.tree_util.tree_map(lambda *x: jnp.stack(x),
                                 *[dec_layer(k) for k in dk])
    return {
        "enc_blocks": enc,
        "dec_blocks": dec,
        "embed": _init(ks[2], (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
        "ln_enc": jnp.ones((cfg.d_model,), dt),
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "lm_head": _init(ks[3], (cfg.d_model, cfg.vocab_size), dt),
    }


def encode(cfg: ModelConfig, p: Params, enc_embeds, enc_pos):
    x = enc_embeds.astype(_dtype(cfg))

    def body(x, bp):
        h = rmsnorm(x, bp["ln1"], cfg.norm_eps)
        o, _ = attn_forward(cfg, bp["attn"], h, enc_pos, causal=False)
        x = x + o
        h = rmsnorm(x, bp["ln2"], cfg.norm_eps)
        return x + mlp_forward(bp["ffn"], h), None

    if cfg.remat:
        body = jax.checkpoint(body)
    from .lm import scan_blocks
    x, _ = scan_blocks(cfg, body, x, p["enc_blocks"])
    return rmsnorm(x, p["ln_enc"], cfg.norm_eps)


def _cross_kv(cfg: ModelConfig, bp: Params, enc_out):
    b, t, _ = enc_out.shape
    h, dh = cfg.n_kv_heads, cfg.head_dim
    k = jnp.einsum("btd,de->bte", enc_out, bp["cross_attn"]["wk"])
    v = jnp.einsum("btd,de->bte", enc_out, bp["cross_attn"]["wv"])
    return (k.reshape(b, t, h, dh).transpose(0, 2, 1, 3),
            v.reshape(b, t, h, dh).transpose(0, 2, 1, 3))


def _dec_sublayer(cfg, bp, x, pos, self_cache, index, cross_kv):
    h = rmsnorm(x, bp["ln1"], cfg.norm_eps)
    o, new_self = attn_forward(cfg, bp["self_attn"], h, pos,
                               self_cache, index)
    x = x + o
    h = rmsnorm(x, bp["ln_x"], cfg.norm_eps)
    o, _ = attn_forward(cfg, bp["cross_attn"], h, pos,
                        kv_override=cross_kv)
    x = x + o
    h = rmsnorm(x, bp["ln2"], cfg.norm_eps)
    return x + mlp_forward(bp["ffn"], h), new_self


def encdec_forward(cfg: ModelConfig, p: Params, enc_embeds, dec_tokens,
                   enc_pos, dec_pos):
    """Teacher-forcing training forward.  Returns (logits, aux=0)."""
    enc_out = encode(cfg, p, enc_embeds, enc_pos)
    x = jnp.take(p["embed"], dec_tokens, axis=0)

    def body(x, bp):
        ckv = _cross_kv(cfg, bp, enc_out)
        x, _ = _dec_sublayer(cfg, bp, x, dec_pos, None, None, ckv)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    from .lm import scan_blocks
    x, _ = scan_blocks(cfg, body, x, p["dec_blocks"])
    x = rmsnorm(x, p["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"]).astype(jnp.float32)
    return logits, jnp.float32(0.0)


def init_dec_cache(cfg: ModelConfig, batch: int, max_len: int,
                   enc_len: int) -> Dict:
    dt = _dtype(cfg)
    nl = cfg.n_layers
    kv = (nl, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    ckv = (nl, batch, cfg.n_kv_heads, enc_len, cfg.head_dim)
    return {"self": (jnp.zeros(kv, dt), jnp.zeros(kv, dt)),
            "cross": (jnp.zeros(ckv, dt), jnp.zeros(ckv, dt))}


def encdec_prefill(cfg: ModelConfig, p: Params, enc_embeds, enc_pos,
                   dec_tokens, dec_pos, cache: Dict):
    """Encode + run decoder prefix, filling self- and cross-caches."""
    enc_out = encode(cfg, p, enc_embeds, enc_pos)
    x = jnp.take(p["embed"], dec_tokens, axis=0)
    zero = jnp.int32(0)

    def body(x, scan_in):
        bp, sc = scan_in
        ckv = _cross_kv(cfg, bp, enc_out)
        x, new_self = _dec_sublayer(cfg, bp, x, dec_pos, sc, zero, ckv)
        return x, (new_self, ckv)

    from .lm import scan_blocks
    x, (new_self, new_cross) = scan_blocks(cfg, body, x,
                                           (p["dec_blocks"], cache["self"]))
    x = rmsnorm(x, p["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x[:, -1:], p["lm_head"]) \
        .astype(jnp.float32)
    return logits, {"self": new_self, "cross": new_cross}


def encdec_decode(cfg: ModelConfig, p: Params, dec_tokens, dec_pos,
                  cache: Dict, index):
    """One decode step against cached self-KV + cross-KV."""
    x = jnp.take(p["embed"], dec_tokens, axis=0)

    def body(x, scan_in):
        bp, sc, ckv = scan_in
        x, new_self = _dec_sublayer(cfg, bp, x, dec_pos, sc, index, ckv)
        return x, new_self

    from .lm import scan_blocks
    x, new_self = scan_blocks(cfg, body, x, (p["dec_blocks"], cache["self"],
                                             cache["cross"]))
    x = rmsnorm(x, p["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"]).astype(jnp.float32)
    return logits, {"self": new_self, "cross": cache["cross"]}
