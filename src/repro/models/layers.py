"""Transformer building blocks, functional style (params = pytrees).

Covers every attention variant in the assigned architecture set: GQA with
optional qk-norm and biases, MLA (compressed-KV latent attention), and
M-RoPE (3-axis rotary for VLM backbones).  The MoE block is the sort-based
dropping implementation (static shapes, expert-parallel over the "model"
mesh axis; the scatter into (E, C, d) expert buffers is where GSPMD plants
the all-to-all).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

Params = Dict[str, jnp.ndarray]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _init(key, shape, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / (shape[0] ** 0.5)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms


def rmsnorm(x, w, eps):
    # NOTE(§Perf cell 2, refuted): a "traffic-lean" variant (f32 variance
    # reduction, bf16 apply path) measured WORSE (+7% memory term) — the
    # f32 copy is still materialized for the reduction and the extra bf16
    # ops outweigh the saved converts under the host backend's fusion.
    xf = x.astype(jnp.float32)
    n = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (n * w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)


def rope_cos_sin(positions, dim, theta, dtype):
    """positions: (..., S) int32; returns cos/sin (..., S, dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x: (B, H, S, D); cos/sin: (B, S, D//2) or (S, D//2)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    if cos.ndim == 2:
        cos = cos[None, None]
        sin = sin[None, None]
    else:
        cos = cos[:, None]
        sin = sin[:, None]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def mrope_cos_sin(positions3, dim, theta, sections, dtype):
    """positions3: (3, B, S) — temporal/height/width position ids.
    Each frequency band takes its positions from the section it belongs to
    (Qwen2-VL M-RoPE)."""
    import numpy as np
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions3.astype(jnp.float32)[..., None] * inv  # (3, B, S, D/2)
    idx = np.repeat(np.arange(3), np.asarray(sections))     # (D/2,) static
    ang = jnp.take_along_axis(
        ang, jnp.asarray(idx, jnp.int32)[None, None, None, :], axis=0)[0]
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


# ---------------------------------------------------------------------------
# Attention core (shared softmax path)


def _sdpa_chunked(q, k, v, *, causal, q_offset, kv_len=None,
                  chunk=2048, unroll=False):
    """Memory-efficient attention (Rabe & Staats / flash-style) in pure
    XLA: online softmax over KV chunks, so no (Sq, Skv) tensor ever hits
    HBM.  The chunk body is rematerialized (p recomputed in the backward
    pass).  ``unroll=True`` is used by the dry-run cost variants — XLA
    cost analysis is trip-count-blind on while loops."""
    b, h, sq, dh = q.shape
    skv = k.shape[2]
    dv = v.shape[3]            # may differ from dh (MLA)
    nc = skv // chunk
    scale = 1.0 / (dh ** 0.5)
    qf = q.astype(jnp.float32) * scale
    q_pos = (jnp.arange(sq) + q_offset)[None, None, :, None]
    kvl = None if kv_len is None else jnp.reshape(kv_len, (-1, 1, 1, 1))

    kc = jnp.moveaxis(k.reshape(b, h, nc, chunk, dh), 2, 0)
    vc = jnp.moveaxis(v.reshape(b, h, nc, chunk, dv), 2, 0)
    starts = jnp.arange(nc) * chunk

    def body(carry, inp):
        m, l, acc = carry
        kcb, vcb, c0 = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kcb.astype(jnp.float32))
        k_pos = c0 + jnp.arange(chunk)[None, None, None, :]
        mask = jnp.ones(s.shape, bool)
        if kvl is not None:
            mask = mask & (k_pos < kvl)
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vcb.dtype), vcb,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    init = (jnp.full((b, h, sq), -1e30, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, h, sq, dv), jnp.float32))
    if unroll:
        carry = init
        for i in range(nc):
            carry, _ = body(carry, (kc[i], vc[i], starts[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), init,
                                      (kc, vc, starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def _sdpa(q, k, v, *, causal, q_offset, kv_len=None, cfg=None):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D).

    GQA is handled by broadcasting KV heads to Hq (not by folding query
    heads into the KV-head dim): the folded form would leave the
    (B, Hkv, ...) score tensor unshardable over a 16-way "model" axis when
    Hkv < 16, replicating the softmax on every device — measured as a 6x
    per-layer compute-term inflation in the dry-run (EXPERIMENTS.md §Perf,
    iteration 0)."""
    from . import dist
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    # chunked (flash-style) attention for LONG sequences: at 32k the
    # (S, S) tensor is 4 GiB/head-batch f32 and cannot be materialized;
    # at 4k the naive form is metric-equivalent (HLO bytes-accessed is
    # residency-blind — §Perf cell 2 iteration 1) and fuses better.
    if dist.optimized() and sq >= 8192:
        chunk = 2048 if skv % 2048 == 0 else (
            1024 if skv % 1024 == 0 else 0)
        if chunk and skv > chunk:
            unroll = bool(cfg is not None and not cfg.scan_layers)
            return _sdpa_chunked(q, k, v, causal=causal,
                                 q_offset=q_offset, kv_len=kv_len,
                                 chunk=chunk, unroll=unroll)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    s *= 1.0 / (d ** 0.5)
    k_pos = jnp.arange(skv)[None, None, None, :]
    q_pos = (jnp.arange(sq) + q_offset)[None, None, :, None]
    mask = jnp.ones((1, 1, sq, skv), bool)
    if kv_len is not None:
        mask = mask & (k_pos < jnp.reshape(kv_len, (-1, 1, 1, 1)))
    if causal:
        mask = mask & (k_pos <= q_pos)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # NOTE(§Perf cell 2, refuted): casting p to bf16 before the PV dot
    # measured +1.7% bytes on the host backend (the convert doesn't fuse
    # there); kept only inside the chunked long-sequence path where the
    # VMEM win is structural.
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def _decode_attn_seq_sharded(q, k_new, v_new, cache, cache_index, mesh):
    """Flash-decoding with a SEQUENCE-sharded KV cache under shard_map.

    Baseline GSPMD decode reshards/gathers the model-sharded cache every
    step ("involuntary full rematerialization" warnings; llama4 decode_32k
    measured 2.07s of collective time PER TOKEN).  Here the cache never
    moves: each model shard updates its own S-slice (the owner is decided
    by the index) and computes a partial softmax over its slice; partials
    combine with one tiny psum of (B, H, 1, D)-sized tensors.

    q/k_new/v_new: (B, H|Hkv, 1, Dh) replicated over "model";
    cache: (k, v) with shape (B, Hkv, Smax, Dh), S sharded over "model".
    """
    from jax.sharding import PartitionSpec as P
    from . import dist

    ck, cv = cache
    b, hq = q.shape[0], q.shape[1]
    hkv, smax, dh = ck.shape[1], ck.shape[2], ck.shape[3]
    tp = mesh.shape["model"]
    s_loc = smax // tp
    dp = dist.dp_axis_names(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    dp_spec = None
    if dp and b % dp_total == 0 and b >= dp_total:
        dp_spec = dp if len(dp) > 1 else dp[0]

    def body(q, kn, vn, ckl, cvl, idx):
        i = jax.lax.axis_index("model")
        base = i * s_loc
        lpos = idx - base
        in_rng = (lpos >= 0) & (lpos < s_loc)
        lp = jnp.clip(lpos, 0, s_loc - 1)
        ck2 = jax.lax.dynamic_update_slice(ckl, kn.astype(ckl.dtype),
                                           (0, 0, lp, 0))
        ck2 = jnp.where(in_rng, ck2, ckl)
        cv2 = jax.lax.dynamic_update_slice(cvl, vn.astype(cvl.dtype),
                                           (0, 0, lp, 0))
        cv2 = jnp.where(in_rng, cv2, cvl)

        g = hq // hkv
        k = jnp.repeat(ck2, g, axis=1) if g > 1 else ck2
        v = jnp.repeat(cv2, g, axis=1) if g > 1 else cv2
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * (1.0 / dh ** 0.5)
        pos = base + jnp.arange(s_loc)
        s = jnp.where((pos <= idx)[None, None, None, :], s, -1e30)
        m = s.max(-1)
        m_all = jax.lax.pmax(m, "model")
        p = jnp.exp(s - m_all[..., None])
        l = jax.lax.psum(p.sum(-1), "model")
        o = jax.lax.psum(
            jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)),
            "model")
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype), ck2, cv2

    from ..launch.mesh import shard_map
    rep4 = P(dp_spec, None, None, None)
    cache_spec = P(dp_spec, None, "model", None)
    out, ck2, cv2 = shard_map(
        body, mesh=mesh,
        in_specs=(rep4, rep4, rep4, cache_spec, cache_spec, P()),
        out_specs=(rep4, cache_spec, cache_spec),
    )(q, k_new, v_new, ck, cv, cache_index)
    return out, (ck2, cv2)


# ---------------------------------------------------------------------------
# GQA attention


def init_attn(cfg: ModelConfig, key) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p = {
        "wq": _init(ks[0], (d, cfg.q_dim), dt),
        "wk": _init(ks[1], (d, cfg.kv_dim), dt),
        "wv": _init(ks[2], (d, cfg.kv_dim), dt),
        "wo": _init(ks[3], (cfg.q_dim, d), dt),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dt)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dt)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dt)
    return p


def attn_forward(cfg: ModelConfig, p: Params, x, positions,
                 cache: Optional[Tuple] = None, cache_index=None,
                 causal: bool = True, kv_override=None):
    """x: (B, S, d).  cache: (k, v) rings (B, Hkv, Smax, Dh) when decoding;
    cache_index: () int32 current length.  kv_override: (k, v) from an
    encoder for cross-attention.  Returns (out, new_cache)."""
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)

    if kv_override is None:
        k = jnp.einsum("bsd,de->bse", x, p["wk"])
        v = jnp.einsum("bsd,de->bse", x, p["wv"])
        if "bk" in p:
            k = k + p["bk"]
            v = v + p["bv"]
        k = k.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
        v = v.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    else:
        k, v = kv_override

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        if kv_override is None:
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)

    if kv_override is None:
        if cfg.m_rope:
            cos, sin = mrope_cos_sin(positions, dh, cfg.rope_theta,
                                     cfg.mrope_sections, x.dtype)
        else:
            cos, sin = rope_cos_sin(positions, dh, cfg.rope_theta, x.dtype)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = None
    kv_len = None
    q_offset = 0
    if cache is not None and kv_override is None:
        from . import dist
        mesh = dist.get_mesh()
        if (s == 1 and dist.optimized() and mesh is not None
                and "model" in mesh.axis_names
                and cache[0].shape[2] % mesh.shape["model"] == 0):
            # sequence-sharded flash-decoding (§Perf cell 3)
            o4, new_cache = _decode_attn_seq_sharded(
                q, k, v, cache, jnp.asarray(cache_index, jnp.int32),
                mesh)
            o = o4.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
            return jnp.einsum("bse,ed->bsd", o, p["wo"]), new_cache
        ck, cv = cache
        if getattr(cache_index, "ndim", 0) == 1:
            # per-row cache indices (continuous batching: each slot is at
            # its own position).  vmapped update; causality = the per-row
            # kv_len mask (exact for single-token decode).
            upd = jax.vmap(lambda c, x2, i: jax.lax.dynamic_update_slice(
                c, x2, (0, i, 0)))
            ck = upd(ck, k.astype(ck.dtype), cache_index)
            cv = upd(cv, v.astype(cv.dtype), cache_index)
            k, v = ck, cv
            new_cache = (ck, cv)
            kv_len = cache_index + s
            q_offset = 0
            causal = False
        else:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, 0, cache_index, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, 0, cache_index, 0))
            k, v = ck, cv
            new_cache = (ck, cv)
            kv_len = cache_index + s
            q_offset = cache_index
            causal = True
    elif kv_override is not None:
        causal = False
        q_offset = 0
    else:
        q_offset = 0

    o = _sdpa(q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
              cfg=cfg)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return jnp.einsum("bse,ed->bsd", o, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLA attention (MiniCPM3 / DeepSeek-V2)


def init_mla(cfg: ModelConfig, key) -> Params:
    m = cfg.mla
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    d, h = cfg.d_model, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": _init(ks[0], (d, m.q_lora_rank), dt),
        "q_norm": jnp.ones((m.q_lora_rank,), dt),
        "wuq": _init(ks[1], (m.q_lora_rank, h * qk_head), dt),
        "wdkv": _init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dt),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dt),
        "wuk": _init(ks[3], (m.kv_lora_rank, h * m.qk_nope_head_dim), dt),
        "wuv": _init(ks[4], (m.kv_lora_rank, h * m.v_head_dim), dt),
        "wo": _init(ks[5], (h * m.v_head_dim, d), dt),
    }


def mla_forward(cfg: ModelConfig, p: Params, x, positions,
                cache: Optional[Tuple] = None, cache_index=None):
    """MLA: caches the compressed latent (c_kv, k_rope) — the paper-level
    memory win.  cache: (c_kv (B, Smax, r), k_rope (B, Smax, dr))."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads

    q = jnp.einsum("bsd,dr->bsr", x, p["wdq"])
    q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,re->bse", q, p["wuq"])
    q = q.reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q = q.transpose(0, 2, 1, 3)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = q[..., m.qk_nope_head_dim:]

    ckv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])
    c_kv, k_rope = ckv[..., :m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)

    cos, sin = rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta,
                            x.dtype)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, None], cos, sin)[:, 0]   # (B, S, dr)

    new_cache = None
    kv_len = None
    q_offset = 0
    if cache is not None:
        cc, cr = cache
        cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype),
                                          (0, cache_index, 0))
        cr = jax.lax.dynamic_update_slice(cr, k_rope.astype(cr.dtype),
                                          (0, cache_index, 0))
        c_kv, k_rope = cc, cr
        new_cache = (cc, cr)
        kv_len = cache_index + s
        q_offset = cache_index

    k_nope = jnp.einsum("bsr,re->bse", c_kv, p["wuk"]).reshape(
        b, -1, h, m.qk_nope_head_dim).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsr,re->bse", c_kv, p["wuv"]).reshape(
        b, -1, h, m.v_head_dim).transpose(0, 2, 1, 3)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, None],
                                  (b, h) + k_rope.shape[1:])], -1)
    qq = jnp.concatenate([q_nope, q_rope], -1)

    o = _sdpa(qq, k, v, causal=True, q_offset=q_offset, kv_len=kv_len,
              cfg=cfg)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head_dim)
    return jnp.einsum("bse,ed->bsd", o, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# FFN / MoE


def init_mlp(cfg: ModelConfig, key, d_ff: int) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {"wg": _init(ks[0], (d, d_ff), dt),
            "wu": _init(ks[1], (d, d_ff), dt),
            "wd": _init(ks[2], (d_ff, d), dt)}


def mlp_forward(p: Params, x):
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"]))
    u = jnp.einsum("bsd,df->bsf", x, p["wu"])
    return jnp.einsum("bsf,fd->bsd", g * u, p["wd"])


def init_moe(cfg: ModelConfig, key) -> Params:
    m = cfg.moe
    dt = _dtype(cfg)
    ks = jax.random.split(key, 5)
    d, e, f = cfg.d_model, m.n_experts, m.d_expert
    p = {
        "router": _init(ks[0], (d, e), jnp.float32, scale=0.02),
        "wg": _init(ks[1], (e, d, f), dt),
        "wu": _init(ks[2], (e, d, f), dt),
        "wd": _init(ks[3], (e, f, d), dt),
    }
    if m.n_shared:
        p["shared"] = init_mlp(cfg.with_(d_ff=m.d_expert * m.n_shared),
                               ks[4], m.d_expert * m.n_shared)
    return p


def moe_forward(cfg: ModelConfig, p: Params, x):
    """MoE dispatch.  x: (B, S, d) -> (out, aux_loss).

    Two implementations:
      * GSPMD path (default; correct everywhere) — the sort-based scatter
        below.  GSPMD cannot shard the data-dependent scatter and falls
        back to replicating the token tensor across the model axis: the
        dry-run measured ~3.4e13 collective bytes/device/step on
        qwen3-moe train_4k (~500x the analytic dispatch volume).
      * shard_map path (production) — experts live on their model shard;
        activations are replicated across the model axis between TP
        layers anyway, so each shard locally selects the tokens routed to
        ITS experts and the only collective is the same output psum TP
        already pays.  See EXPERIMENTS.md §Perf cell 1.
    """
    from . import dist
    mesh = dist.get_mesh()
    if (mesh is not None and "model" in mesh.axis_names
            and cfg.moe.n_experts % mesh.shape["model"] == 0):
        return _moe_forward_shard_map(cfg, p, x, mesh)
    return _moe_forward_gspmd(cfg, p, x)


def _moe_forward_gspmd(cfg: ModelConfig, p: Params, x):
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = m.top_k
    e = m.n_experts
    cap = max(1, int(t * k * m.capacity_factor / e))
    # keep MXU-aligned capacity where possible
    cap = max(8, (cap + 7) // 8 * 8)

    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = jax.lax.top_k(probs, k)                 # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): e * sum_e f_e * p_e
    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[eidx.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce)

    # sort-based dispatch
    flat_e = eidx.reshape(-1)                             # (T*k,)
    order = jnp.argsort(flat_e)
    sorted_e = jnp.take(flat_e, order)
    # rank within expert = position - segment start
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(t * k) - seg_start
    slot = sorted_e * cap + rank
    keep = rank < cap
    slot = jnp.where(keep, slot, e * cap)                 # park drops OOB

    tok = jnp.take(order // k, jnp.arange(t * k))         # token per entry
    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[slot].set(jnp.take(xf, tok, axis=0), mode="drop")
    buf = buf.reshape(e, cap, d)

    # expert FFN (batched over experts; E shards over "model")
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"]))
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    eo = jnp.einsum("ecf,efd->ecd", g * u, p["wd"]).reshape(e * cap, d)

    # combine
    gathered = jnp.take(eo, jnp.clip(slot, 0, e * cap - 1), axis=0)
    gathered = jnp.where(keep[:, None], gathered, 0)
    gate_per_entry = jnp.take(gates.reshape(-1), order)
    out = jnp.zeros((t, d), x.dtype)
    out = out.at[tok].add((gathered.astype(jnp.float32)
                           * gate_per_entry[:, None]).astype(x.dtype))

    if m.n_shared:
        out = out + mlp_forward(p["shared"], xf[None])[0]
    return out.reshape(b, s, d), aux


def _moe_forward_shard_map(cfg: ModelConfig, p: Params, x, mesh):
    """Expert-parallel MoE under shard_map: experts sharded over "model",
    tokens sharded over the DP axes and replicated over "model".  Each
    model shard routes its (replicated) tokens to its local experts; the
    only collective is the psum of partial outputs over "model"."""
    from jax.sharding import PartitionSpec as P
    from . import dist

    m = cfg.moe
    tp = mesh.shape["model"]
    e = m.n_experts
    e_loc = e // tp
    k = m.top_k
    b, s, d = x.shape
    dp = dist.dp_axis_names(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    if not dp or b % dp_total != 0 or b < dp_total:
        dp, dp_total = (), 1          # small batch: replicate over DP
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    t_loc = (b // dp_total) * s
    cap = max(8, (int(t_loc * k * m.capacity_factor / e) + 7) // 8 * 8)

    def body(xb, router, wg, wu, wd):
        bl, sl, _ = xb.shape
        t = bl * sl
        xf = xb.reshape(t, d)
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, -1)
        gates, eidx = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(0)
        ce = jnp.zeros((e,), jnp.float32).at[eidx.reshape(-1)].add(
            1.0 / (t * k))
        aux = e * jnp.sum(me * ce)
        for ax in dp:
            aux = jax.lax.pmean(aux, ax)

        # local-expert selection
        first = jax.lax.axis_index("model") * e_loc
        flat_e = eidx.reshape(-1)
        lid = flat_e - first
        local = (lid >= 0) & (lid < e_loc)
        sort_key = jnp.where(local, lid, e_loc)
        order = jnp.argsort(sort_key)
        sorted_lid = jnp.take(sort_key, order)
        seg_start = jnp.searchsorted(sorted_lid, sorted_lid, side="left")
        rank = jnp.arange(t * k) - seg_start
        keep = (sorted_lid < e_loc) & (rank < cap)
        slot = jnp.where(keep, sorted_lid * cap + rank, e_loc * cap)

        tok = jnp.take(order // k, jnp.arange(t * k))
        buf = jnp.zeros((e_loc * cap, d), xb.dtype)
        buf = buf.at[slot].set(jnp.take(xf, tok, axis=0), mode="drop")
        buf = buf.reshape(e_loc, cap, d)

        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        eo = jnp.einsum("ecf,efd->ecd", g * u, wd).reshape(e_loc * cap, d)

        gathered = jnp.take(eo, jnp.clip(slot, 0, e_loc * cap - 1), axis=0)
        gathered = jnp.where(keep[:, None], gathered, 0)
        gate_per_entry = jnp.take(gates.reshape(-1), order)
        out = jnp.zeros((t, d), xb.dtype)
        out = out.at[tok].add((gathered.astype(jnp.float32)
                               * gate_per_entry[:, None]).astype(xb.dtype))
        out = jax.lax.psum(out, "model")
        return out.reshape(bl, sl, d), aux

    from ..launch.mesh import shard_map
    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(dp_spec, None, None), P()),
    )(x, p["router"], p["wg"], p["wu"], p["wd"])

    if m.n_shared:   # shared expert: plain TP outside the shard_map
        out = out + mlp_forward(p["shared"], x.reshape(1, -1, d)) \
            .reshape(b, s, d)
    return out, aux
