"""Model configuration system covering all assigned architecture families:
dense GQA transformers (w/ qk-norm, biases), MLA, MoE, encoder-decoder,
xLSTM, M-RoPE VLM backbones, and Mamba/attention hybrids.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 style, MiniCPM3 dims)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_expert: int = 0            # expert FFN hidden size
    n_shared: int = 0            # shared (always-on) experts
    every_k_layers: int = 1      # MoE on layers where (i % k == k-1)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective SSM."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0             # 0 => ceil(d_model / 16)
    chunk: int = 256             # chunked-scan length (0 => full sequence)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8         # 1 sLSTM per 8 blocks (xLSTM[7:1])
    proj_factor: float = 2.0     # mLSTM pre-up-projection factor
    chunk_size: int = 256        # chunkwise-parallel training form


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | encdec | ssm | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # attention options
    qk_norm: bool = False
    attn_bias: bool = False
    rope_theta: float = 1_000_000.0
    mla: Optional[MLAConfig] = None
    m_rope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # mixture of experts
    moe: Optional[MoEConfig] = None
    # hybrid (Jamba): one attention layer per `attn_every`, rest Mamba
    attn_every: int = 0          # 0 => pure attention stack
    ssm: Optional[SSMConfig] = None
    # xLSTM
    xlstm: Optional[XLSTMConfig] = None
    # encoder-decoder
    n_encoder_layers: int = 0    # >0 => enc-dec; n_layers is decoder depth
    # frontend stubs: "none" (token ids), "embeds" (precomputed embeddings)
    frontend: str = "none"
    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # distribution hints
    scan_layers: bool = True     # lax.scan over (homogeneous groups of) layers
    remat: bool = True

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid / linear-attn)."""
        return self.family in ("ssm", "hybrid")

    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (enc-dec incl.)

    def moe_on_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        k = self.moe.every_k_layers
        return i % k == k - 1

    def attn_on_layer(self, i: int) -> bool:
        if self.attn_every <= 0:
            return True
        return i % self.attn_every == self.attn_every - 1

    def active_params(self) -> int:
        """6*N*D model-FLOPs numerator: active (per-token) parameter count."""
        return _count_params(self, active_only=True)

    def total_params(self) -> int:
        return _count_params(self, active_only=False)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def _attn_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    if cfg.mla:
        m = cfg.mla
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        n = d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk_head
        n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
        n += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim
                                             + m.v_head_dim)
        n += cfg.n_heads * m.v_head_dim * d
        return n
    return d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d


def _ffn_params(cfg: ModelConfig, d_ff: int) -> int:
    return 3 * cfg.d_model * d_ff     # SwiGLU: gate, up, down


def _ssm_params(cfg: ModelConfig) -> int:
    s = cfg.ssm or SSMConfig()
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    n = cfg.d_model * 2 * d_inner            # in_proj
    n += d_inner * s.d_conv                  # conv
    n += d_inner * (dt_rank + 2 * s.d_state)  # x_proj
    n += dt_rank * d_inner + d_inner         # dt_proj
    n += d_inner * s.d_state + d_inner       # A_log, D
    n += d_inner * cfg.d_model               # out_proj
    return n


def _xlstm_params(cfg: ModelConfig) -> int:
    x = cfg.xlstm or XLSTMConfig()
    d = cfg.d_model
    d_in = int(x.proj_factor * d)
    m = 2 * d * d_in + 3 * d_in * d_in // cfg.n_heads + d_in * d  # rough
    s = 4 * d * d + 4 * d * d // cfg.n_heads + 3 * d * d          # sLSTM+FFN
    n_s = cfg.n_layers // (x.slstm_every or cfg.n_layers)
    return m * (cfg.n_layers - n_s) + s * n_s


def _count_params(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    n = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":
        return n + _xlstm_params(cfg)

    def layer_params(i: int, active: bool) -> int:
        p = 0
        if cfg.attn_on_layer(i):
            p += _attn_params(cfg)
        else:
            p += _ssm_params(cfg)
        if cfg.moe_on_layer(i):
            m = cfg.moe
            e = (m.top_k + m.n_shared) if active else (m.n_experts
                                                       + m.n_shared)
            p += e * _ffn_params(cfg, m.d_expert) + d * m.n_experts
        else:
            p += _ffn_params(cfg, cfg.d_ff)
        p += 2 * d                      # norms
        return p

    total_layers = cfg.n_layers + cfg.n_encoder_layers
    for i in range(cfg.n_layers):
        n += layer_params(i, active_only)
    for i in range(cfg.n_encoder_layers):
        n += layer_params(i, active_only) + (_attn_params(cfg) + d
                                             if False else 0)
    if cfg.n_encoder_layers:
        # decoder cross-attention
        n += cfg.n_layers * _attn_params(cfg)
    return n
