"""Decoder-only LM assembly for all assigned families.

Layers are grouped into homogeneous *superblocks* (period = lcm of the
attention interleave and the MoE interleave) and scanned with lax.scan —
94-layer models lower as one loop, not 94 inlined layers.  Each superblock
slot is one sublayer: attention (GQA or MLA), Mamba, mLSTM or sLSTM mixer,
followed by an MLP or MoE (except for xLSTM blocks, which carry their own
projections).

Caches for decode are pytrees stacked along the superblock axis so the
decode step scans them alongside the parameters.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (Params, _dtype, _init, attn_forward, init_attn,
                     init_mla, init_moe, init_mlp, mla_forward, mlp_forward,
                     moe_forward, rmsnorm)
from .ssm import (init_mamba, init_mlstm, init_slstm, mamba_forward,
                  mlstm_forward, slstm_forward)


# ---------------------------------------------------------------------------
# Superblock layout


def block_period(cfg: ModelConfig) -> int:
    p = 1
    if cfg.attn_every:
        p = cfg.attn_every
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe.every_k_layers)
    if cfg.xlstm is not None:
        p = math.lcm(p, cfg.xlstm.slstm_every)
    return p


def slot_kinds(cfg: ModelConfig) -> List[Tuple[str, str]]:
    """Per superblock: list of (mixer, ffn) kinds; ffn == "none" for xLSTM."""
    period = block_period(cfg)
    out = []
    for i in range(period):
        if cfg.family == "ssm":
            x = cfg.xlstm
            mixer = "slstm" if (i % x.slstm_every == x.slstm_every - 1) \
                else "mlstm"
            out.append((mixer, "none"))
            continue
        if cfg.attn_on_layer(i):
            mixer = "mla" if cfg.mla else "attn"
        else:
            mixer = "mamba"
        ffn = "moe" if cfg.moe_on_layer(i) else "mlp"
        out.append((mixer, ffn))
    return out


def n_superblocks(cfg: ModelConfig) -> int:
    period = block_period(cfg)
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    return cfg.n_layers // period


# ---------------------------------------------------------------------------
# Init


def _init_sublayer(cfg: ModelConfig, key, mixer: str, ffn: str) -> Params:
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    p: Params = {"ln1": jnp.ones((cfg.d_model,), dt)}
    if mixer == "attn":
        p["mixer"] = init_attn(cfg, ks[0])
    elif mixer == "mla":
        p["mixer"] = init_mla(cfg, ks[0])
    elif mixer == "mamba":
        p["mixer"] = init_mamba(cfg, ks[0])
    elif mixer == "mlstm":
        p["mixer"] = init_mlstm(cfg, ks[0])
    elif mixer == "slstm":
        p["mixer"] = init_slstm(cfg, ks[0])
    if ffn == "mlp":
        p["ln2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = init_mlp(cfg, ks[1], cfg.d_ff)
    elif ffn == "moe":
        p["ln2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = init_moe(cfg, ks[1])
    return p


def init_lm(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    kinds = slot_kinds(cfg)
    ns = n_superblocks(cfg)

    supers = []
    bkeys = jax.random.split(ks[0], ns)
    for si in range(ns):
        skeys = jax.random.split(bkeys[si], len(kinds))
        supers.append({f"slot{j}": _init_sublayer(cfg, skeys[j], m, f)
                       for j, (m, f) in enumerate(kinds)})
    blocks = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *supers) \
        if ns > 1 else jax.tree_util.tree_map(lambda x: x[None], supers[0])

    p: Params = {
        "embed": _init(ks[1], (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
        "blocks": blocks,
        "ln_f": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = _init(ks[2], (cfg.d_model, cfg.vocab_size), dt)
    return p


# ---------------------------------------------------------------------------
# Sublayer application


def _apply_sublayer(cfg: ModelConfig, p: Params, kind: Tuple[str, str], x,
                    positions, cache=None, cache_index=None):
    mixer, ffn = kind
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    aux = jnp.float32(0.0)
    new_cache = None
    if mixer == "attn":
        o, new_cache = attn_forward(cfg, p["mixer"], h, positions,
                                    cache, cache_index)
    elif mixer == "mla":
        o, new_cache = mla_forward(cfg, p["mixer"], h, positions,
                                   cache, cache_index)
    elif mixer == "mamba":
        o, new_cache = mamba_forward(cfg, p["mixer"], h, cache)
    elif mixer == "mlstm":
        o, new_cache = mlstm_forward(cfg, p["mixer"], h, cache)
    elif mixer == "slstm":
        o, new_cache = slstm_forward(cfg, p["mixer"], h, cache)
    x = x + o
    if ffn != "none":
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if ffn == "moe":
            o2, aux = moe_forward(cfg, p["ffn"], h2)
        else:
            o2 = mlp_forward(p["ffn"], h2)
        x = x + o2
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Scan-or-unroll over superblocks.  scan_layers=False exists for the
# dry-run's cost extraction: XLA cost analysis counts while bodies once,
# so the depth-1/-2 cost variants compile unrolled.


def scan_blocks(cfg: ModelConfig, body, carry, xs):
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    ns = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(ns):
        sl = jax.tree_util.tree_map(lambda t: t[i], xs)
        carry, y = body(carry, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *t: jnp.stack(t), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# Cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Stacked (per-superblock) decode caches for each slot."""
    kinds = slot_kinds(cfg)
    ns = n_superblocks(cfg)
    dt = _dtype(cfg)
    cache: Dict[str, Tuple] = {}
    for j, (mixer, _f) in enumerate(kinds):
        if mixer == "attn":
            shape = (ns, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
            cache[f"slot{j}"] = (jnp.zeros(shape, dt), jnp.zeros(shape, dt))
        elif mixer == "mla":
            m = cfg.mla
            cache[f"slot{j}"] = (
                jnp.zeros((ns, batch, max_len, m.kv_lora_rank), dt),
                jnp.zeros((ns, batch, max_len, m.qk_rope_head_dim), dt))
        elif mixer == "mamba":
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            cache[f"slot{j}"] = (
                jnp.zeros((ns, batch, s.d_conv - 1, d_in), dt),
                jnp.zeros((ns, batch, d_in, s.d_state), jnp.float32))
        elif mixer == "mlstm":
            x = cfg.xlstm
            d_in = int(x.proj_factor * cfg.d_model)
            h = cfg.n_heads
            dh = d_in // h
            cache[f"slot{j}"] = (
                jnp.zeros((ns, batch, h, dh, dh), jnp.float32),
                jnp.zeros((ns, batch, h, dh), jnp.float32),
                jnp.zeros((ns, batch, h), jnp.float32))
        elif mixer == "slstm":
            d = cfg.d_model
            z = jnp.zeros((ns, batch, d), jnp.float32)
            cache[f"slot{j}"] = (z, z, z - 10.0, z)
    return cache


# ---------------------------------------------------------------------------
# Forward passes


def _embed(cfg: ModelConfig, p: Params, tokens_or_embeds):
    if cfg.frontend == "embeds":
        return tokens_or_embeds.astype(_dtype(cfg))
    return jnp.take(p["embed"], tokens_or_embeds, axis=0)


def _unembed(cfg: ModelConfig, p: Params, x):
    x = rmsnorm(x, p["ln_f"], cfg.norm_eps)
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)


def lm_forward(cfg: ModelConfig, p: Params, tokens_or_embeds, positions):
    """Training/prefill forward without cache.  Returns (logits, aux)."""
    kinds = slot_kinds(cfg)
    x = _embed(cfg, p, tokens_or_embeds)

    def body(carry, bp):
        x, aux = carry
        for j, kind in enumerate(kinds):
            x, a, _ = _apply_sublayer(cfg, bp[f"slot{j}"], kind, x,
                                      positions)
            aux = aux + a
        return (x, aux), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), _ = scan_blocks(cfg, body, (x, jnp.float32(0.0)),
                              p["blocks"])
    return _unembed(cfg, p, x), aux


def lm_prefill(cfg: ModelConfig, p: Params, tokens_or_embeds, positions,
               cache: Dict, start=None):
    """Forward that fills the cache from position ``start`` (prefix-reuse
    serving prefills only the un-cached suffix).  Returns (last-token
    logits, cache)."""
    kinds = slot_kinds(cfg)
    x = _embed(cfg, p, tokens_or_embeds)
    zero = jnp.int32(0) if start is None else jnp.asarray(start, jnp.int32)

    def body(carry, scan_in):
        x = carry
        bp, bc = scan_in
        new_bc = {}
        for j, kind in enumerate(kinds):
            x, _a, nc = _apply_sublayer(cfg, bp[f"slot{j}"], kind, x,
                                        positions, bc[f"slot{j}"], zero)
            new_bc[f"slot{j}"] = _cache_like(bc[f"slot{j}"], nc)
        return x, new_bc

    x, new_cache = scan_blocks(cfg, body, x, (p["blocks"], cache))
    logits = _unembed(cfg, p, x[:, -1:])
    return logits, new_cache


def lm_decode(cfg: ModelConfig, p: Params, tokens_or_embeds, positions,
              cache: Dict, index):
    """One decode step.  tokens: (B, 1).  Returns (logits, cache)."""
    kinds = slot_kinds(cfg)
    x = _embed(cfg, p, tokens_or_embeds)

    def body(carry, scan_in):
        x = carry
        bp, bc = scan_in
        new_bc = {}
        for j, kind in enumerate(kinds):
            x, _a, nc = _apply_sublayer(cfg, bp[f"slot{j}"], kind, x,
                                        positions, bc[f"slot{j}"], index)
            new_bc[f"slot{j}"] = _cache_like(bc[f"slot{j}"], nc)
        return x, new_bc

    x, new_cache = scan_blocks(cfg, body, x, (p["blocks"], cache))
    return _unembed(cfg, p, x), new_cache


def _cache_like(old, new):
    """Keep cache pytree structure stable across sublayers (mamba training
    path returns None ssm state)."""
    if new is None:
        return old
    return tuple(o if n is None else n for o, n in zip(old, new))


# ---------------------------------------------------------------------------
# Loss


def lm_loss(cfg: ModelConfig, p: Params, tokens_or_embeds, positions,
            labels, aux_weight: float = 0.01):
    logits, aux = lm_forward(cfg, p, tokens_or_embeds, positions)
    logp = jax.nn.log_softmax(logits, -1)
    ll = jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
    loss = -ll.mean()
    return loss + aux_weight * aux, (loss, aux)
