"""State-space / recurrent blocks: Mamba-1 selective SSM (Jamba's mixer)
and xLSTM cells (mLSTM matrix memory + sLSTM scalar memory).

Mamba uses a *chunked* scan: the (B, S, d_inner, d_state) discretized
tensors are never materialized at once — an outer lax.scan walks chunks of
``chunk`` steps, and within a chunk an associative scan composes the
affine recurrences.  This is the TPU-native replacement for the fused CUDA
selective-scan kernel (HBM-resident activations, VMEM-sized chunks).

xLSTM cells run as exact sequential scans (lax.scan over time) — correct
for train/prefill and identical to the decode step function; the
chunkwise-parallel training form is a recorded optimization opportunity
(EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dtype, _init, rmsnorm

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Mamba


def _dt_rank(cfg: ModelConfig) -> int:
    s = cfg.ssm
    return s.dt_rank or math.ceil(cfg.d_model / 16)


def init_mamba(cfg: ModelConfig, key) -> Params:
    s = cfg.ssm
    dt = _dtype(cfg)
    d = cfg.d_model
    d_in = s.expand * d
    r = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :],
                 (d_in, 1))
    return {
        "in_proj": _init(ks[0], (d, 2 * d_in), dt),
        "conv_w": _init(ks[1], (s.d_conv, d_in), dt, scale=0.5),
        "conv_b": jnp.zeros((d_in,), dt),
        "x_proj": _init(ks[2], (d_in, r + 2 * s.d_state), dt),
        "dt_proj": _init(ks[3], (r, d_in), dt),
        "dt_bias": jnp.full((d_in,), -4.6, dt),   # softplus^-1(0.01)
        "A_log": jnp.log(a),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": _init(ks[4], (d_in, d), dt),
    }


def _causal_conv(x, w, b, state=None):
    """x: (B, S, C); w: (K, C) depthwise.  state: (B, K-1, C) past inputs."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (k - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+K-1, C)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return out + b[None, None], new_state


def mamba_forward(cfg: ModelConfig, p: Params, x,
                  state: Optional[Tuple] = None):
    chunk = cfg.ssm.chunk or x.shape[1]
    """x: (B, S, d).  state: (conv_state, ssm_state) for decode (S == 1).
    Returns (y, new_state)."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    d_in = s_cfg.expand * d
    n = s_cfg.d_state
    r = _dt_rank(cfg)

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = xz[..., :d_in], xz[..., d_in:]

    conv_state = state[0] if state is not None else None
    xc, new_conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"],
                                      conv_state)
    xc = jax.nn.silu(xc)

    dbc = jnp.einsum("bsc,ce->bse", xc, p["x_proj"])
    dt = dbc[..., :r]
    bmat = dbc[..., r:r + n].astype(jnp.float32)          # (B,S,N)
    cmat = dbc[..., r + n:].astype(jnp.float32)           # (B,S,N)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", dt, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))               # (B,S,d_in)
    a = -jnp.exp(p["A_log"])                              # (d_in, N)
    xcf = xc.astype(jnp.float32)

    if s == 1:   # decode step
        h0 = state[1] if state is not None else jnp.zeros((b, d_in, n),
                                                          jnp.float32)
        da = jnp.exp(dt[:, 0, :, None] * a[None])          # (B,d_in,N)
        dbx = (dt[:, 0, :, None] * bmat[:, 0, None, :]
               * xcf[:, 0, :, None])
        h = da * h0 + dbx
        y = jnp.einsum("bcn,bn->bc", h, cmat[:, 0])[:, None]
        y = y + p["D"][None, None] * xcf
        out = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
        return jnp.einsum("bsc,cd->bsd", out, p["out_proj"]), \
            (new_conv_state, h)

    # chunked scan over the sequence
    assert s % chunk == 0 or s < chunk
    q = min(chunk, s)
    nc = s // q
    dt_c = dt.reshape(b, nc, q, d_in)
    b_c = bmat.reshape(b, nc, q, n)
    c_c = cmat.reshape(b, nc, q, n)
    x_c = xcf.reshape(b, nc, q, d_in)

    h0 = jnp.zeros((b, d_in, n), jnp.float32)

    def chunk_body(h, inp):
        dtq, bq, cq, xq = inp                              # (B,Q,...)
        da = jnp.exp(dtq[..., None] * a[None, None])       # (B,Q,d_in,N)
        dbx = dtq[..., None] * bq[:, :, None, :] * xq[..., None]

        def compose(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        acum, hrel = jax.lax.associative_scan(compose, (da, dbx), axis=1)
        hs = acum * h[:, None] + hrel                      # (B,Q,d_in,N)
        y = jnp.einsum("bqcn,bqn->bqc", hs, cq)
        return hs[:, -1], y

    swap = lambda t: jnp.swapaxes(t, 0, 1)                 # scan over chunks
    if not cfg.scan_layers:
        # cost-extraction mode: unroll the chunk loop so XLA cost
        # analysis (trip-count-blind on while loops) counts every chunk
        h_last, ys_l = h0, []
        for i in range(nc):
            h_last, yi = chunk_body(h_last, (dt_c[:, i], b_c[:, i],
                                             c_c[:, i], x_c[:, i]))
            ys_l.append(yi)
        y = jnp.stack(ys_l, axis=1).reshape(b, s, d_in)
    else:
        h_last, ys = jax.lax.scan(chunk_body, h0,
                                  (swap(dt_c), swap(b_c), swap(c_c),
                                   swap(x_c)))
        y = swap(ys).reshape(b, s, d_in)
    y = y + p["D"][None, None] * xcf
    out = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    # final state returned so prefill can hand off to decode
    return jnp.einsum("bsc,cd->bsd", out, p["out_proj"]), \
        (new_conv_state, h_last)


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory)


def init_mlstm(cfg: ModelConfig, key) -> Params:
    x = cfg.xlstm
    dt = _dtype(cfg)
    d = cfg.d_model
    d_in = int(x.proj_factor * d)
    h = cfg.n_heads
    dh = d_in // h
    ks = jax.random.split(key, 8)
    return {
        "up": _init(ks[0], (d, 2 * d_in), dt),
        "wq": _init(ks[1], (d_in, d_in), dt),
        "wk": _init(ks[2], (d_in, d_in), dt),
        "wv": _init(ks[3], (d_in, d_in), dt),
        "wi": _init(ks[4], (d_in, h), jnp.float32, scale=0.01),
        "wf": _init(ks[5], (d_in, h), jnp.float32, scale=0.01),
        "bf": jnp.full((h,), 3.0, jnp.float32),   # open forget gates
        "bi": jnp.zeros((h,), jnp.float32),
        "gn": jnp.ones((d_in,), dt),
        "down": _init(ks[6], (d_in, d), dt),
    }


def _mlstm_step(q, k, v, i_raw, f_raw, carry):
    """One mLSTM step.  q/k/v: (B,H,Dh); gates: (B,H).  carry: (C,n,m)."""
    c, nrm, m = carry
    log_f = jax.nn.log_sigmoid(f_raw)
    log_i = i_raw
    m_new = jnp.maximum(log_f + m, log_i)
    fg = jnp.exp(log_f + m - m_new)[..., None, None]
    ig = jnp.exp(log_i - m_new)[..., None, None]
    c = fg * c + ig * (k[..., :, None] * v[..., None, :])   # (B,H,Dh,Dh)
    nrm = fg[..., 0] * nrm + ig[..., 0] * k
    h_num = jnp.einsum("bhd,bhde->bhe", q, c)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, nrm)),
                        jnp.exp(-m_new))[..., None]
    return (c, nrm, m_new), h_num / h_den


def mlstm_forward(cfg: ModelConfig, p: Params, x,
                  state: Optional[Tuple] = None):
    """x: (B, S, d).  Exact sequential scan (also the decode step)."""
    xl = cfg.xlstm
    b, s, d = x.shape
    d_in = int(xl.proj_factor * d)
    h = cfg.n_heads
    dh = d_in // h

    up = jnp.einsum("bsd,de->bse", x, p["up"])
    xm, z = up[..., :d_in], up[..., d_in:]
    q = jnp.einsum("bse,ef->bsf", xm, p["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bse,ef->bsf", xm, p["wk"]).reshape(b, s, h, dh)
    k = k / (dh ** 0.5)
    v = jnp.einsum("bse,ef->bsf", xm, p["wv"]).reshape(b, s, h, dh)
    i_raw = (jnp.einsum("bse,eh->bsh", xm.astype(jnp.float32), p["wi"])
             + p["bi"])
    f_raw = (jnp.einsum("bse,eh->bsh", xm.astype(jnp.float32), p["wf"])
             + p["bf"])

    if state is None:
        carry = (jnp.zeros((b, h, dh, dh), jnp.float32),
                 jnp.zeros((b, h, dh), jnp.float32),
                 jnp.zeros((b, h), jnp.float32))
    else:
        carry = state

    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))

    def step(carry, inp):
        qt, kt, vt, it, ft = inp
        carry, ht = _mlstm_step(qt, kt, vt, it, ft, carry)
        return carry, ht

    swap = lambda t: jnp.swapaxes(t, 0, 1)
    carry, hs = jax.lax.scan(
        step, carry, (swap(qf), swap(kf), swap(vf), swap(i_raw),
                      swap(f_raw)))
    hseq = swap(hs).reshape(b, s, d_in).astype(x.dtype)
    hseq = rmsnorm(hseq, p["gn"], cfg.norm_eps)
    out = hseq * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", out, p["down"]), carry


# ---------------------------------------------------------------------------
# xLSTM: sLSTM (scalar memory, post-up-projection block with FFN)


def init_slstm(cfg: ModelConfig, key) -> Params:
    dt = _dtype(cfg)
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 12)
    p = {}
    for i, g in enumerate(("i", "f", "z", "o")):
        p[f"w{g}"] = _init(ks[i], (d, d), dt)
        p[f"r{g}"] = _init(ks[4 + i], (h, dh, dh), dt, scale=1.0 / dh ** 0.5)
        p[f"b{g}"] = (jnp.full((d,), 1.0, jnp.float32) if g == "f"
                      else jnp.zeros((d,), jnp.float32))
    p["gn"] = jnp.ones((d,), dt)
    p["ffn"] = {
        "wg": _init(ks[8], (d, cfg.d_ff or 4 * d // 3, ), dt),
        "wu": _init(ks[9], (d, cfg.d_ff or 4 * d // 3), dt),
        "wd": _init(ks[10], (cfg.d_ff or 4 * d // 3, d), dt),
    }
    return p


def slstm_forward(cfg: ModelConfig, p: Params, x,
                  state: Optional[Tuple] = None):
    """x: (B, S, d).  Returns (y, new_state)."""
    from .layers import mlp_forward
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h

    wx = {g: jnp.einsum("bsd,de->bse", x, p[f"w{g}"]).astype(jnp.float32)
          for g in ("i", "f", "z", "o")}

    if state is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        state = (zeros, zeros, zeros - 10.0, zeros)   # c, n, m, h

    def step(carry, inp):
        c, nrm, m, hprev = carry
        xi, xf, xz, xo = inp
        hh = hprev.reshape(b, h, dh)
        rec = {g: jnp.einsum("bhd,hde->bhe", hh, p[f"r{g}"]
                             .astype(jnp.float32)).reshape(b, d)
               for g in ("i", "f", "z", "o")}
        i_raw = xi + rec["i"] + p["bi"]
        f_raw = xf + rec["f"] + p["bf"]
        z_t = jnp.tanh(xz + rec["z"] + p["bz"])
        o_t = jax.nn.sigmoid(xo + rec["o"] + p["bo"])
        log_f = jax.nn.log_sigmoid(f_raw)
        m_new = jnp.maximum(log_f + m, i_raw)
        ig = jnp.exp(i_raw - m_new)
        fg = jnp.exp(log_f + m - m_new)
        c_new = fg * c + ig * z_t
        n_new = fg * nrm + ig
        h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    swap = lambda t: jnp.swapaxes(t, 0, 1)
    carry, hs = jax.lax.scan(step, state, tuple(swap(wx[g]) for g in
                                                ("i", "f", "z", "o")))
    hseq = swap(hs).astype(x.dtype)
    hseq = rmsnorm(hseq, p["gn"], cfg.norm_eps)
    out = hseq + mlp_forward(p["ffn"], hseq)
    return out, carry
