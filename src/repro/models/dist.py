"""Ambient mesh context for explicitly-distributed layer implementations
(shard_map MoE dispatch, sharded decode attention).

Model code is mesh-agnostic by default (GSPMD infers collectives); the
launch layer calls ``set_mesh`` to unlock the manual paths where GSPMD's
inference is measurably bad (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Optional

import jax

_MESH: Optional[jax.sharding.Mesh] = None
_OPTIMIZED = False


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


def set_optimized(v: bool) -> None:
    """Enable the beyond-baseline implementations (chunked attention,
    shard_map MoE, sharded decode attention)."""
    global _OPTIMIZED
    _OPTIMIZED = v


def optimized() -> bool:
    return _OPTIMIZED


def dp_axis_names(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
