"""PigMix-faithful synthetic workload (paper §7).

Data generator mirrors the PigMix tables (page_views + users/power_users)
and the §7.5 synthetic table (Table 2 field cardinalities); queries
L2-L8 and L11 are expressed over the engine's operator set the same way
Pig compiles them.  Scaled to CPU sizes; the paper's 15 GB/150 GB contrast
becomes a small/large row-count contrast.

The queries are written in the Pig-style builder DSL
(``dataflow.builder``, DESIGN.md §16) — the paper's actual interface.
The original hand-built ``core.plan`` constructors are retained below
as ``LEGACY`` so ``tests/test_builder.py`` can pin that both notations
compile to fingerprint-identical plans.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..core import plan as P
from ..dataflow.builder import Dataflow, col
from ..dataflow.expr import Cast, Col, Const
from ..dataflow.table import Table, encode_strings

N_USERS = 200


def gen_page_views(n_rows: int, seed: int = 0,
                   capacity: int | None = None,
                   n_users: int = N_USERS) -> Table:
    rng = np.random.default_rng(seed)
    users = [f"user{i:04d}" for i in range(n_users)]
    terms = [f"term{i:03d}" for i in range(50)]
    return Table.from_numpy({
        "user": encode_strings([users[i] for i in
                                rng.integers(0, n_users, n_rows)]),
        "action": rng.integers(1, 3, n_rows).astype(np.int32),
        "timespent": rng.integers(0, 100, n_rows).astype(np.int32),
        "query_term": encode_strings([terms[i] for i in
                                      rng.integers(0, 50, n_rows)]),
        "timestamp": rng.integers(0, 24, n_rows).astype(np.int32),
        "estimated_revenue": rng.uniform(0, 100, n_rows)
        .astype(np.float32),
    }, capacity=capacity or n_rows)


def gen_users(seed: int = 1, n_users: int = N_USERS) -> Table:
    rng = np.random.default_rng(seed)
    names = [f"user{i:04d}" for i in range(n_users)]
    return Table.from_numpy({
        "name": encode_strings(names),
        "phone": rng.integers(10**6, 10**7, n_users).astype(np.int32),
        "zip": rng.integers(10**4, 10**5, n_users).astype(np.int32),
    })


def gen_power_users(seed: int = 2) -> Table:
    rng = np.random.default_rng(seed)
    names = [f"user{i:04d}" for i in range(0, N_USERS, 4)]
    return Table.from_numpy({
        "name": encode_strings(names),
        "phone": rng.integers(10**6, 10**7, len(names)).astype(np.int32),
    })


def register_all(catalog, n_rows: int = 1 << 15, seed: int = 0):
    catalog.register("page_views", gen_page_views(n_rows, seed))
    catalog.register("users", gen_users())
    catalog.register("power_users", gen_power_users())


# ---------------------------------------------------------------------------
# Queries, in the Pig-style builder DSL.  Each returns a PhysicalPlan;
# Pig's FOREACH..GENERATE maps to project/foreach, (CO)GROUP..FOREACH agg
# to group_by/cogroup.


def L2() -> P.PhysicalPlan:
    """Join page_views projection with power_users names."""
    pv = Dataflow.load("page_views").project("user", "estimated_revenue")
    pu = Dataflow.load("power_users").project("name")
    return (pv.join(pu, left_on="user", right_on="name")
            .store("L2_out").build())


def L3(agg: str = "sum") -> P.PhysicalPlan:
    """Join then group-by user with revenue aggregate (paper Q2)."""
    pv = Dataflow.load("page_views").project("user", "estimated_revenue")
    u = Dataflow.load("users").project("name")
    return (pv.join(u, left_on="user", right_on="name")
            .group_by("user", total=(agg, "estimated_revenue"))
            .store(f"L3_{agg}_out").build())


def L4() -> P.PhysicalPlan:
    """Distinct aggregate: count distinct actions per user."""
    return (Dataflow.load("page_views").project("user", "action")
            .distinct()
            .group_by("user", n_actions=("count", "action"))
            .store("L4_out").build())


def L5() -> P.PhysicalPlan:
    """Join pv with full users table (wide build side)."""
    pv = Dataflow.load("page_views").project("user", "timespent")
    u = Dataflow.load("users").project("name", "phone", "zip")
    return (pv.join(u, left_on="user", right_on="name")
            .store("L5_out").build())


def L6() -> P.PhysicalPlan:
    """Group on a wide key with a large-cardinality aggregate."""
    return (Dataflow.load("page_views")
            .project("user", "query_term", "timespent")
            .group_by("user", "query_term",
                      total_time=("sum", "timespent"))
            .store("L6_out").build())


def L7() -> P.PhysicalPlan:
    """Morning/afternoon conditional sums (Pig's nested FOREACH)."""
    return (Dataflow.load("page_views")
            .foreach(user=col("user"),
                     morning=Cast((col("timestamp") < 12), "int32")
                     * col("timespent"),
                     afternoon=Cast((col("timestamp") >= 12), "int32")
                     * col("timespent"))
            .group_by("user", m=("sum", "morning"),
                      a=("sum", "afternoon"))
            .store("L7_out").build())


def L8() -> P.PhysicalPlan:
    """Group-ALL: whole-table aggregate."""
    return (Dataflow.load("page_views")
            .foreach(all=Const(1), timespent=col("timespent"),
                     estimated_revenue=col("estimated_revenue"))
            .group_by("all", t=("sum", "timespent"),
                      r=("mean", "estimated_revenue"))
            .store("L8_out").build())


def L11(second: str = "power_users") -> P.PhysicalPlan:
    """Union of user columns, deduplicated (3-job workflow: two map
    pipelines + distinct)."""
    a = Dataflow.load("page_views").project("user").distinct()
    b = Dataflow.load(second).project("name").foreach(user=col("name"))
    return a.union(b).distinct().store(f"L11_{second}_out").build()


def L3F() -> P.PhysicalPlan:
    """L3 with a post-aggregation FOREACH (Pig keeps GROUP and the
    aggregating FOREACH separate, so the GROUP output is mid-reducer —
    exactly the case where the Aggressive Heuristic stores more than the
    Conservative one)."""
    pv = Dataflow.load("page_views").project("user", "estimated_revenue")
    u = Dataflow.load("users").project("name")
    return (pv.join(u, left_on="user", right_on="name")
            .group_by("user", total=("sum", "estimated_revenue"),
                      cnt=("count", "estimated_revenue"))
            .foreach(user=col("user"),
                     avg_rev=col("total") / col("cnt"))
            .store("L3F_out").build())


QUERIES = {"L2": L2, "L3": L3, "L3F": L3F, "L4": L4, "L5": L5, "L6": L6,
           "L7": L7, "L8": L8, "L11": L11}


# ---------------------------------------------------------------------------
# Legacy hand-built constructors (the pre-DSL notation).  Kept verbatim:
# tests/test_builder.py asserts each DSL template above compiles to a
# plan fingerprint-identical to its legacy twin, which is what makes the
# DSL a pure notation change (fingerprints are the reuse currency).


def _legacy_L2() -> P.PhysicalPlan:
    pv = P.project(P.load("page_views"), ["user", "estimated_revenue"])
    pu = P.project(P.load("power_users"), ["name"])
    j = P.join(pv, pu, ["user"], ["name"])
    return P.PhysicalPlan([P.store(j, "L2_out")])


def _legacy_L3(agg: str = "sum") -> P.PhysicalPlan:
    pv = P.project(P.load("page_views"), ["user", "estimated_revenue"])
    u = P.project(P.load("users"), ["name"])
    j = P.join(pv, u, ["user"], ["name"])
    g = P.groupby(j, ["user"],
                  {"total": (agg, "estimated_revenue")})
    return P.PhysicalPlan([P.store(g, f"L3_{agg}_out")])


def _legacy_L4() -> P.PhysicalPlan:
    pv = P.project(P.load("page_views"), ["user", "action"])
    d = P.distinct(pv)
    g = P.groupby(d, ["user"], {"n_actions": ("count", "action")})
    return P.PhysicalPlan([P.store(g, "L4_out")])


def _legacy_L5() -> P.PhysicalPlan:
    pv = P.project(P.load("page_views"), ["user", "timespent"])
    u = P.project(P.load("users"), ["name", "phone", "zip"])
    j = P.join(pv, u, ["user"], ["name"])
    return P.PhysicalPlan([P.store(j, "L5_out")])


def _legacy_L6() -> P.PhysicalPlan:
    pv = P.project(P.load("page_views"),
                   ["user", "query_term", "timespent"])
    g = P.groupby(pv, ["user", "query_term"],
                  {"total_time": ("sum", "timespent")})
    return P.PhysicalPlan([P.store(g, "L6_out")])


def _legacy_L7() -> P.PhysicalPlan:
    pv = P.load("page_views")
    f = P.foreach(pv, {
        "user": Col("user"),
        "morning": Cast((Col("timestamp") < 12), "int32")
        * Col("timespent"),
        "afternoon": Cast((Col("timestamp") >= 12), "int32")
        * Col("timespent"),
    })
    g = P.groupby(f, ["user"], {"m": ("sum", "morning"),
                                "a": ("sum", "afternoon")})
    return P.PhysicalPlan([P.store(g, "L7_out")])


def _legacy_L8() -> P.PhysicalPlan:
    pv = P.foreach(P.load("page_views"),
                   {"all": Const(1), "timespent": Col("timespent"),
                    "estimated_revenue": Col("estimated_revenue")})
    g = P.groupby(pv, ["all"], {"t": ("sum", "timespent"),
                                "r": ("mean", "estimated_revenue")})
    return P.PhysicalPlan([P.store(g, "L8_out")])


def _legacy_L11(second: str = "power_users") -> P.PhysicalPlan:
    a = P.distinct(P.project(P.load("page_views"), ["user"]))
    b = P.foreach(P.project(P.load(second), ["name"]),
                  {"user": Col("name")})
    u = P.union(a, b)
    d = P.distinct(u)
    return P.PhysicalPlan([P.store(d, f"L11_{second}_out")])


def _legacy_L3F() -> P.PhysicalPlan:
    pv = P.project(P.load("page_views"), ["user", "estimated_revenue"])
    u = P.project(P.load("users"), ["name"])
    j = P.join(pv, u, ["user"], ["name"])
    g = P.groupby(j, ["user"], {"total": ("sum", "estimated_revenue"),
                                "cnt": ("count", "estimated_revenue")})
    f = P.foreach(g, {"user": Col("user"),
                      "avg_rev": Col("total") / Col("cnt")})
    return P.PhysicalPlan([P.store(f, "L3F_out")])


LEGACY = {"L2": _legacy_L2, "L3": _legacy_L3, "L3F": _legacy_L3F,
          "L4": _legacy_L4, "L5": _legacy_L5, "L6": _legacy_L6,
          "L7": _legacy_L7, "L8": _legacy_L8, "L11": _legacy_L11}


# ---------------------------------------------------------------------------
# §7.5 synthetic table (Table 2) + QP/QF templates

FILTER_FIELDS = {   # field -> (cardinality proxy, selected fraction)
    "field6": 0.005, "field7": 0.01, "field8": 0.05, "field9": 0.10,
    "field10": 0.20, "field11": 0.50, "field12": 0.60,
}


def gen_synth(n_rows: int, seed: int = 3,
              capacity: int | None = None) -> Table:
    rng = np.random.default_rng(seed)
    cols: Dict[str, np.ndarray] = {}
    for i in range(1, 6):
        vals = [f"s{rng.integers(0, 1 << 30):019d}" for _ in range(n_rows)]
        cols[f"field{i}"] = encode_strings(vals)
    for f, frac in FILTER_FIELDS.items():
        cols[f] = (rng.random(n_rows) >= frac).astype(np.int32)
        # value 0 selected with probability `frac`
    return Table.from_numpy(cols, capacity=capacity or n_rows)


def QP(n_fields: int) -> P.PhysicalPlan:
    """Project field1..fieldN -> group -> count (paper QP template)."""
    fields = [f"field{i}" for i in range(1, n_fields + 1)]
    return (Dataflow.load("synth").project(fields)
            .group_by(fields, cnt=("count", fields[0]))
            .store(f"QP{n_fields}_out").build())


def QF(field: str) -> P.PhysicalPlan:
    """Filter by equality on fieldi -> group by field1 -> count."""
    return (Dataflow.load("synth").filter(col(field) == 0)
            .project("field1", field)
            .group_by("field1", cnt=("count", field))
            .store(f"QF_{field}_out").build())


def _legacy_QP(n_fields: int) -> P.PhysicalPlan:
    fields = [f"field{i}" for i in range(1, n_fields + 1)]
    pr = P.project(P.load("synth"), fields)
    g = P.groupby(pr, fields, {"cnt": ("count", fields[0])})
    return P.PhysicalPlan([P.store(g, f"QP{n_fields}_out")])


def _legacy_QF(field: str) -> P.PhysicalPlan:
    f = P.filter_(P.load("synth"), Col(field) == 0)
    pr = P.project(f, ["field1", field])
    g = P.groupby(pr, ["field1"], {"cnt": ("count", field)})
    return P.PhysicalPlan([P.store(g, f"QF_{field}_out")])
