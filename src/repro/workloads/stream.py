"""Multi-tenant workflow stream driver (DESIGN.md §9).

Replays a configurable stream of PigMix-derived workflows through a
single shared `ReStore`, the way the cross-industry workload study of
Chen et al. (arXiv:1208.4174) describes production clusters: many
tenants, zipfian query popularity (a few hot templates dominate, with a
long tail), and periodic dataset-version churn that invalidates
previously stored results (eviction rule R4).

Every tenant draws from the same template universe but through its own
popularity permutation, so tenants overlap on hot queries (cross-tenant
reuse through the shared repository) while each also has private
favourites.  Templates are version-agnostic; before each run the
catalog's *current* dataset versions are stamped into the plan
(`rebind_load_versions`), so churn is visible to matching.

Modes (the policy arms compared by `benchmarks/policy_bench.py`):

  * ``"off"``  — no reuse at all: every event runs against a fresh store
    with rewriting disabled (the recompute-everything baseline);
  * ``"keep"`` — store everything (NH enumeration), unbounded repository
    (used to size the total candidate byte volume);
  * ``"lru"``  — store everything, byte-budgeted repository with
    recency-only (least-recently-used) eviction;
  * ``"cost"`` — cost-model-driven materialization + benefit-per-byte
    budgeted repository;
  * ``"mqo"``  — cost arm + multi-query batching (DESIGN.md §16):
    events are drained in windows of ``batch_size`` through
    ``core.mqo.run_batch``, so sub-plans shared by queries arriving in
    the same window execute once with known-uses admission hints.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import numpy as np

from ..core import plan as P
from ..core.plan import rebind_load_versions
from ..core.repository import Repository
from ..core.restore import ReStore
from ..dataflow.expr import Col
from ..store.artifacts import ArtifactStore, Catalog
from . import pigmix

DATASETS = ("page_views", "users", "power_users")


def _hi_rev() -> P.PhysicalPlan:
    """High-revenue users: shares its projection prefix with L3."""
    pv = P.project(P.load("page_views"), ["user", "estimated_revenue"])
    f = P.filter_(pv, Col("estimated_revenue") > 50.0)
    g = P.groupby(f, ["user"], {"hi": ("count", "estimated_revenue")})
    return P.PhysicalPlan([P.store(g, "hi_rev_out")])


def _busy_users() -> P.PhysicalPlan:
    """Heavy-timespent users: shares its projection prefix with L5."""
    pv = P.project(P.load("page_views"), ["user", "timespent"])
    f = P.filter_(pv, Col("timespent") > 50)
    g = P.groupby(f, ["user"], {"t": ("sum", "timespent")})
    return P.PhysicalPlan([P.store(g, "busy_out")])


def default_templates() -> List[Tuple[str, Callable[[], P.PhysicalPlan]]]:
    return [
        ("L2", pigmix.L2),
        ("L3_sum", lambda: pigmix.L3("sum")),
        ("L3_mean", lambda: pigmix.L3("mean")),
        ("L3F", pigmix.L3F),
        ("L4", pigmix.L4),
        ("L5", pigmix.L5),
        ("L6", pigmix.L6),
        ("L7", pigmix.L7),
        ("L8", pigmix.L8),
        ("L11", lambda: pigmix.L11("power_users")),
        ("hi_rev", _hi_rev),
        ("busy_users", _busy_users),
    ]


@dataclasses.dataclass
class StreamConfig:
    n_events: int = 48
    n_tenants: int = 3
    zipf_s: float = 1.1           # template popularity skew
    n_rows: int = 1 << 12
    seed: int = 0
    churn_every: int = 0          # bump page_views version every N events
    cache_bytes: int = 64 * 1024 * 1024
    # append churn (DESIGN.md §12): every N events page_views GROWS by
    # append_frac × n_rows fresh rows — the dominant real-world change
    # class, which incremental maintenance refreshes instead of
    # R4-deleting.  maintain="refresh"|"auto"|"lazy" routes stale
    # entries through Repository.maintain; "delete" reproduces the
    # pre-§12 delete-and-recompute behavior (the ablation arm).
    append_every: int = 0
    append_frac: float = 0.10
    maintain: str = "auto"
    # speculative prefetch (DESIGN.md §15): mine the store's read log
    # for zipfian recurrence, warm the predicted top-k between events
    # (off the timed window, like a background service cadence), and on
    # append churn delta-refresh the predicted-hot artifacts ahead of
    # the next probe instead of inside it
    prefetch: bool = False
    prefetch_k: int = 4
    # multi-query batching (DESIGN.md §16): window size for mode="mqo"
    # (0 falls back to per-event execution even in mqo mode)
    batch_size: int = 0


@dataclasses.dataclass
class StreamEvent:
    idx: int
    tenant: int
    template: str
    wall_s: float
    n_executed: int
    n_reused: int


@dataclasses.dataclass
class StreamResult:
    mode: str
    budget_bytes: Optional[int]
    events: List[StreamEvent]
    cum_wall_s: List[float]       # cumulative runtime after each event
    total_wall_s: float
    peak_store_bytes: int
    repo_entries: int
    repo_bytes: int
    evictions: int
    rejections: int
    refreshes: int = 0            # delta-refreshed entries (§12)
    prefetch_hits: int = 0        # warmed artifacts actually probed (§15)
    prefetched: int = 0           # warm attempts
    refreshed_ahead: int = 0      # delta-refreshes run pre-arrival (§15)
    batches: int = 0              # MQO windows drained (§16)
    mqo_shared_wall_s: float = 0.0   # time spent in shared prefixes
    mqo_dup_executions: int = 0      # shared sub-plans run twice (audit)

    @property
    def n_reused_total(self) -> int:
        return sum(e.n_reused for e in self.events)


def open_loop_arrivals(n_events: int, rate_per_s: float,
                       seed: int = 0) -> np.ndarray:
    """Absolute arrival offsets (seconds) for an open-loop Poisson
    stream: exponential inter-arrival times at ``rate_per_s``.  Open
    loop means arrivals do NOT wait for completions — the offered load
    is fixed, so an overloaded service shows up as growing latency and
    falling goodput rather than as a politely self-throttling client
    (the service bench's saturation measurements depend on this)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / float(rate_per_s), n_events)
    return np.cumsum(gaps)


def _event_schedule(cfg: StreamConfig, n_templates: int):
    """Deterministic (tenant, template) sequence: zipfian rank
    distribution mapped through a per-tenant popularity permutation."""
    rng = np.random.default_rng(cfg.seed)
    p = 1.0 / np.arange(1, n_templates + 1) ** cfg.zipf_s
    p /= p.sum()
    perms = [np.random.default_rng(cfg.seed + 101 + t)
             .permutation(n_templates) for t in range(cfg.n_tenants)]
    out = []
    for _ in range(cfg.n_events):
        tenant = int(rng.integers(cfg.n_tenants))
        rank = int(rng.choice(n_templates, p=p))
        out.append((tenant, int(perms[tenant][rank])))
    return out


def _make_restore(mode: str, catalog: Catalog, store: ArtifactStore,
                  budget_bytes: Optional[int]) -> ReStore:
    if mode == "keep":
        repo = Repository()
        heuristic = "none"
    elif mode == "lru":
        repo = Repository(budget_bytes=budget_bytes, policy="lru")
        heuristic = "none"
    elif mode in ("cost", "mqo"):
        repo = Repository(budget_bytes=budget_bytes, policy="cost")
        heuristic = "cost"
    else:
        raise ValueError(f"unknown stream mode {mode!r}")
    return ReStore(catalog, store, repo, heuristic=heuristic,
                   measure_exec=True, repeats=1)


def run_stream(mode: str, cfg: StreamConfig,
               budget_bytes: Optional[int] = None,
               templates=None) -> StreamResult:
    """Replay the stream under one policy arm and return its timeline.

    Runtime per event is the engine's timed window (jit warmed off the
    clock, like every benchmark in this repo), summed over the event's
    executed jobs — a fully reused job contributes zero."""
    templates = templates or default_templates()
    schedule = _event_schedule(cfg, len(templates))

    store = ArtifactStore(cache_bytes=cfg.cache_bytes)
    catalog = Catalog(store)
    pigmix.register_all(catalog, n_rows=cfg.n_rows, seed=cfg.seed)
    shared_rs = None
    if mode != "off":
        shared_rs = _make_restore(mode, catalog, store, budget_bytes)
    prefetcher = None
    if cfg.prefetch and shared_rs is not None:
        from ..store.prefetch import SpeculativePrefetcher
        prefetcher = SpeculativePrefetcher(
            store, k=cfg.prefetch_k,
            maintainer=(None if cfg.maintain == "delete" else
                        lambda names: shared_rs.maintain(
                            mode=cfg.maintain, only=names)))

    events: List[StreamEvent] = []
    cum: List[float] = []
    total = 0.0
    peak_bytes = 0
    n_batches = 0
    mqo_shared_wall = 0.0
    mqo_dups = 0

    def _churn(i: int) -> None:
        if cfg.churn_every and i > 0 and i % cfg.churn_every == 0:
            # dataset-version churn: the hot table is re-ingested; every
            # artifact derived from the old version is stale (rule R4)
            catalog.register("page_views",
                             pigmix.gen_page_views(
                                 cfg.n_rows,
                                 seed=cfg.seed + 1000 + i))
            if shared_rs is not None:
                shared_rs.repo.evict_stale(catalog)
        if cfg.append_every and i > 0 and i % cfg.append_every == 0:
            # append churn: page_views grows; stale entries refresh from
            # the delta instead of recomputing from zero (DESIGN.md §12)
            n_delta = max(int(cfg.n_rows * cfg.append_frac), 1)
            catalog.append("page_views",
                           pigmix.gen_page_views(
                               n_delta, seed=cfg.seed + 5000 + i))
            if shared_rs is not None:
                if cfg.maintain == "delete":
                    shared_rs.repo.evict_stale(catalog)
                elif prefetcher is not None:
                    # ahead-of-arrival: refresh the predicted-hot
                    # entries first (and re-warm them), then sweep the
                    # rest through the regular path
                    prefetcher.observe_append("page_views")
                    shared_rs.maintain(mode=cfg.maintain)
                else:
                    shared_rs.maintain(mode=cfg.maintain)

    def _bind(tidx: int) -> P.PhysicalPlan:
        return rebind_load_versions(
            templates[tidx][1](),
            {ds: catalog.version(ds) for ds in DATASETS})

    if mode == "mqo" and cfg.batch_size > 1:
        # windowed draining (DESIGN.md §16): churn is applied at each
        # event's index as it is *drained*, then the whole window runs
        # through the batch optimizer; the shared prefix's wall is
        # spread evenly across the window's events
        from ..core.mqo import run_batch
        for w0 in range(0, len(schedule), cfg.batch_size):
            window = list(enumerate(schedule))[w0:w0 + cfg.batch_size]
            for i, _ in window:
                _churn(i)
            plans = [_bind(tidx) for _, (_, tidx) in window]
            br = run_batch(shared_rs, plans)
            n_batches += 1
            mqo_shared_wall += br.shared_wall_s
            mqo_dups += br.dup_executions
            spread = br.shared_wall_s / max(len(window), 1)
            for (i, (tenant, tidx)), report in zip(window, br.reports):
                wall = report.total_wall_s + spread
                total += wall
                cum.append(total)
                events.append(StreamEvent(i, tenant, templates[tidx][0],
                                          wall, report.n_executed,
                                          report.n_reused))
            peak_bytes = max(peak_bytes, shared_rs.store.total_bytes())
            if prefetcher is not None:
                prefetcher.prefetch()
    else:
        for i, (tenant, tidx) in enumerate(schedule):
            _churn(i)
            plan = _bind(tidx)
            if mode == "off":
                rs = ReStore(catalog,
                             ArtifactStore(cache_bytes=cfg.cache_bytes),
                             heuristic="off", rewrite_enabled=False,
                             measure_exec=True, repeats=1)
            else:
                rs = shared_rs
            _, report = rs.run_plan(plan)
            wall = report.total_wall_s
            total += wall
            cum.append(total)
            events.append(StreamEvent(i, tenant, templates[tidx][0], wall,
                                      report.n_executed, report.n_reused))
            peak_bytes = max(peak_bytes, rs.store.total_bytes())
            if prefetcher is not None:
                # between events = the background cadence: consume the
                # read log and warm the predicted-next artifacts off
                # the clock
                prefetcher.prefetch()

    repo = shared_rs.repo if shared_rs is not None else Repository()
    pstats = prefetcher.stats() if prefetcher is not None else {}
    return StreamResult(
        mode=mode, budget_bytes=budget_bytes, events=events,
        cum_wall_s=cum, total_wall_s=total, peak_store_bytes=peak_bytes,
        repo_entries=len(repo), repo_bytes=repo.total_stored_bytes(),
        evictions=repo.evictions, rejections=repo.rejections,
        refreshes=repo.refreshes,
        prefetch_hits=pstats.get("hits", 0),
        prefetched=pstats.get("prefetched", 0),
        refreshed_ahead=pstats.get("refreshed_ahead", 0),
        batches=n_batches, mqo_shared_wall_s=mqo_shared_wall,
        mqo_dup_executions=mqo_dups)
