"""Plan -> workflow-of-jobs compiler (the Pig MapReduce-compiler analogue).

A *job* is one jitted map->shuffle->reduce stage.  The compiler walks the
physical plan and cuts it at blocking operators (JOIN / GROUPBY / COGROUP /
DISTINCT), exactly like Pig embeds each such operator in its own reducer
stage (paper §2): pipelined (non-blocking) operators ride along in the map
phase before the blocking op or in the reduce phase after it; a second
blocking operator downstream starts a new job, with the boundary value
materialized to the artifact store.

Materialized boundaries are *content-addressed*: the dataset name is the
producing operator's plan fingerprint.  Two workflows that compute the
same intermediate therefore refer to the same artifact name — this is what
lets ReStore's Load-equivalence work across workflows (paper §3 relies on
rewritten jobs loading canonical repository filenames; content addressing
gives the same property structurally).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from ..core.plan import (BLOCKING_KINDS, Operator, PhysicalPlan, load, store)

MAP, REDUCE = 0, 1


class _UF:
    def __init__(self):
        self.parent: Dict[int, int] = {}
        self.n = 0

    def make(self) -> int:
        x = self.n
        self.n += 1
        self.parent[x] = x
        return x

    def find(self, x: int) -> int:
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a: int, b: int) -> int:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra
        return ra


@dataclasses.dataclass
class Job:
    job_id: int
    plan: PhysicalPlan
    inputs: List[str]          # dataset names read (sources + artifacts)
    outputs: List[str]         # dataset names written
    blocking: Optional[str]    # kind of the reduce-stage op (None = map-only)

    def depends_on(self, other: "Job") -> bool:
        return any(o in self.inputs for o in other.outputs)


@dataclasses.dataclass
class Workflow:
    jobs: List[Job]                 # topologically ordered
    final_outputs: Dict[str, str]   # user store-name -> dataset name

    def n_jobs(self) -> int:
        return len(self.jobs)


def art_name(fp: str) -> str:
    return "art/" + fp[:16]


def compile_workflow(plan: PhysicalPlan) -> Workflow:
    topo = plan.topo()

    uf = _UF()
    jobof: Dict[int, int] = {}
    phase: Dict[int, int] = {}
    has_reduce: Dict[int, bool] = {}   # keyed by uf-root
    cuts: List[Operator] = []          # ops materialized at a job boundary
    cut_set = set()

    def _has_reduce(j: int) -> bool:
        return has_reduce.get(uf.find(j), False)

    def _set_reduce(j: int):
        has_reduce[uf.find(j)] = True

    def _union(a: int, b: int) -> int:
        flag = _has_reduce(a) or _has_reduce(b)
        r = uf.union(a, b)
        if flag:
            has_reduce[uf.find(r)] = True
        return r

    def _cut(op: Operator):
        if id(op) not in cut_set:
            cut_set.add(id(op))
            cuts.append(op)

    for op in topo:
        if op.kind == "LOAD":
            continue
        infos = []
        for i in op.inputs:
            if i.kind == "LOAD":
                infos.append((i, None))
            else:
                infos.append((i, (jobof[id(i)], phase[id(i)])))

        if op.kind in BLOCKING_KINDS:
            myjob = uf.make()
            for i, info in infos:
                if info is None:
                    continue
                j, p = info
                if p == REDUCE or _has_reduce(j):
                    _cut(i)           # boundary: materialize, reload
                else:
                    myjob = _union(myjob, j)
            _set_reduce(myjob)
            jobof[id(op)], phase[id(op)] = myjob, REDUCE
            continue

        # non-blocking (FILTER/PROJECT/FOREACH/UNION/SPLIT/STORE)
        placed = [info for _, info in infos if info is not None]
        if not placed:
            jobof[id(op)], phase[id(op)] = uf.make(), MAP
        elif len(placed) == 1:
            (j, p) = placed[0]
            jobof[id(op)], phase[id(op)] = j, p
        else:
            roots = {uf.find(j) for j, _ in placed}
            phases = {p for _, p in placed}
            if len(roots) == 1 and len(phases) == 1:
                jobof[id(op)], phase[id(op)] = placed[0]
            elif phases == {MAP} and not any(_has_reduce(j) for j, _ in placed):
                j0 = placed[0][0]
                for j, _ in placed[1:]:
                    j0 = _union(j0, j)
                jobof[id(op)], phase[id(op)] = j0, MAP
            else:
                # mixed: keep the first map-phase pipeline, cut the rest
                keep = None
                for (i, info) in infos:
                    if info is None:
                        continue
                    j, p = info
                    if keep is None and p == MAP and not _has_reduce(j):
                        keep = (j, p)
                    else:
                        _cut(i)
                if keep is None:
                    keep = (uf.make(), MAP)
                jobof[id(op)], phase[id(op)] = keep

    # ---- group operators by job root --------------------------------------
    members: Dict[int, List[Operator]] = {}
    for op in topo:
        if op.kind == "LOAD":
            continue
        r = uf.find(jobof[id(op)])
        members.setdefault(r, []).append(op)

    # cut ops that are consumed by a different job than their own, plus
    # every op in `cuts`; order jobs topologically by producer->consumer
    producer_job = {id(op): uf.find(jobof[id(op)]) for op in topo
                    if op.kind != "LOAD"}

    # job dependency edges
    deps: Dict[int, set] = {r: set() for r in members}
    for op in topo:
        if op.kind == "LOAD":
            continue
        r = producer_job[id(op)]
        for i in op.inputs:
            if i.kind == "LOAD":
                continue
            ri = producer_job[id(i)]
            if ri != r:
                deps[r].add(ri)
                _cut(i)

    order: List[int] = []
    seen = set()

    def visit(r):
        if r in seen:
            return
        seen.add(r)
        for d in sorted(deps[r]):
            visit(d)
        order.append(r)

    for r in sorted(members):
        visit(r)

    # ---- build fragments in job-topo order --------------------------------
    artname: Dict[int, str] = {}      # original op id -> artifact dataset
    jobs: List[Job] = []
    final_outputs: Dict[str, str] = {}

    for jid, r in enumerate(order):
        ops = members[r]
        opset = {id(o) for o in ops}
        frag_map: Dict[int, Operator] = {}

        def rebuild(op: Operator) -> Operator:
            if id(op) in frag_map:
                return frag_map[id(op)]
            if op.kind == "LOAD":
                new = load(op.params["dataset"], op.params.get("version", 0),
                           op.params.get("capacity"), op.params.get("schema"))
            elif id(op) not in opset:
                new = load(artname[id(op)])     # boundary input
            else:
                new = Operator(op.kind, dict(op.params),
                               [rebuild(i) for i in op.inputs])
            frag_map[id(op)] = new
            return new

        sinks: List[Operator] = []
        sink_origin: Dict[int, Operator] = {}
        for op in ops:
            if op.kind == "STORE":
                s = rebuild(op)
                sinks.append(s)
                sink_origin[id(s)] = op
        # injected stores for cut ops produced here
        for op in ops:
            if id(op) in cut_set:
                s = store(rebuild(op), "pending")
                sinks.append(s)
                sink_origin[id(s)] = op

        frag = PhysicalPlan(sinks)
        fps = frag.fingerprints()
        outputs: List[str] = []
        dedup: List[Operator] = []
        for s in sinks:
            origin = sink_origin[id(s)]
            name = art_name(fps[id(s.inputs[0])])
            if origin.kind == "STORE":
                final_outputs[origin.params["name"]] = name
            else:
                artname[id(origin)] = name
            s.params["name"] = name
            if name not in outputs:
                outputs.append(name)
                dedup.append(s)
        sinks = dedup
        frag = PhysicalPlan(sinks)

        inputs = sorted({o.params["dataset"] for o in frag.loads()})
        blocking = None
        for op in ops:
            if op.kind in BLOCKING_KINDS:
                blocking = op.kind
        jobs.append(Job(jid, frag, inputs, outputs, blocking))

    return Workflow(jobs, final_outputs)
