"""Row expressions for Filter predicates and ForEach generators.

Expressions are tiny trees with (a) a JAX evaluator over a Table and (b) a
canonical ``key()`` used for operator-equivalence tests and plan
fingerprints (paper §3: two operators are equivalent iff they perform the
same function over equivalent inputs).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from .table import Table, encode_strings


class Expr:
    def key(self) -> Tuple:
        raise NotImplementedError

    def eval(self, t: Table) -> jnp.ndarray:
        raise NotImplementedError

    # sugar
    def _bin(self, op, other):
        other = other if isinstance(other, Expr) else Const(other)
        return BinOp(op, self, other)

    def __add__(self, o):
        return self._bin("add", o)

    def __sub__(self, o):
        return self._bin("sub", o)

    def __mul__(self, o):
        return self._bin("mul", o)

    def __truediv__(self, o):
        return self._bin("div", o)

    def __mod__(self, o):
        return self._bin("mod", o)

    def __eq__(self, o):  # type: ignore[override]
        return self._bin("eq", o)

    def __ne__(self, o):  # type: ignore[override]
        return self._bin("ne", o)

    def __lt__(self, o):
        return self._bin("lt", o)

    def __le__(self, o):
        return self._bin("le", o)

    def __gt__(self, o):
        return self._bin("gt", o)

    def __ge__(self, o):
        return self._bin("ge", o)

    def __and__(self, o):
        return self._bin("and", o)

    def __or__(self, o):
        return self._bin("or", o)

    def __hash__(self):
        return hash(self.key())


@dataclasses.dataclass(frozen=True, eq=False)
class Col(Expr):
    name: str

    def key(self):
        return ("col", self.name)

    def eval(self, t):
        return t.col(self.name)


@dataclasses.dataclass(frozen=True, eq=False)
class Const(Expr):
    value: object  # int | float | str

    def key(self):
        return ("const", repr(self.value))

    def eval(self, t):
        if isinstance(self.value, str):
            return jnp.asarray(encode_strings([self.value])[0])
        return jnp.asarray(self.value)


_OPS = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / jnp.where(b == 0, jnp.ones_like(b), b),
    "mod": lambda a, b: a % jnp.where(b == 0, jnp.ones_like(b), b),
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
}

_COMMUTATIVE = {"add", "mul", "eq", "ne", "and", "or"}


@dataclasses.dataclass(frozen=True, eq=False)
class BinOp(Expr):
    op: str
    lhs: Expr
    rhs: Expr

    def key(self):
        lk, rk = self.lhs.key(), self.rhs.key()
        if self.op in _COMMUTATIVE and rk < lk:  # canonical arg order
            lk, rk = rk, lk
        return ("bin", self.op, lk, rk)

    def eval(self, t):
        a, b = self.lhs.eval(t), self.rhs.eval(t)
        if a.ndim == 2 or (hasattr(b, "ndim") and b.ndim >= 1
                           and b.shape[-1:] == a.shape[-1:] and a.ndim == 2):
            # fixed-width string comparison: reduce across width
            r = _OPS[self.op](a, b)
            if self.op in ("eq",):
                return r.all(axis=-1)
            if self.op in ("ne",):
                return r.any(axis=-1)
            return r
        return _OPS[self.op](a, b)


@dataclasses.dataclass(frozen=True, eq=False)
class Cast(Expr):
    inner: Expr
    dtype: str

    def key(self):
        return ("cast", self.dtype, self.inner.key())

    def eval(self, t):
        return self.inner.eval(t).astype(self.dtype)


# Aggregation spec used by GROUPBY / COGROUP: (fn, column) pairs.
AGG_FNS = ("sum", "count", "min", "max", "mean")


def agg_key(aggs) -> Tuple:
    """aggs: dict outname -> (fn, colname)."""
    return tuple(sorted((o, fn, c) for o, (fn, c) in aggs.items()))


# ---------------------------------------------------------------------------
# Predicate normalization & implication (DESIGN.md §10)
#
# Filter predicates are normalized to conjunctive normal form over *atoms*:
# a comparison of one column against a constant (structured atom, open to
# interval reasoning) or any other boolean leaf (opaque atom, compared by
# canonical key only).  The normal form powers
#   * normalized FILTER fingerprints  — commuted / reassociated conjuncts
#     hash equal (``pred_normal_key``);
#   * subsumption checks              — ``implies(p, q)`` decides whether
#     every row satisfying p also satisfies q;
#   * compensation                    — ``residual_pred(p, q)`` is the part
#     of p a stored σ_q artifact still needs re-applied on top.

_CMP_OPS = frozenset({"lt", "le", "gt", "ge", "eq", "ne"})
_CMP_FLIP = {"lt": "gt", "le": "ge", "gt": "lt", "ge": "le",
             "eq": "eq", "ne": "ne"}


@dataclasses.dataclass(frozen=True, eq=False)
class Atom:
    """One boolean leaf of a normalized predicate.  Compare atoms via
    ``key()`` — generated equality would recurse into ``expr``, whose
    ``==`` is overloaded to build expression nodes.

    ``col``/``op``/``value`` are set only for the structured
    column-vs-numeric-constant form; ``expr`` always holds an evaluable
    expression for re-emission in residual predicates."""
    expr: Expr
    col: object = None    # str | None
    op: object = None     # one of _CMP_OPS | None
    value: object = None  # int | float | None

    @property
    def structured(self) -> bool:
        return self.col is not None

    def key(self) -> Tuple:
        if self.structured:
            return ("atom", self.col, self.op, repr(self.value))
        return ("opaque",) + (self.expr.key(),)


def _as_atom(e: Expr) -> Atom:
    """Recognize ``col <cmp> const`` (either argument order) as a
    structured atom; anything else is opaque."""
    if isinstance(e, BinOp) and e.op in _CMP_OPS:
        lhs, rhs, op = e.lhs, e.rhs, e.op
        if isinstance(lhs, Const) and isinstance(rhs, Col):
            lhs, rhs, op = rhs, lhs, _CMP_FLIP[op]
        if isinstance(lhs, Col) and isinstance(rhs, Const) \
                and isinstance(rhs.value, (int, float)) \
                and not isinstance(rhs.value, bool):
            return Atom(e, col=lhs.name, op=op, value=rhs.value)
    return Atom(e)


# Upper bound on CNF size: OR-over-AND distribution is exponential in
# the worst case, and ``pred_normal_key`` runs inside every FILTER
# fingerprint.  Predicates whose normal form would exceed the cap fall
# back to the raw canonical key (exact-only matching, no semantics).
MAX_CNF_CLAUSES = 64


class PredicateTooComplex(Exception):
    """The predicate's CNF would exceed ``MAX_CNF_CLAUSES``."""


def _cnf_clauses(e: Expr) -> Tuple[Tuple[Atom, ...], ...]:
    """CNF as a tuple of clauses; a clause is a tuple of disjoined atoms.
    AND flattens (union of clauses); OR distributes over AND.  Every
    intermediate result is held under ``MAX_CNF_CLAUSES``, bounding the
    whole normalization polynomially."""
    if isinstance(e, BinOp) and e.op == "and":
        out = _cnf_clauses(e.lhs) + _cnf_clauses(e.rhs)
        if len(out) > MAX_CNF_CLAUSES:
            raise PredicateTooComplex(len(out))
        return out
    if isinstance(e, BinOp) and e.op == "or":
        ls, rs = _cnf_clauses(e.lhs), _cnf_clauses(e.rhs)
        if len(ls) * len(rs) > MAX_CNF_CLAUSES:
            raise PredicateTooComplex(len(ls) * len(rs))
        return tuple(cl + cr for cl in ls for cr in rs)
    return ((_as_atom(e),),)


def _dedup_sort(clauses) -> Tuple[Tuple[Atom, ...], ...]:
    out = []
    seen = set()
    for c in clauses:
        atoms = {a.key(): a for a in c}
        canon = tuple(atoms[k] for k in sorted(atoms))
        ck = tuple(a.key() for a in canon)
        if ck not in seen:
            seen.add(ck)
            out.append((ck, canon))
    out.sort(key=lambda p: p[0])
    return tuple(c for _, c in out)


def to_cnf(pred: Expr) -> Tuple[Tuple[Atom, ...], ...]:
    """Canonical CNF: clauses and atoms deduped and sorted by key.
    Raises ``PredicateTooComplex`` past ``MAX_CNF_CLAUSES`` clauses."""
    return _dedup_sort(_cnf_clauses(pred))


def pred_normal_key(pred: Expr) -> Tuple:
    """Canonical digest of a predicate: equal for commuted and
    reassociated conjuncts/disjuncts.  Used by FILTER fingerprints.
    Oversized predicates keep their raw (linear-time) canonical key."""
    try:
        clauses = to_cnf(pred)
    except PredicateTooComplex:
        return ("rawpred", pred.key())
    return ("cnf",) + tuple(tuple(a.key() for a in c) for c in clauses)


def pred_columns(pred: Expr) -> frozenset:
    """Names of every column the predicate reads."""
    cols = set()

    def walk(e: Expr):
        if isinstance(e, Col):
            cols.add(e.name)
        elif isinstance(e, BinOp):
            walk(e.lhs)
            walk(e.rhs)
        elif isinstance(e, Cast):
            walk(e.inner)
    walk(pred)
    return frozenset(cols)


def _interval_implies(ao: str, va, bo: str, vb) -> bool:
    """``x ⋈ao va  ⇒  x ⋈bo vb`` by containment of satisfying ranges."""
    if bo == "gt":
        return (ao == "gt" and va >= vb) or \
               (ao in ("ge", "eq") and va > vb)
    if bo == "ge":
        return ao in ("gt", "ge", "eq") and va >= vb
    if bo == "lt":
        return (ao == "lt" and va <= vb) or \
               (ao in ("le", "eq") and va < vb)
    if bo == "le":
        return ao in ("lt", "le", "eq") and va <= vb
    if bo == "eq":
        return ao == "eq" and va == vb
    if bo == "ne":
        return (ao == "eq" and va != vb) or \
               (ao == "gt" and va >= vb) or (ao == "ge" and va > vb) or \
               (ao == "lt" and va <= vb) or (ao == "le" and va < vb)
    return False


def atom_implies(a: Atom, b: Atom) -> bool:
    """a ⇒ b for single atoms.  Equal atoms trivially imply; structured
    atoms on the same column use interval reasoning (set containment of
    the satisfying ranges).  Conservative: False when unsure.

    The interval check runs on the exact Python values AND on the
    constants rounded to float32: predicates evaluate against columns as
    narrow as float32, where two distinct reals can collapse to one
    runtime constant and "strictly stronger" silently stops being
    strict.  Requiring the containment under both semantics covers both
    integer columns (exact) and float32 columns (rounded)."""
    if a.key() == b.key():
        return True
    if not (a.structured and b.structured) or a.col != b.col:
        return False
    if not _interval_implies(a.op, a.value, b.op, b.value):
        return False
    return _interval_implies(a.op, float(np.float32(a.value)),
                             b.op, float(np.float32(b.value)))


def _clause_implies(ca, cb) -> bool:
    """Disjunction ca ⇒ disjunction cb: every atom of ca implies some
    atom of cb (then any witness satisfying ca satisfies cb)."""
    return all(any(atom_implies(a, b) for b in cb) for a in ca)


def implies(p: Expr, q: Expr) -> bool:
    """Does p ⇒ q?  p = ∧ Cp; q = ∧ Cq.  Sufficient (and sound) check:
    every clause of q is implied by some clause of p.  Oversized
    predicates conservatively do not imply anything."""
    try:
        cp, cq = to_cnf(p), to_cnf(q)
    except PredicateTooComplex:
        return False
    return all(any(_clause_implies(c1, c2) for c1 in cp) for c2 in cq)


def _clause_expr(clause) -> Expr:
    e = clause[0].expr
    for a in clause[1:]:
        e = BinOp("or", e, a.expr)
    return e


def conjoin(preds) -> Expr:
    """AND together a non-empty sequence of predicates."""
    preds = list(preds)
    e = preds[0]
    for p in preds[1:]:
        e = BinOp("and", e, p)
    return e


def residual_pred(p: Expr, q: Expr):
    """Given p ⇒ q, the compensation predicate R with  q ∧ R ≡ p:
    the clauses of CNF(p) not already implied by q (q implies its own
    clauses, so dropping them is exact, not an approximation).  Returns
    None when p and q are equivalent (no residual filter needed).
    Re-applying all of p is always sound given p ⇒ q, so oversized
    predicates fall back to it."""
    try:
        cq = to_cnf(q)
        keep = [c for c in to_cnf(p)
                if not any(_clause_implies(c2, c) for c2 in cq)]
    except PredicateTooComplex:
        return p
    if not keep:
        return None
    return conjoin(_clause_expr(c) for c in keep)
