"""Row expressions for Filter predicates and ForEach generators.

Expressions are tiny trees with (a) a JAX evaluator over a Table and (b) a
canonical ``key()`` used for operator-equivalence tests and plan
fingerprints (paper §3: two operators are equivalent iff they perform the
same function over equivalent inputs).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from .table import Table, encode_strings


class Expr:
    def key(self) -> Tuple:
        raise NotImplementedError

    def eval(self, t: Table) -> jnp.ndarray:
        raise NotImplementedError

    # sugar
    def _bin(self, op, other):
        other = other if isinstance(other, Expr) else Const(other)
        return BinOp(op, self, other)

    def __add__(self, o):
        return self._bin("add", o)

    def __sub__(self, o):
        return self._bin("sub", o)

    def __mul__(self, o):
        return self._bin("mul", o)

    def __truediv__(self, o):
        return self._bin("div", o)

    def __mod__(self, o):
        return self._bin("mod", o)

    def __eq__(self, o):  # type: ignore[override]
        return self._bin("eq", o)

    def __ne__(self, o):  # type: ignore[override]
        return self._bin("ne", o)

    def __lt__(self, o):
        return self._bin("lt", o)

    def __le__(self, o):
        return self._bin("le", o)

    def __gt__(self, o):
        return self._bin("gt", o)

    def __ge__(self, o):
        return self._bin("ge", o)

    def __and__(self, o):
        return self._bin("and", o)

    def __or__(self, o):
        return self._bin("or", o)

    def __hash__(self):
        return hash(self.key())


@dataclasses.dataclass(frozen=True, eq=False)
class Col(Expr):
    name: str

    def key(self):
        return ("col", self.name)

    def eval(self, t):
        return t.col(self.name)


@dataclasses.dataclass(frozen=True, eq=False)
class Const(Expr):
    value: object  # int | float | str

    def key(self):
        return ("const", repr(self.value))

    def eval(self, t):
        if isinstance(self.value, str):
            return jnp.asarray(encode_strings([self.value])[0])
        return jnp.asarray(self.value)


_OPS = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / jnp.where(b == 0, jnp.ones_like(b), b),
    "mod": lambda a, b: a % jnp.where(b == 0, jnp.ones_like(b), b),
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
}

_COMMUTATIVE = {"add", "mul", "eq", "ne", "and", "or"}


@dataclasses.dataclass(frozen=True, eq=False)
class BinOp(Expr):
    op: str
    lhs: Expr
    rhs: Expr

    def key(self):
        lk, rk = self.lhs.key(), self.rhs.key()
        if self.op in _COMMUTATIVE and rk < lk:  # canonical arg order
            lk, rk = rk, lk
        return ("bin", self.op, lk, rk)

    def eval(self, t):
        a, b = self.lhs.eval(t), self.rhs.eval(t)
        if a.ndim == 2 or (hasattr(b, "ndim") and b.ndim >= 1
                           and b.shape[-1:] == a.shape[-1:] and a.ndim == 2):
            # fixed-width string comparison: reduce across width
            r = _OPS[self.op](a, b)
            if self.op in ("eq",):
                return r.all(axis=-1)
            if self.op in ("ne",):
                return r.any(axis=-1)
            return r
        return _OPS[self.op](a, b)


@dataclasses.dataclass(frozen=True, eq=False)
class Cast(Expr):
    inner: Expr
    dtype: str

    def key(self):
        return ("cast", self.dtype, self.inner.key())

    def eval(self, t):
        return self.inner.eval(t).astype(self.dtype)


# Aggregation spec used by GROUPBY / COGROUP: (fn, column) pairs.
AGG_FNS = ("sum", "count", "min", "max", "mean")


def agg_key(aggs) -> Tuple:
    """aggs: dict outname -> (fn, colname)."""
    return tuple(sorted((o, fn, c) for o, (fn, c) in aggs.items()))
