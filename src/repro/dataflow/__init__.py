# Subpackages import directly (e.g. repro.dataflow.physical); keeping this
# empty avoids a circular import with repro.core.plan.
