"""Distributed shuffle for the relational engine: the MapReduce
map->shuffle->reduce stage as a shard_map program (DESIGN.md §11).

Hadoop's sort-shuffle writes spill files; the TPU-native exchange is:

  map side   : hash rows -> destination shard (the radix_partition
               kernel's binning), bucket rows per destination with a
               bounded per-destination capacity (skew overflows are
               counted, as in the join's probe-window contract);
  shuffle    : one jax.lax.all_to_all along the "data" axis per column
               (the T_sort term of Eq. 2 becomes ICI traffic);
  reduce side: rows for the same key are now co-located — the ordinary
               sort-based segment aggregation runs per shard.

Every blocking operator (GROUPBY / DISTINCT / JOIN / COGROUP) has a
distributed form here, and every one has a **shuffle-free** variant:
when the input is already hash-partitioned on compatible keys across
the same shard count (a co-partitioned repository artifact, or the
output of an upstream exchange — M3R's partition stability), the
map+all_to_all phases are skipped entirely and only the local reduce
runs.  That skip is what partition-aware reuse buys: a reused artifact
answers not just the compute but the exchange.

Losslessness: the per-destination bucket is ``min(cap_loc, max(8,
cap_loc * skew_factor / n_shards))`` rows, so ``skew_factor >=
n_shards`` makes the exchange lossless (every source shard can route
all of its rows to a single destination); smaller factors trade memory
for a counted overflow, exactly like the join probe window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.plan import _join_out_names
from ..launch.mesh import shard_map
from .physical import (_cogroup_prepare, _cogroup_rename, op_distinct,
                       op_groupby, op_join, use_pallas)
from .table import Table, partition_hash


def pad_to_multiple(table: Table, mult: int) -> Table:
    """Append invalid rows so ``capacity % mult == 0`` (shard_map needs
    the row dimension divisible by the mesh axis)."""
    pad = (-table.capacity) % mult
    if pad == 0:
        return table
    cols = {n: jnp.concatenate(
        [c, jnp.zeros((pad,) + c.shape[1:], c.dtype)])
        for n, c in table.columns.items()}
    valid = jnp.concatenate([table.valid, jnp.zeros((pad,), bool)])
    return Table(cols, valid)


def _bucket_size(cap_loc: int, n_shards: int, skew_factor: float) -> int:
    return min(cap_loc, max(8, int(cap_loc * skew_factor / n_shards)))


def _dest_ids(local: Table, keys, n_shards: int) -> jnp.ndarray:
    """Per-row destination shard (invalid rows parked at ``n_shards``),
    via the radix_partition kernel when the shard count is its
    power-of-two binning."""
    h = partition_hash(local, keys)
    cap = local.capacity
    tile = cap if cap % 256 else 256
    if n_shards & (n_shards - 1) == 0:
        from ..kernels.radix_partition.ops import partition
        pid, _hist = partition(
            h, local.valid, n_parts=n_shards, tile_n=tile,
            impl="pallas" if use_pallas() else "ref",
            interpret=jax.default_backend() != "tpu")
        return pid
    pid = (h % jnp.uint32(n_shards)).astype(jnp.int32)
    return jnp.where(local.valid, pid, n_shards)


def _exchange(local: Table, dest: jnp.ndarray, n_shards: int,
              bucket: int, axis: str):
    """Bucket rows by destination shard and all_to_all them.  Runs
    inside a shard_map body.  Returns (received Table with capacity
    ``n_shards * bucket``, global overflow count)."""
    order = jnp.argsort(dest)
    sdest = jnp.take(dest, order)
    seg_start = jnp.searchsorted(sdest, sdest, side="left")
    rank = jnp.arange(sdest.shape[0]) - seg_start
    keep = (sdest < n_shards) & (rank < bucket)
    slot = jnp.where(keep, sdest * bucket + rank, n_shards * bucket)
    overflow = jnp.sum(((sdest < n_shards) & ~keep).astype(jnp.int32))
    overflow = jax.lax.psum(overflow, axis)

    out_cols = {}
    for n in local.names:
        c = jnp.take(local.col(n), order, axis=0)
        buf = jnp.zeros((n_shards * bucket,) + c.shape[1:], c.dtype)
        buf = buf.at[slot].set(c, mode="drop")
        buf = buf.reshape((n_shards, bucket) + c.shape[1:])
        out_cols[n] = jax.lax.all_to_all(
            buf, axis, split_axis=0, concat_axis=0, tiled=False
        ).reshape((n_shards * bucket,) + c.shape[1:])
    vbuf = jnp.zeros((n_shards * bucket,), bool).at[slot].set(
        jnp.take(local.valid, order), mode="drop")
    vrecv = jax.lax.all_to_all(
        vbuf.reshape(n_shards, bucket), axis,
        split_axis=0, concat_axis=0, tiled=False).reshape(-1)
    return Table(out_cols, vrecv), overflow


def _table_specs(table: Table, axis: str):
    return tuple(P(axis) for _ in table.names) + (P(axis),)


def _table_args(table: Table):
    return tuple(table.col(n) for n in table.names) + (table.valid,)


def _as_local(names, flat):
    return Table(dict(zip(names, flat[:-1])), flat[-1])


def distributed_groupby(table: Table, keys, aggs, mesh,
                        axis: str = "data", skew_factor: float = 4.0,
                        co_partitioned: bool = False):
    """GROUPBY over a row-sharded Table.  Returns (result table sharded
    over ``axis`` — each shard holds the groups of its hash range —
    and the global overflow count).  With ``co_partitioned`` the input
    is already hash-partitioned on (a subset of) ``keys`` across the
    shards and the exchange is skipped (DESIGN.md §11)."""
    n_shards = mesh.shape[axis]
    if not co_partitioned:
        table = pad_to_multiple(table, n_shards)
    names = table.names
    cap_loc = table.capacity // n_shards
    bucket = _bucket_size(cap_loc, n_shards, skew_factor)

    def body(*flat):
        local = _as_local(names, flat)
        if co_partitioned:
            recv, overflow = local, jnp.zeros((), jnp.int32)
        else:
            dest = _dest_ids(local, keys, n_shards)
            recv, overflow = _exchange(local, dest, n_shards, bucket, axis)
        grouped = op_groupby(recv, keys, aggs)
        return _table_args(grouped) + (overflow,)

    out_names = sorted(set(list(keys) + list(aggs)))
    out_specs = tuple(P(axis) for _ in out_names) + (P(axis), P())
    flat = shard_map(body, mesh, _table_specs(table, axis), out_specs)(
        *_table_args(table))
    return Table(dict(zip(out_names, flat[:-2])), flat[-2]), flat[-1]


def distributed_distinct(table: Table, mesh, axis: str = "data",
                         skew_factor: float = 4.0,
                         co_partitioned: bool = False):
    """DISTINCT over a row-sharded Table: exchange on all columns (equal
    rows co-locate), then the ordinary local distinct per shard."""
    n_shards = mesh.shape[axis]
    if not co_partitioned:
        table = pad_to_multiple(table, n_shards)
    names = table.names
    cap_loc = table.capacity // n_shards
    bucket = _bucket_size(cap_loc, n_shards, skew_factor)

    def body(*flat):
        local = _as_local(names, flat)
        if co_partitioned:
            recv, overflow = local, jnp.zeros((), jnp.int32)
        else:
            dest = _dest_ids(local, names, n_shards)
            recv, overflow = _exchange(local, dest, n_shards, bucket, axis)
        uniq = op_distinct(recv)
        return _table_args(uniq) + (overflow,)

    out_specs = tuple(P(axis) for _ in names) + (P(axis), P())
    flat = shard_map(body, mesh, _table_specs(table, axis), out_specs)(
        *_table_args(table))
    return Table(dict(zip(names, flat[:-2])), flat[-2]), flat[-1]


def distributed_join(left: Table, right: Table, lkeys, rkeys, mesh,
                     axis: str = "data", expansion: int = 1,
                     skew_factor: float = 4.0,
                     co_left: bool = False, co_right: bool = False):
    """Inner equi-join: both sides are hash-exchanged on their keys with
    POSITIONALLY aligned partition hashes (matching key values land on
    the same shard), then the local sort+probe join runs per shard.
    Either side skips its exchange when already aligned-partitioned.
    Returns (table, exchange overflow, probe-window overflow) — the two
    loss modes are audited separately (JobStats.shuffle_overflow vs
    join_overflow)."""
    n_shards = mesh.shape[axis]
    if not co_left:
        left = pad_to_multiple(left, n_shards)
    if not co_right:
        right = pad_to_multiple(right, n_shards)
    lnames, rnames = left.names, right.names
    lbucket = _bucket_size(left.capacity // n_shards, n_shards, skew_factor)
    rbucket = _bucket_size(right.capacity // n_shards, n_shards, skew_factor)

    def body(*flat):
        nl = len(lnames) + 1
        llocal = _as_local(lnames, flat[:nl])
        rlocal = _as_local(rnames, flat[nl:])
        if co_left:
            lrecv, lovf = llocal, jnp.zeros((), jnp.int32)
        else:
            lrecv, lovf = _exchange(llocal, _dest_ids(llocal, lkeys, n_shards),
                                    n_shards, lbucket, axis)
        if co_right:
            rrecv, rovf = rlocal, jnp.zeros((), jnp.int32)
        else:
            rrecv, rovf = _exchange(rlocal, _dest_ids(rlocal, rkeys, n_shards),
                                    n_shards, rbucket, axis)
        joined, jovf = op_join(lrecv, rrecv, lkeys, rkeys, expansion)
        return _table_args(joined) + (lovf + rovf,
                                      jax.lax.psum(jovf, axis))

    # the SEQUENTIAL rename rule shared with op_join/plan props: a
    # right-side name colliding with an already-renamed "_r" column
    # chains to "_r_r" — a set comprehension would collapse it and
    # desynchronize out_specs from the body's returned columns
    out_names = list(_join_out_names(lnames, rnames))
    in_specs = _table_specs(left, axis) + _table_specs(right, axis)
    out_specs = tuple(P(axis) for _ in out_names) + (P(axis), P(), P())
    flat = shard_map(body, mesh, in_specs, out_specs)(
        *(_table_args(left) + _table_args(right)))
    return (Table(dict(zip(out_names, flat[:-3])), flat[-3]),
            flat[-2], flat[-1])


def distributed_cogroup(a: Table, b: Table, keys_l, keys_r,
                        aggs_l, aggs_r, mesh, axis: str = "data",
                        skew_factor: float = 4.0,
                        co_partitioned: bool = False):
    """COGROUP: both inputs are aligned onto the shared (k0..kn, va_*,
    vb_*) schema on the map side, exchanged on the unified keys, then
    unioned + grouped locally per shard.  The union happens INSIDE the
    shard body: concatenating the global tables first would interleave
    the two inputs' partition blocks and break co-location."""
    n_shards = mesh.shape[axis]
    ta, tb, keys, aggs = _cogroup_prepare(a, b, keys_l, keys_r,
                                          aggs_l, aggs_r)
    if not co_partitioned:
        ta = pad_to_multiple(ta, n_shards)
        tb = pad_to_multiple(tb, n_shards)
    anames, bnames = ta.names, tb.names
    abucket = _bucket_size(ta.capacity // n_shards, n_shards, skew_factor)
    bbucket = _bucket_size(tb.capacity // n_shards, n_shards, skew_factor)

    def body(*flat):
        na = len(anames) + 1
        aloc = _as_local(anames, flat[:na])
        bloc = _as_local(bnames, flat[na:])
        if co_partitioned:
            arecv, brecv = aloc, bloc
            overflow = jnp.zeros((), jnp.int32)
        else:
            arecv, aovf = _exchange(aloc, _dest_ids(aloc, keys, n_shards),
                                    n_shards, abucket, axis)
            brecv, bovf = _exchange(bloc, _dest_ids(bloc, keys, n_shards),
                                    n_shards, bbucket, axis)
            overflow = aovf + bovf
        cols = {n: jnp.concatenate([arecv.col(n), brecv.col(n)])
                for n in arecv.names}
        both = Table(cols, jnp.concatenate([arecv.valid, brecv.valid]))
        grouped = op_groupby(both, keys, aggs)
        return _table_args(grouped) + (overflow,)

    out_names = sorted(set(list(keys) + list(aggs)))
    in_specs = _table_specs(ta, axis) + _table_specs(tb, axis)
    out_specs = tuple(P(axis) for _ in out_names) + (P(axis), P())
    flat = shard_map(body, mesh, in_specs, out_specs)(
        *(_table_args(ta) + _table_args(tb)))
    grouped = Table(dict(zip(out_names, flat[:-2])), flat[-2])
    return _cogroup_rename(grouped, keys_l), flat[-1]
