"""Distributed shuffle for the relational engine: the MapReduce
map->shuffle->reduce stage as a shard_map program.

Hadoop's sort-shuffle writes spill files; the TPU-native exchange is:

  map side   : hash rows -> destination shard (radix_partition kernel's
               binning), bucket rows per destination with a bounded
               per-destination capacity (skew overflows are counted, as
               in the join's probe-window contract);
  shuffle    : one jax.lax.all_to_all along the "data" axis per column
               (the T_sort term of Eq. 2 becomes ICI traffic);
  reduce side: rows for the same key are now co-located — the ordinary
               sort-based segment aggregation runs per shard.

This is the engine's scale-out path: the dry-run lowers a GROUPBY job on
the production 16x16 mesh, and the parity test checks an 8-device run
against the single-device operator.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .physical import op_groupby
from .table import Table, hash_columns


def distributed_groupby(table: Table, keys, aggs, mesh,
                        axis: str = "data", skew_factor: float = 4.0
                        ) -> Tuple[Table, jnp.ndarray]:
    """GROUPBY over a row-sharded Table.  Returns (result table sharded
    over ``axis`` — each shard holds the groups of its hash range —
    and the global overflow count)."""
    n_shards = mesh.shape[axis]
    names = table.names
    cap_loc = table.capacity // n_shards
    bucket = max(8, int(cap_loc * skew_factor / n_shards))

    def body(*cols_and_valid):
        cols = dict(zip(names, cols_and_valid[:-1]))
        valid = cols_and_valid[-1]
        local = Table(cols, valid)

        dest = (hash_columns(local, keys, seed=7)
                % jnp.uint32(n_shards)).astype(jnp.int32)
        dest = jnp.where(valid, dest, n_shards)       # park invalid
        order = jnp.argsort(dest)
        sdest = jnp.take(dest, order)
        seg_start = jnp.searchsorted(sdest, sdest, side="left")
        rank = jnp.arange(sdest.shape[0]) - seg_start
        keep = (sdest < n_shards) & (rank < bucket)
        slot = jnp.where(keep, sdest * bucket + rank, n_shards * bucket)
        overflow = jnp.sum(((sdest < n_shards) & ~keep).astype(jnp.int32))
        overflow = jax.lax.psum(overflow, axis)

        out_cols = {}
        for n in names:
            c = jnp.take(local.col(n), order, axis=0)
            buf = jnp.zeros((n_shards * bucket,) + c.shape[1:], c.dtype)
            buf = buf.at[slot].set(c, mode="drop")
            buf = buf.reshape((n_shards, bucket) + c.shape[1:])
            out_cols[n] = jax.lax.all_to_all(
                buf, axis, split_axis=0, concat_axis=0, tiled=False
            ).reshape((n_shards * bucket,) + c.shape[1:])
        vbuf = jnp.zeros((n_shards * bucket,), bool).at[slot].set(
            jnp.take(valid, order), mode="drop")
        vrecv = jax.lax.all_to_all(
            vbuf.reshape(n_shards, bucket), axis,
            split_axis=0, concat_axis=0, tiled=False).reshape(-1)

        grouped = op_groupby(Table(out_cols, vrecv), keys, aggs)
        flat = tuple(grouped.col(n) for n in grouped.names) \
            + (grouped.valid, overflow)
        return flat

    in_specs = tuple(P(axis) for _ in names) + (P(axis),)
    # probe output structure once to build out_specs
    out_names = sorted(set(list(keys) + list(aggs)))
    out_specs = tuple(P(axis) for _ in out_names) + (P(axis), P())

    flat = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)(
        *(table.col(n) for n in names), table.valid)
    cols = dict(zip(out_names, flat[:-2]))
    return Table(cols, flat[-2]), flat[-1]
