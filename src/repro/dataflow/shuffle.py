"""Distributed shuffle for the relational engine: the MapReduce
map->shuffle->reduce stage as a shard_map program (DESIGN.md §11).

Hadoop's sort-shuffle writes spill files; the TPU-native exchange is:

  map side   : ONE fused kernel (radix_partition.partition_scatter)
               assigns every row its destination shard AND its slot in
               a bounded per-destination bucket — binning + arrival
               rank, no sort; skew overflows are counted, as in the
               join's probe-window contract.  The reduce side's sort
               hashes are also computed here, over the small
               pre-exchange shard;
  shuffle    : all columns + validity + shipped hash lanes byte-packed
               into one buffer -> ONE jnp scatter -> ONE
               jax.lax.all_to_all along the "data" axis (the T_sort
               term of Eq. 2 becomes ICI traffic).  A join's two sides
               are independent dataflow, so XLA may overlap one side's
               collective with the other side's reduce prep;
  reduce side: rows for the same key are now co-located — the ordinary
               sort-based segment aggregation runs per shard, seeded
               with the shipped hash lanes instead of re-hashing.

Every blocking operator (GROUPBY / DISTINCT / JOIN / COGROUP) has a
distributed form here, and every one has a **shuffle-free** variant:
when the input is already hash-partitioned on compatible keys across
the same shard count (a co-partitioned repository artifact, or the
output of an upstream exchange — M3R's partition stability), the
map+all_to_all phases are skipped entirely and only the local reduce
runs.  That skip is what partition-aware reuse buys: a reused artifact
answers not just the compute but the exchange.

Losslessness: the per-destination bucket is ``min(cap_loc, max(8,
cap_loc * skew_factor / n_shards))`` rows, so ``skew_factor >=
n_shards`` makes the exchange lossless (every source shard can route
all of its rows to a single destination); smaller factors trade memory
for a counted overflow, exactly like the join probe window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.plan import _join_out_names
from ..kernels import autotune
from ..launch.mesh import shard_map
from .physical import (_cogroup_prepare, _cogroup_rename, op_distinct,
                       op_distinct_hashed, op_groupby, op_groupby_hashed,
                       op_join, use_pallas)
from .table import (Table, key_hash, pack_rows, partition_finalize,
                    unpack_rows)


def pad_to_multiple(table: Table, mult: int) -> Table:
    """Append invalid rows so ``capacity % mult == 0`` (shard_map needs
    the row dimension divisible by the mesh axis)."""
    pad = (-table.capacity) % mult
    if pad == 0:
        return table
    cols = {n: jnp.concatenate(
        [c, jnp.zeros((pad,) + c.shape[1:], c.dtype)])
        for n, c in table.columns.items()}
    valid = jnp.concatenate([table.valid, jnp.zeros((pad,), bool)])
    return Table(cols, valid)


def _bucket_size(cap_loc: int, n_shards: int, skew_factor: float) -> int:
    return min(cap_loc, max(8, int(cap_loc * skew_factor / n_shards)))


def _exchange(local: Table, keys, n_shards: int, bucket: int, axis: str):
    """Fused map-side exchange (DESIGN.md §14).  One kernel assigns
    every row its destination bucket slot (partition binning + arrival
    rank, no sort); all columns, the validity lane, and the shipped
    hash lane are byte-packed into a single buffer, so the whole
    exchange is ONE scatter and ONE all_to_all instead of one pair per
    column.  Runs inside a shard_map body.  Returns (received Table
    with capacity ``n_shards * bucket``, shipped hash lanes (a 1-tuple
    holding the seed-0 key hash), global overflow count).

    The key columns are string-folded ONCE: the routing bits are
    ``partition_finalize`` (a few integer ops) over the same seed-0
    ``key_hash`` lane that is shipped to the reduce side, where it
    seeds the segmenting / join probe instead of a re-hash over the
    inflated ``n_shards * bucket`` receive capacity — map-side prep
    the collective carries along instead of serializing the reduce
    behind it."""
    h1 = key_hash(local, keys, seed=0)
    tile = autotune.choose("partition_scatter", local.capacity, "uint32",
                           "tile_n", 256)
    from ..kernels.radix_partition.ops import scatter_slots
    slot, overflow = scatter_slots(
        partition_finalize(h1), local.valid, n_parts=n_shards, bucket=bucket,
        impl="pallas" if use_pallas() else "ref", tile_n=tile,
        interpret=jax.default_backend() != "tpu")
    overflow = jax.lax.psum(overflow, axis)

    cols = dict(local.columns)
    cols["__h1__"] = h1
    packed, layout = pack_rows(cols, local.valid)
    row_bytes = packed.shape[1]
    n = packed.shape[0]
    # route the permutation through a 4-byte index scatter + row gather:
    # XLA CPU prices a scatter ~10x a gather of the same rows, so
    # inverting the slot map first and gathering the packed rows beats
    # scattering them directly; unhit slots gather the appended
    # zero row, which unpacks to valid=False
    inv = jnp.full((n_shards * bucket,), n, jnp.int32)
    inv = inv.at[slot].set(jnp.arange(n, dtype=jnp.int32), mode="drop")
    src = jnp.concatenate([packed, jnp.zeros((1, row_bytes), jnp.uint8)])
    buf = jnp.take(src, inv, axis=0)
    recv = jax.lax.all_to_all(
        buf.reshape(n_shards, bucket, row_bytes), axis,
        split_axis=0, concat_axis=0, tiled=False
    ).reshape(n_shards * bucket, row_bytes)
    rcols, rvalid = unpack_rows(recv, layout)
    pre = (rcols.pop("__h1__"),)
    return Table(rcols, rvalid), pre, overflow


def _table_specs(table: Table, axis: str):
    return tuple(P(axis) for _ in table.names) + (P(axis),)


def _table_args(table: Table):
    return tuple(table.col(n) for n in table.names) + (table.valid,)


def _as_local(names, flat):
    return Table(dict(zip(names, flat[:-1])), flat[-1])


def distributed_groupby(table: Table, keys, aggs, mesh,
                        axis: str = "data", skew_factor: float = 4.0,
                        co_partitioned: bool = False,
                        lossless: bool = False,
                        pre_lane=None):
    """GROUPBY over a row-sharded Table.  Returns (result table sharded
    over ``axis`` — each shard holds the groups of its hash range —
    and the global overflow count).  With ``co_partitioned`` the input
    is already hash-partitioned on (a subset of) ``keys`` across the
    shards and the exchange is skipped (DESIGN.md §11).

    The per-shard reduce is the sort-free hash-segmented groupby; its
    h1-collision count folds into the overflow so the engine's lossless
    retry covers both loss modes.  ``lossless`` selects the sort-based
    reduce (collision-proof) — the retry path.

    ``pre_lane`` optionally carries a row-aligned seed-0 ``key_hash``
    lane for ``keys`` (e.g. an upstream join's shipped hash, see
    ``distributed_join(return_pre=True)``); it seeds the reduce in the
    exchange-skipped path so co-partitioned inputs never re-hash their
    key columns.  Ignored unless ``co_partitioned``."""
    n_shards = mesh.shape[axis]
    if not co_partitioned:
        table = pad_to_multiple(table, n_shards)
        pre_lane = None   # lane rows would not survive the exchange
    names = table.names
    cap_loc = table.capacity // n_shards
    bucket = _bucket_size(cap_loc, n_shards, skew_factor)
    n_in = len(names) + 1

    def body(*flat):
        local = _as_local(names, flat[:n_in])
        if co_partitioned:
            pre = (flat[n_in],) if pre_lane is not None else None
            recv, overflow = local, jnp.zeros((), jnp.int32)
        else:
            recv, pre, overflow = _exchange(local, keys, n_shards,
                                            bucket, axis)
        if lossless:
            grouped = op_groupby(recv, keys, aggs, pre=pre)
        else:
            grouped, coll = op_groupby_hashed(recv, keys, aggs, pre=pre)
            overflow = overflow + jax.lax.psum(coll, axis)
        return _table_args(grouped) + (overflow,)

    out_names = sorted(set(list(keys) + list(aggs)))
    in_specs = _table_specs(table, axis)
    args = _table_args(table)
    if pre_lane is not None:
        in_specs = in_specs + (P(axis),)
        args = args + (pre_lane,)
    out_specs = tuple(P(axis) for _ in out_names) + (P(axis), P())
    flat = shard_map(body, mesh, in_specs, out_specs)(*args)
    return Table(dict(zip(out_names, flat[:-2])), flat[-2]), flat[-1]


def distributed_distinct(table: Table, mesh, axis: str = "data",
                         skew_factor: float = 4.0,
                         co_partitioned: bool = False,
                         lossless: bool = False):
    """DISTINCT over a row-sharded Table: exchange on all columns (equal
    rows co-locate), then the local hash-segmented (or, ``lossless``,
    sort-based) distinct per shard."""
    n_shards = mesh.shape[axis]
    if not co_partitioned:
        table = pad_to_multiple(table, n_shards)
    names = table.names
    cap_loc = table.capacity // n_shards
    bucket = _bucket_size(cap_loc, n_shards, skew_factor)

    def body(*flat):
        local = _as_local(names, flat)
        if co_partitioned:
            recv, pre, overflow = local, None, jnp.zeros((), jnp.int32)
        else:
            recv, pre, overflow = _exchange(local, names, n_shards,
                                            bucket, axis)
        if lossless:
            uniq = op_distinct(recv, pre=pre)
        else:
            uniq, coll = op_distinct_hashed(recv, pre=pre)
            overflow = overflow + jax.lax.psum(coll, axis)
        return _table_args(uniq) + (overflow,)

    out_specs = tuple(P(axis) for _ in names) + (P(axis), P())
    flat = shard_map(body, mesh, _table_specs(table, axis), out_specs)(
        *_table_args(table))
    return Table(dict(zip(names, flat[:-2])), flat[-2]), flat[-1]


def distributed_join(left: Table, right: Table, lkeys, rkeys, mesh,
                     axis: str = "data", expansion: int = 1,
                     skew_factor: float = 4.0,
                     co_left: bool = False, co_right: bool = False,
                     return_pre: bool = False):
    """Inner equi-join: both sides are hash-exchanged on their keys with
    POSITIONALLY aligned partition hashes (matching key values land on
    the same shard), then the local sort+probe join runs per shard.
    Either side skips its exchange when already aligned-partitioned.
    Returns (table, exchange overflow, probe-window overflow) — the two
    loss modes are audited separately (JobStats.shuffle_overflow vs
    join_overflow).

    With ``return_pre=True`` the result tuple gains a second element:
    the left exchange's shipped h1 lane repeated onto the join output's
    row layout (output row ``i*expansion+k`` is left row ``i``), or
    None when the left exchange was skipped.  A downstream
    co-partitioned GROUPBY on the same key columns can seed its
    hash-segmented reduce from that lane instead of re-hashing string
    keys over the inflated receive capacity (DESIGN.md §14)."""
    n_shards = mesh.shape[axis]
    if not co_left:
        left = pad_to_multiple(left, n_shards)
    if not co_right:
        right = pad_to_multiple(right, n_shards)
    lnames, rnames = left.names, right.names
    lbucket = _bucket_size(left.capacity // n_shards, n_shards, skew_factor)
    rbucket = _bucket_size(right.capacity // n_shards, n_shards, skew_factor)

    def body(*flat):
        nl = len(lnames) + 1
        llocal = _as_local(lnames, flat[:nl])
        rlocal = _as_local(rnames, flat[nl:])
        if co_left:
            lrecv, lpre, lovf = llocal, None, jnp.zeros((), jnp.int32)
        else:
            lrecv, lpre, lovf = _exchange(llocal, lkeys, n_shards,
                                          lbucket, axis)
        if co_right:
            rrecv, rpre, rovf = rlocal, None, jnp.zeros((), jnp.int32)
        else:
            rrecv, rpre, rovf = _exchange(rlocal, rkeys, n_shards,
                                          rbucket, axis)
        joined, jovf = op_join(lrecv, rrecv, lkeys, rkeys, expansion,
                               pre_left=lpre, pre_right=rpre)
        out = _table_args(joined)
        if return_pre and not co_left:
            out = out + (jnp.repeat(lpre[0], expansion),)
        return out + (lovf + rovf, jax.lax.psum(jovf, axis))

    # the SEQUENTIAL rename rule shared with op_join/plan props: a
    # right-side name colliding with an already-renamed "_r" column
    # chains to "_r_r" — a set comprehension would collapse it and
    # desynchronize out_specs from the body's returned columns
    out_names = list(_join_out_names(lnames, rnames))
    in_specs = _table_specs(left, axis) + _table_specs(right, axis)
    n_lane = 1 if return_pre and not co_left else 0
    out_specs = (tuple(P(axis) for _ in out_names)
                 + (P(axis),) * (1 + n_lane) + (P(), P()))
    flat = shard_map(body, mesh, in_specs, out_specs)(
        *(_table_args(left) + _table_args(right)))
    nc = len(out_names)
    table = Table(dict(zip(out_names, flat[:nc])), flat[nc])
    if not return_pre:
        return table, flat[-2], flat[-1]
    lane = flat[nc + 1] if n_lane else None
    return table, lane, flat[-2], flat[-1]


def distributed_cogroup(a: Table, b: Table, keys_l, keys_r,
                        aggs_l, aggs_r, mesh, axis: str = "data",
                        skew_factor: float = 4.0,
                        co_partitioned: bool = False,
                        lossless: bool = False):
    """COGROUP: both inputs are aligned onto the shared (k0..kn, va_*,
    vb_*) schema on the map side, exchanged on the unified keys, then
    unioned + grouped locally per shard.  The union happens INSIDE the
    shard body: concatenating the global tables first would interleave
    the two inputs' partition blocks and break co-location."""
    n_shards = mesh.shape[axis]
    ta, tb, keys, aggs = _cogroup_prepare(a, b, keys_l, keys_r,
                                          aggs_l, aggs_r)
    if not co_partitioned:
        ta = pad_to_multiple(ta, n_shards)
        tb = pad_to_multiple(tb, n_shards)
    anames, bnames = ta.names, tb.names
    abucket = _bucket_size(ta.capacity // n_shards, n_shards, skew_factor)
    bbucket = _bucket_size(tb.capacity // n_shards, n_shards, skew_factor)

    def body(*flat):
        na = len(anames) + 1
        aloc = _as_local(anames, flat[:na])
        bloc = _as_local(bnames, flat[na:])
        if co_partitioned:
            arecv, brecv, pre = aloc, bloc, None
            overflow = jnp.zeros((), jnp.int32)
        else:
            arecv, apre, aovf = _exchange(aloc, keys, n_shards,
                                          abucket, axis)
            brecv, bpre, bovf = _exchange(bloc, keys, n_shards,
                                          bbucket, axis)
            overflow = aovf + bovf
            pre = tuple(jnp.concatenate([x, y])
                        for x, y in zip(apre, bpre))
        cols = {n: jnp.concatenate([arecv.col(n), brecv.col(n)])
                for n in arecv.names}
        both = Table(cols, jnp.concatenate([arecv.valid, brecv.valid]))
        if lossless:
            grouped = op_groupby(both, keys, aggs, pre=pre)
        else:
            grouped, coll = op_groupby_hashed(both, keys, aggs, pre=pre)
            overflow = overflow + jax.lax.psum(coll, axis)
        return _table_args(grouped) + (overflow,)

    out_names = sorted(set(list(keys) + list(aggs)))
    in_specs = _table_specs(ta, axis) + _table_specs(tb, axis)
    out_specs = tuple(P(axis) for _ in out_names) + (P(axis), P())
    flat = shard_map(body, mesh, in_specs, out_specs)(
        *(_table_args(ta) + _table_args(tb)))
    grouped = Table(dict(zip(out_names, flat[:-2])), flat[-2])
    return _cogroup_rename(grouped, keys_l), flat[-1]
