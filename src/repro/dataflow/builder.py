"""Pig-style fluent dataflow builder (DESIGN.md §16).

ReStore's user interface in the paper is Pig Latin: scripts are chains
of LOAD / FILTER / FOREACH / GROUP / JOIN / STORE statements that the
Pig compiler lowers to MapReduce plans.  This module is that front-end
for our engine — a small immutable builder whose methods mirror Pig
statements and whose ``build()`` lowers to the existing
:class:`~repro.core.plan.PhysicalPlan`:

    plan = (Dataflow.load("page_views")
            .filter(col("timespent") > 10)
            .group_by("user", views=("count", "user"))
            .store("out")
            .build())

Every method delegates to the ``core.plan`` free-function constructors,
so the compiled operators carry *identical* params — and therefore
identical Merkle fingerprints — to hand-built plans.  That identity is
load-bearing: fingerprints are the reuse currency (repository keys,
singleflight keys, MQO sharing keys), so the front-end must be a pure
notation change.  ``tests/test_builder.py`` pins this with a
fingerprint-equality sweep over all PigMix templates plus random
programs.

Builders are immutable: each method returns a *new* ``Dataflow``
wrapping a new operator DAG node, so intermediate flows can be reused
to express DAG fan-out naturally::

    scan = Dataflow.load("synth").filter(col("f0") > 3)
    a = scan.group_by("f1", n=("count", "f1")).store("a")
    b = scan.distinct().store("b")

``as_plan`` is the coercion point the unified submission surface
(``ReStore.run`` / ``ReStoreService.submit`` / ``submit_batch``) funnels
through: it accepts a ``Dataflow`` or a ``PhysicalPlan`` and always
hands back a plan.
"""
from __future__ import annotations

from typing import Dict, List, Tuple, Union

from ..core import plan as P
from ..core.plan import Operator, PhysicalPlan
from .expr import AGG_FNS, Col, Expr

__all__ = ["Dataflow", "col", "as_plan"]


def col(name: str) -> Col:
    """Column reference for builder predicates / generators:
    ``col("timespent") > 10`` builds the same ``Expr`` tree as
    ``Col("timespent") > Const(10)``."""
    return Col(name)


def _keys(keys) -> List[str]:
    """Normalize a key spec: a bare column name or a sequence of them."""
    if isinstance(keys, str):
        return [keys]
    return list(keys)


def _check_aggs(aggs: Dict[str, Tuple[str, str]], where: str) -> None:
    for out, spec in aggs.items():
        if (not isinstance(spec, tuple)) or len(spec) != 2:
            raise TypeError(
                f"{where}: agg {out!r} must be a (fn, column) tuple, "
                f"got {spec!r}")
        fn, c = spec
        if fn not in AGG_FNS:
            raise ValueError(
                f"{where}: unknown agg fn {fn!r} for {out!r} "
                f"(expected one of {AGG_FNS})")
        if not isinstance(c, str):
            raise TypeError(
                f"{where}: agg {out!r} column must be a str, got {c!r}")


class Dataflow:
    """One relation in a Pig-style script, wrapping the operator that
    produces it.  Immutable — every method returns a new ``Dataflow``."""

    __slots__ = ("_op",)

    def __init__(self, op: Operator):
        self._op = op

    # -- source -----------------------------------------------------------

    @classmethod
    def load(cls, dataset: str, version: int = 0, capacity: int = None,
             schema=None) -> "Dataflow":
        return cls(P.load(dataset, version=version, capacity=capacity,
                          schema=schema))

    # -- per-row (map-side) statements ------------------------------------

    def filter(self, pred: Expr) -> "Dataflow":
        if not isinstance(pred, Expr):
            raise TypeError(f"filter() wants an Expr predicate, built "
                            f"e.g. from col(...); got {pred!r}")
        return Dataflow(P.filter_(self._op, pred))

    def project(self, *cols: str) -> "Dataflow":
        if len(cols) == 1 and not isinstance(cols[0], str):
            cols = tuple(cols[0])        # .project(["a", "b"]) also works
        return Dataflow(P.project(self._op, cols))

    def foreach(self, **gens: Expr) -> "Dataflow":
        """Pig's FOREACH ... GENERATE: keyword args name the generated
        columns, values are expressions over input columns."""
        out = {}
        for name, g in gens.items():
            out[name] = Col(g) if isinstance(g, str) else g
        return Dataflow(P.foreach(self._op, out))

    # -- blocking statements ----------------------------------------------

    def group_by(self, *keys, **aggs: Tuple[str, str]) -> "Dataflow":
        """Pig's GROUP ... + FOREACH GENERATE agg(...): positional args
        are the grouping keys, keyword args map output column ->
        ``(fn, column)`` with fn in ``AGG_FNS``."""
        if len(keys) == 1 and not isinstance(keys[0], str):
            keys = tuple(keys[0])
        _check_aggs(aggs, "group_by")
        return Dataflow(P.groupby(self._op, keys, aggs))

    def join(self, other: "Dataflow", on=None, *, left_on=None,
             right_on=None, expansion: int = 1) -> "Dataflow":
        """Pig's JOIN a BY k, b BY k2: either ``on=`` (same key names on
        both sides) or ``left_on=`` / ``right_on=``."""
        if on is not None:
            if left_on is not None or right_on is not None:
                raise TypeError("join(): pass either on= or "
                                "left_on=/right_on=, not both")
            left_on = right_on = on
        if left_on is None or right_on is None:
            raise TypeError("join(): key columns required "
                            "(on= or left_on=/right_on=)")
        return Dataflow(P.join(self._op, _as_op(other), _keys(left_on),
                               _keys(right_on), expansion=expansion))

    def cogroup(self, other: "Dataflow", *, on=None, left_on=None,
                right_on=None, left_aggs: Dict[str, Tuple[str, str]],
                right_aggs: Dict[str, Tuple[str, str]]) -> "Dataflow":
        if on is not None:
            left_on = right_on = on
        if left_on is None or right_on is None:
            raise TypeError("cogroup(): key columns required "
                            "(on= or left_on=/right_on=)")
        _check_aggs(left_aggs, "cogroup")
        _check_aggs(right_aggs, "cogroup")
        return Dataflow(P.cogroup(self._op, _as_op(other), _keys(left_on),
                                  _keys(right_on), left_aggs, right_aggs))

    def distinct(self) -> "Dataflow":
        return Dataflow(P.distinct(self._op))

    def union(self, other: "Dataflow") -> "Dataflow":
        return Dataflow(P.union(self._op, _as_op(other)))

    # -- sink / lowering --------------------------------------------------

    def store(self, name: str) -> "Dataflow":
        return Dataflow(P.store(self._op, name))

    def build(self, *sibling_sinks: "Dataflow") -> PhysicalPlan:
        """Lower to a ``PhysicalPlan``.  The flow must end in ``store``;
        extra stored flows may be passed to build a multi-sink plan."""
        sinks = []
        for flow in (self,) + sibling_sinks:
            op = _as_op(flow)
            if op.kind != "STORE":
                raise ValueError(
                    "build(): call .store(name) before .build() "
                    f"(flow ends in {op.kind})")
            sinks.append(op)
        return PhysicalPlan(sinks)

    # -- introspection ----------------------------------------------------

    @property
    def op(self) -> Operator:
        """The underlying operator (escape hatch to core.plan wiring)."""
        return self._op

    def __repr__(self) -> str:
        return f"Dataflow<{self._op.kind}>"


def _as_op(flow: Union[Dataflow, Operator]) -> Operator:
    if isinstance(flow, Dataflow):
        return flow._op
    if isinstance(flow, Operator):
        return flow
    raise TypeError(f"expected a Dataflow (or Operator), got {flow!r}")


def as_plan(query: Union[Dataflow, PhysicalPlan]) -> PhysicalPlan:
    """Coerce the unified submission surface's input to a plan: accepts
    a ``PhysicalPlan`` (passed through) or a stored ``Dataflow``
    (lowered via ``build()``)."""
    if isinstance(query, PhysicalPlan):
        return query
    if isinstance(query, Dataflow):
        return query.build()
    raise TypeError(
        f"expected a PhysicalPlan or dataflow builder, got {type(query)!r}")
