"""Execution of physical plans over Tables, in pure JAX.

All operators are static-shape: capacities are compile-time, deletion is
masking.  The blocking operators (JOIN / GROUPBY / COGROUP / DISTINCT) are
implemented sort-based — the TPU-native replacement for Hadoop's
sort-shuffle and for GPU shared-memory hash tables (see DESIGN.md §7).

Hash-collision handling: rows are ordered by a (h1, h2) pair of
independent uint32 hashes, but *all* equality decisions (segment
boundaries, join-match verification) compare the actual key columns, so
grouping/distinct are exact and joins are exact up to a bounded probe
window whose overflows are counted in job stats.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from ..core.plan import Operator, PhysicalPlan
from .table import Table, cols_equal, hash_columns

_U32_MAX = jnp.uint32(0xFFFFFFFF)

# Pallas kernel integration for the relational hot spots (join probe,
# segment aggregation).  interpret=True executes the kernel bodies in
# Python — correct everywhere, fast only on real TPUs — so the switch is
# explicit rather than automatic.
_USE_PALLAS = False


def set_use_pallas(v: bool) -> None:
    global _USE_PALLAS
    _USE_PALLAS = v


def use_pallas() -> bool:
    return _USE_PALLAS or jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Sorting & segments shared by GROUPBY / DISTINCT / COGROUP


class HashCache:
    """Per-plan-execution memo of raw key-column hashes.

    GROUPBY / DISTINCT / COGROUP / JOIN all hash the same (table, keys)
    pairs — often the *same* columns, e.g. a SPLIT fan-out feeding a
    GROUPBY and a JOIN on one key.  Keyed by the identity of the column
    arrays (in sorted-name order, which is what ``hash_columns`` mixes
    over), so a FILTER that only rewrites ``valid`` still shares the
    hashes of its input.  Validity masking happens at the use site."""

    def __init__(self):
        # value holds the column objects alongside the hash: the memo
        # key uses id()s, which are only stable while the arrays stay
        # referenced (a GC'd temporary's recycled id must never hit)
        self._memo: Dict[Tuple, Tuple[Tuple, jnp.ndarray]] = {}

    def hashes(self, t: Table, keys, seed: int) -> jnp.ndarray:
        cols = tuple(t.col(n) for n in sorted(keys))
        key = (tuple(id(c) for c in cols), seed)
        ent = self._memo.get(key)
        if ent is None:
            ent = (cols, hash_columns(t, keys, seed=seed))
            self._memo[key] = ent
        return ent[1]


def _key_hashes(t: Table, keys, seed: int,
                hc: "HashCache | None") -> jnp.ndarray:
    if hc is None:
        return hash_columns(t, keys, seed=seed)
    return hc.hashes(t, keys, seed)


def _pad1(a: jnp.ndarray, pad: int, value) -> jnp.ndarray:
    if pad == 0:
        return a
    return jnp.concatenate([a, jnp.full((pad,), value, a.dtype)])


def _sort_by_keys(t: Table, keys,
                  hc: "HashCache | None" = None,
                  pre=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (order, new_seg): stable order by (h1, h2) with invalid rows
    last, and exact segment-start mask in sorted order.  ``pre`` is an
    optional (h1, h2) pair of UNMASKED key hashes computed upstream —
    the lanes a distributed exchange ships with each row (DESIGN.md
    §14) — substituting for re-hashing the key columns here.  Validity
    masking still happens at this use site, so zero-filled rows from
    unhit exchange slots are parked with the invalid rows either way."""
    if pre is not None:
        h1u = pre[0]
        h2u = pre[1] if len(pre) > 1 else _key_hashes(t, keys, 101, hc)
    else:
        h1u = _key_hashes(t, keys, 0, hc)
        h2u = _key_hashes(t, keys, 101, hc)
    h1 = jnp.where(t.valid, h1u, _U32_MAX)
    h2 = jnp.where(t.valid, h2u, _U32_MAX)
    order = jnp.lexsort((h2, h1))
    sv = jnp.take(t.valid, order)
    prev = jnp.roll(order, 1)
    same_as_prev = cols_equal(t, order, t, prev, keys)
    same_as_prev = same_as_prev & jnp.take(t.valid, prev)
    same_as_prev = same_as_prev.at[0].set(False)
    new_seg = sv & ~same_as_prev
    return order, new_seg


def _segment_aggregate(t: Table, keys, aggs, order, new_seg) -> Table:
    cap = t.capacity
    sv = jnp.take(t.valid, order)
    seg_id = jnp.cumsum(new_seg.astype(jnp.int32)) - 1
    seg_id = jnp.where(sv, seg_id, cap - 1)  # park invalid in last bucket
    n_seg = jnp.sum(new_seg.astype(jnp.int32))
    out_valid = jnp.arange(cap) < n_seg

    # representative row per segment (for key columns)
    rep = jnp.zeros(cap, dtype=jnp.int32)
    rep = rep.at[jnp.where(new_seg, seg_id, cap - 1)].set(
        order.astype(jnp.int32), mode="drop")

    cols: Dict[str, jnp.ndarray] = {}
    for k in keys:
        kc = jnp.take(t.col(k), rep, axis=0)
        cols[k] = jnp.where(
            out_valid.reshape((-1,) + (1,) * (kc.ndim - 1)), kc,
            jnp.zeros_like(kc))

    def _segsum(v):
        if use_pallas():
            from ..kernels.segment_reduce.ops import segment_sum
            # pad rows to the tile multiple instead of bailing to the
            # dense fallback: padded rows carry value 0 and the
            # out-of-range segment id `cap`, so the kernel drops them
            pad = (-cap) % min(256, cap)
            return segment_sum(_pad1(v, pad, 0)[:, None],
                               _pad1(seg_id, pad, cap),
                               num_segments=cap, impl="pallas",
                               interpret=jax.default_backend() != "tpu"
                               )[:, 0]
        return jax.ops.segment_sum(v, seg_id, num_segments=cap)

    ones = sv.astype(jnp.float32)
    counts = _segsum(ones)
    for out_name, (fn, cname) in aggs.items():
        if fn == "count":
            cols[out_name] = counts.astype(jnp.float32)
            continue
        v = jnp.take(t.col(cname), order, axis=0).astype(jnp.float32)
        v = jnp.where(sv, v, 0.0)
        if fn in ("sum", "mean"):
            s = _segsum(v)
            cols[out_name] = s if fn == "sum" else s / jnp.maximum(counts, 1.0)
        elif fn == "min":
            v = jnp.where(sv, v, jnp.inf)
            cols[out_name] = jax.ops.segment_min(v, seg_id, num_segments=cap)
        elif fn == "max":
            v = jnp.where(sv, v, -jnp.inf)
            cols[out_name] = jax.ops.segment_max(v, seg_id, num_segments=cap)
        else:
            raise ValueError(f"unknown aggregate {fn}")
        cols[out_name] = jnp.where(out_valid, cols[out_name], 0.0)
    return Table(cols, out_valid)


# ---------------------------------------------------------------------------
# Sort-free hash-segmented reduce (distributed path, DESIGN.md §14)
#
# XLA CPU argsort costs ~6x a plain value sort at 64k rows, and the
# lexsort in _sort_by_keys dominates every blocking operator.  The
# distributed reduce does not need a row ORDER, only segment ids: sort
# the h1 VALUES (cheap), then each row's segment is the first sorted
# position of its hash.  Exactness: every row's actual key columns are
# verified against its segment representative; any mismatch (two
# distinct keys sharing an h1) is COUNTED, and the engine reruns the
# job on the lossless sort-based path — the same contract as the
# exchange's bounded buckets and the join's probe window.
#
# Bit-identity with the single-device sort path: within a group all
# rows share (h1, h2), so the stable lexsort keeps them in row-index
# order — exactly the order segment_sum accumulates them here; group
# representatives are the minimum-index row on both paths.


def _hash_segments(t: Table, keys, h1u):
    """Return (pos, out_valid, rep, collisions): per-row segment id
    (the first sorted position of the row's masked h1, invalid rows
    parked at cap-1), validity of each output slot (first-occurrence
    positions among valid rows), the minimum-index representative row
    per segment, and the count of valid rows whose keys mismatch their
    representative (h1 collisions between distinct keys)."""
    cap = t.capacity
    h1m = jnp.where(t.valid, h1u, _U32_MAX)
    s = jnp.sort(h1m)
    pos = jnp.searchsorted(s, h1m, side="left").astype(jnp.int32)
    # invalid rows park at cap-1; a valid row's first-occurrence
    # position is always < n_valid <= cap-1 when any invalid row
    # exists, so parking never mixes with a real segment
    pos = jnp.where(t.valid, pos, cap - 1)
    iota = jnp.arange(cap, dtype=jnp.int32)
    n_valid = jnp.sum(t.valid.astype(jnp.int32))
    new = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    out_valid = new & (iota < n_valid)
    rep = jax.ops.segment_min(jnp.where(t.valid, iota, cap), pos,
                              num_segments=cap)
    rep = jnp.clip(rep, 0, cap - 1).astype(jnp.int32)
    eq = cols_equal(t, iota, t, jnp.take(rep, pos), keys)
    collisions = jnp.sum((t.valid & ~eq).astype(jnp.int32))
    return pos, out_valid, rep, collisions


def op_groupby_hashed(t: Table, keys, aggs, hc: "HashCache | None" = None,
                      pre=None) -> Tuple[Table, jnp.ndarray]:
    """Sort-free GROUPBY for the distributed reduce.  Returns (table,
    collision count); a nonzero count means the result dropped/merged
    groups and the caller must fall back to the sort-based path."""
    h1u = pre[0] if pre is not None else _key_hashes(t, keys, 0, hc)
    pos, out_valid, rep, collisions = _hash_segments(t, keys, h1u)
    cap = t.capacity
    sv = t.valid

    cols: Dict[str, jnp.ndarray] = {}
    for k in keys:
        kc = jnp.take(t.col(k), rep, axis=0)
        cols[k] = jnp.where(
            out_valid.reshape((-1,) + (1,) * (kc.ndim - 1)), kc,
            jnp.zeros_like(kc))

    # one batched (N, k) scatter-add covers the count column and every
    # sum/mean aggregate: segment reduction is row-bound scatter traffic
    # (~6 ms per pass at 128k rows on host XLA), so lanes ride together
    need_counts = any(fn in ("count", "mean") for fn, _ in aggs.values())
    lanes, lane_names = [], []
    if need_counts:
        lanes.append(sv.astype(jnp.float32))
        lane_names.append(None)
    for out_name, (fn, cname) in aggs.items():
        if fn in ("sum", "mean"):
            lanes.append(jnp.where(sv, t.col(cname).astype(jnp.float32),
                                   0.0))
            lane_names.append(out_name)
    if lanes:
        summed = jax.ops.segment_sum(jnp.stack(lanes, axis=1), pos,
                                     num_segments=cap)
        by_lane = {n: summed[:, i] for i, n in enumerate(lane_names)}
        counts = by_lane.get(None)

    for out_name, (fn, cname) in aggs.items():
        if fn == "count":
            cols[out_name] = counts.astype(jnp.float32)
            continue
        if fn in ("sum", "mean"):
            s = by_lane[out_name]
            cols[out_name] = s if fn == "sum" else s / jnp.maximum(counts,
                                                                   1.0)
        elif fn == "min":
            v = jnp.where(sv, t.col(cname).astype(jnp.float32), jnp.inf)
            cols[out_name] = jax.ops.segment_min(v, pos, num_segments=cap)
        elif fn == "max":
            v = jnp.where(sv, t.col(cname).astype(jnp.float32), -jnp.inf)
            cols[out_name] = jax.ops.segment_max(v, pos, num_segments=cap)
        else:
            raise ValueError(f"unknown aggregate {fn}")
        cols[out_name] = jnp.where(out_valid, cols[out_name], 0.0)
    return Table(cols, out_valid), collisions


def op_distinct_hashed(t: Table, hc: "HashCache | None" = None,
                       pre=None) -> Tuple[Table, jnp.ndarray]:
    """Sort-free DISTINCT: keep each segment's minimum-index row in
    place (no reorder).  Returns (table, collision count)."""
    keys = t.names
    h1u = pre[0] if pre is not None else _key_hashes(t, keys, 0, hc)
    pos, out_valid, rep, collisions = _hash_segments(t, keys, h1u)
    keep = t.valid & (jnp.take(rep, pos)
                      == jnp.arange(t.capacity, dtype=jnp.int32))
    return t.with_valid(keep), collisions


# ---------------------------------------------------------------------------
# Operator implementations


def op_filter(t: Table, pred) -> Table:
    p = pred.eval(t)
    return t.with_valid(t.valid & p.astype(bool))


def op_project(t: Table, cols) -> Table:
    return t.select(cols)


def op_foreach(t: Table, gens) -> Table:
    out = {}
    for name, e in gens.items():
        v = e.eval(t)
        if v.ndim == 0:
            v = jnp.broadcast_to(v, (t.capacity,))
        out[name] = v
    return Table(out, t.valid)


def op_groupby(t: Table, keys, aggs, hc: "HashCache | None" = None,
               pre=None) -> Table:
    order, new_seg = _sort_by_keys(t, keys, hc, pre=pre)
    return _segment_aggregate(t, keys, aggs, order, new_seg)


def op_distinct(t: Table, hc: "HashCache | None" = None,
                pre=None) -> Table:
    keys = t.names
    order, new_seg = _sort_by_keys(t, keys, hc, pre=pre)
    return t.gather(order, new_seg)


def op_union(a: Table, b: Table) -> Table:
    names = a.names
    assert set(names) == set(b.columns), "UNION schema mismatch"
    cols = {n: jnp.concatenate([a.col(n), b.col(n)], axis=0) for n in names}
    return Table(cols, jnp.concatenate([a.valid, b.valid]))


def op_join(left: Table, right: Table, lkeys, rkeys,
            expansion: int = 1,
            hc: "HashCache | None" = None,
            pre_left=None, pre_right=None) -> Tuple[Table, jnp.ndarray]:
    """Inner equi-join, sort+probe based.  Output capacity =
    left.capacity * expansion.  ``pre_left``/``pre_right`` optionally
    carry each side's exchange-shipped (h1,) probe-hash lane in place
    of re-hashing the key columns (DESIGN.md §14); every match is still
    verified against the actual key columns, and validity masks every
    decision, so shipped hashes change nothing observable.
    Returns (table, overflow_count)."""
    from ..kernels import autotune
    # window slack absorbs h1 ties among distinct right keys; every
    # exhausted window is counted in the returned overflow, so a tuned
    # narrower window stays auditable (the tuner rejects candidates
    # whose measurement reports overflow)
    probe_w = expansion + autotune.choose("join_probe", left.capacity,
                                          "uint32", "slack", 4)
    cap_r = right.capacity

    h_r_raw = (pre_right[0] if pre_right is not None
               else _key_hashes(right, rkeys, 0, hc))
    h_r = jnp.where(right.valid, h_r_raw, _U32_MAX)
    r_order = jnp.argsort(h_r, stable=True)
    h_r_sorted = jnp.take(h_r, r_order)

    h_l = (pre_left[0] if pre_left is not None
           else _key_hashes(left, lkeys, 0, hc))
    if use_pallas():
        from ..kernels.hash_join.ops import probe
        # pad probe lanes to the tile multiple (extra lanes are sliced
        # off) so the kernel path covers every capacity
        n = h_l.shape[0]
        pad = (-n) % min(256, n)
        pos = probe(_pad1(h_l, pad, 0), h_r_sorted, impl="pallas",
                    tile_n=256,
                    interpret=jax.default_backend() != "tpu")[:n]
    else:
        pos = jnp.searchsorted(h_r_sorted, h_l, side="left")
    cand = jnp.clip(pos[:, None] + jnp.arange(probe_w)[None, :], 0, cap_r - 1)
    cand_rows = jnp.take(r_order, cand)  # (Cl, W) right row ids
    hash_ok = jnp.take(h_r_sorted, cand) == h_l[:, None]

    # exact key verification
    eq = jnp.ones(cand_rows.shape, dtype=bool)
    for lk, rk in zip(lkeys, rkeys):
        lc = left.col(lk)
        rc = jnp.take(right.col(rk), cand_rows, axis=0)
        e = lc[:, None] == rc if lc.ndim == 1 else \
            (lc[:, None, :] == rc).all(axis=-1)
        eq = eq & e
    ok = (hash_ok & eq & jnp.take(right.valid, cand_rows)
          & left.valid[:, None])

    rank = jnp.cumsum(ok.astype(jnp.int32), axis=1) - 1
    # overflow: window exhausted while hashes were still equal.  Only a
    # tail INSIDE the array can witness that — when pos + probe_w runs
    # past the end, the window already covers every remaining row, and
    # the old clip-to-last-row check false-flagged any left key whose
    # hash sorted within probe_w of the array end.
    in_range = pos + probe_w <= cap_r - 1
    tail = jnp.clip(pos + probe_w, 0, cap_r - 1)
    overflow = jnp.sum(((jnp.take(h_r_sorted, tail) == h_l)
                        & in_range & left.valid).astype(jnp.int32))

    out_cols: Dict[str, jnp.ndarray] = {}
    matched_list: List[jnp.ndarray] = []
    ridx_list: List[jnp.ndarray] = []
    for j in range(expansion):
        sel = ok & (rank == j)
        matched_list.append(sel.any(axis=1))
        # per-row gather of the selected window slot.  Must be
        # take_along_axis: jnp.take(..., axis=1) with a (Cl, 1) index
        # array both materializes a (Cl, Cl) gather (XLA CPU: ~800x
        # slower at 64k rows) and — worse — indexes every row by row
        # 0's argmax, silently joining the wrong right row whenever a
        # probe window's first match sits past slot 0 (h1 ties,
        # duplicate right keys under expansion > 1).
        ridx_list.append(jnp.take_along_axis(
            cand_rows, jnp.argmax(sel, axis=1)[:, None], axis=1)[:, 0])
    matched = jnp.stack(matched_list, 1).reshape(-1)      # (Cl*exp,)
    ridx = jnp.stack(ridx_list, 1).reshape(-1)

    for n in left.names:
        c = jnp.repeat(left.col(n), expansion, axis=0)
        out_cols[n] = c
    for n in right.names:
        name = n if n not in out_cols else n + "_r"
        out_cols[name] = jnp.take(right.col(n), ridx, axis=0)
    return Table(out_cols, matched), overflow


def _cogroup_prepare(a: Table, b: Table, keys_l, keys_r, aggs_l, aggs_r):
    """Map-side alignment of both COGROUP inputs onto one shared schema
    (``k0..kn`` unified keys, ``va_*``/``vb_*`` value carriers): after
    this, COGROUP is UNION + GROUPBY.  The other side's carrier rows are
    the aggregate's neutral element (0 for sums, NaN-masked otherwise).
    Shared with the distributed path, which exchanges the two prepared
    tables separately and unions them per shard (DESIGN.md §11)."""
    a_cols = {f"k{i}": a.col(k) for i, k in enumerate(keys_l)}
    b_cols = {f"k{i}": b.col(k) for i, k in enumerate(keys_r)}
    aggs = {}
    for out, (fn, c) in aggs_l.items():
        fn2 = "sum" if fn == "count" else fn
        a_cols[f"va_{out}"] = (a.col(c).astype(jnp.float32)
                               if fn != "count" else jnp.ones(a.capacity))
        b_cols[f"va_{out}"] = jnp.full(
            (b.capacity,), 0.0 if fn2 == "sum" else jnp.nan, jnp.float32)
        aggs[f"l_{out}"] = (fn2, f"va_{out}")
    for out, (fn, c) in aggs_r.items():
        fn2 = "sum" if fn == "count" else fn
        b_cols[f"vb_{out}"] = (b.col(c).astype(jnp.float32)
                               if fn != "count" else jnp.ones(b.capacity))
        a_cols[f"vb_{out}"] = jnp.full(
            (a.capacity,), 0.0 if fn2 == "sum" else jnp.nan, jnp.float32)
        aggs[f"r_{out}"] = (fn2, f"vb_{out}")
    keys = [f"k{i}" for i in range(len(keys_l))]
    return Table(a_cols, a.valid), Table(b_cols, b.valid), keys, aggs


def _cogroup_rename(grouped: Table, keys_l) -> Table:
    """Restore the left input's key names on the grouped result."""
    renamed = {}
    for i, k in enumerate(keys_l):
        renamed[k] = grouped.col(f"k{i}")
    for n in grouped.names:
        if not n.startswith("k"):
            renamed[n] = grouped.col(n)
    return Table(renamed, grouped.valid)


def op_cogroup(a: Table, b: Table, keys_l, keys_r, aggs_l, aggs_r,
               hc: "HashCache | None" = None) -> Table:
    """Group both inputs by key; per-key aggregates from each side."""
    ta, tb, keys, aggs = _cogroup_prepare(a, b, keys_l, keys_r,
                                          aggs_l, aggs_r)
    grouped = op_groupby(op_union(ta, tb), keys, aggs, hc)
    return _cogroup_rename(grouped, keys_l)


def op_store(t: Table) -> Table:
    # no in-graph work: compaction/truncation to the live row count
    # happens host-side on the store's write-behind path (DESIGN.md §3),
    # keeping sorts/gathers off the timed critical path of every job
    return t


# ---------------------------------------------------------------------------
# Plan evaluation


def execute_plan(plan: PhysicalPlan, datasets: Dict[str, Table],
                 mesh=None, shuffle_axis: str = "data",
                 skew_factor: float = 4.0, props=None,
                 lossless: bool = False):
    """Evaluate a physical plan.  Returns (outputs, stats):
    outputs: store-name -> output Table (uncompacted; the artifact
    store compacts host-side on its write path);
    stats: op uid -> dict of traced scalars (rows_out, join_overflow,
    shuffle_overflow).

    With a ``mesh``, the blocking operators run through the shard_map
    map->shuffle->reduce path of ``dataflow/shuffle.py`` across the
    ``shuffle_axis`` devices; ``props`` (a ``core.plan.PlanProps``, same
    plan object) marks which exchanges are skipped because the input is
    already co-partitioned (DESIGN.md §11).  ``lossless=True`` is the
    engine's overflow-retry configuration: callers pair it with
    ``skew_factor >= n_shards`` (lossless buckets) and it selects the
    collision-proof sort-based reduce over the hash-segmented one."""
    values: Dict[int, Table] = {}
    outputs: Dict[str, Table] = {}
    stats: Dict[int, Dict[str, jnp.ndarray]] = {}
    # table id -> (key column names, row-aligned h1 lane): shipped hash
    # lanes that survive an op (a join's left exchange) and can seed a
    # downstream co-partitioned GROUPBY's reduce (DESIGN.md §14)
    pres: Dict[int, Tuple[Tuple[str, ...], jnp.ndarray]] = {}
    # (h1, h2) key hashes are computed once per (columns, seed) within
    # this plan execution and shared across GROUPBY/DISTINCT/COGROUP/JOIN
    hc = HashCache()
    if mesh is not None:
        from .shuffle import (distributed_cogroup, distributed_distinct,
                              distributed_groupby, distributed_join)
        n_shards = int(mesh.shape[shuffle_axis])
    skips = props.skip if props is not None else {}

    def _skip(op, i: int, table: Table) -> bool:
        flags = skips.get(id(op), ())
        if not (i < len(flags) and flags[i]):
            return False
        if table.capacity % n_shards != 0:
            # a partitioned value is always laid out in n_shards equal
            # blocks; silently falling back to an exchange here would
            # leave downstream partitioning claims wrong — fail loud
            raise ValueError(
                f"co-partitioned input of {op.kind}#{op.uid} has capacity "
                f"{table.capacity} not divisible by {n_shards} shards")
        return True

    for op in plan.topo():
        p = op.params
        ins = [values[id(i)] for i in op.inputs]
        extra: Dict[str, jnp.ndarray] = {}
        if op.kind == "LOAD":
            v = datasets[p["dataset"]]
        elif op.kind == "FILTER":
            v = op_filter(ins[0], p["pred"])
        elif op.kind == "PROJECT":
            v = op_project(ins[0], p["cols"])
        elif op.kind == "FOREACH":
            v = op_foreach(ins[0], p["gens"])
        elif op.kind == "JOIN":
            if mesh is not None:
                v, jpre, sh_ovf, ovf = distributed_join(
                    ins[0], ins[1], p["left_keys"], p["right_keys"], mesh,
                    axis=shuffle_axis, expansion=p.get("expansion", 1),
                    skew_factor=skew_factor,
                    co_left=_skip(op, 0, ins[0]),
                    co_right=_skip(op, 1, ins[1]),
                    return_pre=True)
                if jpre is not None:
                    # left-side names survive the join rename rule
                    # unchanged, so the lane keys are the left keys
                    pres[id(v)] = (tuple(p["left_keys"]), jpre)
                extra["shuffle_overflow"] = sh_ovf
            else:
                v, ovf = op_join(ins[0], ins[1], p["left_keys"],
                                 p["right_keys"], p.get("expansion", 1), hc)
            extra["join_overflow"] = ovf
        elif op.kind == "GROUPBY":
            if mesh is not None:
                entry = pres.get(id(ins[0]))
                lane = (entry[1] if entry is not None
                        and entry[0] == tuple(p["keys"]) else None)
                v, ovf = distributed_groupby(
                    ins[0], p["keys"], p["aggs"], mesh, axis=shuffle_axis,
                    skew_factor=skew_factor,
                    co_partitioned=_skip(op, 0, ins[0]),
                    lossless=lossless, pre_lane=lane)
                extra["shuffle_overflow"] = ovf
            else:
                v = op_groupby(ins[0], p["keys"], p["aggs"], hc)
        elif op.kind == "COGROUP":
            if mesh is not None:
                co = _skip(op, 0, ins[0]) and _skip(op, 1, ins[1])
                v, ovf = distributed_cogroup(
                    ins[0], ins[1], p["keys_left"], p["keys_right"],
                    p["aggs_left"], p["aggs_right"], mesh,
                    axis=shuffle_axis, skew_factor=skew_factor,
                    co_partitioned=co, lossless=lossless)
                extra["shuffle_overflow"] = ovf
            else:
                v = op_cogroup(ins[0], ins[1], p["keys_left"],
                               p["keys_right"], p["aggs_left"],
                               p["aggs_right"], hc)
        elif op.kind == "DISTINCT":
            if mesh is not None:
                v, ovf = distributed_distinct(
                    ins[0], mesh, axis=shuffle_axis,
                    skew_factor=skew_factor,
                    co_partitioned=_skip(op, 0, ins[0]),
                    lossless=lossless)
                extra["shuffle_overflow"] = ovf
            else:
                v = op_distinct(ins[0], hc)
        elif op.kind == "UNION":
            v = op_union(ins[0], ins[1])
        elif op.kind == "SPLIT":
            v = ins[0]
        elif op.kind == "STORE":
            v = op_store(ins[0])
            outputs[p["name"]] = v
        else:
            raise ValueError(op.kind)
        values[id(op)] = v
        extra["rows_out"] = v.num_valid()
        stats[op.uid] = extra
    return outputs, stats
