"""Table: the tuple-stream representation of the dataflow engine.

Hadoop streams tuples between operators; XLA wants static shapes.  A Table
is a struct-of-arrays with a *compile-time capacity* and a validity mask:

  * every column is a jnp array of shape ``(capacity,)`` (numeric) or
    ``(capacity, width)`` (fixed-width byte strings, dtype uint8);
  * ``valid`` is a boolean ``(capacity,)`` mask — Filter marks rows
    invalid instead of compacting; compaction happens host-side on the
    artifact store's write path (see ``host_compact``).

Tables are pytrees so they flow through jit/shard_map unchanged.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Schema


@dataclasses.dataclass(frozen=True)
class ColumnType:
    """dtype + optional byte-width (width > 0 means fixed-width string)."""

    dtype: str  # numpy dtype name, e.g. "int32", "float32", "uint8"
    width: int = 0  # 0 => scalar column; >0 => (capacity, width) bytes

    @property
    def is_string(self) -> bool:
        return self.width > 0

    def key(self) -> Tuple:
        return ("col", self.dtype, self.width)


INT = ColumnType("int32")
FLOAT = ColumnType("float32")


def STR(width: int = 20) -> ColumnType:
    return ColumnType("uint8", width)


Schema = Dict[str, ColumnType]


def schema_key(schema: Schema) -> Tuple:
    return tuple(sorted((n, t.key()) for n, t in schema.items()))


# ---------------------------------------------------------------------------
# Table pytree


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Table:
    columns: Dict[str, jnp.ndarray]
    valid: jnp.ndarray  # bool (capacity,)

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        children = tuple(self.columns[n] for n in names) + (self.valid,)
        return children, names

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(columns=dict(zip(names, children[:-1])), valid=children[-1])

    # -- accessors ----------------------------------------------------------
    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    @property
    def names(self):
        return sorted(self.columns)

    def col(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    def schema(self) -> Schema:
        out: Schema = {}
        for n, c in self.columns.items():
            if c.ndim == 2:
                out[n] = ColumnType("uint8", int(c.shape[1]))
            else:
                out[n] = ColumnType(str(c.dtype))
        return out

    def num_valid(self) -> jnp.ndarray:
        return jnp.sum(self.valid.astype(jnp.int32))

    def nbytes(self) -> int:
        """Logical bytes at full capacity (the T_load/T_store proxy)."""
        total = self.valid.size  # 1 byte/bool
        for c in self.columns.values():
            total += c.size * c.dtype.itemsize
        return int(total)

    # -- construction helpers ----------------------------------------------
    @staticmethod
    def from_numpy(cols: Dict[str, np.ndarray], nvalid: int | None = None,
                   capacity: int | None = None) -> "Table":
        n = len(next(iter(cols.values())))
        nvalid = n if nvalid is None else nvalid
        capacity = n if capacity is None else capacity
        out = {}
        for name, a in cols.items():
            a = np.asarray(a)
            if capacity != n:
                pad = [(0, capacity - n)] + [(0, 0)] * (a.ndim - 1)
                a = np.pad(a, pad)
            out[name] = jnp.asarray(a)
        valid = jnp.arange(capacity) < nvalid
        return Table(out, valid)

    def to_numpy(self, only_valid: bool = True) -> Dict[str, np.ndarray]:
        mask = np.asarray(self.valid)
        out = {}
        for n, c in self.columns.items():
            a = np.asarray(c)
            out[n] = a[mask] if only_valid else a
        return out

    # -- row ops used by physical operators ----------------------------------
    def gather(self, idx: jnp.ndarray, valid: jnp.ndarray) -> "Table":
        cols = {n: jnp.take(c, idx, axis=0) for n, c in self.columns.items()}
        return Table(cols, valid)

    def with_valid(self, valid: jnp.ndarray) -> "Table":
        return Table(dict(self.columns), valid)

    def select(self, names) -> "Table":
        return Table({n: self.columns[n] for n in names}, self.valid)

    def compact(self) -> "Table":
        """Reorder rows so valid rows form a prefix (stable).

        Device-side utility (the artifact store compacts host-side via
        ``host_compact`` instead).  Sort-free: ``order[j]`` = index of
        the j-th valid row, found by binary-searching the running count
        of valid rows — XLA's CPU sort is ~5x slower than
        cumsum+searchsorted+gather at these sizes."""
        cnt = jnp.cumsum(self.valid.astype(jnp.int32))
        order = jnp.searchsorted(cnt, jnp.arange(1, self.capacity + 1))
        order = jnp.clip(order, 0, self.capacity - 1)
        return self.gather(order, jnp.arange(self.capacity) < cnt[-1])

    def host_compact(self, capacity: int, nvalid: int
                     ) -> "Dict[str, np.ndarray]":
        """Numpy-side compaction for the store's write path: extract the
        ``nvalid`` valid rows (stable), pad to ``capacity``.  Returns
        column arrays plus ``__valid__``; runs off the device and off the
        timed path (flusher thread)."""
        mask = np.asarray(self.valid).astype(bool)
        out: Dict[str, np.ndarray] = {}
        for n, c in self.columns.items():
            a = np.asarray(c)[mask][:capacity]
            if len(a) < capacity:
                pad = [(0, capacity - len(a))] + [(0, 0)] * (a.ndim - 1)
                a = np.pad(a, pad)
            out[n] = a
        out["__valid__"] = np.arange(capacity) < nvalid
        return out


def concat_tables(parts, capacity: int | None = None) -> Table:
    """Host-side concatenation of the *valid* rows of ``parts``, in
    order — the append primitive of incremental artifact maintenance
    (DESIGN.md §12): an append-refreshed dataset/artifact is exactly the
    old valid rows followed by the delta's valid rows (prefix-stable).
    Schemas must match exactly."""
    assert parts, "concat_tables: no inputs"
    names = parts[0].names
    for p in parts[1:]:
        assert p.names == names, "concat_tables: schema mismatch"
    cols: Dict[str, np.ndarray] = {}
    for n in names:
        cols[n] = np.concatenate(
            [np.asarray(p.col(n))[np.asarray(p.valid).astype(bool)]
             for p in parts])
    nvalid = len(cols[names[0]])
    cap = capacity if capacity is not None else max(nvalid, 8)
    return Table.from_numpy(cols, nvalid=nvalid, capacity=cap)


def slice_valid(table: Table, lo: int, hi: int | None = None,
                round_pow2: bool = False, cols=None) -> Table:
    """Table holding valid rows ``[lo:hi]`` of ``table`` (host-side).
    With an append-only lineage, ``slice_valid(cur, 0, n_old)`` is the
    pre-append snapshot and ``slice_valid(cur, n_old)`` the delta
    (DESIGN.md §12).  ``round_pow2`` pads the capacity to the next
    power of two — data-dependent row counts otherwise produce a fresh
    shape (and a fresh jit trace) per call on anything downstream.
    ``cols`` restricts the slice to a column subset (delta bindings only
    materialize the bytes their subplan consumes)."""
    # one flatnonzero over the mask, then a gather of just the selected
    # rows — not an O(n)-per-column copy of every valid row first
    rows = np.flatnonzero(np.asarray(table.valid))[lo:hi]
    names = table.names if cols is None else sorted(cols)
    out: Dict[str, np.ndarray] = {}
    for n in names:
        out[n] = np.asarray(table.col(n))[rows]
    nvalid = len(rows)
    cap = max(nvalid, 8)
    if round_pow2:
        cap = 1 << (cap - 1).bit_length()
    return Table.from_numpy(out, nvalid=nvalid, capacity=cap)


def pad_capacity(table: Table, multiple: int) -> Table:
    """Pad ``table`` with invalid rows so its capacity is a multiple of
    ``multiple`` (mesh engines shard inputs into equal blocks)."""
    cap = table.capacity
    if multiple <= 1 or cap % multiple == 0:
        return table
    new_cap = ((cap + multiple - 1) // multiple) * multiple
    cols = {}
    for n, c in table.columns.items():
        pad = [(0, new_cap - cap)] + [(0, 0)] * (c.ndim - 1)
        cols[n] = jnp.asarray(np.pad(np.asarray(c), pad))
    valid = jnp.asarray(np.pad(np.asarray(table.valid), (0, new_cap - cap)))
    return Table(cols, valid)


def encode_strings(values, width: int = 20) -> np.ndarray:
    """Python strings -> (n, width) uint8, truncated/zero-padded."""
    out = np.zeros((len(values), width), dtype=np.uint8)
    for i, s in enumerate(values):
        b = s.encode("utf-8")[:width]
        out[i, : len(b)] = np.frombuffer(b, dtype=np.uint8)
    return out


def decode_strings(arr: np.ndarray):
    return ["".join(chr(c) for c in row if c) for row in np.asarray(arr)]


# ---------------------------------------------------------------------------
# Row packing (DESIGN.md §14): the fused exchange moves every column of
# a table through ONE collective by byte-packing rows into a single
# (capacity, row_bytes) uint8 buffer.  bitcast keeps the packing exact
# (float32 round-trips bit-identically) and free of format work.


def _col_bytes(c: jnp.ndarray) -> jnp.ndarray:
    if c.ndim == 2:                      # fixed-width string: already bytes
        return c
    if c.dtype == jnp.bool_:
        return c.astype(jnp.uint8)[:, None]
    if c.dtype == jnp.uint8:
        return c[:, None]
    return jax.lax.bitcast_convert_type(c, jnp.uint8)   # (N,) -> (N, itemsize)


def pack_rows(cols: Dict[str, jnp.ndarray], valid: jnp.ndarray
              ) -> Tuple[jnp.ndarray, Tuple]:
    """Pack columns + the validity lane into one (N, B) uint8 buffer.
    Returns (packed, layout); the layout is static (hashable) and drives
    ``unpack_rows``.  Column order is sorted-name for determinism."""
    parts, layout = [], []
    for n in sorted(cols):
        c = cols[n]
        b = _col_bytes(c)
        parts.append(b)
        layout.append((n, str(c.dtype), int(b.shape[1]), c.ndim == 2))
    parts.append(valid.astype(jnp.uint8)[:, None])
    return jnp.concatenate(parts, axis=1), tuple(layout)


def unpack_rows(packed: jnp.ndarray, layout: Tuple
                ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    """Inverse of ``pack_rows``.  Zero-filled rows (unhit scatter slots)
    unpack to zero values with valid=False."""
    cols: Dict[str, jnp.ndarray] = {}
    off = 0
    for name, dtype, width, is_string in layout:
        b = packed[:, off:off + width]
        off += width
        if is_string:
            cols[name] = b
        elif dtype == "bool":
            cols[name] = b[:, 0].astype(jnp.bool_)
        elif dtype == "uint8":
            cols[name] = b[:, 0]
        else:
            cols[name] = jax.lax.bitcast_convert_type(b, jnp.dtype(dtype))
    valid = packed[:, off].astype(jnp.bool_)
    return cols, valid


# ---------------------------------------------------------------------------
# Hashing (uint32; two independent lanes available for sort tie-breaking)

_FNV_OFFSET = np.uint32(2166136261)
_FNV_PRIME = np.uint32(16777619)


def _mix32(x: jnp.ndarray, seed: int) -> jnp.ndarray:
    """splitmix-style avalanche on uint32."""
    x = x.astype(jnp.uint32) ^ jnp.uint32(seed)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def hash_column(col: jnp.ndarray, seed: int = 0) -> jnp.ndarray:
    """uint32 hash of one column (any dtype, 1-D or 2-D bytes)."""
    if col.ndim == 2:  # fixed-width string: FNV-1a fold, then mix
        h = jnp.full(col.shape[:1], _FNV_OFFSET, dtype=jnp.uint32)
        for j in range(col.shape[1]):
            h = (h ^ col[:, j].astype(jnp.uint32)) * _FNV_PRIME
        return _mix32(h, seed)
    if jnp.issubdtype(col.dtype, jnp.floating):
        col = jax.lax.bitcast_convert_type(col.astype(jnp.float32), jnp.uint32)
    return _mix32(col.astype(jnp.uint32), seed)


def hash_columns(table: Table, names, seed: int = 0) -> jnp.ndarray:
    """Combined uint32 hash over several key columns."""
    h = jnp.zeros(table.capacity, dtype=jnp.uint32)
    for i, n in enumerate(sorted(names)):
        h = _mix32(h * jnp.uint32(31) + hash_column(table.col(n), seed + i), seed)
    return h


def key_hash(table: Table, keys, seed: int = 0) -> jnp.ndarray:
    """uint32 key hash mixing the key columns in the GIVEN order.

    Unlike ``hash_columns`` (which sorts names so GROUPBY fingerprints
    are order-insensitive), this hash is positional: the two sides of a
    JOIN carry differently-named key columns, and their hashes only
    agree if column i on the left is hashed exactly like column i on
    the right."""
    h = jnp.zeros(table.capacity, dtype=jnp.uint32)
    for i, n in enumerate(keys):
        h = _mix32(h * jnp.uint32(31) + hash_column(table.col(n), seed + i),
                   seed)
    return h


def partition_finalize(h: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 over an already-computed ``key_hash`` lane.

    The partition hash is *derived* from the seed-0 key hash with a
    handful of integer ops so the exchange pays ONE string-fold pass
    for both its routing bits and the ``__h0__`` lane it ships; the
    finalizer decorrelates the low routing bits from the lane the
    reducers sort/segment by.  Every component that assigns rows to
    shards — the shard_map exchange, the artifact store's sharded
    writer, and re-partition-on-read — must agree bit-for-bit on
    hash(keys) % P, or "co-partitioned" artifacts would silently hold
    rows on the wrong shard (DESIGN.md §11)."""
    h = h.astype(jnp.uint32)
    h = (h ^ (h >> 16)) * jnp.uint32(0x85EBCA6B)
    h = (h ^ (h >> 13)) * jnp.uint32(0xC2B2AE35)
    return h ^ (h >> 16)


def partition_hash(table: Table, keys) -> jnp.ndarray:
    """Canonical uint32 partition hash: ``partition_finalize`` of the
    positional seed-0 ``key_hash`` (see ``partition_finalize`` for why
    the derivation matters)."""
    return partition_finalize(key_hash(table, keys, seed=0))


@partial(jax.jit, static_argnames=("keys", "n_parts"))
def partition_ids_device(table: Table, keys: Tuple[str, ...],
                         n_parts: int) -> jnp.ndarray:
    """Jitted ``partition_hash(keys) % n_parts`` — the artifact store
    computes this on every partitioned put (the one on-clock device pass
    of a sharded store), so the ~dozen hash-mix ops must launch as one
    fused computation, not eager per-op dispatches."""
    return partition_hash(table, keys) % jnp.uint32(n_parts)


def cols_equal(table_a: Table, idx_a, table_b: Table, idx_b, names) -> jnp.ndarray:
    """Exact row equality on key columns between gathered row indices."""
    eq = jnp.ones(jnp.shape(idx_a), dtype=bool)
    for n in names:
        ca = jnp.take(table_a.col(n), idx_a, axis=0)
        cb = jnp.take(table_b.col(n), idx_b, axis=0)
        e = ca == cb
        if e.ndim == 2:
            e = e.all(axis=-1)
        eq = eq & e
    return eq
