"""Job / workflow execution engine.

Each job's plan fragment is jitted as one XLA computation (the analogue of
one MapReduce job launch).  Statistics collected per job mirror what
Hadoop gives ReStore (paper §5): input/output rows and bytes, wall time —
they feed the repository's ordering and eviction rules.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax

from ..store.artifacts import ArtifactStore, Catalog
from .compiler import Job, Workflow
from .physical import execute_plan
from .table import Table


@dataclasses.dataclass
class JobStats:
    job_id: int
    wall_s: float
    rows_in: int
    bytes_in: int
    rows_out: int
    bytes_out: int
    op_rows: Dict[int, int]
    join_overflow: int = 0

    @property
    def reduction(self) -> float:
        """input:output byte ratio — ordering rule 2 metric (paper §3)."""
        return self.bytes_in / max(self.bytes_out, 1)


class Engine:
    """Executes workflows of jobs over a catalog + artifact store."""

    def __init__(self, catalog: Catalog, store: ArtifactStore,
                 use_kernels: bool = False, measure_exec: bool = False,
                 repeats: int = 5):
        self.catalog = catalog
        self.store = store
        self.use_kernels = use_kernels
        # measure_exec: warm the jit off the clock, then repeat the full
        # load->execute->store cycle `repeats` times and report the median
        # (benchmarks compare execution, not tracing+compile, and median
        # suppresses disk jitter)
        self.measure_exec = measure_exec
        self.repeats = repeats
        self._jit_cache: Dict[str, object] = {}

    # ------------------------------------------------------------------
    def _dataset(self, name: str) -> Table:
        if self.store.exists(name):
            return self.store.get(name)
        return self.catalog.get(name)

    def run_job(self, job: Job) -> tuple[Dict[str, Table], JobStats]:
        """Timed window mirrors Eq. 2: T_load (dataset reads from the
        store) + operator execution + T_store (artifact writes)."""
        input_names = sorted({o.params["dataset"] for o in job.plan.loads()})
        fps = job.plan.fingerprints()
        sig = "|".join(sorted(fps[id(s)] for s in job.plan.sinks))

        if sig not in self._jit_cache:
            plan = job.plan

            def fn(datasets):
                return execute_plan(plan, datasets)

            self._jit_cache[sig] = jax.jit(fn)

        if self.measure_exec:   # warm jit + OS page cache off the clock
            warm_in = {n: self._dataset(n) for n in input_names}
            warm, _ = self._jit_cache[sig](warm_in)
            jax.tree_util.tree_map(lambda x: x.block_until_ready(), warm)
            del warm, warm_in

        walls = []
        reps = self.repeats if self.measure_exec else 1
        for _ in range(reps):
            t0 = time.perf_counter()
            inputs = {n: self._dataset(n) for n in input_names}  # T_load
            outputs, stats = self._jit_cache[sig](inputs)
            outputs = jax.tree_util.tree_map(
                lambda x: x.block_until_ready(), outputs)
            for name, t in outputs.items():                      # T_store
                self.store.put(name, t)
            walls.append(time.perf_counter() - t0)
        wall = sorted(walls)[len(walls) // 2]

        rows_in = sum(int(t.num_valid()) for t in inputs.values())
        bytes_in = sum(t.nbytes() for t in inputs.values())
        rows_out = sum(int(t.num_valid()) for t in outputs.values())
        bytes_out = sum(t.nbytes() for t in outputs.values())
        op_rows = {uid: int(s["rows_out"]) for uid, s in stats.items()}
        ovf = sum(int(s.get("join_overflow", 0)) for s in stats.values())
        return outputs, JobStats(job.job_id, wall, rows_in, bytes_in,
                                 rows_out, bytes_out, op_rows, ovf)

    def run_workflow(self, wf: Workflow) -> tuple[Dict[str, Table],
                                                  List[JobStats]]:
        all_stats: List[JobStats] = []
        for job in wf.jobs:
            # whole-job reuse fast path: if every output already exists in
            # the artifact store the job is a no-op (paper §3: a fully
            # matched job is dropped from the workflow)
            if all(self.store.exists(o) for o in job.outputs):
                all_stats.append(JobStats(job.job_id, 0.0, 0, 0, 0, 0, {}))
                continue
            _, stats = self.run_job(job)
            all_stats.append(stats)
        results = {user: self.store.get(ds)
                   for user, ds in wf.final_outputs.items()}
        return results, all_stats
