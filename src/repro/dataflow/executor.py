"""Job / workflow execution engine.

Each job's plan fragment is jitted as one XLA computation (the analogue of
one MapReduce job launch).  Compiled computations live in a
**process-wide cache keyed by plan fingerprint** — benchmarks build a
fresh ``Engine`` per arm, and identical plans must trace/compile exactly
once per process, not once per engine (Hadoop's job-launch overhead is
constant across arms; JIT compile must be too).

Statistics collected per job mirror what Hadoop gives ReStore (paper §5):
input/output rows and bytes, wall time — they feed the repository's
ordering and eviction rules.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
import time
from typing import Callable, Dict, List, Tuple

import jax

from ..core.plan import (Partitioning, load_partition_demands,
                         plan_physical_props)
from ..kernels import autotune
from ..store.artifacts import ArtifactStore, Catalog
from .compiler import Job, Workflow
from .physical import execute_plan, use_pallas
from .table import Table


@dataclasses.dataclass
class JobStats:
    job_id: int
    wall_s: float
    rows_in: int
    bytes_in: int
    rows_out: int
    bytes_out: int
    op_rows: Dict[int, int]
    join_overflow: int = 0
    # op uid -> estimated cumulative seconds to produce that op's output
    # (its whole input cone) — the producer cost of the sub-job rooted
    # there, feeding the repository cost model (DESIGN.md §9)
    op_cost_s: Dict[int, float] = dataclasses.field(default_factory=dict)
    # mesh execution (DESIGN.md §11): rows the exchange's bounded
    # buckets dropped, exchange counts, and the static partition
    # property of each op's output (op uid -> Partitioning.to_dict())
    shuffle_overflow: int = 0
    shuffles: int = 0
    shuffles_skipped: int = 0
    # 1 if the bounded-bucket / hash-reduce run lost rows and the job
    # was rerun on the lossless configuration (DESIGN.md §14)
    shuffle_retries: int = 0
    op_partitioning: Dict[int, dict] = dataclasses.field(default_factory=dict)

    @property
    def reduction(self) -> float:
        """input:output byte ratio — ordering rule 2 metric (paper §3)."""
        return self.bytes_in / max(self.bytes_out, 1)


# Relative work weights for attributing a job's measured wall time over
# its operators.  One jitted XLA computation cannot be timed per-op, so
# the wall clock is split proportional to a rows-processed work model:
# blocking (sort/shuffle-backed) operators weigh several times a
# streaming map op.  The absolute values only matter relative to each
# other; the attributed times always sum to the measured wall time.
_OP_WEIGHT = {
    "LOAD": 0.5, "STORE": 0.05, "SPLIT": 0.02,
    "PROJECT": 0.3, "FILTER": 0.4, "FOREACH": 0.6, "UNION": 0.3,
    "DISTINCT": 2.5, "GROUPBY": 3.0, "JOIN": 4.0, "COGROUP": 4.0,
}


def attribute_op_costs(plan, op_rows: Dict[int, int],
                       wall_s: float) -> Dict[int, float]:
    """Split a job's wall time across its operators (weighted by rows
    touched), then accumulate over each operator's input cone.  Returns
    op uid -> cumulative producer cost in seconds; for a single-sink
    plan the sink's value equals ``wall_s``."""
    topo = plan.topo()
    work: Dict[int, float] = {}
    for op in topo:
        rin = sum(op_rows.get(i.uid, 0) for i in op.inputs)
        rout = op_rows.get(op.uid, 0)
        work[op.uid] = _OP_WEIGHT.get(op.kind, 1.0) * (rin + rout + 64)
    total = sum(work.values()) or 1.0
    own = {uid: wall_s * w / total for uid, w in work.items()}
    # cumulative over the input cone; a shared subtree is counted once
    cones: Dict[int, frozenset] = {}
    out: Dict[int, float] = {}
    for op in topo:
        cone = frozenset({op.uid}).union(*(cones[id(i)] for i in op.inputs)) \
            if op.inputs else frozenset({op.uid})
        cones[id(op)] = cone
        out[op.uid] = sum(own[u] for u in cone)
    return out


class JitCache:
    """Process-wide plan-fingerprint -> jitted-computation cache.

    LRU-bounded by entry count: each entry pins a plan closure plus its
    XLA executables, so an unbounded dict would grow for the whole
    process lifetime across benchmark sweeps."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._fns: "collections.OrderedDict[Tuple, Callable]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        # key -> Event for a build in progress: concurrent service
        # workers building DIFFERENT plans must not serialize on one
        # global lock (tracing/compilation dominates cold latency), and
        # two workers racing on the SAME key must compile it once
        self._building: Dict[Tuple, threading.Event] = {}
        self.hits = 0
        self.misses = 0

    def get(self, key: Tuple, build: Callable[[], Callable]) -> Callable:
        while True:
            with self._lock:
                fn = self._fns.get(key)
                if fn is not None:
                    self._fns.move_to_end(key)
                    self.hits += 1
                    return fn
                ev = self._building.get(key)
                if ev is None:
                    self._building[key] = threading.Event()
                    self.misses += 1
                    break               # this thread builds
            ev.wait()                   # a peer is building this key
        try:
            fn = build()
        except BaseException:
            with self._lock:            # waiters retry (and rebuild)
                self._building.pop(key).set()
            raise
        with self._lock:
            self._fns[key] = fn
            while len(self._fns) > self.max_entries:
                self._fns.popitem(last=False)
            self._building.pop(key).set()
            return fn

    def clear(self):
        with self._lock:
            self._fns.clear()
            self.hits = 0
            self.misses = 0

    def __len__(self):
        return len(self._fns)


GLOBAL_JIT_CACHE = JitCache(
    max_entries=int(os.environ.get("RESTORE_JIT_CACHE_ENTRIES", 256)))


class Engine:
    """Executes workflows of jobs over a catalog + artifact store."""

    def __init__(self, catalog: Catalog, store: ArtifactStore,
                 use_kernels: bool = False, measure_exec: bool = False,
                 repeats: int = 5, mesh=None, shuffle_axis: str = "data",
                 skew_factor: float = 4.0, partition_aware: bool = True):
        self.catalog = catalog
        self.store = store
        self.use_kernels = use_kernels
        # measure_exec: warm the jit off the clock, then repeat the full
        # load->execute->store cycle `repeats` times and report the median
        # (benchmarks compare execution, not tracing+compile, and median
        # suppresses disk jitter)
        self.measure_exec = measure_exec
        self.repeats = repeats
        # mesh execution (DESIGN.md §11): blocking operators run through
        # the shard_map exchange across the mesh's ``shuffle_axis``.
        # partition_aware=False is the ablation arm: artifacts are
        # stored monolithic and stored partition properties are ignored
        # (every exchange always runs) — the baseline the distributed
        # benchmark beats.
        self.mesh = mesh
        self.shuffle_axis = shuffle_axis
        # the exchange's bucket skew is an autotunable knob: a smaller
        # factor shrinks every downstream capacity (less reduce work),
        # a larger one absorbs more key skew without the lossless retry
        # (kernels/autotune.py; inert unless RESTORE_AUTOTUNE=1)
        self.skew_factor = autotune.choose("exchange", 0, "row", "skew",
                                           skew_factor)
        self.partition_aware = partition_aware
        self._jit_cache = GLOBAL_JIT_CACHE

    @property
    def n_shards(self):
        if self.mesh is None:
            return None
        return int(self.mesh.shape[self.shuffle_axis])

    # ------------------------------------------------------------------
    def _dataset(self, name: str) -> Table:
        if self.store.exists(name):
            return self.store.get(name)
        return self.catalog.get(name)

    def _mesh_context(self, plan, input_names):
        """Physical context of a mesh run: per-dataset partition
        properties and schemas, plus re-partitioned overrides for
        mismatched-P artifacts a blocking consumer demands (DESIGN.md
        §11).  Returns (props, overrides, parts_key) — parts_key goes
        into the jit-cache key, because the co-partition skip decisions
        are baked into the traced computation."""
        n_shards = self.n_shards
        demands = load_partition_demands(plan) if self.partition_aware \
            else {}
        dataset_parts, schemas, overrides = {}, {}, {}
        for n in input_names:
            sp = self.store.partitioning(n) if self.partition_aware \
                else None
            want = demands.get(n)
            covered = (sp is not None and sp["n_parts"] == n_shards
                       and set(sp["keys"]) <= set(want or ()))
            if want and not covered and self.partition_aware \
                    and self.store.exists(n):
                # co-partition on read (M3R-style partition stability):
                # one host pass now, cached as a derived view, instead
                # of a device exchange on every consumption — covers
                # monolithic artifacts and mismatched-P layouts alike.
                # Catalog-only datasets stay on the device exchange.
                overrides[n], sp = self.store.get_partitioned(
                    n, want, n_shards)
            dataset_parts[n] = sp
            schemas[n] = self._schema(n, overrides)
        props = None
        if self.partition_aware:
            props = plan_physical_props(
                plan,
                {k: Partitioning.from_dict(v)
                 for k, v in dataset_parts.items() if v is not None},
                schemas, n_shards)
        # key only what changes the trace: the partition FUNCTION
        # (keys/n_parts/scheme) — per-shard row counts vary run to run
        # without changing the computation, and keying them would stop
        # the process-wide jit cache from ever hitting on mesh plans
        parts_key = (
            self.shuffle_axis, n_shards, self.skew_factor,
            self.partition_aware,
            tuple(d.id for d in self.mesh.devices.flat),
            tuple(sorted(
                (n, (tuple(dataset_parts[n]["keys"]),
                     dataset_parts[n]["n_parts"],
                     dataset_parts[n].get("scheme", "hash_mod"))
                 if dataset_parts[n] is not None else None)
                for n in input_names)))
        return props, overrides, parts_key

    def _schema(self, name: str, overrides) -> tuple:
        """Column names of a dataset without forcing a cold load (the
        store reads just the npz directory for on-disk artifacts)."""
        t = overrides.get(name)
        if t is not None:
            return tuple(t.names)
        try:
            return self.store.column_names(name)
        except KeyError:
            return tuple(self.catalog.get(name).names)

    def _jitted(self, plan, props=None, parts_key=None,
                skew=None, lossless=False):
        """Returns (fn, uid_by_fp, fps): the cached jitted computation,
        the CACHED plan's op-uid per fingerprint, and the current plan's
        fingerprints.  A cache hit serves a closure over the *first*
        fingerprint-equal plan, whose op uids differ from the current
        plan's — stats must be translated through fingerprints or every
        ``op_rows`` lookup by current-plan uid would miss."""
        fps = plan.fingerprints()
        sig = "|".join(sorted(fps[id(s)] for s in plan.sinks))
        # the pallas switch changes the traced computation, so it is part
        # of the cache key, and so is the mesh + dataset-partitioning
        # context (a co-partition skip is baked into the trace: the same
        # plan over a differently-partitioned artifact is a different
        # computation).  Everything else that matters is in the
        # fingerprints; input shapes are handled by jax.jit retracing.
        if skew is None:
            skew = self.skew_factor
        key = (sig, use_pallas(), parts_key, skew, lossless)
        # the closure outlives this Engine in the PROCESS-WIDE cache:
        # capture plain locals, never `self` (an Engine reference would
        # pin its catalog + store + device cache for process lifetime)
        mesh, axis = self.mesh, self.shuffle_axis

        def build():
            def fn(datasets):
                return execute_plan(plan, datasets, mesh=mesh,
                                    shuffle_axis=axis, skew_factor=skew,
                                    props=props, lossless=lossless)
            uid_by_fp = {fps[id(op)]: op.uid for op in plan.topo()}
            return jax.jit(fn), uid_by_fp

        fn, uid_by_fp = self._jit_cache.get(key, build)
        return fn, uid_by_fp, fps

    def run_job(self, job: Job,
                transient: bool = False) -> tuple[Dict[str, Table],
                                                  JobStats]:
        """Timed window mirrors Eq. 2: T_load (dataset reads from the
        store) + operator execution + T_store (artifact writes — with the
        write-behind store only the device-side handoff is on the clock;
        serialization happens on the flusher thread).

        ``transient=True`` skips T_store entirely: outputs are returned
        to the caller but never put in the artifact store.  Incremental
        maintenance (DESIGN.md §12) runs its delta jobs this way — the
        delta value exists only to be merged into the refreshed
        artifact, so storing-then-deleting it would waste a disk write
        per refresh and pollute the IO calibration samples."""
        input_names = sorted({o.params["dataset"] for o in job.plan.loads()})
        props, overrides, parts_key = (None, {}, None)
        if self.mesh is not None:
            props, overrides, parts_key = self._mesh_context(
                job.plan, input_names)
        fn, uid_by_fp, fps = self._jitted(job.plan, props, parts_key)
        # partition property of each output artifact (STORE sinks
        # inherit their input's property), recorded at put() so the
        # artifact is written sharded and later consumers can skip
        # their exchange (DESIGN.md §11)
        out_parts = {}
        if props is not None:
            for s in job.plan.sinks:
                if s.kind == "STORE" and props.part.get(id(s)) is not None:
                    out_parts[s.params["name"]] = \
                        props.part[id(s)].to_dict()

        def load_inputs():
            return {n: overrides[n] if n in overrides else self._dataset(n)
                    for n in input_names}

        if self.measure_exec:   # warm jit + OS page cache off the clock
            warm, _ = fn(load_inputs())
            jax.block_until_ready(warm)
            del warm

        walls = []
        reps = self.repeats if self.measure_exec else 1
        for _ in range(reps):
            t0 = time.perf_counter()
            inputs = load_inputs()                               # T_load
            outputs, stats = fn(inputs)
            # one synchronization point per job (not per output): wait for
            # the whole output pytree at once
            outputs = jax.block_until_ready(outputs)
            if not transient:
                for name, t in outputs.items():                  # T_store
                    self.store.put(name, t,
                                   partitioning=out_parts.get(name))
            walls.append(time.perf_counter() - t0)
            if self.measure_exec:
                # drain the write-behind queue between reps so background
                # serialization does not contend with the next timed rep
                # (production jobs absorb it in pipeline idle gaps)
                self.store.flush()
        wall = sorted(walls)[len(walls) // 2]

        rows_in = sum(int(t.num_valid()) for t in inputs.values())
        bytes_in = sum(t.nbytes() for t in inputs.values())
        rows_out = sum(int(t.num_valid()) for t in outputs.values())
        bytes_out = sum(t.nbytes() for t in outputs.values())
        # stats arrive keyed by the cached plan's op uids; translate to
        # the current plan's uids through the shared fingerprints
        op_rows = {}
        for op in job.plan.topo():
            s = stats.get(uid_by_fp.get(fps[id(op)]))
            if s is not None:
                op_rows[op.uid] = int(s["rows_out"])
        ovf = sum(int(s.get("join_overflow", 0)) for s in stats.values())
        sh_ovf = sum(int(s.get("shuffle_overflow", 0))
                     for s in stats.values())
        retries = 0
        if sh_ovf > 0 and self.mesh is not None:
            # lossless retry (DESIGN.md §14): the bounded buckets
            # dropped rows or the hash reduce hit an h1 collision, so
            # results are not trustworthy — rerun once with
            # skew=n_shards (every bucket can hold a full source shard)
            # and the collision-proof sort-based reduce.  The retry's
            # wall adds to the job's; the first attempt's overflow
            # count stays in the stats as the audit trail.
            fn2, uid_by_fp, fps = self._jitted(
                job.plan, props, parts_key,
                skew=float(self.n_shards), lossless=True)
            if self.measure_exec:       # keep compile off the clock
                warm, _ = fn2(load_inputs())
                jax.block_until_ready(warm)
                del warm
            t0 = time.perf_counter()
            inputs = load_inputs()
            outputs, stats = fn2(inputs)
            outputs = jax.block_until_ready(outputs)
            if not transient:
                for name, t in outputs.items():
                    self.store.put(name, t,
                                   partitioning=out_parts.get(name))
            wall += time.perf_counter() - t0
            retries = 1
            rows_out = sum(int(t.num_valid()) for t in outputs.values())
            bytes_out = sum(t.nbytes() for t in outputs.values())
            op_rows = {}
            for op in job.plan.topo():
                s = stats.get(uid_by_fp.get(fps[id(op)]))
                if s is not None:
                    op_rows[op.uid] = int(s["rows_out"])
            ovf = sum(int(s.get("join_overflow", 0))
                      for s in stats.values())
        op_cost = attribute_op_costs(job.plan, op_rows, wall)
        js = JobStats(job.job_id, wall, rows_in, bytes_in,
                      rows_out, bytes_out, op_rows, ovf, op_cost,
                      shuffle_overflow=sh_ovf, shuffle_retries=retries)
        if props is not None:
            js.shuffles = props.n_exchanges()
            js.shuffles_skipped = props.n_skipped()
            js.op_partitioning = {
                op.uid: props.part[id(op)].to_dict()
                for op in job.plan.topo()
                if props.part.get(id(op)) is not None}
        return outputs, js

    def run_workflow(self, wf: Workflow) -> tuple[Dict[str, Table],
                                                  List[JobStats]]:
        all_stats: List[JobStats] = []
        for job in wf.jobs:
            # whole-job reuse fast path: if every output already exists in
            # the artifact store the job is a no-op (paper §3: a fully
            # matched job is dropped from the workflow)
            if all(self.store.exists(o) for o in job.outputs):
                all_stats.append(JobStats(job.job_id, 0.0, 0, 0, 0, 0, {}))
                continue
            _, stats = self.run_job(job)
            all_stats.append(stats)
        results = {user: self.store.get(ds)
                   for user, ds in wf.final_outputs.items()}
        # workflow end is a durability point: all artifacts on disk
        self.store.flush()
        return results, all_stats
