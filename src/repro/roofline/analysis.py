"""Three-term roofline analysis from the dry-run's compiled artifacts.

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = collective_bytes_per_chip / link_bw

HLO numbers come from ``compiled.cost_analysis()`` with the loop-aware
depth extrapolation (launch/dryrun.py); collective bytes are parsed from
the post-SPMD HLO text (shapes there are already per-shard, so dividing
by the chip count again would double-count).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

N_CHIPS = {"16x16": 256, "2x16x16": 512}


def predict_tile_time_s(bytes_accessed: float, flops: float = 0.0,
                        collective_bytes: float = 0.0,
                        dispatch_overhead_s: float = 0.0) -> float:
    """Price one candidate kernel/exchange configuration by the same
    three-term roofline that scores whole dry-run cells: the dominant of
    compute, HBM, and ICI time, plus a caller-modeled fixed dispatch
    cost (per-tile grid overhead, collective launch).  Consumed by
    ``kernels/autotune.py`` to prune a candidate grid down to the few
    configurations worth actually measuring."""
    return max(flops / PEAK_FLOPS, bytes_accessed / HBM_BW,
               collective_bytes / ICI_BW) + dispatch_overhead_s


def model_flops(report: dict) -> float:
    """6*N*D (train) / 2*N*D (fwd-only), N = active params, D = tokens."""
    n = report["active_params"]
    kind = report["kind"]
    if kind == "train":
        tokens = report["seq"] * report["global_batch"]
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = report["seq"] * report["global_batch"]
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * report["global_batch"]


def analyze_cell(report: dict) -> Optional[dict]:
    if report.get("status") != "ok":
        return None
    chips = N_CHIPS[report["mesh"]]
    ce = report.get("cost_extrapolated")
    if not ce:
        return None
    flops_dev = max(ce["flops"], 0.0)
    bytes_dev = max(ce["bytes"], 0.0)
    # depth-extrapolation noise can drive tiny cells negative — clamp
    coll_dev = max(sum(ce["collective_bytes"].values()), 0.0)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)

    mf = model_flops(report)
    hlo_global = flops_dev * chips
    useful = mf / hlo_global if hlo_global else float("nan")
    # roofline fraction: useful work vs what the dominant term costs
    t_ideal = (mf / chips) / PEAK_FLOPS
    frac = t_ideal / max(terms[dominant], 1e-30)

    return {
        "arch": report["arch"], "shape": report["shape"],
        "mesh": report["mesh"], "kind": report["kind"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": useful, "roofline_fraction": frac,
        "collective_breakdown": ce["collective_bytes"],
        "memory_per_device": report.get("memory", {}),
    }


_SUGGESTIONS = {
    "compute": ("compute-bound: raise MXU utilization — fuse the "
                "attention softmax (Pallas flash kernel), drop remat "
                "recompute on cheap ops, verify no replicated einsum."),
    "memory": ("memory-bound: cut HBM traffic — fuse elementwise chains "
               "into the matmuls, keep activations bf16, shard the "
               "largest resident tensor further."),
    "collective": ("collective-bound: overlap or shrink comms — "
                   "reduce-scatter instead of all-reduce+slice, "
                   "sequence-shard the KV cache, async collectives "
                   "overlapped with compute."),
}


def suggestion(row: dict) -> str:
    base = _SUGGESTIONS[row["dominant"]]
    if row["useful_ratio"] < 0.4 and row["dominant"] == "compute":
        base += (" useful/HLO flops is low (remat or redundant "
                 "recompute dominates) — revisit checkpoint policy.")
    return base


def load_reports(dryrun_dir: str) -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rep = json.load(f)
        rep["_optimized"] = path.endswith("_opt.json")
        out.append(rep)
    return out


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def to_markdown(rows: List[dict], skipped: List[dict]) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_FLOPS | useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute_s'])} | "
            f"{fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} | "
            f"**{r['dominant']}** | {r['model_flops']:.3g} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} |")
    lines.append("")
    lines.append("Per-cell bottleneck notes:")
    for r in rows:
        lines.append(f"* `{r['arch']} x {r['shape']}`: {suggestion(r)}")
    if skipped:
        lines.append("")
        lines.append("Skipped cells (assignment rules):")
        for s in skipped:
            lines.append(f"* `{s['arch']} x {s['shape']}` ({s['mesh']}): "
                         f"{s.get('reason', '')}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    args = ap.parse_args()

    reports = load_reports(args.dryrun_dir)
    rows, rows_opt, skipped = [], [], []
    for rep in reports:
        if rep.get("mesh") != "16x16":   # roofline table: single-pod only
            continue
        if rep.get("status") == "skipped":
            if not rep["_optimized"]:
                skipped.append(rep)
            continue
        row = analyze_cell(rep)
        if row:
            (rows_opt if rep["_optimized"] else rows).append(row)
    key = lambda r: (r["arch"], r["shape"])
    rows.sort(key=key)
    rows_opt.sort(key=key)

    with open(args.json_out, "w") as f:
        json.dump({"baseline": rows, "optimized": rows_opt}, f, indent=1)
    md = ["## Baseline (paper-faithful first implementation)", "",
          to_markdown(rows, skipped)]
    if rows_opt:
        md += ["", "## Optimized (beyond-baseline, §Perf changes)", "",
               to_markdown(rows_opt, [])]
        # per-cell dominant-term improvement summary
        base_by = {key(r): r for r in rows}
        md += ["", "Dominant-term improvement (baseline -> optimized):"]
        for r in rows_opt:
            b = base_by.get(key(r))
            if not b:
                continue
            bd = max(b["t_compute_s"], b["t_memory_s"],
                     b["t_collective_s"])
            od = max(r["t_compute_s"], r["t_memory_s"],
                     r["t_collective_s"])
            md.append(f"* `{r['arch']} x {r['shape']}`: "
                      f"{fmt_s(bd)} -> {fmt_s(od)}  "
                      f"({bd / max(od, 1e-30):.1f}x)")
    text = "\n".join(md)
    with open(args.out, "w") as f:
        f.write(text)
    print(text)


if __name__ == "__main__":
    main()
