"""Plan / expression serialization — makes the ReStore repository
durable.  The paper's premise is reuse ACROSS workflows submitted over
days (Facebook's 7-day retention); a production driver restarts many
times in that window, so repository entries (physical plans + stats)
must round-trip through storage, not just the artifacts.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

from ..dataflow import expr as E
from . import plan as P


# ---------------------------------------------------------------------------
# Expressions


def expr_to_json(e: E.Expr) -> Dict[str, Any]:
    if isinstance(e, E.Col):
        return {"t": "col", "name": e.name}
    if isinstance(e, E.Const):
        return {"t": "const", "value": e.value}
    if isinstance(e, E.BinOp):
        return {"t": "bin", "op": e.op, "lhs": expr_to_json(e.lhs),
                "rhs": expr_to_json(e.rhs)}
    if isinstance(e, E.Cast):
        return {"t": "cast", "dtype": e.dtype,
                "inner": expr_to_json(e.inner)}
    raise TypeError(f"unserializable expr {type(e)}")


def expr_from_json(d: Dict[str, Any]) -> E.Expr:
    t = d["t"]
    if t == "col":
        return E.Col(d["name"])
    if t == "const":
        return E.Const(d["value"])
    if t == "bin":
        return E.BinOp(d["op"], expr_from_json(d["lhs"]),
                       expr_from_json(d["rhs"]))
    if t == "cast":
        return E.Cast(expr_from_json(d["inner"]), d["dtype"])
    raise TypeError(t)


# ---------------------------------------------------------------------------
# Plans


def _params_to_json(op: P.Operator) -> Dict[str, Any]:
    p = dict(op.params)
    if op.kind == "FILTER":
        p["pred"] = expr_to_json(p["pred"])
    elif op.kind == "FOREACH":
        p["gens"] = {k: expr_to_json(v) for k, v in p["gens"].items()}
    elif op.kind == "LOAD":
        p = {"dataset": p["dataset"], "version": p.get("version", 0)}
    return p


def _params_from_json(kind: str, p: Dict[str, Any]) -> Dict[str, Any]:
    p = dict(p)
    if kind == "FILTER":
        p["pred"] = expr_from_json(p["pred"])
    elif kind == "FOREACH":
        p["gens"] = {k: expr_from_json(v) for k, v in p["gens"].items()}
    elif kind in ("PROJECT",):
        p["cols"] = tuple(p["cols"])
    elif kind == "JOIN":
        p["left_keys"] = tuple(p["left_keys"])
        p["right_keys"] = tuple(p["right_keys"])
    elif kind == "GROUPBY":
        p["keys"] = tuple(p["keys"])
        p["aggs"] = {k: tuple(v) for k, v in p["aggs"].items()}
    elif kind == "COGROUP":
        p["keys_left"] = tuple(p["keys_left"])
        p["keys_right"] = tuple(p["keys_right"])
        p["aggs_left"] = {k: tuple(v) for k, v in p["aggs_left"].items()}
        p["aggs_right"] = {k: tuple(v) for k, v in p["aggs_right"].items()}
    return p


def plan_to_json(plan: P.PhysicalPlan) -> Dict[str, Any]:
    topo = plan.topo()
    ids = {id(op): i for i, op in enumerate(topo)}
    ops = [{"kind": op.kind, "params": _params_to_json(op),
            "inputs": [ids[id(i)] for i in op.inputs]} for op in topo]
    return {"ops": ops, "sinks": [ids[id(s)] for s in plan.sinks]}


def plan_from_json(d: Dict[str, Any]) -> P.PhysicalPlan:
    built: List[P.Operator] = []
    for o in d["ops"]:
        inputs = [built[i] for i in o["inputs"]]
        built.append(P.Operator(o["kind"],
                                _params_from_json(o["kind"], o["params"]),
                                inputs))
    return P.PhysicalPlan([built[i] for i in d["sinks"]])


# ---------------------------------------------------------------------------
# Repository


def _payload_to_json(e) -> Dict[str, Any]:
    if getattr(e, "kind", "plan") == "prefix":
        return {"prefix": {"tokens": [int(t) for t in e.plan.tokens],
                           "model_version": e.plan.model_version}}
    return plan_to_json(e.plan)


def entry_to_json(e) -> Dict[str, Any]:
    """One repository entry as a JSON-safe dict (shared by the state
    snapshot and the WAL journal — one codec, one format).  Entries are
    tagged with their artifact kind (DESIGN.md §17): a "prefix" entry
    serializes its token chain instead of an operator DAG."""
    return {
        "kind": getattr(e, "kind", "plan"),
        "plan": _payload_to_json(e), "artifact": e.artifact,
        "signature": e.signature, "bytes_in": e.bytes_in,
        "bytes_out": e.bytes_out, "rows_out": e.rows_out,
        "exec_time_s": e.exec_time_s, "created_at": e.created_at,
        "producer_cost_s": e.producer_cost_s,
        "history_uses": e.history_uses,
        "last_used": e.last_used, "use_count": e.use_count,
        "semantic_uses": e.semantic_uses,
        "saved_s_total": e.saved_s_total,
        "source_versions": e.source_versions,
        "partitioning": e.partitioning,
    }


def entry_from_json(d: Dict[str, Any]):
    """Decode one entry, or None when the payload fails the integrity
    check (a corrupted plan no longer matches its signature)."""
    from .repository import RepositoryEntry
    kind = d.get("kind", "plan")
    if kind == "prefix":
        from .prefix_plan import PrefixPlan
        p = d["plan"]["prefix"]
        try:
            plan = PrefixPlan(p["tokens"], p["model_version"])
        except (ValueError, KeyError, TypeError):
            return None
        if plan.signature != d["signature"]:
            return None
    else:
        plan = plan_from_json(d["plan"])
    e = RepositoryEntry(
        kind=kind,
        plan=plan, artifact=d["artifact"], signature=d["signature"],
        bytes_in=d["bytes_in"], bytes_out=d["bytes_out"],
        rows_out=d["rows_out"], exec_time_s=d["exec_time_s"],
        producer_cost_s=d.get("producer_cost_s", 0.0),
        history_uses=d.get("history_uses", 0.0),
        created_at=d["created_at"], last_used=d["last_used"],
        use_count=d["use_count"],
        semantic_uses=d.get("semantic_uses", 0),
        saved_s_total=d.get("saved_s_total", 0.0),
        source_versions=d["source_versions"],
        partitioning=d.get("partitioning"))
    if kind != "prefix" and P.plan_signature(plan) != e.signature:
        return None
    return e


def repository_to_json(repo) -> str:
    return json.dumps(
        {"entries": [entry_to_json(e) for e in repo.entries]}, indent=1)


def repository_from_json(text: str, repo=None):
    from .repository import Repository
    repo = repo if repo is not None else Repository()
    data = json.loads(text)
    for d in data["entries"]:
        e = entry_from_json(d)
        if e is not None:
            repo.add(e)
    return repo


def save_repository(repo, path: str) -> None:
    import os
    import tempfile
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d)
    with os.fdopen(fd, "w") as f:
        f.write(repository_to_json(repo))
    os.replace(tmp, path)        # atomic, like the artifact store


def load_repository(path: str, repo=None, journal_path=None):
    """Load a repository state file.  A truncated/corrupt file raises by
    default (pre-§13 behavior); with ``journal_path`` it instead falls
    back to replaying the WAL journal — the crash-consistent source of
    truth the snapshot is merely a compaction of (DESIGN.md §13)."""
    try:
        with open(path) as f:
            return repository_from_json(f.read(), repo)
    except (OSError, ValueError, KeyError, TypeError):
        if journal_path is None:
            raise
        from ..service.journal import replay_journal
        return replay_journal(journal_path, repo)
