"""Physical plan IR: DAGs of physical operators, exactly the abstraction
ReStore matches and rewrites (paper §2, §3).

Operator kinds (the Pig physical-operator set used by the paper):
  LOAD, STORE, PROJECT, FOREACH, FILTER, JOIN, GROUPBY, COGROUP,
  DISTINCT, UNION, SPLIT.

Every operator has a canonical ``local_sig`` (kind + parameters) and a
Merkle ``fingerprint`` (sha256 over local_sig + input fingerprints).  Two
operators are *equivalent* in the paper's sense — same function over
equivalent inputs — iff their fingerprints are equal.  LOAD fingerprints
include the dataset version, which implements eviction rule R4 (modified
inputs never match) structurally.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from ..dataflow.expr import Col, Expr, agg_key, pred_normal_key

# operator kinds whose inputs are order-insensitive
_COMMUTATIVE_KINDS = {"UNION"}
# operators that force a shuffle boundary (map -> reduce)
BLOCKING_KINDS = {"JOIN", "GROUPBY", "COGROUP", "DISTINCT"}
# operators that distribute over input append — F(R ∪ ΔR) = F(R) ∪ F(ΔR)
# record-wise — so a plan built only from these refreshes a stale
# artifact by appending the delta plan's rows (DESIGN.md §12)
APPEND_DISTRIBUTIVE_KINDS = frozenset(
    {"LOAD", "FILTER", "PROJECT", "FOREACH", "UNION", "SPLIT"})


_op_counter = itertools.count()


@dataclasses.dataclass(eq=False)
class Operator:
    kind: str
    params: Dict
    inputs: List["Operator"]
    uid: int = dataclasses.field(default_factory=lambda: next(_op_counter))

    # ------------------------------------------------------------------
    def param_key(self) -> Tuple:
        p = self.params
        k = self.kind
        if k == "LOAD":
            return (p["dataset"], p.get("version", 0))
        if k == "STORE":
            return ()  # store target name is irrelevant for equivalence
        if k == "PROJECT":
            return tuple(sorted(p["cols"]))
        if k == "FOREACH":
            return tuple(sorted((n, e.key()) for n, e in p["gens"].items()))
        if k == "FILTER":
            # normalized digest: commuted / reassociated conjuncts
            # fingerprint equal (DESIGN.md §10)
            return pred_normal_key(p["pred"])
        if k == "JOIN":
            return (tuple(p["left_keys"]), tuple(p["right_keys"]),
                    p.get("expansion", 1))
        if k == "GROUPBY":
            return (tuple(sorted(p["keys"])), agg_key(p["aggs"]))
        if k == "COGROUP":
            return (tuple(p["keys_left"]), tuple(p["keys_right"]),
                    agg_key(p["aggs_left"]), agg_key(p["aggs_right"]))
        if k in ("DISTINCT", "UNION", "SPLIT"):
            return ()
        raise ValueError(f"unknown operator kind {k}")

    def local_sig(self) -> Tuple:
        return (self.kind, self.param_key())

    def __repr__(self):
        return f"{self.kind}#{self.uid}"


# ---------------------------------------------------------------------------
# Builder helpers


def load(dataset: str, version: int = 0, capacity: int | None = None,
         schema=None) -> Operator:
    return Operator("LOAD", dict(dataset=dataset, version=version,
                                 capacity=capacity, schema=schema), [])


def store(inp: Operator, name: str) -> Operator:
    return Operator("STORE", dict(name=name), [inp])


def project(inp: Operator, cols: Sequence[str]) -> Operator:
    return Operator("PROJECT", dict(cols=tuple(cols)), [inp])


def foreach(inp: Operator, gens: Dict[str, Expr]) -> Operator:
    return Operator("FOREACH", dict(gens=dict(gens)), [inp])


def filter_(inp: Operator, pred: Expr) -> Operator:
    return Operator("FILTER", dict(pred=pred), [inp])


def join(left: Operator, right: Operator, left_keys, right_keys,
         expansion: int = 1) -> Operator:
    return Operator("JOIN", dict(left_keys=tuple(left_keys),
                                 right_keys=tuple(right_keys),
                                 expansion=expansion), [left, right])


def groupby(inp: Operator, keys, aggs: Dict[str, Tuple[str, str]]) -> Operator:
    return Operator("GROUPBY", dict(keys=tuple(keys), aggs=dict(aggs)), [inp])


def cogroup(left: Operator, right: Operator, keys_left, keys_right,
            aggs_left, aggs_right) -> Operator:
    return Operator("COGROUP", dict(keys_left=tuple(keys_left),
                                    keys_right=tuple(keys_right),
                                    aggs_left=dict(aggs_left),
                                    aggs_right=dict(aggs_right)),
                    [left, right])


def distinct(inp: Operator) -> Operator:
    return Operator("DISTINCT", {}, [inp])


def union(a: Operator, b: Operator) -> Operator:
    return Operator("UNION", {}, [a, b])


def split(inp: Operator) -> Operator:
    return Operator("SPLIT", {}, [inp])


# ---------------------------------------------------------------------------
# Plan


@dataclasses.dataclass
class PhysicalPlan:
    """A DAG identified by its sink operators (STOREs)."""

    sinks: List[Operator]

    # -- traversal -----------------------------------------------------------
    def topo(self) -> List[Operator]:
        seen: Dict[int, Operator] = {}
        order: List[Operator] = []

        def visit(op: Operator):
            if id(op) in seen:
                return
            seen[id(op)] = op
            for i in op.inputs:
                visit(i)
            order.append(op)

        for s in self.sinks:
            visit(s)
        return order

    def loads(self) -> List[Operator]:
        return [o for o in self.topo() if o.kind == "LOAD"]

    def successors(self) -> Dict[int, List[Operator]]:
        succ: Dict[int, List[Operator]] = {id(o): [] for o in self.topo()}
        for o in self.topo():
            for i in o.inputs:
                succ[id(i)].append(o)
        return succ

    # -- fingerprints ----------------------------------------------------------
    def _fingerprints(self, version_sensitive: bool) -> Dict[int, str]:
        fp: Dict[int, str] = {}
        for op in self.topo():
            in_fps = [fp[id(i)] for i in op.inputs]
            if op.kind in _COMMUTATIVE_KINDS:
                in_fps = sorted(in_fps)
            sig = op.local_sig()
            if not version_sensitive and op.kind == "LOAD":
                sig = (op.kind, (op.params["dataset"],))
            h = hashlib.sha256(
                repr((sig, tuple(in_fps))).encode()).hexdigest()
            fp[id(op)] = h
        return fp

    def fingerprints(self) -> Dict[int, str]:
        return self._fingerprints(version_sensitive=True)

    def structural_fingerprints(self) -> Dict[int, str]:
        """Fingerprints with LOAD dataset *versions* masked out.

        Artifact identity must be version-sensitive (eviction rule R4:
        a churned input invalidates the artifact), but the cost model's
        plan *statistics* should not be — "this operator recurs and is
        expensive" survives a dataset version bump.  Statistics are
        therefore keyed by this version-blind variant (DESIGN.md §9)."""
        return self._fingerprints(version_sensitive=False)

    def fingerprint_of(self, op: Operator) -> str:
        return self.fingerprints()[id(op)]

    # -- rewriting -------------------------------------------------------------
    def replace(self, old: Operator, new: Operator) -> "PhysicalPlan":
        """Return a new plan with ``old``'s subtree replaced by ``new``.

        Downstream operators are rebuilt; untouched subgraphs are shared.
        """
        mapping: Dict[int, Operator] = {id(old): new}

        def rebuild(op: Operator) -> Operator:
            if id(op) in mapping:
                return mapping[id(op)]
            new_inputs = [rebuild(i) for i in op.inputs]
            if all(a is b for a, b in zip(new_inputs, op.inputs)):
                mapping[id(op)] = op
            else:
                mapping[id(op)] = Operator(op.kind, dict(op.params), new_inputs)
            return mapping[id(op)]

        return PhysicalPlan([rebuild(s) for s in self.sinks])

    def subplan_upto(self, op: Operator, store_name: str) -> "PhysicalPlan":
        """The paper's sub-job J_P: everything from the Loads up to and
        including ``op``, terminated by a Store (paper §4)."""
        if op.kind == "STORE":
            return PhysicalPlan([op])
        return PhysicalPlan([store(op, store_name)])

    def describe(self) -> str:
        lines = []
        for op in self.topo():
            ins = ",".join(repr(i) for i in op.inputs)
            lines.append(f"{op!r}({ins}) {op.param_key()}")
        return "\n".join(lines)

    def n_ops(self) -> int:
        return len(self.topo())


def rebind_load_versions(plan: PhysicalPlan,
                         versions: Dict[str, int]) -> PhysicalPlan:
    """Return a copy of ``plan`` whose LOAD operators carry the given
    dataset versions (untouched subgraphs are shared, like `replace`).

    Workload drivers build queries from version-agnostic templates; this
    stamps the catalog's *current* versions into the plan so that LOAD
    fingerprints — and therefore matching — respect rule R4 after
    dataset churn."""
    mapping: Dict[int, Operator] = {}

    def rebuild(op: Operator) -> Operator:
        if id(op) in mapping:
            return mapping[id(op)]
        if op.kind == "LOAD":
            ds = op.params["dataset"]
            if ds in versions and op.params.get("version", 0) != versions[ds]:
                new = Operator("LOAD", dict(op.params), [])
                new.params["version"] = versions[ds]
            else:
                new = op
        else:
            new_inputs = [rebuild(i) for i in op.inputs]
            if all(a is b for a, b in zip(new_inputs, op.inputs)):
                new = op
            else:
                new = Operator(op.kind, dict(op.params), new_inputs)
        mapping[id(op)] = new
        return new

    return PhysicalPlan([rebuild(s) for s in plan.sinks])


# ---------------------------------------------------------------------------
# Partitioning: the physical property behind shuffle-free reuse
# (DESIGN.md §11).  A value is *hash-partitioned* when row r lives on
# shard ``partition_hash(keys)(r) % n_parts`` — the property the mesh
# exchange establishes and FILTER/PROJECT/FOREACH preserve (M3R's
# partition stability).  It is a PHYSICAL property: it never enters
# operator fingerprints, so a partitioned and a monolithic artifact of
# the same value are interchangeable for matching, but a consumer that
# finds the property compatible skips its exchange entirely.


@dataclasses.dataclass(frozen=True)
class Partitioning:
    keys: Tuple[str, ...]          # ordered: the hash is positional
    n_parts: int
    scheme: str = "hash_mod"

    def covers(self, keys, n_parts: int) -> bool:
        """True when data partitioned this way is already co-located for
        a grouping exchange on ``keys`` across ``n_parts`` shards: rows
        equal on ``keys`` are equal on any subset, so they share a
        shard.  (JOIN sides need `aligns`, not `covers`: subset hashing
        would break positional agreement between the two sides.)"""
        return (self.scheme == "hash_mod" and self.n_parts == n_parts
                and set(self.keys) <= set(keys))

    def aligns(self, keys, n_parts: int) -> bool:
        """Exact positional match — required for JOIN/COGROUP sides."""
        return (self.scheme == "hash_mod" and self.n_parts == n_parts
                and tuple(self.keys) == tuple(keys))

    def to_dict(self) -> Dict:
        return {"keys": list(self.keys), "n_parts": self.n_parts,
                "scheme": self.scheme}

    @staticmethod
    def from_dict(d) -> "Optional[Partitioning]":
        if d is None:
            return None
        if isinstance(d, Partitioning):
            return d
        return Partitioning(tuple(d["keys"]), int(d["n_parts"]),
                            d.get("scheme", "hash_mod"))


@dataclasses.dataclass
class PlanProps:
    """Static physical properties of a plan under mesh execution:
    per-op output partitioning, per-blocking-op exchange-skip flags
    (one bool per table input), and per-op output column names."""
    part: Dict[int, Optional[Partitioning]]
    skip: Dict[int, Tuple[bool, ...]]
    schema: Dict[int, Tuple[str, ...]]

    def n_exchanges(self) -> int:
        return sum(len(v) for v in self.skip.values())

    def n_skipped(self) -> int:
        return sum(1 for v in self.skip.values() for s in v if s)


def _join_out_names(left_names, right_names):
    out = list(left_names)
    for n in right_names:
        out.append(n if n not in out else n + "_r")
    return tuple(sorted(out))


def plan_physical_props(plan: PhysicalPlan,
                        dataset_parts: Dict[str, Optional[Partitioning]],
                        dataset_schemas: Dict[str, Tuple[str, ...]],
                        n_parts: Optional[int]) -> PlanProps:
    """Propagate the partition property through a plan (DESIGN.md §11).

    ``dataset_parts``/``dataset_schemas`` describe the LOAD-able inputs
    (artifact manifests + catalog tables); ``n_parts`` is the mesh's
    shuffle-axis size (None = single device, everything unpartitioned).
    Rules: FILTER/SPLIT/STORE preserve; PROJECT preserves iff the keys
    survive; FOREACH preserves iff every key column is an identity
    generator; blocking operators inherit a covering input property
    (their exchange is skipped) or establish a fresh one on their keys;
    UNION destroys the property (concatenation breaks block layout)."""
    part: Dict[int, Optional[Partitioning]] = {}
    skip: Dict[int, Tuple[bool, ...]] = {}
    schema: Dict[int, Tuple[str, ...]] = {}

    for op in plan.topo():
        p = op.params
        in_parts = [part[id(i)] for i in op.inputs]
        in_schemas = [schema[id(i)] for i in op.inputs]
        out_part: Optional[Partitioning] = None
        out_schema: Tuple[str, ...] = in_schemas[0] if in_schemas else ()

        if op.kind == "LOAD":
            # ONLY the store-backed property (dataset_parts) is trusted:
            # a rewriter-spliced LOAD also carries the repository entry's
            # claim in params["partitioning"], but that claim can go
            # stale (e.g. the artifact re-written monolithic by a
            # partition-blind run) and a wrongly-granted skip silently
            # corrupts aggregates
            out_part = Partitioning.from_dict(
                dataset_parts.get(p["dataset"]))
            if n_parts is None or (out_part is not None
                                   and out_part.n_parts != n_parts):
                out_part = None     # mismatched P: no locality to exploit
            out_schema = tuple(sorted(dataset_schemas.get(p["dataset"], ())))
        elif op.kind in ("FILTER", "SPLIT", "STORE"):
            out_part = in_parts[0]
        elif op.kind == "PROJECT":
            out_schema = tuple(sorted(p["cols"]))
            ip = in_parts[0]
            out_part = ip if ip and set(ip.keys) <= set(p["cols"]) else None
        elif op.kind == "FOREACH":
            out_schema = tuple(sorted(p["gens"]))
            ip = in_parts[0]
            if ip and all(isinstance(p["gens"].get(k), Col)
                          and p["gens"][k].name == k for k in ip.keys):
                out_part = ip
        elif op.kind == "UNION":
            out_part = None
        elif op.kind == "GROUPBY":
            keys = tuple(p["keys"])
            out_schema = tuple(sorted(set(keys) | set(p["aggs"])))
            if n_parts is not None:
                ip = in_parts[0]
                if ip is not None and ip.covers(keys, n_parts):
                    skip[id(op)] = (True,)
                    out_part = ip          # partition stability
                else:
                    skip[id(op)] = (False,)
                    out_part = Partitioning(keys, n_parts)
        elif op.kind == "DISTINCT":
            # the exchange keys are ALL columns; any partitioning on a
            # subset of them co-locates equal rows
            if n_parts is not None:
                ip = in_parts[0]
                if ip is not None and ip.covers(out_schema, n_parts):
                    skip[id(op)] = (True,)
                    out_part = ip
                else:
                    skip[id(op)] = (False,)
                    out_part = Partitioning(out_schema, n_parts)
        elif op.kind == "JOIN":
            lkeys, rkeys = tuple(p["left_keys"]), tuple(p["right_keys"])
            out_schema = _join_out_names(in_schemas[0], in_schemas[1])
            if n_parts is not None:
                sl = in_parts[0] is not None \
                    and in_parts[0].aligns(lkeys, n_parts)
                sr = in_parts[1] is not None \
                    and in_parts[1].aligns(rkeys, n_parts)
                skip[id(op)] = (sl, sr)
                out_part = Partitioning(lkeys, n_parts)
        elif op.kind == "COGROUP":
            kl, kr = tuple(p["keys_left"]), tuple(p["keys_right"])
            out_schema = tuple(sorted(
                set(kl) | {f"l_{n}" for n in p["aggs_left"]}
                | {f"r_{n}" for n in p["aggs_right"]}))
            if n_parts is not None:
                sl = in_parts[0] is not None \
                    and in_parts[0].aligns(kl, n_parts)
                sr = in_parts[1] is not None \
                    and in_parts[1].aligns(kr, n_parts)
                both = sl and sr      # the unioned exchange is one unit
                skip[id(op)] = (both, both)
                out_part = Partitioning(kl, n_parts)

        part[id(op)] = out_part
        schema[id(op)] = out_schema
    return PlanProps(part, skip, schema)


# operators an input's partition property survives on the way to its
# first blocking consumer.  PROJECT needs no column check HERE: the
# demand keys come from the blocking consumer itself, and keys a
# consumer exchanges on necessarily survived every projection between
# the Load and that consumer (they exist in its input).
_PART_PRESERVING = {"FILTER", "SPLIT", "STORE", "PROJECT"}


def load_partition_demands(plan: PhysicalPlan) -> Dict[str, Tuple[str, ...]]:
    """dataset name -> the key tuple its first blocking consumer
    exchanges on, walking through partition-preserving operators.  The
    engine uses this to re-partition a mismatched-P artifact on read
    (DESIGN.md §11) so the consumer's exchange can still be skipped."""
    succ = plan.successors()
    out: Dict[str, Tuple[str, ...]] = {}
    for ld in plan.loads():
        frontier = [ld]
        seen = set()
        demand = None
        while frontier and demand is None:
            op = frontier.pop()
            for s in succ.get(id(op), []):
                if id(s) in seen:
                    continue
                seen.add(id(s))
                if s.kind == "GROUPBY":
                    demand = tuple(s.params["keys"])
                elif s.kind == "JOIN":
                    demand = tuple(s.params["left_keys"]) \
                        if s.inputs[0] is op else \
                        tuple(s.params["right_keys"])
                elif s.kind == "COGROUP":
                    demand = tuple(s.params["keys_left"]) \
                        if s.inputs[0] is op else \
                        tuple(s.params["keys_right"])
                elif s.kind in _PART_PRESERVING:
                    frontier.append(s)
                if demand:
                    break
        if demand:
            out[ld.params["dataset"]] = demand
    return out


def plan_signature(plan: PhysicalPlan) -> str:
    """Fingerprint of a single-sink plan's *output* (pre-Store), used as the
    repository key: two plans with the same signature compute the same
    result from the same inputs."""
    assert len(plan.sinks) == 1
    sink = plan.sinks[0]
    target = sink.inputs[0] if sink.kind == "STORE" else sink
    return plan.fingerprints()[id(target)]
