"""Physical plan IR: DAGs of physical operators, exactly the abstraction
ReStore matches and rewrites (paper §2, §3).

Operator kinds (the Pig physical-operator set used by the paper):
  LOAD, STORE, PROJECT, FOREACH, FILTER, JOIN, GROUPBY, COGROUP,
  DISTINCT, UNION, SPLIT.

Every operator has a canonical ``local_sig`` (kind + parameters) and a
Merkle ``fingerprint`` (sha256 over local_sig + input fingerprints).  Two
operators are *equivalent* in the paper's sense — same function over
equivalent inputs — iff their fingerprints are equal.  LOAD fingerprints
include the dataset version, which implements eviction rule R4 (modified
inputs never match) structurally.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from ..dataflow.expr import Expr, agg_key, pred_normal_key

# operator kinds whose inputs are order-insensitive
_COMMUTATIVE_KINDS = {"UNION"}
# operators that force a shuffle boundary (map -> reduce)
BLOCKING_KINDS = {"JOIN", "GROUPBY", "COGROUP", "DISTINCT"}


_op_counter = itertools.count()


@dataclasses.dataclass(eq=False)
class Operator:
    kind: str
    params: Dict
    inputs: List["Operator"]
    uid: int = dataclasses.field(default_factory=lambda: next(_op_counter))

    # ------------------------------------------------------------------
    def param_key(self) -> Tuple:
        p = self.params
        k = self.kind
        if k == "LOAD":
            return (p["dataset"], p.get("version", 0))
        if k == "STORE":
            return ()  # store target name is irrelevant for equivalence
        if k == "PROJECT":
            return tuple(sorted(p["cols"]))
        if k == "FOREACH":
            return tuple(sorted((n, e.key()) for n, e in p["gens"].items()))
        if k == "FILTER":
            # normalized digest: commuted / reassociated conjuncts
            # fingerprint equal (DESIGN.md §10)
            return pred_normal_key(p["pred"])
        if k == "JOIN":
            return (tuple(p["left_keys"]), tuple(p["right_keys"]),
                    p.get("expansion", 1))
        if k == "GROUPBY":
            return (tuple(sorted(p["keys"])), agg_key(p["aggs"]))
        if k == "COGROUP":
            return (tuple(p["keys_left"]), tuple(p["keys_right"]),
                    agg_key(p["aggs_left"]), agg_key(p["aggs_right"]))
        if k in ("DISTINCT", "UNION", "SPLIT"):
            return ()
        raise ValueError(f"unknown operator kind {k}")

    def local_sig(self) -> Tuple:
        return (self.kind, self.param_key())

    def __repr__(self):
        return f"{self.kind}#{self.uid}"


# ---------------------------------------------------------------------------
# Builder helpers


def load(dataset: str, version: int = 0, capacity: int | None = None,
         schema=None) -> Operator:
    return Operator("LOAD", dict(dataset=dataset, version=version,
                                 capacity=capacity, schema=schema), [])


def store(inp: Operator, name: str) -> Operator:
    return Operator("STORE", dict(name=name), [inp])


def project(inp: Operator, cols: Sequence[str]) -> Operator:
    return Operator("PROJECT", dict(cols=tuple(cols)), [inp])


def foreach(inp: Operator, gens: Dict[str, Expr]) -> Operator:
    return Operator("FOREACH", dict(gens=dict(gens)), [inp])


def filter_(inp: Operator, pred: Expr) -> Operator:
    return Operator("FILTER", dict(pred=pred), [inp])


def join(left: Operator, right: Operator, left_keys, right_keys,
         expansion: int = 1) -> Operator:
    return Operator("JOIN", dict(left_keys=tuple(left_keys),
                                 right_keys=tuple(right_keys),
                                 expansion=expansion), [left, right])


def groupby(inp: Operator, keys, aggs: Dict[str, Tuple[str, str]]) -> Operator:
    return Operator("GROUPBY", dict(keys=tuple(keys), aggs=dict(aggs)), [inp])


def cogroup(left: Operator, right: Operator, keys_left, keys_right,
            aggs_left, aggs_right) -> Operator:
    return Operator("COGROUP", dict(keys_left=tuple(keys_left),
                                    keys_right=tuple(keys_right),
                                    aggs_left=dict(aggs_left),
                                    aggs_right=dict(aggs_right)),
                    [left, right])


def distinct(inp: Operator) -> Operator:
    return Operator("DISTINCT", {}, [inp])


def union(a: Operator, b: Operator) -> Operator:
    return Operator("UNION", {}, [a, b])


def split(inp: Operator) -> Operator:
    return Operator("SPLIT", {}, [inp])


# ---------------------------------------------------------------------------
# Plan


@dataclasses.dataclass
class PhysicalPlan:
    """A DAG identified by its sink operators (STOREs)."""

    sinks: List[Operator]

    # -- traversal -----------------------------------------------------------
    def topo(self) -> List[Operator]:
        seen: Dict[int, Operator] = {}
        order: List[Operator] = []

        def visit(op: Operator):
            if id(op) in seen:
                return
            seen[id(op)] = op
            for i in op.inputs:
                visit(i)
            order.append(op)

        for s in self.sinks:
            visit(s)
        return order

    def loads(self) -> List[Operator]:
        return [o for o in self.topo() if o.kind == "LOAD"]

    def successors(self) -> Dict[int, List[Operator]]:
        succ: Dict[int, List[Operator]] = {id(o): [] for o in self.topo()}
        for o in self.topo():
            for i in o.inputs:
                succ[id(i)].append(o)
        return succ

    # -- fingerprints ----------------------------------------------------------
    def _fingerprints(self, version_sensitive: bool) -> Dict[int, str]:
        fp: Dict[int, str] = {}
        for op in self.topo():
            in_fps = [fp[id(i)] for i in op.inputs]
            if op.kind in _COMMUTATIVE_KINDS:
                in_fps = sorted(in_fps)
            sig = op.local_sig()
            if not version_sensitive and op.kind == "LOAD":
                sig = (op.kind, (op.params["dataset"],))
            h = hashlib.sha256(
                repr((sig, tuple(in_fps))).encode()).hexdigest()
            fp[id(op)] = h
        return fp

    def fingerprints(self) -> Dict[int, str]:
        return self._fingerprints(version_sensitive=True)

    def structural_fingerprints(self) -> Dict[int, str]:
        """Fingerprints with LOAD dataset *versions* masked out.

        Artifact identity must be version-sensitive (eviction rule R4:
        a churned input invalidates the artifact), but the cost model's
        plan *statistics* should not be — "this operator recurs and is
        expensive" survives a dataset version bump.  Statistics are
        therefore keyed by this version-blind variant (DESIGN.md §9)."""
        return self._fingerprints(version_sensitive=False)

    def fingerprint_of(self, op: Operator) -> str:
        return self.fingerprints()[id(op)]

    # -- rewriting -------------------------------------------------------------
    def replace(self, old: Operator, new: Operator) -> "PhysicalPlan":
        """Return a new plan with ``old``'s subtree replaced by ``new``.

        Downstream operators are rebuilt; untouched subgraphs are shared.
        """
        mapping: Dict[int, Operator] = {id(old): new}

        def rebuild(op: Operator) -> Operator:
            if id(op) in mapping:
                return mapping[id(op)]
            new_inputs = [rebuild(i) for i in op.inputs]
            if all(a is b for a, b in zip(new_inputs, op.inputs)):
                mapping[id(op)] = op
            else:
                mapping[id(op)] = Operator(op.kind, dict(op.params), new_inputs)
            return mapping[id(op)]

        return PhysicalPlan([rebuild(s) for s in self.sinks])

    def subplan_upto(self, op: Operator, store_name: str) -> "PhysicalPlan":
        """The paper's sub-job J_P: everything from the Loads up to and
        including ``op``, terminated by a Store (paper §4)."""
        if op.kind == "STORE":
            return PhysicalPlan([op])
        return PhysicalPlan([store(op, store_name)])

    def describe(self) -> str:
        lines = []
        for op in self.topo():
            ins = ",".join(repr(i) for i in op.inputs)
            lines.append(f"{op!r}({ins}) {op.param_key()}")
        return "\n".join(lines)

    def n_ops(self) -> int:
        return len(self.topo())


def rebind_load_versions(plan: PhysicalPlan,
                         versions: Dict[str, int]) -> PhysicalPlan:
    """Return a copy of ``plan`` whose LOAD operators carry the given
    dataset versions (untouched subgraphs are shared, like `replace`).

    Workload drivers build queries from version-agnostic templates; this
    stamps the catalog's *current* versions into the plan so that LOAD
    fingerprints — and therefore matching — respect rule R4 after
    dataset churn."""
    mapping: Dict[int, Operator] = {}

    def rebuild(op: Operator) -> Operator:
        if id(op) in mapping:
            return mapping[id(op)]
        if op.kind == "LOAD":
            ds = op.params["dataset"]
            if ds in versions and op.params.get("version", 0) != versions[ds]:
                new = Operator("LOAD", dict(op.params), [])
                new.params["version"] = versions[ds]
            else:
                new = op
        else:
            new_inputs = [rebuild(i) for i in op.inputs]
            if all(a is b for a, b in zip(new_inputs, op.inputs)):
                new = op
            else:
                new = Operator(op.kind, dict(op.params), new_inputs)
        mapping[id(op)] = new
        return new

    return PhysicalPlan([rebuild(s) for s in plan.sinks])


def plan_signature(plan: PhysicalPlan) -> str:
    """Fingerprint of a single-sink plan's *output* (pre-Store), used as the
    repository key: two plans with the same signature compute the same
    result from the same inputs."""
    assert len(plan.sinks) == 1
    sink = plan.sinks[0]
    target = sink.inputs[0] if sink.kind == "STORE" else sink
    return plan.fingerprints()[id(target)]
