"""Prompt prefixes as physical plans (DESIGN.md §17).

ReStore's repository stores *plans* and the artifacts they produced.
The serving path stores *token prefixes* and the KV/recurrent state
prefilling them produced.  This module makes the correspondence literal:
a `PrefixPlan` is the PhysicalPlan-analog of a prompt prefix — a chain
of per-token "operators" whose Merkle fingerprints play exactly the role
`plan.fingerprints()` plays for relational plans:

  fingerprint(prefix) = H(fingerprint(prefix[:-1]), token[-1])

seeded with the model version (the "input dataset" of the decode path:
a weight change invalidates every stored state, rule R4).  A
`RepositoryEntry` built over a `PrefixPlan` (``kind="prefix"``) lives in
the SAME byte-budgeted `Repository` as analytics artifacts and is
priced by the same `CostModel` — producer cost is the calibrated
prefill cost of the prefix, load cost is the tier read of the KV bytes.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional

import numpy as np


def prefix_fingerprints(tokens, model_version: str) -> List[str]:
    """Fingerprint of every prefix of a token sequence (Merkle chain)."""
    out = []
    h = hashlib.sha256(model_version.encode()).hexdigest()
    for t in tokens:
        h = hashlib.sha256(f"{h}:{int(t)}".encode()).hexdigest()
        out.append(h)
    return out


class PrefixOp:
    """Pseudo-operator standing for the whole prefill of a prefix.

    Exists so kind-agnostic cost-model code (`should_splice` scans
    ``entry.plan.topo()`` for streaming kinds) works unchanged:
    ``"PREFIX"`` is not a streaming kind — prefill amortizes quadratic
    attention work, so a stored prefix always splices.
    """

    kind = "PREFIX"

    def __init__(self, plan: "PrefixPlan"):
        self.params = {"length": len(plan.tokens),
                       "model_version": plan.model_version}
        self.inputs: list = []


class PrefixPlan:
    """PhysicalPlan-analog for a token prefix (DESIGN.md §17).

    Duck-types the slice of the `PhysicalPlan` API the repository,
    cost model, and serializer touch: ``n_ops`` (token count — the
    ordering rule "longest prefix first" falls out of the repository's
    existing ``-n_ops`` sort), ``topo``, ``fingerprints``, and a
    content signature (the Merkle fingerprint of the full prefix).
    """

    def __init__(self, tokens, model_version: str,
                 fingerprints: Optional[List[str]] = None):
        self.tokens = np.asarray(tokens, np.int32)
        self.model_version = str(model_version)
        self._fps = (list(fingerprints) if fingerprints is not None
                     else prefix_fingerprints(self.tokens, model_version))
        if len(self._fps) != len(self.tokens) or not self._fps:
            raise ValueError("prefix plan needs one fingerprint per token")
        self._op = PrefixOp(self)

    @property
    def signature(self) -> str:
        return self._fps[-1]

    def n_ops(self) -> int:
        return int(len(self.tokens))

    def topo(self):
        return [self._op]

    def fingerprints(self) -> Dict[int, str]:
        """Per-prefix-length fingerprints, keyed by length (the analog
        of per-operator fingerprints keyed by operator)."""
        return {i + 1: fp for i, fp in enumerate(self._fps)}

    def prefix(self, length: int) -> "PrefixPlan":
        """The sub-plan covering the first ``length`` tokens (the
        sub-job analog; shares the already-computed fingerprint chain)."""
        if not 0 < length <= len(self.tokens):
            raise ValueError(f"bad prefix length {length}")
        return PrefixPlan(self.tokens[:length], self.model_version,
                          fingerprints=self._fps[:length])

    def is_prefix_of(self, other: "PrefixPlan") -> bool:
        return (len(self.tokens) <= len(other.tokens)
                and other._fps[len(self.tokens) - 1] == self.signature)


def prefix_plan_signature(plan: PrefixPlan) -> str:
    return plan.signature


def make_prefix_entry(plan: PrefixPlan, artifact: str, *, nbytes: int,
                      producer_cost_s: float = 0.0, created_at: float = 0.0,
                      history_uses: float = 0.0,
                      source_versions: Optional[Dict[str, int]] = None):
    """A repository entry for a stored prefix state.  ``nbytes=0`` marks
    an alias entry: an intermediate prefix length sharing the parent
    snapshot's arrays (the sub-job-enumeration analog) — it charges the
    budget nothing and is dropped with its parent artifact."""
    from .repository import RepositoryEntry
    return RepositoryEntry(
        plan=plan, artifact=artifact, signature=plan.signature,
        bytes_in=0, bytes_out=int(nbytes), rows_out=plan.n_ops(),
        exec_time_s=producer_cost_s, producer_cost_s=producer_cost_s,
        created_at=created_at, history_uses=history_uses,
        source_versions=dict(source_versions or {}), kind="prefix")
