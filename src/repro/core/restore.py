"""The ReStore driver (paper Fig. 7, §6.2).

Mirrors the extended JobControlCompiler: jobs are processed in dependency
order; each job's plan goes through (1) matching + rewriting against the
repository, (2) sub-job enumeration, then is executed; statistics are
retrieved and the outputs registered in the repository.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from ..dataflow.compiler import Job, Workflow, compile_workflow
from ..dataflow.executor import Engine, JobStats
from ..store.artifacts import ArtifactStore, Catalog
from .enumerator import enumerate_subjobs, whole_job_candidates
from .plan import PhysicalPlan
from .repository import Repository, make_entry
from .rewriter import is_trivial, rewrite_plan


@dataclasses.dataclass
class JobReport:
    job_id: int
    executed: bool
    reused_artifacts: List[str]
    stored_candidates: List[str]
    stats: Optional[JobStats]
    n_ops_before: int = 0
    n_ops_after: int = 0


@dataclasses.dataclass
class RunReport:
    jobs: List[JobReport]
    wall_s: float = 0.0

    @property
    def n_executed(self) -> int:
        return sum(1 for j in self.jobs if j.executed)

    @property
    def n_reused(self) -> int:
        return sum(len(j.reused_artifacts) for j in self.jobs)

    @property
    def total_wall_s(self) -> float:
        return sum(j.stats.wall_s for j in self.jobs if j.stats)


class ReStore:
    def __init__(self, catalog: Catalog, store: ArtifactStore,
                 repository: Optional[Repository] = None,
                 heuristic: str = "aggressive",
                 use_algorithm1: bool = False,
                 rewrite_enabled: bool = True,
                 measure_exec: bool = False):
        self.catalog = catalog
        self.store = store
        self.repo = repository if repository is not None else Repository()
        self.engine = Engine(catalog, store, measure_exec=measure_exec)
        self.heuristic = heuristic
        self.use_algorithm1 = use_algorithm1
        self.rewrite_enabled = rewrite_enabled

    # ------------------------------------------------------------------
    def run_plan(self, plan: PhysicalPlan):
        return self.run_workflow(compile_workflow(plan))

    def run_workflow(self, wf: Workflow):
        reports: List[JobReport] = []
        for job in wf.jobs:
            reports.append(self._process_job(job))
        results = {user: self.store.get(ds)
                   for user, ds in wf.final_outputs.items()}
        # workflow end is a durability point for the write-behind store
        self.store.flush()
        return results, RunReport(reports)

    # ------------------------------------------------------------------
    def _process_job(self, job: Job) -> JobReport:
        # a job whose outputs all exist is fully answered by the store
        if all(self.store.exists(o) for o in job.outputs):
            return JobReport(job.job_id, False, list(job.outputs), [], None,
                             job.plan.n_ops(), 0)

        n_before = job.plan.n_ops()
        if self.rewrite_enabled:
            rw = rewrite_plan(job.plan, self.repo,
                              use_algorithm1=self.use_algorithm1)
            plan, used, origin = rw.plan, rw.used, rw.origin
        else:
            plan = job.plan
            used = []
            origin = {id(op): op for op in plan.topo()}

        if is_trivial(plan):
            # fully reused: alias outputs to the loaded artifacts
            for s in plan.sinks:
                self.store.alias(s.params["name"],
                                 s.inputs[0].params["dataset"])
            return JobReport(job.job_id, False,
                             [e.artifact for e in used], [], None,
                             n_before, plan.n_ops())

        exec_plan, cands = enumerate_subjobs(plan, origin, job.plan,
                                             self.heuristic)
        cands = cands + whole_job_candidates(plan, origin, job.plan)

        exec_job = Job(job.job_id, exec_plan,
                       inputs=sorted({o.params["dataset"]
                                      for o in exec_plan.loads()}),
                       outputs=[s.params["name"] for s in exec_plan.sinks],
                       blocking=job.blocking)
        outputs, stats = self.engine.run_job(exec_job)

        stored = []
        versions = {ds: self.catalog.version(ds) for ds in exec_job.inputs
                    if not ds.startswith("art/")}
        for c in cands:
            if not self.store.exists(c.artifact):
                continue
            entry = make_entry(
                c.plan, c.artifact,
                bytes_in=stats.bytes_in,
                bytes_out=self.store.nbytes(c.artifact),
                rows_out=stats.op_rows.get(c.exec_op_uid, 0),
                exec_time_s=stats.wall_s,
                source_versions=versions)
            if self.repo.add(entry):
                stored.append(c.artifact)

        return JobReport(job.job_id, True, [e.artifact for e in used],
                         stored, stats, n_before, exec_plan.n_ops())
