"""The ReStore driver (paper Fig. 7, §6.2; economics in DESIGN.md §9).

Mirrors the extended JobControlCompiler: jobs are processed in dependency
order; each job's plan goes through (1) matching + rewriting against the
repository, (2) sub-job enumeration, then is executed; statistics are
retrieved and the outputs registered in the repository.

Beyond the paper's driver, every execution feeds the repository's cost
model: per-op producer costs (attributed from the job's wall time),
output sizes, and the store's measured IO bandwidth.  Under the
``"cost"`` heuristic those statistics decide which sub-jobs are
materialized, and under a repository byte budget they decide which
entries survive — a candidate the repository rejects has its artifact
deleted from the store again (admission replaces the old unconditional
put).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from ..dataflow.builder import as_plan
from ..dataflow.compiler import Job, Workflow, compile_workflow
from ..dataflow.executor import Engine, JobStats
from ..store.artifacts import (ArtifactError, ArtifactFlushError,
                               ArtifactStore, Catalog)
from .enumerator import enumerate_subjobs, whole_job_candidates
from .plan import PhysicalPlan
from .repository import Repository, make_entry
from .rewriter import is_trivial, rewrite_plan


@dataclasses.dataclass
class JobReport:
    job_id: int
    executed: bool
    reused_artifacts: List[str]
    stored_candidates: List[str]
    stats: Optional[JobStats]
    n_ops_before: int = 0
    n_ops_after: int = 0
    rejected_candidates: List[str] = dataclasses.field(default_factory=list)
    n_semantic: int = 0               # subsumption hits among the reuses


@dataclasses.dataclass
class RunReport:
    jobs: List[JobReport]
    wall_s: float = 0.0
    # artifacts quarantined (corrupt/missing -> recomputed cold) during
    # this run: reuse degraded, correctness did not (DESIGN.md §13)
    degraded: int = 0
    # artifact names whose write-behind flush failed permanently at the
    # end-of-run durability barrier (they are de-advertised; the run's
    # results are unaffected — they were computed on device)
    flush_failures: List[str] = dataclasses.field(default_factory=list)

    @property
    def n_executed(self) -> int:
        return sum(1 for j in self.jobs if j.executed)

    @property
    def n_reused(self) -> int:
        return sum(len(j.reused_artifacts) for j in self.jobs)

    @property
    def n_semantic(self) -> int:
        return sum(j.n_semantic for j in self.jobs)

    @property
    def total_wall_s(self) -> float:
        return sum(j.stats.wall_s for j in self.jobs if j.stats)


class ReStore:
    def __init__(self, catalog: Catalog, store: ArtifactStore,
                 repository: Optional[Repository] = None,
                 heuristic: str = "aggressive",
                 use_algorithm1: bool = False,
                 rewrite_enabled: bool = True,
                 semantic: bool = True,
                 measure_exec: bool = False,
                 repeats: int = 5,
                 mesh=None, shuffle_axis: str = "data",
                 skew_factor: float = 4.0, partition_aware: bool = True,
                 min_splice_benefit_s: float = 1e-3):
        self.catalog = catalog
        self.store = store
        if repository is not None:
            self.repo = repository
        else:
            # engine-owned repository: arm the exact-splice admission
            # guard (CostModel.should_splice) — a streaming-only region
            # whose predicted byte-diet saving cannot clear the splice
            # overhead recomputes instead of reusing (the L7 fix).  A
            # caller-supplied repository keeps its own cost model as-is.
            self.repo = Repository()
            self.repo.cost_model.min_splice_benefit_s = min_splice_benefit_s
        self.repo.bind_store(store)
        # mesh: run every job's map->shuffle->reduce stages across a JAX
        # device mesh (DESIGN.md §11); partition_aware=False is the
        # partition-blind ablation (artifacts monolithic, every
        # exchange always runs)
        self.engine = Engine(catalog, store, measure_exec=measure_exec,
                             repeats=repeats, mesh=mesh,
                             shuffle_axis=shuffle_axis,
                             skew_factor=skew_factor,
                             partition_aware=partition_aware)
        self.heuristic = heuristic
        self.use_algorithm1 = use_algorithm1
        self.rewrite_enabled = rewrite_enabled
        # subsumption fallback (DESIGN.md §10): when the exact probes of
        # both reuse paths miss — the whole-job fast path (store hit on
        # identical outputs) and the exact rewrite scan — covering
        # artifacts may still answer sub-plans through compensation
        self.semantic = semantic
        # boundary artifact -> source-dataset versions it was derived
        # from, so entries of downstream jobs (whose plans load art/...
        # names) still carry the *transitive* source versions rule R4's
        # garbage collector needs
        self._art_versions: Dict[str, Dict[str, int]] = {}
        # artifacts pinned mid-run beyond the boundary names: when a
        # reused job ALIASES its output to a repository artifact, that
        # backing artifact must survive budget eviction until the
        # workflow is done (downstream jobs load it through the alias)
        self._run_pins: set = set()
        # artifacts quarantined + recomputed cold in the current run
        self._degraded = 0

    # ------------------------------------------------------------------
    def run(self, query):
        """Unified submission surface (DESIGN.md §16): accept either a
        ``PhysicalPlan`` or a Pig-style ``dataflow.builder.Dataflow``
        (lowered via its ``build()``), compile to a workflow and run it.
        Returns ``(results, RunReport)``."""
        return self.run_workflow(compile_workflow(as_plan(query)))

    def run_plan(self, plan: PhysicalPlan):
        """Deprecated alias for :meth:`run` (pre-§16 signature; kept so
        existing call sites migrate incrementally)."""
        return self.run(plan)

    def run_batch(self, queries, semantic: bool = True):
        """Run a batch of queries through the multi-query optimizer
        (DESIGN.md §16): shared sub-plans execute once, then each query
        runs against the materialized shared work.  Returns a
        :class:`repro.core.mqo.BatchResult`."""
        from .mqo import run_batch
        return run_batch(self, queries, semantic=semantic)

    def run_workflow(self, wf: Workflow):
        # job-boundary artifacts are loaded by downstream jobs of THIS
        # workflow: pin them so budget eviction cannot delete them
        # mid-run, then settle back under budget once the run is over
        boundary = {o for job in wf.jobs for o in job.outputs}
        self.repo.pin(boundary)
        self._degraded = 0
        try:
            # graceful degradation (DESIGN.md §13): an ArtifactError while
            # gathering results means a boundary artifact went bad AFTER
            # its job completed — quarantine it and replay the workflow;
            # intact jobs short-circuit through the fast path, only the
            # damaged one recomputes.  Per-job faults degrade inside
            # _process_job; this loop only absorbs the gather window.
            for cycle in range(3):
                reports: List[JobReport] = []
                try:
                    for job in wf.jobs:
                        reports.append(self._process_job(job))
                    results = {user: self.store.get(ds)
                               for user, ds in wf.final_outputs.items()}
                    break
                except ArtifactError as e:
                    if e.name is None or cycle == 2:
                        raise
                    self._degrade(e)
        finally:
            # unpin mirrors the two pin sites exactly (boundary at run
            # start, _pin_for_run increments during the run): pins are
            # refcounted so concurrent workflows sharing the repository
            # don't release each other's protection
            self.repo.unpin(boundary)
            self.repo.unpin(self._run_pins)
            self._run_pins = set()
        self.repo.rebalance()
        # workflow end is a durability point for the write-behind store.
        # A permanent flush failure does not invalidate the results (they
        # were computed on device); the failed artifacts are already
        # de-advertised — report them instead of failing the run.
        flush_failures: List[str] = []
        try:
            self.store.flush()
        except ArtifactFlushError as e:
            flush_failures = sorted(e.failures)
        return results, RunReport(reports, degraded=self._degraded,
                                  flush_failures=flush_failures)

    def maintain(self, mode: str = "auto", only=None) -> Dict[str, int]:
        """Incremental maintenance entry point (DESIGN.md §12): refresh
        append-stale repository artifacts from their dataset deltas
        through this driver's engine; entries with no derivable delta
        plan fall back to R4 deletion.  Call after `Catalog.append`/
        `Catalog.register` churn, where `evict_stale` used to be.
        ``only`` restricts the sweep to a set of artifact names (the
        prefetcher's ahead-of-arrival refresh, DESIGN.md §15)."""
        return self.repo.maintain(self.catalog, self.engine, self.store,
                                  mode=mode, only=only)

    # ------------------------------------------------------------------
    def _degrade(self, e: ArtifactError) -> None:
        """Absorb one artifact failure: quarantine the damaged bytes,
        un-advertise every repository entry backed by them, count it.
        The caller then retries — with the artifact gone, matching
        cannot pick it again, so the retry recomputes cold."""
        self._degraded += 1
        self.store.quarantine(e.name)
        self.repo.drop_artifact(e.name)

    def _process_job(self, job: Job) -> JobReport:
        """One job with graceful degradation: an ArtifactError from the
        reuse machinery (corrupt npz, missing file, flaky IO past its
        retries) quarantines the named artifact and retries; the final
        attempt runs with rewriting disabled — fully cold — so reuse is
        never a correctness dependency (DESIGN.md §13)."""
        last: Optional[ArtifactError] = None
        for attempt in range(3):
            try:
                return self._process_job_once(
                    job, rewrite_enabled=(self.rewrite_enabled
                                          and attempt < 2))
            except ArtifactError as e:
                if e.name is None:
                    raise
                last = e
                self._degrade(e)
        raise last

    def _process_job_once(self, job: Job,
                          rewrite_enabled: bool = True) -> JobReport:
        # lazily-deferred refreshes whose probe has arrived run first,
        # so the refreshed entries match exactly below (DESIGN.md §12)
        if self.repo.pending_refresh:
            self.repo.refresh_pending(job.plan, self.engine, self.catalog,
                                      self.store)
        # a job whose outputs all exist is fully answered by the store
        if all(self.store.exists(o) for o in job.outputs):
            # this is the hottest reuse path (identical recurring jobs):
            # credit the backing entries — resolving aliases, since a
            # previously reused job serves its output THROUGH an alias
            # to the backing artifact — or budget eviction would rank
            # exactly the most-reused artifacts as unused
            outs = {self.store._resolve(o) for o in job.outputs} \
                | set(job.outputs)
            cm = self.repo.cost_model
            for e in self.repo.entries:
                if e.artifact in outs:
                    saved = cm.savings_per_reuse_s(
                        e.producer_cost_s or e.exec_time_s, e.bytes_out)
                    self.repo.record_use(e, saved_s=max(saved, 0.0))
            self._pin_for_run(outs)
            return JobReport(job.job_id, False, list(job.outputs), [], None,
                             job.plan.n_ops(), 0)

        n_before = job.plan.n_ops()
        n_semantic = 0
        comp_ids = set()
        if rewrite_enabled:
            # mesh context lets the rewriter price the exchanges a
            # co-partitioned artifact avoids (DESIGN.md §11)
            n_shards = self.engine.n_shards \
                if self.engine.partition_aware else None
            rw = rewrite_plan(job.plan, self.repo,
                              use_algorithm1=self.use_algorithm1,
                              semantic=self.semantic,
                              n_shards=n_shards)
            plan, used, origin = rw.plan, rw.used, rw.origin
            n_semantic = rw.n_semantic
            comp_ids = rw.comp_op_ids
        else:
            plan = job.plan
            used = []
            origin = {id(op): op for op in plan.topo()}

        if is_trivial(plan):
            # fully reused: alias outputs to the loaded artifacts
            trivial_versions = {}
            for e in used:
                trivial_versions.update(e.source_versions)
            for s in plan.sinks:
                self.store.alias(s.params["name"],
                                 s.inputs[0].params["dataset"])
                self._art_versions[s.params["name"]] = dict(trivial_versions)
                # the alias target backs this job's output for the rest
                # of the workflow: keep it safe from budget eviction
                self._pin_for_run({self.store._resolve(s.params["name"])})
            return JobReport(job.job_id, False,
                             [e.artifact for e in used], [], None,
                             n_before, plan.n_ops(),
                             n_semantic=n_semantic)

        exec_plan, cands = enumerate_subjobs(plan, origin, job.plan,
                                             self.heuristic,
                                             cost_model=self.repo.cost_model)
        whole = whole_job_candidates(plan, origin, job.plan)

        exec_job = Job(job.job_id, exec_plan,
                       inputs=sorted({o.params["dataset"]
                                      for o in exec_plan.loads()}),
                       outputs=[s.params["name"] for s in exec_plan.sinks],
                       blocking=job.blocking)
        outputs, stats = self.engine.run_job(exec_job)

        self._observe_execution(job.plan, exec_plan, origin, stats,
                                skip_ids=comp_ids)

        stored, rejected = [], []
        versions: Dict[str, int] = {}
        for ds in exec_job.inputs:
            if ds.startswith("art/"):
                versions.update(self._versions_of_artifact(ds))
            else:
                versions[ds] = self.catalog.version(ds)
        for o in exec_job.outputs:
            self._art_versions[o] = dict(versions)
        for c, injected in [(c, True) for c in cands] + \
                           [(c, False) for c in whole]:
            if not self.store.exists(c.artifact):
                continue
            nbytes = self.store.nbytes(c.artifact)
            self.repo.cost_model.observe_stored_bytes(c.struct_fp, nbytes)
            op_hist = self.repo.cost_model.stats_for(c.struct_fp)
            entry = make_entry(
                c.plan, c.artifact,
                bytes_in=stats.bytes_in,
                bytes_out=nbytes,
                rows_out=stats.op_rows.get(c.exec_op_uid, 0),
                exec_time_s=stats.wall_s,
                producer_cost_s=stats.op_cost_s.get(c.exec_op_uid,
                                                    stats.wall_s),
                # seed admission with observed recurrence OR the batch
                # optimizer's known consumer count (§16), whichever is
                # stronger — known uses are facts about queued queries
                history_uses=max(
                    op_hist.times_seen if op_hist else 0.0,
                    self.repo.cost_model.known_uses_for(
                        c.struct_fp, c.artifact)),
                source_versions=versions,
                # partition property of the candidate's output under
                # mesh execution — what future rewrites splice in as a
                # shuffle-free Load (DESIGN.md §11)
                partitioning=stats.op_partitioning.get(c.exec_op_uid))
            if self.repo.add(entry):
                stored.append(c.artifact)
            elif injected and entry.signature not in self.repo.by_sig \
                    and c.artifact not in job.outputs:
                # an injected sub-job artifact the repository refused to
                # keep is dead weight: nothing will ever match it, so
                # reclaim its bytes (whole-job outputs stay — they are
                # the workflow's actual results)
                self.store.delete(c.artifact)
                rejected.append(c.artifact)

        return JobReport(job.job_id, True, [e.artifact for e in used],
                         stored, stats, n_before, exec_plan.n_ops(),
                         rejected_candidates=rejected,
                         n_semantic=n_semantic)

    def _pin_for_run(self, names) -> None:
        """Pin artifacts until the current workflow run finishes (used
        for alias targets that back reused job outputs).  Each name is
        pinned at most once per run so the single unpin in run_workflow
        balances the refcount exactly."""
        new = set(names) - self._run_pins
        if new:
            self._run_pins |= new
            self.repo.pin(new)

    def _versions_of_artifact(self, name: str) -> Dict[str, int]:
        """Transitive source versions of a boundary artifact: from this
        driver's run history, falling back to the repository entry that
        recorded the artifact (a fresh driver over a warm repo)."""
        v = self._art_versions.get(name)
        if v is not None:
            return v
        for e in self.repo.entries:
            if e.artifact == name:
                return e.source_versions
        return {}

    # ------------------------------------------------------------------
    def _observe_execution(self, orig_plan: PhysicalPlan,
                           exec_plan: PhysicalPlan,
                           origin: Dict[int, object],
                           stats: JobStats,
                           skip_ids=frozenset()) -> None:
        """Feed one job's measured statistics into the cost model: per-op
        rows / byte estimates / attributed producer cost, keyed by
        structural fingerprint, plus the store's IO bandwidth samples.
        Every executed operator counts as a missed reuse opportunity —
        exactly the signal `should_materialize` needs next time.
        ``skip_ids`` holds the semantic compensation roots: they carry
        the anchor's origin (so the enumerator can re-materialize the
        exact value) but their execution is a reuse HIT, not a miss, and
        their cheap residual-pass cost must not pollute the original
        operator's producer-cost estimate (DESIGN.md §10)."""
        cm = self.repo.cost_model
        struct_fps = orig_plan.structural_fingerprints()
        row_width = stats.bytes_in / max(stats.rows_in, 1)
        for op in exec_plan.topo():
            if op.kind in ("LOAD", "STORE", "SPLIT") or id(op) in skip_ids:
                continue
            orig = origin.get(id(op))
            if orig is None or id(orig) not in struct_fps:
                continue
            rows = stats.op_rows.get(op.uid, 0)
            cm.observe_op(struct_fps[id(orig)],
                          rows_out=rows,
                          bytes_out=int(rows * row_width),
                          producer_cost_s=stats.op_cost_s.get(
                              op.uid, stats.wall_s))
        cm.calibrate_io(self.store)
