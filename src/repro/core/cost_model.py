"""Repository economics: the cost model behind keep/evict decisions
(paper §5; gain/loss framing after Chakroborti et al., arXiv:2202.06473;
DESIGN.md §9).

The paper decides *which* job and sub-job outputs to materialize from
collected plan statistics.  This module is the single place those
statistics meet a price:

  * **IO price** — load/store bandwidth, calibrated online from the
    artifact store's measured transfer samples (`calibrate_io`), so the
    same policy code prices a device-cache hit (~free) and a cold disk
    read (bytes / bandwidth) correctly.
  * **Plan statistics** — per-operator rows/bytes/producer-cost keyed by
    *structural* fingerprint (dataset versions masked), fed by the
    executor's per-op cost attribution (`JobStats.op_cost_s`).  Keying
    structurally lets statistics survive dataset-version churn: the
    artifact of a churned input can never be reused (rule R4), but the
    knowledge "this operator is expensive and recurs" can.
  * **Decisions** — `should_materialize` (sub-job admission at
    enumeration time) and `benefit_per_byte` (the knapsack-style ranking
    the byte-budgeted repository evicts by).

Benefit model (Eq. analogous to paper Eq. 1/2):

  savings_per_reuse = producer_cost − load_cost(bytes)
  benefit           = savings_per_reuse × expected_future_uses
  materialize iff     benefit > store_cost(bytes) + fixed_io
  evict by ascending  benefit / bytes  (recency-decayed)

`expected_future_uses` is a history-repeats estimator: every *observed
execution* of an operator was a missed reuse opportunity, so an operator
seen k times is predicted to recur ~k more times; a repository entry's
future uses decay with time since last use (half-life) from its hit
count.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

# Operator kinds that stream: one pass, output rows a subset/projection
# of input rows, no exchange and no state across rows.  A matched region
# made only of these re-derives its value at memory bandwidth, so an
# exact splice saves IO bytes at most (see CostModel.should_splice).
STREAMING_KINDS = frozenset(
    {"LOAD", "STORE", "SPLIT", "FILTER", "PROJECT", "FOREACH", "UNION"})


@dataclasses.dataclass
class OpStats:
    """Collected statistics for one structural operator fingerprint."""
    times_seen: int = 0           # executions observed (missed reuses)
    rows_out: int = 0
    bytes_out: int = 0            # estimate until stored once, then exact
    bytes_exact: bool = False
    producer_cost_s: float = 0.0  # EWMA cumulative cost to (re)compute
    last_seen: float = 0.0


class CostModel:
    def __init__(self,
                 load_bandwidth_bytes_s: float = 2e9,
                 store_bandwidth_bytes_s: float = 2e9,
                 shuffle_bandwidth_bytes_s: float = 5e8,
                 fixed_io_s: float = 1e-5,
                 ewma_alpha: float = 0.5,
                 reuse_halflife_s: float = 1800.0,
                 prior_uses: float = 0.5,
                 max_expected_uses: float = 64.0,
                 min_splice_benefit_s: float = 0.0):
        self.load_bw = load_bandwidth_bytes_s
        self.store_bw = store_bandwidth_bytes_s
        self.shuffle_bw = shuffle_bandwidth_bytes_s
        # per-tier load bandwidths (DESIGN.md §15): "disk" mirrors
        # load_bw (kept as the attribute every existing caller prices
        # with); "host" and "remote" start at priors spanning the
        # realistic orders of magnitude and are replaced by calibration
        # from tier-tagged samples.  Each tier calibrates ONLY from its
        # own samples — the satellite-3 contract that a device-cache
        # hit (or a remote fetch) can never skew the disk estimate.
        self.tier_bw: Dict[str, float] = {
            "host": 8e9, "remote": 1e8, "device": 5e10}
        self.fixed_io_s = fixed_io_s
        # fixed per-request latency of the remote tier (calibrated from
        # request-level samples when available; the prior models an
        # object-store round trip)
        self.remote_latency_s = 0.02
        self.alpha = ewma_alpha
        self.halflife_s = reuse_halflife_s
        self.prior_uses = prior_uses
        self.max_expected_uses = max_expected_uses
        self.min_splice_benefit_s = min_splice_benefit_s
        # serve-side producer price (DESIGN.md §17): seconds of prefill
        # per prompt token, calibrated online from measured prefill
        # walls exactly like the IO bandwidths — it is the "producer
        # cost" of a stored prefix entry
        self.prefill_s_per_token = 1e-3
        self._prefill_tokens_seen = 0
        self.op_stats: Dict[str, OpStats] = {}
        # Batch-optimizer materialization hints (DESIGN.md §16): key
        # (structural fingerprint OR artifact name) -> number of queries
        # in the current batch *known* to consume that sub-job.  Unlike
        # op_stats these are facts about queued work, not history — they
        # override the seen-once admission gate and floor the
        # expected-uses estimate while a batch is in flight.
        self.known_uses: Dict[str, float] = {}

    # ------------------------------------------------------------- IO price
    #: minimum sampled byte mass before a measurement replaces a prior
    MIN_SAMPLE_BYTES = 1 << 16

    def calibrate_io(self, store) -> None:
        """Pull measured (bytes, seconds) transfer totals from an
        `ArtifactStore` and update the per-tier bandwidth estimates.
        Samples are tagged by the tier that served them (DESIGN.md
        §15), and each tier calibrates only from its own tag — a
        blended average would price cold reads at ~zero the moment
        cache hits dominate traffic.  The one sanctioned crossover:
        a store with NO disk backend (``has_disk`` false) may stand its
        memory samples in for the load bandwidth, because there loads
        genuinely are that cheap.  Disk-backed stores must never do
        this — a probe mix of many cache hits and a few small disk
        reads would otherwise calibrate cold reads at memory speed and
        skew every refresh_decision built on it.  A minimum sample mass
        guards against one-off timing flukes."""
        io = getattr(store, "io_stats", None)
        if io is None:
            return
        s = io() if callable(io) else io

        def bw(prefix):
            if (s.get(prefix + "_bytes", 0) > self.MIN_SAMPLE_BYTES
                    and s.get(prefix + "_s", 0.0) > 0):
                return s[prefix + "_bytes"] / s[prefix + "_s"]
            return None

        disk = bw("load")
        if disk is not None:
            self.load_bw = disk
        elif not s.get("has_disk", False):
            mem = bw("memload")
            if mem is not None:
                self.load_bw = mem
        mem = bw("memload")
        if mem is not None:
            self.tier_bw["device"] = mem
        host = bw("hostload")
        if host is not None:
            self.tier_bw["host"] = host
        remote = bw("remoteload")
        if remote is not None:
            self.tier_bw["remote"] = remote
        st = bw("store")
        if st is not None:
            self.store_bw = st

    #: minimum token mass before a prefill sample replaces the prior
    MIN_PREFILL_TOKENS = 16

    def observe_prefill(self, n_tokens: int, seconds: float) -> None:
        """Record one measured prefill (``n_tokens`` prompt tokens in
        ``seconds``).  The first qualifying sample replaces the prior;
        later samples blend by the same EWMA the op-cost stats use, so
        the per-token rate tracks compile warmup settling down."""
        if n_tokens <= 0 or seconds <= 0.0:
            return
        rate = seconds / n_tokens
        if self._prefill_tokens_seen < self.MIN_PREFILL_TOKENS:
            self.prefill_s_per_token = rate
        else:
            self.prefill_s_per_token += self.alpha * (
                rate - self.prefill_s_per_token)
        self._prefill_tokens_seen += int(n_tokens)

    def prefill_cost_s(self, n_tokens: int) -> float:
        """Predicted wall cost of prefilling ``n_tokens`` — the producer
        cost of a prefix entry, priced per calibrated token rate."""
        return max(int(n_tokens), 0) * self.prefill_s_per_token

    def tier_bandwidth(self, tier: str) -> float:
        if tier == "disk":
            return self.load_bw
        return self.tier_bw.get(tier, self.load_bw)

    def tier_load_cost_s(self, nbytes: int, tier: str) -> float:
        """Price of serving ``nbytes`` from a given tier.  Remote reads
        carry the per-request latency on top of the bandwidth term —
        that latency, not the bytes, is what batching and prefetch
        amortize."""
        fixed = self.fixed_io_s
        if tier == "remote":
            fixed += self.remote_latency_s
        return fixed + nbytes / max(self.tier_bandwidth(tier), 1.0)

    def should_promote(self, nbytes: int, from_tier: str, to_tier: str,
                       expected_uses: float = None) -> bool:
        """Admission pricing for a tier transition (DESIGN.md §15):
        copy an artifact from ``from_tier`` to the warmer ``to_tier``
        iff the predicted read savings over its expected future uses
        exceed the one-time migration cost (one read from the source
        plus one write at store bandwidth).  The same inequality
        prices demotion in reverse: a demotion is free capacity-wise
        and only costs the write, so callers demote unless the entry
        is about to be read again from the cold tier."""
        if expected_uses is None:
            expected_uses = max(self.prior_uses * 2.0, 1.0)
        save = (self.tier_load_cost_s(nbytes, from_tier)
                - self.tier_load_cost_s(nbytes, to_tier))
        if save <= 0.0:
            return False
        migrate = (self.tier_load_cost_s(nbytes, from_tier)
                   + self.store_cost_s(nbytes))
        return save * expected_uses > migrate

    def load_cost_s(self, nbytes: int) -> float:
        return self.fixed_io_s + nbytes / max(self.load_bw, 1.0)

    def store_cost_s(self, nbytes: int) -> float:
        return self.fixed_io_s + nbytes / max(self.store_bw, 1.0)

    def shuffle_cost_s(self, nbytes: int) -> float:
        """Price of one full exchange of ``nbytes`` across the mesh —
        the map-side bucketing plus the all_to_all (DESIGN.md §11).
        Modelled as a bandwidth term like load/store (the exchange
        moves every byte once over a slower path); a reused artifact
        that is co-partitioned on its consumer's keys is credited this
        on top of the recompute savings, because the consumer's
        exchange is skipped outright."""
        return self.fixed_io_s + nbytes / max(self.shuffle_bw, 1.0)

    def compensation_cost_s(self, nbytes: int, n_ops: int = 1) -> float:
        """Price of re-deriving an exact value from a *covering* artifact
        (DESIGN.md §10): each compensation operator (residual FILTER,
        narrowing PROJECT) is one streaming pass over the loaded bytes at
        compute bandwidth — modelled as the load bandwidth, since both
        are memory-bound scans — plus the fixed dispatch cost.  Semantic
        reuse is credited with savings *net* of this, so a cheap-to-
        recompute sub-job never looks better covered than recomputed."""
        if n_ops <= 0:
            return 0.0
        return n_ops * (self.fixed_io_s + nbytes / max(self.load_bw, 1.0))

    # ----------------------------------------------------- plan statistics
    def observe_op(self, struct_fp: str, *, rows_out: int, bytes_out: int,
                   producer_cost_s: float, now: Optional[float] = None) -> None:
        """Record one observed execution of an operator (its sub-job was
        computed, not reused).  `bytes_out` may be an estimate; it is
        replaced by the exact artifact size via `observe_stored_bytes`."""
        st = self.op_stats.get(struct_fp)
        if st is None:
            st = self.op_stats[struct_fp] = OpStats()
        st.times_seen += 1
        st.rows_out = rows_out
        if not st.bytes_exact:
            st.bytes_out = bytes_out
        if st.producer_cost_s == 0.0:
            st.producer_cost_s = producer_cost_s
        else:
            st.producer_cost_s += self.alpha * (producer_cost_s
                                                - st.producer_cost_s)
        st.last_seen = now if now is not None else time.time()

    def observe_stored_bytes(self, struct_fp: str, nbytes: int) -> None:
        st = self.op_stats.get(struct_fp)
        if st is not None:
            st.bytes_out = nbytes
            st.bytes_exact = True

    def stats_for(self, struct_fp: str) -> Optional[OpStats]:
        return self.op_stats.get(struct_fp)

    # -------------------------------------------------------------- decide
    def savings_per_reuse_s(self, producer_cost_s: float,
                            nbytes: int) -> float:
        return producer_cost_s - self.load_cost_s(nbytes)

    def splice_benefit_s(self, bytes_in: int, bytes_out: int) -> float:
        """Predicted benefit of answering a *streaming* matched region
        from its artifact: such a region re-derives its value in one
        pass over bytes the query loads anyway, so the only real saving
        is the byte diet — reading the (smaller) artifact instead of
        the (larger) region inputs."""
        return self.load_cost_s(bytes_in) - self.load_cost_s(bytes_out)

    def should_splice(self, entry) -> bool:
        """Exact-splice admission (the L7 guard): decline splices whose
        predicted benefit cannot clear the splice overhead
        ``min_splice_benefit_s`` (re-trace of the rewritten plan plus
        an artifact read where the input may sit in the page cache —
        the measured L7 0.6x regression).  Scope is deliberately
        narrow: only regions made entirely of streaming operators — a
        blocking region (JOIN/GROUPBY/DISTINCT/COGROUP) amortizes
        super-linear recompute and always splices — and only with
        bytes evidence on the entry; absent either, the paper's
        always-reuse rule stands.  Inert at the default threshold 0.

        A known-uses hint (batch optimizer, §16) also always splices:
        the batch deliberately materialized that artifact for queries
        queued *right now*, so declining would re-execute a sub-plan
        the shared prefix just paid to store — exactly the duplicate
        execution ``dup_executions`` gates at zero."""
        if self.min_splice_benefit_s <= 0.0:
            return True
        if self.known_uses_for(getattr(entry, "artifact", None)) > 0.0:
            return True
        kinds = {op.kind for op in entry.plan.topo()}
        if not kinds <= STREAMING_KINDS:
            return True
        if entry.bytes_in <= 0 or entry.bytes_out <= 0:
            return True
        return (self.splice_benefit_s(entry.bytes_in, entry.bytes_out)
                >= self.min_splice_benefit_s)

    def expected_future_uses(self, past_uses: float, ref_time: float,
                             now: Optional[float] = None) -> float:
        now = now if now is not None else time.time()
        decay = 0.5 ** (max(now - ref_time, 0.0) / self.halflife_s)
        return min(self.max_expected_uses,
                   (past_uses + self.prior_uses) * decay)

    # ---------------------------------------------- known-uses hints (§16)

    def set_known_uses(self, hints: Dict[str, float]) -> None:
        """Install batch-optimizer hints: key (structural fingerprint or
        artifact name) -> queries known to consume it.  Max-merged so
        overlapping batches never lower an existing hint."""
        for k, v in hints.items():
            self.known_uses[k] = max(self.known_uses.get(k, 0.0), float(v))

    def clear_known_uses(self, keys=None) -> None:
        """Drop hints when their batch retires (all, or just ``keys``)."""
        if keys is None:
            self.known_uses.clear()
        else:
            for k in keys:
                self.known_uses.pop(k, None)

    def known_uses_for(self, *keys: Optional[str]) -> float:
        """Max hint across any of the given keys (0.0 when unhinted)."""
        return max((self.known_uses.get(k, 0.0) for k in keys if k),
                   default=0.0)

    def should_materialize(self, struct_fp: str,
                           now: Optional[float] = None,
                           artifact: Optional[str] = None) -> bool:
        """Sub-job admission: materialize only when the predicted benefit
        (savings × expected reuses) exceeds the store cost.  Operators
        never observed before are NOT materialized — the first execution
        collects their statistics, the second pays the store only if
        history says it recurs and saves time.  Exception: a known-uses
        hint (batch optimizer, §16) is a fact, not an estimate — a
        hinted sub-job is admitted on first sight because consumers are
        already queued behind it."""
        hint = self.known_uses_for(struct_fp, artifact)
        st = self.op_stats.get(struct_fp)
        if st is None or st.times_seen < 1:
            return hint > 0.0
        savings = self.savings_per_reuse_s(st.producer_cost_s, st.bytes_out)
        if savings <= 0.0:
            return False
        uses = max(self.expected_future_uses(st.times_seen, st.last_seen,
                                             now), hint)
        return savings * uses > self.store_cost_s(st.bytes_out)

    def refresh_cost_s(self, entry, delta_fraction: float) -> float:
        """Predicted cost of delta-refreshing a stale entry (DESIGN.md
        §12): the delta job re-runs the producer over the delta fraction
        of its input, plus one load and one store of the artifact for
        the merge."""
        cost = entry.producer_cost_s or entry.exec_time_s
        return (max(delta_fraction, 0.0) * cost
                + self.load_cost_s(entry.bytes_out)
                + self.store_cost_s(entry.bytes_out))

    def refresh_decision(self, entry, delta_fraction: float,
                         now: Optional[float] = None,
                         eager_uses: float = 1.0) -> str:
        """Arbitrate refresh-vs-delete-vs-lazy for an append-stale entry
        (DESIGN.md §12):

          * ``"delete"`` — refreshing is not worth it: the delta is so
            large that the refresh costs as much as recomputing on
            demand would, or the entry's predicted future reuse value
            (savings × recency-decayed expected uses) is below the
            refresh cost;
          * ``"refresh"`` — hot entry (expected uses ≥ ``eager_uses``):
            pay the delta job now so the next probe is an exact hit;
          * ``"lazy"`` — worth keeping but not hot: defer the delta job
            until a probe actually demands the refreshed value."""
        rcost = self.refresh_cost_s(entry, delta_fraction)
        recompute = entry.producer_cost_s or entry.exec_time_s
        if rcost >= recompute:
            return "delete"
        if self.entry_benefit_s(entry, now) <= rcost:
            return "delete"
        past = entry.use_count + getattr(entry, "history_uses", 0.0)
        uses = self.expected_future_uses(
            past, entry.last_used or entry.created_at, now)
        return "refresh" if uses >= eager_uses else "lazy"

    def entry_benefit_s(self, entry, now: Optional[float] = None) -> float:
        """Predicted total future time saved by keeping a repository
        entry: savings per reuse times recency-decayed expected uses.
        Past evidence is actual reuse hits plus the executions observed
        before materialization (`history_uses`) — both predict future
        demand, and without the latter a fresh entry for a known-hot
        operator would rank below every incumbent and thrash."""
        cost = entry.producer_cost_s or entry.exec_time_s
        savings = max(self.savings_per_reuse_s(cost, entry.bytes_out), 0.0)
        ref = entry.last_used or entry.created_at
        past = entry.use_count + getattr(entry, "history_uses", 0.0)
        return savings * self.expected_future_uses(past, ref, now)

    def benefit_per_byte(self, entry, now: Optional[float] = None) -> float:
        """Eviction rank: entries are kept greedily by benefit density,
        the classic approximation to the 0/1 knapsack a byte-budgeted
        repository actually solves."""
        return self.entry_benefit_s(entry, now) / max(entry.bytes_out, 1)
