"""Sub-job enumeration (paper §4).

For every physical operator selected by the active heuristic, inject a
Split + Store so its output is materialized during job execution and
becomes a repository candidate:

  * Conservative H_C — input-reducing operators: PROJECT, FILTER (and
    FOREACH, Pig's projection carrier);
  * Aggressive   H_A — H_C plus the expensive operators: JOIN, GROUPBY,
    COGROUP;
  * NoHeuristic  NH  — every operator.

Candidate artifacts are named by the fingerprint of the *original-form*
operator (pre-rewrite), so the same logical value always maps to the same
artifact regardless of how much of the plan was answered from the
repository this time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from ..dataflow.compiler import art_name
from .plan import Operator, PhysicalPlan, split, store

CONSERVATIVE = frozenset({"PROJECT", "FILTER", "FOREACH"})
AGGRESSIVE = CONSERVATIVE | {"JOIN", "GROUPBY", "COGROUP"}
ALL_OPS = AGGRESSIVE | {"UNION", "DISTINCT"}

HEURISTICS = {
    "conservative": CONSERVATIVE,
    "aggressive": AGGRESSIVE,
    "none": ALL_OPS,          # the paper's "No Heuristic" policy
    "off": frozenset(),       # no sub-job materialization at all
}


@dataclasses.dataclass
class Candidate:
    artifact: str
    plan: PhysicalPlan        # original-form Load...→op→Store
    exec_op_uid: int          # uid of the op in the executed plan


def enumerate_subjobs(exec_plan: PhysicalPlan, origin: Dict[int, Operator],
                      orig_plan: PhysicalPlan,
                      heuristic: str) -> tuple[PhysicalPlan, List[Candidate]]:
    kinds = HEURISTICS[heuristic]
    orig_fps = orig_plan.fingerprints()

    existing = {s.params["name"] for s in exec_plan.sinks
                if s.kind == "STORE"}
    sinks = list(exec_plan.sinks)
    candidates: List[Candidate] = []
    for op in exec_plan.topo():
        if op.kind not in kinds:
            continue
        orig = origin.get(id(op))
        if orig is None:
            continue
        name = art_name(orig_fps[id(orig)])
        if name in existing:
            continue
        existing.add(name)
        sinks.append(store(split(op), name))
        candidates.append(Candidate(
            artifact=name,
            plan=orig_plan.subplan_upto(orig, name),
            exec_op_uid=op.uid))
    return PhysicalPlan(sinks), candidates


def whole_job_candidates(exec_plan: PhysicalPlan, origin: Dict[int, Operator],
                         orig_plan: PhysicalPlan) -> List[Candidate]:
    """Every job output is a repository candidate (paper §4 ¶2) — at zero
    extra cost, since workflow outputs are stored anyway."""
    orig_fps = orig_plan.fingerprints()
    out: List[Candidate] = []
    for s in exec_plan.sinks:
        if s.kind != "STORE":
            continue
        inp = s.inputs[0]
        target = inp.inputs[0] if inp.kind == "SPLIT" else inp
        if target.kind == "LOAD":
            continue
        orig = origin.get(id(target))
        if orig is None:
            continue
        out.append(Candidate(
            artifact=s.params["name"],
            plan=orig_plan.subplan_upto(orig, s.params["name"]),
            exec_op_uid=target.uid))
    return out
