"""Sub-job enumeration (paper §4; cost-driven mode in DESIGN.md §9).

For every physical operator selected by the active policy, inject a
Split + Store so its output is materialized during job execution and
becomes a repository candidate:

  * Conservative H_C — input-reducing operators: PROJECT, FILTER (and
    FOREACH, Pig's projection carrier);
  * Aggressive   H_A — H_C plus the expensive operators: JOIN, GROUPBY,
    COGROUP;
  * NoHeuristic  NH  — every operator;
  * Cost         —   any operator, but only when the cost model predicts
    the benefit of keeping it (recompute savings × expected reuses)
    exceeds the cost of storing it.  Operators are identified by the
    *structural* (version-blind) fingerprint so the prediction survives
    dataset churn; never-seen operators are not materialized — their
    first execution only collects statistics.

Candidate artifacts are named by the fingerprint of the *original-form*
operator (pre-rewrite), so the same logical value always maps to the same
artifact regardless of how much of the plan was answered from the
repository this time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from ..dataflow.compiler import art_name
from .cost_model import CostModel
from .plan import Operator, PhysicalPlan, split, store

CONSERVATIVE = frozenset({"PROJECT", "FILTER", "FOREACH"})
AGGRESSIVE = CONSERVATIVE | {"JOIN", "GROUPBY", "COGROUP"}
ALL_OPS = AGGRESSIVE | {"UNION", "DISTINCT"}

HEURISTICS = {
    "conservative": CONSERVATIVE,
    "aggressive": AGGRESSIVE,
    "none": ALL_OPS,          # the paper's "No Heuristic" policy
    "cost": ALL_OPS,          # candidate universe; cost model selects
    "off": frozenset(),       # no sub-job materialization at all
}


@dataclasses.dataclass
class Candidate:
    artifact: str
    plan: PhysicalPlan        # original-form Load...→op→Store
    exec_op_uid: int          # uid of the op in the executed plan
    struct_fp: str = ""       # version-blind fingerprint (cost-model key)


def enumerate_subjobs(exec_plan: PhysicalPlan, origin: Dict[int, Operator],
                      orig_plan: PhysicalPlan, heuristic: str,
                      cost_model: Optional[CostModel] = None
                      ) -> tuple[PhysicalPlan, List[Candidate]]:
    """Inject Split+Store sinks for every sub-job the active policy
    wants materialized and return (augmented plan, candidates).

    ``exec_plan`` is the (possibly rewritten) plan about to execute;
    ``origin`` maps its operators back to ``orig_plan`` (the original,
    pre-rewrite form), which names the candidate artifacts.  In
    ``"cost"`` mode a ``cost_model`` is required: an operator is
    materialized only if ``cost_model.should_materialize`` approves its
    structural fingerprint (predicted benefit > store cost).

    Batch-optimizer known-uses hints (DESIGN.md §16) extend the reach of
    any non-"off" heuristic: an operator whose fingerprint or artifact
    name is hinted is materialized even when its kind falls outside the
    heuristic's set, because queued queries are known to consume it."""
    kinds = HEURISTICS[heuristic]
    use_cost = heuristic == "cost"
    if use_cost and cost_model is None:
        raise ValueError('heuristic "cost" requires a cost_model')
    orig_fps = orig_plan.fingerprints()
    struct_fps = orig_plan.structural_fingerprints()

    existing = {s.params["name"] for s in exec_plan.sinks
                if s.kind == "STORE"}
    sinks = list(exec_plan.sinks)
    candidates: List[Candidate] = []
    for op in exec_plan.topo():
        orig = origin.get(id(op))
        if orig is None:
            continue
        hinted = (cost_model is not None and kinds
                  and op.kind in ALL_OPS
                  and cost_model.known_uses_for(
                      struct_fps[id(orig)],
                      art_name(orig_fps[id(orig)])) > 0.0)
        if op.kind not in kinds and not hinted:
            continue
        if use_cost and not cost_model.should_materialize(
                struct_fps[id(orig)],
                artifact=art_name(orig_fps[id(orig)])):
            continue
        name = art_name(orig_fps[id(orig)])
        if name in existing:
            continue
        existing.add(name)
        sinks.append(store(split(op), name))
        candidates.append(Candidate(
            artifact=name,
            plan=orig_plan.subplan_upto(orig, name),
            exec_op_uid=op.uid,
            struct_fp=struct_fps[id(orig)]))
    return PhysicalPlan(sinks), candidates


def whole_job_candidates(exec_plan: PhysicalPlan, origin: Dict[int, Operator],
                         orig_plan: PhysicalPlan) -> List[Candidate]:
    """Every job output is a repository candidate (paper §4 ¶2) — at zero
    extra cost, since workflow outputs are stored anyway."""
    orig_fps = orig_plan.fingerprints()
    struct_fps = orig_plan.structural_fingerprints()
    out: List[Candidate] = []
    for s in exec_plan.sinks:
        if s.kind != "STORE":
            continue
        inp = s.inputs[0]
        target = inp.inputs[0] if inp.kind == "SPLIT" else inp
        if target.kind == "LOAD":
            continue
        orig = origin.get(id(target))
        if orig is None:
            continue
        out.append(Candidate(
            artifact=s.params["name"],
            plan=orig_plan.subplan_upto(orig, s.params["name"]),
            exec_op_uid=target.uid,
            struct_fp=struct_fps[id(orig)]))
    return out
