"""The ReStore repository (paper §2.2, §3 ordering rules, §5 management).

One entry per stored job/sub-job output: the physical plan that produced
it, the artifact name in the store, and execution statistics.  Entries are
kept partially ordered so that the *first* match found during the
sequential scan is the best match:

  rule 1 — plan A before plan B if A subsumes B (B contained in A);
  rule 2 — otherwise, higher input:output byte ratio first, then longer
           producing-job execution time first.

Eviction (paper §5 rules):
  R1  keep only if |output| < |input|                       (optional)
  R2  keep only if reuse is predicted to save time          (optional)
  R3  evict entries unused within a time window
  R4  evict entries whose source datasets changed (handled structurally:
      Load fingerprints embed dataset versions, so stale entries can never
      match — ``evict_stale`` garbage-collects them)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from .matcher import match_bottom_up
from .plan import PhysicalPlan, plan_signature


@dataclasses.dataclass
class RepositoryEntry:
    plan: PhysicalPlan            # Load...→op→Store, original (unrewritten) form
    artifact: str                 # dataset name in the artifact store
    signature: str                # fingerprint of the output operator
    bytes_in: int = 0
    bytes_out: int = 0
    rows_out: int = 0
    exec_time_s: float = 0.0      # ET of the producing (sub-)job
    created_at: float = 0.0
    last_used: float = 0.0
    use_count: int = 0
    source_versions: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def reduction(self) -> float:
        return self.bytes_in / max(self.bytes_out, 1)

    def n_ops(self) -> int:
        return self.plan.n_ops()


class Repository:
    def __init__(self, keep_only_reducing: bool = False,
                 keep_only_time_saving: bool = False,
                 load_bandwidth_bytes_s: float = 2e9):
        self.entries: List[RepositoryEntry] = []
        self.by_sig: Dict[str, RepositoryEntry] = {}
        self.keep_only_reducing = keep_only_reducing
        self.keep_only_time_saving = keep_only_time_saving
        self.load_bw = load_bandwidth_bytes_s
        self._ordered_dirty = True
        self._ordered: List[RepositoryEntry] = []

    # ------------------------------------------------------------- insert
    def add(self, entry: RepositoryEntry) -> bool:
        """Apply keep-rules R1/R2, then insert (idempotent by signature)."""
        if entry.signature in self.by_sig:
            return False
        if self.keep_only_reducing and entry.bytes_out >= entry.bytes_in:
            return False            # rule R1
        if self.keep_only_time_saving:
            load_time = entry.bytes_out / self.load_bw
            if entry.exec_time_s <= load_time:
                return False        # rule R2 (Eq. 1/2 estimate)
        entry.created_at = entry.created_at or time.time()
        self.entries.append(entry)
        self.by_sig[entry.signature] = entry
        self._ordered_dirty = True
        return True

    # ------------------------------------------------------------- ordering
    def ordered(self) -> List[RepositoryEntry]:
        """Entries in scan order per the two ordering rules."""
        if not self._ordered_dirty:
            return self._ordered
        # subsumption partial order: A subsumes B iff B's plan is contained
        # in A's plan.  n_ops is a cheap necessary condition.
        es = sorted(self.entries,
                    key=lambda e: (-e.n_ops(), -e.reduction, -e.exec_time_s))
        # stable insertion respecting subsumption (larger plans first
        # already guarantees a subsumer precedes what it subsumes, since a
        # subsumer has strictly more operators unless equal)
        self._ordered = es
        self._ordered_dirty = False
        return self._ordered

    def subsumes(self, a: RepositoryEntry, b: RepositoryEntry) -> bool:
        return match_bottom_up(a.plan, b.plan) is not None

    # ------------------------------------------------------------- use/evict
    def touch(self, entry: RepositoryEntry):
        entry.last_used = time.time()
        entry.use_count += 1

    def evict_unused(self, window_s: float, store=None) -> int:
        """Rule R3."""
        now = time.time()
        keep, drop = [], []
        for e in self.entries:
            ref = e.last_used or e.created_at
            (keep if now - ref <= window_s else drop).append(e)
        self._replace(keep, drop, store)
        return len(drop)

    def evict_stale(self, catalog) -> int:
        """Rule R4 garbage collection: an entry whose recorded source
        versions no longer match the catalog can never match again."""
        keep, drop = [], []
        for e in self.entries:
            stale = any(catalog.version(ds) != v
                        for ds, v in e.source_versions.items())
            (drop if stale else keep).append(e)
        self._replace(keep, drop, None)
        return len(drop)

    def _replace(self, keep, drop, store):
        self.entries = keep
        self.by_sig = {e.signature: e for e in keep}
        self._ordered_dirty = True
        if store is not None:
            for e in drop:
                store.delete(e.artifact)

    # ------------------------------------------------------------- helpers
    def __len__(self):
        return len(self.entries)

    def total_stored_bytes(self) -> int:
        return sum(e.bytes_out for e in self.entries)


def make_entry(plan: PhysicalPlan, artifact: str, *, bytes_in=0, bytes_out=0,
               rows_out=0, exec_time_s=0.0,
               source_versions: Optional[Dict[str, int]] = None
               ) -> RepositoryEntry:
    return RepositoryEntry(plan=plan, artifact=artifact,
                           signature=plan_signature(plan),
                           bytes_in=bytes_in, bytes_out=bytes_out,
                           rows_out=rows_out, exec_time_s=exec_time_s,
                           created_at=time.time(),
                           source_versions=dict(source_versions or {}))
