"""The ReStore repository (paper §2.2, §3 ordering rules, §5 management;
budget economics in DESIGN.md §9).

One entry per stored job/sub-job output: the physical plan that produced
it, the artifact name in the store, and execution statistics.  Entries are
kept partially ordered so that the *first* match found during the
sequential scan is the best match:

  rule 1 — plan A before plan B if A subsumes B (B contained in A);
  rule 2 — otherwise, higher input:output byte ratio first, then longer
           producing-job execution time first.

Eviction (paper §5 rules):
  R1  keep only if |output| < |input|                       (optional)
  R2  keep only if reuse is predicted to save time          (optional)
  R3  evict entries unused within a time window
  R4  evict entries whose source datasets changed (handled structurally:
      Load fingerprints embed dataset versions, so stale entries can never
      match — ``evict_stale`` garbage-collects them; ``maintain`` instead
      delta-refreshes append-stale entries and reserves R4 for entries
      with no derivable delta plan, DESIGN.md §12)

Byte budget (DESIGN.md §9): when ``budget_bytes`` is set, ``add`` is no
longer an unconditional put.  Admission may evict lower-value entries to
make room (deleting their artifacts from the bound store) and rejects the
newcomer when the incumbents are worth more.  Two ranking policies:

  * ``"cost"`` — benefit-per-byte density from the `CostModel` (greedy
    knapsack: keep the entries whose predicted future time savings per
    stored byte are highest);
  * ``"lru"``  — recency only (the unconditional-keep baseline: always
    admit, evict least-recently-used to fit).

Entries whose artifacts are **pinned** (the driver pins a workflow's
job-boundary artifacts while it runs, since downstream jobs load them)
are never chosen as budget-eviction victims and always admitted; the
driver calls ``rebalance`` after unpinning to settle back under budget.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional

from .cost_model import CostModel
from .matcher import match_bottom_up
from .plan import PhysicalPlan, plan_signature


@dataclasses.dataclass
class RepositoryEntry:
    plan: PhysicalPlan            # Load...→op→Store, original (unrewritten)
    #                               form — or a PrefixPlan (kind="prefix")
    artifact: str                 # dataset name in the artifact store
    signature: str                # fingerprint of the output operator
    bytes_in: int = 0
    bytes_out: int = 0
    rows_out: int = 0
    exec_time_s: float = 0.0      # ET of the producing job (whole job)
    producer_cost_s: float = 0.0  # cumulative cost of this entry's sub-job
    created_at: float = 0.0
    last_used: float = 0.0
    use_count: int = 0
    # executions of this operator observed BEFORE materialization (each
    # was a missed reuse): seeds the expected-uses estimate so a fresh
    # entry for a known-hot operator is not ranked below incumbents and
    # store-then-rejected every event
    history_uses: float = 0.0
    # of use_count, hits where the entry only *covered* the query and a
    # compensation chain re-derived the exact value (DESIGN.md §10)
    semantic_uses: int = 0
    saved_s_total: float = 0.0    # realized savings credited on each reuse
    source_versions: Dict[str, int] = dataclasses.field(default_factory=dict)
    # physical partition property of the stored artifact (DESIGN.md §11):
    # {"keys": [...], "n_parts": P, "scheme": "hash_mod"} or None.  Not
    # part of the signature — a partitioned and a monolithic artifact of
    # the same value match identically — but a rewrite that splices a
    # co-partitioned artifact also skips the consumer's exchange.
    partitioning: Optional[Dict] = None
    # artifact-kind axis (DESIGN.md §17): "plan" = analytics job output,
    # "prefix" = serving-time KV/recurrent state.  One repository, one
    # budget, one economics engine — the kind only routes store deletes
    # and scopes the paper's plan-specific keep rules (R1/R2).
    kind: str = "plan"

    @property
    def reduction(self) -> float:
        return self.bytes_in / max(self.bytes_out, 1)

    def n_ops(self) -> int:
        return self.plan.n_ops()


class Repository:
    def __init__(self, keep_only_reducing: bool = False,
                 keep_only_time_saving: bool = False,
                 load_bandwidth_bytes_s: float = 2e9,
                 budget_bytes: Optional[int] = None,
                 policy: str = "cost",
                 cost_model: Optional[CostModel] = None,
                 clock=None):
        if policy not in ("cost", "lru"):
            raise ValueError(f"unknown eviction policy {policy!r}")
        # injectable time source: every recency stamp and eviction "now"
        # flows through it, so tests (and the serve path, which defaults
        # to a logical event counter) get deterministic eviction order
        # instead of wall-clock-dependent LRU ties (DESIGN.md §17)
        self._now = clock if clock is not None else time.time
        self.entries: List[RepositoryEntry] = []
        self.by_sig: Dict[str, RepositoryEntry] = {}
        self.keep_only_reducing = keep_only_reducing
        self.keep_only_time_saving = keep_only_time_saving
        self.load_bw = load_bandwidth_bytes_s
        self.budget_bytes = budget_bytes
        self.policy = policy
        self.cost_model = cost_model or CostModel(
            load_bandwidth_bytes_s=load_bandwidth_bytes_s)
        # artifact name -> pin refcount.  Counting (not a set) lets
        # concurrent service workflows pin a shared artifact without one
        # run's unpin releasing another's protection; membership and
        # emptiness read exactly like the old set.
        self.pinned: Dict[str, int] = {}
        self.evictions = 0            # budget evictions (not R3/R4)
        self.rejections = 0           # budget admission rejections
        self.exact_hits = 0           # record_use(kind="exact")
        self.semantic_hits = 0        # record_use(kind="semantic")
        self.refreshes = 0            # delta-refreshed entries (§12)
        # per-artifact-kind hit counters, surfaced by stats()
        self._hits_by_kind: Dict[str, Dict[str, int]] = {}
        # artifact-kind -> store: non-"plan" kinds bind their own tier
        # store here so eviction routes deletes to the right backend
        self._stores: Dict[str, object] = {}
        # stale-but-refreshable entries deferred by the cost model:
        # old entry signature -> RefreshSpec, executed on the next probe
        # whose plan would match the refreshed signature (DESIGN.md §12)
        self.pending_refresh: Dict[str, object] = {}
        self._store = None            # bound by the ReStore driver
        # WAL journal (service.journal.RepositoryJournal) or None: every
        # state transition that must survive process death is appended
        # before this method returns (DESIGN.md §13)
        self.journal = None
        # one lock around every compound state transition: service
        # workers share a single Repository.  Reentrant because
        # add -> _admit -> _apply_eviction -> _replace nest.
        self._lock = threading.RLock()
        self._ordered_dirty = True
        self._ordered: List[RepositoryEntry] = []

    # ------------------------------------------------------------- binding
    def bind_store(self, store, kind: str = "plan") -> None:
        """Attach the artifact store so budget eviction (and R3/R4 when
        called without an explicit store) can delete evicted artifacts.
        Non-"plan" kinds (e.g. ``"prefix"`` KV snapshots, DESIGN.md §17)
        bind their own backend; eviction routes each dropped entry's
        delete to its kind's store."""
        if kind == "plan":
            self._store = store
        else:
            self._stores[kind] = store

    def bind_journal(self, journal) -> None:
        """Attach a WAL journal; subsequent mutations are logged."""
        self.journal = journal

    def pin(self, artifacts) -> None:
        with self._lock:
            for a in artifacts:
                self.pinned[a] = self.pinned.get(a, 0) + 1
            if self.journal is not None:
                self.journal.record_pin(artifacts)

    def unpin(self, artifacts) -> None:
        with self._lock:
            for a in artifacts:
                n = self.pinned.get(a, 0) - 1
                if n > 0:
                    self.pinned[a] = n
                else:
                    self.pinned.pop(a, None)
            if self.journal is not None:
                self.journal.record_unpin(artifacts)

    # ------------------------------------------- known-uses hints (§16)
    def set_known_uses(self, hints) -> None:
        """Install batch-optimizer materialization hints (key: structural
        fingerprint or artifact name -> queries known to consume it) on
        the cost model this repository admits/evicts by."""
        with self._lock:
            self.cost_model.set_known_uses(hints)

    def clear_known_uses(self, keys=None) -> None:
        with self._lock:
            self.cost_model.clear_known_uses(keys)

    # ------------------------------------------------------------- insert
    def add(self, entry: RepositoryEntry) -> bool:
        """Apply keep-rules R1/R2 and the byte-budget admission policy,
        then insert (idempotent by signature).  Returns True iff the
        entry is now in the repository."""
        with self._lock:
            if entry.signature in self.by_sig:
                return False
            # R1/R2 are the paper's *plan* keep-rules (output vs input
            # bytes of a relational job); prefix entries have no input
            # byte mass and are governed by the budget economics alone
            if entry.kind == "plan":
                if self.keep_only_reducing \
                        and entry.bytes_out >= entry.bytes_in:
                    return False        # rule R1
                if self.keep_only_time_saving:
                    load_time = entry.bytes_out / self.load_bw
                    if entry.exec_time_s <= load_time:
                        return False    # rule R2 (Eq. 1/2 estimate)
            entry.created_at = entry.created_at or self._now()
            if self.budget_bytes is not None and not self._admit(entry):
                self.rejections += 1
                return False
            self.entries.append(entry)
            self.by_sig[entry.signature] = entry
            self._ordered_dirty = True
            if self.journal is not None:
                self.journal.record_add(entry)
            return True

    # ------------------------------------------------------------- budget
    def _score(self, e: RepositoryEntry, now: float) -> float:
        """Eviction rank (ascending = evicted first)."""
        if self.policy == "lru":
            return e.last_used or e.created_at
        return self.cost_model.benefit_per_byte(e, now)

    def _select_victims(self, need_bytes: int, now: float,
                        stop_score: Optional[float] = None):
        """Pick unpinned entries in ascending `_score` order until
        ``need_bytes`` would be freed (or, with ``stop_score``, until
        the next victim would rank at/above it).  Selection only — the
        caller applies `_apply_eviction` once its condition holds.
        Returns (victims, bytes_freed)."""
        victims, freed = [], 0
        for e in sorted((e for e in self.entries
                         if e.artifact not in self.pinned),
                        key=lambda e: self._score(e, now)):
            if freed >= need_bytes:
                break
            if stop_score is not None and self._score(e, now) >= stop_score:
                break               # incumbents from here on are worth more
            victims.append(e)
            freed += e.bytes_out
        return victims, freed

    def _apply_eviction(self, victims) -> None:
        if not victims:
            return
        # expand to every entry sharing a victim's artifact: alias
        # entries (intermediate prefix lengths, bytes_out=0) share the
        # parent snapshot's arrays, so they must die with it — a
        # dangling alias would advertise bytes the store deleted
        arts = {v.artifact for v in victims}
        drop = [e for e in self.entries if e.artifact in arts]
        self._replace([e for e in self.entries if e.artifact not in arts],
                      drop, self._store)
        self.evictions += len(drop)

    def _admit(self, entry: RepositoryEntry) -> bool:
        """Knapsack-style admission: free enough bytes by evicting
        entries ranked below the newcomer; reject the newcomer when the
        incumbents are worth more (cost policy) or nothing evictable is
        left (both policies).  Pinned entries always enter — their
        artifacts exist regardless (workflow outputs), registration just
        makes them matchable — and are reconciled by `rebalance`."""
        if entry.artifact in self.pinned:
            return True
        need = self.total_stored_bytes() + entry.bytes_out - self.budget_bytes
        if need <= 0:
            return True
        if entry.bytes_out > self.budget_bytes:
            return False
        now = self._now()
        stop = self._score(entry, now) if self.policy == "cost" else None
        victims, freed = self._select_victims(need, now, stop_score=stop)
        if freed < need:
            return False            # incumbents worth more: reject newcomer
        self._apply_eviction(victims)
        return True

    def rebalance(self) -> int:
        """Evict lowest-ranked unpinned entries until the repository fits
        its byte budget again (no-op without a budget).  Called by the
        driver after unpinning a finished workflow's artifacts."""
        with self._lock:
            if self.budget_bytes is None:
                return 0
            excess = self.total_stored_bytes() - self.budget_bytes
            if excess <= 0:
                return 0
            victims, _ = self._select_victims(excess, self._now())
            self._apply_eviction(victims)
            return len(victims)

    # ------------------------------------------------------------- ordering
    def ordered(self) -> List[RepositoryEntry]:
        """Entries in scan order per the two ordering rules."""
        with self._lock:
            if not self._ordered_dirty:
                return self._ordered
            # subsumption partial order: A subsumes B iff B's plan is
            # contained in A's plan.  n_ops is a cheap necessary condition.
            es = sorted(self.entries,
                        key=lambda e: (-e.n_ops(), -e.reduction,
                                       -e.exec_time_s))
            # stable insertion respecting subsumption (larger plans first
            # already guarantees a subsumer precedes what it subsumes,
            # since a subsumer has strictly more operators unless equal)
            self._ordered = es
            self._ordered_dirty = False
            return self._ordered

    def subsumes(self, a: RepositoryEntry, b: RepositoryEntry) -> bool:
        if a.kind == "prefix" or b.kind == "prefix":
            # prefix containment IS the subsumption analog (§17)
            return (a.kind == b.kind == "prefix"
                    and b.plan.is_prefix_of(a.plan))
        return match_bottom_up(a.plan, b.plan) is not None

    # ------------------------------------------------------------- use/evict
    def record_use(self, entry: RepositoryEntry,
                   saved_s: float = 0.0, kind: str = "exact") -> None:
        """Record a reuse hit: bumps recency/hit-count (feeding both LRU
        and the cost model's expected-uses estimate) and credits the
        realized time savings to the entry.  ``kind="semantic"`` marks a
        subsumption hit (DESIGN.md §10): callers pass savings net of the
        compensation compute, and the split counters let the economics
        of covering-but-inexact artifacts be audited separately."""
        if kind not in ("exact", "semantic"):
            raise ValueError(f"unknown reuse kind {kind!r}")
        with self._lock:
            entry.last_used = self._now()
            entry.use_count += 1
            entry.saved_s_total += saved_s
            hk = self._hits_by_kind.setdefault(
                entry.kind, {"exact": 0, "semantic": 0})
            hk[kind] += 1
            if kind == "semantic":
                entry.semantic_uses += 1
                self.semantic_hits += 1
            else:
                self.exact_hits += 1
            if self.journal is not None:
                self.journal.record_use(entry, saved_s, kind)

    # backwards-compatible alias (pre-§9 API)
    def touch(self, entry: RepositoryEntry):
        self.record_use(entry)

    def evict_unused(self, window_s: float, store=None) -> int:
        """Rule R3: drop entries not used within ``window_s`` seconds
        (artifacts deleted from ``store``, defaulting to the bound one)."""
        with self._lock:
            now = self._now()
            keep, drop = [], []
            for e in self.entries:
                ref = e.last_used or e.created_at
                (keep if now - ref <= window_s else drop).append(e)
            self._replace(keep, drop,
                          store if store is not None else self._store)
            return len(drop)

    def evict_stale(self, catalog, store=None, kinds=None) -> int:
        """Rule R4 garbage collection: an entry whose recorded source
        versions no longer match the catalog can never match again.  Its
        artifact is deleted from ``store`` (default: the bound store).
        ``kinds`` restricts the sweep to entries of those artifact kinds
        — the serve path invalidates a model-version bump against its
        own catalog without evaluating analytics entries (§17)."""
        with self._lock:
            keep, drop = [], []
            for e in self.entries:
                if kinds is not None and e.kind not in kinds:
                    keep.append(e)
                    continue
                stale = any(catalog.version(ds) != v
                            for ds, v in e.source_versions.items())
                (drop if stale else keep).append(e)
            self._replace(keep, drop,
                          store if store is not None else self._store)
            return len(drop)

    def drop_artifact(self, name: str) -> int:
        """Drop every entry whose artifact is ``name`` WITHOUT touching
        the store — the quarantine path already deleted the damaged
        bytes; what remains is un-advertising them (DESIGN.md §13)."""
        with self._lock:
            keep = [e for e in self.entries if e.artifact != name]
            drop = [e for e in self.entries if e.artifact == name]
            self._replace(keep, drop, None, route=False)
            return len(drop)

    def _replace(self, keep, drop, store, route=True):
        """Swap the entry list; deletes dropped artifacts.  ``store`` is
        the plan-kind backend (explicit or the bound default); with
        ``route`` (the normal case) non-plan entries delete from their
        kind's bound store instead.  An artifact still referenced by a
        kept entry is never deleted (alias entries share artifacts)."""
        with self._lock:
            self.entries = keep
            self.by_sig = {e.signature: e for e in keep}
            self._ordered_dirty = True
            for e in drop:           # evicted entries owe no lazy refresh
                self.pending_refresh.pop(e.signature, None)
            if self.journal is not None and drop:
                self.journal.record_drop([e.signature for e in drop])
            kept_by_art: Dict[str, List[RepositoryEntry]] = {}
            for e in keep:
                kept_by_art.setdefault(e.artifact, []).append(e)
            for e in drop:
                survivors = kept_by_art.get(e.artifact)
                if survivors:
                    # shared artifact survives; the byte charge moves to
                    # the largest surviving entry so the budget still
                    # counts the stored arrays exactly once
                    if e.bytes_out:
                        heir = max(survivors, key=lambda s: s.bytes_out)
                        heir.bytes_out += e.bytes_out
                    continue
                st = self._stores.get(e.kind, store) if route else store
                if st is not None:
                    st.delete(e.artifact)

    # ------------------------------------------------- incremental refresh
    def maintain(self, catalog, engine, store=None,
                 mode: str = "auto", only=None) -> Dict[str, int]:
        """Incremental maintenance sweep (DESIGN.md §12): where
        ``evict_stale`` (rule R4) deletes every entry whose source
        versions moved, this refreshes append-stale entries from the
        dataset delta instead.  Per stale entry: `derive_refresh`
        produces a delta plan + merge operator (None ⇒ not incrementally
        maintainable ⇒ R4 delete as before); the cost model then
        arbitrates refresh-now / lazy (refresh on next probe) / delete
        (``mode="auto"``; ``"refresh"``/``"lazy"``/``"delete"`` force
        the decision — "delete" reproduces the pre-§12 behavior).
        ``only`` (a set of artifact names) restricts the sweep to those
        entries — the speculative prefetcher's ahead-of-arrival refresh
        (DESIGN.md §15) targets just the artifacts it predicts the next
        probe will touch, leaving the rest for the regular sweep.
        Returns counters {refreshed, lazy, deleted}."""
        from .delta import derive_refresh
        with self._lock:
            store = store if store is not None else self._store
            report = {"refreshed": 0, "lazy": 0, "deleted": 0}
            drop = []
            for e in list(self.entries):
                if only is not None and e.artifact not in only:
                    continue
                stale = any(catalog.version(ds) != v
                            for ds, v in e.source_versions.items())
                if not stale:
                    continue
                spec = derive_refresh(e, catalog)
                if spec is None:
                    drop.append(e)
                    continue
                if spec.refreshed_signature in self.by_sig:
                    # a probe already recomputed (and registered) the
                    # new-version value: refreshing would index two
                    # entries under one signature — the stale entry is
                    # plain R4
                    drop.append(e)
                    continue
                decision = mode if mode != "auto" else \
                    self.cost_model.refresh_decision(e, spec.delta_fraction)
                if decision == "delete":
                    drop.append(e)
                elif decision == "lazy":
                    self.pending_refresh[e.signature] = spec
                    if self.journal is not None:
                        self.journal.record_pending(e.signature)
                    report["lazy"] += 1
                else:
                    self.apply_refresh(spec, engine, store, catalog)
                    report["refreshed"] += 1
            drop_ids = {id(e) for e in drop}
            self._replace([e for e in self.entries
                           if id(e) not in drop_ids], drop, store)
            report["deleted"] = len(drop)
            return report

    def reindex(self, entry: RepositoryEntry, old_sig: str) -> None:
        """Re-key an entry that was refreshed/extended in place: the
        caller already mutated ``entry`` (plan, signature, bytes, ...)
        and this re-indexes it under the new signature, journalling the
        transition as a refresh.  Shared by §12 delta refresh and the
        §17 append-style prefix extension (a multi-turn conversation
        growing a stored prefix rides this instead of re-storing)."""
        with self._lock:
            self.by_sig.pop(old_sig, None)
            self.by_sig[entry.signature] = entry
            self.pending_refresh.pop(old_sig, None)
            self._ordered_dirty = True
            self.refreshes += 1
            if self.journal is not None:
                self.journal.record_refresh(old_sig, entry)

    def apply_refresh(self, spec, engine, store, catalog) -> None:
        """Execute one derived refresh and re-index the entry under its
        refreshed signature (the semantic/exact matchers then see it as
        an exact producer of the new-version value)."""
        from .delta import execute_refresh
        with self._lock:
            entry = spec.entry
            old_sig = entry.signature
            execute_refresh(spec, engine, store, catalog)
            self.reindex(entry, old_sig)

    def refresh_pending(self, plan, engine, catalog, store=None) -> int:
        """Lazy-refresh hook: execute every pending refresh whose
        *refreshed* signature appears in ``plan``'s fingerprints (the
        probe that was deferred for has arrived).  A spec whose catalog
        versions moved again since derivation is re-derived; one that is
        no longer derivable is R4-dropped.  Returns refreshes applied."""
        if not self.pending_refresh:
            return 0
        from .delta import derive_refresh
        self._lock.acquire()
        try:
            return self._refresh_pending_locked(
                plan, engine, catalog,
                store if store is not None else self._store,
                derive_refresh)
        finally:
            self._lock.release()

    def _refresh_pending_locked(self, plan, engine, catalog, store,
                                derive_refresh) -> int:
        fps = set(plan.fingerprints().values())
        n = 0
        for old_sig, spec in list(self.pending_refresh.items()):
            entry = spec.entry
            if any(catalog.version(ds) != v
                   for ds, v in spec.new_versions.items()):
                # catalog moved again since derivation: re-derive (the
                # delta grew) before the fingerprint probe below, or
                # drop to R4 when no longer derivable
                del self.pending_refresh[old_sig]
                spec = derive_refresh(entry, catalog)
                if spec is None:
                    drop_ids = {id(entry)}
                    self._replace([e for e in self.entries
                                   if id(e) not in drop_ids], [entry],
                                  store)
                    continue
                self.pending_refresh[entry.signature] = spec
            if spec.refreshed_signature in self.by_sig:
                # the new-version value was recomputed+registered while
                # the refresh was parked: the stale entry is redundant
                del self.pending_refresh[entry.signature]
                self._replace([e for e in self.entries if e is not entry],
                              [entry], store)
                continue
            if spec.refreshed_signature not in fps:
                continue
            self.apply_refresh(spec, engine, store, catalog)
            n += 1
        return n

    # ------------------------------------------------------------- helpers
    def __len__(self):
        return len(self.entries)

    def total_stored_bytes(self) -> int:
        return sum(e.bytes_out for e in self.entries)

    def stats(self) -> Dict[str, Dict[str, int]]:
        """Per-artifact-kind accounting: entry/byte counts plus the hit
        split — the audit surface for "KV state and analytics artifacts
        share one budget" (DESIGN.md §17)."""
        with self._lock:
            out: Dict[str, Dict[str, int]] = {}
            for e in self.entries:
                k = out.setdefault(e.kind, {
                    "entries": 0, "bytes": 0,
                    "exact_hits": 0, "semantic_hits": 0})
                k["entries"] += 1
                k["bytes"] += e.bytes_out
            for kind, hk in self._hits_by_kind.items():
                k = out.setdefault(kind, {
                    "entries": 0, "bytes": 0,
                    "exact_hits": 0, "semantic_hits": 0})
                k["exact_hits"] = hk["exact"]
                k["semantic_hits"] = hk["semantic"]
            return out


def make_entry(plan: PhysicalPlan, artifact: str, *, bytes_in=0, bytes_out=0,
               rows_out=0, exec_time_s=0.0, producer_cost_s=0.0,
               history_uses=0.0,
               source_versions: Optional[Dict[str, int]] = None,
               partitioning: Optional[Dict] = None,
               kind: str = "plan") -> RepositoryEntry:
    return RepositoryEntry(plan=plan, artifact=artifact,
                           signature=plan_signature(plan),
                           bytes_in=bytes_in, bytes_out=bytes_out,
                           rows_out=rows_out, exec_time_s=exec_time_s,
                           producer_cost_s=producer_cost_s,
                           history_uses=history_uses,
                           created_at=time.time(),
                           source_versions=dict(source_versions or {}),
                           partitioning=dict(partitioning)
                           if partitioning else None,
                           kind=kind)
