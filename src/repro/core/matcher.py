"""Plan containment matching (paper §3).

Two implementations, tested to agree:

* ``match_bottom_up`` — the production path.  Operator equivalence (same
  function over equivalent inputs) is exactly Merkle-fingerprint equality,
  so containment of a repository plan in an input plan reduces to: "does
  the input plan contain an operator whose fingerprint equals the
  fingerprint of the repository plan's output operator?".  O(|plan|) with
  an index, instead of the paper's repeated pairwise traversals.

* ``pairwise_plan_traversal`` — a faithful port of the paper's
  Algorithm 1 (simultaneous depth-first traversal from the Load
  operators).  Kept as the reference implementation and exercised by the
  benchmarks that reproduce the paper's matcher behaviour.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .plan import Operator, PhysicalPlan


def _output_op(plan: PhysicalPlan) -> Operator:
    sink = plan.sinks[0]
    return sink.inputs[0] if sink.kind == "STORE" else sink


# ---------------------------------------------------------------------------
# Production matcher: bottom-up / fingerprint


def match_bottom_up(input_plan: PhysicalPlan,
                    repo_plan: PhysicalPlan) -> Optional[Operator]:
    """Return the operator in ``input_plan`` equivalent to ``repo_plan``'s
    output, or None if the repository plan is not contained."""
    target_fp = repo_plan.fingerprints()[id(_output_op(repo_plan))]
    in_fps = input_plan.fingerprints()
    for op in input_plan.topo():
        if op.kind in ("LOAD", "STORE"):
            continue  # rewriting a Load with a Load is useless
        if in_fps[id(op)] == target_fp:
            return op
    return None


class FingerprintIndex:
    """Beyond-paper fast path: index input-plan ops by fingerprint once,
    then each repository probe is O(1) instead of a plan scan."""

    def __init__(self, input_plan: PhysicalPlan):
        self.by_fp: Dict[str, Operator] = {}
        fps = input_plan.fingerprints()
        for op in input_plan.topo():
            if op.kind in ("LOAD", "STORE"):
                continue
            self.by_fp.setdefault(fps[id(op)], op)

    def probe(self, repo_plan: PhysicalPlan) -> Optional[Operator]:
        fp = repo_plan.fingerprints()[id(_output_op(repo_plan))]
        return self.by_fp.get(fp)


# ---------------------------------------------------------------------------
# Paper Algorithm 1 (faithful port)


def _find_equivalent(op: Operator, candidates: List[Operator]) -> Optional[Operator]:
    for c in candidates:
        if c.local_sig() == op.local_sig():
            return c
    return None


def pairwise_plan_traversal(input_plan: PhysicalPlan,
                            repo_plan: PhysicalPlan) -> Optional[Operator]:
    """Algorithm 1: simultaneous DFS from the Load operators.  Returns the
    last matched operator of the *input* plan (the rewrite anchor), or
    None.  As in the paper, matching starts by pairing Load operators that
    read the same dataset."""
    succ1 = input_plan.successors()
    succ2 = repo_plan.successors()

    loads1 = input_plan.loads()
    loads2 = repo_plan.loads()
    # each repo Load must have an equivalent input Load
    pairs = []
    used = set()
    for l2 in loads2:
        found = None
        for l1 in loads1:
            if id(l1) in used:
                continue
            if l1.local_sig() == l2.local_sig():
                found = l1
                break
        if found is None:
            return None
        used.add(id(found))
        pairs.append((found, l2))

    remaining2 = [o for o in repo_plan.topo()
                  if o.kind not in ("LOAD", "STORE")]
    matched: Dict[int, Operator] = {}   # repo op id -> input op
    seen = set()

    def traverse(succs1: List[Operator], succs2: List[Operator],
                 last_match: Optional[Operator]) -> Optional[Operator]:
        succs2 = [s for s in succs2 if s.kind != "STORE"]
        if not succs2:
            return last_match
        if not succs1:
            return None
        ret: Optional[Operator] = None
        s2_left = list(succs2)
        for s in succs1:
            if id(s) in seen:
                continue
            seen.add(id(s))
            eq = _find_equivalent(s, s2_left)
            if eq is None:
                continue
            ret = traverse(succ1[id(s)], succ2[id(eq)], s)
            if ret is None:
                return None
            matched[id(eq)] = s
            s2_left.remove(eq)
            if not s2_left:
                break
        if s2_left:
            return None
        return ret

    last: Optional[Operator] = None
    for l1, l2 in pairs:
        r = traverse(succ1[id(l1)], succ2[id(l2)], last)
        if r is None:
            return None
        last = r

    # all repo ops must be matched
    for o in remaining2:
        if id(o) not in matched:
            return None
    out2 = _output_op(repo_plan)
    return matched.get(id(out2), last)
