"""Plan containment matching (paper §3; semantic extension DESIGN.md §10).

Two exact implementations, tested to agree:

* ``match_bottom_up`` — the production path.  Operator equivalence (same
  function over equivalent inputs) is exactly Merkle-fingerprint equality,
  so containment of a repository plan in an input plan reduces to: "does
  the input plan contain an operator whose fingerprint equals the
  fingerprint of the repository plan's output operator?".  O(|plan|) with
  an index, instead of the paper's repeated pairwise traversals.

* ``pairwise_plan_traversal`` — a faithful port of the paper's
  Algorithm 1 (simultaneous depth-first traversal from the Load
  operators).  Kept as the reference implementation and exercised by the
  benchmarks that reproduce the paper's matcher behaviour.

Beyond the paper's exact matching, ``SemanticIndex`` finds *subsumption*
matches: a repository plan identical to an input sub-plan except for a
weaker FILTER predicate and/or a wider PROJECT column set still answers
the sub-plan, provided the rewriter re-applies a compensation (residual
predicate / narrowing projection) on top of the loaded artifact.  Exact
hits always take priority: the semantic probe refuses to fire whenever
the exact index would hit."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..dataflow.expr import (Expr, conjoin, implies, pred_columns,
                             residual_pred)
from .plan import Operator, PhysicalPlan


def _output_op(plan: PhysicalPlan) -> Operator:
    sink = plan.sinks[0]
    return sink.inputs[0] if sink.kind == "STORE" else sink


# ---------------------------------------------------------------------------
# Production matcher: bottom-up / fingerprint


def match_bottom_up(input_plan: PhysicalPlan,
                    repo_plan: PhysicalPlan) -> Optional[Operator]:
    """Return the operator in ``input_plan`` equivalent to ``repo_plan``'s
    output, or None if the repository plan is not contained.  When
    duplicate-fingerprint operators exist (a diamond plan with repeated
    subtrees) the topologically-latest one is returned, matching
    ``FingerprintIndex.probe`` — anchoring late keeps sub-job credit
    attribution on the copy whose downstream consumers run last."""
    target_fp = repo_plan.fingerprints()[id(_output_op(repo_plan))]
    in_fps = input_plan.fingerprints()
    for op in reversed(input_plan.topo()):
        if op.kind in ("LOAD", "STORE"):
            continue  # rewriting a Load with a Load is useless
        if in_fps[id(op)] == target_fp:
            return op
    return None


class FingerprintIndex:
    """Beyond-paper fast path: index input-plan ops by fingerprint once,
    then each repository probe is O(1) instead of a plan scan.  All ops
    sharing a fingerprint are kept (duplicated subtrees in diamond plans
    are distinct rewrite sites); ``probe`` prefers the topologically-
    latest anchor."""

    def __init__(self, input_plan: PhysicalPlan):
        self.by_fp: Dict[str, List[Operator]] = {}
        self.fps = input_plan.fingerprints()   # shared with SemanticIndex
        for op in input_plan.topo():
            if op.kind in ("LOAD", "STORE"):
                continue
            self.by_fp.setdefault(self.fps[id(op)], []).append(op)

    def probe(self, repo_plan: PhysicalPlan) -> Optional[Operator]:
        return self.probe_fp(
            repo_plan.fingerprints()[id(_output_op(repo_plan))])

    def probe_fp(self, fp: str) -> Optional[Operator]:
        """Probe by a precomputed output fingerprint (a repository
        entry's ``signature``), skipping the repo-plan Merkle pass."""
        ops = self.by_fp.get(fp)
        return ops[-1] if ops else None


# ---------------------------------------------------------------------------
# Paper Algorithm 1 (faithful port)


def _find_equivalent(op: Operator, candidates: List[Operator]) -> Optional[Operator]:
    for c in candidates:
        if c.local_sig() == op.local_sig():
            return c
    return None


def pairwise_plan_traversal(input_plan: PhysicalPlan,
                            repo_plan: PhysicalPlan) -> Optional[Operator]:
    """Algorithm 1: simultaneous DFS from the Load operators.  Returns the
    last matched operator of the *input* plan (the rewrite anchor), or
    None.  As in the paper, matching starts by pairing Load operators that
    read the same dataset."""
    succ1 = input_plan.successors()
    succ2 = repo_plan.successors()

    loads1 = input_plan.loads()
    loads2 = repo_plan.loads()
    # each repo Load must have an equivalent input Load
    pairs = []
    used = set()
    for l2 in loads2:
        found = None
        for l1 in loads1:
            if id(l1) in used:
                continue
            if l1.local_sig() == l2.local_sig():
                found = l1
                break
        if found is None:
            return None
        used.add(id(found))
        pairs.append((found, l2))

    remaining2 = [o for o in repo_plan.topo()
                  if o.kind not in ("LOAD", "STORE")]
    matched: Dict[int, Operator] = {}   # repo op id -> input op
    seen = set()

    def traverse(succs1: List[Operator], succs2: List[Operator],
                 last_match: Optional[Operator]) -> Optional[Operator]:
        succs2 = [s for s in succs2 if s.kind != "STORE"]
        if not succs2:
            return last_match
        if not succs1:
            return None
        ret: Optional[Operator] = None
        s2_left = list(succs2)
        for s in succs1:
            if id(s) in seen:
                continue
            seen.add(id(s))
            eq = _find_equivalent(s, s2_left)
            if eq is None:
                continue
            ret = traverse(succ1[id(s)], succ2[id(eq)], s)
            if ret is None:
                return None
            matched[id(eq)] = s
            s2_left.remove(eq)
            if not s2_left:
                break
        if s2_left:
            return None
        return ret

    last: Optional[Operator] = None
    for l1, l2 in pairs:
        r = traverse(succ1[id(l1)], succ2[id(l2)], last)
        if r is None:
            return None
        last = r

    # all repo ops must be matched
    for o in remaining2:
        if id(o) not in matched:
            return None
    out2 = _output_op(repo_plan)
    return matched.get(id(out2), last)


# ---------------------------------------------------------------------------
# Semantic subsumption matching (DESIGN.md §10)


@dataclasses.dataclass
class SemanticMatch:
    """A subsumption hit: the repository artifact *covers* the anchor's
    sub-plan; splicing it in requires re-applying ``residual`` (a FILTER)
    and/or ``narrow_cols`` (a PROJECT) on top of the Load."""
    anchor: Operator
    residual: Optional[Expr]
    narrow_cols: Optional[Tuple[str, ...]]

    @property
    def n_comp_ops(self) -> int:
        return (self.residual is not None) + (self.narrow_cols is not None)


def _peel_chain(op: Operator):
    """Strip the maximal FILTER/PROJECT chain under ``op``.

    Returns (base, preds, net_cols): the first non-FILTER/PROJECT
    operator, every filter predicate on the way down, and the chain's
    net output columns (the *topmost* PROJECT's column set — inner
    projections are supersets in any well-formed plan; None = all of the
    base's columns survive).  The chain is semantically
    σ(∧preds) ∘ π(net_cols) over the base: FILTER and PROJECT commute
    here because predicates only need their own columns at eval time and
    neither operator reorders rows."""
    preds: List[Expr] = []
    net_cols: Optional[Tuple[str, ...]] = None
    cur = op
    while cur.kind in ("FILTER", "PROJECT"):
        if cur.kind == "FILTER":
            preds.append(cur.params["pred"])
        elif net_cols is None:
            net_cols = tuple(sorted(cur.params["cols"]))
        cur = cur.inputs[0]
    return cur, preds, net_cols


def _base_id(op: Operator, fps: Dict[int, str]) -> str:
    """Identity of a chain base, robust to prior exact rewriting.

    Artifact names are content-addressed — ``art/<fp[:16]>`` of the
    original-form operator that produced them — so a ``LOAD(art/h)``
    spliced in by an earlier rewrite round denotes the same value as any
    operator whose fingerprint starts with ``h``.  Truncating every base
    to the 16-hex prefix lets a repository chain over the original
    subtree line up with an input chain over its already-rewritten
    Load."""
    if op.kind == "LOAD":
        ds = op.params["dataset"]
        if ds.startswith("art/"):
            return ds[4:]
    return fps[id(op)][:16]


def peel_repo_output(repo_plan: PhysicalPlan) -> Optional[tuple]:
    """Precompute a repository plan's probe-side peel:
    ``(output_fp, base_id, preds, net_cols)``, or None when the output
    is not a FILTER/PROJECT chain (nothing to weaken/widen).  Entry
    plans are immutable, so the rewriter caches this across rounds."""
    out = _output_op(repo_plan)
    if out.kind not in ("FILTER", "PROJECT"):
        return None
    repo_fps = repo_plan.fingerprints()
    r_base, r_preds, r_cols = _peel_chain(out)
    return (repo_fps[id(out)], _base_id(r_base, repo_fps),
            r_preds, r_cols)


class SemanticIndex:
    """After the exact ``FingerprintIndex`` probe misses, find repository
    plans identical to an input sub-plan except for a *weaker* FILTER
    predicate and/or *wider* PROJECT column set.

    Input-plan FILTER/PROJECT chain tops are indexed by the identity of
    the first operator *below* the chain (see ``_base_id``), so a probe
    only compares chains hanging off an identical base.  Exact hits take
    priority by construction: the probe returns None whenever the
    repository plan's output fingerprint occurs anywhere in the input
    plan (the exact index would have answered).

    ``fps`` lets the caller share the input plan's fingerprint map with
    an already-built ``FingerprintIndex`` instead of recomputing it."""

    def __init__(self, input_plan: PhysicalPlan,
                 fps: Optional[Dict[int, str]] = None):
        fps = fps if fps is not None else input_plan.fingerprints()
        self._all_fps = frozenset(fps.values())
        # chain-base identity -> chain tops in topo order
        self._by_base: Dict[str, List[tuple]] = {}
        for op in input_plan.topo():
            if op.kind not in ("FILTER", "PROJECT"):
                continue
            base, preds, cols = _peel_chain(op)
            self._by_base.setdefault(_base_id(base, fps), []).append(
                (op, preds, cols))

    def probe(self, repo_plan: PhysicalPlan) -> Optional[SemanticMatch]:
        return self.probe_peeled(peel_repo_output(repo_plan))

    def probe_peeled(self, peeled: Optional[tuple]
                     ) -> Optional[SemanticMatch]:
        if peeled is None:
            return None               # nothing to weaken/widen
        out_fp, base_id, r_preds, r_cols = peeled
        if out_fp in self._all_fps:
            return None               # exact hit: not semantic's business
        cands = self._by_base.get(base_id)
        if not cands:
            return None
        for anchor, preds, cols in reversed(cands):   # topo-latest first
            m = self._compensate(preds, cols, r_preds, r_cols)
            if m is not None:
                residual, narrow = m
                return SemanticMatch(anchor, residual, narrow)
        return None

    @staticmethod
    def _compensate(preds, cols, r_preds, r_cols):
        """Compensation for answering σ(∧preds)∘π(cols) from a stored
        σ(∧r_preds)∘π(r_cols) artifact, or None when unsound."""
        # projection containment: the artifact must retain every column
        # the input chain outputs (r_cols None = all base columns kept)
        if r_cols is not None and (cols is None
                                   or not set(cols) <= set(r_cols)):
            return None
        # predicate containment: input rows must be a subset of stored
        if r_preds and not preds:
            return None
        residual: Optional[Expr] = None
        if preds:
            p = conjoin(preds)
            if r_preds:
                q = conjoin(r_preds)
                if not implies(p, q):
                    return None
                residual = residual_pred(p, q)
            else:
                residual = p
        # the residual re-runs over the artifact: its columns must exist
        if residual is not None and r_cols is not None \
                and not pred_columns(residual) <= set(r_cols):
            return None
        narrow = None
        if cols is not None and (r_cols is None or set(cols) < set(r_cols)):
            narrow = cols
        # residual None and narrow None = the chains are equivalent up to
        # FILTER/PROJECT reordering (different fingerprints, same value):
        # the artifact answers the anchor with no compensation at all
        return residual, narrow
