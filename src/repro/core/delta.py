"""Incremental artifact maintenance: delta plans + merge operators
(DESIGN.md §12).

ReStore's rule R4 treats any input change as total loss: a version bump
deletes every dependent repository entry and the next workflow recomputes
from zero.  Real analytic inputs overwhelmingly grow by *append* (the
cross-industry workload study in PAPERS.md), so this module turns
"stale ⇒ delete" into "stale ⇒ refresh from the delta" whenever the
`Catalog` can prove the change was append-only (its append lineage,
``Catalog.append``).

For a stored entry whose plan P ran over inputs R (now R ∪ ΔR), a
*delta plan* and a *merge operator* are derived per root operator class:

  root class                  delta plan                  merge operator
  --------------------------  --------------------------  ----------------
  record-wise chain           P(Δ): changed Loads bound    append rows
  (FILTER/PROJECT/FOREACH/    to their delta rows,         (shard-local for
  UNION/SPLIT over Loads)     unchanged Loads to empty     partitioned
                                                           artifacts)
  GROUPBY, decomposable aggs  partial aggregate            re-aggregate the
  (sum/count/min/max)         G(sub(Δ))                    union of stored +
                                                           partial (count
                                                           partials SUM)
  DISTINCT                    DISTINCT(sub(Δ))             DISTINCT of union
  JOIN                        three-way delta join         append rows
                              ΔL ⋈ R' ∪ L ⋈ ΔR (L = pre-
                              append snapshot, R' = post)
  anything else (incl. non-   —                            fall back to R4
  decomposable aggregates,                                 delete+recompute
  e.g. mean)

The merged value is bit-identical to a cold recompute over the appended
inputs for append/join merges (they partition the recomputed multiset
exactly) and for min/max/count re-aggregation.  Float SUM re-aggregation
combines the stored total with the delta partial — a different
association than one pass over all rows — so it is bit-identical
exactly when the aggregation is rounding-free (integer-valued float
data within the mantissa, as in the differential tests and the delta
bench) and approximately equal otherwise, the same contract any
partial-aggregation system (combiners, M3R) offers.  The other caveat
is a both-sides-changed JOIN whose bounded probe window saturates
(``expansion`` overflow) — overflows are counted, not silent, exactly
as in normal execution.

`execute_refresh` runs the delta plan through the normal `Engine` (as a
transient job: its output never lands in the store), applies the merge
via `ArtifactStore.append`/`merge_shards`, then rebinds the entry's plan
and ``source_versions`` to the catalog's current versions — after which
the entry matches *exactly* again (same signature a fresh plan over the
new versions fingerprints to), with no semantic compensation needed.
"""
from __future__ import annotations

import dataclasses
import itertools
from functools import partial
from typing import Dict, Optional, Tuple

import jax

from ..dataflow.compiler import Job
from ..dataflow.physical import op_distinct, op_groupby, op_union
from ..dataflow.table import Table, pad_capacity, slice_valid
from .plan import (APPEND_DISTRIBUTIVE_KINDS, Operator, PhysicalPlan, load,
                   plan_signature, rebind_load_versions, store)

# decomposable aggregate -> the aggregate that merges its partials
MERGEABLE_AGGS = {"sum": "sum", "count": "sum", "min": "min", "max": "max"}


@dataclasses.dataclass
class RefreshSpec:
    """A derived refresh: the delta plan to execute plus the merge
    operator class, bindings for its temporary Load datasets, and the
    signature the entry will carry once rebound."""
    entry: object                      # RepositoryEntry
    kind: str                          # append | reagg | distinct | join
    delta_plan: PhysicalPlan           # Load(tmp)…→root→Store(delta_name)
    delta_name: str
    bindings: Dict[str, Table]         # tmp dataset name -> bound rows
    new_versions: Dict[str, int]       # dataset -> catalog version after
    refreshed_signature: str           # entry signature after rebinding
    delta_fraction: float              # Δ rows / base rows over changed ds
    merge_keys: Tuple[str, ...] = ()   # reagg: group keys
    merge_aggs: Optional[Dict] = None  # reagg: out -> (merge fn, out col)


def _subplan_ops(op: Operator):
    return PhysicalPlan([op]).topo()


def _is_recordwise(op: Operator) -> bool:
    return all(o.kind in APPEND_DISTRIBUTIVE_KINDS for o in _subplan_ops(op))


def _empty_like(table: Table, cols=None) -> Table:
    return slice_valid(table, 0, 0, cols=cols)


def derive_refresh(entry, catalog) -> Optional["RefreshSpec"]:
    """Derive a delta plan + merge operator for a stale entry, or None
    when the entry is not incrementally maintainable (plan loads a
    boundary artifact, a changed input is off the append lineage, the
    root class has no merge operator, or an aggregate is
    non-decomposable) — the caller then falls back to R4."""
    plan = entry.plan
    if len(plan.sinks) != 1 or plan.sinks[0].kind != "STORE":
        return None
    root = plan.sinks[0].inputs[0]

    changed: Dict[str, Tuple[int, int]] = {}
    for ld in plan.loads():
        ds = ld.params["dataset"]
        v = ld.params.get("version", 0)
        if ds not in catalog.sources:
            return None            # boundary artifact / unknown dataset
        cur = catalog.version(ds)
        if cur == v:
            continue
        if not catalog.is_append_since(ds, v):
            return None            # arbitrary rewrite: R4 territory
        changed[ds] = (v, cur)
    if not changed:
        return None                # nothing stale to refresh

    bindings: Dict[str, Table] = {}
    counter = itertools.count()

    def bind(table: Table) -> str:
        nm = f"tmp$delta${next(counter)}"
        bindings[nm] = table
        return nm

    # column pruning: a Load whose consumers (in this plan) are all
    # PROJECTs only ever contributes those columns, so its delta/base
    # bindings materialize just that subset — host slicing is the bulk
    # of a small refresh's cost, and wide source rows (strings) would
    # otherwise be copied only to be projected away
    succ = plan.successors()

    def _needed_cols(ld: Operator):
        ss = succ.get(id(ld), [])
        if ss and all(s.kind == "PROJECT" for s in ss):
            cols = set()
            for s in ss:
                cols.update(s.params["cols"])
            return tuple(sorted(cols))
        return None

    def rebound(op: Operator, mode: str) -> Operator:
        """Copy of a record-wise subplan with every Load bound to the
        dataset's delta / current / pre-append rows (each occurrence
        gets its own binding, so self-joins bind independently)."""
        if op.kind == "LOAD":
            ds = op.params["dataset"]
            v = op.params.get("version", 0)
            nc = _needed_cols(op)
            if mode == "delta":
                t = catalog.delta_table(ds, v, cols=nc) if ds in changed \
                    else _empty_like(catalog.get(ds), cols=nc)
            elif mode == "base":
                t = catalog.snapshot_table(ds, v, cols=nc) \
                    if ds in changed else _full(ds, nc)
            else:                  # "full": post-append state
                t = _full(ds, nc)
            return load(bind(t))
        return Operator(op.kind, dict(op.params),
                        [rebound(i, mode) for i in op.inputs])

    def _full(ds: str, nc) -> Table:
        t = catalog.get(ds)
        return t.select(nc) if nc is not None else t

    merge_keys: Tuple[str, ...] = ()
    merge_aggs: Optional[Dict] = None
    if root.kind in APPEND_DISTRIBUTIVE_KINDS and _is_recordwise(root):
        kind = "append"
        droot = rebound(root, "delta")
    elif root.kind == "GROUPBY" and _is_recordwise(root.inputs[0]):
        if any(fn not in MERGEABLE_AGGS
               for fn, _ in root.params["aggs"].values()):
            return None            # non-decomposable (e.g. mean)
        kind = "reagg"
        droot = Operator("GROUPBY", dict(root.params),
                         [rebound(root.inputs[0], "delta")])
        merge_keys = tuple(root.params["keys"])
        merge_aggs = {out: (MERGEABLE_AGGS[fn], out)
                      for out, (fn, _c) in root.params["aggs"].items()}
    elif root.kind == "DISTINCT" and _is_recordwise(root.inputs[0]):
        kind = "distinct"
        droot = Operator("DISTINCT", {}, [rebound(root.inputs[0], "delta")])
    elif root.kind == "JOIN" and all(_is_recordwise(i) for i in root.inputs):
        kind = "join"
        left, right = root.inputs

        def side_changed(side: Operator) -> bool:
            return any(o.kind == "LOAD" and o.params["dataset"] in changed
                       for o in _subplan_ops(side))

        terms = []
        if side_changed(left):     # ΔL ⋈ R'
            terms.append(Operator("JOIN", dict(root.params),
                                  [rebound(left, "delta"),
                                   rebound(right, "full")]))
        if side_changed(right):    # L ⋈ ΔR (L = pre-append snapshot)
            terms.append(Operator("JOIN", dict(root.params),
                                  [rebound(left, "base"),
                                   rebound(right, "delta")]))
        droot = terms[0] if len(terms) == 1 \
            else Operator("UNION", {}, terms)
    else:
        return None

    # content-addressed like every job output: STORE names are excluded
    # from fingerprints, so the process-wide jit cache may serve a
    # structurally-identical delta plan's closure — outputs then arrive
    # under THAT plan's sink name, which must therefore be the same name
    delta_name = "delta/" + \
        PhysicalPlan([droot]).fingerprints()[id(droot)][:16]
    new_versions = {ld.params["dataset"]:
                    catalog.version(ld.params["dataset"])
                    for ld in plan.loads()}
    refreshed_sig = plan_signature(rebind_load_versions(plan, new_versions))

    d_rows = base_rows = 0
    for ds, (v, cur) in changed.items():
        n_old = catalog.rows_at(ds, v) or 0
        n_new = catalog.rows_at(ds, cur) or n_old
        d_rows += n_new - n_old
        base_rows += n_old
    return RefreshSpec(entry=entry, kind=kind,
                       delta_plan=PhysicalPlan([store(droot, delta_name)]),
                       delta_name=delta_name, bindings=bindings,
                       new_versions=new_versions,
                       refreshed_signature=refreshed_sig,
                       delta_fraction=d_rows / max(base_rows, 1),
                       merge_keys=merge_keys, merge_aggs=merge_aggs)


# ---------------------------------------------------------------------------
# Merge operators


# the jitted merge kernels live at module level with static (hashable)
# parameters, so jax's own cache serves every refresh of the same shape
# after the first — a fresh closure per refresh would recompile the
# lexsort/segment-sum chain every time and eager dispatch would swamp
# the (tiny) merge work


@partial(jax.jit, static_argnames=("keys", "aggs_t"))
def _reagg_merge_jit(old: Table, delta: Table, keys, aggs_t) -> Table:
    return op_groupby(op_union(old, delta), keys,
                      {out: (fn, col) for out, fn, col in aggs_t})


@jax.jit
def _distinct_merge(old: Table, delta: Table) -> Table:
    return op_distinct(op_union(old, delta))


def _reagg_merge(keys, aggs):
    """Merge operator of a refreshed GROUPBY artifact: group the union
    of the stored aggregate rows and the delta partial (at most two
    partial rows per key).  min/max/count merges are exact; SUM merges
    re-associate the reduction and are bit-identical to a cold
    recompute only when the aggregation itself is rounding-free (see
    module docstring)."""
    aggs_t = tuple(sorted((out, fn, col)
                          for out, (fn, col) in aggs.items()))

    def merge(old: Table, delta: Table) -> Table:
        return _reagg_merge_jit(old, delta, tuple(keys), aggs_t)
    return merge


def execute_refresh(spec: RefreshSpec, engine, store_, catalog) -> object:
    """Execute a derived refresh through the normal `Engine`: run the
    delta plan as a transient job (its output is returned, never put in
    the store), merge into the stored artifact — shard-locally when the
    artifact is partitioned and its partition keys co-locate each merge
    group — then rebind the entry's plan/signature/source_versions to
    the catalog's current versions so it matches exactly again.  The
    caller (`Repository`) re-indexes the entry under its new signature.
    Returns the delta job's `JobStats`."""
    entry = spec.entry
    n_shards = getattr(engine, "n_shards", None)
    bindings = spec.bindings
    if n_shards:
        bindings = {nm: pad_capacity(t, n_shards)
                    for nm, t in bindings.items()}
    job = Job(job_id=-1, plan=spec.delta_plan,
              inputs=sorted(bindings), outputs=[spec.delta_name],
              blocking=None)
    for nm, t in bindings.items():
        catalog.sources[nm] = t
    try:
        outputs, stats = engine.run_job(job, transient=True)
    finally:
        for nm in bindings:
            catalog.sources.pop(nm, None)
    delta = outputs[spec.delta_name]

    part = store_.partitioning(entry.artifact)
    if spec.kind in ("append", "join"):
        store_.append(entry.artifact, delta)
    else:
        merge = _reagg_merge(spec.merge_keys, spec.merge_aggs) \
            if spec.kind == "reagg" else _distinct_merge
        local_ok = part is not None and (
            spec.kind == "distinct"        # equal rows share a shard
            or set(part["keys"]) <= set(spec.merge_keys))
        if local_ok:
            store_.merge_shards(entry.artifact, delta, merge_fn=merge)
        else:
            # monolithic artifact — or partition keys that don't
            # co-locate the merge groups (re-put monolithic: a safe
            # downgrade, never a wrong skip).  Compact the loaded value
            # first: a memory-backend artifact keeps its producer's full
            # capacity (disk compaction lives on the flusher), and
            # merging at that width would cost as much as recomputing.
            # Power-of-two capacities keep the jitted merge shape-stable
            # across refreshes with slightly different group counts.
            old = slice_valid(store_.get(entry.artifact), 0,
                              round_pow2=True)
            merged = merge(old, slice_valid(delta, 0, round_pow2=True))
            store_.put(entry.artifact, merged)

    entry.plan = rebind_load_versions(entry.plan, spec.new_versions)
    entry.signature = spec.refreshed_signature
    assert plan_signature(entry.plan) == entry.signature
    entry.source_versions = dict(spec.new_versions)
    entry.bytes_out = store_.nbytes(entry.artifact)
    entry.partitioning = store_.partitioning(entry.artifact)
    return stats
