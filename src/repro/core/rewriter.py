"""Plan rewriting (paper §3; semantic compensation DESIGN.md §10).

Given a job's physical plan and the repository, repeatedly:
  scan the repository in its partial order; the first entry whose plan is
  contained in the job plan rewrites it — the matched region is replaced
  by a Load of the entry's artifact — then a fresh scan starts (so several
  repository plans can rewrite one job, exactly as in the paper).

Beyond the paper, when a full exact scan comes up empty the rewriter
probes the ``SemanticIndex``: a stored artifact that merely *covers* the
matched region (weaker FILTER / wider PROJECT) is spliced in together
with a compensation chain — FILTER(residual) and/or PROJECT(narrowing) on
top of the Load — that re-derives the exact value.  The compensation root
inherits the anchor's origin, so the enumerator can re-materialize the
exact value under its canonical name (upgrading the semantic hit to an
exact one for future runs).

The rewriter tracks, for every operator of the rewritten plan, which
operator of the *original* plan it computes.  The sub-job enumerator uses
this to name candidate artifacts by original-form fingerprints, keeping
the repository language canonical across runs (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .matcher import (FingerprintIndex, SemanticIndex,
                      pairwise_plan_traversal, peel_repo_output)
from .plan import (Operator, Partitioning, PhysicalPlan, filter_, load,
                   project)
from .repository import Repository, RepositoryEntry


@dataclasses.dataclass
class RewriteResult:
    plan: PhysicalPlan
    used: List[RepositoryEntry]              # entries applied, in order
    origin: Dict[int, Operator]              # rewritten op id -> original op
    n_semantic: int = 0                      # of which, subsumption hits
    # ids (in `plan`) of compensation-chain roots: these ops re-derive a
    # reused value, so the driver must not record their execution as the
    # original operator's cost / missed-reuse statistics
    comp_op_ids: Set[int] = dataclasses.field(default_factory=set)


def _replace_tracking(plan: PhysicalPlan, old: Operator, new: Operator,
                      origin: Dict[int, Operator],
                      tracked: Set[int]) -> Tuple[PhysicalPlan,
                                                  Dict[int, Operator],
                                                  Set[int]]:
    mapping: Dict[int, Operator] = {id(old): new}
    new_origin: Dict[int, Operator] = {}

    def rebuild(op: Operator) -> Operator:
        if id(op) in mapping:
            return mapping[id(op)]
        new_inputs = [rebuild(i) for i in op.inputs]
        if all(a is b for a, b in zip(new_inputs, op.inputs)):
            out = op
        else:
            out = Operator(op.kind, dict(op.params), new_inputs)
        mapping[id(op)] = out
        return out

    sinks = [rebuild(s) for s in plan.sinks]
    rewritten = PhysicalPlan(sinks)
    for op in plan.topo():
        new_op = mapping.get(id(op))
        if new_op is None:
            continue
        orig = origin.get(id(op))
        if orig is not None:
            new_origin[id(new_op)] = orig
    # the injected Load computes what `old` computed
    if id(old) in origin:
        new_origin[id(new)] = origin[id(old)]
    # carry tracked op ids through the rebuild (ops replaced away drop out)
    new_tracked = {id(mapping[t]) for t in tracked if t in mapping}
    return rewritten, new_origin, new_tracked


def _avoided_exchanges(plan: PhysicalPlan, anchor: Operator,
                       part: Optional[Partitioning],
                       n_shards: Optional[int]) -> int:
    """How many downstream exchanges a co-partitioned artifact spliced
    at ``anchor`` makes shuffle-free (DESIGN.md §11): walk the anchor's
    consumers through partition-preserving operators and count blocking
    consumers whose keys the artifact's property covers/aligns."""
    if part is None or n_shards is None or part.n_parts != n_shards:
        return 0
    succ = plan.successors()
    n = 0
    frontier = [anchor]
    seen = set()
    while frontier:
        op = frontier.pop()
        for s in succ.get(id(op), []):
            if id(s) in seen:
                continue
            seen.add(id(s))
            k = s.kind
            if k in ("FILTER", "SPLIT", "STORE"):
                frontier.append(s)
            elif k == "PROJECT" \
                    and set(part.keys) <= set(s.params["cols"]):
                frontier.append(s)
            elif k == "GROUPBY" \
                    and part.covers(s.params["keys"], n_shards):
                n += 1
            elif k == "JOIN":
                keys = s.params["left_keys"] if s.inputs[0] is op \
                    else s.params["right_keys"]
                n += part.aligns(keys, n_shards)
            elif k == "COGROUP":
                keys = s.params["keys_left"] if s.inputs[0] is op \
                    else s.params["keys_right"]
                n += part.aligns(keys, n_shards)
            elif k == "DISTINCT":
                n += 1     # any subset partitioning co-locates equal rows
    return n


def rewrite_plan(plan: PhysicalPlan, repo: Repository,
                 use_algorithm1: bool = False,
                 semantic: bool = True,
                 max_rewrites: int = 64,
                 n_shards: Optional[int] = None,
                 record: bool = True) -> RewriteResult:
    """Rewrite ``plan`` against the repository until no entry matches.

    Each round scans ``repo.ordered()`` (the paper's partial order, so
    the first hit is the best hit); the matched region is replaced by a
    Load of the entry's artifact and a fresh scan starts, letting
    several repository plans rewrite one job.  When an exact scan misses
    and ``semantic`` is on, the round falls back to subsumption probes
    (DESIGN.md §10): the anchor is replaced by the Load *plus* its
    compensation chain, and the realized saving is net of the predicted
    compensation compute.  Every hit is recorded via ``repo.record_use``
    with the predicted time saved and its kind, which feeds recency
    eviction, the cost model's expected-reuse statistics (DESIGN.md §9),
    and the repository's exact/semantic hit counters.  Returns the
    rewritten plan, the entries applied (in order), and the
    rewritten-op -> original-op map the sub-job enumerator needs.

    ``record=False`` makes the scan a pure *planning probe*: no
    ``record_use`` credit is issued.  The batch optimizer (DESIGN.md
    §16) probes candidate shared sub-plans to see what is already
    materialized; those probes are not reuse hits, and crediting them
    would inflate recency/hit-count and the expected-uses estimate the
    repository evicts by."""
    origin: Dict[int, Operator] = {id(op): op for op in plan.topo()}
    used: List[RepositoryEntry] = []
    n_semantic = 0
    comp_ids: Set[int] = set()
    # entry plans are immutable: peel each once, not once per round
    peels: Dict[int, Optional[tuple]] = {}

    cm = repo.cost_model
    for _ in range(max_rewrites):
        hit: Optional[Tuple[RepositoryEntry, Operator]] = None
        index: Optional[FingerprintIndex] = None
        if use_algorithm1:
            # faithful sequential scan with Algorithm 1 per entry
            for entry in repo.ordered():
                anchor = pairwise_plan_traversal(plan, entry.plan)
                if anchor is not None and anchor.kind not in ("LOAD", "STORE"):
                    if not cm.should_splice(entry):
                        continue       # L7 guard: benefit below overhead
                    hit = (entry, anchor)
                    break
        else:
            index = FingerprintIndex(plan)
            for entry in repo.ordered():
                # entry.signature IS the output fingerprint: no per-probe
                # Merkle pass over the entry plan
                anchor = index.probe_fp(entry.signature)
                if anchor is not None:
                    if not cm.should_splice(entry):
                        continue       # L7 guard: benefit below overhead
                    hit = (entry, anchor)
                    break
        if hit is not None:
            entry, anchor = hit
            new_load = load(entry.artifact)
            saved = cm.savings_per_reuse_s(
                entry.producer_cost_s or entry.exec_time_s, entry.bytes_out)
            if entry.partitioning is not None:
                # the partition property rides along on the spliced Load
                # (physical property: not part of the fingerprint), and
                # every downstream exchange it makes shuffle-free is
                # extra realized savings (DESIGN.md §11)
                new_load.params["partitioning"] = dict(entry.partitioning)
                saved += _avoided_exchanges(
                    plan, anchor, Partitioning.from_dict(entry.partitioning),
                    n_shards) * cm.shuffle_cost_s(entry.bytes_out)
            plan, origin, comp_ids = _replace_tracking(
                plan, anchor, new_load, origin, comp_ids)
            used.append(entry)
            if record:
                repo.record_use(entry, saved_s=max(saved, 0.0))
            continue
        if semantic and not use_algorithm1:
            sem = None
            sem_index = SemanticIndex(plan, fps=index.fps)
            for entry in repo.ordered():
                if id(entry) not in peels:
                    peels[id(entry)] = peel_repo_output(entry.plan)
                m = sem_index.probe_peeled(peels[id(entry)])
                if m is not None:
                    sem = (entry, m)
                    break
            if sem is not None:
                entry, m = sem
                comp: Operator = load(entry.artifact)
                saved = cm.savings_per_reuse_s(
                    entry.producer_cost_s or entry.exec_time_s,
                    entry.bytes_out) - cm.compensation_cost_s(
                        entry.bytes_out, m.n_comp_ops)
                if entry.partitioning is not None:
                    # compensation FILTERs preserve the property (the
                    # executor's propagation re-checks PROJECT
                    # narrowing), so a co-partitioned covering artifact
                    # earns the same avoided-exchange credit as an
                    # exact hit
                    comp.params["partitioning"] = dict(entry.partitioning)
                    saved += _avoided_exchanges(
                        plan, m.anchor,
                        Partitioning.from_dict(entry.partitioning),
                        n_shards) * cm.shuffle_cost_s(entry.bytes_out)
                if m.residual is not None:
                    comp = filter_(comp, m.residual)
                if m.narrow_cols is not None:
                    comp = project(comp, m.narrow_cols)
                plan, origin, comp_ids = _replace_tracking(
                    plan, m.anchor, comp, origin, comp_ids)
                comp_ids.add(id(comp))
                used.append(entry)
                n_semantic += 1
                if record:
                    repo.record_use(entry, saved_s=max(saved, 0.0),
                                    kind="semantic")
                continue
        break
    return RewriteResult(plan, used, origin, n_semantic, comp_ids)


def is_trivial(plan: PhysicalPlan) -> bool:
    """True when every sink is STORE(LOAD(...)) — a fully-reused job."""
    for s in plan.sinks:
        if s.kind != "STORE" or s.inputs[0].kind != "LOAD":
            return False
    return True
