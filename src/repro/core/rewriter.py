"""Plan rewriting (paper §3).

Given a job's physical plan and the repository, repeatedly:
  scan the repository in its partial order; the first entry whose plan is
  contained in the job plan rewrites it — the matched region is replaced
  by a Load of the entry's artifact — then a fresh scan starts (so several
  repository plans can rewrite one job, exactly as in the paper).

The rewriter tracks, for every operator of the rewritten plan, which
operator of the *original* plan it computes.  The sub-job enumerator uses
this to name candidate artifacts by original-form fingerprints, keeping
the repository language canonical across runs (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .matcher import FingerprintIndex, match_bottom_up, pairwise_plan_traversal
from .plan import Operator, PhysicalPlan, load
from .repository import Repository, RepositoryEntry


@dataclasses.dataclass
class RewriteResult:
    plan: PhysicalPlan
    used: List[RepositoryEntry]              # entries applied, in order
    origin: Dict[int, Operator]              # rewritten op id -> original op


def _replace_tracking(plan: PhysicalPlan, old: Operator, new: Operator,
                      origin: Dict[int, Operator]) -> Tuple[PhysicalPlan,
                                                            Dict[int, Operator]]:
    mapping: Dict[int, Operator] = {id(old): new}
    new_origin: Dict[int, Operator] = {}

    def rebuild(op: Operator) -> Operator:
        if id(op) in mapping:
            return mapping[id(op)]
        new_inputs = [rebuild(i) for i in op.inputs]
        if all(a is b for a, b in zip(new_inputs, op.inputs)):
            out = op
        else:
            out = Operator(op.kind, dict(op.params), new_inputs)
        mapping[id(op)] = out
        return out

    sinks = [rebuild(s) for s in plan.sinks]
    rewritten = PhysicalPlan(sinks)
    for op in plan.topo():
        new_op = mapping.get(id(op))
        if new_op is None:
            continue
        orig = origin.get(id(op))
        if orig is not None:
            new_origin[id(new_op)] = orig
    # the injected Load computes what `old` computed
    if id(old) in origin:
        new_origin[id(new)] = origin[id(old)]
    return rewritten, new_origin


def rewrite_plan(plan: PhysicalPlan, repo: Repository,
                 use_algorithm1: bool = False,
                 max_rewrites: int = 64) -> RewriteResult:
    """Rewrite ``plan`` against the repository until no entry matches.

    Each round scans ``repo.ordered()`` (the paper's partial order, so
    the first hit is the best hit); the matched region is replaced by a
    Load of the entry's artifact and a fresh scan starts, letting
    several repository plans rewrite one job.  Every hit is recorded via
    ``repo.record_use`` with the predicted time saved, which feeds both
    recency-based eviction and the cost model's expected-reuse
    statistics (DESIGN.md §9).  Returns the rewritten plan, the entries
    applied (in order), and the rewritten-op -> original-op map the
    sub-job enumerator needs."""
    origin: Dict[int, Operator] = {id(op): op for op in plan.topo()}
    used: List[RepositoryEntry] = []

    for _ in range(max_rewrites):
        hit: Optional[Tuple[RepositoryEntry, Operator]] = None
        if use_algorithm1:
            # faithful sequential scan with Algorithm 1 per entry
            for entry in repo.ordered():
                anchor = pairwise_plan_traversal(plan, entry.plan)
                if anchor is not None and anchor.kind not in ("LOAD", "STORE"):
                    hit = (entry, anchor)
                    break
        else:
            index = FingerprintIndex(plan)
            for entry in repo.ordered():
                anchor = index.probe(entry.plan)
                if anchor is not None:
                    hit = (entry, anchor)
                    break
        if hit is None:
            break
        entry, anchor = hit
        new_load = load(entry.artifact)
        plan, origin = _replace_tracking(plan, anchor, new_load, origin)
        used.append(entry)
        saved = repo.cost_model.savings_per_reuse_s(
            entry.producer_cost_s or entry.exec_time_s, entry.bytes_out)
        repo.record_use(entry, saved_s=max(saved, 0.0))
    return RewriteResult(plan, used, origin)


def is_trivial(plan: PhysicalPlan) -> bool:
    """True when every sink is STORE(LOAD(...)) — a fully-reused job."""
    for s in plan.sinks:
        if s.kind != "STORE" or s.inputs[0].kind != "LOAD":
            return False
    return True
