"""Multi-query batch optimization (DESIGN.md §16).

ReStore reuses job outputs *across time*; this module shares work
*within a batch*, where the cross-industry workload studies
(arXiv:1208.4174) put the bigger win: N queued workflows that overlap
right now.  ``optimize_batch`` finds the common sub-plans —

  * **exact** — operators whose Merkle fingerprints appear in ≥2 of the
    batch's plans (the same currency ``FingerprintIndex`` probes with),
    keeping only per-plan *maximal* ones so a shared join subsumes its
    shared inputs;
  * **subsumed** — FILTER/PROJECT chains over the same base that differ
    only in predicate strength / column width: the batch's *covering*
    chain (weakest predicate, widest columns — checked with the same
    implication machinery ``SemanticIndex`` uses) is materialized once
    and every variant compensates with a residual filter at query time

— then builds one shared prefix plan whose operator DAG is physically
deduplicated (operators keyed by fingerprint, so the engine computes
each shared value once even inside the prefix), schedules it first, and
hands the repository *known-uses* hints: a sub-job about to be consumed
by 5 queries is admitted with known (not estimated) expected uses in
the CostModel knapsack, overriding the seen-once admission gate.

Planning never perturbs the economics it relies on: repository probes
run through ``rewrite_plan(..., record=False)`` so an optimizer looking
at the repository is not mistaken for a reuse hit (the satellite-6
audit), and already-materialized shared sub-plans are simply dropped
from the prefix.

``run_batch`` drives a :class:`~repro.core.restore.ReStore` through the
whole protocol — hint, pin, shared prefix, per-query runs, release —
and audits ``dup_executions`` (a shared sub-plan executing more than
once anywhere in the batch) for the bench/CI gate.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..dataflow.builder import as_plan
from ..dataflow.compiler import art_name, compile_workflow
from .matcher import SemanticIndex, _base_id, _peel_chain
from .plan import Operator, PhysicalPlan, store
from .rewriter import is_trivial, rewrite_plan

# Operator kinds worth sharing across queries.  LOAD is free (both
# queries read the catalog anyway), STORE/SPLIT are plumbing.
SHARE_KINDS = frozenset({"PROJECT", "FILTER", "FOREACH", "JOIN",
                         "GROUPBY", "COGROUP", "DISTINCT", "UNION"})


@dataclasses.dataclass
class SharedSubplan:
    """One sub-plan selected for single execution on behalf of the batch."""
    fp: str                   # fingerprint of the shared operator
    kind: str                 # operator kind (JOIN, FOREACH, ...)
    n_consumers: int          # distinct queries known to consume it
    artifact: str             # content-addressed boundary artifact name
    plan: PhysicalPlan        # standalone Load...→op→Store form
    semantic: bool = False    # covering chain serving subsumed variants
    already_stored: bool = False  # repository probe found it → no exec


@dataclasses.dataclass
class BatchPlan:
    plans: List[PhysicalPlan]            # the batch, coerced to plans
    shared_plan: Optional[PhysicalPlan]  # dedup'd shared prefix (or None)
    shared: List[SharedSubplan]
    known_uses: Dict[str, float]         # hint key -> known consumers
    boundary_artifacts: Set[str]         # everything the prefix stores
    planning_s: float = 0.0


@dataclasses.dataclass
class BatchResult:
    results: List[Dict]                  # per-query outputs, batch order
    reports: List                        # per-query RunReport
    batch: BatchPlan
    shared_report: Optional[object]      # RunReport of the shared prefix
    shared_wall_s: float
    dup_executions: int


# ---------------------------------------------------------------------------
# Batch analysis


def _chain_tops(plan: PhysicalPlan) -> List[Operator]:
    """FILTER/PROJECT operators that top a maximal chain (no
    FILTER/PROJECT consumer above them) — the units SemanticIndex
    reasons about."""
    succ = plan.successors()
    return [op for op in plan.topo()
            if op.kind in ("FILTER", "PROJECT")
            and not any(s.kind in ("FILTER", "PROJECT")
                        for s in succ[id(op)])]


def _maximal_shared_fps(plans: Sequence[PhysicalPlan],
                        all_fps: List[Dict[int, str]],
                        shared_fps: Set[str]) -> Set[str]:
    """Shared fingerprints that are maximal in at least one plan: no
    ancestor (toward the sinks) of an occurrence is itself shared.  The
    union over plans keeps a sub-plan that is maximal for one query even
    when another query shares a larger cone containing it."""
    keep: Set[str] = set()
    for plan, fps in zip(plans, all_fps):
        succ = plan.successors()
        covered: Dict[int, bool] = {}
        for op in reversed(plan.topo()):
            cov = False
            for s in succ[id(op)]:
                if covered[id(s)] or fps[id(s)] in shared_fps:
                    cov = True
                    break
            covered[id(op)] = cov
        for op in plan.topo():
            if fps[id(op)] in shared_fps and not covered[id(op)]:
                keep.add(fps[id(op)])
    return keep


def _semantic_groups(plans: Sequence[PhysicalPlan],
                     all_fps: List[Dict[int, str]]):
    """Group FILTER/PROJECT chain tops by the identity of the operator
    under the chain, across every plan in the batch.  Returns
    base_id -> list of (plan_idx, top_op, preds, net_cols, top_fp)."""
    groups: Dict[str, List[Tuple]] = {}
    for pi, (plan, fps) in enumerate(zip(plans, all_fps)):
        for top in _chain_tops(plan):
            base, preds, cols = _peel_chain(top)
            groups.setdefault(_base_id(base, fps), []).append(
                (pi, top, preds, cols, fps[id(top)]))
    return groups


def _pick_covering(group, exact_fps: Set[str]):
    """From one base's chain variants pick the covering chain — the one
    whose stored output can answer the most *other* variants through
    residual compensation (``SemanticIndex._compensate`` soundness).
    Variants already shared exactly have their own materialization and
    do not count as semantic consumers.  Returns
    (top_op, plan_idx, top_fp, n_consumer_plans) or None."""
    best = None
    for (pi, top, preds, cols, fp) in group:
        consumers = {pi}
        for (qi, _, q_preds, q_cols, q_fp) in group:
            if q_fp == fp or q_fp in exact_fps:
                continue
            if SemanticIndex._compensate(q_preds, q_cols,
                                         preds, cols) is not None:
                consumers.add(qi)
        if len(consumers) >= 2 and (best is None
                                    or len(consumers) > best[3]):
            best = (top, pi, fp, len(consumers))
    return best


def optimize_batch(queries: Sequence, repo=None,
                   semantic: bool = True) -> BatchPlan:
    """Analyze a batch of queries (plans or dataflow builders) and plan
    the shared execution: which sub-plans are common (exactly or by
    subsumption), one deduplicated prefix plan that materializes each of
    them once, and the known-uses hints for the repository.

    ``repo`` (optional) is probed — with ``record=False``, planning
    probes must not look like reuse hits — to drop shared sub-plans the
    repository already holds."""
    t0 = time.time()
    plans = [as_plan(q) for q in queries]
    all_fps = [p.fingerprints() for p in plans]

    # -- exact sharing: fingerprint present in >= 2 distinct plans
    where: Dict[str, Set[int]] = {}
    reps: Dict[str, Tuple[int, Operator]] = {}
    for pi, (plan, fps) in enumerate(zip(plans, all_fps)):
        for op in plan.topo():
            if op.kind not in SHARE_KINDS:
                continue
            fp = fps[id(op)]
            where.setdefault(fp, set()).add(pi)
            reps.setdefault(fp, (pi, op))
    exact_fps = {fp for fp, pis in where.items() if len(pis) >= 2}
    selected: List[Tuple[str, Operator, int, bool]] = [
        (fp, reps[fp][1], len(where[fp]), False)
        for fp in sorted(_maximal_shared_fps(plans, all_fps, exact_fps))]

    # -- subsumed sharing: covering FILTER/PROJECT chains across plans
    if semantic:
        seen = {fp for fp, _, _, _ in selected}
        groups = _semantic_groups(plans, all_fps)
        for base_id in sorted(groups):
            group = groups[base_id]
            if len({pi for pi, *_ in group}) < 2:
                continue
            pick = _pick_covering(group, exact_fps)
            if pick is None:
                continue
            top, pi, fp, n = pick
            if fp in exact_fps:
                # covering chain is itself exact-shared: already
                # selected; raise its known uses to the semantic reach
                selected = [(f, o, max(c, n) if f == fp else c, s)
                            for f, o, c, s in selected]
                continue
            if fp not in seen:
                seen.add(fp)
                selected.append((fp, top, n, True))

    # -- one physically-deduplicated prefix DAG (operators keyed by
    # fingerprint, so shared subtrees are computed once inside it too)
    canon: Dict[str, Operator] = {}

    def build(op: Operator, fps: Dict[int, str]) -> Operator:
        fp = fps[id(op)]
        got = canon.get(fp)
        if got is None:
            got = Operator(op.kind, dict(op.params),
                           [build(i, fps) for i in op.inputs])
            canon[fp] = got
        return got

    shared: List[SharedSubplan] = []
    live_sinks: List[Operator] = []
    for fp, op, n, is_sem in selected:
        # identical fingerprints denote identical subtrees, so any
        # representative occurrence serves; reps covers every SHARE_KINDS
        # op in the batch, semantic picks included
        rep_pi, rep_op = reps[fp]
        c_op = build(rep_op, all_fps[rep_pi])
        sink = store(c_op, art_name(fp))
        sub = PhysicalPlan([sink])
        wf = compile_workflow(sub)
        artifact = wf.final_outputs[art_name(fp)]
        stored_already = False
        if repo is not None:
            probe = rewrite_plan(sub, repo, semantic=semantic,
                                 record=False)
            stored_already = is_trivial(probe.plan)
        shared.append(SharedSubplan(fp=fp, kind=op.kind, n_consumers=n,
                                    artifact=artifact, plan=sub,
                                    semantic=is_sem,
                                    already_stored=stored_already))
        if not stored_already:
            live_sinks.append(sink)

    shared_plan = PhysicalPlan(live_sinks) if live_sinks else None

    # -- known-uses hints + the prefix's full boundary footprint
    known: Dict[str, float] = {}
    boundary: Set[str] = set()
    for s in shared:
        known[s.artifact] = max(known.get(s.artifact, 0.0),
                                float(s.n_consumers))
        boundary.add(s.artifact)
    if shared_plan is not None:
        peak = max((s.n_consumers for s in shared), default=0)
        for job in compile_workflow(shared_plan).jobs:
            for out in job.outputs:
                boundary.add(out)
                # intermediate boundaries under a shared op serve at
                # least that op's consumers transitively
                known.setdefault(out, float(peak))

    return BatchPlan(plans=plans, shared_plan=shared_plan, shared=shared,
                     known_uses=known, boundary_artifacts=boundary,
                     planning_s=time.time() - t0)


# ---------------------------------------------------------------------------
# Batch execution


def count_dup_executions(bp: BatchPlan, reports) -> int:
    """Shared sub-plans executed more than once across the batch: a
    per-query job that re-produced a shared boundary artifact, or that
    recomputed a shared operator no splice shielded.  A splice at or
    above an operator (its subtree was replaced by an artifact load —
    exactly, or semantically at a chain top over the same base) means
    the operator never executed, so a job that reuses the FILTER chain
    artifact is clean even though the shared FOREACH below it also
    appears in its plan.  The shared prefix itself is the sanctioned
    single execution, so any hit here is a duplicate."""
    shared_arts = {s.artifact for s in bp.shared}
    sem_base = {}                 # covering artifact -> its chain's base id
    for s in bp.shared:
        if s.semantic:
            top = s.plan.sinks[0].inputs[0]
            base, _, _ = _peel_chain(top)
            sem_base[s.artifact] = _base_id(base, s.plan.fingerprints())
    dup = 0
    for plan, rep in zip(bp.plans, reports):
        wf = compile_workflow(plan)
        for job, jr in zip(wf.jobs, rep.jobs):
            if not jr.executed:
                continue
            if set(job.outputs) & bp.boundary_artifacts:
                dup += 1
                continue
            fps = job.plan.fingerprints()
            reused = set(jr.reused_artifacts)
            spliced = {id(op) for op in job.plan.topo()
                       if art_name(fps[id(op)]) in reused}
            hot_bases = {sem_base[a] for a in reused if a in sem_base}
            if hot_bases:
                for top in _chain_tops(job.plan):
                    base, _, _ = _peel_chain(top)
                    if _base_id(base, fps) in hot_bases:
                        spliced.add(id(top))
            succ = job.plan.successors()
            covered: Dict[int, bool] = {}
            for op in reversed(job.plan.topo()):
                covered[id(op)] = (id(op) in spliced
                                   or any(covered[id(s2)]
                                          for s2 in succ[id(op)]))
            if any(art_name(fps[id(op)]) in shared_arts
                   and not covered[id(op)] for op in job.plan.topo()):
                dup += 1
    return dup


def run_batch(driver, queries: Sequence, semantic: bool = True
              ) -> BatchResult:
    """Execute a batch through one :class:`ReStore` driver: optimize,
    install known-uses hints, pin the shared boundary (names pin fine
    before the artifacts exist), run the shared prefix once, run each
    query (their rewrites splice the shared artifacts), then release
    hints and pins and settle the repository budget."""
    bp = optimize_batch(queries, repo=driver.repo, semantic=semantic)
    repo = driver.repo
    shared_report = None
    repo.set_known_uses(bp.known_uses)
    repo.pin(bp.boundary_artifacts)
    try:
        if bp.shared_plan is not None:
            _, shared_report = driver.run(bp.shared_plan)
        results: List[Dict] = []
        reports: List = []
        for plan in bp.plans:
            out, rep = driver.run(plan)
            results.append(out)
            reports.append(rep)
    finally:
        repo.unpin(bp.boundary_artifacts)
        repo.clear_known_uses(bp.known_uses)
        repo.rebalance()
    return BatchResult(
        results=results, reports=reports, batch=bp,
        shared_report=shared_report,
        shared_wall_s=(shared_report.total_wall_s
                       if shared_report is not None else 0.0),
        dup_executions=count_dup_executions(bp, reports))
