"""llama4-maverick-400b-a17b [moe] 48L d_model=5120 40H (GQA kv=8)
vocab=202048, MoE 128 experts top-1 + 1 shared expert, MoE every other
layer (dense interleave d_ff = 2 x expert d_ff = 16384); the multimodal
early-fusion frontend is out of scope for the LM shapes (text backbone
per the assignment).  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from ..models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=202048,
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=128, top_k=1, d_expert=8192, n_shared=1,
                  every_k_layers=2, capacity_factor=1.25),
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=256, dtype="float32", remat=False,
    moe=MoEConfig(n_experts=8, top_k=1, d_expert=32, n_shared=1,
                  every_k_layers=2, capacity_factor=8.0),  # dropless smoke
)
