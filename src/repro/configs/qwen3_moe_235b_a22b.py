"""qwen3-moe-235b-a22b [moe] 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8, qk_norm  [hf:Qwen/Qwen3-30B-A3B; hf]"""
from ..models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936,
    qk_norm=True, rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536,
                  every_k_layers=1, capacity_factor=1.25),
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=256, dtype="float32", remat=False,
    # capacity_factor >= n_experts makes the smoke config dropless, so
    # prefill+decode is bit-consistent with the full forward
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, every_k_layers=1,
                  capacity_factor=8.0),
)
