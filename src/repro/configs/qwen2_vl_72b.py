"""qwen2-vl-72b [vlm] 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE (3-axis rotary), dynamic resolution; the vision
frontend is a STUB (input_specs() provides precomputed, merged patch/text
embeddings plus 3-axis position ids).  [arXiv:2409.12191; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064,
    m_rope=True, mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0, frontend="embeds",
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", remat=False,
    mrope_sections=(2, 3, 3),
)
