"""codeqwen1.5-7b [dense] 32L d_model=4096 32H (GQA kv=32) d_ff=13440
vocab=92416 — qwen1.5 arch (QKV biases)  [hf:Qwen/CodeQwen1.5-7B; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=13440, vocab_size=92416,
    attn_bias=True, rope_theta=1_000_000.0,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", remat=False,
)
