"""xlstm-350m [ssm] 24 blocks d_model=1024 4H vocab=50304 — sLSTM + mLSTM
blocks (xLSTM[7:1]: one sLSTM per 8 blocks), d_ff=0 (mLSTM blocks are
pre-up-projection and carry their own FFN-equivalent projections).
[arXiv:2405.04517; unverified]"""
from ..models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0, chunk_size=256),
    tie_embeddings=True,
)

SMOKE = CONFIG.with_(
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    vocab_size=256, dtype="float32", remat=False,
    xlstm=XLSTMConfig(slstm_every=4, proj_factor=2.0, chunk_size=32),
)
