"""Architecture config registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib
from typing import Dict

from ..models.config import ModelConfig

_MODULES: Dict[str, str] = {
    "qwen3-1.7b": "qwen3_1_7b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "minicpm3-4b": "minicpm3_4b",
    "yi-6b": "yi_6b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "xlstm-350m": "xlstm_350m",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
}

ARCH_IDS = list(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.SMOKE if smoke else mod.CONFIG
