"""minicpm3-4b [dense] 62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA
(multi-head latent attention, DeepSeek-V2-style compressed KV)
[hf:openbmb/MiniCPM3-4B; hf]"""
from ..models.config import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=6400, vocab_size=73448,
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    rope_theta=10_000.0,
)

SMOKE = CONFIG.with_(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", remat=False,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
)
