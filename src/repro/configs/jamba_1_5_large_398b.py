"""jamba-1.5-large-398b [hybrid] 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16 experts top-2 every other layer, Mamba+attention 1:7
interleave (one attention layer per 8).  [arXiv:2403.19887; hf]"""
from ..models.config import MoEConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    attn_every=8,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576,
                  every_k_layers=2, capacity_factor=1.25),
    rope_theta=10_000.0,
)

SMOKE = CONFIG.with_(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", remat=False,
    attn_every=4,
    ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, every_k_layers=2,
                  capacity_factor=4.0),  # dropless smoke
)
