"""seamless-m4t-medium [audio] 12L encoder + 12L decoder, d_model=1024
16H d_ff=4096 vocab=256206 — enc-dec; speech frontend is a STUB
(input_specs() provides precomputed frame embeddings).
[arXiv:2308.11596; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_encoder_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206,
    rope_theta=10_000.0, frontend="embeds",
)

SMOKE = CONFIG.with_(
    n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=256, dtype="float32", remat=False,
)
