"""Pure-jnp oracle for filter+compact."""
import jax.numpy as jnp


def filter_compact_ref(values, mask):
    n, d = values.shape
    order = jnp.argsort(~mask, stable=True)
    out = jnp.take(values, order, axis=0).astype(jnp.float32)
    total = mask.sum()
    live = jnp.arange(n) < total
    return jnp.where(live[:, None], out, 0.0), total
