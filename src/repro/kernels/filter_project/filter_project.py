"""Fused filter + compaction as a Pallas TPU kernel.

The Store-time compaction hot spot (filter marks rows invalid; storing
needs the survivors contiguous).  GPUs do this with warp ballots and
atomics; the TPU-native design compacts each tile with a permutation
matmul on the MXU:

    pos_i  = cumsum(mask)[i] - 1                    (slot for live row i)
    P[i,j] = 1 if pos_i == j and mask_i             (TN x TN one-hot)
    tile_out = P^T @ rows                           (live rows to front)

plus a per-tile count; the ops wrapper stitches tiles with a cheap
jnp gather using the exclusive scan of counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fp_kernel(mask_ref, val_ref, count_ref, out_ref, *, tile_n):
    mask = mask_ref[0].astype(jnp.int32)           # (TN,)
    vals = val_ref[0].astype(jnp.float32)          # (TN, D)
    pos = jnp.cumsum(mask) - 1                     # slot per live row
    onehot = ((pos[:, None] ==
               jax.lax.broadcasted_iota(jnp.int32, (tile_n, tile_n), 1))
              & (mask[:, None] > 0)).astype(jnp.float32)
    out_ref[0] = jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    count_ref[0, 0] = mask.sum()


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def filter_compact(values, mask, *, tile_n: int = 256,
                   interpret: bool = False):
    """values: (N, D) f32; mask: (N,) bool.  Returns (out, total):
    out (N, D) with survivors compacted to the front, total survivors."""
    n, d = values.shape
    tile_n = min(tile_n, n)
    assert n % tile_n == 0
    n_tiles = n // tile_n

    counts, tiles = pl.pallas_call(
        functools.partial(_fp_kernel, tile_n=tile_n),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),
            pl.BlockSpec((1, tile_n, d), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, tile_n, d), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, tile_n, d), jnp.float32),
        ],
        interpret=interpret,
    )(mask.reshape(n_tiles, tile_n), values.reshape(n_tiles, tile_n, d))

    counts = counts.reshape(n_tiles)
    offsets = jnp.cumsum(counts) - counts          # exclusive scan
    total = counts.sum()

    # global stitch: row j of tile t lands at offsets[t] + j if j < count[t]
    dst = offsets[:, None] + jnp.arange(tile_n)[None, :]
    live = jnp.arange(tile_n)[None, :] < counts[:, None]
    dst = jnp.where(live, dst, n)                  # park dead rows OOB
    out = jnp.zeros((n, d), jnp.float32)
    out = out.at[dst.reshape(-1)].set(tiles.reshape(-1, d), mode="drop")
    return out, total
