"""Jit'd wrapper with impl dispatch."""
from .filter_project import filter_compact
from .ref import filter_compact_ref


def compact(values, mask, *, impl: str = "ref", tile_n: int = 256,
            interpret: bool = True):
    if impl == "pallas":
        return filter_compact(values, mask, tile_n=tile_n,
                              interpret=interpret)
    return filter_compact_ref(values, mask)
