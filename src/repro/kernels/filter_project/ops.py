"""Jit'd wrapper with impl dispatch + internal padding.

``compact`` accepts ANY row count: the kernel wants a tile-multiple, so
inputs are padded with masked-out rows and the output sliced back —
padded rows never survive compaction, so results are unaffected.
"""
import jax.numpy as jnp

from .filter_project import filter_compact
from .ref import filter_compact_ref


def compact(values, mask, *, impl: str = "ref", tile_n: int = 256,
            interpret: bool = True):
    if impl == "pallas":
        n = values.shape[0]
        pad = (-n) % min(tile_n, n) if n else 0
        if pad:
            values = jnp.concatenate(
                [values, jnp.zeros((pad,) + values.shape[1:],
                                   values.dtype)])
            mask = jnp.concatenate([mask, jnp.zeros((pad,), bool)])
        out, total = filter_compact(values, mask, tile_n=tile_n,
                                    interpret=interpret)
        return out[:n], total
    return filter_compact_ref(values, mask)
