"""Join probe as a Pallas TPU kernel: vectorized branchless binary search.

The join hot spot.  GPU hash joins build shared-memory hash tables with
atomics; the TPU-native equivalent keeps the build side *sorted* in VMEM
and probes with a branchless binary search (fori over log2(R) rounds of
vectorized compares) — no scatter, no atomics, MXU-free but fully
VPU-parallel.  Exact-key verification happens in the ops wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(lhash_ref, rhash_ref, pos_ref, *, n_right, rounds):
    lh = lhash_ref[0]                     # (TN,) uint32 probe keys
    rh = rhash_ref[...]                   # (R,)  uint32 sorted build keys

    lo = jnp.zeros(lh.shape, jnp.int32)
    hi = jnp.full(lh.shape, n_right, jnp.int32)

    def body(_, carry):
        lo, hi = carry
        cont = lo < hi
        mid = (lo + hi) // 2
        mv = jnp.take(rh, jnp.clip(mid, 0, n_right - 1))
        go_right = mv < lh
        lo = jnp.where(cont & go_right, mid + 1, lo)
        hi = jnp.where(cont & ~go_right, mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, rounds, body, (lo, hi))
    pos_ref[0] = lo                       # leftmost index with rh >= lh


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def join_probe(left_hashes, right_hashes_sorted, *, tile_n: int = 256,
               interpret: bool = False):
    """left_hashes: (N,) uint32; right_hashes_sorted: (R,) uint32 ascending.
    Returns pos (N,) int32 = searchsorted(right, left, side='left')."""
    n = left_hashes.shape[0]
    r = right_hashes_sorted.shape[0]
    tile_n = min(tile_n, n)
    assert n % tile_n == 0
    n_tiles = n // tile_n
    rounds = max(1, r.bit_length())  # converge lo==hi over [0, r]

    pos = pl.pallas_call(
        functools.partial(_probe_kernel, n_right=r, rounds=rounds),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),
            pl.BlockSpec((r,), lambda i: (0,)),   # build side resident
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, tile_n), jnp.int32),
        interpret=interpret,
    )(left_hashes.reshape(n_tiles, tile_n), right_hashes_sorted)
    return pos.reshape(n)
