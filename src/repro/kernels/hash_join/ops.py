"""Jit'd wrapper with impl dispatch + internal padding.

``probe`` accepts ANY probe-side row count: the kernel wants a
tile-multiple, so the probe lane is zero-padded and the positions
sliced back (padded lookups are discarded).
"""
import jax.numpy as jnp

from .hash_join import join_probe
from .ref import join_probe_ref


def probe(left_hashes, right_hashes_sorted, *, impl: str = "ref",
          tile_n: int = 256, interpret: bool = True):
    if impl == "pallas":
        n = left_hashes.shape[0]
        pad = (-n) % min(tile_n, n) if n else 0
        if pad:
            left_hashes = jnp.concatenate(
                [left_hashes, jnp.zeros((pad,), left_hashes.dtype)])
        pos = join_probe(left_hashes, right_hashes_sorted,
                         tile_n=tile_n, interpret=interpret)
        return pos[:n]
    return join_probe_ref(left_hashes, right_hashes_sorted)
