"""Jit'd wrapper with impl dispatch."""
from .hash_join import join_probe
from .ref import join_probe_ref


def probe(left_hashes, right_hashes_sorted, *, impl: str = "ref",
          tile_n: int = 256, interpret: bool = True):
    if impl == "pallas":
        return join_probe(left_hashes, right_hashes_sorted,
                          tile_n=tile_n, interpret=interpret)
    return join_probe_ref(left_hashes, right_hashes_sorted)
