"""Pure-jnp oracle for the join probe."""
import jax.numpy as jnp


def join_probe_ref(left_hashes, right_hashes_sorted):
    return jnp.searchsorted(right_hashes_sorted, left_hashes,
                            side="left").astype(jnp.int32)
