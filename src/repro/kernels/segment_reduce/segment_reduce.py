"""Sorted segment reduction as a Pallas TPU kernel.

The relational GROUPBY hot spot.  GPU implementations use shared-memory
hash tables + atomics; the TPU-native design exploits that rows arrive
*sorted by segment*: each tile of TN rows touches at most TN consecutive
segment ids, so a tile reduces to a one-hot matmul on the MXU

    partial[tile] = onehot(seg - seg_base, TN)^T @ values        (TN x D)

with a cheap jnp scatter-add combine across tiles in the ops wrapper (the
boundary segment of adjacent tiles overlaps, which the combine resolves —
no atomics anywhere).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _seg_kernel(seg_ref, val_ref, base_ref, part_ref, *, tile_n):
    seg = seg_ref[0]                              # (TN,) int32, sorted
    vals = val_ref[0].astype(jnp.float32)         # (TN, D)
    base = seg[0]
    off = seg - base                              # 0 <= off < TN for live rows
    live = (off >= 0) & (off < tile_n)
    onehot = (off[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (tile_n, tile_n), 1))
    onehot = jnp.where(live[:, None], onehot, False).astype(jnp.float32)
    part_ref[0] = jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # (TN, D)
    base_ref[0, 0] = base


@functools.partial(jax.jit, static_argnames=("num_segments", "tile_n",
                                             "interpret"))
def segment_sum_sorted(values, seg_ids, *, num_segments: int,
                       tile_n: int = 256, interpret: bool = False):
    """values: (N, D) f32; seg_ids: (N,) int32 sorted ascending AND dense
    (consecutive ids, as produced by cumsum-over-boundaries — the engine's
    GROUPBY contract; a tile of TN rows then spans < TN ids).  Rows with
    out-of-range ids (e.g. a num_segments sentinel) are dropped.
    Returns (S, D)."""
    n, d = values.shape
    tile_n = min(tile_n, n)
    assert n % tile_n == 0
    n_tiles = n // tile_n

    bases, parts = pl.pallas_call(
        functools.partial(_seg_kernel, tile_n=tile_n),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),
            pl.BlockSpec((1, tile_n, d), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, tile_n, d), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, tile_n, d), jnp.float32),
        ],
        interpret=interpret,
    )(seg_ids.reshape(n_tiles, tile_n).astype(jnp.int32),
      values.reshape(n_tiles, tile_n, d))

    # combine: scatter-add each tile's partial at its base offset
    out = jnp.zeros((num_segments, d), jnp.float32)
    idx = bases.reshape(n_tiles, 1) + jnp.arange(tile_n)[None, :]
    # negative ids are out-of-range like the >= num_segments sentinel,
    # but mode="drop" only drops high indices — it WRAPS negatives, so
    # push them past the end explicitly
    idx = jnp.where(idx < 0, num_segments, idx)
    out = out.at[idx.reshape(-1)].add(parts.reshape(-1, d), mode="drop")
    return out
