"""Pure-jnp oracle for sorted segment sum."""
import jax
import jax.numpy as jnp


def segment_sum_ref(values, seg_ids, *, num_segments: int):
    ok = (seg_ids >= 0) & (seg_ids < num_segments)
    v = jnp.where(ok[:, None], values.astype(jnp.float32), 0.0)
    sid = jnp.where(ok, seg_ids, num_segments)
    out = jax.ops.segment_sum(v, sid, num_segments=num_segments + 1)
    return out[:num_segments]
