"""Jit'd wrapper with impl dispatch + internal padding.

``segment_sum`` accepts ANY row count: the kernel wants a tile-multiple,
so rows are padded with out-of-range segment ids, which the kernel drops
exactly as the ref masks them.
"""
import jax.numpy as jnp

from .ref import segment_sum_ref
from .segment_reduce import segment_sum_sorted


def segment_sum(values, seg_ids, *, num_segments: int, impl: str = "ref",
                tile_n: int = 256, interpret: bool = True):
    if impl == "pallas":
        n = values.shape[0]
        pad = (-n) % min(tile_n, n) if n else 0
        if pad:
            values = jnp.concatenate(
                [values, jnp.zeros((pad,) + values.shape[1:],
                                   values.dtype)])
            # padded ids sit past num_segments, keeping the lane sorted
            # and the rows outside every real segment
            seg_ids = jnp.concatenate(
                [seg_ids, jnp.full((pad,), num_segments, seg_ids.dtype)])
        return segment_sum_sorted(values, seg_ids,
                                  num_segments=num_segments,
                                  tile_n=tile_n, interpret=interpret)
    return segment_sum_ref(values, seg_ids, num_segments=num_segments)
