"""Jit'd wrapper with impl dispatch."""
from .ref import segment_sum_ref
from .segment_reduce import segment_sum_sorted


def segment_sum(values, seg_ids, *, num_segments: int, impl: str = "ref",
                tile_n: int = 256, interpret: bool = True):
    if impl == "pallas":
        return segment_sum_sorted(values, seg_ids,
                                  num_segments=num_segments,
                                  tile_n=tile_n, interpret=interpret)
    return segment_sum_ref(values, seg_ids, num_segments=num_segments)
