"""Pure-jnp oracle for radix partitioning + the fused bucket scatter."""
import jax.numpy as jnp


def radix_partition_ref(hashes, valid, *, n_parts: int, tile_n: int = 256):
    n = hashes.shape[0]
    tile_n = min(tile_n, n)
    n_tiles = n // tile_n
    pid = (hashes & jnp.uint32(n_parts - 1)).astype(jnp.int32)
    pid = jnp.where(valid, pid, n_parts)
    onehot = (pid[:, None] == jnp.arange(n_parts)[None, :]).astype(jnp.int32)
    hist = onehot.reshape(n_tiles, tile_n, n_parts).sum(axis=1)
    return pid, hist


def partition_scatter_ref(hashes, valid, *, n_parts: int, bucket: int,
                          tile_n: int = 256):
    """Fused binning + bucket-slot assignment (the map side of the
    exchange, DESIGN.md §14).  For every row: destination partition
    ``h % n_parts`` and its *arrival rank* within that partition —
    the count of earlier valid rows bound for the same destination —
    giving scatter slot ``pid * bucket + rank``.  Rows whose rank
    overflows the bounded bucket (and invalid rows) get the drop slot
    ``n_parts * bucket``.

    The running-count rank equals the rank a stable sort by destination
    would assign, so the slots are bit-identical to the former
    argsort+searchsorted exchange — without the O(n log n) sort.
    Returns (slot (N,) int32, overflow () int32)."""
    if n_parts & (n_parts - 1) == 0:
        pid = (hashes & jnp.uint32(n_parts - 1)).astype(jnp.int32)
    else:
        pid = (hashes % jnp.uint32(n_parts)).astype(jnp.int32)
    onehot = ((pid[:, None] == jnp.arange(n_parts)[None, :])
              & valid[:, None]).astype(jnp.int32)
    incl = jnp.cumsum(onehot, axis=0)          # inclusive running counts
    # invalid rows never need masking here: their onehot row is zero, so
    # rank is garbage, but ``keep`` drops them before it can matter
    rank = jnp.take_along_axis(incl, pid[:, None], axis=1)[:, 0] - 1
    keep = valid & (rank < bucket)
    slot = jnp.where(keep, pid * bucket + rank, n_parts * bucket)
    overflow = jnp.sum((valid & ~keep).astype(jnp.int32))
    return slot, overflow
