"""Pure-jnp oracle for radix partitioning."""
import jax.numpy as jnp


def radix_partition_ref(hashes, valid, *, n_parts: int, tile_n: int = 256):
    n = hashes.shape[0]
    tile_n = min(tile_n, n)
    n_tiles = n // tile_n
    pid = (hashes & jnp.uint32(n_parts - 1)).astype(jnp.int32)
    pid = jnp.where(valid, pid, n_parts)
    onehot = (pid[:, None] == jnp.arange(n_parts)[None, :]).astype(jnp.int32)
    hist = onehot.reshape(n_tiles, tile_n, n_parts).sum(axis=1)
    return pid, hist
