"""Jit'd wrappers with impl dispatch + internal padding.

Both entry points accept ANY row count: inputs are padded with invalid
rows up to the tile multiple before the kernel and sliced back after,
so callers never have to reason about tile granularity (padded rows are
invalid, which both kernels park/drop by construction).
"""
import jax.numpy as jnp

from .radix_partition import partition_scatter, radix_partition
from .ref import partition_scatter_ref, radix_partition_ref


def _pad_invalid(hashes, valid, tile_n):
    n = hashes.shape[0]
    pad = (-n) % min(tile_n, n)
    if pad == 0:
        return hashes, valid, n
    return (jnp.concatenate([hashes, jnp.zeros((pad,), hashes.dtype)]),
            jnp.concatenate([valid, jnp.zeros((pad,), bool)]), n)


def partition(hashes, valid, *, n_parts: int, impl: str = "ref",
              tile_n: int = 256, interpret: bool = True):
    h, v, n = _pad_invalid(hashes, valid, tile_n)
    if impl == "pallas":
        pid, hist = radix_partition(h, v, n_parts=n_parts,
                                    tile_n=tile_n, interpret=interpret)
    else:
        # the ref reshapes rows into tiles for the per-tile hist, so it
        # needs the same invalid-padding the kernel gets
        pid, hist = radix_partition_ref(h, v, n_parts=n_parts,
                                        tile_n=tile_n)
    return pid[:n], hist


def scatter_slots(hashes, valid, *, n_parts: int, bucket: int,
                  impl: str = "ref", tile_n: int = 256,
                  interpret: bool = True):
    """Fused partition + bucket-scatter slots (DESIGN.md §14).  Returns
    (slot (N,) int32 — ``n_parts * bucket`` is the drop slot — and the
    scalar count of valid rows that overflowed their bucket)."""
    if impl == "pallas" and n_parts & (n_parts - 1) == 0:
        h, v, n = _pad_invalid(hashes, valid, tile_n)
        slot, ovf = partition_scatter(h, v, n_parts=n_parts, bucket=bucket,
                                      tile_n=tile_n, interpret=interpret)
        return slot[:n], ovf
    return partition_scatter_ref(hashes, valid, n_parts=n_parts,
                                 bucket=bucket, tile_n=tile_n)
