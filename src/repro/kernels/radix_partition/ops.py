"""Jit'd wrapper with impl dispatch."""
from .radix_partition import radix_partition
from .ref import radix_partition_ref


def partition(hashes, valid, *, n_parts: int, impl: str = "ref",
              tile_n: int = 256, interpret: bool = True):
    if impl == "pallas":
        return radix_partition(hashes, valid, n_parts=n_parts,
                               tile_n=tile_n, interpret=interpret)
    return radix_partition_ref(hashes, valid, n_parts=n_parts,
                               tile_n=tile_n)
