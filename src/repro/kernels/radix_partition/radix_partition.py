"""Radix partitioning (hash binning) as a Pallas TPU kernel.

The shuffle-preparation hot spot: every row gets a partition id
``h & (P-1)`` and the all-to-all needs per-tile histograms to compute send
offsets.  GPU radix partitioning uses shared-memory atomics; the
TPU-native histogram is a one-hot matmul on the MXU:

    hist[tile] = sum_i onehot(pid_i, P)            (P,)

computed as ``ones(1,TN) @ onehot`` so the reduction runs on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _radix_kernel(hash_ref, valid_ref, pid_ref, hist_ref, *, tile_n,
                  n_parts):
    h = hash_ref[0]                                  # (TN,) uint32
    valid = valid_ref[0].astype(jnp.bool_)
    pid = (h & jnp.uint32(n_parts - 1)).astype(jnp.int32)
    pid = jnp.where(valid, pid, n_parts)             # park invalid
    pid_ref[0] = pid
    onehot = (pid[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (tile_n, n_parts), 1))
    hist_ref[0] = jnp.sum(onehot.astype(jnp.float32), axis=0,
                          dtype=jnp.float32).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_parts", "tile_n",
                                             "interpret"))
def radix_partition(hashes, valid, *, n_parts: int, tile_n: int = 256,
                    interpret: bool = False):
    """hashes: (N,) uint32; valid: (N,) bool; n_parts power of two.
    Returns (pid (N,) int32 with invalid rows = n_parts,
             hist (n_tiles, n_parts) int32)."""
    assert n_parts & (n_parts - 1) == 0
    n = hashes.shape[0]
    tile_n = min(tile_n, n)
    assert n % tile_n == 0
    n_tiles = n // tile_n

    pid, hist = pl.pallas_call(
        functools.partial(_radix_kernel, tile_n=tile_n, n_parts=n_parts),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),
            pl.BlockSpec((1, n_parts), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, tile_n), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, n_parts), jnp.int32),
        ],
        interpret=interpret,
    )(hashes.reshape(n_tiles, tile_n), valid.reshape(n_tiles, tile_n))
    return pid.reshape(n), hist


def _scatter_kernel(hash_ref, valid_ref, slot_ref, ovf_ref, count_ref, *,
                    tile_n, n_parts, bucket):
    """Fused binning + bucket-slot assignment over one tile.

    The per-destination running counts live in VMEM scratch and carry
    across the sequential grid (the accumulation pattern): tile i sees
    the totals of tiles 0..i-1, so each row's rank is its global arrival
    rank — identical to what a stable sort by destination would give."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)

    h = hash_ref[0]                                  # (TN,) uint32
    valid = valid_ref[0].astype(jnp.bool_)
    pid = (h & jnp.uint32(n_parts - 1)).astype(jnp.int32)
    pid = jnp.where(valid, pid, n_parts)             # park invalid
    iota = jax.lax.broadcasted_iota(jnp.int32, (tile_n, n_parts), 1)
    onehot = ((pid[:, None] == iota) & valid[:, None]).astype(jnp.int32)
    incl = jnp.cumsum(onehot, axis=0)                # per-tile running count
    base = count_ref[0]                              # carried totals (P,)
    # exclusive rank = carried base + in-tile count before this row;
    # the onehot mask selects the row's own destination column
    rank = jnp.sum((incl - onehot + base[None, :]) * onehot, axis=1)
    keep = valid & (rank < bucket)
    slot_ref[0] = jnp.where(keep, pid * bucket + rank, n_parts * bucket)
    ovf_ref[0, 0] = jnp.sum((valid & ~keep).astype(jnp.int32))
    count_ref[0] = base + incl[tile_n - 1]


@functools.partial(jax.jit, static_argnames=("n_parts", "bucket", "tile_n",
                                             "interpret"))
def partition_scatter(hashes, valid, *, n_parts: int, bucket: int,
                      tile_n: int = 256, interpret: bool = False):
    """hashes: (N,) uint32; valid: (N,) bool; n_parts power of two.
    Returns (slot (N,) int32 in [0, n_parts*bucket] with n_parts*bucket
    the drop slot, overflow () int32) — see ``partition_scatter_ref``."""
    assert n_parts & (n_parts - 1) == 0
    n = hashes.shape[0]
    tile_n = min(tile_n, n)
    assert n % tile_n == 0
    n_tiles = n // tile_n

    slot, ovf = pl.pallas_call(
        functools.partial(_scatter_kernel, tile_n=tile_n, n_parts=n_parts,
                          bucket=bucket),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, tile_n), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, n_parts), jnp.int32)],
        interpret=interpret,
    )(hashes.reshape(n_tiles, tile_n), valid.reshape(n_tiles, tile_n))
    return slot.reshape(n), jnp.sum(ovf)
