"""Radix partitioning (hash binning) as a Pallas TPU kernel.

The shuffle-preparation hot spot: every row gets a partition id
``h & (P-1)`` and the all-to-all needs per-tile histograms to compute send
offsets.  GPU radix partitioning uses shared-memory atomics; the
TPU-native histogram is a one-hot matmul on the MXU:

    hist[tile] = sum_i onehot(pid_i, P)            (P,)

computed as ``ones(1,TN) @ onehot`` so the reduction runs on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _radix_kernel(hash_ref, valid_ref, pid_ref, hist_ref, *, tile_n,
                  n_parts):
    h = hash_ref[0]                                  # (TN,) uint32
    valid = valid_ref[0].astype(jnp.bool_)
    pid = (h & jnp.uint32(n_parts - 1)).astype(jnp.int32)
    pid = jnp.where(valid, pid, n_parts)             # park invalid
    pid_ref[0] = pid
    onehot = (pid[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (tile_n, n_parts), 1))
    hist_ref[0] = jnp.sum(onehot.astype(jnp.float32), axis=0,
                          dtype=jnp.float32).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_parts", "tile_n",
                                             "interpret"))
def radix_partition(hashes, valid, *, n_parts: int, tile_n: int = 256,
                    interpret: bool = False):
    """hashes: (N,) uint32; valid: (N,) bool; n_parts power of two.
    Returns (pid (N,) int32 with invalid rows = n_parts,
             hist (n_tiles, n_parts) int32)."""
    assert n_parts & (n_parts - 1) == 0
    n = hashes.shape[0]
    tile_n = min(tile_n, n)
    assert n % tile_n == 0
    n_tiles = n // tile_n

    pid, hist = pl.pallas_call(
        functools.partial(_radix_kernel, tile_n=tile_n, n_parts=n_parts),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_n), lambda i: (i, 0)),
            pl.BlockSpec((1, n_parts), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, tile_n), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, n_parts), jnp.int32),
        ],
        interpret=interpret,
    )(hashes.reshape(n_tiles, tile_n), valid.reshape(n_tiles, tile_n))
    return pid.reshape(n), hist
