"""Pure-jnp oracle for flash attention."""
import jax.numpy as jnp


def attention_ref(q, k, v, kv_len=None, *, causal=True, q_offset=None):
    """q: (BH, Sq, D); k, v: (BH, Skv, D)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    if q_offset is None:
        q_offset = skv - sq
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    s = jnp.einsum("bqd,bkd->bqk", qf, kf) / (d ** 0.5)
    k_pos = jnp.arange(skv)[None, None, :]
    q_pos = (jnp.arange(sq) + q_offset)[None, :, None]
    mask = jnp.ones((1, sq, skv), bool)
    if kv_len is not None:
        mask = mask & (k_pos < jnp.asarray(kv_len).reshape(-1, 1, 1))
    if causal:
        mask = mask & (k_pos <= q_pos)
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)
