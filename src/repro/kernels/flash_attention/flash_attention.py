"""Blockwise online-softmax attention (FlashAttention) as a Pallas TPU
kernel.

TPU adaptation notes (vs the CUDA original): tiles are sized for VMEM and
the 128-lane MXU rather than SM shared memory — block shapes are multiples
of 128 in the lane dimension; the online-softmax carry (m, l, acc) lives
in VMEM scratch and the KV loop is the innermost *grid* dimension
(sequential on TPU), not a warp-level loop.

Layout: q (BH, Sq, D), k/v (BH, Skv, D) — the ops wrapper folds batch and
heads.  Causal masking supports a query offset (decode: queries sit at the
end of the KV timeline) and a valid KV length (masking cache padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref,
                 m_scr, l_scr, acc_scr, *, block_q, block_k,
                 causal, q_offset, scale):
    kv_j = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(kv_j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_i = pl.program_id(1)
    q_start = q_i * block_q + q_offset
    k_start = kv_j * block_k

    run = True
    if causal:
        # skip blocks strictly above the diagonal
        run = k_start <= q_start + block_q - 1

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (bq, D)
        k = k_ref[0].astype(jnp.float32)            # (bk, D)
        v = v_ref[0].astype(jnp.float32)            # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kv_len_ref[0]
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                          # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
        l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kv_j == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_k", "q_offset", "interpret"))
def flash_attention_bhsd(q, k, v, kv_len=None, *, causal=True,
                         q_offset=None, block_q=128, block_k=128,
                         interpret=False):
    """q: (BH, Sq, D); k, v: (BH, Skv, D); kv_len: int32 () or (1,)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    if q_offset is None:
        q_offset = skv - sq
    if kv_len is None:
        kv_len = jnp.array([skv], jnp.int32)
    else:
        kv_len = jnp.asarray(kv_len, jnp.int32).reshape((1,))
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k,
        causal=causal, q_offset=q_offset, scale=scale)

    grid = (bh, sq // block_q, skv // block_k)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_q, d), lambda b, i, j, _: (b, i, 0)),
                pl.BlockSpec((1, block_k, d), lambda b, i, j, _: (b, j, 0)),
                pl.BlockSpec((1, block_k, d), lambda b, i, j, _: (b, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_q, d),
                                   lambda b, i, j, _: (b, i, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(kv_len, q, k, v)
