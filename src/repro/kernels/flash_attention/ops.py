"""Jit'd public wrapper: GQA-aware multihead attention on (B, H, S, D)."""
from __future__ import annotations

import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd
from .ref import attention_ref


def _fold(x):
    b, h, s, d = x.shape
    return x.reshape(b * h, s, d)


def mha(q, k, v, kv_len=None, *, causal=True, q_offset=None,
        impl="ref", block_q=128, block_k=128, interpret=True):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0.

    impl: "ref" (jnp oracle — default on CPU) or "pallas" (the TPU
    kernel; interpret=True executes it in Python for validation).
    A production deployment folds the GQA group into the q tile; here we
    broadcast KV heads, which is bit-identical.
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf, kf, vf = _fold(q), _fold(k), _fold(v)
    if impl == "pallas":
        out = flash_attention_bhsd(qf, kf, vf, kv_len, causal=causal,
                                   q_offset=q_offset, block_q=block_q,
                                   block_k=block_k, interpret=interpret)
    else:
        out = attention_ref(qf, kf, vf, kv_len, causal=causal,
                            q_offset=q_offset)
    return out.reshape(b, hq, sq, d)
