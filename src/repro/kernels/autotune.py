"""Autotuner for exchange/kernel configuration knobs (DESIGN.md §14).

Two halves:

* **TuningTable** — a persisted JSON map from ``op|rows-bucket|dtype``
  keys to ``{param: value}`` choices.  Rows are bucketed to the next
  power of two so one tuning run covers a whole size class.  Loading a
  missing or corrupt table yields an empty one (graceful fallback to
  defaults), and save -> load round-trips bit-exactly.

* **tune()** — pick a value for one parameter: a caller-supplied
  roofline price function (``roofline.analysis.predict_tile_time_s``
  underneath) prunes the candidate grid to the ``top_k`` cheapest
  predictions, then an injectable ``measure`` callback times those few
  for real and the median-fastest wins.  Ties break toward the earlier
  candidate, so selection is deterministic under a deterministic
  measurement stub.

Runtime consumers call :func:`choose`, which returns the caller's
default unless tuning is enabled (``RESTORE_AUTOTUNE=1``) AND the table
has an entry — so the tuner is inert by default and dropping the table
file merely reverts every knob to its built-in default.  Tuned knobs:

* ``("partition_scatter", rows, "uint32") / "tile_n"`` — Pallas grid
  tile of the fused partition+scatter kernel (dataflow/shuffle.py).
* ``("exchange", 0, "row") / "skew"`` — the exchange's per-destination
  bucket skew factor; rows=0 is the global size class (the executor
  does not know the input size at engine construction).
"""
from __future__ import annotations

import json
import os
import statistics
from typing import Callable, Dict, Optional, Sequence

DEFAULT_TABLE_ENV = "RESTORE_AUTOTUNE_TABLE"
ENABLE_ENV = "RESTORE_AUTOTUNE"
DEFAULT_TABLE_PATH = os.path.join(
    os.path.expanduser("~"), ".cache", "restore_tuning.json")


def rows_bucket(rows: int) -> int:
    """Next power of two >= rows (0 stays 0: the global size class)."""
    rows = int(rows)
    return 1 << (rows - 1).bit_length() if rows > 0 else 0


class TuningTable:
    """``{key: {param: value}}`` with JSON persistence."""

    def __init__(self, entries: Optional[Dict[str, Dict]] = None):
        self.entries: Dict[str, Dict] = dict(entries or {})

    @staticmethod
    def key(op: str, rows: int, dtype: str) -> str:
        return f"{op}|{rows_bucket(rows)}|{dtype}"

    def get(self, op: str, rows: int, dtype: str, param: str,
            default=None):
        ent = self.entries.get(self.key(op, rows, dtype))
        if ent is None:
            return default
        return ent.get(param, default)

    def put(self, op: str, rows: int, dtype: str, param: str,
            value) -> None:
        self.entries.setdefault(self.key(op, rows, dtype), {})[param] = value

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(self.entries, f, indent=1, sort_keys=True)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "TuningTable":
        try:
            with open(path) as f:
                data = json.load(f)
            if not isinstance(data, dict):
                raise ValueError("tuning table root must be an object")
            return cls({k: dict(v) for k, v in data.items()
                        if isinstance(v, dict)})
        except (OSError, ValueError):
            return cls()


def enabled() -> bool:
    return os.environ.get(ENABLE_ENV, "") not in ("", "0")


def table_path() -> str:
    return os.environ.get(DEFAULT_TABLE_ENV, DEFAULT_TABLE_PATH)


_cache: Dict[str, TuningTable] = {}


def get_table(refresh: bool = False) -> TuningTable:
    path = table_path()
    if refresh or path not in _cache:
        _cache[path] = TuningTable.load(path)
    return _cache[path]


def choose(op: str, rows: int, dtype: str, param: str, default):
    """The runtime hook: tuned value if tuning is on and the table has
    one, the caller's default otherwise.  The returned value is coerced
    to the default's type so a hand-edited table cannot change a knob's
    kind (e.g. float skew vs int tile)."""
    if not enabled():
        return default
    v = get_table().get(op, rows, dtype, param, default)
    try:
        return type(default)(v)
    except (TypeError, ValueError):
        return default


def tune(op: str, rows: int, dtype: str, param: str,
         candidates: Sequence, measure: Callable[[object], float], *,
         table: Optional[TuningTable] = None,
         price: Optional[Callable[[object], float]] = None,
         top_k: int = 3, reps: int = 3):
    """Select a value for ``param`` and record it in ``table``.

    ``price(candidate) -> predicted seconds`` (roofline) prunes to the
    ``top_k`` cheapest candidates; ``measure(candidate) -> seconds`` is
    then run ``reps`` times per survivor and the median-fastest wins,
    first-listed winning ties.  Returns the chosen candidate."""
    cands = list(candidates)
    if not cands:
        raise ValueError("tune() needs at least one candidate")
    if price is not None and len(cands) > top_k:
        priced = sorted(range(len(cands)), key=lambda i: (price(cands[i]), i))
        cands = [cands[i] for i in priced[:top_k]]
    best, best_t = None, None
    for c in cands:
        t = statistics.median(measure(c) for _ in range(max(1, reps)))
        if best_t is None or t < best_t:
            best, best_t = c, t
    if table is not None:
        table.put(op, rows, dtype, param, best)
    return best


def scatter_tile_price(rows: int, n_parts: int,
                       dispatch_cost_s: float = 2e-6):
    """Roofline price function for the fused partition+scatter tile:
    bytes touched are fixed (hash + valid in, slot out), so the tile
    choice trades per-tile dispatch overhead against the VMEM-resident
    cumsum working set ``tile_n * n_parts`` — priced as extra HBM-class
    traffic once the working set spills past the tile's own rows."""
    from ..roofline.analysis import predict_tile_time_s

    def price(tile_n: int) -> float:
        n_tiles = max(1, rows // max(1, tile_n))
        data = rows * (4 + 1 + 4)
        working = n_tiles * tile_n * n_parts * 4
        return predict_tile_time_s(
            bytes_accessed=data + working,
            dispatch_overhead_s=n_tiles * dispatch_cost_s)
    return price
